//! Vendored stand-in for the `anyhow` crate (no external crates are
//! available offline — ARCHITECTURE.md §Substitutions). Implements the subset the
//! workspace uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`]
//! macros, and the [`Context`] extension on `Result` — including results
//! that already carry an [`Error`], mirroring upstream's `ext::StdError`
//! trick so `.context()` chains compose.
//!
//! Differences from upstream: the error is a flat context chain of
//! pre-rendered strings (no source/backtrace capture, no downcasting).
//! `{e}` prints the outermost message, `{e:#}` the full chain joined with
//! `": "`, and `{e:?}` an upstream-style "Caused by:" listing.

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chain error. `chain[0]` is the outermost (most recent) context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream, `Error` deliberately does NOT implement `std::error::Error`,
// which is what makes the blanket impls below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::msg(&error)
    }
}

/// Internal unification of "things that can become an [`Error`]": any
/// `std::error::Error`, or an [`Error`] itself (upstream's `ext::StdError`).
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::msg(&self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format args.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format args.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_modes() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        let d = format!("{e:?}");
        assert!(d.contains("outer") && d.contains("Caused by") && d.contains("inner"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "missing thing");
    }

    #[test]
    fn context_on_std_and_anyhow_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: missing thing");

        let r: Result<()> = Err(anyhow!("low level"));
        let e = r.with_context(|| format!("stage {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "stage 3: low level");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        assert!(v.context("absent").is_err());
        fn f() -> Result<u32> {
            bail!("boom {}", 7);
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 7");
    }
}
