//! Integration tests for the work-stealing executor subsystem: every
//! sched-backed driver (`evaluate_grid`, `simulate_grid`,
//! `ProgrammedCnn::forward`, raw `Executor::map`) must be bit-identical to
//! its sequential reference across worker counts and seeds — the executor
//! is a wall-clock optimisation, never a numerics change.

use newton::config::{ChipConfig, XbarParams};
use newton::pipeline::{des, evaluate, evaluate_grid_on};
use newton::prop_assert;
use newton::proptest_lite::check;
use newton::sched::{self, Executor};
use newton::workloads;
use newton::xbar::cnn::{random_images, MiniCnn};

#[test]
fn prop_executor_map_bit_identical_across_worker_counts() {
    check("sched-map-identity", 12, |rng| {
        let n = rng.below(200) as usize;
        let seed = rng.next_u64();
        let spins = 10 + rng.below(300) as usize;
        let want: Vec<u64> = (0..n)
            .map(|i| sched::spin_job(seed ^ i as u64, spins))
            .collect();
        for workers in [1usize, 2, 3, 8, 17] {
            let got =
                Executor::new(workers).map(n, |i| sched::spin_job(seed ^ i as u64, spins));
            prop_assert!(got == want, "stealing workers={workers} n={n}");
            let got = Executor::contiguous(workers)
                .map(n, |i| sched::spin_job(seed ^ i as u64, spins));
            prop_assert!(got == want, "contiguous workers={workers} n={n}");
        }
        Ok(())
    });
}

#[test]
fn prop_evaluate_grid_bit_identical_to_sequential() {
    let nets = workloads::suite();
    let chips = [ChipConfig::isaac(), ChipConfig::newton()];
    check("sched-evaluate-grid", 4, |rng| {
        let nn = 1 + rng.below(4) as usize;
        let start = rng.below((nets.len() - nn) as u64 + 1) as usize;
        let sub = &nets[start..start + nn];
        let workers = 1 + rng.below(12) as usize;
        let grid = evaluate_grid_on(sub, &chips, &Executor::new(workers));
        prop_assert!(grid.len() == chips.len(), "grid rows");
        for (ci, row) in grid.iter().enumerate() {
            prop_assert!(row.len() == sub.len(), "grid cols");
            for (ni, got) in row.iter().enumerate() {
                let want = evaluate(&sub[ni], &chips[ci]);
                prop_assert!(
                    got.net == want.net
                        && got.energy_per_op_pj == want.energy_per_op_pj
                        && got.throughput == want.throughput
                        && got.latency_us == want.latency_us
                        && got.area_mm2 == want.area_mm2,
                    "cell ({ci},{ni}) diverged at workers={workers}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simulate_grid_bit_identical_to_sequential() {
    let nets = [workloads::alexnet(), workloads::vgg_a(), workloads::resnet34()];
    let chips = [ChipConfig::isaac(), ChipConfig::newton()];
    check("sched-simulate-grid", 4, |rng| {
        let workers = 1 + rng.below(12) as usize;
        let n_images = 5 + rng.below(20) as usize;
        let grid = des::simulate_grid_on(&nets, &chips, n_images, &Executor::new(workers));
        for (ci, chip) in chips.iter().enumerate() {
            for (ni, net) in nets.iter().enumerate() {
                let want = des::simulate(net, chip, n_images);
                prop_assert!(
                    grid[ci][ni].throughput == want.throughput
                        && grid[ci][ni].latency_us == want.latency_us
                        && grid[ci][ni].n_stages == want.n_stages,
                    "cell ({ci},{ni}) diverged at workers={workers} n_images={n_images}"
                );
            }
        }
        Ok(())
    });
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
fn prop_programmed_cnn_forward_bit_identical_across_workers() {
    check("sched-cnn-forward", 2, |rng| {
        let cnn = MiniCnn::new(rng.next_u64());
        let img = random_images(3, rng.next_u64());
        let programmed = cnn.program(&XbarParams::default(), false);
        let want = programmed.forward_seq(&img);
        for workers in [1usize, 2, 4, 9] {
            let got = programmed.forward_on(&img, &Executor::new(workers));
            prop_assert!(got.data == want.data, "workers={workers}");
        }
        Ok(())
    });
}

#[test]
fn oversubscribed_stress_is_deterministic() {
    // small in-test twin of the `newton sched-stress` CI smoke: correctness
    // asserts (completion + determinism) live inside sched::stress
    let stats = sched::stress(96, 3, 20_000);
    assert_eq!(stats.executed.iter().sum::<usize>(), 96);
    assert!(stats.workers >= 3);
}
