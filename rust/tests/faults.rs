//! Fault-tolerance integration tests: quarantine edges that need the full
//! crate surface — reinstall racing an in-flight batch, and the property
//! pin that fault-free serving with the health machinery armed is
//! bit-identical to the plain pipelined path.
//!
//! The unit-level quarantine edges (threshold-exact deviation, EWMA drift,
//! all-replicas-quarantined degradation) live next to the state machine in
//! `coordinator::health` and `coordinator::golden`.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use newton::config::AdcKind;
use newton::coordinator::{GoldenServer, HealthPolicy, HealthState};
use newton::faults::FaultPlan;
use newton::mapping::StagePolicy;
use newton::sched::Executor;
use newton::util::Rng;

fn images(n: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..32 * 32 * 3).map(|_| rng.below(256) as i32).collect())
        .collect()
}

/// Reinstall ("reprogram the crossbar") while batches are in flight: the
/// replica's RwLock write acquisition serialises against read-locked
/// forwards, so whichever install a batch observes, the served answer must
/// stay exact — the drifted replica's output is caught by the golden
/// comparison and re-run on the clean one, and the reinstalled replica
/// rejoins without a wrong answer ever escaping.
#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
fn reinstall_during_inflight_batches_never_serves_a_wrong_answer() {
    let policy = HealthPolicy {
        quarantine_after: 2,
        ..HealthPolicy::default()
    };
    let s = Arc::new(GoldenServer::replicated(0, AdcKind::Exact, 2, 2).with_health(policy));
    s.inject_cell_faults(0, &FaultPlan::drift(7, 0.05, 30));
    let imgs = images(16, 41); // 8 batches: plenty in flight around the reinstall
    let want = GoldenServer::replicated(0, AdcKind::Exact, 1, 2).infer(&imgs);

    let srv = Arc::clone(&s);
    let imgs2 = imgs.clone();
    // sequential executor: the race under test is serve vs reinstall, not
    // batch-vs-batch interleaving
    let worker = thread::spawn(move || srv.serve_batches_on(&imgs2, &Executor::new(1)));
    // land the reinstall mid-stream; exact timing is irrelevant — the
    // invariants below must hold wherever the write lock slots in
    thread::sleep(Duration::from_millis(2));
    s.reinstall(0);
    let reports = worker.join().unwrap();

    assert_eq!(reports.iter().map(|r| r.n_real).sum::<usize>(), 16);
    let mut got: Vec<Vec<i32>> = Vec::new();
    for r in &reports {
        assert_eq!(r.max_abs_err, 0, "batch {}: a drifted result was served", r.index);
        got.extend(r.logits.iter().cloned());
    }
    assert_eq!(got, want, "reinstall race changed the served numbers");

    let rep = s.health_report().unwrap();
    assert_eq!(rep.states.len(), 2);
    assert!(!rep.degraded, "clean replica 1 should keep the pool serviceable");
    // replica 0 was reinstalled: it must not be stuck quarantined — it is
    // on probation, re-earned healthy, or (if a drifted in-flight batch
    // was observed after the reset) back to suspect awaiting clean runs
    assert_ne!(
        rep.states[0],
        HealthState::Quarantined.as_u8(),
        "reinstalled replica left quarantined"
    );
    // replica 1 never drifted
    assert_eq!(rep.states[1], HealthState::Healthy.as_u8());
}

/// Property pin: with no faults injected, arming the health machinery on
/// the pipelined path changes nothing — the BatchReport stream (routing,
/// ids, logits, deviation) is bit-identical to the plain pipelined server,
/// and the monitor records zero re-runs and zero quarantines. This holds
/// both for exact configs (deviation is always zero) and for lossy
/// configs under a permissive threshold (benign ADC deviation must not be
/// misread as a fault).
#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
fn fault_free_health_serving_is_bit_identical_to_the_pipelined_path() {
    let permissive = HealthPolicy {
        deviation_threshold: i64::MAX,
        ..HealthPolicy::default()
    };
    let cases = [
        (AdcKind::Exact, HealthPolicy::default()),
        (AdcKind::Adaptive, permissive),
    ];
    for seed in [0u64, 3, 11] {
        for (kind, policy) in &cases {
            let imgs = images(5, seed.wrapping_mul(100) + 7); // 2.5 batches: tail padding
            let plain = GoldenServer::replicated(seed, *kind, 3, 2)
                .with_pipeline(StagePolicy::newton())
                .unwrap();
            let armed = GoldenServer::replicated(seed, *kind, 3, 2)
                .with_pipeline(StagePolicy::newton())
                .unwrap()
                .with_health(*policy);
            let want = plain.serve_batches(&imgs);
            let got = armed.serve_batches(&imgs);
            assert_eq!(want.len(), got.len());
            for (w, g) in want.iter().zip(&got) {
                let tag = format!("seed {seed} adc {} batch {}", kind.label(), w.index);
                assert_eq!(w.index, g.index, "{tag}");
                assert_eq!(w.replica, g.replica, "{tag}: health changed the routing");
                assert_eq!(w.ids, g.ids, "{tag}");
                assert_eq!(w.n_real, g.n_real, "{tag}");
                assert_eq!(w.logits, g.logits, "{tag}: health changed the numbers");
                assert_eq!(w.max_abs_err, g.max_abs_err, "{tag}: deviation report drifted");
            }
            let rep = armed.health_report().unwrap();
            assert_eq!(rep.reruns, 0, "fault-free run triggered re-runs");
            assert_eq!(rep.quarantines, 0, "fault-free run quarantined a replica");
            assert!(!rep.degraded);
            assert!(rep
                .states
                .iter()
                .all(|&b| b == HealthState::Healthy.as_u8()));
            // the stage map never re-derived away from the construction map
            assert_eq!(
                plain.pipeline_map().unwrap().assignment,
                armed.pipeline_map().unwrap().assignment,
                "health rebuilt the stage map without a quarantine"
            );
        }
    }
}
