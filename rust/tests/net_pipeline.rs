//! Protocol-conformance and concurrency tests for the event-driven
//! pipelined serving path (`serve-net --event-loop`, proto v4).
//!
//! The raw-socket tests speak hand-built v3/v4 frames so they pin the
//! wire contract itself (tag echo, completion-order replies, per-request
//! Busy, duplicate-tag fatality, torn-frame reassembly, half-close),
//! independent of any client library. The property tests pin that
//! pipelining is *only* a reordering: replies keyed by tag/id are
//! bit-identical to sequential serving across window x worker grids.
//! Heavy cases (golden engine, 1k connections) are release-gated like
//! the other serving tests.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use newton::config::AdcKind;
use newton::coordinator::{Batch, GoldenServer};
use newton::net::proto::{self, InferRequest, Msg};
use newton::net::{
    bench_image, load_generate_pipelined, scrape_statz, BenchConfig, Client, Engine, EngineBatch,
    EventLoopConfig, InferOutcome, NetServer, PipelinedClient, ServeConfig,
};

/// Cheap deterministic engine: per real row, logits are
/// `[sum(row), first element]` (same model as `tests/net.rs`).
#[derive(Clone)]
struct EchoEngine {
    elems: usize,
    capacity: usize,
}

impl EchoEngine {
    fn small() -> Self {
        EchoEngine { elems: 4, capacity: 2 }
    }
}

fn echo_logits(row: &[i32]) -> Vec<i32> {
    vec![row.iter().sum::<i32>(), row[0]]
}

impl Engine for EchoEngine {
    fn image_elems(&self) -> usize {
        self.elems
    }

    fn batch_capacity(&self) -> usize {
        self.capacity
    }

    fn n_replicas(&self) -> usize {
        1
    }

    fn describe(&self) -> String {
        "echo stub".to_string()
    }

    fn run(&self, _index: usize, b: &Batch) -> EngineBatch {
        let logits = (0..b.n_real)
            .map(|r| echo_logits(&b.data[r * self.elems..(r + 1) * self.elems]))
            .collect();
        EngineBatch {
            replica: 0,
            n_real: b.n_real,
            logits,
            max_abs_err: 0,
            cost: newton::obs::CostLedger::new(),
            energy_pj: 0.0,
        }
    }
}

/// Echo engine whose per-request service time is data-driven: each row
/// sleeps `row[0]` milliseconds. With capacity-1 batches and >1 dispatch
/// workers, a fast request submitted after a slow one completes first —
/// the lever every reordering test here pulls.
struct SleepyEngine;

impl Engine for SleepyEngine {
    fn image_elems(&self) -> usize {
        4
    }

    fn batch_capacity(&self) -> usize {
        1
    }

    fn n_replicas(&self) -> usize {
        1
    }

    fn describe(&self) -> String {
        "sleepy echo stub".to_string()
    }

    fn run(&self, _index: usize, b: &Batch) -> EngineBatch {
        let ms = b.data[0].max(0) as u64;
        std::thread::sleep(Duration::from_millis(ms));
        EchoEngine { elems: 4, capacity: 1 }.run(0, b)
    }
}

/// Start an event-loop server on an ephemeral port.
fn start_event(
    engine: Arc<dyn Engine>,
    max_inflight: usize,
    workers: usize,
    max_pipeline: usize,
) -> NetServer {
    NetServer::start(
        engine,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_inflight,
            batch_wait: Duration::from_millis(1),
            event_loop: Some(EventLoopConfig { workers, max_pipeline }),
            ..ServeConfig::default()
        },
    )
    .expect("bind ephemeral port")
}

/// Raw test socket: nodelay (the tests measure ordering, not Nagle) and
/// a read timeout so a server bug fails the test instead of hanging it.
fn raw_connect(addr: std::net::SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_nodelay(true).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s
}

fn infer_msg(id: u64, image: &[i32]) -> Msg {
    Msg::Infer(InferRequest {
        id,
        trace: 0x7000_0000 + id,
        image: image.to_vec(),
    })
}

/// Read one tagged reply and unwrap the `(tag, Reply)` shape.
fn read_reply(s: &mut TcpStream) -> (Option<u16>, proto::InferReply) {
    match proto::read_msg_tagged(s).expect("read reply frame") {
        (tag, Msg::Reply(r)) => (tag, r),
        (tag, other) => panic!("want Reply (tag {tag:?}), got {other:?}"),
    }
}

#[test]
fn v3_blocking_client_is_served_byte_identically_by_the_event_loop() {
    let server = start_event(Arc::new(EchoEngine::small()), 16, 2, 8);
    let addr = server.local_addr();

    // wire-level pin first: an untagged (v3) request must come back in an
    // untagged frame — version byte 3, reserved bytes zero — so a v3-era
    // peer that validates its reserved bytes keeps working unchanged
    let mut raw = raw_connect(addr);
    raw.write_all(&proto::encode_frame(&infer_msg(1, &[1, 2, 3, 4]))).unwrap();
    let mut header = [0u8; proto::HEADER_LEN];
    raw.read_exact(&mut header).unwrap();
    assert_eq!(header[4], proto::VERSION_UNTAGGED, "v3 request answered with a non-v3 frame");
    assert_eq!(&header[6..8], &[0, 0], "v3 reply put bytes in the reserved field");
    let fh = proto::parse_header_tagged(&header).unwrap();
    let mut payload = vec![0u8; fh.len];
    raw.read_exact(&mut payload).unwrap();
    match proto::decode_payload(fh.ty, &payload).unwrap() {
        Msg::Reply(r) => {
            assert_eq!(r.id, 1);
            assert_eq!(r.logits, echo_logits(&[1, 2, 3, 4]));
        }
        other => panic!("want Reply, got {other:?}"),
    }
    drop(raw);

    // then the stock blocking client end to end: infer, stats, shutdown
    let mut c = Client::connect(addr).unwrap();
    for i in 0..5u64 {
        let img = [i as i32, 2, 3, 4];
        match c.infer(i, &img).unwrap() {
            InferOutcome::Ok(r) => {
                assert_eq!(r.id, i);
                assert_eq!(r.logits, echo_logits(&img));
                assert_eq!(r.max_abs_err, 0);
            }
            InferOutcome::Busy => panic!("busy under a 16-deep limit"),
        }
    }
    let stats = c.stats().unwrap();
    assert_eq!(stats.served, 6);
    assert_eq!(stats.busy, 0);
    c.shutdown().unwrap();
    let final_stats = server.join();
    assert_eq!(final_stats.served, 6);
    assert!(TcpStream::connect(addr).is_err(), "listener survived the drain");
}

#[test]
fn tagged_replies_return_in_completion_order_not_submission_order() {
    // one connection, two tagged requests: the first sleeps 400ms, the
    // second 1ms. With 2 dispatch workers and capacity-1 batches both run
    // concurrently, so the fast one's reply MUST come back first — the
    // defining observable of the pipelined path
    let server = start_event(Arc::new(SleepyEngine), 16, 2, 8);
    let mut s = raw_connect(server.local_addr());

    proto::write_msg_tagged(&mut s, &infer_msg(10, &[400, 0, 0, 0]), 7).unwrap();
    proto::write_msg_tagged(&mut s, &infer_msg(11, &[1, 0, 0, 0]), 9).unwrap();

    let (tag_a, ra) = read_reply(&mut s);
    let (tag_b, rb) = read_reply(&mut s);
    assert_eq!(tag_a, Some(9), "fast request did not overtake the slow one");
    assert_eq!(ra.id, 11);
    assert_eq!(ra.logits, echo_logits(&[1, 0, 0, 0]));
    assert_eq!(tag_b, Some(7));
    assert_eq!(rb.id, 10);
    assert_eq!(rb.logits, echo_logits(&[400, 0, 0, 0]));
    drop(s);
    server.shutdown();
}

#[test]
fn duplicate_inflight_tag_is_a_fatal_protocol_error() {
    // two live requests under one tag make the reply stream undecodable,
    // so the second is a protocol error and the connection dies — but the
    // already-admitted request still gets its reply before the close
    let server = start_event(Arc::new(SleepyEngine), 16, 2, 8);
    let mut s = raw_connect(server.local_addr());

    proto::write_msg_tagged(&mut s, &infer_msg(1, &[300, 0, 0, 0]), 5).unwrap();
    proto::write_msg_tagged(&mut s, &infer_msg(2, &[1, 0, 0, 0]), 5).unwrap();

    match proto::read_msg_tagged(&mut s).unwrap() {
        (Some(5), Msg::Error(e)) => {
            assert_eq!(e.code, proto::ERR_MALFORMED);
            assert!(e.message.contains("duplicate"), "{}", e.message);
        }
        other => panic!("want tagged Error, got {other:?}"),
    }
    // the first request was already in flight; drain semantics still owe
    // us its reply, then EOF
    let (tag, r) = read_reply(&mut s);
    assert_eq!(tag, Some(5));
    assert_eq!(r.id, 1);
    let mut tail = Vec::new();
    s.read_to_end(&mut tail).unwrap();
    assert!(tail.is_empty(), "server kept talking after a fatal tag error");

    // the server itself is unharmed
    let mut c = Client::connect(server.local_addr()).unwrap();
    assert!(matches!(c.infer(9, &[0, 1, 1, 1]), Ok(InferOutcome::Ok(_))));
    let stats = c.stats().unwrap();
    assert_eq!(stats.proto_errors, 1);
    server.shutdown();
}

#[test]
fn over_window_requests_get_per_request_busy_and_connection_survives() {
    // window of 2: two slow requests fill it, the third gets a *tagged*
    // Busy immediately (per-request backpressure, not a connection
    // verdict), the window's worth completes normally, and the freed
    // window serves a fourth request on the same socket
    let server = start_event(Arc::new(SleepyEngine), 16, 2, 2);
    let mut s = raw_connect(server.local_addr());

    proto::write_msg_tagged(&mut s, &infer_msg(1, &[300, 0, 0, 0]), 1).unwrap();
    proto::write_msg_tagged(&mut s, &infer_msg(2, &[300, 0, 0, 0]), 2).unwrap();
    proto::write_msg_tagged(&mut s, &infer_msg(3, &[1, 0, 0, 0]), 3).unwrap();

    // the refusal is immediate, long before the slow pair completes
    match proto::read_msg_tagged(&mut s).unwrap() {
        (Some(3), Msg::Busy) => {}
        other => panic!("want tagged Busy for the over-window request, got {other:?}"),
    }
    let (ta, _) = read_reply(&mut s);
    let (tb, _) = read_reply(&mut s);
    let mut served: Vec<u16> = vec![ta.unwrap(), tb.unwrap()];
    served.sort_unstable();
    assert_eq!(served, vec![1, 2], "the in-window pair must complete untouched");

    // same connection, freed window: tag 3 is reusable and served
    proto::write_msg_tagged(&mut s, &infer_msg(4, &[2, 0, 0, 0]), 3).unwrap();
    let (tag, r) = read_reply(&mut s);
    assert_eq!(tag, Some(3));
    assert_eq!(r.logits, echo_logits(&[2, 0, 0, 0]));
    drop(s);

    let stats = server.shutdown();
    assert_eq!(stats.served, 3);
    assert!(stats.busy >= 1, "window Busy not counted");
}

#[test]
fn torn_frames_across_write_boundaries_are_reassembled() {
    // frames arrive however TCP segments them: a header split mid-way, a
    // payload dribbled in two pieces, and two frames glued so the second
    // starts mid-read. The parser must reassemble all of it
    let server = start_event(Arc::new(EchoEngine::small()), 16, 1, 8);
    let mut s = raw_connect(server.local_addr());

    let f1 = proto::encode_frame_tagged(&infer_msg(1, &[1, 2, 3, 4]), 21);
    let f2 = proto::encode_frame_tagged(&infer_msg(2, &[5, 6, 7, 8]), 22);
    let glued: Vec<u8> = f1.iter().chain(f2.iter()).copied().collect();
    // cut points: inside f1's header, inside f1's payload, inside f2
    let cuts = [5, proto::HEADER_LEN + 3, f1.len() + 9, glued.len()];
    let mut at = 0;
    for &cut in &cuts {
        s.write_all(&glued[at..cut]).unwrap();
        s.flush().unwrap();
        at = cut;
        std::thread::sleep(Duration::from_millis(30));
    }

    let (tag_a, ra) = read_reply(&mut s);
    assert_eq!(tag_a, Some(21));
    assert_eq!(ra.logits, echo_logits(&[1, 2, 3, 4]));
    let (tag_b, rb) = read_reply(&mut s);
    assert_eq!(tag_b, Some(22));
    assert_eq!(rb.logits, echo_logits(&[5, 6, 7, 8]));
    drop(s);
    let stats = server.shutdown();
    assert_eq!(stats.proto_errors, 0, "torn-but-complete frames are not errors");
}

#[test]
fn half_closed_connections_still_receive_all_replies() {
    let server = start_event(Arc::new(EchoEngine::small()), 16, 2, 8);
    let addr = server.local_addr();

    // v4: submit a burst, shutdown(Write), then collect every reply
    let mut s = raw_connect(addr);
    for i in 0..3u64 {
        proto::write_msg_tagged(&mut s, &infer_msg(i, &[i as i32, 0, 0, 0]), 30 + i as u16)
            .unwrap();
    }
    s.shutdown(Shutdown::Write).unwrap();
    let mut tags: Vec<u16> = (0..3).map(|_| read_reply(&mut s).0.unwrap()).collect();
    tags.sort_unstable();
    assert_eq!(tags, vec![30, 31, 32]);
    let mut tail = Vec::new();
    s.read_to_end(&mut tail).unwrap();
    assert!(tail.is_empty(), "server wrote past the last owed reply");

    // v3: two strictly-serial requests buffered behind one write, then a
    // half-close — the second must still be parsed after the first's
    // reply clears the serial window (regression: the loop re-parses
    // buffered bytes when an untagged reply completes, because no new
    // readable event will ever arrive on a half-closed socket)
    let mut s = raw_connect(addr);
    let mut burst = proto::encode_frame(&infer_msg(10, &[9, 0, 0, 0]));
    burst.extend_from_slice(&proto::encode_frame(&infer_msg(11, &[8, 0, 0, 0])));
    s.write_all(&burst).unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    let (t1, r1) = read_reply(&mut s);
    let (t2, r2) = read_reply(&mut s);
    assert_eq!((t1, r1.id), (None, 10), "v3 replies are untagged and in order");
    assert_eq!((t2, r2.id), (None, 11));
    drop(s);

    let stats = server.shutdown();
    assert_eq!(stats.served, 5);
    assert_eq!(stats.proto_errors, 0);
}

#[test]
fn mid_frame_disconnect_counts_a_proto_error_and_server_survives() {
    let server = start_event(Arc::new(EchoEngine::small()), 16, 1, 8);
    let addr = server.local_addr();
    {
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(&proto::MAGIC).unwrap(); // half a header, then gone
    }
    {
        let _clean = TcpStream::connect(addr).unwrap(); // zero bytes is fine
    }
    std::thread::sleep(Duration::from_millis(300));
    let mut c = Client::connect(addr).unwrap();
    assert!(matches!(c.infer(1, &[2, 2, 2, 2]), Ok(InferOutcome::Ok(_))));
    let stats = c.stats().unwrap();
    assert_eq!(stats.proto_errors, 1, "mid-frame cut counts, clean close does not");
    server.shutdown();
}

#[test]
fn slow_reader_does_not_stall_other_connections() {
    // connection A pipelines a window of requests and never reads a
    // byte of its replies; connection B's round trips must stay prompt.
    // (With per-connection write buffering plus the write-cap read pause,
    // A can only ever hurt A.)
    let server = start_event(Arc::new(SleepyEngine), 32, 2, 16);
    let addr = server.local_addr();

    let mut stuck = raw_connect(addr);
    for i in 0..8u64 {
        proto::write_msg_tagged(&mut stuck, &infer_msg(i, &[50, 0, 0, 0]), 1 + i as u16).unwrap();
    }
    // A's replies pile up unread. B meanwhile gets served immediately.
    let mut c = Client::connect(addr).unwrap();
    for i in 0..3u64 {
        let t0 = std::time::Instant::now();
        match c.infer(100 + i, &[1, 0, 0, 0]).unwrap() {
            InferOutcome::Ok(r) => assert_eq!(r.logits, echo_logits(&[1, 0, 0, 0])),
            InferOutcome::Busy => panic!("busy under a 32-deep limit"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "round trip behind a non-reading peer took {:?}",
            t0.elapsed()
        );
    }
    drop(stuck);
    server.shutdown();
}

#[test]
fn pipelined_replies_are_a_tag_keyed_permutation_of_sequential_replies() {
    // the property pin, on the cheap engine so it runs in debug too:
    // across a window x worker grid, pipelined serving may only *reorder*
    // completions — replies keyed by request id must be exactly the
    // sequential client's answers, every id exactly once
    const N: u64 = 40;
    let images: Vec<Vec<i32>> = (0..N).map(|i| vec![i as i32, 1, 2, 3]).collect();

    // sequential reference pass (blocking v3 client, its own server)
    let server = start_event(Arc::new(EchoEngine::small()), 64, 1, 1);
    let mut c = Client::connect(server.local_addr()).unwrap();
    let sequential: Vec<Vec<i32>> = (0..N)
        .map(|i| match c.infer(i, &images[i as usize]).unwrap() {
            InferOutcome::Ok(r) => r.logits,
            InferOutcome::Busy => panic!("busy"),
        })
        .collect();
    server.shutdown();

    for &workers in &[1usize, 2, 4] {
        for &window in &[1usize, 8, 32] {
            let server = start_event(Arc::new(EchoEngine::small()), 64, workers, 32);
            let mut p = PipelinedClient::connect(server.local_addr(), window).unwrap();
            let mut got: Vec<Option<Vec<i32>>> = vec![None; N as usize];
            let collect = |r: newton::net::TaggedReply, got: &mut Vec<Option<Vec<i32>>>| {
                match r.outcome {
                    InferOutcome::Ok(reply) => {
                        let slot = &mut got[reply.id as usize];
                        assert!(slot.is_none(), "id {} answered twice", reply.id);
                        *slot = Some(reply.logits);
                    }
                    InferOutcome::Busy => panic!("window-paced submit saw Busy"),
                }
            };
            for i in 0..N {
                p.submit(i, &images[i as usize]).unwrap();
                while let Some(r) = p.ready() {
                    collect(r, &mut got);
                }
            }
            for r in p.drain().unwrap() {
                collect(r, &mut got);
            }
            let got: Vec<Vec<i32>> = got
                .into_iter()
                .enumerate()
                .map(|(i, g)| g.unwrap_or_else(|| panic!("id {i} never answered")))
                .collect();
            assert_eq!(
                got, sequential,
                "pipelining changed answers (window {window}, workers {workers})"
            );
            let stats = server.shutdown();
            assert_eq!(stats.served, N, "window {window}, workers {workers}");
        }
    }
}

#[test]
fn event_loop_metrics_ride_the_stats_frame() {
    let server = start_event(Arc::new(EchoEngine::small()), 16, 1, 8);
    let mut p = PipelinedClient::connect(server.local_addr(), 4).unwrap();
    for i in 0..6u64 {
        p.submit(i, &[i as i32, 0, 0, 0]).unwrap();
    }
    assert_eq!(p.drain().unwrap().len(), 6);
    let stats = p.stats().unwrap();
    // obs counters are process-global, so assert presence and floor, not
    // exact values (other tests in this binary bump them too)
    for name in ["net.evloop.wakeups", "net.evloop.accepts", "net.evloop.completions"] {
        assert!(
            stats.metrics.iter().any(|(k, v)| k == name && *v >= 1),
            "{name} missing from the stats metrics block: {:?}",
            stats.metrics
        );
    }
    server.shutdown();
}

#[test]
fn admin_scrape_during_drain_still_answers() {
    // regression for the admin busy-poll fix: the admin plane is
    // readiness-driven and must keep answering while the serving plane
    // drains in-flight work (it stops only after the drain completes)
    let server = NetServer::start(
        Arc::new(SleepyEngine),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            admin_addr: Some("127.0.0.1:0".to_string()),
            max_inflight: 16,
            batch_wait: Duration::from_millis(1),
            event_loop: Some(EventLoopConfig { workers: 2, max_pipeline: 8 }),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let admin = server.admin_addr().expect("admin plane requested but not bound");

    let mut p = PipelinedClient::connect(server.local_addr(), 1).unwrap();
    p.submit(1, &[800, 0, 0, 0]).unwrap();
    std::thread::sleep(Duration::from_millis(150)); // let it dispatch

    let mut ctl = Client::connect(server.local_addr()).unwrap();
    ctl.shutdown().unwrap(); // ack arrives as soon as the drain flag is set

    // the drain now waits on the 800ms sleeper; the admin plane must
    // still answer a scrape in the meantime
    let body = scrape_statz(admin, Duration::from_secs(2))
        .expect("admin scrape during drain went unanswered");
    assert!(body.contains("newton_served"), "scrape lost its gauges:\n{body}");

    // drain semantics: the in-flight request is still owed its reply
    let r = p.recv().unwrap();
    match r.outcome {
        InferOutcome::Ok(reply) => assert_eq!(reply.logits, echo_logits(&[800, 0, 0, 0])),
        InferOutcome::Busy => panic!("in-flight request bounced by the drain"),
    }
    let stats = server.join();
    assert_eq!(stats.served, 1);
    assert!(
        TcpStream::connect(admin).is_err(),
        "admin listener survived the drain"
    );
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
fn pipelined_bit_identical_to_golden_across_windows_and_workers() {
    // the acceptance gate: the pipelined socket path must not change a
    // single bit vs the in-process GoldenServer, at every point of the
    // window x worker grid. One engine Arc serves all nine servers.
    let engine = Arc::new(GoldenServer::replicated(0, AdcKind::Exact, 2, 8));
    let requests = 12usize;
    let seed = 21u64;
    let images: Vec<Vec<i32>> = (0..requests).map(|i| bench_image(seed, i)).collect();
    let want = GoldenServer::replicated(0, AdcKind::Exact, 1, 8).infer(&images);

    for &workers in &[1usize, 2, 4] {
        for &depth in &[1usize, 8, 32] {
            let server = start_event(engine.clone(), 64, workers, 32);
            let mut cfg = BenchConfig::new(&server.local_addr().to_string());
            cfg.requests = requests;
            cfg.seed = seed;
            let report = load_generate_pipelined(&cfg, depth).unwrap();
            assert_eq!(report.requests, requests);
            assert_eq!(
                report.worst_abs_err, 0,
                "exact pipelined serving deviated (depth {depth}, workers {workers})"
            );
            assert_eq!(
                report.logits, want,
                "pipelined path changed the numbers (depth {depth}, workers {workers})"
            );
            let stats = server.shutdown();
            assert_eq!(stats.served, requests as u64);
            assert_eq!(stats.worst_abs_err, 0);
        }
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
fn idle_connections_cost_file_descriptors_not_threads() {
    // the scale story behind the event loop: ~1k held-open connections
    // plus 8 active lanes, with the server's thread count bounded by its
    // fixed pools — opening connections must not spawn anything
    #[cfg(target_os = "linux")]
    fn thread_count() -> usize {
        std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
    }
    #[cfg(not(target_os = "linux"))]
    fn thread_count() -> usize {
        0 // no cheap portable probe; the connect/serve/drain path still runs
    }

    let server = start_event(Arc::new(EchoEngine::small()), 64, 2, 8);
    let addr = server.local_addr();
    let before = thread_count();

    let mut idle = Vec::with_capacity(1000);
    for i in 0..1000 {
        idle.push(TcpStream::connect(addr).expect("idle connect"));
        if i % 100 == 99 {
            std::thread::sleep(Duration::from_millis(10)); // let accepts drain
        }
    }
    std::thread::sleep(Duration::from_millis(200));
    let with_idle = thread_count();
    // slack covers threads other concurrently-running tests spawn, not
    // anything these connections are allowed to cost
    assert!(
        with_idle <= before + 12,
        "1000 idle connections grew the thread count {before} -> {with_idle}"
    );

    // 8 active lanes through the same server, around the idle crowd
    let lanes: Vec<_> = (0..8u64)
        .map(|lane| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for i in 0..10u64 {
                    let img = [(lane * 10 + i) as i32, 1, 2, 3];
                    match c.infer(lane * 10 + i, &img).unwrap() {
                        InferOutcome::Ok(r) => assert_eq!(r.logits, echo_logits(&img)),
                        InferOutcome::Busy => panic!("busy under a 64-deep limit"),
                    }
                }
            })
        })
        .collect();
    for l in lanes {
        l.join().unwrap();
    }
    let after_lanes = thread_count();
    assert!(
        after_lanes <= before + 12,
        "active lanes left threads behind: {before} -> {after_lanes}"
    );

    // clean drain: every idle socket observes EOF, the join returns
    let stats = server.shutdown();
    assert_eq!(stats.served, 80);
    for mut s in idle.into_iter().take(5) {
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = [0u8; 1];
        assert_eq!(s.read(&mut buf).unwrap(), 0, "drain left an idle connection open");
    }
}
