//! Property tests over the coordinator-side invariants (proptest_lite
//! harness — proptest itself is unavailable offline, ARCHITECTURE.md
//! §Substitutions): the numeric contract of the crossbar pipeline, the
//! D&C equivalences, ADC schedule invariants, batcher behaviour, and
//! mapping conservation laws.

use newton::adc::{AdaptiveSchedule, SarShares};
use newton::config::{ImaConfig, XbarParams};
use newton::coordinator::batcher::{Batcher, PendingRequest};
use newton::karatsuba::{karatsuba_vmm_raw, DncSchedule};
use newton::mapping::{Mapping, MappingPolicy};
use newton::prop_assert;
use newton::proptest_lite::check;
use newton::sched::Executor;
use newton::strassen::{strassen, strassen_with};
use newton::util::Rng;
use newton::workloads;
use newton::xbar::cnn::ProgrammedLinear;
use newton::xbar::reference::{
    biased_product_reference, vmm_raw_reference, vmm_raw_signed_reference,
};
use newton::xbar::{matmul, scale_clamp, vmm_raw, vmm_raw_signed, Matrix, ProgrammedXbar, RunScratch};

fn rand_matrix(rng: &mut Rng, rows: usize, cols: usize, lo: i64, hi: i64) -> Matrix {
    Matrix::from_fn(rows, cols, |_, _| rng.range_i64(lo, hi))
}

#[test]
fn prop_pipeline_equals_matmul() {
    let p = XbarParams::default();
    check("pipeline==matmul", 25, |rng| {
        let b = 1 + rng.below(4) as usize;
        let n = 1 + rng.below(24) as usize;
        let x = rand_matrix(rng, b, p.rows, 0, 1 << p.input_bits);
        let w = rand_matrix(rng, p.rows, n, -(1 << 15), 1 << 15);
        let got = vmm_raw(&x, &w, &p, false);
        let want = matmul(&x, &w);
        prop_assert!(got == want, "raw mismatch at {b}x{n}");
        Ok(())
    });
}

#[test]
fn prop_signed_inputs_equal_matmul() {
    let p = XbarParams::default();
    check("signed==matmul", 20, |rng| {
        let x = rand_matrix(rng, 2, p.rows, -(1 << 15), 1 << 15);
        let w = rand_matrix(rng, p.rows, 9, -(1 << 15), 1 << 15);
        prop_assert!(
            vmm_raw_signed(&x, &w, &p, false) == matmul(&x, &w),
            "signed-input encoding mismatch"
        );
        Ok(())
    });
}

#[test]
fn prop_karatsuba_equals_plain() {
    let p = XbarParams::default();
    check("karatsuba==plain", 20, |rng| {
        let x = rand_matrix(rng, 2, p.rows, 0, 1 << 16);
        let w = rand_matrix(rng, p.rows, 7, -(1 << 15), 1 << 15);
        prop_assert!(
            karatsuba_vmm_raw(&x, &w, &p) == vmm_raw(&x, &w, &p, false),
            "karatsuba != plain"
        );
        Ok(())
    });
}

#[test]
fn prop_strassen_equals_matmul_any_even_shape() {
    check("strassen==matmul", 20, |rng| {
        let r = 2 * (1 + rng.below(5) as usize);
        let k = 2 * (1 + rng.below(5) as usize);
        let c = 2 * (1 + rng.below(5) as usize);
        let x = rand_matrix(rng, r, k, -1000, 1000);
        let w = rand_matrix(rng, k, c, -1000, 1000);
        prop_assert!(strassen(&x, &w) == matmul(&x, &w), "{r}x{k}x{c}");
        Ok(())
    });
}

#[test]
fn prop_strassen_is_recursive() {
    // strassen_with(strassen) == matmul: composability of the mul hook
    check("strassen-recursive", 10, |rng| {
        let x = rand_matrix(rng, 4, 4, -50, 50);
        let w = rand_matrix(rng, 4, 4, -50, 50);
        let nested = strassen_with(&x, &w, &|a, b| strassen(a, b));
        prop_assert!(nested == matmul(&x, &w), "nested strassen mismatch");
        Ok(())
    });
}

#[test]
fn prop_scale_clamp_monotone() {
    let p = XbarParams::default();
    check("scale-clamp-monotone", 20, |rng| {
        let a = rng.range_i64(-(1 << 30), 1 << 30);
        let b = rng.range_i64(-(1 << 30), 1 << 30);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let m = |v: i64| {
            scale_clamp(
                &Matrix {
                    rows: 1,
                    cols: 1,
                    data: vec![v],
                },
                &p,
            )
            .at(0, 0)
        };
        prop_assert!(m(lo) <= m(hi), "monotonicity violated: {lo} {hi}");
        Ok(())
    });
}

#[test]
fn prop_adc_schedule_energy_scale_bounds() {
    check("adc-energy-bounds", 30, |rng| {
        let p = XbarParams {
            out_shift: rng.below(16) as u32,
            ..XbarParams::default()
        };
        let s = AdaptiveSchedule::new(&p, 16, 16);
        let e = s.energy_scale(&SarShares::default());
        prop_assert!(e > 0.0 && e <= 1.0 + 1e-9, "scale {e} out of range");
        Ok(())
    });
}

#[test]
fn prop_adc_tests_never_exceed_full_resolution() {
    check("adc-tests-bounded", 20, |rng| {
        let p = XbarParams {
            out_shift: rng.below(20) as u32,
            out_bits: 8 + rng.below(12) as u32,
            ..XbarParams::default()
        };
        let s = AdaptiveSchedule::new(&p, 16, 16);
        for w in &s.samples {
            prop_assert!(w.tests <= p.adc_bits, "{} > {}", w.tests, p.adc_bits);
        }
        Ok(())
    });
}

#[test]
fn prop_dnc_schedule_invariants() {
    let p = XbarParams::default();
    check("dnc-invariants", 3, |rng| {
        let k = rng.below(3) as u32;
        let s = DncSchedule::new(k, &p);
        prop_assert!(s.adc_samples <= 128, "samples grew: {}", s.adc_samples);
        prop_assert!(s.xbars_used <= s.xbars_allocated, "used > allocated");
        let t: usize = s.phases.iter().map(|ph| ph.iters).sum();
        prop_assert!(t == s.time_iters, "phase time mismatch");
        Ok(())
    });
}

#[test]
fn prop_batcher_conserves_requests() {
    check("batcher-conservation", 20, |rng| {
        let cap = 1 + rng.below(8) as usize;
        let n = rng.below(40) as usize;
        let mut b = Batcher::new(cap, 4, std::time::Duration::from_secs(0));
        for i in 0..n {
            b.push(PendingRequest {
                id: i as u64,
                trace: 0,
                image: vec![i as i32; 4],
                enqueued: std::time::Instant::now(),
            });
        }
        let mut seen = Vec::new();
        while let Some(batch) = b.take_batch() {
            prop_assert!(batch.n_real <= cap, "overfull batch");
            prop_assert!(
                batch.data.len() == cap * 4,
                "batch not padded to capacity"
            );
            seen.extend(batch.ids);
        }
        let want: Vec<u64> = (0..n as u64).collect();
        prop_assert!(seen == want, "requests lost or reordered: {seen:?}");
        Ok(())
    });
}

#[test]
fn prop_batcher_deadline_edge_cases() {
    // the deadline edges the serving loop leans on: an empty batcher never
    // flushes, and an exactly-full batch closes without waiting
    check("batcher-deadline-edges", 20, |rng| {
        let cap = 1 + rng.below(8) as usize;
        let mut b = Batcher::new(cap, 3, std::time::Duration::from_millis(0));
        prop_assert!(
            !b.ready(std::time::Instant::now()),
            "empty batcher ready at zero deadline"
        );
        prop_assert!(b.take_batch().is_none(), "empty flush produced a batch");

        let mut b = Batcher::new(cap, 3, std::time::Duration::from_secs(3600));
        for i in 0..cap {
            prop_assert!(
                !b.ready(std::time::Instant::now()),
                "ready below capacity (cap {cap}, {i} queued)"
            );
            b.push(PendingRequest {
                id: i as u64,
                trace: 0,
                image: vec![i as i32; 3],
                enqueued: std::time::Instant::now(),
            });
        }
        prop_assert!(
            b.ready(std::time::Instant::now()),
            "exact-capacity batch not ready (cap {cap})"
        );
        let Some(batch) = b.take_batch() else {
            return Err("exact-capacity close yielded no batch".to_string());
        };
        prop_assert!(batch.n_real == cap, "n_real {} != cap {cap}", batch.n_real);
        prop_assert!(b.pending_len() == 0, "leftover pending after exact close");
        prop_assert!(!b.ready(std::time::Instant::now()), "drained batcher still ready");
        prop_assert!(b.take_batch().is_none(), "drained batcher flushed again");
        Ok(())
    });
}

#[test]
fn prop_mapping_conservation() {
    // allocated capacity always covers used capacity; utilisation in (0,1]
    let p = XbarParams::default();
    let nets = workloads::suite();
    check("mapping-conservation", 9, |rng| {
        let net = &nets[rng.below(nets.len() as u64) as usize];
        let ima = ImaConfig {
            inputs: 128 << rng.below(3),
            outputs: 64 << rng.below(4),
            ..ImaConfig::newton_default()
        };
        let m = Mapping::build(net, &ima, &p, MappingPolicy::newton(), 16);
        for a in &m.allocs {
            prop_assert!(
                a.utilization > 0.0 && a.utilization <= 1.0 + 1e-9,
                "{}: util {}",
                net.name,
                a.utilization
            );
        }
        prop_assert!(
            m.conv_imas + m.fc_imas == m.allocs.iter().map(|a| a.imas).sum::<usize>(),
            "ima counts disagree"
        );
        Ok(())
    });
}

#[test]
fn prop_programmed_xbar_equals_reference_engine() {
    // the install-once engine must be bit-identical to the legacy per-call
    // engine across random shapes, streaming widths, and ADC regimes
    // (lossless-fused, lossy, adaptive, lossy+adaptive)
    check("programmed==reference", 30, |rng| {
        let p = XbarParams {
            dac_bits: 1 + rng.below(2) as u32,
            cell_bits: 1 + rng.below(2) as u32,
            adc_bits: 5 + rng.below(6) as u32,
            out_shift: rng.below(12) as u32,
            ..XbarParams::default()
        };
        let adaptive = rng.below(2) == 1;
        let in_bits = 4 + rng.below(13) as u32;
        let w_bits = 4 + rng.below(13) as u32;
        let b = 1 + rng.below(4) as usize;
        let k = 1 + rng.below(p.rows as u64) as usize;
        let n = 1 + rng.below(16) as usize;
        let x = rand_matrix(rng, b, k, 0, 1 << in_bits);
        let wb = rand_matrix(rng, k, n, 0, 1 << w_bits);
        let programmed = ProgrammedXbar::install_biased(&wb, in_bits, w_bits, &p, adaptive);
        let want = biased_product_reference(&x, &wb, in_bits, w_bits, &p, adaptive);
        prop_assert!(
            programmed.run(&x) == want,
            "mismatch b={b} k={k} n={n} in={in_bits} w={w_bits} adc={} shift={} adaptive={adaptive}",
            p.adc_bits,
            p.out_shift
        );
        Ok(())
    });
}

#[test]
fn prop_programmed_signed_paths_equal_reference() {
    check("programmed-signed==reference", 15, |rng| {
        let p = XbarParams {
            adc_bits: 6 + rng.below(4) as u32,
            out_shift: rng.below(12) as u32,
            ..XbarParams::default()
        };
        let adaptive = rng.below(2) == 1;
        let b = 1 + rng.below(3) as usize;
        let n = 1 + rng.below(10) as usize;
        let w = rand_matrix(rng, p.rows, n, -(1 << 15), 1 << 15);
        let programmed = ProgrammedXbar::install(&w, &p, adaptive);
        let xu = rand_matrix(rng, b, p.rows, 0, 1 << 16);
        prop_assert!(
            programmed.run(&xu) == vmm_raw_reference(&xu, &w, &p, adaptive),
            "vmm_raw path diverged (adc={} adaptive={adaptive})",
            p.adc_bits
        );
        let xs = rand_matrix(rng, b, p.rows, -(1 << 15), 1 << 15);
        prop_assert!(
            programmed.run_signed(&xs) == vmm_raw_signed_reference(&xs, &w, &p, adaptive),
            "signed-input path diverged (adc={} adaptive={adaptive})",
            p.adc_bits
        );
        Ok(())
    });
}

#[test]
fn prop_digit_major_engine_equals_reference_across_workers() {
    // the digit-major slice engine (k-major planes, zero/uniform slice
    // classification, per-row digit extraction) must be bit-identical to
    // the pre-refactor oracle across random shapes, all four ADC regimes,
    // run_window offsets, and 1/2/8 workers — parallelism and layout are
    // wall-clock optimisations, never numerics changes
    check("digit-major==reference", 16, |rng| {
        let regime = rng.below(4);
        let (adc_bits, adaptive) = match regime {
            0 => (9 + rng.below(3) as u32, false), // lossless -> fused
            1 => (9, true),                        // adaptive
            2 => (5 + rng.below(4) as u32, false), // lossy
            _ => (5 + rng.below(4) as u32, true),  // lossy + adaptive
        };
        let p = XbarParams {
            adc_bits,
            out_shift: rng.below(12) as u32,
            ..XbarParams::default()
        };
        let b = 1 + rng.below(5) as usize;
        let k = 1 + rng.below(p.rows as u64) as usize;
        let n = 1 + rng.below(12) as usize;
        let pad = (rng.below(3) * 7) as usize; // window offset into x
        let w = rand_matrix(rng, k, n, -(1 << 15), 1 << 15);
        let wide = rand_matrix(rng, b, pad + k, 0, 1 << 16);
        let programmed = ProgrammedXbar::install(&w, &p, adaptive);
        let sliced = Matrix::from_fn(b, k, |r, c| wide.at(r, pad + c));
        let want = vmm_raw_reference(&sliced, &w, &p, adaptive);
        prop_assert!(
            programmed.run_window(&wide, pad) == want,
            "auto-split run diverged (regime {regime}, b={b} k={k} n={n} pad={pad} shift={})",
            p.out_shift
        );
        for workers in [1usize, 2, 8] {
            let got = programmed.run_window_on(&wide, pad, &Executor::new(workers));
            prop_assert!(
                got == want,
                "forced {workers}-worker run diverged (regime {regime}, b={b} k={k} n={n} pad={pad})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_pipelined_forward_equals_seq_across_replicas_and_workers() {
    // acceptance gate for pipelined stage scheduling: the wavefront over
    // the replica pool must be bit-identical to forward_seq across
    // {1,2,4} replicas × {1,2,8} workers, for random small staged CNNs
    // (8x8x2 images, 2 conv stages + classifier) in exact and lossy
    // regimes — overlap and placement are wall-clock choices, never
    // numerics changes
    use newton::coordinator::pipeline::forward_pipelined;
    use newton::mapping::{StageMap, StagePolicy};
    use newton::xbar::cnn::{ProgrammedCnn, Tensor};

    let _g = trace_guard();
    check("pipelined==seq", 6, |rng| {
        let p = XbarParams {
            adc_bits: 8 + rng.below(2) as u32, // lossy:8 or lossless 9
            ..XbarParams::default()
        };
        let adaptive = rng.below(2) == 1;
        let shifts = [6u32, 5, 4];
        let conv_w = [
            rand_matrix(rng, 18, 3, -63, 64), // 3x3x2 -> 3
            rand_matrix(rng, 27, 4, -63, 64), // 3x3x3 -> 4
        ];
        let fc_w = rand_matrix(rng, 2 * 2 * 4, 5, -63, 64);
        let install = || {
            let convs = conv_w
                .iter()
                .zip(shifts)
                .map(|(w, out_shift)| {
                    ProgrammedLinear::install(w, &XbarParams { out_shift, ..p }, adaptive)
                })
                .collect();
            let fc = ProgrammedLinear::install(
                &fc_w,
                &XbarParams {
                    out_shift: shifts[2],
                    ..p
                },
                adaptive,
            );
            ProgrammedCnn::from_layers(convs, fc, 255)
        };
        let b = 1 + rng.below(5) as usize;
        let mut img = Tensor::zeros(b, 8, 8, 2);
        for v in img.data.iter_mut() {
            *v = rng.below(256) as i64;
        }
        let reference = install();
        let want = reference.forward_seq(&img);
        for n_replicas in [1usize, 2, 4] {
            let pool: Vec<ProgrammedCnn> = (0..n_replicas).map(|_| install()).collect();
            let policy = if n_replicas == 1 {
                StagePolicy::unconstrained()
            } else {
                StagePolicy::newton()
            };
            let map = StageMap::build(pool[0].n_conv_stages(), n_replicas, policy)
                .expect("feasible stage map");
            for workers in [1usize, 2, 8] {
                let got = forward_pipelined(&pool[..], &map, &img, &Executor::new(workers));
                prop_assert!(
                    got == want,
                    "pipelined forward diverged (replicas={n_replicas} workers={workers} b={b} adc={} adaptive={adaptive})",
                    p.adc_bits
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_forward_scratch_reuse_is_pure() {
    // one reused forward scratch (caller-owned raw accumulator through
    // ProgrammedLinear::run_with) across repeated and interleaved runs
    // must be bit-identical to fresh-scratch runs
    check("forward-scratch-pure", 8, |rng| {
        let p = XbarParams {
            adc_bits: 6 + rng.below(4) as u32,
            ..XbarParams::default()
        };
        let adaptive = rng.below(2) == 1;
        let kdim = 130 + rng.below(140) as usize; // always spans 2 chunks
        let n = 1 + rng.below(8) as usize;
        let w = rand_matrix(rng, kdim, n, -(1 << 15), 1 << 15);
        let layer = ProgrammedLinear::install(&w, &p, adaptive);
        let x1 = rand_matrix(rng, 2, kdim, 0, 1 << 16);
        let x2 = rand_matrix(rng, 2, kdim, 0, 1 << 16);
        let want1 = layer.run(&x1);
        let want2 = layer.run(&x2);
        let mut raw = Matrix::zeros(0, 0);
        let mut xs = RunScratch::empty();
        prop_assert!(
            layer.run_with(&x1, &mut raw, &mut xs) == want1,
            "first scratch run diverged from fresh run"
        );
        prop_assert!(
            layer.run_with(&x2, &mut raw, &mut xs) == want2,
            "interleaved scratch run diverged"
        );
        prop_assert!(
            layer.run_with(&x1, &mut raw, &mut xs) == want1,
            "reused forward scratch leaked state"
        );
        Ok(())
    });
}

#[test]
fn prop_wrappers_preserve_legacy_contract() {
    // the free functions are install-and-run wrappers now; they must keep
    // returning exactly what the pre-refactor engine returned
    check("wrappers==reference", 10, |rng| {
        let p = XbarParams {
            adc_bits: 7 + rng.below(3) as u32,
            ..XbarParams::default()
        };
        let adaptive = rng.below(2) == 1;
        let x = rand_matrix(rng, 2, p.rows, 0, 1 << 16);
        let w = rand_matrix(rng, p.rows, 6, -(1 << 15), 1 << 15);
        prop_assert!(
            vmm_raw(&x, &w, &p, adaptive) == vmm_raw_reference(&x, &w, &p, adaptive),
            "vmm_raw wrapper drifted"
        );
        let xs = rand_matrix(rng, 2, p.rows, -(1 << 15), 1 << 15);
        prop_assert!(
            vmm_raw_signed(&xs, &w, &p, adaptive)
                == vmm_raw_signed_reference(&xs, &w, &p, adaptive),
            "vmm_raw_signed wrapper drifted"
        );
        Ok(())
    });
}

#[test]
fn prop_installed_runs_are_observationally_pure() {
    // scratch-buffer reuse across runs (and interleaved batches) must not
    // leak state: every re-run of the same input is bit-identical
    check("install-run-pure", 10, |rng| {
        let p = XbarParams {
            adc_bits: 6 + rng.below(3) as u32,
            ..XbarParams::default()
        };
        let w = rand_matrix(rng, p.rows, 8, -(1 << 15), 1 << 15);
        let programmed = ProgrammedXbar::install(&w, &p, true);
        let x1 = rand_matrix(rng, 3, p.rows, 0, 1 << 16);
        let x2 = rand_matrix(rng, 3, p.rows, 0, 1 << 16);
        let first = programmed.run(&x1);
        let _ = programmed.run(&x2);
        prop_assert!(programmed.run(&x1) == first, "second run diverged");
        let mut scratch = programmed.scratch();
        prop_assert!(
            programmed.run_with_scratch(&x1, &mut scratch) == first,
            "scratch run diverged from fresh run"
        );
        let _ = programmed.run_with_scratch(&x2, &mut scratch);
        prop_assert!(
            programmed.run_with_scratch(&x1, &mut scratch) == first,
            "reused scratch leaked state"
        );
        Ok(())
    });
}

// ---- observability ---------------------------------------------------------

/// The tests below mutate the process-global trace level and inspect the
/// global span sink, so everything in this binary that can emit or read
/// pipeline "cell" spans serialises on this lock (survives poisoning —
/// a failed peer must not cascade).
static TRACE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn trace_guard() -> std::sync::MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn prop_tracing_off_vs_on_is_bit_identical() {
    // the span-purity contract (obs/span.rs overhead discipline): flipping
    // tracing on must be observationally invisible to the numerics — the
    // pipelined forward is bit-identical off, at verbose, and off again
    use newton::coordinator::pipeline::forward_pipelined;
    use newton::mapping::{StageMap, StagePolicy};
    use newton::xbar::cnn::{random_images, MiniCnn};

    let _g = trace_guard();
    let p = XbarParams::default();
    let cnn = MiniCnn::new(5);
    let pool: Vec<_> = (0..2).map(|_| cnn.program(&p, false)).collect();
    let map = StageMap::build(pool[0].n_conv_stages(), 2, StagePolicy::newton())
        .expect("feasible stage map");
    let exec = Executor::new(2);
    let img = random_images(3, 11);

    newton::obs::set_trace_level(newton::obs::TraceLevel::Off);
    let want = forward_pipelined(&pool[..], &map, &img, &exec);
    newton::obs::set_trace_level(newton::obs::TraceLevel::Verbose);
    let traced = forward_pipelined(&pool[..], &map, &img, &exec);
    newton::obs::set_trace_level(newton::obs::TraceLevel::Off);
    let after = forward_pipelined(&pool[..], &map, &img, &exec);
    assert!(traced == want, "tracing at verbose changed the numerics");
    assert!(after == want, "disabling tracing changed the numerics");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-gated: full traced wavefront")]
fn trace_completeness_every_pipeline_cell_recorded_once() {
    // exported-trace completeness: a traced pipelined forward must record
    // every (image k, stage s) wavefront cell exactly once, on the replica
    // the stage map assigned, spanning >= 2 replicas
    use newton::coordinator::pipeline::forward_pipelined;
    use newton::mapping::{StageMap, StagePolicy};
    use newton::obs::{flush_thread, global_sink, set_trace_level, TraceLevel};
    use newton::xbar::cnn::{random_images, MiniCnn};
    use std::collections::HashSet;

    let _g = trace_guard();
    let p = XbarParams::default();
    let cnn = MiniCnn::new(0);
    let n_replicas = 4usize;
    let pool: Vec<_> = (0..n_replicas).map(|_| cnn.program(&p, false)).collect();
    let n_stages = pool[0].n_conv_stages() + 1; // + classifier
    let map = StageMap::build(pool[0].n_conv_stages(), n_replicas, StagePolicy::newton())
        .expect("feasible stage map");
    let exec = Executor::new(4);
    let b = 6usize;
    let img = random_images(b, 3);

    set_trace_level(TraceLevel::Off);
    flush_thread();
    global_sink().clear();
    set_trace_level(TraceLevel::Spans);
    let _ = forward_pipelined(&pool[..], &map, &img, &exec);
    set_trace_level(TraceLevel::Off);
    // workers flushed on scope exit inside map; cover the caller too
    flush_thread();

    let cells: Vec<_> = global_sink()
        .snapshot()
        .into_iter()
        .filter(|e| e.name == "cell" && e.cat == "pipeline")
        .collect();
    assert_eq!(
        cells.len(),
        b * n_stages,
        "expected one cell span per (image, stage)"
    );
    let mut seen = HashSet::new();
    let mut replicas = HashSet::new();
    for c in &cells {
        let k = c.arg("k").expect("cell span missing k");
        let s = c.arg("s").expect("cell span missing s");
        let r = c.arg("replica").expect("cell span missing replica");
        assert!(k < b as u64 && s < n_stages as u64, "cell ({k},{s}) out of range");
        assert!(seen.insert((k, s)), "cell ({k},{s}) recorded twice");
        assert_eq!(
            r,
            map.assignment[s as usize] as u64,
            "cell ({k},{s}) ran on the wrong replica"
        );
        replicas.insert(r);
    }
    assert!(replicas.len() >= 2, "pipelined cells all ran on one replica");
}

/// The cost-ledger tests flip the process-global `obs::ledger` enable
/// flag and read the shared per-stage registry counters, so — like the
/// trace tests above — everything touching them serialises on one lock
/// (the crate-internal ledger test guard is not visible to integration
/// tests; same poison-tolerant pattern as `TRACE_LOCK`).
static LEDGER_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn ledger_guard() -> std::sync::MutexGuard<'static, ()> {
    LEDGER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn prop_ledger_enable_is_pure() {
    // the cost ledger's purity pin, companion to
    // prop_forward_scratch_reuse_is_pure: counting hardware cost must
    // change no output bit, and a disabled ledger must count nothing
    let _g = ledger_guard();
    check("ledger-pure", 8, |rng| {
        let p = XbarParams {
            adc_bits: 6 + rng.below(4) as u32,
            out_shift: rng.below(12) as u32,
            ..XbarParams::default()
        };
        let adaptive = rng.below(2) == 1;
        let kdim = 130 + rng.below(140) as usize; // always spans 2 chunks
        let n = 1 + rng.below(8) as usize;
        let w = rand_matrix(rng, kdim, n, -(1 << 15), 1 << 15);
        let layer = ProgrammedLinear::install(&w, &p, adaptive);
        let x = rand_matrix(rng, 2, kdim, 0, 1 << 16);
        let mut raw = Matrix::zeros(0, 0);
        let mut xs = RunScratch::empty();
        newton::obs::ledger::set_enabled(false);
        let off = layer.run_with(&x, &mut raw, &mut xs);
        prop_assert!(xs.ledger.is_empty(), "disabled ledger counted work");
        newton::obs::ledger::set_enabled(true);
        let on = layer.run_with(&x, &mut raw, &mut xs);
        newton::obs::ledger::set_enabled(false);
        prop_assert!(off == on, "enabling the ledger moved bits");
        prop_assert!(
            !xs.take_ledger().is_empty(),
            "enabled ledger counted nothing across a two-chunk layer"
        );
        prop_assert!(
            layer.run_with(&x, &mut raw, &mut xs) == off,
            "run after disabling the ledger diverged"
        );
        Ok(())
    });
}

#[test]
fn prop_ledger_slice_accounting_is_conserved() {
    // integration-side conservation sweep over random geometry in all
    // four ADC regimes: executed + folded + skipped slice iterations
    // must account exactly against the install-time slice profile, and
    // every non-skipped slice sample must be either quantised (an ADC
    // op) or folded as an identity — nothing vanishes, nothing is
    // double-counted
    let _g = ledger_guard();
    check("ledger-conservation", 12, |rng| {
        let (adc_bits, out_shift, adaptive) = [
            (9u32, 10u32, false), // lossless -> fused fast path
            (9, 10, true),        // lossless + adaptive -> slice engine
            (6, 0, false),        // lossy
            (7, 4, true),         // lossy + adaptive
        ][rng.below(4) as usize];
        let p = XbarParams {
            adc_bits,
            out_shift,
            ..XbarParams::default()
        };
        let b = 1 + rng.below(4) as usize;
        let k = 1 + rng.below(p.rows as u64) as usize;
        let n = 1 + rng.below(9) as usize;
        let w = rand_matrix(rng, k, n, -(1 << 15), 1 << 15);
        let x = rand_matrix(rng, b, k, 0, 1 << 16);
        let programmed = ProgrammedXbar::install(&w, &p, adaptive);
        newton::obs::ledger::set_enabled(true);
        let mut scratch = programmed.scratch();
        let _ = programmed.run_with_scratch(&x, &mut scratch);
        newton::obs::ledger::set_enabled(false);
        let l = scratch.take_ledger();

        let rows = b as u64;
        let iters = programmed.iters() as u64;
        let n64 = n as u64;
        let (dense, uniform, zero) = programmed.slice_profile();
        prop_assert!(
            l.row_elems == rows * programmed.kdim() as u64,
            "row movement miscounted (adc={adc_bits} shift={out_shift} adaptive={adaptive})"
        );
        if programmed.is_fused() {
            prop_assert!(
                l.fused_rows == rows && l.slice_rows == 0,
                "fused run attributed rows to the slice engine"
            );
            prop_assert!(l.adc_ops() == 0, "fused path quantised something");
            prop_assert!(
                l.identity_folds == rows * iters * programmed.slices() as u64 * n64,
                "fused identity folds diverged from the analytic count"
            );
        } else {
            prop_assert!(
                l.slice_rows == rows && l.fused_rows == 0,
                "slice-engine run attributed rows to the fused path"
            );
            prop_assert!(
                l.iters_executed + l.iters_skipped == rows * iters,
                "DAC iterations leaked (adc={adc_bits} shift={out_shift} adaptive={adaptive})"
            );
            prop_assert!(
                l.slice_iters_executed + l.slice_iters_folded + l.slice_iters_skipped
                    == rows * iters * (dense + uniform + zero) as u64,
                "slice iterations do not account against slice_profile() \
                 (adc={adc_bits} shift={out_shift} adaptive={adaptive})"
            );
            prop_assert!(
                l.adc_ops() + l.identity_folds
                    == (l.slice_iters_executed + l.slice_iters_folded) * n64,
                "a non-skipped slice sample was neither quantised nor folded"
            );
        }
        Ok(())
    });
}

#[test]
fn ledger_stage_attribution_sums_to_the_whole_forward() {
    // per-stage attribution conservation: the `ledger.stage<i>.adc_ops`
    // registry deltas captured by ProgrammedCnn::run_stage across one
    // sequential forward must sum exactly to the whole-forward scratch
    // ledger — no stage loses or double-counts conversions
    use newton::xbar::cnn::{random_images, ForwardScratch, MiniCnn};

    let _g = ledger_guard();
    let p = XbarParams {
        adc_bits: 8, // lossy -> slice engine everywhere, every stage quantises
        ..XbarParams::default()
    };
    let cnn = MiniCnn::new(7).program(&p, true);
    let img = random_images(2, 19);
    let before: Vec<u64> = (0..cnn.n_stages())
        .map(newton::obs::ledger::stage_adc_ops)
        .collect();
    newton::obs::ledger::set_enabled(true);
    let mut scratch = ForwardScratch::new();
    let _ = cnn.forward_seq_with(&img, &mut scratch);
    newton::obs::ledger::set_enabled(false);
    let whole = scratch.take_ledger();
    assert!(whole.adc_ops() > 0, "lossy forward quantised nothing");
    let mut stage_sum = 0u64;
    for s in 0..cnn.n_stages() {
        let delta = newton::obs::ledger::stage_adc_ops(s) - before[s];
        assert!(delta > 0, "stage {s} attributed no ADC conversions");
        stage_sum += delta;
    }
    assert_eq!(
        stage_sum,
        whole.adc_ops(),
        "per-stage ADC-op attribution does not sum to the whole forward"
    );
}

#[test]
fn prop_adaptive_within_bound_of_exact() {
    // the adaptive ADC's rounding never moves a scaled output by more than
    // the analytic bound (0.5 ulp per rounded partial + scaling round)
    let p = XbarParams::default();
    let n_rounded = (0..p.iters())
        .flat_map(|i| (0..p.slices()).map(move |s| (i, s)))
        .filter(|(i, s)| (i * p.dac_bits as usize + s * p.cell_bits as usize) < p.out_shift as usize)
        .count() as i64;
    let bound = n_rounded / 2 + 2;
    check("adaptive-bounded", 10, |rng| {
        let x = rand_matrix(rng, 2, p.rows, 0, 1 << 16);
        let w = rand_matrix(rng, p.rows, 8, -(1 << 15), 1 << 15);
        let a = scale_clamp(&vmm_raw(&x, &w, &p, true), &p);
        let e = scale_clamp(&matmul(&x, &w), &p);
        for (av, ev) in a.data.iter().zip(e.data.iter()) {
            prop_assert!((av - ev).abs() <= bound, "{av} vs {ev} (bound {bound})");
        }
        Ok(())
    });
}
