//! End-to-end serving tests over the real PJRT runtime + AOT artifacts.
//! These need `make artifacts` to have run; they skip (with a loud note)
//! when the artifacts directory is absent so `cargo test` stays usable in
//! a fresh checkout.

use std::path::PathBuf;
use std::time::Instant;

use newton::coordinator::{argmax, PipelineServer, ServerConfig};
use newton::runtime::{Manifest, Runtime};
use newton::util::Rng;
use newton::xbar::{scale_clamp, vmm_raw, Matrix};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = newton::runtime::default_artifacts_dir();
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    for name in [
        "model_b1",
        "model_b8",
        "stage0_b8",
        "stage1_b8",
        "stage2_b8",
        "stage3_b8",
        "vmm_plain",
        "vmm_karatsuba",
    ] {
        assert!(m.artifact(name).is_ok(), "missing {name}");
    }
}

#[test]
fn fused_model_matches_golden_logits() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let (_, input) = rt.manifest.load_testvec("input_b8").unwrap();
    let (_, want) = rt.manifest.load_testvec("logits_b8").unwrap();
    let got = rt.run("model_b8", &input).unwrap();
    assert_eq!(got, want);
}

#[test]
fn staged_pipeline_equals_fused_model() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let (_, input) = rt.manifest.load_testvec("input_b8").unwrap();
    let fused = rt.run("model_b8", &input).unwrap();
    let mut act = input;
    for s in 0..4 {
        act = rt.run(&format!("stage{s}_b8"), &act).unwrap();
    }
    assert_eq!(act, fused);
}

#[test]
fn batch1_and_batch8_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let (_, input) = rt.manifest.load_testvec("input_b8").unwrap();
    let per = input.len() / 8;
    let b8 = rt.run("model_b8", &input).unwrap();
    for i in [0usize, 3, 7] {
        let one = rt.run("model_b1", &input[i * per..(i + 1) * per]).unwrap();
        assert_eq!(one, &b8[i * 10..(i + 1) * 10], "image {i}");
    }
}

#[test]
fn vmm_artifact_matches_rust_golden_model() {
    // The L1 Pallas kernel (through PJRT) and the rust golden model must be
    // bit-identical — the cross-language contract.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let (ispec, vin) = rt.manifest.load_testvec("vmm_in").unwrap();
    let got = rt.run("vmm_plain", &vin).unwrap();

    // reconstruct the same weights aot.py generated (numpy default_rng is
    // not replicated here; instead solve via the golden testvec)
    let (_, want) = rt.manifest.load_testvec("vmm_out").unwrap();
    assert_eq!(got, want);
    assert_eq!(ispec.dims, vec![8, 128]);

    // karatsuba artifact: same numbers
    let gk = rt.run("vmm_karatsuba", &vin).unwrap();
    assert_eq!(gk, want);
}

#[test]
fn rust_golden_model_agrees_with_python_kernel_semantics() {
    // Same contract, checked constructively: random inputs through the rust
    // golden model equal clamp(round(x@w >> 10)) — the exact semantics the
    // python tests pin for the Pallas kernel. (Direct x-language equality
    // is covered by vmm_artifact_matches_rust_golden_model.)
    let p = newton::config::XbarParams::default();
    let mut rng = Rng::new(123);
    let x = Matrix::from_fn(4, 128, |_, _| rng.range_i64(0, 1 << 16));
    let w = Matrix::from_fn(128, 32, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
    let got = scale_clamp(&vmm_raw(&x, &w, &p, false), &p);
    let want = scale_clamp(&newton::xbar::matmul(&x, &w), &p);
    assert_eq!(got, want);
}

#[test]
fn wrong_input_shape_is_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let err = rt.run("vmm_plain", &vec![0i32; 7]).unwrap_err();
    assert!(err.to_string().contains("elements"), "{err}");
}

#[test]
fn corrupted_artifact_fails_to_compile() {
    let Some(dir) = artifacts_dir() else { return };
    // copy the artifacts dir metadata, point one entry at a corrupt file
    let tmp = std::env::temp_dir().join("newton-corrupt-artifacts");
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::write(tmp.join("bad.hlo.txt"), "HloModule not really hlo {{{").unwrap();
    std::fs::write(
        tmp.join("manifest.txt"),
        "artifact bad bad.hlo.txt in:2x2:i32 out:2x2:i32\n",
    )
    .unwrap();
    let mut rt = Runtime::new(&tmp).unwrap();
    let err = rt.run("bad", &vec![0i32; 4]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bad.hlo.txt") || msg.contains("parse"), "{msg}");
}

#[test]
fn missing_stage_fails_fast_at_start() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = ServerConfig::newton_mini(dir);
    cfg.stages.push("no_such_stage".into());
    let Err(err) = PipelineServer::start(cfg) else {
        panic!("server started with a missing stage artifact");
    };
    assert!(format!("{err}").contains("no_such_stage"));
}

#[test]
fn pipeline_server_serves_and_matches_fused() {
    let Some(dir) = artifacts_dir() else { return };
    let n_req = 12; // 1.5 batches: exercises padding
    let mut server = PipelineServer::start(ServerConfig::newton_mini(dir.clone())).unwrap();
    let mut rng = Rng::new(99);
    let images: Vec<Vec<i32>> = (0..n_req)
        .map(|_| (0..3072).map(|_| rng.below(256) as i32).collect())
        .collect();
    let t0 = Instant::now();
    for img in &images {
        server.submit(img.clone()).unwrap();
    }
    let mut results = server.collect(n_req).unwrap();
    let report = server.shutdown(&results, t0.elapsed());
    assert_eq!(report.completed, n_req);
    results.sort_by_key(|r| r.id);

    // cross-check against the fused model
    let mut rt = Runtime::new(&dir).unwrap();
    let fused_in: Vec<i32> = images.iter().take(8).flatten().copied().collect();
    let fused = rt.run("model_b8", &fused_in).unwrap();
    for i in 0..8 {
        assert_eq!(results[i].logits, &fused[i * 10..(i + 1) * 10], "req {i}");
        assert!(argmax(&results[i].logits) < 10);
    }
    assert!(report.throughput_rps > 0.0);
}
