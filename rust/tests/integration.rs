//! Cross-module integration tests: mapping + tiles + pipeline + metrics
//! must agree with each other and with the paper's qualitative claims.

use newton::config::{ChipConfig, ImaConfig, NewtonFeatures, XbarParams};
use newton::energy::{Component, TileModel};
use newton::mapping::{Mapping, MappingPolicy};
use newton::metrics;
use newton::pipeline::{evaluate, evaluate_suite};
use newton::tiles::ChipPlan;
use newton::util::geomean;
use newton::workloads;

#[test]
fn every_feature_helps_energy_on_the_suite() {
    // Each technique, enabled alone on top of the constrained baseline,
    // must not increase the suite's geomean energy/op.
    let nets = workloads::suite();
    let base_features = NewtonFeatures {
        constrained_mapping: true,
        ..NewtonFeatures::none()
    };
    let base_chip = ChipConfig::newton_with(base_features);
    let base: Vec<f64> = evaluate_suite(&nets, &base_chip)
        .iter()
        .map(|r| r.energy_per_op_pj)
        .collect();

    let variants: Vec<(&str, NewtonFeatures)> = vec![
        ("adaptive_adc", NewtonFeatures { adaptive_adc: true, ..base_features }),
        ("karatsuba", NewtonFeatures { karatsuba: 1, ..base_features }),
        ("small_buffers", NewtonFeatures { small_buffers: true, ..base_features }),
        ("strassen", NewtonFeatures { strassen: true, ..base_features }),
        ("hetero_tiles", NewtonFeatures { hetero_tiles: true, ..base_features }),
    ];
    for (name, f) in variants {
        let chip = ChipConfig::newton_with(f);
        let e: Vec<f64> = evaluate_suite(&nets, &chip)
            .iter()
            .map(|r| r.energy_per_op_pj)
            .collect();
        assert!(
            geomean(&e) <= geomean(&base) * 1.005,
            "{name}: {} !<= {}",
            geomean(&e),
            geomean(&base)
        );
    }
}

#[test]
fn plan_tile_counts_match_mapping() {
    let chip = ChipConfig::newton();
    let p = XbarParams::default();
    for net in workloads::suite() {
        let m = Mapping::build(&net, &chip.conv_tile.ima, &p, MappingPolicy::newton(), 16);
        let plan = ChipPlan::new(&chip, &m);
        assert_eq!(plan.conv_tiles, m.conv_tiles());
        assert_eq!(plan.fc_tiles, m.fc_tiles());
        assert!(plan.area_mm2() > 0.0 && plan.peak_power_w() > 0.0);
    }
}

#[test]
fn peak_metrics_bound_delivered_metrics() {
    // delivered CE can exceed conv-tile peak CE only via FC-tile effects;
    // for resnet (conv-dominated, few FC tiles) delivered <= ~peak.
    let chip = ChipConfig::newton();
    let peak = metrics::peak_metrics(&chip);
    let r = evaluate(&workloads::resnet34(), &chip);
    assert!(
        r.ce_eff <= peak.ce_gops_mm2 * 1.10,
        "delivered {} vs peak {}",
        r.ce_eff,
        peak.ce_gops_mm2
    );
}

#[test]
fn isaac_vs_newton_area_per_throughput() {
    // headline: 2.2x throughput/area. Also check both chips actually fit
    // a plausible tile budget for single-image pipelines.
    let nets = workloads::suite();
    let mut ratios = vec![];
    for net in &nets {
        let i = evaluate(net, &ChipConfig::isaac());
        let n = evaluate(net, &ChipConfig::newton());
        ratios.push(n.ce_eff / i.ce_eff);
    }
    let g = geomean(&ratios);
    assert!((1.5..3.5).contains(&g), "throughput/area ratio {g}");
}

#[test]
fn energy_breakdown_sums_to_total() {
    let r = evaluate(&workloads::vgg_b(), &ChipConfig::newton());
    let sum_pj: f64 = r.energy_breakdown.iter().map(|(_, e)| e).sum();
    let total_pj = r.energy_per_image_mj * 1e9;
    assert!(
        (sum_pj - total_pj).abs() / total_pj < 1e-9,
        "{sum_pj} vs {total_pj}"
    );
}

#[test]
fn adaptive_adc_shifts_the_breakdown_away_from_adc() {
    let nets = [workloads::vgg_a()];
    let mut on = ChipConfig::newton();
    on.features.adaptive_adc = true;
    let mut off = on.clone();
    off.features.adaptive_adc = false;
    let frac = |chip: &ChipConfig| {
        let r = evaluate(&nets[0], chip);
        let adc = r
            .energy_breakdown
            .iter()
            .find(|(c, _)| *c == Component::Adc)
            .unwrap()
            .1;
        let tot: f64 = r.energy_breakdown.iter().map(|(_, e)| e).sum();
        adc / tot
    };
    assert!(frac(&on) < frac(&off));
}

#[test]
fn bigger_images_cost_proportionally_more_energy() {
    let chip = ChipConfig::newton();
    let n224 = evaluate(&workloads::vgg_a(), &chip);
    let n448 = evaluate(&workloads::vgg_a().with_input_width(448), &chip);
    let ratio = n448.energy_per_image_mj / n224.energy_per_image_mj;
    assert!((2.5..6.0).contains(&ratio), "{ratio}");
}

#[test]
fn isaac_model_self_consistency() {
    // The ISAAC tile model's pJ/op at peak should sit near the pipeline
    // model's delivered pJ/op for conv-heavy nets (same constants).
    let tile = TileModel::new(newton::config::TileConfig::isaac(), XbarParams::default());
    let peak_pj = tile.energy_per_op_pj();
    let r = evaluate(&workloads::resnet34(), &ChipConfig::isaac());
    let ratio = r.energy_per_op_pj / peak_pj;
    assert!((0.4..3.0).contains(&ratio), "delivered/peak = {ratio}");
}

#[test]
fn ima_shape_sweep_is_stable() {
    // the Fig-10 sweep must run over every net without panicking and give
    // monotonically *worse* utilisation for degenerate huge IMAs
    let nets = workloads::suite();
    let p = XbarParams::default();
    let mut last = 0.0;
    for (i, o) in [(128, 256), (512, 512), (8192, 1024)] {
        let ima = ImaConfig {
            inputs: i,
            outputs: o,
            ..ImaConfig::newton_default()
        };
        let u = newton::mapping::avg_underutilization(&nets, &ima, &p, 16);
        assert!(u >= last - 0.02, "{u} vs {last}");
        last = u;
    }
}
