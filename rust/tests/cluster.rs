//! Real-process failover tests for the sharded cluster serving stack
//! (`rust/src/coordinator/cluster.rs`): `newton worker` child processes
//! on ephemeral ports, driven by an in-process coordinator engine, with a
//! SIGKILL landing mid-stream. The failover contract under test is the
//! strongest one the generation protocol makes: killing any worker must
//! change no reply bit, and the merged per-shard cost ledger must be
//! conserved across re-sharding.
//!
//! Heavy (each worker programs the full model): release-gated like the
//! other serving tests.

use std::process::{Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use newton::config::AdcKind;
use newton::coordinator::batcher::PendingRequest;
use newton::coordinator::golden::IMAGE_ELEMS;
use newton::coordinator::{Batcher, ClusterConfig, ClusterEngine, GoldenServer};
use newton::net::{bench_image, Engine, EngineBatch};

/// The cluster tests flip the process-global `obs::ledger` enable flag;
/// serialise them so a toggle in one test cannot race another's ledger
/// assertions (the crate-internal guard is not visible out here).
static LEDGER_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn ledger_guard() -> std::sync::MutexGuard<'static, ()> {
    LEDGER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

struct WorkerChild {
    child: std::process::Child,
    addr: String,
    admin: String,
}

impl WorkerChild {
    /// SIGKILL and reap; idempotent (a second kill of a dead child is an
    /// error std already swallows).
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn one `newton worker` child on ephemeral ports and wait for its
/// port files (written only after both listeners bound).
fn spawn_worker(dir: &std::path::Path, i: usize, seed: u64) -> WorkerChild {
    let pf = dir.join(format!("w{i}.port"));
    let af = dir.join(format!("w{i}.admin"));
    let _ = std::fs::remove_file(&pf);
    let _ = std::fs::remove_file(&af);
    let mut child = Command::new(env!("CARGO_BIN_EXE_newton"))
        .args([
            "worker",
            "--seed",
            &seed.to_string(),
            "--addr",
            "127.0.0.1:0",
            "--admin-addr",
            "127.0.0.1:0",
            "--port-file",
            pf.to_str().unwrap(),
            "--admin-port-file",
            af.to_str().unwrap(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn newton worker");
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let (Ok(a), Ok(ad)) = (std::fs::read_to_string(&pf), std::fs::read_to_string(&af)) {
            if !a.is_empty() && !ad.is_empty() {
                return WorkerChild { child, addr: a, admin: ad };
            }
        }
        assert!(
            !matches!(child.try_wait(), Ok(Some(_))),
            "worker {i} exited before binding"
        );
        assert!(Instant::now() < deadline, "worker {i} did not come up within 30s");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Push `images` through the engine as one padded batcher-shaped batch,
/// exactly the way the net server's dispatcher would.
fn run_batch(engine: &ClusterEngine, images: &[Vec<i32>], batch: usize, base_id: u64) -> EngineBatch {
    let mut b = Batcher::new(batch, IMAGE_ELEMS, Duration::from_secs(60));
    for (j, img) in images.iter().enumerate() {
        b.push(PendingRequest {
            id: base_id + j as u64,
            trace: 0,
            image: img.clone(),
            enqueued: Instant::now(),
        });
    }
    engine.run(0, &b.take_batch().expect("non-empty batch"))
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
fn sigkill_mid_stream_keeps_replies_bit_exact_and_ledger_conserved() {
    let _g = ledger_guard();
    newton::obs::ledger::set_enabled(true);
    let seed = 5u64;
    let batch = 4usize;
    let dir = std::env::temp_dir().join(format!("newton-cluster-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut fleet: Vec<WorkerChild> = (0..3).map(|i| spawn_worker(&dir, i, seed)).collect();
    let endpoints: Vec<(String, Option<String>)> =
        fleet.iter().map(|w| (w.addr.clone(), Some(w.admin.clone()))).collect();

    let mut cfg = ClusterConfig::new(seed, AdcKind::Exact, batch).unwrap();
    // loopback hops land in milliseconds; a short deadline keeps the
    // dead-worker detection (which burns one full hop deadline) quick
    cfg.hop_deadline = Duration::from_millis(500);
    cfg.lifecycle.heartbeat_every = Duration::from_millis(50);
    let engine = ClusterEngine::connect(cfg, &endpoints).expect("cluster join");
    let heartbeats = engine.spawn_heartbeats();
    assert!(!engine.degraded(), "fresh three-worker cluster must not be degraded");

    // the reference every assertion compares against: the single-process
    // golden path over the same installed weights and request stream
    let images: Vec<Vec<i32>> = (0..2 * batch).map(|i| bench_image(seed, i)).collect();
    let want = GoldenServer::replicated(seed, AdcKind::Exact, 1, batch).infer(&images);

    // batch A, clean: pipelined across all three shards; its merged hop
    // ledger is the conservation baseline
    let clean = run_batch(&engine, &images[..batch], batch, 0);
    assert_eq!(clean.logits.as_slice(), &want[..batch], "clean cluster batch diverged");
    assert_eq!(clean.max_abs_err, 0);
    assert!(!clean.cost.is_empty(), "workers did not ship hop ledgers");

    // SIGKILL the middle worker while batch B forwards stream on another
    // thread — whether the kill lands mid-hop or between forwards, every
    // reply must still match the golden path bit for bit
    let eng = Arc::clone(&engine);
    let tail: Vec<Vec<i32>> = images[batch..].to_vec();
    let pump = std::thread::spawn(move || {
        (0u64..4).map(|k| run_batch(&eng, &tail, batch, (k + 1) * batch as u64)).collect::<Vec<_>>()
    });
    std::thread::sleep(Duration::from_millis(5));
    fleet[1].kill();
    for out in pump.join().expect("pump thread") {
        assert_eq!(out.logits.as_slice(), &want[batch..], "reply diverged across the kill");
        assert_eq!(out.max_abs_err, 0);
    }
    assert!(engine.reshard_count() >= 1, "losing a worker must force a re-shard");
    assert!(!engine.degraded(), "two survivors can still serve every stage");

    // ledger conservation: batch A re-run on the survivors partitions the
    // stages differently, but the merged ledger (and its priced energy)
    // must be identical — stage costs move between shards, never appear
    // or vanish
    let after = run_batch(&engine, &images[..batch], batch, 100);
    assert_eq!(after.logits.as_slice(), &want[..batch]);
    assert_eq!(after.cost, clean.cost, "re-sharded hop ledgers do not merge to the same total");
    let tol = 1e-6 * clean.energy_pj.abs().max(1.0);
    assert!(
        (after.energy_pj - clean.energy_pj).abs() <= tol,
        "priced energy not conserved: {} vs {}",
        after.energy_pj,
        clean.energy_pj
    );

    // degraded transition: kill the survivors too — the engine must fall
    // back to its in-process single-process path (still bit-exact) and
    // latch the degraded gauge
    fleet[0].kill();
    fleet[2].kill();
    let fallback = run_batch(&engine, &images[..batch], batch, 200);
    assert_eq!(fallback.logits.as_slice(), &want[..batch], "fallback path diverged");
    assert!(engine.degraded(), "serving with zero workers must flag degraded");
    let health = engine.health().expect("cluster engine reports health");
    assert!(health.degraded, "health report must carry the degraded verdict");

    engine.stop();
    let _ = heartbeats.join();
    for w in &mut fleet {
        w.kill();
    }
    let _ = std::fs::remove_dir_all(&dir);
    newton::obs::ledger::set_enabled(false);
}
