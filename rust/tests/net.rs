//! Loopback integration tests for the TCP serving subsystem
//! (`rust/src/net/`): an in-process `NetServer` on an ephemeral port,
//! driven by real sockets.
//!
//! Protocol-edge tests (malformed frames, oversized payloads, abrupt
//! disconnects, backpressure, drain) run against cheap stub engines so
//! they stay fast in debug builds; the bit-identity test against the real
//! golden crossbar engine is release-gated like the other heavy serving
//! tests.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use newton::config::AdcKind;
use newton::coordinator::{Batch, GoldenServer, HealthReport};
use newton::net::proto::{self, Msg, StatsSnapshot};
use newton::net::{
    bench_image, load_generate, Backoff, BenchConfig, Client, Engine, EngineBatch, InferOutcome,
    NetError, NetServer, ServeConfig,
};

/// Cheap deterministic engine: per real row, logits are
/// `[sum(row), first element]`.
#[derive(Clone)]
struct EchoEngine {
    elems: usize,
    capacity: usize,
    replicas: usize,
}

impl EchoEngine {
    /// 4-element requests, capacity-2 batches, one replica — the shape
    /// most protocol-edge tests use.
    fn small() -> Self {
        EchoEngine {
            elems: 4,
            capacity: 2,
            replicas: 1,
        }
    }

    /// newton-mini request shape, so the real `bench-net` load generator
    /// can drive it without the golden engine's compute cost.
    fn wide() -> Self {
        EchoEngine {
            elems: newton::coordinator::golden::IMAGE_ELEMS,
            capacity: 4,
            replicas: 2,
        }
    }
}

fn echo_logits(row: &[i32]) -> Vec<i32> {
    vec![row.iter().sum::<i32>(), row[0]]
}

impl Engine for EchoEngine {
    fn image_elems(&self) -> usize {
        self.elems
    }

    fn batch_capacity(&self) -> usize {
        self.capacity
    }

    fn n_replicas(&self) -> usize {
        self.replicas
    }

    fn describe(&self) -> String {
        "echo stub".to_string()
    }

    fn run(&self, index: usize, b: &Batch) -> EngineBatch {
        let logits = (0..b.n_real)
            .map(|r| echo_logits(&b.data[r * self.elems..(r + 1) * self.elems]))
            .collect();
        EngineBatch {
            replica: index % self.replicas,
            n_real: b.n_real,
            logits,
            max_abs_err: 0,
            cost: newton::obs::CostLedger::new(),
            energy_pj: 0.0,
        }
    }
}

/// Echo engine that also sleeps, to hold requests in flight while a test
/// probes the admission limit. Capacity 1 so every request is its own
/// batch.
struct SlowEngine(Duration);

impl Engine for SlowEngine {
    fn image_elems(&self) -> usize {
        4
    }

    fn batch_capacity(&self) -> usize {
        1
    }

    fn n_replicas(&self) -> usize {
        1
    }

    fn describe(&self) -> String {
        "slow echo stub".to_string()
    }

    fn run(&self, index: usize, b: &Batch) -> EngineBatch {
        std::thread::sleep(self.0);
        EchoEngine {
            elems: 4,
            capacity: 1,
            replicas: 1,
        }
        .run(index, b)
    }
}

fn start(engine: Arc<dyn Engine>, max_inflight: usize) -> NetServer {
    NetServer::start(
        engine,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_inflight,
            batch_wait: Duration::from_millis(1),
            ..ServeConfig::default()
        },
    )
    .expect("bind ephemeral port")
}

#[test]
fn stub_loopback_roundtrip_and_stats() {
    let server = start(Arc::new(EchoEngine::small()), 16);
    let addr = server.local_addr();

    let mut c = Client::connect(addr).unwrap();
    for i in 0..5u64 {
        let img = [i as i32, 2, 3, 4];
        match c.infer(i, &img).unwrap() {
            InferOutcome::Ok(r) => {
                assert_eq!(r.id, i);
                assert_eq!(r.logits, echo_logits(&img));
                assert_eq!(r.max_abs_err, 0);
                assert_eq!(r.replica, 0);
            }
            InferOutcome::Busy => panic!("busy under a 16-deep limit"),
        }
    }
    let stats = c.stats().unwrap();
    assert_eq!(stats.served, 5);
    assert_eq!(stats.busy, 0);
    assert_eq!(stats.per_replica, vec![5]);
    assert!(stats.batches >= 3, "capacity 2, 5 sequential requests");
    assert!(stats.batch_fill > 0.0 && stats.batch_fill <= 1.0);
    assert!(stats.p50_us <= stats.p99_us);

    c.shutdown().unwrap();
    let final_stats = server.join();
    assert_eq!(final_stats.served, 5);
    // the listener is gone after the drain
    assert!(TcpStream::connect(addr).is_err());
}

#[test]
fn wrong_shape_gets_a_typed_error_and_connection_survives() {
    let server = start(Arc::new(EchoEngine::small()), 16);
    let mut c = Client::connect(server.local_addr()).unwrap();
    match c.infer(1, &[1, 2, 3]) {
        Err(NetError::Server(e)) => {
            assert_eq!(e.code, proto::ERR_BAD_SHAPE);
            assert!(e.message.contains('4'), "{}", e.message);
        }
        other => panic!("want shape error, got {other:?}"),
    }
    // same connection still serves
    match c.infer(2, &[5, 6, 7, 8]).unwrap() {
        InferOutcome::Ok(r) => assert_eq!(r.logits, echo_logits(&[5, 6, 7, 8])),
        InferOutcome::Busy => panic!("busy"),
    }
    server.shutdown();
}

#[test]
fn malformed_frame_is_fatal_to_its_connection_only() {
    let server = start(Arc::new(EchoEngine::small()), 16);
    let addr = server.local_addr();

    let mut raw = TcpStream::connect(addr).unwrap();
    raw.write_all(b"GARBAGEGARBAGEGA").unwrap(); // 16 junk bytes = one bad header
    // the server replies with an Error frame, then closes
    match proto::read_msg(&mut raw) {
        Ok(Msg::Error(e)) => {
            assert_eq!(e.code, proto::ERR_MALFORMED);
            assert!(e.message.contains("magic"), "{}", e.message);
        }
        other => panic!("want error frame, got {other:?}"),
    }
    let mut tail = Vec::new();
    raw.read_to_end(&mut tail).unwrap();
    assert!(tail.is_empty(), "server kept talking after a fatal error");

    // a fresh, well-behaved connection is unaffected
    let mut c = Client::connect(addr).unwrap();
    assert!(matches!(c.infer(9, &[1, 1, 1, 1]), Ok(InferOutcome::Ok(_))));
    let stats = c.stats().unwrap();
    assert_eq!(stats.proto_errors, 1);
    server.shutdown();
}

#[test]
fn oversized_payload_is_rejected_at_the_header() {
    let server = start(Arc::new(EchoEngine::small()), 16);
    let mut raw = TcpStream::connect(server.local_addr()).unwrap();
    // hand-craft a header lying about a huge payload
    let mut h = Vec::new();
    h.extend_from_slice(&proto::MAGIC);
    h.push(proto::VERSION);
    h.push(proto::TY_INFER);
    h.extend_from_slice(&[0, 0]);
    h.extend_from_slice(&((proto::MAX_PAYLOAD as u32) + 1).to_le_bytes());
    h.extend_from_slice(&0u32.to_le_bytes());
    raw.write_all(&h).unwrap();
    match proto::read_msg(&mut raw) {
        Ok(Msg::Error(e)) => assert!(e.message.contains("exceeds"), "{}", e.message),
        other => panic!("want error frame, got {other:?}"),
    }
    let mut tail = Vec::new();
    raw.read_to_end(&mut tail).unwrap();
    assert!(tail.is_empty());
    server.shutdown();
}

#[test]
fn abrupt_disconnect_mid_frame_leaves_the_server_serving() {
    let server = start(Arc::new(EchoEngine::small()), 16);
    let addr = server.local_addr();
    {
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(&proto::MAGIC).unwrap(); // half a header
        // dropped here: abrupt disconnect mid-frame
    }
    {
        // clean immediate disconnect (no bytes at all) is not an error
        let _ = TcpStream::connect(addr).unwrap();
    }
    // give the handler a moment to observe both sockets
    std::thread::sleep(Duration::from_millis(300));
    let mut c = Client::connect(addr).unwrap();
    assert!(matches!(c.infer(1, &[2, 2, 2, 2]), Ok(InferOutcome::Ok(_))));
    let stats = c.stats().unwrap();
    assert_eq!(stats.proto_errors, 1, "mid-frame cut counts, clean close does not");
    server.shutdown();
}

#[test]
fn admission_limit_returns_busy_not_queueing() {
    let server = start(Arc::new(SlowEngine(Duration::from_millis(500))), 1);
    let addr = server.local_addr();

    let blocker = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.infer(1, &[1, 0, 0, 0]).unwrap()
    });
    // let the blocker get admitted and into the engine
    std::thread::sleep(Duration::from_millis(150));
    let mut c = Client::connect(addr).unwrap();
    match c.infer(2, &[2, 0, 0, 0]).unwrap() {
        InferOutcome::Busy => {}
        InferOutcome::Ok(_) => panic!("second request admitted past a 1-deep limit"),
    }
    assert!(matches!(blocker.join().unwrap(), InferOutcome::Ok(_)));
    // once the slot frees, the same connection gets served
    let mut backoff = Backoff::new(Duration::from_millis(2), Duration::from_millis(20), 3);
    let (reply, _retries) = c
        .infer_backoff(3, &[3, 0, 0, 0], 1000, &mut backoff)
        .unwrap();
    assert_eq!(reply.logits, echo_logits(&[3, 0, 0, 0]));
    let stats = server.stats();
    assert!(stats.busy >= 1, "no Busy recorded");
    assert_eq!(stats.served, 2);
    server.shutdown();
}

#[test]
fn drain_refuses_new_work_flushes_inflight_and_acks() {
    let server = start(Arc::new(SlowEngine(Duration::from_millis(300))), 16);
    let addr = server.local_addr();

    // a request that is mid-engine when the drain starts must complete
    let inflight = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.infer(1, &[7, 0, 0, 0]).unwrap()
    });
    std::thread::sleep(Duration::from_millis(100));

    // pre-connected bystander, used after the drain starts
    let mut bystander = Client::connect(addr).unwrap();

    let mut ctl = Client::connect(addr).unwrap();
    ctl.shutdown().unwrap(); // acked once the drain flag is set

    match bystander.infer(2, &[8, 0, 0, 0]) {
        Err(NetError::Server(e)) => assert_eq!(e.code, proto::ERR_DRAINING),
        other => panic!("want draining error, got {other:?}"),
    }

    match inflight.join().unwrap() {
        InferOutcome::Ok(r) => assert_eq!(r.logits, echo_logits(&[7, 0, 0, 0])),
        InferOutcome::Busy => panic!("in-flight request bounced by the drain"),
    }
    let stats = server.join();
    assert_eq!(stats.served, 1);
    assert!(TcpStream::connect(addr).is_err(), "listener survived the drain");
}

#[test]
fn client_refuses_oversized_images_locally() {
    let server = start(Arc::new(EchoEngine::small()), 16);
    let mut c = Client::connect(server.local_addr()).unwrap();
    let huge = vec![0i32; proto::MAX_IMAGE_ELEMS + 1];
    // fails client-side, before any frame reaches the wire
    assert!(matches!(c.infer(1, &huge), Err(NetError::Proto(_))));
    // the connection was never touched, so it still serves
    assert!(matches!(c.infer(2, &[1, 1, 1, 1]), Ok(InferOutcome::Ok(_))));
    server.shutdown();
}

#[test]
fn server_rejects_client_sent_server_frames() {
    let server = start(Arc::new(EchoEngine::small()), 16);
    // a "client" that speaks a server-only frame gets a malformed error
    let mut raw = TcpStream::connect(server.local_addr()).unwrap();
    proto::write_msg(&mut raw, &Msg::Stats(StatsSnapshot::default())).unwrap();
    match proto::read_msg(&mut raw) {
        Ok(Msg::Error(e)) => assert_eq!(e.code, proto::ERR_MALFORMED),
        other => panic!("want error, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn load_generator_covers_every_request_exactly_once() {
    // the wide echo engine takes newton-mini-shaped bench images, so this
    // drives the real bench-net load generator end to end cheaply
    let server = start(Arc::new(EchoEngine::wide()), 32);
    let mut cfg = BenchConfig::new(&server.local_addr().to_string());
    cfg.requests = 40;
    cfg.concurrency = 6;
    cfg.seed = 3;
    let report = load_generate(&cfg).unwrap();
    assert_eq!(report.requests, 40);
    assert_eq!(report.logits.len(), 40);
    for (i, logits) in report.logits.iter().enumerate() {
        assert_eq!(logits, &echo_logits(&bench_image(cfg.seed, i)), "request {i}");
    }
    assert!(report.throughput_rps > 0.0);
    assert!(report.p50_ms <= report.p99_ms + 1e-9);
    assert_eq!(report.per_replica.iter().sum::<u64>(), 40);
    let stats = server.shutdown();
    assert_eq!(stats.served, 40);
    assert_eq!(stats.per_replica.len(), 2);
    assert_eq!(stats.per_replica.iter().sum::<u64>(), 40);
}

/// Echo engine that also reports a canned health snapshot, to exercise
/// the stats plumbing without the golden engine's compute cost.
struct HealthyEcho(EchoEngine);

impl Engine for HealthyEcho {
    fn image_elems(&self) -> usize {
        self.0.image_elems()
    }

    fn batch_capacity(&self) -> usize {
        self.0.batch_capacity()
    }

    fn n_replicas(&self) -> usize {
        self.0.n_replicas()
    }

    fn describe(&self) -> String {
        "echo stub + health".to_string()
    }

    fn run(&self, index: usize, b: &Batch) -> EngineBatch {
        self.0.run(index, b)
    }

    fn health(&self) -> Option<HealthReport> {
        Some(HealthReport {
            states: vec![0, 2],
            reruns: 5,
            quarantines: 1,
            degraded: false,
        })
    }
}

#[test]
fn health_report_rides_the_stats_frame() {
    let server = start(
        Arc::new(HealthyEcho(EchoEngine {
            elems: 4,
            capacity: 2,
            replicas: 2,
        })),
        16,
    );
    let mut c = Client::connect(server.local_addr()).unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.health, vec![0, 2]);
    assert_eq!(stats.reruns, 5);
    assert_eq!(stats.quarantines, 1);
    assert!(!stats.degraded);
    // an engine without a monitor reports empty health (see other tests'
    // stats assertions, which Default to exactly that)
    server.shutdown();
}

/// Echo engine that also fills the batch cost ledger with a fixed
/// per-real-row profile, to exercise the per-request CostReport division
/// without the golden engine's compute cost.
struct CostedEcho(EchoEngine);

impl Engine for CostedEcho {
    fn image_elems(&self) -> usize {
        self.0.image_elems()
    }

    fn batch_capacity(&self) -> usize {
        self.0.batch_capacity()
    }

    fn n_replicas(&self) -> usize {
        self.0.n_replicas()
    }

    fn describe(&self) -> String {
        "echo stub + ledger".to_string()
    }

    fn run(&self, index: usize, b: &Batch) -> EngineBatch {
        let mut out = self.0.run(index, b);
        for _ in 0..b.n_real {
            out.cost.count_adc(8, 10); // 10 conversions per real row
            out.cost.identity_folds += 3;
            out.cost.slice_iters_executed += 4;
            out.cost.slice_iters_folded += 2;
            out.cost.slice_iters_skipped += 1;
            out.cost.slice_rows += 1;
            out.cost.row_elems += self.0.elems as u64;
        }
        out.energy_pj = 50.0 * b.n_real as f64;
        out
    }
}

#[test]
fn cost_report_rides_the_reply_only_when_enabled() {
    // proto v3 opt-in: with --cost-reports the Reply frame carries the
    // batch ledger divided per real request; without it the tail is
    // absent (zero extra bytes on the wire, pinned in proto's unit tests)
    let server = NetServer::start(
        Arc::new(CostedEcho(EchoEngine::small())),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_inflight: 16,
            batch_wait: Duration::from_millis(1),
            cost_reports: true,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut c = Client::connect(server.local_addr()).unwrap();
    for i in 0..3u64 {
        match c.infer(i, &[1, 2, 3, 4]).unwrap() {
            InferOutcome::Ok(r) => {
                let cost = r.cost.expect("cost_reports on but the reply carried none");
                assert_eq!(cost.adc_ops, 10, "per-request ADC-op division");
                assert_eq!(cost.identity_folds, 3);
                assert_eq!(cost.slice_iters_executed, 4);
                assert_eq!(cost.slice_iters_folded, 2);
                assert_eq!(cost.slice_iters_skipped, 1);
                assert_eq!(cost.rows, 1);
                assert!(
                    (cost.energy_pj - 50.0).abs() < 1e-9,
                    "per-request energy division, got {}",
                    cost.energy_pj
                );
            }
            InferOutcome::Busy => panic!("busy under a 16-deep limit"),
        }
    }
    server.shutdown();

    // default config: same engine, no cost tail on the reply
    let server = start(Arc::new(CostedEcho(EchoEngine::small())), 16);
    let mut c = Client::connect(server.local_addr()).unwrap();
    match c.infer(9, &[1, 1, 1, 1]).unwrap() {
        InferOutcome::Ok(r) => assert!(r.cost.is_none(), "cost report rode a disabled reply"),
        InferOutcome::Busy => panic!("busy"),
    }
    server.shutdown();
}

#[test]
fn admin_plane_serves_a_sorted_exposition() {
    // pull-based introspection: `--admin-addr` binds a second listener
    // that answers every connection with one name-sorted text exposition
    // (counters, histograms, replica health, serving gauges) and closes;
    // it dies with the drain
    let server = NetServer::start(
        Arc::new(HealthyEcho(EchoEngine {
            elems: 4,
            capacity: 2,
            replicas: 2,
        })),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            admin_addr: Some("127.0.0.1:0".to_string()),
            max_inflight: 16,
            batch_wait: Duration::from_millis(1),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let admin = server.admin_addr().expect("admin plane requested but not bound");
    let mut c = Client::connect(server.local_addr()).unwrap();
    for i in 0..4u64 {
        assert!(matches!(c.infer(i, &[1, 2, 3, 4]).unwrap(), InferOutcome::Ok(_)));
    }

    let body = newton::net::scrape_statz(admin, Duration::from_secs(5)).unwrap();
    assert!(body.ends_with('\n'), "exposition must end with a newline");
    let lines: Vec<&str> = body.lines().collect();
    let mut sorted = lines.clone();
    sorted.sort_unstable();
    assert_eq!(lines, sorted, "exposition lines are not name-sorted:\n{body}");
    assert!(lines.contains(&"newton_served 4"), "served gauge missing:\n{body}");
    assert!(lines.contains(&"newton_degraded 0"), "degraded gauge missing:\n{body}");
    assert!(
        lines.iter().any(|l| l.starts_with("newton_energy_pj_per_infer ")),
        "energy-per-inference gauge missing:\n{body}"
    );
    assert!(
        lines.contains(&"newton_replica_health{replica=\"0\",state=\"healthy\"} 1"),
        "replica 0 health line missing:\n{body}"
    );
    assert!(
        lines.contains(&"newton_replica_health{replica=\"1\",state=\"quarantined\"} 1"),
        "replica 1 health line missing:\n{body}"
    );
    assert!(
        lines.iter().any(|l| l.starts_with("newton_latency_us{stat=\"p99\"}")),
        "latency gauge missing:\n{body}"
    );
    // one exposition per connection: a second scrape answers too
    let again = newton::net::scrape_statz(admin, Duration::from_secs(5)).unwrap();
    assert!(again.contains("newton_served 4"), "second scrape diverged:\n{again}");

    let stats = server.shutdown();
    assert_eq!(stats.served, 4);
    assert!(
        TcpStream::connect(admin).is_err(),
        "admin listener survived the drain"
    );
}

#[test]
fn stalled_admin_scraper_cannot_pin_the_admin_plane() {
    // regression: the admin loop used to write the exposition inline on
    // the admin thread, so a scraper that connects and never reads could
    // wedge `write_all` against a full send buffer — pinning watchdog
    // ticks and every later scrape behind one bad client. Scrapes now go
    // to a short-lived writer thread with read AND write timeouts on the
    // socket, so stalled peers cost only their own thread.
    let timeouts = newton::net::Timeouts {
        write_timeout: Duration::from_millis(200),
        ..Default::default()
    };
    let server = NetServer::start(
        Arc::new(EchoEngine::small()),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            admin_addr: Some("127.0.0.1:0".to_string()),
            max_inflight: 16,
            batch_wait: Duration::from_millis(1),
            timeouts,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let admin = server.admin_addr().expect("admin plane requested but not bound");
    let mut c = Client::connect(server.local_addr()).unwrap();
    assert!(matches!(c.infer(1, &[1, 2, 3, 4]).unwrap(), InferOutcome::Ok(_)));

    // a pack of scrapers that connect and then never read a byte
    let stalled: Vec<TcpStream> =
        (0..4).map(|_| TcpStream::connect(admin).expect("connect stalled scraper")).collect();
    // let the admin loop accept them all before the real scrape arrives
    std::thread::sleep(Duration::from_millis(50));

    // a well-behaved scrape behind the stalled pack is still answered,
    // well inside the stalled peers' write timeout budget
    let t0 = std::time::Instant::now();
    let body = newton::net::scrape_statz(admin, Duration::from_secs(2)).unwrap();
    assert!(body.contains("newton_served 1"), "scrape behind stalled peers diverged:\n{body}");
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "scrape took {:?} behind stalled scrapers",
        t0.elapsed()
    );

    // and the drain is not wedged behind them either
    let t0 = std::time::Instant::now();
    let stats = server.shutdown();
    assert_eq!(stats.served, 1);
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "drain took {:?} behind stalled scrapers",
        t0.elapsed()
    );
    drop(stalled);
}

#[test]
fn chaos_lanes_still_cover_every_request_exactly_once() {
    // chaos mode over real sockets: client-side fault injection tears
    // frames, stalls reads, and drops connections, and the retry loop
    // must still deliver every request's correct answer exactly once
    let server = start(Arc::new(EchoEngine::wide()), 32);
    let mut cfg = BenchConfig::new(&server.local_addr().to_string());
    cfg.requests = 48;
    cfg.concurrency = 4;
    cfg.seed = 5;
    cfg.fault_seed = 7;
    cfg.fault_rate = 0.1;
    let report = load_generate(&cfg).unwrap();
    assert_eq!(report.logits.len(), 48);
    for (i, logits) in report.logits.iter().enumerate() {
        assert_eq!(logits, &echo_logits(&bench_image(cfg.seed, i)), "request {i}");
    }
    assert!(
        report.injected_faults > 0,
        "rate 0.1 over 48 requests of IO injected nothing"
    );
    assert!(
        report.fault_retries > 0,
        "faults were injected but nothing retried"
    );
    // every retryable failure drops its connection; the next attempt (if
    // the lane is not already done) must reconnect
    assert!(
        report.reconnects + cfg.concurrency as u64 >= report.fault_retries,
        "retries without matching reconnects: {} vs {}",
        report.fault_retries,
        report.reconnects
    );
    server.shutdown();
}

#[test]
fn retry_attempts_share_one_trace_id() {
    // satellite contract: RetryClient mints ONE trace id per logical
    // request, and every resend carries it. A hand-rolled listener reads
    // the first attempt's Infer frame and drops the connection without
    // replying; the retry reconnects and resends, and the second
    // connection serves it. Both wire frames must carry the same nonzero
    // trace, which the reply echoes.
    use newton::net::proto::InferReply;
    use newton::net::{RetryClient, RetryPolicy};

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        // connection 1: read the request, then hang up with no reply
        let (mut s1, _) = listener.accept().unwrap();
        let t1 = match proto::read_msg(&mut s1).unwrap() {
            Msg::Infer(req) => req.trace,
            other => panic!("want Infer on conn 1, got {other:?}"),
        };
        drop(s1);
        // connection 2: the resend; answer it properly
        let (mut s2, _) = listener.accept().unwrap();
        let t2 = match proto::read_msg(&mut s2).unwrap() {
            Msg::Infer(req) => {
                proto::write_msg(
                    &mut s2,
                    &Msg::Reply(InferReply {
                        id: req.id,
                        trace: req.trace,
                        replica: 0,
                        max_abs_err: 0,
                        logits: vec![42],
                        cost: None,
                    }),
                )
                .unwrap();
                req.trace
            }
            other => panic!("want Infer on conn 2, got {other:?}"),
        };
        (t1, t2)
    });

    let policy = RetryPolicy {
        deadline: Duration::from_secs(10),
        attempt_timeout: Duration::from_secs(2),
        ..RetryPolicy::default()
    };
    let mut c = RetryClient::new(&addr.to_string(), policy, 9);
    let reply = c.infer(7, &[1, 2, 3, 4]).expect("retry must recover");
    let (t1, t2) = server.join().unwrap();
    assert_ne!(t1, 0, "first attempt went out untraced");
    assert_eq!(t1, t2, "the resend minted a fresh trace id");
    assert_eq!(c.last_trace(), t1, "client-side trace record disagrees with the wire");
    assert_eq!(reply.trace, t1, "reply does not echo the logical request's trace");
    assert_eq!(reply.logits, vec![42]);
    assert!(c.reconnects() >= 1, "recovery without a reconnect");
}

#[test]
fn duplicate_trace_dispatch_is_counted_server_side() {
    // the server's dedup window spots two dispatched requests carrying
    // the same trace id (a resend whose first attempt was actually
    // served) and bumps net.dup_trace_dispatch, which rides the Stats
    // frame's metrics block
    let server = start(Arc::new(EchoEngine::small()), 16);
    let mut c = Client::connect(server.local_addr()).unwrap();
    let trace = 0xDEAD_0000_0001u64;
    for id in 0..2u64 {
        match c.infer_traced(id, trace, &[1, 1, 1, 1]).unwrap() {
            InferOutcome::Ok(r) => assert_eq!(r.trace, trace),
            InferOutcome::Busy => panic!("busy under a 16-deep limit"),
        }
    }
    let stats = c.stats().unwrap();
    let dup = stats
        .metrics
        .iter()
        .find(|(name, _)| name == "net.dup_trace_dispatch")
        .map(|(_, v)| *v);
    assert!(
        dup.is_some_and(|v| v >= 1),
        "duplicate-trace dispatch not counted; metrics: {:?}",
        stats.metrics
    );
    // the request counter rides along too
    assert!(
        stats
            .metrics
            .iter()
            .any(|(name, v)| name == "net.requests" && *v >= 2),
        "net.requests missing from the stats metrics block"
    );
    server.shutdown();
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
fn pipelined_serve_net_bit_identical_to_non_pipelined_path() {
    // `serve-net --pipeline` loopback: the wavefront stage scheduler
    // behind the socket must not change a bit vs the plain in-process
    // path, and batch accounting lands on the classifier-stage replica
    let engine = Arc::new(
        GoldenServer::replicated(0, AdcKind::Exact, 3, 4)
            .with_pipeline(newton::mapping::StagePolicy::newton())
            .unwrap(),
    );
    let classifier = *engine.pipeline_map().unwrap().assignment.last().unwrap();
    let server = NetServer::start(
        engine,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_inflight: 32,
            batch_wait: Duration::from_millis(2),
            ..ServeConfig::default()
        },
    )
    .unwrap();

    let mut cfg = BenchConfig::new(&server.local_addr().to_string());
    cfg.requests = 12;
    cfg.concurrency = 4;
    cfg.seed = 17;
    let report = load_generate(&cfg).unwrap();
    assert_eq!(report.worst_abs_err, 0, "exact pipelined serving deviated");

    let images: Vec<Vec<i32>> = (0..cfg.requests).map(|i| bench_image(cfg.seed, i)).collect();
    let plain = GoldenServer::replicated(0, AdcKind::Exact, 1, 4);
    assert_eq!(
        report.logits,
        plain.infer(&images),
        "pipelined socket path changed the numbers"
    );

    let stats = server.shutdown();
    assert_eq!(stats.served, 12);
    assert_eq!(stats.per_replica.len(), 3);
    assert_eq!(
        stats.per_replica.iter().sum::<u64>(),
        stats.per_replica[classifier],
        "pipelined batches must be accounted to the classifier replica"
    );
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
fn concurrent_clients_bit_identical_to_in_process_golden() {
    // the acceptance gate: the socket path must not change a single bit
    // vs the in-process GoldenServer under an exact ADC config
    let engine = Arc::new(GoldenServer::replicated(0, AdcKind::Exact, 2, 4));
    let server = NetServer::start(
        engine,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_inflight: 32,
            batch_wait: Duration::from_millis(2),
            ..ServeConfig::default()
        },
    )
    .unwrap();

    let mut cfg = BenchConfig::new(&server.local_addr().to_string());
    cfg.requests = 16;
    cfg.concurrency = 4;
    cfg.seed = 11;
    let report = load_generate(&cfg).unwrap();
    assert_eq!(report.worst_abs_err, 0, "exact serving deviated");

    let images: Vec<Vec<i32>> = (0..cfg.requests).map(|i| bench_image(cfg.seed, i)).collect();
    let golden = GoldenServer::replicated(0, AdcKind::Exact, 1, 4);
    assert_eq!(report.logits, golden.infer(&images), "socket path changed the numbers");

    let stats = server.shutdown();
    assert_eq!(stats.served, 16);
    assert_eq!(stats.per_replica.len(), 2);
    assert_eq!(stats.per_replica.iter().sum::<u64>(), 16);
    assert_eq!(stats.worst_abs_err, 0);
}
