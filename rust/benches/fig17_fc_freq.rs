//! Fig 17 — chip peak power as FC tiles run 8x/32x/128x slower.
//! Paper: power is lowest at 128x (~50% lower peak power on average);
//! throughput is unaffected because FC is off the critical path.
use newton::config::{ChipConfig, XbarParams};
use newton::mapping::{Mapping, MappingPolicy};
use newton::pipeline::evaluate;
use newton::tiles::fc_slowdown_sweep;
use newton::util::{f2, geomean, Table};
use newton::workloads;

fn main() {
    let p = XbarParams::default();
    let mut chip = ChipConfig::newton();
    // isolate the frequency effect: sweep from un-shared (1:1) FC ADCs,
    // like the paper's Fig 17 (sharing is Fig 18's axis)
    chip.fc_tile.ima.xbars_per_adc = 1;
    println!("=== Fig 17: FC-tile ADC slowdown vs chip peak power (W) ===");
    let slows = [1.0, 8.0, 32.0, 128.0];
    let mut headers = vec!["net".to_string()];
    headers.extend(slows.iter().map(|s| format!("{s}x")));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    let mut ratio = vec![];
    for net in workloads::suite() {
        let m = Mapping::build(&net, &chip.conv_tile.ima, &p, MappingPolicy::newton(), 16);
        let sweep = fc_slowdown_sweep(&chip, &m, &slows);
        let mut row = vec![net.name.to_string()];
        for (_, w) in &sweep {
            row.push(f2(*w));
        }
        ratio.push(sweep[0].1 / sweep[3].1);
        t.row(&row);
    }
    t.print();
    println!(
        "\ngeomean power reduction 1x -> 128x: {:.2}x (paper: ~2x / 50% lower)",
        geomean(&ratio)
    );

    // throughput must be unchanged (FC off the critical path)
    let base = evaluate(&workloads::vgg_a(), &chip);
    let mut slow = chip.clone();
    slow.fc_tile.ima.adc_slowdown = 8.0;
    let s = evaluate(&workloads::vgg_a(), &slow);
    println!(
        "vgg-a throughput at 128x vs 8x FC tiles: {:.1} vs {:.1} images/s (must match)",
        base.throughput, s.throughput
    );
}
