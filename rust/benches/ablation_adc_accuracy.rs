//! §III-A3 accuracy ablation at model scale: run the rust-golden newton-
//! mini CNN with adaptive sampling and with genuinely lossy ADC resolutions
//! and count classification agreement vs the exact pipeline. Backs the
//! paper's "zero impact on algorithm accuracy" claim for the adaptive
//! scheme — and shows where accuracy actually breaks.
use newton::config::XbarParams;
use newton::util::Table;
use newton::xbar::cnn::{random_images, MiniCnn};

fn main() {
    let cnn = MiniCnn::new(0);
    let n = 16;
    let img = random_images(n, 123);
    let exact = cnn.classify(&img, &XbarParams::default(), false);

    println!("=== adaptive ADC & lossy-ADC classification agreement (newton-mini, {n} images) ===");
    let mut t = Table::new(&["config", "agreement", "note"]);
    let adaptive = cnn.classify(&img, &XbarParams::default(), true);
    let agree = |got: &[usize]| {
        format!(
            "{}/{}",
            exact.iter().zip(got).filter(|(a, b)| a == b).count(),
            n
        )
    };
    t.row(&[
        "adaptive sampling (paper scheme)".into(),
        agree(&adaptive),
        "sub-window rounding: <=1 ulp/logit; near-ties can flip".into(),
    ]);
    for bits in [9u32, 8, 7, 6, 5] {
        let p = XbarParams {
            adc_bits: bits,
            ..XbarParams::default()
        };
        let got = cnn.classify(&img, &p, false);
        let note = match bits {
            9 => "lossless (design point)",
            8 => "needs ISAAC's data encoding (not modelled) -> degrades",
            _ => "below spec",
        };
        t.row(&[format!("{bits}-bit ADC"), agree(&got), note.into()]);
    }
    t.print();
    println!("\npaper: adaptive sampling has zero accuracy impact; the 9-bit ADC is");
    println!("exactly lossless for 128 rows x 1-bit DAC x 2-bit cells.");
    println!("measured: adaptive outputs stay within ~1 ulp of exact (the paper's");
    println!("rounding-carry caveat), so only statistically-tied logits can flip —");
    println!("a truncating (non-adaptive) 8-bit ADC, by contrast, breaks everything.");
}
