//! Fig 19 — improvement from Strassen's algorithm. Paper: +4.5% energy
//! efficiency overall; Resnet gains nothing (small matrices, high wastage).
use newton::config::{ChipConfig, NewtonFeatures};
use newton::pipeline::evaluate;
use newton::util::{f2, geomean, Table};
use newton::workloads;

fn main() {
    let mut pre_f = NewtonFeatures::all();
    pre_f.strassen = false;
    pre_f.hetero_tiles = false;
    let mut post_f = pre_f;
    post_f.strassen = true;
    let pre = ChipConfig::newton_with(pre_f);
    let post = ChipConfig::newton_with(post_f);
    println!("=== Fig 19: Strassen's algorithm ===");
    let mut t = Table::new(&["net", "energy-eff x", "eligible MAC frac"]);
    let mut ee = vec![];
    for net in workloads::suite() {
        let b = evaluate(&net, &pre);
        let s = evaluate(&net, &post);
        let e = b.energy_per_op_pj / s.energy_per_op_pj;
        ee.push(e);
        let total: f64 = net.conv_layers().map(|l| l.macs() as f64).sum();
        let eligible: f64 = net
            .conv_layers()
            .filter(|l| {
                let (r, c) = l.matrix().unwrap();
                newton::strassen::eligible(r, c, &pre.xbar)
            })
            .map(|l| l.macs() as f64)
            .sum();
        t.row(&[
            net.name.to_string(),
            f2(e),
            format!("{:.0}%", eligible / total * 100.0),
        ]);
    }
    t.row(&["geomean".into(), f2(geomean(&ee)), "".into()]);
    t.print();
    println!("\npaper: +4.5% energy efficiency; resnet does not benefit;");
    println!("also frees 1-in-8 IMAs for more compact mapping");
}
