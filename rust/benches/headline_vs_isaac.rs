//! Headline comparison (paper abstract): Newton vs ISAAC across the suite,
//! plus the §I energy ladder.
use newton::baselines;
use newton::metrics::headline;
use newton::util::{f2, Table};
use newton::workloads;

fn main() {
    let h = headline(&workloads::suite());
    println!("=== headline: Newton vs ISAAC (geomean over suite) ===");
    let mut t = Table::new(&["metric", "paper", "model"]);
    t.row(&["power decrease".into(), "77%".into(), format!("{:.1}%", h.power_decrease * 100.0)]);
    t.row(&["energy decrease".into(), "51%".into(), format!("{:.1}%", h.energy_decrease * 100.0)]);
    t.row(&["throughput/area".into(), "2.2x".into(), format!("{:.2}x", h.throughput_area_ratio)]);
    t.row(&["newton pJ/op".into(), "0.85".into(), f2(h.newton_pj_per_op)]);
    t.row(&["isaac pJ/op".into(), "1.8".into(), f2(h.isaac_pj_per_op)]);
    t.print();

    println!("\n=== energy ladder (paper §I), pJ/op ===");
    let mut t = Table::new(&["design", "model", "paper"]);
    t.row(&["ideal neuron".into(), f2(baselines::ideal_neuron().pj_per_op), "0.33".into()]);
    t.row(&["newton".into(), f2(h.newton_pj_per_op), "0.85".into()]);
    t.row(&["eyeriss".into(), f2(baselines::eyeriss().pj_per_op), "1.67".into()]);
    t.row(&["isaac".into(), f2(h.isaac_pj_per_op), "1.8".into()]);
    t.row(&["dadiannao".into(), f2(baselines::dadiannao().pj_per_op), "3.5".into()]);
    t.print();
    println!("\npaper conclusion: Newton cuts the ISAAC-to-ideal gap roughly in half");
    let gap_frac = (h.newton_pj_per_op - 0.33) / (h.isaac_pj_per_op - 0.33);
    println!("model: remaining gap = {:.0}% of ISAAC's", gap_frac * 100.0);
}
