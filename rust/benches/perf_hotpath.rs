//! Hot-path timing microbenchmarks (EXPERIMENTS.md §Perf, L3).
//!
//! Times the coordinator-side hot paths with a median-of-N harness
//! (criterion is unavailable offline): the analytic suite evaluation (inner
//! loop of every design-space sweep), the rust golden-model VMM, the
//! batcher, and — when artifacts exist — the PJRT VMM/stage/model execute
//! path used at serve time.

use std::time::Instant;

use newton::config::{ChipConfig, XbarParams};
use newton::coordinator::batcher::{Batcher, PendingRequest};
use newton::pipeline::evaluate_suite;
use newton::runtime::{default_artifacts_dir, Runtime};
use newton::util::{median, Rng};
use newton::workloads;
use newton::xbar::{vmm, Matrix};

/// Median wall time of `f` over `n` runs, after one warmup, in microseconds.
fn bench<T>(name: &str, n: usize, mut f: impl FnMut() -> T) {
    let _ = f();
    let mut times = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    println!("{name:44} {:12.1} us (median of {n})", median(&times));
}

fn main() {
    println!("=== L3 hot-path microbenchmarks ===");
    let nets = workloads::suite();
    let newton_chip = ChipConfig::newton();
    let isaac_chip = ChipConfig::isaac();
    bench("analytic: evaluate_suite(newton)", 20, || {
        evaluate_suite(&nets, &newton_chip)
    });
    bench("analytic: evaluate_suite(isaac)", 20, || {
        evaluate_suite(&nets, &isaac_chip)
    });

    let p = XbarParams::default();
    let mut rng = Rng::new(3);
    let x = Matrix::from_fn(8, p.rows, |_, _| rng.range_i64(0, 1 << 16));
    let w = Matrix::from_fn(p.rows, 256, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
    bench("golden model: 8x128x256 bit-serial VMM", 10, || {
        vmm(&x, &w, &p)
    });

    bench("batcher: 1024 requests through batches of 8", 50, || {
        let mut b = Batcher::new(8, 4, std::time::Duration::from_secs(0));
        let mut taken = 0;
        for i in 0..1024u64 {
            b.push(PendingRequest {
                id: i,
                image: vec![0; 4],
                enqueued: Instant::now(),
            });
            while let Some(batch) = b.take_batch() {
                taken += batch.n_real;
            }
        }
        taken
    });

    let dir = default_artifacts_dir();
    match Runtime::new(&dir) {
        Ok(mut rt) => {
            let (_, vin) = rt.manifest.load_testvec("vmm_in").unwrap();
            rt.compile("vmm_plain").unwrap();
            bench("pjrt: vmm_plain (8x128 -> 8x256)", 20, || {
                rt.run("vmm_plain", &vin).unwrap()
            });
            let (_, input) = rt.manifest.load_testvec("input_b8").unwrap();
            rt.compile("stage0_b8").unwrap();
            bench("pjrt: stage0 conv (8x32x32x3)", 5, || {
                rt.run("stage0_b8", &input).unwrap()
            });
            rt.compile("model_b8").unwrap();
            bench("pjrt: fused model (batch 8)", 3, || {
                rt.run("model_b8", &input).unwrap()
            });
        }
        Err(_) => println!("pjrt benches skipped (run `make artifacts`)"),
    }
}
