//! Hot-path timing microbenchmarks (PERF.md §Measuring, L3).
//!
//! Times the coordinator-side hot paths with a median-of-N harness
//! (criterion is unavailable offline): the analytic suite evaluation —
//! sequential vs the parallel `evaluate_grid` engine — the rust golden
//! model VMM through the legacy per-call engine vs the install-once
//! `ProgrammedXbar` (per-call and amortised), the programmed CNN forward,
//! the batcher, the pipelined staged replica pool (wavefront overlap vs
//! the sequential whole-batch pass), and — when artifacts exist — the
//! PJRT execute path.
//!
//! Alongside the human table it emits `BENCH_hotpath.json` (median µs per
//! case plus derived speedups) so future PRs have a perf trajectory to
//! compare against. `--smoke` shrinks the run counts for CI.
//!
//! Run: `cargo bench --bench perf_hotpath [-- --smoke]`

use std::time::Instant;

use newton::cli::Args;
use newton::config::{ChipConfig, NewtonFeatures, XbarParams};
use newton::coordinator::batcher::{Batcher, PendingRequest};
use newton::coordinator::pipeline::forward_pipelined;
use newton::mapping::{StageMap, StagePolicy};
use newton::pipeline::{evaluate, evaluate_grid, evaluate_suite};
use newton::runtime::{default_artifacts_dir, Runtime};
use newton::sched::{self, Executor};
use newton::util::{median, worker_count, Rng};
use newton::workloads;
use newton::xbar::cnn::{random_images, MiniCnn};
use newton::xbar::{reference, scale_clamp, Matrix, ProgrammedXbar};

struct Harness {
    results: Vec<(String, f64, usize)>,
    scale: usize,
}

impl Harness {
    /// Median wall time of `f` over `n/scale` runs, after one warmup, in µs.
    fn bench<T>(&mut self, name: &str, n: usize, mut f: impl FnMut() -> T) -> f64 {
        let n = (n / self.scale).max(3);
        let _ = f();
        let mut times = Vec::with_capacity(n);
        for _ in 0..n {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        let med = median(&times);
        println!("{name:52} {med:12.1} us (median of {n})");
        self.results.push((name.to_string(), med, n));
        med
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let smoke = args.has_flag("smoke");
    let mut h = Harness {
        results: Vec::new(),
        scale: if smoke { 5 } else { 1 },
    };
    println!("=== L3 hot-path microbenchmarks{} ===", if smoke { " (smoke)" } else { "" });

    // ---- analytic sweeps: sequential vs parallel ---------------------------
    let nets = workloads::suite();
    let newton_chip = ChipConfig::newton();
    let isaac_chip = ChipConfig::isaac();
    let seq = h.bench("analytic: suite sequential (9 nets)", 20, || {
        nets.iter().map(|n| evaluate(n, &newton_chip)).collect::<Vec<_>>()
    });
    let par = h.bench("analytic: evaluate_suite parallel (9 nets)", 20, || {
        evaluate_suite(&nets, &newton_chip)
    });
    h.bench("analytic: evaluate_suite(isaac)", 20, || {
        evaluate_suite(&nets, &isaac_chip)
    });
    let grid_chips: Vec<ChipConfig> = NewtonFeatures::incremental()
        .into_iter()
        .map(|(_, f)| ChipConfig::newton_with(f))
        .collect();
    h.bench("analytic: evaluate_grid 7 designs x 9 nets", 10, || {
        evaluate_grid(&nets, &grid_chips)
    });

    // ---- golden-model VMM: legacy per-call vs install-once -----------------
    let p = XbarParams::default();
    let mut rng = Rng::new(3);
    let x = Matrix::from_fn(8, p.rows, |_, _| rng.range_i64(0, 1 << 16));
    let w = Matrix::from_fn(p.rows, 256, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
    let legacy = h.bench("golden: 8x128x256 VMM, legacy per-call engine", 16, || {
        scale_clamp(&reference::vmm_raw_reference(&x, &w, &p, false), &p)
    });
    h.bench("golden: 8x128x256 VMM, install+run per call", 16, || {
        let programmed = ProgrammedXbar::install(&w, &p, false);
        scale_clamp(&programmed.run(&x), &p)
    });
    let programmed = ProgrammedXbar::install(&w, &p, false);
    let amortised = h.bench("golden: 8x128x256 VMM, installed (amortised)", 16, || {
        scale_clamp(&programmed.run(&x), &p)
    });

    // ---- digit-major slice engine (adaptive / lossy, b=1 and b=8) ----------
    // the configs the fused shortcut cannot serve: legacy slice-major
    // per-call sweep vs the installed digit-major engine. b=1 isolates the
    // layout + install amortisation (no batch fan-out); b=8 stacks the
    // batch-row parallelism on top.
    let x1 = Matrix::from_fn(1, p.rows, |_, _| rng.range_i64(0, 1 << 16));
    let legacy_adaptive_b1 = h.bench("golden: 1x128x256 VMM, legacy adaptive", 12, || {
        reference::vmm_raw_reference(&x1, &w, &p, true)
    });
    let programmed_adaptive = ProgrammedXbar::install(&w, &p, true);
    let slice_adaptive_b1 = h.bench("golden: 1x128x256 VMM, installed adaptive (slice)", 12, || {
        programmed_adaptive.run(&x1)
    });
    let legacy_adaptive = h.bench("golden: 8x128x256 VMM, legacy adaptive", 10, || {
        reference::vmm_raw_reference(&x, &w, &p, true)
    });
    let amortised_adaptive = h.bench("golden: 8x128x256 VMM, installed adaptive (slice)", 10, || {
        programmed_adaptive.run(&x)
    });
    let lossy_p = XbarParams {
        adc_bits: 8,
        ..p
    };
    let legacy_lossy_b1 = h.bench("golden: 1x128x256 VMM, legacy lossy:8", 12, || {
        reference::vmm_raw_reference(&x1, &w, &lossy_p, false)
    });
    let programmed_lossy = ProgrammedXbar::install(&w, &lossy_p, false);
    let slice_lossy_b1 = h.bench("golden: 1x128x256 VMM, installed lossy:8 (slice)", 12, || {
        programmed_lossy.run(&x1)
    });
    let legacy_lossy_b8 = h.bench("golden: 8x128x256 VMM, legacy lossy:8", 10, || {
        reference::vmm_raw_reference(&x, &w, &lossy_p, false)
    });
    let slice_lossy_b8 = h.bench("golden: 8x128x256 VMM, installed lossy:8 (slice)", 10, || {
        programmed_lossy.run(&x)
    });

    // ---- programmed CNN forward -------------------------------------------
    let cnn = MiniCnn::new(0);
    let img = random_images(2, 7);
    let legacy_cnn = h.bench("cnn: newton-mini forward b2, per-call weights", 5, || {
        cnn.forward(&img, &p, false)
    });
    let programmed_cnn = cnn.program(&p, false);
    let amortised_cnn = h.bench("cnn: newton-mini forward b2, installed", 5, || {
        programmed_cnn.forward(&img)
    });

    // serving batch, before/after this PR's split: the whole-batch pass
    // (PR 1's engine — parallel only inside each chunked VMM via the
    // batch-row fan-out) vs the per-image split across the pool
    // (bit-identical by property test)
    let img8 = random_images(8, 13);
    let cnn_seq_b8 = h.bench("cnn: newton-mini forward b8, whole-batch (VMM rows)", 3, || {
        programmed_cnn.forward_seq(&img8)
    });
    let cnn_par_b8 = h.bench("cnn: newton-mini forward b8, per-image sched", 3, || {
        programmed_cnn.forward(&img8)
    });

    // pipelined stage scheduling: stage s of image k+1 overlaps stage s+1
    // of image k on distinct replicas (coordinator::pipeline wavefront,
    // newton stage policy: classifier replica isolated). The baseline for
    // the overlap claim is *device-sequential*: one replica run inside a
    // pool worker, where the per-VMM batch-row fan-out is suppressed
    // (sched::in_worker) exactly as it is inside every pipeline stage job
    // — replicas, not cores, are the unit being provisioned. The
    // whole-batch pass above (cnn_seq_b8) is NOT that baseline: on the
    // caller thread its chunked VMMs fan rows across every core, so the
    // multicore ratio is reported separately and ungated.
    let cnn_seq_dev_b8 = h.bench("cnn: newton-mini forward b8, one replica in-worker", 3, || {
        Executor::new(2).map(2, |i| (i == 0).then(|| programmed_cnn.forward_seq(&img8)))
    });
    let pipe_pool: Vec<_> = (0..4).map(|_| cnn.program(&p, false)).collect();
    let map_r4 =
        StageMap::build(pipe_pool[0].n_conv_stages(), 4, StagePolicy::newton()).unwrap();
    let exec_r4 = Executor::new(worker_count(4));
    let cnn_pipe_b8_r4 = h.bench("cnn: newton-mini forward b8, pipelined 4 replicas", 3, || {
        forward_pipelined(&pipe_pool[..], &map_r4, &img8, &exec_r4)
    });
    // tracing overhead on the same workload: per-cell + per-stage spans
    // live, draining into the bounded global sink (drop-oldest, so a full
    // ring costs the same as an empty one). verify.sh gates the ratio.
    newton::obs::set_trace_level(newton::obs::TraceLevel::Spans);
    let cnn_pipe_b8_r4_traced =
        h.bench("cnn: newton-mini forward b8, pipelined 4 replicas, traced", 3, || {
            forward_pipelined(&pipe_pool[..], &map_r4, &img8, &exec_r4)
        });
    newton::obs::set_trace_level(newton::obs::TraceLevel::Off);
    // cost-ledger overhead on the same workload: per-run scratch counting
    // plus per-stage registry attribution live. verify.sh gates the ratio
    // next to trace_overhead_b8.
    newton::obs::ledger::set_enabled(true);
    let cnn_pipe_b8_r4_ledgered =
        h.bench("cnn: newton-mini forward b8, pipelined 4 replicas, ledgered", 3, || {
            forward_pipelined(&pipe_pool[..], &map_r4, &img8, &exec_r4)
        });
    newton::obs::ledger::set_enabled(false);
    let map_r2 =
        StageMap::build(pipe_pool[0].n_conv_stages(), 2, StagePolicy::newton()).unwrap();
    let exec_r2 = Executor::new(worker_count(2));
    let cnn_pipe_b8_r2 = h.bench("cnn: newton-mini forward b8, pipelined 2 replicas", 3, || {
        forward_pipelined(&pipe_pool[..2], &map_r2, &img8, &exec_r2)
    });

    // ---- sched executor: contiguous vs stealing on a skewed mix ------------
    // first eighth of the jobs cost 10x (a resnet column on a design grid):
    // the contiguous split strands every other worker behind worker 0
    let skew_jobs = 256usize;
    let heavy_spins = if smoke { 60_000 } else { 300_000 };
    let cost = move |i: usize| {
        if i < skew_jobs / 8 {
            heavy_spins
        } else {
            heavy_spins / 10
        }
    };
    let skewed = |exec: &Executor| exec.map(skew_jobs, |i| sched::spin_job(i as u64, cost(i)));
    let pool = worker_count(skew_jobs);
    let sched_one = h.bench("sched: skewed 256 jobs, 1 worker", 8, || {
        skewed(&Executor::new(1))
    });
    let sched_contig = h.bench("sched: skewed 256 jobs, N workers contiguous", 8, || {
        skewed(&Executor::contiguous(pool))
    });
    let sched_steal = h.bench("sched: skewed 256 jobs, N workers stealing", 8, || {
        skewed(&Executor::new(pool))
    });

    // ---- batcher -----------------------------------------------------------
    h.bench("batcher: 1024 requests through batches of 8", 50, || {
        let mut b = Batcher::new(8, 4, std::time::Duration::from_secs(0));
        let mut taken = 0;
        for i in 0..1024u64 {
            b.push(PendingRequest {
                id: i,
                trace: 0,
                image: vec![0; 4],
                enqueued: Instant::now(),
            });
            while let Some(batch) = b.take_batch() {
                taken += batch.n_real;
            }
        }
        taken
    });

    // ---- PJRT (artifact-gated) --------------------------------------------
    let dir = default_artifacts_dir();
    match Runtime::new(&dir) {
        Ok(mut rt) => {
            let (_, vin) = rt.manifest.load_testvec("vmm_in").unwrap();
            rt.compile("vmm_plain").unwrap();
            h.bench("pjrt: vmm_plain (8x128 -> 8x256)", 20, || {
                rt.run("vmm_plain", &vin).unwrap()
            });
            let (_, input) = rt.manifest.load_testvec("input_b8").unwrap();
            rt.compile("stage0_b8").unwrap();
            h.bench("pjrt: stage0 conv (8x32x32x3)", 5, || {
                rt.run("stage0_b8", &input).unwrap()
            });
            rt.compile("model_b8").unwrap();
            h.bench("pjrt: fused model (batch 8)", 3, || {
                rt.run("model_b8", &input).unwrap()
            });
        }
        Err(_) => println!("pjrt benches skipped (run `make artifacts`)"),
    }

    // ---- per-inference ledger aggregates -----------------------------------
    // one ledgered lossy:8 adaptive b8 forward (the regime where the slice
    // engine, adaptive truncation, and zero-slice skips are all live),
    // priced through the serving tile model — the keys PERF.md's measured
    // table and BENCH_net.json share
    let cnn_lossy = cnn.program(&lossy_p, true);
    newton::obs::ledger::set_enabled(true);
    let mut ledger_scratch = newton::xbar::cnn::ForwardScratch::new();
    let _ = cnn_lossy.forward_seq_with(&img8, &mut ledger_scratch);
    newton::obs::ledger::set_enabled(false);
    let ledger = ledger_scratch.take_ledger();
    let tile = newton::energy::TileModel::new(ChipConfig::newton().conv_tile, lossy_p);
    let adc_ops_per_infer = ledger.adc_ops() as f64 / 8.0;
    let skipped_slice_frac = ledger.skipped_slice_frac();
    let energy_pj_per_infer = tile.ledger_energy_pj(&ledger) / 8.0;

    // ---- derived speedups + machine-readable artifact ----------------------
    let vmm_speedup = legacy / amortised.max(1e-9);
    let vmm_slice_speedup = legacy_adaptive / amortised_adaptive.max(1e-9);
    let slice_adaptive_b1_speedup = legacy_adaptive_b1 / slice_adaptive_b1.max(1e-9);
    let slice_lossy_b1_speedup = legacy_lossy_b1 / slice_lossy_b1.max(1e-9);
    let slice_lossy_b8_speedup = legacy_lossy_b8 / slice_lossy_b8.max(1e-9);
    let suite_speedup = seq / par.max(1e-9);
    let cnn_speedup = legacy_cnn / amortised_cnn.max(1e-9);
    let sched_scaling_speedup = sched_one / sched_steal.max(1e-9);
    let sched_steal_speedup = sched_contig / sched_steal.max(1e-9);
    let cnn_image_split_speedup = cnn_seq_b8 / cnn_par_b8.max(1e-9);
    let pipeline_speedup_b8 = cnn_seq_dev_b8 / cnn_pipe_b8_r4.max(1e-9);
    let pipeline_speedup_b8_r2 = cnn_seq_dev_b8 / cnn_pipe_b8_r2.max(1e-9);
    let pipeline_vs_multicore_b8 = cnn_seq_b8 / cnn_pipe_b8_r4.max(1e-9);
    let trace_overhead_b8 = cnn_pipe_b8_r4_traced / cnn_pipe_b8_r4.max(1e-9);
    let ledger_overhead_b8 = cnn_pipe_b8_r4_ledgered / cnn_pipe_b8_r4.max(1e-9);
    println!("\nderived:");
    println!("  amortised VMM speedup (installed vs legacy) : {vmm_speedup:7.1}x (target >= 5x)");
    println!("  slice-engine speedup (adaptive b8)          : {vmm_slice_speedup:7.1}x (target >= 2x)");
    println!("  slice-engine speedup (adaptive b1)          : {slice_adaptive_b1_speedup:7.1}x");
    println!("  slice-engine speedup (lossy:8 b1)           : {slice_lossy_b1_speedup:7.1}x");
    println!("  slice-engine speedup (lossy:8 b8)           : {slice_lossy_b8_speedup:7.1}x");
    println!("  evaluate_suite parallel speedup             : {suite_speedup:7.1}x over sequential");
    println!("  programmed CNN forward speedup              : {cnn_speedup:7.1}x");
    println!("  sched scaling (1 worker vs {pool} stealing)     : {sched_scaling_speedup:7.1}x");
    println!("  sched stealing vs contiguous (skewed mix)   : {sched_steal_speedup:7.1}x");
    println!("  cnn b8 per-image split vs sequential        : {cnn_image_split_speedup:7.1}x");
    println!("  cnn b8 pipelined stages, 4 replicas         : {pipeline_speedup_b8:7.1}x over one device-sequential replica");
    println!("  cnn b8 pipelined stages, 2 replicas         : {pipeline_speedup_b8_r2:7.1}x over one device-sequential replica");
    println!("  cnn b8 pipelined vs multicore whole-batch   : {pipeline_vs_multicore_b8:7.1}x (informational)");
    println!("  tracing overhead, pipelined b8 (spans on)   : {trace_overhead_b8:7.2}x (target <= 1.03x)");
    println!("  ledger overhead, pipelined b8 (counts on)   : {ledger_overhead_b8:7.2}x (target <= 1.03x)");
    println!("  ADC ops per inference (lossy:8 adaptive b8) : {adc_ops_per_infer:9.0}");
    println!("  skipped slice fraction (lossy:8 adaptive)   : {skipped_slice_frac:9.4}");
    println!("  modeled energy per inference                : {energy_pj_per_infer:9.1} pJ");

    let mut json = String::from("{\n  \"cases\": [\n");
    for (i, (name, med, n)) in h.results.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{name}\", \"median_us\": {med:.3}, \"runs\": {n}}}{}\n",
            if i + 1 < h.results.len() { "," } else { "" }
        ));
    }
    json.push_str(&format!(
        "  ],\n  \"derived\": {{\n    \"vmm_amortised_speedup\": {vmm_speedup:.2},\n    \"vmm_slice_engine_speedup\": {vmm_slice_speedup:.2},\n    \"slice_speedup_adaptive_b1\": {slice_adaptive_b1_speedup:.2},\n    \"slice_speedup_adaptive_b8\": {vmm_slice_speedup:.2},\n    \"slice_speedup_lossy_b1\": {slice_lossy_b1_speedup:.2},\n    \"slice_speedup_lossy_b8\": {slice_lossy_b8_speedup:.2},\n    \"suite_parallel_speedup\": {suite_speedup:.2},\n    \"cnn_programmed_speedup\": {cnn_speedup:.2},\n    \"sched_scaling_speedup\": {sched_scaling_speedup:.2},\n    \"sched_steal_speedup\": {sched_steal_speedup:.2},\n    \"cnn_image_split_speedup\": {cnn_image_split_speedup:.2},\n    \"pipeline_speedup_b8\": {pipeline_speedup_b8:.2},\n    \"pipeline_speedup_b8_r2\": {pipeline_speedup_b8_r2:.2},\n    \"pipeline_vs_multicore_b8\": {pipeline_vs_multicore_b8:.2},\n    \"trace_overhead_b8\": {trace_overhead_b8:.3},\n    \"ledger_overhead_b8\": {ledger_overhead_b8:.3},\n    \"adc_ops_per_infer\": {adc_ops_per_infer:.3},\n    \"skipped_slice_frac\": {skipped_slice_frac:.6},\n    \"energy_pj_per_infer\": {energy_pj_per_infer:.3}\n  }}\n}}\n"
    ));
    match std::fs::write("BENCH_hotpath.json", &json) {
        Ok(()) => println!("\nwrote BENCH_hotpath.json"),
        Err(e) => println!("\ncould not write BENCH_hotpath.json: {e}"),
    }
}
