//! Fig 16 — area-efficiency improvement from the smaller eDRAM buffer
//! (64 KB -> 16 KB via layer spreading). Paper: ~6.5% average.
use newton::config::{ChipConfig, NewtonFeatures};
use newton::pipeline::evaluate;
use newton::util::{f2, geomean, Table};
use newton::workloads;

fn main() {
    let pre = ChipConfig::newton_with(NewtonFeatures {
        constrained_mapping: true,
        adaptive_adc: true,
        karatsuba: 1,
        ..NewtonFeatures::none()
    });
    let post = ChipConfig::newton_with(NewtonFeatures {
        small_buffers: true,
        ..pre.features
    });
    assert_eq!(pre.conv_tile.edram_kb, 64.0);
    assert_eq!(post.conv_tile.edram_kb, 16.0);
    println!("=== Fig 16: smaller eDRAM buffers (64 KB -> 16 KB) ===");
    let mut t = Table::new(&["net", "area-eff x", "power x"]);
    let (mut ae, mut pw) = (vec![], vec![]);
    for net in workloads::suite() {
        let b = evaluate(&net, &pre);
        let s = evaluate(&net, &post);
        let a = s.ce_eff / b.ce_eff;
        let p = b.peak_power_w / s.peak_power_w;
        ae.push(a);
        pw.push(p);
        t.row(&[net.name.to_string(), f2(a), f2(p)]);
    }
    t.row(&["geomean".into(), f2(geomean(&ae)), f2(geomean(&pw))]);
    t.print();
    println!("\npaper: ~6.5% average area-efficiency improvement");
}
