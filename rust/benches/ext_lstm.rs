//! Conclusion extension — "Many of these ideas would also apply ... to
//! other neural networks such as RNN, LSTM": evaluate LSTM stacks on
//! ISAAC vs Newton. Recurrent layers reuse in-situ weights every timestep
//! (no refetch), so the Newton gains carry over.
use newton::config::ChipConfig;
use newton::pipeline::evaluate;
use newton::util::{f1, f2, geomean, Table};
use newton::workloads::lstm;

fn main() {
    let nets = [
        lstm("lstm-512x2-t32", 512, 512, 2, 32),
        lstm("lstm-1024x4-t64", 1024, 1024, 4, 64),
        lstm("lstm-2048x2-t128", 2048, 2048, 2, 128),
    ];
    println!("=== LSTM workloads: ISAAC vs Newton ===");
    let mut t = Table::new(&[
        "net",
        "weights (M)",
        "isaac pJ/op",
        "newton pJ/op",
        "energy x",
        "newton seq/s",
    ]);
    let mut ratios = vec![];
    for net in &nets {
        let i = evaluate(net, &ChipConfig::isaac());
        let n = evaluate(net, &ChipConfig::newton());
        let r = i.energy_per_op_pj / n.energy_per_op_pj;
        ratios.push(r);
        t.row(&[
            net.name.to_string(),
            f1(net.total_weights() as f64 / 1e6),
            f2(i.energy_per_op_pj),
            f2(n.energy_per_op_pj),
            f2(r),
            f1(n.throughput),
        ]);
    }
    t.print();
    println!(
        "\ngeomean energy improvement: {:.2}x — the CNN-era techniques transfer",
        geomean(&ratios)
    );
}
