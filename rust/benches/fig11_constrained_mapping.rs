//! Fig 11 — impact of constrained mapping + compact HTree, per workload.
//! Paper: ~37% better area efficiency, ~18% better power/energy, at the
//! cost of ~9% idle crossbars.
use newton::config::{ChipConfig, NewtonFeatures};
use newton::pipeline::evaluate;
use newton::util::{f2, geomean, Table};
use newton::workloads;

fn main() {
    let isaac = ChipConfig::isaac();
    let constrained = ChipConfig::newton_with(NewtonFeatures {
        constrained_mapping: true,
        ..NewtonFeatures::none()
    });
    println!("=== Fig 11: constrained mapping + compact HTree (vs ISAAC) ===");
    let mut t = Table::new(&["net", "area-eff x", "power x", "energy-eff x"]);
    let (mut ae, mut pw, mut ee) = (vec![], vec![], vec![]);
    for net in workloads::suite() {
        let i = evaluate(&net, &isaac);
        let c = evaluate(&net, &constrained);
        let a = c.ce_eff / i.ce_eff;
        let p = i.peak_power_w / c.peak_power_w;
        let e = i.energy_per_op_pj / c.energy_per_op_pj;
        ae.push(a);
        pw.push(p);
        ee.push(e);
        t.row(&[net.name.to_string(), f2(a), f2(p), f2(e)]);
    }
    t.row(&[
        "geomean".into(),
        f2(geomean(&ae)),
        f2(geomean(&pw)),
        f2(geomean(&ee)),
    ]);
    t.print();
    println!("\npaper: area eff +37% (1.37x), power/energy eff +18% (1.18x)");
}
