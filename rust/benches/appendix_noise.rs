//! Appendix — crossbar non-idealities: the active-row sizing rule, the
//! chip weight-programming delay (§IV: 16.4 ms), and Monte-Carlo output
//! error under write noise / IR drop with and without install-time
//! compensation (Hu et al. [14]).
use newton::config::XbarParams;
use newton::util::{f2, Rng, Table};
use newton::workloads;
use newton::xbar::noise::{noisy_vmm_error, NoiseParams};
use newton::xbar::Matrix;

fn main() {
    let p = XbarParams::default();
    let np = NoiseParams::default();

    println!("=== Appendix: active-row limit rows <= r_range/(l * dr) ===");
    let mut t = Table::new(&["cell bits", "levels", "max active rows", "128-row ok?"]);
    for bits in [1u32, 2, 3, 4] {
        let rows = np.max_active_rows(1 << bits);
        t.row(&[
            bits.to_string(),
            (1u32 << bits).to_string(),
            rows.to_string(),
            if rows >= 128 { "yes".into() } else { "no".into() },
        ]);
    }
    t.print();
    println!("paper: a conservative 128x128 with 2-bit cells is the design point\n");

    println!("=== §IV: chip weight-programming delay ===");
    let mut t = Table::new(&["net", "weights (M)", "program ms (paper: ~16.4)"]);
    for n in workloads::suite() {
        t.row(&[
            n.name.to_string(),
            f2(n.total_weights() as f64 / 1e6),
            f2(np.chip_program_ms(n.total_weights(), &p, 160)),
        ]);
    }
    t.print();

    println!("\n=== Monte-Carlo output error (ULPs of the 16-bit result) ===");
    let mut rng = Rng::new(1);
    let x = Matrix::from_fn(4, p.rows, |_, _| rng.range_i64(0, 1 << 16));
    let w = Matrix::from_fn(p.rows, 16, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
    let mut t = Table::new(&["config", "mean err", "max err"]);
    let configs = [
        ("tight writes + compensation", NoiseParams::default()),
        (
            "tight writes, no compensation",
            NoiseParams {
                compensate_ir: false,
                ..NoiseParams::default()
            },
        ),
        (
            "sloppy writes (1 pv iter)",
            NoiseParams {
                write_tolerance: 0.25,
                pv_iterations: 1,
                ..NoiseParams::default()
            },
        ),
    ];
    for (name, cfg) in configs {
        let (mx, mean) = noisy_vmm_error(&x, &w, &p, &cfg, 77);
        t.row(&[name.to_string(), f2(mean), f2(mx)]);
    }
    t.print();
    println!("\npaper: program-and-verify + encoding keep a 128x128 2-bit array accurate");
}
