//! Fig 15 — per-tile buffer requirement when layers spread across tiles,
//! for different tile/IMA configurations and image sizes. Paper: linear in
//! image size; 16 KB suffices for 256x256 (vs ISAAC's worst-case 64 KB).
use newton::config::{ImaConfig, XbarParams};
use newton::mapping::{Mapping, MappingPolicy};
use newton::util::{f1, Table};
use newton::workloads;

fn main() {
    let p = XbarParams::default();
    let nets = workloads::suite();
    println!("=== Fig 15: buffer requirement per tile (max over suite), KB ===");
    let configs = [
        ("8 IMAs of 128x128", ImaConfig { inputs: 128, outputs: 128, ..ImaConfig::newton_default() }, 8),
        ("16 IMAs of 128x256", ImaConfig::newton_default(), 16),
        ("16 IMAs of 128x512", ImaConfig { inputs: 128, outputs: 512, ..ImaConfig::newton_default() }, 16),
        ("32 IMAs of 128x256", ImaConfig::newton_default(), 32),
    ];
    let mut headers = vec!["image px".to_string()];
    headers.extend(configs.iter().map(|(n, _, _)| n.to_string()));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    for w in [64usize, 128, 224, 256, 384, 512] {
        let mut row = vec![w.to_string()];
        for (_, ima, ipt) in &configs {
            let worst = nets
                .iter()
                .map(|n| {
                    Mapping::build(
                        &n.with_input_width(w),
                        ima,
                        &p,
                        MappingPolicy::newton(),
                        *ipt,
                    )
                    .buffer_per_tile_bytes()
                })
                .fold(0.0f64, f64::max);
            row.push(f1(worst / 1024.0));
        }
        t.row(&row);
    }
    t.print();
    println!("\npaper: 256x256 images fit a 16 KB buffer (75% below ISAAC's 64 KB);");
    println!("requirement grows ~linearly with image width");
}
