//! Fig 18 — area-efficiency improvement when FC tiles share multiple
//! crossbars per ADC. Paper: ~38% average chip-area saving at 4:1; the
//! ratio stops at 4 because the mux gets complex.
use newton::config::{ChipConfig, XbarParams};
use newton::mapping::{Mapping, MappingPolicy};
use newton::tiles::fc_sharing_sweep;
use newton::util::{f1, f2, geomean, Table};
use newton::workloads;

fn main() {
    let p = XbarParams::default();
    let chip = ChipConfig::newton();
    println!("=== Fig 18: FC-tile crossbars per ADC vs chip area (mm2) ===");
    let mut t = Table::new(&["net", "1:1", "2:1", "4:1", "saving @4:1"]);
    let mut savings = vec![];
    for net in workloads::suite() {
        let m = Mapping::build(&net, &chip.conv_tile.ima, &p, MappingPolicy::newton(), 16);
        let sweep = fc_sharing_sweep(&chip, &m, &[1, 2, 4]);
        let save = 1.0 - sweep[2].1 / sweep[0].1;
        savings.push(1.0 - save); // for geomean of ratios
        t.row(&[
            net.name.to_string(),
            f1(sweep[0].1),
            f1(sweep[1].1),
            f1(sweep[2].1),
            format!("{:.0}%", save * 100.0),
        ]);
    }
    t.print();
    println!(
        "\ngeomean area saving at 4:1: {:.0}% (paper: ~38%; resnet gains least)",
        (1.0 - geomean(&savings)) * 100.0
    );
    let _ = f2(0.0);
}
