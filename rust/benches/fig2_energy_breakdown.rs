//! Fig 2 — energy breakdown of a 1x128 . 128x128 16-bit VMM across
//! digital (DaDianNao-like, Eyeriss-like) and analog (ISAAC-like, +Newton
//! optimisations) pipelines.
use newton::adc::{AdaptiveSchedule, SarShares};
use newton::config::XbarParams;
use newton::energy::constants as k;
use newton::karatsuba::DncSchedule;
use newton::util::{f1, Table};

fn main() {
    let p = XbarParams::default();
    let macs = 128.0 * 128.0;
    let ops = 2.0 * macs;

    // --- analog pipeline: per-component pJ for the whole VMM ---------------
    let adc_pj = k::ADC_POWER_MW * 1e-3 / k::ADC_RATE_SPS * 1e12;
    let samples = 128.0 * (p.iters() * p.slices()) as f64; // per column x (i,s)
    let xbar = (k::XBAR_POWER_MW + k::SH_POWER_MW) * 1e-3 * k::CYCLE_NS * (p.slices() * p.iters()) as f64;
    let dac = k::DAC_ARRAY_POWER_MW * 1e-3 * k::CYCLE_NS * (p.slices() * p.iters()) as f64;
    let sa = samples * 0.05;
    let edram = (128.0 + 128.0) * 2.0 * k::EDRAM_PJ_PER_BYTE;

    let isaac_adc = samples * adc_pj;
    let adaptive_scale =
        AdaptiveSchedule::new(&p, 16, 16).energy_scale(&SarShares::default());
    let kara = DncSchedule::new(1, &p).adc_work_ratio(&p);

    // --- digital pipelines: movement-dominated ------------------------------
    let dig_compute = macs * 0.25;
    let dadi_movement = macs * (2.0 * 0.65 + 1.95);
    let eyeriss_movement = macs * (0.55 + 0.82);

    println!("=== Fig 2: VMM energy breakdown, pJ per 1x128x128 16-bit VMM ===");
    let mut t = Table::new(&["pipeline", "compute", "ADC", "DAC+xbar", "S+A", "buffer/mem", "total", "pJ/op"]);
    let rows = [
        ("dadiannao-like", dig_compute, 0.0, 0.0, 0.0, dadi_movement),
        ("eyeriss-like", dig_compute, 0.0, 0.0, 0.0, eyeriss_movement),
        ("isaac-like", 0.0, isaac_adc, dac + xbar, sa, edram),
        ("+adaptive adc", 0.0, isaac_adc * adaptive_scale, dac + xbar, sa, edram),
        ("+karatsuba", 0.0, isaac_adc * adaptive_scale * kara, dac + xbar, sa, edram),
    ];
    for (name, c, a, dx, s, m) in rows {
        let total = c + a + dx + s + m;
        t.row(&[
            name.to_string(),
            f1(c),
            f1(a),
            f1(dx),
            f1(s),
            f1(m),
            f1(total),
            format!("{:.2}", total / ops),
        ]);
    }
    t.print();
    println!("\npaper's point: digital is communication/memory-bound; analog is ADC-bound");
    println!("ADC share of isaac-like analog total: {:.0}%", isaac_adc / (isaac_adc + dac + xbar + sa + edram) * 100.0);
}
