//! Fig 20 — peak CE and PE of DaDianNao, ISAAC and the incrementally
//! enhanced Newton design points. Paper values: DaDianNao ~63 GOPS/mm² /
//! ~286 GOPS/W; ISAAC ~455-480 / ~380; Newton roughly doubles both.
//! The heterogeneous FC tile is excluded (it is deliberately slow).
use newton::baselines;
use newton::metrics::incremental_progression;
use newton::util::{f1, f2, Table};
use newton::workloads;

fn main() {
    println!("=== Fig 20: peak CE and PE of the design points ===");
    let (dce, dpe) = baselines::dadiannao_ce_pe();
    let mut t = Table::new(&["design point", "peak CE GOPS/mm2", "peak PE GOPS/W", "suite pJ/op"]);
    t.row(&["dadiannao (published)".into(), f1(dce), f1(dpe), f2(baselines::dadiannao().pj_per_op)]);
    for r in incremental_progression(&workloads::suite()) {
        if r.label == "+fc-tiles (newton)" {
            // Fig 20 excludes the FC tile from the *peak* plot
            t.row(&[
                "newton (conv tile, fc excluded)".into(),
                f1(r.peak.ce_gops_mm2),
                f1(r.peak.pe_gops_w),
                f2(r.energy_per_op_pj),
            ]);
        } else {
            t.row(&[
                r.label.to_string(),
                f1(r.peak.ce_gops_mm2),
                f1(r.peak.pe_gops_w),
                f2(r.energy_per_op_pj),
            ]);
        }
    }
    t.print();
    println!("\npaper anchors: ISAAC CE ~455-480, PE ~380; adaptive ADC and D&C");
    println!("drive the PE gains; Strassen mostly frees resources (1 IMA in 8)");
}
