//! Fig 23 — per-benchmark energy-efficiency improvement, broken down by
//! technique. Paper: multiple innovations (HTree, adaptive ADC, Karatsuba,
//! FC tiles) contribute comparably; ~51% total energy decrease.
use newton::config::{ChipConfig, NewtonFeatures};
use newton::pipeline::evaluate;
use newton::util::{f2, geomean, Table};
use newton::workloads;

fn main() {
    println!("=== Fig 23: energy-efficiency improvement breakdown (x over ISAAC) ===");
    let steps: Vec<(&str, ChipConfig)> = NewtonFeatures::incremental()
        .into_iter()
        .map(|(l, f)| {
            (
                l,
                if l == "isaac" {
                    ChipConfig::isaac()
                } else {
                    ChipConfig::newton_with(f)
                },
            )
        })
        .collect();
    let mut headers = vec!["net".to_string()];
    headers.extend(steps.iter().skip(1).map(|(l, _)| l.to_string()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    let mut finals = vec![];
    for net in workloads::suite() {
        let base = evaluate(&net, &steps[0].1).energy_per_op_pj;
        let mut row = vec![net.name.to_string()];
        for (i, (_, chip)) in steps.iter().enumerate().skip(1) {
            let x = base / evaluate(&net, chip).energy_per_op_pj;
            if i == steps.len() - 1 {
                finals.push(x);
            }
            row.push(f2(x));
        }
        t.row(&row);
    }
    t.print();
    println!(
        "\nfinal energy efficiency: {:.2}x ISAAC (paper: ~2.05x, i.e. -51% energy)",
        geomean(&finals)
    );
}
