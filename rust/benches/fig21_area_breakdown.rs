//! Fig 21 — per-benchmark area-efficiency improvement, broken down by
//! technique (incremental stacking). Paper: compact HTree and FC tiles are
//! the biggest contributors.
use newton::config::{ChipConfig, NewtonFeatures};
use newton::pipeline::evaluate;
use newton::util::{f2, Table};
use newton::workloads;

fn steps() -> Vec<(&'static str, ChipConfig)> {
    NewtonFeatures::incremental()
        .into_iter()
        .map(|(label, f)| {
            let chip = if label == "isaac" {
                ChipConfig::isaac()
            } else {
                ChipConfig::newton_with(f)
            };
            (label, chip)
        })
        .collect()
}

fn main() {
    println!("=== Fig 21: area-efficiency improvement breakdown (x over ISAAC) ===");
    let chips = steps();
    let mut headers = vec!["net".to_string()];
    headers.extend(chips.iter().skip(1).map(|(l, _)| l.to_string()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for net in workloads::suite() {
        let base = evaluate(&net, &chips[0].1).ce_eff;
        let mut row = vec![net.name.to_string()];
        for (_, chip) in chips.iter().skip(1) {
            row.push(f2(evaluate(&net, chip).ce_eff / base));
        }
        t.row(&row);
    }
    t.print();
    println!("\npaper: final column ~2.2x average; HTree + FC tiles dominate the gains");
}
