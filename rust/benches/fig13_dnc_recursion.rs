//! Fig 13 — CE and PE as Karatsuba divide & conquer is applied recursively.
//! Paper: applying it once is nearly as good as twice, and much simpler.
use newton::config::{ChipConfig, XbarParams};
use newton::energy::TileModel;
use newton::karatsuba::DncSchedule;
use newton::util::{f1, f2, Table};

fn main() {
    let p = XbarParams::default();
    println!("=== Fig 13: recursive divide & conquer ===");
    let mut t = Table::new(&[
        "k",
        "xbars/IMA-slot",
        "iters",
        "ADC samples",
        "ADC work x",
        "CE GOPS/mm2",
        "PE GOPS/W",
    ]);
    let chip = ChipConfig::newton();
    for k in 0..=2u32 {
        let s = DncSchedule::new(k, &p);
        let m = TileModel::with_features(chip.conv_tile, p, true, k);
        t.row(&[
            k.to_string(),
            s.xbars_allocated.to_string(),
            s.time_iters.to_string(),
            s.adc_samples.to_string(),
            f2(s.adc_work_ratio(&p)),
            f1(m.ce()),
            f1(m.pe()),
        ]);
    }
    t.print();
    println!("\npaper: k=1 -> 16 xbars, 17 iters, -15% work; k=2 -> 20 xbars, faster,");
    println!("more ADC savings but diminishing returns -> the paper picks k=1");
}
