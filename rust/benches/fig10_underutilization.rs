//! Fig 10 — crossbar under-utilisation vs constrained-IMA size, averaged
//! over the Table-II suite. Paper: the 128x256 IMA leaves only ~9% unused.
use newton::config::{ImaConfig, XbarParams};
use newton::mapping::avg_underutilization;
use newton::util::{f1, Table};
use newton::workloads;

fn main() {
    let nets = workloads::suite();
    let p = XbarParams::default();
    println!("=== Fig 10: xbar under-utilisation with constrained mapping ===");
    let mut t = Table::new(&["IMA (in x out)", "model under-util %", "paper"]);
    let points = [
        (128usize, 64usize, ""),
        (128, 128, ""),
        (128, 256, "~9% (chosen design point)"),
        (128, 512, ""),
        (256, 512, ""),
        (512, 512, ""),
        (1024, 1024, ""),
        (2048, 1024, ""),
        (8192, 1024, "large IMAs waste significantly"),
    ];
    for (i, o, note) in points {
        let ima = ImaConfig {
            inputs: i,
            outputs: o,
            ..ImaConfig::newton_default()
        };
        let u = avg_underutilization(&nets, &ima, &p, 16);
        t.row(&[format!("{i}x{o}"), f1(u * 100.0), note.to_string()]);
    }
    t.print();
    println!("\nper-net at the 128x256 design point:");
    let mut t = Table::new(&["net", "under-util %"]);
    for n in &nets {
        let m = newton::mapping::Mapping::build(
            n,
            &ImaConfig::newton_default(),
            &p,
            newton::mapping::MappingPolicy::newton(),
            16,
        );
        t.row(&[n.name.to_string(), f1(m.underutilization() * 100.0)]);
    }
    t.print();
}
