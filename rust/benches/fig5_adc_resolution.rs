//! Fig 5 — heterogeneous ADC sampling resolution: relevant bit-tests per
//! (iteration, weight-slice) sample for the default 16x16-bit VMM.
use newton::adc::{AdaptiveSchedule, SarShares};
use newton::config::XbarParams;

fn main() {
    let p = XbarParams::default();
    let s = AdaptiveSchedule::new(&p, 16, 16);
    println!(
        "=== Fig 5: ADC bit-tests per (iteration, slice); kept window [{}, {}) ===",
        p.out_shift,
        p.out_shift + p.out_bits
    );
    println!("iter\\slice   s0  s1  s2  s3  s4  s5  s6  s7");
    for (i, row) in s.tests_matrix().iter().enumerate() {
        let cells: Vec<String> = row.iter().map(|b| format!("{b:3}")).collect();
        println!("   i{:02}      {}", i, cells.join(" "));
    }
    let full = (s.samples.len() as u64) * p.adc_bits as u64;
    println!(
        "\ntotal bit-tests: {} / {} full-resolution ({:.0}% skipped)",
        s.total_tests(),
        full,
        (1.0 - s.total_tests() as f64 / full as f64) * 100.0
    );
    let e = s.energy_scale(&SarShares::default());
    println!("ADC energy scale vs always-9-bit: {:.3} (paper: ~15% chip power saved with ADC ~49% of chip power)", e);
    println!("chip-power saving at 49% ADC share: {:.1}%", (1.0 - e) * 49.0);
}
