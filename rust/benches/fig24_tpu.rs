//! Fig 24 — Newton (8-bit) vs TPU-1, iso-area, 7 ms latency target.
//! Paper: ~10.3x average throughput, ~3.4x energy; MSRA-C is the TPU's
//! worst case (batch 1, weight-streaming-bound); CE 12.3x peak.
use newton::baselines::TpuModel;
use newton::config::{ChipConfig, XbarParams};
use newton::pipeline::evaluate;
use newton::util::{f1, f2, geomean, Table};
use newton::workloads;

/// Newton's 8-bit variant: 8-bit weights (4 slices) and inputs (8 iters).
fn newton_8bit() -> ChipConfig {
    let mut chip = ChipConfig::newton();
    chip.xbar = XbarParams {
        weight_bits: 8,
        input_bits: 8,
        out_shift: 4,
        out_bits: 8,
        ..chip.xbar
    };
    chip
}

fn main() {
    let tpu = TpuModel::default();
    let chip = newton_8bit();
    println!("=== Fig 24: Newton (8-bit) vs TPU-1 (iso-area {:.0} mm2) ===", tpu.area_mm2);
    let mut t = Table::new(&[
        "net",
        "tpu batch",
        "tpu img/s",
        "newton img/s",
        "thr x",
        "tpu mJ/img",
        "newton mJ/img",
        "energy x",
    ]);
    let (mut thr, mut en) = (vec![], vec![]);
    for net in workloads::suite() {
        let tr = tpu.evaluate(&net);
        let nr = evaluate(&net, &chip);
        // iso-area: scale Newton's one-pipeline numbers to the TPU die area
        let scale = tpu.area_mm2 / nr.area_mm2;
        let n_thr = nr.throughput * scale.max(1.0);
        let tx = n_thr / tr.throughput;
        let ex = tr.energy_per_image_mj / nr.energy_per_image_mj;
        thr.push(tx);
        en.push(ex);
        t.row(&[
            net.name.to_string(),
            tr.batch.to_string(),
            f1(tr.throughput),
            f1(n_thr),
            f2(tx),
            f2(tr.energy_per_image_mj),
            f2(nr.energy_per_image_mj),
            f2(ex),
        ]);
    }
    t.print();
    println!(
        "\ngeomean: throughput {:.1}x (paper 10.3x), energy {:.1}x (paper 3.4x)",
        geomean(&thr),
        geomean(&en)
    );
    let pm = newton::metrics::peak_metrics(&chip);
    println!(
        "peak CE: newton-8b {:.0} vs TPU {:.0} GOPS/mm2 -> {:.1}x (paper 12.3x)",
        pm.ce_gops_mm2,
        tpu.peak_ce(),
        pm.ce_gops_mm2 / tpu.peak_ce()
    );
}
