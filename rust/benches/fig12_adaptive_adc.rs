//! Fig 12 — improvement from the adaptive-ADC scheme on top of the
//! compact-HTree design. Paper: ~15% average power reduction (ADC was ~49%
//! of ISAAC chip power), plus area efficiency from the 16-bit out-HTree.
//! Also the CDAC-share sensitivity mentioned in §V.
use newton::adc::{AdaptiveSchedule, SarShares};
use newton::config::{ChipConfig, NewtonFeatures, XbarParams};
use newton::pipeline::evaluate;
use newton::util::{f2, geomean, Table};
use newton::workloads;

fn main() {
    let base = ChipConfig::newton_with(NewtonFeatures {
        constrained_mapping: true,
        ..NewtonFeatures::none()
    });
    let adaptive = ChipConfig::newton_with(NewtonFeatures {
        constrained_mapping: true,
        adaptive_adc: true,
        ..NewtonFeatures::none()
    });
    println!("=== Fig 12: adaptive ADC (vs compact-HTree design) ===");
    let mut t = Table::new(&["net", "power x", "energy-eff x", "area-eff x"]);
    let (mut pw, mut ee, mut ae) = (vec![], vec![], vec![]);
    for net in workloads::suite() {
        let b = evaluate(&net, &base);
        let a = evaluate(&net, &adaptive);
        let p = b.peak_power_w / a.peak_power_w;
        let e = b.energy_per_op_pj / a.energy_per_op_pj;
        let ar = a.ce_eff / b.ce_eff;
        pw.push(p);
        ee.push(e);
        ae.push(ar);
        t.row(&[net.name.to_string(), f2(p), f2(e), f2(ar)]);
    }
    t.row(&["geomean".into(), f2(geomean(&pw)), f2(geomean(&ee)), f2(geomean(&ae))]);
    t.print();
    println!("\npaper: ~15% power reduction; out-HTree carries 16 bits instead of 39");

    // CDAC sensitivity (§V: 10% and 27% CDAC -> 13% and 12% improvements)
    let p = XbarParams::default();
    let sched = AdaptiveSchedule::new(&p, 16, 16);
    println!("\nCDAC-share sensitivity of the ADC energy scale:");
    for share in [0.10, 0.27, 0.30] {
        let e = sched.energy_scale(&SarShares::with_cdac_share(share));
        println!(
            "  cdac {:>4.0}% -> ADC energy scale {:.3} (chip saving at 49% ADC share: {:.1}%)",
            share * 100.0,
            e,
            (1.0 - e) * 49.0
        );
    }
}
