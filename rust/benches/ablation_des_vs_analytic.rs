//! Methodology ablation (§IV): the paper claims analytic estimates match
//! cycle-accurate simulation because the dataflow is deterministic. This
//! bench runs the discrete-event tandem-queue simulator against the
//! analytic model for every workload on both chips.
use newton::config::ChipConfig;
use newton::pipeline::{des, evaluate};
use newton::util::{f1, f2, Table};
use newton::workloads;

fn main() {
    println!("=== §IV ablation: analytic model vs discrete-event simulation ===");
    for (label, chip) in [("ISAAC", ChipConfig::isaac()), ("Newton", ChipConfig::newton())] {
        println!("\n{label}:");
        let mut t = Table::new(&["net", "analytic img/s", "DES img/s", "ratio", "DES fill-latency us"]);
        for net in workloads::suite() {
            let a = evaluate(&net, &chip);
            let d = des::simulate(&net, &chip, 100);
            t.row(&[
                net.name.to_string(),
                f1(a.throughput),
                f1(d.throughput),
                f2(d.throughput / a.throughput),
                f1(d.latency_us),
            ]);
        }
        t.print();
    }
    println!("\npaper: 'analytical estimates are enough to capture the behavior of");
    println!("cycle-accurate simulations' — ratios must sit near 1.0");
}
