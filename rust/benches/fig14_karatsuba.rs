//! Fig 14 — improvement with Karatsuba's algorithm on top of the adaptive
//! ADC design. Paper: ~25% energy-efficiency gain, ~6.4% area-efficiency
//! loss, ADCs busy ~75-80% of the lengthened window.
use newton::config::{ChipConfig, NewtonFeatures, XbarParams};
use newton::karatsuba::DncSchedule;
use newton::pipeline::evaluate;
use newton::util::{f2, geomean, Table};
use newton::workloads;

fn main() {
    let base = ChipConfig::newton_with(NewtonFeatures {
        constrained_mapping: true,
        adaptive_adc: true,
        ..NewtonFeatures::none()
    });
    let kara = ChipConfig::newton_with(NewtonFeatures {
        constrained_mapping: true,
        adaptive_adc: true,
        karatsuba: 1,
        ..NewtonFeatures::none()
    });
    println!("=== Fig 14: Karatsuba (vs adaptive-ADC design) ===");
    let mut t = Table::new(&["net", "energy-eff x", "power x", "area-eff x"]);
    let (mut ee, mut pw, mut ae) = (vec![], vec![], vec![]);
    for net in workloads::suite() {
        let b = evaluate(&net, &base);
        let k = evaluate(&net, &kara);
        let e = b.energy_per_op_pj / k.energy_per_op_pj;
        let p = b.peak_power_w / k.peak_power_w;
        let a = k.ce_eff / b.ce_eff;
        ee.push(e);
        pw.push(p);
        ae.push(a);
        t.row(&[net.name.to_string(), f2(e), f2(p), f2(a)]);
    }
    t.row(&["geomean".into(), f2(geomean(&ee)), f2(geomean(&pw)), f2(geomean(&ae))]);
    t.print();
    let p = XbarParams::default();
    let s = DncSchedule::new(1, &p);
    println!("\nschedule: {} ADC samples (paper 109), {} iters (paper 17), busy {:.0}% (paper ~75%)",
        s.adc_samples, s.time_iters, s.adc_busy_frac(&p) * 100.0);
    println!("paper: energy eff +~25%, area eff -6.4%");
}
