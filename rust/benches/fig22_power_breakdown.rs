//! Fig 22 — per-benchmark decrease in the chip power envelope, broken down
//! by technique. Paper: HTree, adaptive ADC, Karatsuba and FC tiles
//! contribute roughly equally; total ~77% decrease.
use newton::config::{ChipConfig, NewtonFeatures};
use newton::pipeline::evaluate;
use newton::util::{f2, geomean, Table};
use newton::workloads;

fn main() {
    println!("=== Fig 22: power-envelope decrease breakdown (fraction of ISAAC) ===");
    let steps: Vec<(&str, ChipConfig)> = NewtonFeatures::incremental()
        .into_iter()
        .map(|(l, f)| {
            (
                l,
                if l == "isaac" {
                    ChipConfig::isaac()
                } else {
                    ChipConfig::newton_with(f)
                },
            )
        })
        .collect();
    let mut headers = vec!["net".to_string()];
    headers.extend(steps.iter().skip(1).map(|(l, _)| l.to_string()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    let mut finals = vec![];
    for net in workloads::suite() {
        let base = evaluate(&net, &steps[0].1).peak_power_w;
        let mut row = vec![net.name.to_string()];
        for (i, (_, chip)) in steps.iter().enumerate().skip(1) {
            let frac = evaluate(&net, chip).peak_power_w / base;
            if i == steps.len() - 1 {
                finals.push(frac);
            }
            row.push(f2(frac));
        }
        t.row(&row);
    }
    t.print();
    println!(
        "\nfinal power envelope: {:.0}% of ISAAC (paper: 23%, i.e. -77%)",
        geomean(&finals) * 100.0
    );
}
