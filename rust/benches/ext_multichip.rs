//! §III-B2 extension — multi-chip deployments: conv-chips + classifier-
//! chips for workloads beyond one chip's in-situ capacity, with the
//! HyperTransport cut checked against the pipeline rate.
use newton::config::{ChipConfig, XbarParams};
use newton::mapping::{Mapping, MappingPolicy};
use newton::pipeline::evaluate;
use newton::tiles::multichip::MultiChipPlan;
use newton::util::{f1, f2, Table};
use newton::workloads;

fn main() {
    let chip = ChipConfig::newton();
    println!("=== multi-chip plans (max {} tiles/chip) ===", chip.max_tiles);
    let mut t = Table::new(&[
        "net",
        "conv chips",
        "fc chips",
        "cut KB/img",
        "HT-bound img/s",
        "pipeline img/s",
        "total W",
        "total mm2",
    ]);
    for net in workloads::suite() {
        let m = Mapping::build(
            &net,
            &chip.conv_tile.ima,
            &XbarParams::default(),
            MappingPolicy::newton(),
            chip.conv_tile.imas_per_tile,
        );
        let plan = MultiChipPlan::new(&chip, &m, &net);
        let a = evaluate(&net, &chip);
        t.row(&[
            net.name.to_string(),
            plan.conv_chips.to_string(),
            plan.fc_chips.to_string(),
            f2(plan.cut_bytes_per_image as f64 / 1024.0),
            f1(plan.ht_bound_throughput),
            f1(a.throughput),
            f1(plan.total_power_w),
            f1(plan.total_area_mm2),
        ]);
    }
    t.print();
    println!("\npaper: large workloads split into ~equal conv-chips and classifier-chips;");
    println!("HT links must never be the pipeline bottleneck (statically routed)");
}
