//! Table I — key contributing elements: paper spec vs model constants.
use newton::energy::constants as k;
use newton::util::Table;

fn main() {
    println!("=== Table I: component power/area (paper vs model constants) ===");
    let mut t = Table::new(&["component", "spec", "paper power", "model power", "paper area", "model area"]);
    let rows: Vec<[String; 6]> = vec![
        ["router".into(), "32 flits, 8 ports".into(), "168 mW".into(),
         format!("{} mW", k::ROUTER_POWER_MW), "0.604 mm2".into(), format!("{} mm2", k::ROUTER_AREA_MM2)],
        ["adc".into(), "8-bit, 1.2 GSps".into(), "3.1 mW".into(),
         format!("{} mW", k::ADC_POWER_MW), "0.0015 mm2".into(), format!("{} mm2", k::ADC_AREA_MM2)],
        ["hyper-transport".into(), "4 links @ 1.6GHz".into(), "10.4 W".into(),
         format!("{} W", k::HT_POWER_MW / 1000.0), "22.88 mm2".into(), format!("{} mm2", k::HT_AREA_MM2)],
        ["dac array".into(), "128 x 1-bit".into(), "0.5 mW".into(),
         format!("{} mW", k::DAC_ARRAY_POWER_MW), "0.00002 mm2".into(), format!("{} mm2", k::DAC_ARRAY_AREA_MM2)],
        ["memristor xbar".into(), "128x128".into(), "0.3 mW".into(),
         format!("{} mW", k::XBAR_POWER_MW), "0.0001 mm2".into(), format!("{} mm2", k::XBAR_AREA_MM2)],
        ["edram 64KB".into(), "CACTI 6.5 anchor".into(), "20.7 mW".into(),
         format!("{:.1} mW", k::edram_power_mw(64.0)), "0.083 mm2".into(), format!("{:.3} mm2", k::edram_area_mm2(64.0))],
    ];
    for r in rows {
        t.row(&r);
    }
    t.print();
    println!("\n[T1] values are verbatim; [CAL] laws hit the anchors (see energy/constants.rs)");
}
