//! Table II — the benchmark suite: layer counts, weights, MACs per image.
use newton::util::{f1, Table};
use newton::workloads;

fn main() {
    println!("=== Table II: benchmark suite ===");
    let mut t = Table::new(&["net", "convs", "fcs", "weights (M)", "MACs/img (G)", "min fmap px"]);
    for n in workloads::suite() {
        let min_px = n
            .conv_layers()
            .map(|l| l.out_hw())
            .min()
            .unwrap_or(0);
        t.row(&[
            n.name.to_string(),
            n.conv_layers().count().to_string(),
            n.fc_layers().count().to_string(),
            f1(n.total_weights() as f64 / 1e6),
            f1(n.total_macs() as f64 / 1e9),
            min_px.to_string(),
        ]);
    }
    t.print();
    println!("\npaper checks: MSRA nets ~5.5x Alexnet's parameters; Resnet-34 deep but small");
    let a = workloads::alexnet().total_weights() as f64;
    let m = workloads::msra_c().total_weights() as f64;
    println!("  msra-c / alexnet weights = {:.1}x (paper: ~5.5x)", m / a);
}
