//! Component power/area constants (paper Table I + ISAAC-derived values).
//!
//! CALIBRATION NOTE (ARCHITECTURE.md §Substitutions): CACTI 6.5 and Orion 2.0 are
//! not runnable here, so published anchor points are embedded and
//! interpolated with CACTI-shaped laws. Components marked \[T1\] are straight
//! from the paper's Table I; \[ISAAC\] come from the ISAAC paper's tile table;
//! \[CAL\] are calibrated so that the ISAAC baseline configuration lands near
//! its published efficiency (CE ~455-480 GOPS/mm², PE ~380 GOPS/W) while the
//! component *shares* match the text (ADC ~49% of chip power, analog ~61%).

/// \[T1\] 8-bit SAR ADC @ 1.28 GS/s (Kull et al. [18]).
pub const ADC_POWER_MW: f64 = 3.1;
pub const ADC_AREA_MM2: f64 = 0.0015;

/// \[T1\] 128-lane 1-bit DAC array driving one crossbar's wordlines.
pub const DAC_ARRAY_POWER_MW: f64 = 0.5;
pub const DAC_ARRAY_AREA_MM2: f64 = 0.00002;

/// \[T1\] 128x128 memristor crossbar in compute mode.
pub const XBAR_POWER_MW: f64 = 0.3;
pub const XBAR_AREA_MM2: f64 = 0.0001;

/// \[T1\] 32-flit 8-port router (Orion 2.0).
pub const ROUTER_POWER_MW: f64 = 168.0;
pub const ROUTER_AREA_MM2: f64 = 0.604;

/// \[T1\] HyperTransport: 4 links @ 1.6 GHz, 6.4 GB/s each, per chip.
pub const HT_POWER_MW: f64 = 10_400.0;
pub const HT_AREA_MM2: f64 = 22.88;
pub const HT_LINK_GBPS: f64 = 6.4;

/// \[ISAAC\] sample-and-hold per crossbar (8x128 S+H: 10 fJ, tiny area).
pub const SH_POWER_MW: f64 = 0.01;
pub const SH_AREA_MM2: f64 = 0.00004;

/// \[ISAAC\] energy of capturing one analog column sample without an ADC
/// conversion (the identity-ADC fold still pays the sample-and-hold):
/// 10 fJ = 0.01 pJ. The ledger energy model charges this per fold.
pub const SH_SAMPLE_PJ: f64 = 0.01;

/// \[ISAAC\] shift-and-add unit (one per pair of ADC streams).
pub const SA_POWER_MW: f64 = 0.2;
pub const SA_AREA_MM2: f64 = 0.00006;

/// \[ISAAC\] IMA input register (2 KB for the 8-stream worst case; scales
/// with the number of independent input streams the mapping allows).
pub const IR_POWER_MW_8STREAM: f64 = 1.24;
pub const IR_AREA_MM2_8STREAM: f64 = 0.0021;

/// \[ISAAC\] IMA output register (256 B).
pub const OR_POWER_MW: f64 = 0.23;
pub const OR_AREA_MM2: f64 = 0.00077;

/// \[ISAAC\] sigmoid unit (2 per tile).
pub const SIGMOID_POWER_MW: f64 = 0.52;
pub const SIGMOID_AREA_MM2: f64 = 0.0006;
pub const SIGMOIDS_PER_TILE: usize = 2;

/// \[ISAAC\] max/average-pool block per tile.
pub const POOL_POWER_MW: f64 = 0.4;
pub const POOL_AREA_MM2: f64 = 0.00024;

/// \[ISAAC\] tile output register (3 KB).
pub const TILE_OR_POWER_MW: f64 = 1.68;
pub const TILE_OR_AREA_MM2: f64 = 0.0032;

/// \[CAL\] tile control/decode logic.
pub const CTRL_POWER_MW: f64 = 5.0;
pub const CTRL_AREA_MM2: f64 = 0.002;

/// \[ISAAC\] eDRAM-to-IMA bus (256 bits).
pub const EDRAM_BUS_POWER_MW: f64 = 7.0;
/// \[CAL\] CACTI-32nm bus area, reduced from ISAAC's 0.09 to a routed-over
/// estimate (wires over logic).
pub const EDRAM_BUS_AREA_MM2: f64 = 0.03;

/// \[ISAAC\] 64 KB eDRAM buffer anchor: 20.7 mW, 0.083 mm².
/// \[CAL\] CACTI-shaped laws: area ~ fixed periphery + linear in capacity;
/// access power ~ periphery + sqrt-ish in capacity. Anchored at 64 KB and
/// checked to stay sane at 4-64 KB (Fig 15/16 sweep range).
pub fn edram_area_mm2(kb: f64) -> f64 {
    0.012 + (0.083 - 0.012) * (kb / 64.0)
}

pub fn edram_power_mw(kb: f64) -> f64 {
    2.7 + (20.7 - 2.7) * (kb / 64.0).powf(0.75)
}

/// \[CAL\] IMA input HTree: area/power per independent input stream the tree
/// is provisioned for. ISAAC provisions one stream per crossbar (8);
/// Newton's constrained mapping shares a single stream. Calibrated so the
/// constrained-mapping step yields the paper's ~37% area-efficiency and
/// ~18% power gains (Fig 11).
pub const HTREE_IN_POWER_MW_PER_STREAM: f64 = 1.0;
pub const HTREE_IN_AREA_MM2_PER_STREAM: f64 = 0.0012;

/// \[CAL\] IMA output HTree (collects digitised results): per ADC stream and
/// per bit of carried width. ISAAC carries the full 39-bit accumulator;
/// adaptive-ADC Newton carries 16 bits (Fig 12's area effect).
pub const HTREE_OUT_POWER_MW_PER_ADC_BIT: f64 = 0.005;
pub const HTREE_OUT_AREA_MM2_PER_ADC_BIT: f64 = 0.00002;

/// ADC nominal sampling rate (samples/s) matching ADC_POWER_MW.
pub const ADC_RATE_SPS: f64 = 1.28e9;

/// Intra-tile pipeline cycle (one crossbar read), ns.
pub const CYCLE_NS: f64 = 100.0;

/// Energy of moving one byte over the inter-tile network (router + link),
/// pJ/byte. \[CAL\] Orion-flavoured constant used by the pipeline model.
pub const NOC_PJ_PER_BYTE: f64 = 1.8;

/// Energy of one eDRAM byte access, pJ/byte. \[CAL\] from the 64 KB anchor:
/// 20.7 mW at 256 b / 100 ns duty.
pub const EDRAM_PJ_PER_BYTE: f64 = 0.65;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edram_laws_hit_the_isaac_anchor() {
        assert!((edram_area_mm2(64.0) - 0.083).abs() < 1e-9);
        assert!((edram_power_mw(64.0) - 20.7).abs() < 1e-9);
    }

    #[test]
    fn edram_laws_monotone_and_sublinear_power() {
        let a4 = edram_area_mm2(4.0);
        let a16 = edram_area_mm2(16.0);
        let a64 = edram_area_mm2(64.0);
        assert!(a4 < a16 && a16 < a64);
        // 4x capacity < 4x power (periphery amortisation)
        assert!(edram_power_mw(64.0) < 4.0 * edram_power_mw(16.0));
        // but fixed periphery keeps small buffers from being free
        assert!(edram_power_mw(4.0) > 2.7);
    }

    #[test]
    fn adc_energy_per_sample_is_about_2_4_pj() {
        let pj = ADC_POWER_MW * 1e-3 / ADC_RATE_SPS * 1e12;
        assert!((2.0..3.0).contains(&pj), "{pj}");
    }
}
