//! Area/power/energy composition: component -> IMA -> tile -> chip
//! (paper §IV Table I constants; Figs 21/22/23 breakdowns).
//! Serve-path role: the simulated-hardware metrics `newton serve` prints
//! next to the measured wallclock numbers come from this model.
//!
//! `TileModel` assembles a tile's cost breakdown from the component library
//! in [`constants`], applying the Newton technique knobs (ADC energy scale
//! from the adaptive schedule, Karatsuba mat structure, compact HTree,
//! buffer size, FC-tile slowdown). The per-component breakdown is what the
//! Fig 21/22/23 benches print.

pub mod constants;

use crate::adc::{AdaptiveSchedule, SarShares};
use crate::config::{TileConfig, XbarParams};
use crate::karatsuba::DncSchedule;
use constants as k;

/// Chip components tracked in breakdowns.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Component {
    Xbar,
    Dac,
    SampleHold,
    Adc,
    ShiftAdd,
    InHtree,
    OutHtree,
    InputReg,
    OutputReg,
    Edram,
    EdramBus,
    Router,
    Sigmoid,
    Pool,
    TileOr,
    Ctrl,
    Ht,
}

impl Component {
    pub fn name(&self) -> &'static str {
        match self {
            Component::Xbar => "xbar",
            Component::Dac => "dac",
            Component::SampleHold => "s+h",
            Component::Adc => "adc",
            Component::ShiftAdd => "s+a",
            Component::InHtree => "in-htree",
            Component::OutHtree => "out-htree",
            Component::InputReg => "in-reg",
            Component::OutputReg => "out-reg",
            Component::Edram => "edram",
            Component::EdramBus => "edram-bus",
            Component::Router => "router",
            Component::Sigmoid => "sigmoid",
            Component::Pool => "pool",
            Component::TileOr => "tile-or",
            Component::Ctrl => "ctrl",
            Component::Ht => "ht",
        }
    }

    pub fn is_analog(&self) -> bool {
        matches!(
            self,
            Component::Xbar | Component::Dac | Component::SampleHold | Component::Adc
        )
    }
}

/// Power (mW) and area (mm²) of a component instance group.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    pub power_mw: f64,
    pub area_mm2: f64,
}

impl Cost {
    pub fn new(power_mw: f64, area_mm2: f64) -> Self {
        Cost { power_mw, area_mm2 }
    }

    pub fn scaled(self, n: f64) -> Self {
        Cost::new(self.power_mw * n, self.area_mm2 * n)
    }
}

/// Itemised cost list with aggregation helpers.
#[derive(Clone, Debug, Default)]
pub struct CostBreakdown {
    pub items: Vec<(Component, Cost)>,
}

impl CostBreakdown {
    pub fn push(&mut self, c: Component, cost: Cost) {
        self.items.push((c, cost));
    }

    pub fn power_mw(&self) -> f64 {
        self.items.iter().map(|(_, c)| c.power_mw).sum()
    }

    pub fn area_mm2(&self) -> f64 {
        self.items.iter().map(|(_, c)| c.area_mm2).sum()
    }

    pub fn get(&self, comp: Component) -> Cost {
        self.items
            .iter()
            .filter(|(c, _)| *c == comp)
            .fold(Cost::default(), |a, (_, c)| {
                Cost::new(a.power_mw + c.power_mw, a.area_mm2 + c.area_mm2)
            })
    }

    pub fn analog_power_frac(&self) -> f64 {
        let analog: f64 = self
            .items
            .iter()
            .filter(|(c, _)| c.is_analog())
            .map(|(_, c)| c.power_mw)
            .sum();
        analog / self.power_mw()
    }

    pub fn merge(&mut self, other: &CostBreakdown) {
        self.items.extend(other.items.iter().cloned());
    }

    pub fn scaled(&self, n: f64) -> CostBreakdown {
        CostBreakdown {
            items: self.items.iter().map(|&(c, cost)| (c, cost.scaled(n))).collect(),
        }
    }
}

/// A fully-parameterised tile: configuration + technique activity factors.
#[derive(Clone, Debug)]
pub struct TileModel {
    pub cfg: TileConfig,
    pub xbar: XbarParams,
    /// Average ADC energy vs full-resolution sampling (1.0 = ISAAC; the
    /// adaptive schedule's `energy_scale` when the feature is on).
    pub adc_energy_scale: f64,
    /// Karatsuba schedule if enabled.
    pub dnc: Option<DncSchedule>,
}

impl TileModel {
    /// Plain tile, no technique activity adjustments.
    pub fn new(cfg: TileConfig, xbar: XbarParams) -> Self {
        TileModel {
            cfg,
            xbar,
            adc_energy_scale: 1.0,
            dnc: None,
        }
    }

    /// Tile with the feature set's activity factors applied.
    pub fn with_features(
        cfg: TileConfig,
        xbar: XbarParams,
        adaptive_adc: bool,
        karatsuba: u32,
    ) -> Self {
        let mut scale = 1.0;
        if adaptive_adc {
            scale *=
                AdaptiveSchedule::new(&xbar, xbar.input_bits, xbar.weight_bits)
                    .energy_scale(&SarShares::default());
        }
        let dnc = (karatsuba > 0).then(|| DncSchedule::new(karatsuba, &xbar));
        if let Some(d) = &dnc {
            // fewer ADC samples per VMM, spread over the (possibly longer)
            // schedule window -> lower average ADC power
            scale *= d.adc_work_ratio(&xbar) / d.time_ratio(&xbar);
        }
        TileModel {
            cfg,
            xbar,
            adc_energy_scale: scale,
            dnc,
        }
    }

    /// Crossbars per IMA, including Karatsuba's extra mats.
    pub fn xbars_per_ima(&self) -> f64 {
        let base = self.cfg.ima.xbars(&self.xbar) as f64;
        match &self.dnc {
            Some(d) => base * d.xbar_ratio(&self.xbar),
            None => base,
        }
    }

    /// VMM latency in ns (Karatsuba changes the iteration count).
    pub fn vmm_ns(&self) -> f64 {
        let t = match &self.dnc {
            Some(d) => d.time_iters as f64,
            None => self.xbar.iters() as f64,
        };
        t * self.xbar.read_ns * self.cfg.ima.adc_slowdown
    }

    /// Peak tile throughput, GOPS (2 ops per MAC).
    pub fn peak_gops(&self) -> f64 {
        let macs = (self.cfg.ima.inputs * self.cfg.ima.outputs * self.cfg.imas_per_tile) as f64;
        2.0 * macs / self.vmm_ns()
    }

    /// Per-IMA cost breakdown.
    pub fn ima_breakdown(&self) -> CostBreakdown {
        let p = &self.xbar;
        let ima = &self.cfg.ima;
        let xbars = self.xbars_per_ima();
        let adcs = ima.adcs(p) as f64;
        // mats pair crossbars behind a shared DAC when Karatsuba is on
        let dacs = if self.dnc.is_some() { xbars / 2.0 } else { xbars };
        let streams = self.cfg.in_streams as f64;
        let out_bits = self.cfg.out_htree_bits as f64;
        let adc_each =
            crate::adc::adc_power_mw(k::ADC_POWER_MW, ima.adc_slowdown, self.adc_energy_scale);

        let mut b = CostBreakdown::default();
        b.push(Component::Xbar, Cost::new(k::XBAR_POWER_MW, k::XBAR_AREA_MM2).scaled(xbars));
        b.push(
            Component::Dac,
            Cost::new(k::DAC_ARRAY_POWER_MW, k::DAC_ARRAY_AREA_MM2).scaled(dacs),
        );
        b.push(
            Component::SampleHold,
            Cost::new(k::SH_POWER_MW, k::SH_AREA_MM2).scaled(xbars),
        );
        b.push(Component::Adc, Cost::new(adc_each, k::ADC_AREA_MM2).scaled(adcs));
        b.push(
            Component::ShiftAdd,
            Cost::new(k::SA_POWER_MW, k::SA_AREA_MM2).scaled((adcs / 2.0).max(1.0)),
        );
        b.push(
            Component::InHtree,
            Cost::new(
                k::HTREE_IN_POWER_MW_PER_STREAM,
                k::HTREE_IN_AREA_MM2_PER_STREAM,
            )
            .scaled(streams),
        );
        b.push(
            Component::OutHtree,
            Cost::new(
                k::HTREE_OUT_POWER_MW_PER_ADC_BIT,
                k::HTREE_OUT_AREA_MM2_PER_ADC_BIT,
            )
            .scaled(adcs * out_bits),
        );
        b.push(
            Component::InputReg,
            Cost::new(k::IR_POWER_MW_8STREAM, k::IR_AREA_MM2_8STREAM).scaled(streams / 8.0),
        );
        b.push(Component::OutputReg, Cost::new(k::OR_POWER_MW, k::OR_AREA_MM2));
        b
    }

    /// Full tile breakdown: IMAs + buffer + bus + router share + digital.
    pub fn breakdown(&self) -> CostBreakdown {
        let mut b = self.ima_breakdown().scaled(self.cfg.imas_per_tile as f64);
        b.push(
            Component::Edram,
            Cost::new(
                k::edram_power_mw(self.cfg.edram_kb),
                k::edram_area_mm2(self.cfg.edram_kb),
            ),
        );
        b.push(
            Component::EdramBus,
            Cost::new(k::EDRAM_BUS_POWER_MW, k::EDRAM_BUS_AREA_MM2),
        );
        b.push(
            Component::Router,
            Cost::new(k::ROUTER_POWER_MW, k::ROUTER_AREA_MM2).scaled(0.25),
        );
        b.push(
            Component::Sigmoid,
            Cost::new(k::SIGMOID_POWER_MW, k::SIGMOID_AREA_MM2)
                .scaled(k::SIGMOIDS_PER_TILE as f64),
        );
        b.push(Component::Pool, Cost::new(k::POOL_POWER_MW, k::POOL_AREA_MM2));
        b.push(
            Component::TileOr,
            Cost::new(k::TILE_OR_POWER_MW, k::TILE_OR_AREA_MM2),
        );
        b.push(Component::Ctrl, Cost::new(k::CTRL_POWER_MW, k::CTRL_AREA_MM2));
        b
    }

    /// Computational efficiency, GOPS/mm² (peak; excludes off-chip HT like
    /// the paper's Fig 20).
    pub fn ce(&self) -> f64 {
        self.peak_gops() / self.breakdown().area_mm2()
    }

    /// Power efficiency, GOPS/W (peak).
    pub fn pe(&self) -> f64 {
        self.peak_gops() / (self.breakdown().power_mw() / 1000.0)
    }

    /// Peak energy per 16-bit op, pJ.
    pub fn energy_per_op_pj(&self) -> f64 {
        self.breakdown().power_mw() * 1e-3 / self.peak_gops() * 1e3
    }

    /// Modeled energy (pJ) of the work one [`crate::obs::CostLedger`]
    /// records — the bridge from the engine's op counts to the paper's
    /// energy-per-inference figure:
    ///
    /// * quantising ADC conversions at `ADC_POWER_MW / ADC_RATE_SPS`
    ///   (~2.4 pJ), scaled by resolved width over the deployed width — a
    ///   SAR conversion spends one capacitor-settle-and-compare cycle per
    ///   bit, so the adaptive schedule's truncated conversions cost
    ///   proportionally less (§III-B);
    /// * identity-ADC folds at [`constants::SH_SAMPLE_PJ`] — a skipped
    ///   conversion still pays its sample-and-hold;
    /// * row movement at [`constants::EDRAM_PJ_PER_BYTE`] per activation
    ///   byte streamed out of the tile buffer.
    ///
    /// The fold and movement terms keep lossless/fused configurations —
    /// which quantise nothing — from reading as free.
    pub fn ledger_energy_pj(&self, l: &crate::obs::CostLedger) -> f64 {
        let full_bits = self.xbar.adc_bits.min(self.xbar.lossless_adc_bits()).max(1) as f64;
        let adc_sample_pj = k::ADC_POWER_MW * 1e-3 / k::ADC_RATE_SPS * 1e12;
        let bytes_per_elem = self.xbar.input_bits.div_ceil(8) as f64;
        let mut pj = 0.0;
        for (bits, &count) in l.adc_ops_by_bits.iter().enumerate() {
            if count > 0 {
                pj += count as f64 * adc_sample_pj * (bits as f64 / full_bits).min(1.0);
            }
        }
        pj += l.identity_folds as f64 * k::SH_SAMPLE_PJ;
        pj += l.row_elems as f64 * bytes_per_elem * k::EDRAM_PJ_PER_BYTE;
        pj
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipConfig, TileConfig};

    fn isaac_tile() -> TileModel {
        TileModel::new(TileConfig::isaac(), XbarParams::default())
    }

    #[test]
    fn isaac_tile_lands_near_published_efficiency() {
        let t = isaac_tile();
        // 12 IMAs x 8 xbars x 2.56 GOPS = 245.76 GOPS
        assert!((t.peak_gops() - 245.76).abs() < 1e-6, "{}", t.peak_gops());
        let ce = t.ce();
        let pe = t.pe();
        // calibration corridor (ARCHITECTURE.md §Substitutions): ISAAC published CE 455-480,
        // PE ~380; our bottom-up model must land within ~25% on CE and
        // ~15% on PE.
        assert!((330.0..520.0).contains(&ce), "CE {ce}");
        assert!((320.0..450.0).contains(&pe), "PE {pe}");
    }

    #[test]
    fn isaac_component_shares_match_the_text() {
        let b = isaac_tile().breakdown();
        let adc_share = b.get(Component::Adc).power_mw / b.power_mw();
        // paper: "ADC contributed to 49% of the chip power in ISAAC"
        assert!((0.40..0.58).contains(&adc_share), "{adc_share}");
        // "the overhead of analog dominates - 61% of the total power"
        let analog = b.analog_power_frac();
        assert!((0.50..0.70).contains(&analog), "{analog}");
    }

    #[test]
    fn newton_conv_tile_beats_isaac_on_ce_and_pe() {
        let cc = ChipConfig::newton();
        let newton = TileModel::with_features(
            cc.conv_tile,
            cc.xbar,
            cc.features.adaptive_adc,
            cc.features.karatsuba,
        );
        let isaac = isaac_tile();
        assert!(newton.ce() > 1.25 * isaac.ce(), "{} vs {}", newton.ce(), isaac.ce());
        assert!(newton.pe() > 1.4 * isaac.pe(), "{} vs {}", newton.pe(), isaac.pe());
    }

    #[test]
    fn fc_tile_power_is_tiny() {
        let cc = ChipConfig::newton();
        let fc = TileModel::new(cc.fc_tile, cc.xbar);
        let conv = TileModel::new(cc.conv_tile, cc.xbar);
        // 128x slower ADCs + shared ADCs -> order-of-magnitude less power
        assert!(fc.breakdown().power_mw() < 0.35 * conv.breakdown().power_mw());
    }

    #[test]
    fn adaptive_adc_cuts_tile_power() {
        let cc = ChipConfig::newton();
        let plain = TileModel::new(cc.conv_tile, cc.xbar);
        let adaptive = TileModel::with_features(cc.conv_tile, cc.xbar, true, 0);
        let drop = 1.0 - adaptive.breakdown().power_mw() / plain.breakdown().power_mw();
        // paper Fig 12: ~15% chip-power reduction from adaptive sampling
        assert!((0.05..0.30).contains(&drop), "{drop}");
    }

    #[test]
    fn karatsuba_trades_area_for_power() {
        // Fig 14: Karatsuba cuts ADC work (-15%) at the cost of extra
        // crossbars (-6.4% area efficiency). At *peak-power* level the ADC
        // saving must outweigh the extra crossbar power; the full energy
        // win shows up in the pipeline model (see pipeline::tests).
        let cc = ChipConfig::newton();
        let base = TileModel::with_features(cc.conv_tile, cc.xbar, true, 0);
        let kara = TileModel::with_features(cc.conv_tile, cc.xbar, true, 1);
        assert!(kara.breakdown().area_mm2() > base.breakdown().area_mm2());
        assert!(kara.breakdown().power_mw() < base.breakdown().power_mw());
        assert!(kara.ce() < base.ce()); // the area-efficiency price
    }

    #[test]
    fn ledger_energy_charges_every_dimension() {
        let t = isaac_tile();
        let empty = crate::obs::CostLedger::new();
        assert_eq!(t.ledger_energy_pj(&empty), 0.0);

        // a fused forward records only folds and row movement — it must
        // still cost something (the admin smoke keys on nonzero energy
        // under the default lossless config)
        let mut fused = crate::obs::CostLedger::new();
        fused.identity_folds = 1000;
        fused.row_elems = 128;
        let fused_pj = t.ledger_energy_pj(&fused);
        assert!(fused_pj > 0.0, "fused path read as free");

        // quantising the same samples at full width costs strictly more
        let mut full = crate::obs::CostLedger::new();
        full.count_adc(t.xbar.adc_bits, 1000);
        full.row_elems = 128;
        let full_pj = t.ledger_energy_pj(&full);
        assert!(full_pj > fused_pj, "{full_pj} vs {fused_pj}");

        // ...and the adaptive schedule's truncated conversions cost less
        // than full-width ones (bit-proportional SAR energy)
        let mut trunc = crate::obs::CostLedger::new();
        trunc.count_adc(t.xbar.adc_bits - 4, 1000);
        trunc.row_elems = 128;
        let trunc_pj = t.ledger_energy_pj(&trunc);
        assert!(trunc_pj < full_pj, "{trunc_pj} vs {full_pj}");
        assert!(trunc_pj > fused_pj, "a real conversion beats an S+H fold");
    }

    #[test]
    fn breakdown_aggregation_consistent() {
        let b = isaac_tile().breakdown();
        let sum: f64 = b.items.iter().map(|(_, c)| c.power_mw).sum();
        assert!((sum - b.power_mw()).abs() < 1e-9);
        assert!(b.get(Component::Adc).power_mw > 0.0);
        assert_eq!(b.get(Component::Ht).power_mw, 0.0);
    }
}
