//! RAII spans, instant events, the bounded global trace sink, and the
//! Chrome-trace exporter.
//!
//! Overhead discipline (the reason this file exists at all, given the
//! paper's argument is an accounting argument):
//!
//! * **Disabled** (`TraceLevel::Off`, the default): creating a span is one
//!   relaxed atomic load; drop is one branch. No timestamps, no
//!   allocation, no thread-local touch — the hot paths stay bit-identical
//!   and effectively free (property-pinned in `tests/properties.rs`).
//! * **Enabled**: a span costs two `Instant::now` calls (start/drop) plus
//!   a push into a per-thread buffer — the `sched::in_worker` trick
//!   applied to tracing: no lock on the hot path. Buffers drain into the
//!   global [`TraceSink`] every [`FLUSH_AT`] events and on thread exit.
//! * **Bounded**: the sink is a drop-oldest ring with a dropped-events
//!   counter, so tracing can never OOM or convoy the serve path; a full
//!   sink costs the same as an empty one.
//!
//! Timestamps are monotonic microseconds since a process-global epoch
//! (first obs touch), which is exactly what the Chrome trace format wants.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// How much the runtime records; see `--trace-level` on the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// No spans or events (counters/histograms still update).
    Off = 0,
    /// Request / batch / stage / pipeline-cell spans and health events.
    Spans = 1,
    /// Additionally the per-frame decode/encode sub-spans.
    Verbose = 2,
}

impl TraceLevel {
    pub fn parse(s: &str) -> Option<TraceLevel> {
        match s {
            "off" => Some(TraceLevel::Off),
            "spans" => Some(TraceLevel::Spans),
            "verbose" => Some(TraceLevel::Verbose),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(TraceLevel::Off as u8);

pub fn set_trace_level(l: TraceLevel) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn trace_level() -> TraceLevel {
    match LEVEL.load(Ordering::Relaxed) {
        0 => TraceLevel::Off,
        1 => TraceLevel::Spans,
        _ => TraceLevel::Verbose,
    }
}

/// One relaxed load — the disabled-path cost of every span site.
#[inline]
pub fn spans_on() -> bool {
    LEVEL.load(Ordering::Relaxed) >= TraceLevel::Spans as u8
}

#[inline]
pub fn verbose_on() -> bool {
    LEVEL.load(Ordering::Relaxed) >= TraceLevel::Verbose as u8
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_us() -> u64 {
    Instant::now().saturating_duration_since(epoch()).as_micros() as u64
}

/// Small dense per-thread ordinal (Chrome `tid`), assigned on first span.
fn thread_ord() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static ORD: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    ORD.with(|o| *o)
}

/// Client-side trace-ID mint: unique within a process run and very
/// unlikely to collide across client processes (pid in the high half).
/// 0 is reserved for "no trace".
pub fn next_trace_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    ((std::process::id() as u64) << 32) | (NEXT.fetch_add(1, Ordering::Relaxed) & 0xFFFF_FFFF)
}

/// One recorded span (`ph == b'X'`) or instant event (`ph == b'i'`).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: &'static str,
    pub cat: &'static str,
    pub ph: u8,
    pub ts_us: u64,
    pub dur_us: u64,
    pub tid: u64,
    pub args: Vec<(&'static str, u64)>,
}

impl TraceEvent {
    /// Named argument lookup (tests and exporter assertions).
    pub fn arg(&self, key: &str) -> Option<u64> {
        self.args.iter().find(|(k, _)| *k == key).map(|(_, v)| *v)
    }
}

/// Bounded drop-oldest event ring. The global sink behind all spans is
/// one of these ([`global_sink`]); tests build private ones.
#[derive(Debug)]
pub struct TraceSink {
    inner: Mutex<VecDeque<TraceEvent>>,
    capacity: usize,
    dropped: AtomicU64,
}

/// Global sink capacity: at ~100 bytes/event this bounds trace memory to
/// a few MiB regardless of how long a server runs.
pub const GLOBAL_SINK_CAPACITY: usize = 1 << 16;

impl TraceSink {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        TraceSink {
            inner: Mutex::new(VecDeque::with_capacity(capacity.min(1024))),
            capacity,
            dropped: AtomicU64::new(0),
        }
    }

    /// Append events, evicting the oldest past capacity (counted, never
    /// blocking on memory).
    pub fn push_all<I: IntoIterator<Item = TraceEvent>>(&self, events: I) {
        let mut q = self.inner.lock().unwrap();
        for ev in events {
            if q.len() >= self.capacity {
                q.pop_front();
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
            q.push_back(ev);
        }
    }

    pub fn push(&self, ev: TraceEvent) {
        self.push_all(std::iter::once(ev));
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted to keep the ring bounded.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Copy of the current contents, oldest first.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.inner.lock().unwrap().iter().cloned().collect()
    }

    /// Empty the ring and zero the dropped counter (tests, run restarts).
    pub fn clear(&self) {
        self.inner.lock().unwrap().clear();
        self.dropped.store(0, Ordering::Relaxed);
    }

    /// Write the sink as Chrome-trace JSON (object form), loadable by
    /// chrome://tracing and Perfetto: `ph:"X"` complete events with µs
    /// timestamps, span args verbatim, plus the dropped-event count under
    /// `otherData`.
    pub fn export_chrome_trace(&self, path: &Path) -> std::io::Result<()> {
        let events = self.snapshot();
        let mut out = String::with_capacity(events.len() * 96 + 128);
        out.push_str("{\"traceEvents\":[\n");
        for (i, ev) in events.iter().enumerate() {
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"{}\",\"pid\":1,\"tid\":{},\"ts\":{},\"dur\":{},\"args\":{{",
                esc(ev.name),
                esc(ev.cat),
                ev.ph as char,
                ev.tid,
                ev.ts_us,
                ev.dur_us,
            ));
            for (j, (k, v)) in ev.args.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&format!("\"{}\":{}", esc(k), v));
            }
            out.push_str("}}");
            if i + 1 < events.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str(&format!(
            "],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"dropped_events\":{}}}}}\n",
            self.dropped()
        ));
        let mut f = std::fs::File::create(path)?;
        f.write_all(out.as_bytes())
    }
}

fn esc(s: &str) -> String {
    // span/cat names are in-crate static strings, but stay safe anyway
    s.chars()
        .flat_map(|c| match c {
            '"' | '\\' => vec!['\\', c],
            c if (c as u32) < 0x20 => vec![' '],
            c => vec![c],
        })
        .collect()
}

/// The process-global sink every span records into.
pub fn global_sink() -> &'static TraceSink {
    static SINK: OnceLock<TraceSink> = OnceLock::new();
    SINK.get_or_init(|| TraceSink::new(GLOBAL_SINK_CAPACITY))
}

/// Thread-local buffer size before draining into the global sink.
pub const FLUSH_AT: usize = 64;

struct ThreadBuf {
    events: Vec<TraceEvent>,
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        if !self.events.is_empty() {
            global_sink().push_all(self.events.drain(..));
        }
    }
}

thread_local! {
    static BUF: RefCell<ThreadBuf> = RefCell::new(ThreadBuf { events: Vec::new() });
}

fn record(ev: TraceEvent) {
    let full = BUF
        .try_with(|b| {
            let mut b = b.borrow_mut();
            b.events.push(ev);
            b.events.len() >= FLUSH_AT
        })
        .unwrap_or(false);
    if full {
        flush_thread();
    }
}

/// Drain the calling thread's span buffer into the global sink. Worker
/// and handler threads flush automatically on exit (thread-local drop);
/// long-lived threads (main) call this before exporting.
pub fn flush_thread() {
    let _ = BUF.try_with(|b| {
        let mut b = b.borrow_mut();
        if !b.events.is_empty() {
            global_sink().push_all(b.events.drain(..));
        }
    });
}

/// Flush the calling thread, then export the global sink; the shape every
/// `--trace-out` CLI path uses.
pub fn export_global_chrome_trace(path: &Path) -> std::io::Result<()> {
    flush_thread();
    global_sink().export_chrome_trace(path)
}

/// RAII span. Inactive spans (tracing off, or level below the span's
/// gate) skip timestamps, args, and recording entirely.
#[must_use = "a span measures the scope it is bound to; bind it with `let _sp = ...`"]
pub struct Span {
    start: Option<Instant>,
    name: &'static str,
    cat: &'static str,
    args: Vec<(&'static str, u64)>,
}

/// Open a span recorded at `TraceLevel::Spans` and above.
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> Span {
    Span {
        start: if spans_on() {
            epoch(); // pin the epoch before the first timestamp
            Some(Instant::now())
        } else {
            None
        },
        name,
        cat,
        args: Vec::new(),
    }
}

/// Open a span recorded only at `TraceLevel::Verbose`.
#[inline]
pub fn span_verbose(name: &'static str, cat: &'static str) -> Span {
    Span {
        start: if verbose_on() {
            epoch();
            Some(Instant::now())
        } else {
            None
        },
        name,
        cat,
        args: Vec::new(),
    }
}

impl Span {
    /// Attach a key/value argument (no-op on inactive spans).
    #[inline]
    pub fn arg(mut self, key: &'static str, value: u64) -> Span {
        if self.start.is_some() {
            self.args.push((key, value));
        }
        self
    }

    pub fn active(&self) -> bool {
        self.start.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let dur_us = start.elapsed().as_micros() as u64;
        let ts_us = start.saturating_duration_since(epoch()).as_micros() as u64;
        record(TraceEvent {
            name: self.name,
            cat: self.cat,
            ph: b'X',
            ts_us,
            dur_us,
            tid: thread_ord(),
            args: std::mem::take(&mut self.args),
        });
    }
}

/// Record an instant event (health transitions, duplicate dispatches).
pub fn event(name: &'static str, cat: &'static str, args: &[(&'static str, u64)]) {
    if !spans_on() {
        return;
    }
    record(TraceEvent {
        name,
        cat,
        ph: b'i',
        ts_us: now_us(),
        dur_us: 0,
        tid: thread_ord(),
        args: args.to_vec(),
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    // tests below mutate the process-global trace level; serialise them
    static LEVEL_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn trace_level_parses() {
        assert_eq!(TraceLevel::parse("off"), Some(TraceLevel::Off));
        assert_eq!(TraceLevel::parse("spans"), Some(TraceLevel::Spans));
        assert_eq!(TraceLevel::parse("verbose"), Some(TraceLevel::Verbose));
        assert_eq!(TraceLevel::parse("loud"), None);
        assert!(TraceLevel::Off < TraceLevel::Spans);
        assert!(TraceLevel::Spans < TraceLevel::Verbose);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let sink = TraceSink::new(3);
        let ev = |ts| TraceEvent {
            name: "e",
            cat: "t",
            ph: b'X',
            ts_us: ts,
            dur_us: 1,
            tid: 0,
            args: Vec::new(),
        };
        sink.push_all((0..5).map(ev));
        assert_eq!(sink.len(), 3);
        assert_eq!(sink.dropped(), 2);
        let got: Vec<u64> = sink.snapshot().iter().map(|e| e.ts_us).collect();
        assert_eq!(got, vec![2, 3, 4], "oldest events must go first");
        sink.clear();
        assert!(sink.is_empty());
        assert_eq!(sink.dropped(), 0);
    }

    #[test]
    fn chrome_export_is_wellformed() {
        let sink = TraceSink::new(8);
        sink.push(TraceEvent {
            name: "cell",
            cat: "pipeline",
            ph: b'X',
            ts_us: 10,
            dur_us: 5,
            tid: 2,
            args: vec![("k", 1), ("s", 2), ("replica", 0)],
        });
        sink.push(TraceEvent {
            name: "quarantine",
            cat: "health",
            ph: b'i',
            ts_us: 20,
            dur_us: 0,
            tid: 2,
            args: vec![("replica", 1)],
        });
        let dir = std::env::temp_dir().join(format!("obs_export_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        sink.export_chrome_trace(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert!(text.starts_with("{\"traceEvents\":["));
        assert!(text.contains("\"name\":\"cell\""));
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"ph\":\"i\""));
        assert!(text.contains("\"k\":1,\"s\":2,\"replica\":0"));
        assert!(text.contains("\"dropped_events\":0"));
        // crude structural balance check in lieu of a JSON parser
        assert_eq!(
            text.matches('{').count(),
            text.matches('}').count(),
            "unbalanced braces"
        );
        assert_eq!(text.matches('[').count(), text.matches(']').count());
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = LEVEL_LOCK.lock().unwrap();
        set_trace_level(TraceLevel::Off);
        let before = global_sink().len();
        for _ in 0..8 {
            let _sp = span("obs_test_disabled", "test").arg("x", 1);
        }
        flush_thread();
        let polluting: Vec<TraceEvent> = global_sink()
            .snapshot()
            .into_iter()
            .filter(|e| e.name == "obs_test_disabled")
            .collect();
        assert!(polluting.is_empty(), "disabled span recorded: {polluting:?}");
        let _ = before;
    }

    #[test]
    fn enabled_spans_reach_the_global_sink_with_args() {
        let _g = LEVEL_LOCK.lock().unwrap();
        set_trace_level(TraceLevel::Spans);
        {
            let _sp = span("obs_test_enabled", "test").arg("k", 7);
            let _v = span_verbose("obs_test_verbose_gated", "test");
        }
        set_trace_level(TraceLevel::Off);
        flush_thread();
        let snap = global_sink().snapshot();
        let mine: Vec<&TraceEvent> =
            snap.iter().filter(|e| e.name == "obs_test_enabled").collect();
        assert_eq!(mine.len(), 1);
        assert_eq!(mine[0].arg("k"), Some(7));
        assert_eq!(mine[0].ph, b'X');
        assert!(
            !snap.iter().any(|e| e.name == "obs_test_verbose_gated"),
            "verbose span leaked at Spans level"
        );
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
        assert_eq!(a >> 32, (std::process::id() as u64));
    }
}
