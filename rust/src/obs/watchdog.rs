//! Registry watchdog: drift detection against a startup baseline window.
//!
//! The admin plane (net/server.rs) runs a sampling loop over the metrics
//! registry; this module is the pure state machine under it, so the drift
//! logic is unit-testable without sockets or timers. A [`DriftWatch`]
//! collects its first `baseline_window` positive observations, freezes
//! their mean as the baseline, and flags any later observation exceeding
//! `factor ×` baseline. [`Watchdog`] composes the two serving watches the
//! tentpole asks for — request-latency p99 and modeled energy per
//! inference — raising `obs.anomaly.*` counters and reporting a degraded
//! verdict the admin exposition surfaces as `newton_degraded`.

use super::counter;

/// One drifting-signal detector: baseline = mean of the first
/// `baseline_window` positive samples, anomaly = sample > factor × baseline.
#[derive(Debug)]
pub struct DriftWatch {
    baseline_window: usize,
    factor: f64,
    seen: Vec<f64>,
    baseline: Option<f64>,
}

impl DriftWatch {
    pub fn new(baseline_window: usize, factor: f64) -> Self {
        assert!(baseline_window > 0, "baseline window must be non-empty");
        assert!(factor > 1.0, "a drift factor <= 1 flags the baseline itself");
        DriftWatch {
            baseline_window,
            factor,
            seen: Vec::new(),
            baseline: None,
        }
    }

    /// Feed one observation. Non-positive samples are ignored (no traffic
    /// yet — an idle histogram reports 0). Returns `true` when the sample
    /// exceeds `factor ×` the frozen baseline.
    pub fn observe(&mut self, v: f64) -> bool {
        if v <= 0.0 {
            return false;
        }
        match self.baseline {
            None => {
                self.seen.push(v);
                if self.seen.len() >= self.baseline_window {
                    let mean = self.seen.iter().sum::<f64>() / self.seen.len() as f64;
                    self.baseline = Some(mean);
                    self.seen = Vec::new();
                }
                false
            }
            Some(b) => v > b * self.factor,
        }
    }

    /// Frozen baseline, once the startup window filled.
    pub fn baseline(&self) -> Option<f64> {
        self.baseline
    }

    /// Drop the frozen baseline and re-learn it from the next
    /// `baseline_window` positive samples. Called after a structural
    /// change to the serving pool (quarantine, reinstall, re-shard): the
    /// old baseline described a pool that no longer exists, and holding a
    /// recovered cluster against it latches `degraded` forever.
    pub fn reset(&mut self) {
        self.seen.clear();
        self.baseline = None;
    }
}

/// The serving watchdog: p99 latency and energy-per-inference drift
/// against their startup baselines. Factors are deliberately loose — the
/// watchdog flags regressions an operator should look at, not noise.
#[derive(Debug)]
pub struct Watchdog {
    latency: DriftWatch,
    energy: DriftWatch,
}

impl Watchdog {
    /// Default windows: 5 baseline samples, 3× latency / 1.5× energy drift
    /// (energy per inference is near-deterministic for a fixed model, so a
    /// tighter bound still avoids false positives).
    pub fn new() -> Self {
        Watchdog {
            latency: DriftWatch::new(5, 3.0),
            energy: DriftWatch::new(5, 1.5),
        }
    }

    /// One sampling tick. Raises `obs.anomaly.latency_p99` /
    /// `obs.anomaly.energy_drift` counters for each drifting signal and
    /// returns whether any fired (the admin plane latches this into its
    /// `degraded` flag).
    pub fn tick(&mut self, latency_p99_us: f64, energy_pj_per_infer: f64) -> bool {
        let mut degraded = false;
        if self.latency.observe(latency_p99_us) {
            counter("obs.anomaly.latency_p99").inc();
            degraded = true;
        }
        if self.energy.observe(energy_pj_per_infer) {
            counter("obs.anomaly.energy_drift").inc();
            degraded = true;
        }
        degraded
    }

    /// Re-learn both baselines ([`DriftWatch::reset`]) after a pool
    /// change — quarantine, reinstall, or cluster re-shard. The admin
    /// plane calls this when the `obs.rebaseline` counter moves and
    /// un-latches its `degraded` flag at the same time; counts
    /// `obs.anomaly.rebaseline` so rebaselines are visible in the
    /// exposition.
    pub fn rebaseline(&mut self) {
        self.latency.reset();
        self.energy.reset();
        counter("obs.anomaly.rebaseline").inc();
    }
}

impl Default for Watchdog {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_freezes_after_window() {
        let mut w = DriftWatch::new(3, 2.0);
        assert!(w.baseline().is_none());
        assert!(!w.observe(10.0));
        assert!(!w.observe(20.0));
        assert!(!w.observe(30.0));
        assert_eq!(w.baseline(), Some(20.0));
        // later samples cannot move the baseline
        assert!(!w.observe(1000.0) || w.baseline() == Some(20.0));
        assert_eq!(w.baseline(), Some(20.0));
    }

    #[test]
    fn drift_fires_only_beyond_factor() {
        let mut w = DriftWatch::new(2, 3.0);
        w.observe(10.0);
        w.observe(10.0);
        assert!(!w.observe(29.9), "below 3x baseline");
        assert!(w.observe(30.1), "above 3x baseline");
        // recovery: a sane sample after an anomaly does not flag
        assert!(!w.observe(12.0));
    }

    #[test]
    fn idle_zero_samples_never_fill_the_window() {
        let mut w = DriftWatch::new(2, 2.0);
        for _ in 0..10 {
            assert!(!w.observe(0.0));
        }
        assert!(w.baseline().is_none());
        w.observe(5.0);
        w.observe(5.0);
        assert_eq!(w.baseline(), Some(5.0));
    }

    #[test]
    fn watchdog_raises_the_anomaly_counters() {
        let lat_before = counter("obs.anomaly.latency_p99").get();
        let en_before = counter("obs.anomaly.energy_drift").get();
        let mut w = Watchdog::new();
        // fill both baselines
        for _ in 0..5 {
            assert!(!w.tick(100.0, 1000.0));
        }
        // latency blows past 3x, energy stays flat
        assert!(w.tick(500.0, 1000.0));
        assert_eq!(counter("obs.anomaly.latency_p99").get(), lat_before + 1);
        assert_eq!(counter("obs.anomaly.energy_drift").get(), en_before);
        // energy drifts past 1.5x
        assert!(w.tick(100.0, 1600.0));
        assert_eq!(counter("obs.anomaly.energy_drift").get(), en_before + 1);
        // both healthy again
        assert!(!w.tick(100.0, 1000.0));
    }

    #[test]
    fn reset_relearns_the_baseline() {
        let mut w = DriftWatch::new(2, 2.0);
        w.observe(10.0);
        w.observe(10.0);
        assert_eq!(w.baseline(), Some(10.0));
        assert!(w.observe(25.0), "drift before the reset");
        w.reset();
        assert!(w.baseline().is_none());
        // the very samples that flagged before now *are* the baseline —
        // the recovered pool's normal is the new normal
        assert!(!w.observe(25.0));
        assert!(!w.observe(25.0));
        assert_eq!(w.baseline(), Some(25.0));
        assert!(!w.observe(30.0), "within factor of the new baseline");
        assert!(w.observe(60.0), "drift against the new baseline");
    }

    #[test]
    fn rebaseline_unlatches_a_recovered_watchdog() {
        let mut w = Watchdog::new();
        for _ in 0..5 {
            assert!(!w.tick(100.0, 1000.0));
        }
        // a re-shard doubles per-survivor latency: old baseline flags it
        assert!(w.tick(500.0, 1000.0));
        let before = counter("obs.anomaly.rebaseline").get();
        w.rebaseline();
        assert_eq!(counter("obs.anomaly.rebaseline").get(), before + 1);
        // the post-reshard steady state fills a fresh window quietly
        for _ in 0..5 {
            assert!(!w.tick(500.0, 1000.0));
        }
        assert!(!w.tick(520.0, 1000.0), "new normal flagged as drift");
        assert!(w.tick(5000.0, 1000.0), "real drift still caught");
    }
}
