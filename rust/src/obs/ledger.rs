//! Per-forward hardware cost ledger: the dimensional attribution layer on
//! top of the flat `obs::metrics` counters (ARCHITECTURE.md §Observability).
//!
//! Newton's argument is an accounting argument — energy and ADC pressure
//! attributed per sub-computation (PAPER.md §IV) — and the [`CostLedger`]
//! makes the serving stack a measured instance of it: every forward pass
//! counts the ADC conversions it performed *bucketed by resolved bit-width*
//! (heterogeneous under the adaptive schedule — that is the paper's point),
//! the slice iterations it executed vs skipped (zero/uniform planes from
//! `ProgrammedXbar::slice_profile`, all-zero DAC iterations), the
//! identity-ADC folds that bypassed the quantiser, and the rows it moved.
//!
//! The ledger is a plain-`u64` struct embedded in `xbar::RunScratch` — zero
//! allocation, no atomics on the counting path — and merged upward:
//! `RunScratch` → `ForwardScratch` → per-stage deltas in
//! `ProgrammedCnn::run_stage` → per-batch/per-replica/per-request in
//! `coordinator::golden`/`pipeline`, where `energy::TileModel::
//! ledger_energy_pj` converts the counts into modeled picojoules.
//!
//! Counting is gated by a process-global flag ([`set_enabled`]/[`enabled`],
//! the `TraceLevel` pattern): when off, an instrumented row costs one
//! relaxed atomic load and ledger-on vs ledger-off forwards are pinned
//! bit-identical by the property tests (`prop_ledger_enable_is_pure`); the
//! wall-clock cost when on is gated by `ledger_overhead_b8 <= 1.03` in
//! verify.sh.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

use super::metrics::Counter;

/// Resolved-bit-width buckets: index = effective bits of one quantising ADC
/// conversion, clamped to the last bucket. `AdcKind` caps resolutions at 16
/// bits, so 20 buckets never clamp in practice.
pub const ADC_BIT_BUCKETS: usize = 20;

/// Hardware-cost counters of one unit of forward work. Plain `u64`s — the
/// counting path takes no locks and allocates nothing; aggregation is
/// [`Self::merge`] up the scratch hierarchy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CostLedger {
    /// Quantising ADC conversions by resolved bit-width (index = bits).
    /// Lossy configs resolve `adc_bits`; the adaptive schedule truncates
    /// `out_shift - place` further bits below the kept window, so one
    /// forward spreads over several buckets — the heterogeneity Fig 12
    /// prices.
    pub adc_ops_by_bits: [u64; ADC_BIT_BUCKETS],
    /// ADC samples folded as exact identities (lossless window, and the
    /// fused masked-matmul path where every sample telescopes away): no
    /// quantiser engages, only sample-and-hold + shift-add.
    pub identity_folds: u64,
    /// DAC iterations executed (some digit was non-zero).
    pub iters_executed: u64,
    /// DAC iterations skipped outright (all digits zero).
    pub iters_skipped: u64,
    /// Dense slice-iterations walked: one per (row, executed iteration,
    /// dense slice).
    pub slice_iters_executed: u64,
    /// Uniform slice-iterations folded to one quantise-and-broadcast.
    pub slice_iters_folded: u64,
    /// Slice-iterations skipped: zero planes of executed iterations plus
    /// every slice of a skipped iteration.
    pub slice_iters_skipped: u64,
    /// Batch rows run through the fused masked-matmul path.
    pub fused_rows: u64,
    /// Batch rows run through the digit-major slice engine.
    pub slice_rows: u64,
    /// Input elements streamed (rows × reduction length): the eDRAM/DAC
    /// traffic a row move costs.
    pub row_elems: u64,
}

impl CostLedger {
    pub const fn new() -> Self {
        CostLedger {
            adc_ops_by_bits: [0; ADC_BIT_BUCKETS],
            identity_folds: 0,
            iters_executed: 0,
            iters_skipped: 0,
            slice_iters_executed: 0,
            slice_iters_folded: 0,
            slice_iters_skipped: 0,
            fused_rows: 0,
            slice_rows: 0,
            row_elems: 0,
        }
    }

    /// Count `n` quantising conversions resolving `bits` bits each.
    #[inline]
    pub fn count_adc(&mut self, bits: u32, n: u64) {
        let i = (bits as usize).min(ADC_BIT_BUCKETS - 1);
        self.adc_ops_by_bits[i] += n;
    }

    /// Total quantising ADC conversions across all bit-width buckets.
    pub fn adc_ops(&self) -> u64 {
        self.adc_ops_by_bits.iter().sum()
    }

    /// Rows moved through either engine.
    pub fn rows(&self) -> u64 {
        self.fused_rows + self.slice_rows
    }

    /// Fraction of slice-iterations the engine never executed (zero planes
    /// + all-zero iterations); 0 when nothing was counted.
    pub fn skipped_slice_frac(&self) -> f64 {
        let total =
            self.slice_iters_executed + self.slice_iters_folded + self.slice_iters_skipped;
        if total == 0 {
            0.0
        } else {
            self.slice_iters_skipped as f64 / total as f64
        }
    }

    pub fn is_empty(&self) -> bool {
        *self == CostLedger::new()
    }

    /// Add `other`'s counts into `self` (the scratch-to-aggregate step).
    pub fn merge(&mut self, other: &CostLedger) {
        for (a, b) in self
            .adc_ops_by_bits
            .iter_mut()
            .zip(other.adc_ops_by_bits.iter())
        {
            *a += b;
        }
        self.identity_folds += other.identity_folds;
        self.iters_executed += other.iters_executed;
        self.iters_skipped += other.iters_skipped;
        self.slice_iters_executed += other.slice_iters_executed;
        self.slice_iters_folded += other.slice_iters_folded;
        self.slice_iters_skipped += other.slice_iters_skipped;
        self.fused_rows += other.fused_rows;
        self.slice_rows += other.slice_rows;
        self.row_elems += other.row_elems;
    }

    /// Counts accrued since `earlier` was copied out of the same ledger
    /// (per-stage delta capture in `ProgrammedCnn::run_stage`).
    pub fn delta_since(&self, earlier: &CostLedger) -> CostLedger {
        let mut d = CostLedger::new();
        for (i, slot) in d.adc_ops_by_bits.iter_mut().enumerate() {
            *slot = self.adc_ops_by_bits[i].wrapping_sub(earlier.adc_ops_by_bits[i]);
        }
        d.identity_folds = self.identity_folds.wrapping_sub(earlier.identity_folds);
        d.iters_executed = self.iters_executed.wrapping_sub(earlier.iters_executed);
        d.iters_skipped = self.iters_skipped.wrapping_sub(earlier.iters_skipped);
        d.slice_iters_executed = self
            .slice_iters_executed
            .wrapping_sub(earlier.slice_iters_executed);
        d.slice_iters_folded = self
            .slice_iters_folded
            .wrapping_sub(earlier.slice_iters_folded);
        d.slice_iters_skipped = self
            .slice_iters_skipped
            .wrapping_sub(earlier.slice_iters_skipped);
        d.fused_rows = self.fused_rows.wrapping_sub(earlier.fused_rows);
        d.slice_rows = self.slice_rows.wrapping_sub(earlier.slice_rows);
        d.row_elems = self.row_elems.wrapping_sub(earlier.row_elems);
        d
    }
}

impl Default for CostLedger {
    fn default() -> Self {
        Self::new()
    }
}

static LEDGER_ON: AtomicBool = AtomicBool::new(false);

/// Enable or disable cost counting process-wide (CLI: serve paths enable it
/// unless `--no-ledger`). Off by default: a disabled ledger site costs one
/// relaxed atomic load, and enabling it must not move a bit of any result
/// (property-pinned).
pub fn set_enabled(on: bool) {
    LEDGER_ON.store(on, Ordering::Relaxed);
}

/// Whether forwards currently count hardware cost.
#[inline]
pub fn enabled() -> bool {
    LEDGER_ON.load(Ordering::Relaxed)
}

/// Serialises unit tests that flip the process-global enable flag, so a
/// toggle in one test cannot race another's ledger assertions (the
/// integration tests keep their own lock in `tests/properties.rs`).
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Per-stage ledger counter names, indexed by pipeline stage (clamped to
/// the table end — newton-mini has 4 stages). The stage dimension is owned
/// by `ProgrammedCnn::run_stage`; conservation across stages is
/// property-pinned (`prop_ledger_stage_sums_match_whole_model`).
const STAGE_ADC_OPS: [&str; 8] = [
    "ledger.stage0.adc_ops",
    "ledger.stage1.adc_ops",
    "ledger.stage2.adc_ops",
    "ledger.stage3.adc_ops",
    "ledger.stage4.adc_ops",
    "ledger.stage5.adc_ops",
    "ledger.stage6.adc_ops",
    "ledger.stage7.adc_ops",
];
const STAGE_IDENTITY: [&str; 8] = [
    "ledger.stage0.identity_folds",
    "ledger.stage1.identity_folds",
    "ledger.stage2.identity_folds",
    "ledger.stage3.identity_folds",
    "ledger.stage4.identity_folds",
    "ledger.stage5.identity_folds",
    "ledger.stage6.identity_folds",
    "ledger.stage7.identity_folds",
];

/// Record one stage's ledger delta into the global registry. ADC ops and
/// identity folds carry the stage dimension; the full-dimensional ledger is
/// aggregated one level up (per batch, in `coordinator::golden`).
pub fn record_stage(s: usize, delta: &CostLedger) {
    let i = s.min(STAGE_ADC_OPS.len() - 1);
    super::counter(STAGE_ADC_OPS[i]).add(delta.adc_ops());
    super::counter(STAGE_IDENTITY[i]).add(delta.identity_folds);
}

/// Read back the per-stage ADC-op counter for stage `s` (conservation
/// tests compare these sums against whole-model ledgers).
pub fn stage_adc_ops(s: usize) -> u64 {
    super::counter(STAGE_ADC_OPS[s.min(STAGE_ADC_OPS.len() - 1)]).get()
}

/// Per-replica ledger counter names (clamped to the table end). The
/// replica dimension is owned by `coordinator::golden::run_batch`: the
/// count is total ADC samples — quantising conversions plus identity
/// folds — of the forward whose logits a replica served.
const REPLICA_ADC_SAMPLES: [&str; 8] = [
    "ledger.replica0.adc_samples",
    "ledger.replica1.adc_samples",
    "ledger.replica2.adc_samples",
    "ledger.replica3.adc_samples",
    "ledger.replica4.adc_samples",
    "ledger.replica5.adc_samples",
    "ledger.replica6.adc_samples",
    "ledger.replica7.adc_samples",
];

/// Record one served forward's ADC pressure against the replica that ran
/// it.
pub fn record_replica(r: usize, delta: &CostLedger) {
    let i = r.min(REPLICA_ADC_SAMPLES.len() - 1);
    super::counter(REPLICA_ADC_SAMPLES[i]).add(delta.adc_ops() + delta.identity_folds);
}

struct ServeSites {
    adc_ops: Arc<Counter>,
    identity_folds: Arc<Counter>,
    slice_iters_executed: Arc<Counter>,
    slice_iters_folded: Arc<Counter>,
    slice_iters_skipped: Arc<Counter>,
    rows: Arc<Counter>,
    energy_pj: Arc<Counter>,
    energy_hist: Arc<super::metrics::Histogram>,
    adc_hist: Arc<super::metrics::Histogram>,
}

fn serve_sites() -> &'static ServeSites {
    static SITES: OnceLock<ServeSites> = OnceLock::new();
    SITES.get_or_init(|| ServeSites {
        adc_ops: super::counter("ledger.adc_ops"),
        identity_folds: super::counter("ledger.identity_folds"),
        slice_iters_executed: super::counter("ledger.slice_iters_executed"),
        slice_iters_folded: super::counter("ledger.slice_iters_folded"),
        slice_iters_skipped: super::counter("ledger.slice_iters_skipped"),
        rows: super::counter("ledger.rows"),
        energy_pj: super::counter("ledger.energy_pj"),
        energy_hist: super::histogram("serve.energy_pj_per_infer"),
        adc_hist: super::histogram("serve.adc_ops_per_infer"),
    })
}

/// Record one served batch's ledger into the global registry: totals into
/// the `ledger.*` counters (integer picojoules, so the aggregates ride the
/// wire `Stats` metrics vec), per-inference figures into the
/// `serve.energy_pj_per_infer` / `serve.adc_ops_per_infer` histograms.
pub fn record_serving(delta: &CostLedger, n_real: usize, energy_pj: f64) {
    let s = serve_sites();
    s.adc_ops.add(delta.adc_ops());
    s.identity_folds.add(delta.identity_folds);
    s.slice_iters_executed.add(delta.slice_iters_executed);
    s.slice_iters_folded.add(delta.slice_iters_folded);
    s.slice_iters_skipped.add(delta.slice_iters_skipped);
    s.rows.add(delta.rows());
    s.energy_pj.add(energy_pj.round().max(0.0) as u64);
    if n_real > 0 {
        s.energy_hist
            .record((energy_pj / n_real as f64).round().max(0.0) as u64);
        s.adc_hist.record(delta.adc_ops() / n_real as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CostLedger {
        let mut l = CostLedger::new();
        l.count_adc(9, 10);
        l.count_adc(5, 3);
        l.count_adc(99, 2); // clamps to the last bucket
        l.identity_folds = 7;
        l.iters_executed = 4;
        l.iters_skipped = 12;
        l.slice_iters_executed = 20;
        l.slice_iters_folded = 4;
        l.slice_iters_skipped = 104;
        l.slice_rows = 2;
        l.row_elems = 256;
        l
    }

    #[test]
    fn adc_ops_sums_buckets_and_clamps() {
        let l = sample();
        assert_eq!(l.adc_ops(), 15);
        assert_eq!(l.adc_ops_by_bits[9], 10);
        assert_eq!(l.adc_ops_by_bits[5], 3);
        assert_eq!(l.adc_ops_by_bits[ADC_BIT_BUCKETS - 1], 2);
    }

    #[test]
    fn merge_adds_every_field() {
        let mut a = sample();
        let b = sample();
        a.merge(&b);
        assert_eq!(a.adc_ops(), 30);
        assert_eq!(a.identity_folds, 14);
        assert_eq!(a.slice_iters_skipped, 208);
        assert_eq!(a.row_elems, 512);
        assert_eq!(a.rows(), 4);
    }

    #[test]
    fn delta_since_inverts_merge() {
        let before = sample();
        let mut after = before;
        after.merge(&sample());
        assert_eq!(after.delta_since(&before), before);
        assert_eq!(before.delta_since(&before), CostLedger::new());
        assert!(CostLedger::new().is_empty());
        assert!(!before.is_empty());
    }

    #[test]
    fn skipped_frac_is_a_fraction() {
        let l = sample();
        let f = l.skipped_slice_frac();
        assert!((0.0..=1.0).contains(&f));
        assert!((f - 104.0 / 128.0).abs() < 1e-12);
        assert_eq!(CostLedger::new().skipped_slice_frac(), 0.0);
    }

    #[test]
    fn enable_flag_round_trips() {
        let _guard = test_guard();
        let was = enabled();
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
        set_enabled(was);
    }

    #[test]
    fn stage_recording_accumulates_by_stage() {
        let mut d = CostLedger::new();
        d.count_adc(8, 5);
        d.identity_folds = 2;
        let s0 = stage_adc_ops(0);
        record_stage(0, &d);
        assert_eq!(stage_adc_ops(0), s0 + 5);
        // out-of-table stages clamp instead of panicking
        let tail = stage_adc_ops(99);
        record_stage(99, &d);
        assert_eq!(stage_adc_ops(99), tail + 5);
    }

    #[test]
    fn serving_record_updates_counters_and_histograms() {
        let d = sample();
        let before = super::super::counter("ledger.adc_ops").get();
        record_serving(&d, 2, 100.0);
        assert_eq!(super::super::counter("ledger.adc_ops").get(), before + 15);
        assert!(super::super::counter("ledger.energy_pj").get() >= 100);
        let snap = super::super::metrics_snapshot();
        let h = snap
            .histograms
            .iter()
            .find(|(n, _)| n == "serve.energy_pj_per_infer")
            .expect("energy histogram registered");
        assert!(h.1.count >= 1);
    }
}
