//! Named counters and fixed log-bucket histograms (std-only).
//!
//! The histogram is the HdrHistogram idea at its cheapest useful setting:
//! values bucket by their power-of-two magnitude with four linear
//! sub-buckets per octave (two significant bits), so any recorded value is
//! reported within 25% of its true magnitude and the whole structure is a
//! fixed 252-slot array of relaxed atomics — `record` is two atomic adds,
//! writers are never stopped, and a snapshot is a plain load sweep.
//! Percentiles are *exact-bucket*: the reported value is the inclusive
//! upper edge of the bucket holding the requested rank (conservative for
//! latency), unlike the reservoir sampler this replaces whose tail
//! quantiles were sampling-noisy at high request counts.
//!
//! Counters and histograms live in a process-global [`Registry`] keyed by
//! `&'static str`; instrumentation sites cache the returned `Arc` in a
//! `OnceLock` so the steady-state cost is one relaxed atomic add with no
//! registry lock. Standalone instances (no registry) back per-server
//! state like the net server's latency histogram.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonic named counter: one relaxed `fetch_add` per event.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Counter::default()
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Bucket count: 4 exact buckets for values 0..=3, then 4 linear
/// sub-buckets per power-of-two octave for bit positions 2..=63.
pub const N_BUCKETS: usize = 4 + 62 * 4;

/// Bucket index for a value: exact below 4, otherwise the octave (msb
/// position) plus the next two significant bits.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < 4 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize; // >= 2
    let sub = ((v >> (msb - 2)) & 0b11) as usize;
    4 + (msb - 2) * 4 + sub
}

/// Inclusive upper edge of bucket `i` — the value percentiles report.
pub fn bucket_upper(i: usize) -> u64 {
    assert!(i < N_BUCKETS);
    if i < 4 {
        return i as u64;
    }
    let msb = (i - 4) / 4 + 2;
    let sub = ((i - 4) % 4) as u64;
    let width = 1u64 << (msb - 2);
    let lo = (1u64 << msb) + sub * width;
    lo.saturating_add(width - 1)
}

/// Fixed log-bucket histogram; see the module docs for the layout.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>, // N_BUCKETS slots
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Consistent point-in-time copy without stopping writers: the count is
    /// recomputed from the loaded buckets, so percentile ranks always agree
    /// with the bucket contents even if a record lands mid-sweep.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let count = buckets.iter().sum();
        HistogramSnapshot {
            buckets,
            count,
            sum: self.sum.load(Ordering::Relaxed),
        }
    }

    /// Shorthand: percentile of a fresh snapshot.
    pub fn percentile(&self, q: f64) -> u64 {
        self.snapshot().percentile(q)
    }
}

/// Loaded bucket counts; all derived stats come from here.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl HistogramSnapshot {
    /// Exact-bucket percentile: upper edge of the bucket holding rank
    /// `ceil(q * count)` (nearest-rank). Empty histograms report 0.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_upper(i);
            }
        }
        bucket_upper(N_BUCKETS - 1)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// Point-in-time copy of every registered metric.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

/// Name → metric map. `counter`/`histogram` lock only on first lookup per
/// site (sites cache the `Arc` in a `OnceLock`); the metrics themselves
/// are lock-free to update.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<&'static str, Arc<Counter>>>,
    histograms: Mutex<BTreeMap<&'static str, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn counter(&self, name: &'static str) -> Arc<Counter> {
        Arc::clone(
            self.counters
                .lock()
                .unwrap()
                .entry(name)
                .or_insert_with(|| Arc::new(Counter::new())),
        )
    }

    pub fn histogram(&self, name: &'static str) -> Arc<Histogram> {
        Arc::clone(
            self.histograms
                .lock()
                .unwrap()
                .entry(name)
                .or_insert_with(|| Arc::new(Histogram::new())),
        )
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(n, c)| (n.to_string(), c.get()))
            .collect();
        let histograms = self
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(n, h)| (n.to_string(), h.snapshot()))
            .collect();
        MetricsSnapshot {
            counters,
            histograms,
        }
    }
}

fn global() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::new)
}

/// Process-global counter by name (BTreeMap-ordered in snapshots).
pub fn counter(name: &'static str) -> Arc<Counter> {
    global().counter(name)
}

/// Process-global histogram by name.
pub fn histogram(name: &'static str) -> Arc<Histogram> {
    global().histogram(name)
}

/// Snapshot of the process-global registry.
pub fn metrics_snapshot() -> MetricsSnapshot {
    global().snapshot()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        assert_eq!(c.get(), 0);
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
    }

    #[test]
    fn bucket_layout_is_monotonic_and_total() {
        // every value maps to exactly one bucket whose range contains it,
        // and bucket edges are strictly increasing
        let mut prev_upper = None;
        for i in 0..N_BUCKETS {
            let up = bucket_upper(i);
            if let Some(p) = prev_upper {
                assert!(up > p, "bucket {i} upper {up} <= previous {p}");
            }
            prev_upper = Some(up);
        }
        for v in [0u64, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 100, 999, 1 << 20, u64::MAX] {
            let i = bucket_index(v);
            assert!(v <= bucket_upper(i), "v={v} above its bucket {i}");
            if i > 0 {
                assert!(v > bucket_upper(i - 1), "v={v} below bucket {i}");
            }
        }
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
        assert_eq!(bucket_upper(N_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn empty_histogram_percentiles_are_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.50), 0);
        assert_eq!(h.percentile(0.999), 0);
        assert_eq!(h.snapshot().mean(), 0.0);
    }

    #[test]
    fn single_sample_owns_every_percentile() {
        let h = Histogram::new();
        h.record(100);
        let want = bucket_upper(bucket_index(100));
        for q in [0.0, 0.5, 0.99, 0.999, 1.0] {
            assert_eq!(h.percentile(q), want, "q={q}");
        }
        // exact-bucket contract: within 25% above the true value
        assert!(want >= 100 && want <= 125);
    }

    #[test]
    fn top_bucket_saturates_not_overflows() {
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX - 1);
        assert_eq!(h.percentile(1.0), u64::MAX);
        assert_eq!(h.snapshot().count, 2);
    }

    #[test]
    fn percentiles_split_a_bimodal_load() {
        let h = Histogram::new();
        for _ in 0..99 {
            h.record(10);
        }
        h.record(10_000);
        let p50 = h.percentile(0.50);
        let p99 = h.percentile(0.99);
        let p999 = h.percentile(0.999);
        assert!(p50 >= 10 && p50 < 13, "p50={p50}");
        assert!(p99 < 13, "p99={p99} (99 of 100 samples are 10)");
        assert!(p999 >= 10_000, "p999={p999} must see the outlier");
        assert!(p50 <= p99 && p99 <= p999);
    }

    #[test]
    fn low_values_are_exact() {
        let h = Histogram::new();
        for v in [0, 1, 2, 3] {
            h.record(v);
        }
        assert_eq!(h.percentile(0.25), 0);
        assert_eq!(h.percentile(1.0), 3);
    }

    #[test]
    fn registry_interns_by_name() {
        let r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.inc();
        assert_eq!(b.get(), 1);
        r.histogram("h").record(7);
        let snap = r.snapshot();
        assert_eq!(snap.counters, vec![("x".to_string(), 1)]);
        assert_eq!(snap.histograms.len(), 1);
        assert_eq!(snap.histograms[0].1.count, 1);
    }
}
