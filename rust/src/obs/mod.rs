//! Observability: spans over the serving hot path, named counters,
//! log-bucket latency histograms, and a Chrome-trace exporter — std-only.
//!
//! Newton's argument is an accounting argument (energy and ADC pressure
//! attributed per sub-computation, PAPER.md §IV), so the runtime needs the
//! same attribution at serve time: which stage, which replica, which
//! request. This module is that substrate; every layer above the engine
//! threads through it (see ARCHITECTURE.md §Observability for the span
//! taxonomy and the overhead discipline).
//!
//! Four parts:
//!
//! * [`span`] — RAII spans with monotonic µs timestamps and per-thread
//!   buffers draining into a bounded drop-oldest [`TraceSink`];
//!   `TraceSink::export_chrome_trace` writes chrome://tracing /
//!   Perfetto-loadable JSON. Gated by a process-global [`TraceLevel`]
//!   (CLI: `--trace-level off|spans|verbose`, `--trace-out PATH`); when
//!   off a span site costs one relaxed atomic load.
//! * [`metrics`] — named [`Counter`]s and fixed log-bucket [`Histogram`]s
//!   (exact-bucket p50/p99/p999, replacing the net server's reservoir
//!   sampler) in a process-global registry snapshotted without stopping
//!   writers; snapshots ride the net `Stats` frame into
//!   `print_net_stats`, `net_summary.csv`, and `BENCH_net.json`.
//! * [`ledger`] — the per-forward hardware [`CostLedger`] (ADC conversions
//!   by resolved bit-width, slice iterations executed vs skipped, identity
//!   folds, rows moved), threaded through the engine scratches and
//!   aggregated per stage / replica / request; gated like [`TraceLevel`].
//! * [`watchdog`] — baseline-window drift detection over the registry
//!   (p99 latency, energy per inference) feeding the admin plane's
//!   `degraded` flag and the `obs.anomaly.*` counters.

pub mod ledger;
pub mod metrics;
pub mod span;
pub mod watchdog;

pub use ledger::CostLedger;
pub use metrics::{
    counter, histogram, metrics_snapshot, Counter, Histogram, HistogramSnapshot, MetricsSnapshot,
    Registry,
};
pub use span::{
    event, export_global_chrome_trace, flush_thread, global_sink, next_trace_id, set_trace_level,
    span, span_verbose, spans_on, trace_level, verbose_on, Span, TraceEvent, TraceLevel, TraceSink,
};
