//! Strassen's divide & conquer for crossbar matrix multiplication
//! (paper §III-A2, Figs 4, 8, 19).
//!
//! Functional half: exact Strassen over integer matrices, verified against
//! plain matmul. Schedule half: the 7-IMA tile mapping — a 2Rx2C layer that
//! would occupy 8 IMAs' worth of crossbars runs as 7 sub-products P0..P6
//! (Fig 8), freeing 1 in 8 IMAs and cutting ADC work by 1/8 for eligible
//! layers. Pre-additions on weights happen at install time; pre-additions
//! on inputs and the post-processing run on the tile's digital units.

use crate::config::XbarParams;
use crate::xbar::{matmul, Matrix};

fn sub_block(m: &Matrix, r0: usize, c0: usize, rs: usize, cs: usize) -> Matrix {
    Matrix::from_fn(rs, cs, |r, c| m.at(r0 + r, c0 + c))
}

fn add(a: &Matrix, b: &Matrix) -> Matrix {
    Matrix::from_fn(a.rows, a.cols, |r, c| a.at(r, c) + b.at(r, c))
}

fn sub(a: &Matrix, b: &Matrix) -> Matrix {
    Matrix::from_fn(a.rows, a.cols, |r, c| a.at(r, c) - b.at(r, c))
}

/// One level of Strassen on even-dimension matrices; the 7 sub-products use
/// `mul` (so the sub-products can themselves run on the crossbar pipeline).
pub fn strassen_with(
    x: &Matrix,
    w: &Matrix,
    mul: &dyn Fn(&Matrix, &Matrix) -> Matrix,
) -> Matrix {
    assert!(x.rows % 2 == 0 && x.cols % 2 == 0 && w.cols % 2 == 0);
    assert_eq!(x.cols, w.rows);
    let (hr, hk, hc) = (x.rows / 2, x.cols / 2, w.cols / 2);
    let a11 = sub_block(x, 0, 0, hr, hk);
    let a12 = sub_block(x, 0, hk, hr, hk);
    let a21 = sub_block(x, hr, 0, hr, hk);
    let a22 = sub_block(x, hr, hk, hr, hk);
    let b11 = sub_block(w, 0, 0, hk, hc);
    let b12 = sub_block(w, 0, hc, hk, hc);
    let b21 = sub_block(w, hk, 0, hk, hc);
    let b22 = sub_block(w, hk, hc, hk, hc);

    // P0..P6 (Fig 4 / Fig 8 numbering)
    let p0 = mul(&add(&a11, &a22), &add(&b11, &b22));
    let p1 = mul(&add(&a21, &a22), &b11);
    let p2 = mul(&a11, &sub(&b12, &b22));
    let p3 = mul(&a22, &sub(&b21, &b11));
    let p4 = mul(&add(&a11, &a12), &b22);
    let p5 = mul(&sub(&a21, &a11), &add(&b11, &b12));
    let p6 = mul(&sub(&a12, &a22), &add(&b21, &b22));

    let c11 = add(&sub(&add(&p0, &p3), &p4), &p6);
    let c12 = add(&p2, &p4);
    let c21 = add(&p1, &p3);
    let c22 = add(&sub(&add(&p0, &p2), &p1), &p5);

    Matrix::from_fn(x.rows, w.cols, |r, c| match (r < hr, c < hc) {
        (true, true) => c11.at(r, c),
        (true, false) => c12.at(r, c - hc),
        (false, true) => c21.at(r - hr, c),
        (false, false) => c22.at(r - hr, c - hc),
    })
}

/// Exact Strassen with plain sub-multiplies.
pub fn strassen(x: &Matrix, w: &Matrix) -> Matrix {
    strassen_with(x, w, &matmul)
}

/// Whether a layer's logical matrix is eligible for the 7-IMA mapping:
/// both halves of the reduction dim and the output dim must still fill
/// whole crossbars, otherwise decomposition just adds fragmentation
/// (the paper: "Resnet has high wastage ... does not benefit at all").
pub fn eligible(rows: usize, cols: usize, p: &XbarParams) -> bool {
    rows / 2 >= p.rows && cols / 2 >= p.cols / p.slices().max(1) * 8 / 8 && cols / 2 >= 128
}

/// Resource model for one Strassen level (Fig 8).
#[derive(Clone, Copy, Debug)]
pub struct StrassenSchedule {
    /// Sub-products executed (7 instead of 8).
    pub products: usize,
    /// Ratio of crossbar/ADC work vs the naive 8-product split.
    pub work_ratio: f64,
    /// Extra digital add operations per output element (post-processing).
    pub extra_adds_per_output: f64,
}

impl StrassenSchedule {
    pub fn one_level() -> Self {
        StrassenSchedule {
            products: 7,
            work_ratio: 7.0 / 8.0,
            // c11 needs 3 adds, c12/c21 1 each, c22 3 -> 8 adds / 4 outputs
            extra_adds_per_output: 2.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::xbar::{scale_clamp, vmm_raw_signed};

    #[test]
    fn strassen_equals_matmul() {
        let mut rng = Rng::new(4);
        let x = Matrix::from_fn(8, 6, |_, _| rng.range_i64(-100, 100));
        let w = Matrix::from_fn(6, 10, |_, _| rng.range_i64(-100, 100));
        assert_eq!(strassen(&x, &w), matmul(&x, &w));
    }

    #[test]
    fn strassen_over_crossbar_pipeline_is_exact() {
        // Sub-products run through the full analog pipeline. Strassen's
        // pre-subtractions (A21-A11 etc.) can be negative, so the crossbar
        // multiply uses the signed-input offset encoding; operand ranges are
        // halved so pre-additions stay inside the 16-bit windows.
        let p = XbarParams::default();
        let mut rng = Rng::new(5);
        let x = Matrix::from_fn(4, 2 * p.rows, |_, _| rng.range_i64(0, 1 << 14));
        let w = Matrix::from_fn(2 * p.rows, 8, |_, _| rng.range_i64(-(1 << 13), 1 << 13));
        let crossbar_mul = |a: &Matrix, b: &Matrix| vmm_raw_signed(a, b, &p, false);
        let got = strassen_with(&x, &w, &crossbar_mul);
        assert_eq!(got, matmul(&x, &w));
        // and the scaled result matches the scaled oracle
        assert_eq!(
            scale_clamp(&got, &p),
            scale_clamp(&matmul(&x, &w), &p)
        );
    }

    #[test]
    fn schedule_frees_one_in_eight() {
        let s = StrassenSchedule::one_level();
        assert_eq!(s.products, 7);
        assert!((s.work_ratio - 0.875).abs() < 1e-12);
    }

    #[test]
    fn eligibility_requires_large_matrices() {
        let p = XbarParams::default();
        assert!(eligible(512, 512, &p));
        assert!(!eligible(128, 512, &p)); // reduction too small to split
        assert!(!eligible(512, 128, &p)); // outputs too small to split
    }

    #[test]
    fn odd_dims_panic() {
        let x = Matrix::zeros(3, 4);
        let w = Matrix::zeros(4, 4);
        let r = std::panic::catch_unwind(|| strassen(&x, &w));
        assert!(r.is_err());
    }
}
