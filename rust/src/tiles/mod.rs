//! Heterogeneous tile composition (paper §III-B2, Figs 17, 18).
//! The serving stack mirrors this split at replica granularity: the
//! pipelined stage scheduler ([`crate::coordinator::pipeline`]) keeps
//! classifier stages off conv replicas via [`crate::mapping::StagePolicy`].
//!
//! Conv tiles run their ADCs at full rate; classifier (FC) tiles are
//! weight-capacity-bound and communication-bound, never throughput-bound,
//! so they share one ADC among several crossbars, run it 8x-128x slower,
//! and carry a 4 KB buffer. `ChipPlan` composes a chip for one workload
//! from its mapping.

pub mod multichip;

use crate::config::{ChipConfig, TileConfig};
use crate::energy::{CostBreakdown, TileModel};
use crate::mapping::Mapping;

/// A chip provisioned for one workload: tile counts + per-kind models.
#[derive(Clone, Debug)]
pub struct ChipPlan {
    pub conv_tiles: usize,
    pub fc_tiles: usize,
    pub conv_model: TileModel,
    pub fc_model: TileModel,
}

impl ChipPlan {
    /// Compose a chip for `mapping` under `chip`'s tile configurations.
    pub fn new(chip: &ChipConfig, mapping: &Mapping) -> ChipPlan {
        let f = &chip.features;
        let conv_model = TileModel::with_features(
            chip.conv_tile,
            chip.xbar,
            f.adaptive_adc,
            f.karatsuba,
        );
        let fc_model = TileModel::with_features(
            chip.fc_tile,
            chip.xbar,
            f.adaptive_adc,
            f.karatsuba,
        );
        ChipPlan {
            conv_tiles: mapping.conv_tiles(),
            fc_tiles: mapping.fc_tiles(),
            conv_model,
            fc_model,
        }
    }

    /// Whole-chip cost (tiles only; HT is accounted per chip by callers).
    pub fn breakdown(&self) -> CostBreakdown {
        let mut b = self.conv_model.breakdown().scaled(self.conv_tiles as f64);
        b.merge(&self.fc_model.breakdown().scaled(self.fc_tiles as f64));
        b
    }

    pub fn total_tiles(&self) -> usize {
        self.conv_tiles + self.fc_tiles
    }

    pub fn area_mm2(&self) -> f64 {
        self.breakdown().area_mm2()
    }

    pub fn peak_power_w(&self) -> f64 {
        self.breakdown().power_mw() / 1000.0
    }
}

/// Fig 17 sweep: chip peak power as the FC-tile ADC slowdown varies.
pub fn fc_slowdown_sweep(
    chip: &ChipConfig,
    mapping: &Mapping,
    slowdowns: &[f64],
) -> Vec<(f64, f64)> {
    slowdowns
        .iter()
        .map(|&s| {
            let mut c = chip.clone();
            c.fc_tile = TileConfig {
                ima: crate::config::ImaConfig {
                    adc_slowdown: s,
                    ..c.fc_tile.ima
                },
                ..c.fc_tile
            };
            (s, ChipPlan::new(&c, mapping).peak_power_w())
        })
        .collect()
}

/// Fig 18 sweep: chip area as FC tiles share more crossbars per ADC.
pub fn fc_sharing_sweep(
    chip: &ChipConfig,
    mapping: &Mapping,
    ratios: &[usize],
) -> Vec<(usize, f64)> {
    ratios
        .iter()
        .map(|&r| {
            let mut c = chip.clone();
            c.fc_tile = TileConfig {
                ima: crate::config::ImaConfig {
                    xbars_per_adc: r,
                    ..c.fc_tile.ima
                },
                ..c.fc_tile
            };
            (r, ChipPlan::new(&c, mapping).area_mm2())
        })
        .collect()
}

/// Recommended conv:fc tile ratio for single-chip workloads ("a ratio of
/// 1:1 is a good fit for most of our workloads").
pub fn conv_fc_ratio(mapping: &Mapping) -> f64 {
    mapping.conv_tiles() as f64 / mapping.fc_tiles().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipConfig, TileKind, XbarParams};
    use crate::mapping::{Mapping, MappingPolicy};
    use crate::workloads;

    fn plan_for(net: &crate::workloads::Network, chip: &ChipConfig) -> (ChipPlan, Mapping) {
        let m = Mapping::build(
            net,
            &chip.conv_tile.ima,
            &XbarParams::default(),
            MappingPolicy::newton(),
            chip.conv_tile.imas_per_tile,
        );
        (ChipPlan::new(chip, &m), m)
    }

    #[test]
    fn hetero_tiles_cut_power_for_fc_heavy_nets() {
        let net = workloads::vgg_a();
        let hetero = ChipConfig::newton();
        let mut homo = hetero.clone();
        homo.fc_tile = homo.conv_tile;
        let (ph, _) = plan_for(&net, &hetero);
        let (pm, _) = plan_for(&net, &homo);
        // paper Fig 17: ~50% lower peak power with 128x-slow FC tiles
        let drop = 1.0 - ph.peak_power_w() / pm.peak_power_w();
        assert!((0.25..0.75).contains(&drop), "{drop}");
    }

    #[test]
    fn fc_sharing_cuts_area() {
        let net = workloads::vgg_a();
        let chip = ChipConfig::newton();
        let m = plan_for(&net, &chip).1;
        let sweep = fc_sharing_sweep(&chip, &m, &[1, 2, 4]);
        assert!(sweep[2].1 < sweep[0].1, "{sweep:?}");
        // paper Fig 18: ~38% average chip-area saving at 4:1 — generous
        // corridor since it varies per net
        let save = 1.0 - sweep[2].1 / sweep[0].1;
        assert!((0.05..0.60).contains(&save), "{save}");
    }

    #[test]
    fn slowdown_sweep_monotone() {
        let net = workloads::msra_a();
        let chip = ChipConfig::newton();
        let m = plan_for(&net, &chip).1;
        let sweep = fc_slowdown_sweep(&chip, &m, &[8.0, 32.0, 128.0]);
        assert!(sweep[0].1 > sweep[1].1 && sweep[1].1 > sweep[2].1, "{sweep:?}");
    }

    #[test]
    fn resnet_needs_few_fc_tiles() {
        // paper: "Resnet does not gain much from the heterogeneous tiles
        // because it needs relatively fewer FC tiles"
        let chip = ChipConfig::newton();
        let (pr, _) = plan_for(&workloads::resnet34(), &chip);
        let (pv, _) = plan_for(&workloads::vgg_a(), &chip);
        let r_frac = pr.fc_tiles as f64 / pr.total_tiles() as f64;
        let v_frac = pv.fc_tiles as f64 / pv.total_tiles() as f64;
        assert!(r_frac < 0.5 * v_frac, "{r_frac} vs {v_frac}");
    }

    #[test]
    fn kind_tags_are_consistent() {
        let chip = ChipConfig::newton();
        assert_eq!(chip.conv_tile.kind, TileKind::Conv);
        assert_eq!(chip.fc_tile.kind, TileKind::Fc);
    }
}
