//! Multi-chip partitioning (paper §III-B2): "For large-scale workloads that
//! use multiple chips, each chip can be homogeneous; we use roughly an
//! equal number of conv-chips and classifier-chips."
//!
//! Partitions a workload's tiles across chips under a per-chip tile
//! budget, splits conv tiles onto conv-chips and FC tiles onto
//! classifier-chips, and checks the HyperTransport links can carry the
//! inter-chip activation traffic at the pipeline's rate.

use crate::config::ChipConfig;
use crate::energy::constants as k;
use crate::mapping::Mapping;
use crate::tiles::ChipPlan;

/// Multi-chip deployment plan for one workload.
#[derive(Clone, Debug)]
pub struct MultiChipPlan {
    pub conv_chips: usize,
    pub fc_chips: usize,
    /// Activation bytes crossing the conv/classifier chip boundary per
    /// image (the largest inter-chip cut).
    pub cut_bytes_per_image: usize,
    /// Total power across chips, W (incl. HT).
    pub total_power_w: f64,
    /// Total silicon, mm² (incl. HT pads).
    pub total_area_mm2: f64,
    /// Max images/s the HT links can sustain across the cut.
    pub ht_bound_throughput: f64,
}

impl MultiChipPlan {
    pub fn new(chip: &ChipConfig, mapping: &Mapping, net: &crate::workloads::Network) -> Self {
        let plan = ChipPlan::new(chip, mapping);
        let conv_chips = plan.conv_tiles.div_ceil(chip.max_tiles).max(1);
        let fc_chips = if plan.fc_tiles == 0 {
            0
        } else {
            plan.fc_tiles.div_ceil(chip.max_tiles).max(1)
        };

        // the conv->classifier cut: activations entering the first FC layer
        let cut_bytes_per_image = net
            .layers
            .iter()
            .find(|l| l.is_fc())
            .map(|l| match *l {
                crate::workloads::Layer::Fc { inputs, .. } => inputs * 2,
                _ => 0,
            })
            .unwrap_or(0);

        let conv_b = plan.conv_model.breakdown().scaled(plan.conv_tiles as f64);
        let fc_b = plan.fc_model.breakdown().scaled(plan.fc_tiles as f64);
        let chips = conv_chips + fc_chips;
        let ht_power_w = chips as f64 * k::HT_POWER_MW / 1000.0;
        let ht_area = chips as f64 * k::HT_AREA_MM2;

        let ht_bytes_per_s = chip.ht_links as f64 * k::HT_LINK_GBPS * 1e9;
        let ht_bound_throughput = if cut_bytes_per_image == 0 {
            f64::INFINITY
        } else {
            ht_bytes_per_s / cut_bytes_per_image as f64
        };

        MultiChipPlan {
            conv_chips,
            fc_chips,
            cut_bytes_per_image,
            total_power_w: (conv_b.power_mw() + fc_b.power_mw()) / 1000.0 + ht_power_w,
            total_area_mm2: conv_b.area_mm2() + fc_b.area_mm2() + ht_area,
            ht_bound_throughput,
        }
    }

    pub fn chips(&self) -> usize {
        self.conv_chips + self.fc_chips
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::XbarParams;
    use crate::mapping::MappingPolicy;
    use crate::pipeline::evaluate;
    use crate::workloads;

    fn plan(net: &workloads::Network) -> MultiChipPlan {
        let chip = ChipConfig::newton();
        let m = Mapping::build(
            net,
            &chip.conv_tile.ima,
            &XbarParams::default(),
            MappingPolicy::newton(),
            chip.conv_tile.imas_per_tile,
        );
        MultiChipPlan::new(&chip, &m, net)
    }

    #[test]
    fn msra_c_needs_multiple_chips() {
        // 330M weights -> far beyond one chip's in-situ capacity
        let p = plan(&workloads::msra_c());
        assert!(p.chips() >= 2, "{}", p.chips());
        assert!(p.fc_chips >= 1);
    }

    #[test]
    fn resnet_fits_fewer_chips_than_msra() {
        let r = plan(&workloads::resnet34());
        let m = plan(&workloads::msra_c());
        assert!(r.chips() < m.chips(), "{} vs {}", r.chips(), m.chips());
    }

    #[test]
    fn ht_does_not_bottleneck_the_pipeline() {
        // §IV statically routes transfers to be conflict-free; the HT links
        // must sustain the conv->fc cut at the pipeline's rate
        for net in workloads::suite() {
            let p = plan(&net);
            let a = evaluate(&net, &ChipConfig::newton());
            assert!(
                p.ht_bound_throughput > a.throughput,
                "{}: HT {} img/s < pipeline {} img/s",
                net.name,
                p.ht_bound_throughput,
                a.throughput
            );
        }
    }

    #[test]
    fn power_includes_ht_per_chip() {
        let p = plan(&workloads::vgg_a());
        assert!(p.total_power_w > p.chips() as f64 * k::HT_POWER_MW / 1000.0);
        assert!(p.total_area_mm2 > p.chips() as f64 * k::HT_AREA_MM2);
    }
}
