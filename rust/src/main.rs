//! `newton` CLI — leader entrypoint.
//!
//! Subcommands (the authoritative table is [`newton::cli::SUBCOMMANDS`];
//! `newton help` prints it):
//!
//! ```text
//! report                     headline Newton-vs-ISAAC comparison
//! simulate --net <name>      analytic evaluation of one workload
//! incremental                Fig-20-style technique stacking table
//! sweep --what ima|buffer|fc design-space sweeps (Figs 10/15/17/18)
//! verify                     run artifacts against golden test vectors
//! serve --requests N         batched serving demo over the PJRT runtime
//!   --adc exact|adaptive|lossy:<bits>  multi-replica golden serving with
//!                            per-batch deviation vs the lossless golden
//!   --replicas N             installed replicas for the --adc path
//!   --pipeline               pipelined stage scheduling across the
//!                            replicas (conv/classifier stage split;
//!                            implies the golden path, default --adc exact)
//!   --trace-out PATH         write a Chrome-trace JSON (Perfetto) of the
//!                            run's spans when the command exits
//!   --trace-level off|spans|verbose   span detail; defaults to `spans`
//!                            when --trace-out is given, `off` otherwise
//! serve-net                  TCP serving endpoint (rust/src/net/)
//!   --addr HOST:PORT         bind address (port 0 = ephemeral)
//!   --adc / --replicas / --batch   engine config, as for `serve`
//!   --pipeline               pipelined stage scheduling, as for `serve`
//!   --max-inflight N         admission limit (Busy beyond it)
//!   --port-file PATH         write the bound address for scripts
//!   --health                 replica health monitor: deviating replicas
//!                            walk Healthy -> Suspect -> Quarantined and
//!                            leave the serving rotation; batches re-run
//!                            on a healthy replica
//!   --deviation-threshold N  batch |err| beyond which a replica is bad
//!   --suspect-after/--quarantine-after N   consecutive-bad thresholds
//!   --inject-drift R         perturb replica R's installed cells
//!                            (--drift-seed/--drift-rate/--drift-mag)
//!   --read-tick-ms/--write-timeout-ms/--wake-timeout-ms   IO timeouts
//!   --trace-out/--trace-level      Chrome-trace export, as for `serve`
//!   --admin-addr HOST:PORT   pull-based admin plane: every connection
//!                            gets one sorted plain-text metrics
//!                            exposition (scrape with `newton statz`);
//!                            also arms the latency/energy drift watchdog
//!   --admin-port-file PATH   write the bound admin address for scripts
//!   --cost-reports           attach a per-request CostReport to every
//!                            Reply frame (proto v3 tail)
//!   --no-ledger              disable the hardware cost ledger (on by
//!                            default under serve-net)
//!   --metrics-out PATH       periodically rewrite PATH with a sorted
//!                            metric_<name> snapshot of the obs registry
//!   --metrics-interval-ms N  snapshot cadence (default 1000)
//! worker                     cluster shard worker: programs the full
//!                            model from (--seed, --adc) and serves the
//!                            shard-plane wire protocol on --addr
//!   --admin-addr HOST:PORT   per-worker admin plane (heartbeat target)
//!   --port-file/--admin-port-file PATH   write bound addresses
//! cluster-serve              coordinator: shards the stage pipeline
//!                            across --workers A,B,C processes and serves
//!                            the ordinary client protocol on --addr
//!   --worker-admins A,B,C    admin planes for heartbeat scrapes
//!   --hop-deadline-ms N      per-hop forwarding deadline
//!   --link-fault-rate/--link-fault-seed   seeded chaos on shard links
//!   --shutdown-workers       drain the fleet after the server drains
//! statz --addr HOST:PORT     scrape a serve-net admin plane and print
//!                            the exposition (read-to-EOF plain text)
//! bench-net --addr HOST:PORT multi-threaded load generator
//!   --requests N --concurrency C[,C..]   writes BENCH_net.json; a comma
//!                            list (e.g. 1,8,64) sweeps extra passes and
//!                            emits latency_p50/p99/p999_us_c{N} keys
//!   --expect-exact           assert bit-identity vs in-process golden
//!   --engine-seed N          seed of the server's install (default 0)
//!   --fault-seed S --fault-rate P   chaos mode: inject client-side wire
//!                            faults, retry under deadlines, and compare
//!                            against a clean pass (fault_overhead_b8)
//!   --deadline-ms N          per-request deadline across retries
//!   --shutdown               drain the server after the run
//!   --cluster                self-contained failover benchmark: spawns
//!                            --workers N (default 3) worker processes,
//!                            serves them through an in-process cluster
//!                            coordinator, and replays the stream under a
//!                            seeded kill/stall/restart ChaosPlan
//!                            (--chaos-seed/--chaos-events, or a pinned
//!                            --kill-worker W --kill-at R); asserts
//!                            bit-exact replies under --expect-exact and
//!                            writes cluster_failover_* JSON keys
//!   --trace-out/--trace-level      client-side Chrome-trace export
//! sched-stress               work-stealing executor stress smoke (CI)
//! export --out DIR           every figure's data series as CSV
//! list                       workloads, artifacts, and subcommands
//! ```

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use newton::cli::{self, Args};
use newton::config::{AdcKind, ChipConfig, ImaConfig, XbarParams};
use newton::coordinator::{
    newton_mini, ClusterConfig, ClusterEngine, ClusterWorker, GoldenServer, HealthPolicy,
    HealthState, PipelineServer, ServerConfig, WorkerConfig,
};
use newton::faults::{ChaosAction, ChaosPlan, FaultPlan};
use newton::mapping::{self, Mapping, MappingPolicy, StagePolicy};
use newton::metrics;
use newton::net::{self, BenchConfig, NetServer, ServeConfig};
use newton::pipeline::evaluate;
use newton::runtime::{default_artifacts_dir, Runtime};
use newton::tiles;
use newton::util::{f1, f2, Rng, Table};
use newton::workloads::{self, Network};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("report");
    let r = match cmd {
        "report" => cmd_report(),
        "simulate" => cmd_simulate(&args),
        "incremental" => cmd_incremental(),
        "sweep" => cmd_sweep(&args),
        "verify" => cmd_verify(&args),
        "serve" => cmd_serve(&args),
        "serve-net" => cmd_serve_net(&args),
        "worker" => cmd_worker(&args),
        "cluster-serve" => cmd_cluster_serve(&args),
        "bench-net" => cmd_bench_net(&args),
        "statz" => cmd_statz(&args),
        "sched-stress" => cmd_sched_stress(&args),
        "export" => cmd_export(&args),
        "list" => cmd_list(),
        "help" => cmd_help(),
        other => Err(anyhow!("unknown command {other:?}; try {}", cli::command_summary())),
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `--trace-out PATH` / `--trace-level off|spans|verbose`: arm the obs
/// layer for this run. The level defaults to `spans` when an output path
/// is given and `off` otherwise, so plain runs pay only the relaxed-load
/// disabled cost. Returns the output path for [`export_trace`].
fn init_tracing(args: &Args) -> Result<Option<String>> {
    let out = args.get("trace-out").map(str::to_string);
    let level = match args.get("trace-level") {
        Some(l) => newton::obs::TraceLevel::parse(l)
            .ok_or_else(|| anyhow!("--trace-level wants off|spans|verbose, got {l:?}"))?,
        None if out.is_some() => newton::obs::TraceLevel::Spans,
        None => newton::obs::TraceLevel::Off,
    };
    newton::obs::set_trace_level(level);
    Ok(out)
}

/// Flush this thread and write the global sink as Chrome-trace JSON.
/// Worker/handler threads flushed on exit; by the time a command gets
/// here their spans are already in the sink.
fn export_trace(out: Option<&str>) {
    let Some(path) = out else { return };
    match newton::obs::export_global_chrome_trace(std::path::Path::new(path)) {
        Ok(()) => println!(
            "wrote {path} ({} trace events, {} dropped)",
            newton::obs::global_sink().len(),
            newton::obs::global_sink().dropped()
        ),
        Err(e) => println!("could not write trace {path}: {e}"),
    }
}

fn find_net(name: &str) -> Result<Network> {
    if name == "newton-mini" {
        return Ok(newton_mini());
    }
    workloads::suite()
        .into_iter()
        .find(|n| n.name == name)
        .ok_or_else(|| anyhow!("unknown net {name:?}; see `newton list`"))
}

fn cmd_report() -> Result<()> {
    let nets = workloads::suite();
    let h = metrics::headline(&nets);
    println!("Newton vs ISAAC (geomean over the Table-II suite)");
    println!("  power decrease        : {:5.1}%  (paper: 77%)", h.power_decrease * 100.0);
    println!("  energy decrease       : {:5.1}%  (paper: 51%)", h.energy_decrease * 100.0);
    println!("  throughput/area ratio : {:5.2}x (paper: 2.2x)", h.throughput_area_ratio);
    println!("  energy per op (newton): {:5.2} pJ (paper: 0.85 pJ)", h.newton_pj_per_op);
    println!("  energy per op (isaac) : {:5.2} pJ (paper: 1.8 pJ)", h.isaac_pj_per_op);
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let name = args.get_or("net", "vgg-a");
    let net = find_net(name)?;
    let chip = if args.has_flag("isaac") {
        ChipConfig::isaac()
    } else {
        ChipConfig::newton()
    };
    let r = evaluate(&net, &chip);
    println!("{name} on {}", if args.has_flag("isaac") { "ISAAC" } else { "Newton" });
    println!("  throughput    : {:.1} images/s", r.throughput);
    println!("  latency       : {:.1} us", r.latency_us);
    println!("  peak power    : {:.2} W", r.peak_power_w);
    println!("  avg power     : {:.2} W", r.avg_power_w);
    println!("  energy/image  : {:.3} mJ", r.energy_per_image_mj);
    println!("  energy/op     : {:.2} pJ", r.energy_per_op_pj);
    println!("  area          : {:.1} mm² ({} conv + {} fc tiles)", r.area_mm2, r.conv_tiles, r.fc_tiles);
    println!("  CE (delivered): {:.0} GOPS/mm²", r.ce_eff);
    println!("  PE (delivered): {:.0} GOPS/W", r.pe_eff);
    Ok(())
}

fn cmd_incremental() -> Result<()> {
    let nets = workloads::suite();
    let rows = metrics::incremental_progression(&nets);
    let mut t = Table::new(&[
        "design point",
        "peak CE",
        "peak PE",
        "pJ/op",
        "peak W",
    ]);
    for r in rows {
        t.row(&[
            r.label.to_string(),
            f1(r.peak.ce_gops_mm2),
            f1(r.peak.pe_gops_w),
            f2(r.energy_per_op_pj),
            f2(r.peak_power_w),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let what = args.get_or("what", "ima");
    let nets = workloads::suite();
    let p = XbarParams::default();
    match what {
        "ima" => {
            let mut t = Table::new(&["IMA (in x out)", "under-utilization %"]);
            for (i, o) in [(128, 64), (128, 128), (128, 256), (256, 256), (512, 512), (2048, 1024), (8192, 1024)] {
                let ima = ImaConfig {
                    inputs: i,
                    outputs: o,
                    ..ImaConfig::newton_default()
                };
                let u = mapping::avg_underutilization(&nets, &ima, &p, 16);
                t.row(&[format!("{i}x{o}"), f1(u * 100.0)]);
            }
            t.print();
        }
        "buffer" => {
            let mut t = Table::new(&["image", "worst-case KB", "spread KB"]);
            for w in [32usize, 64, 128, 224, 256, 512] {
                let (mut worst, mut avg) = (0.0f64, 0.0f64);
                for n in &nets {
                    let n = n.with_input_width(w);
                    let mw = Mapping::build(&n, &ImaConfig::newton_default(), &p, MappingPolicy::isaac(), 16);
                    let ma = Mapping::build(&n, &ImaConfig::newton_default(), &p, MappingPolicy::newton(), 16);
                    worst = worst.max(mw.buffer_per_tile_bytes());
                    avg = avg.max(ma.buffer_per_tile_bytes());
                }
                t.row(&[w.to_string(), f1(worst / 1024.0), f1(avg / 1024.0)]);
            }
            t.print();
        }
        "fc" => {
            let chip = ChipConfig::newton();
            let net = workloads::vgg_a();
            let m = Mapping::build(&net, &chip.conv_tile.ima, &p, MappingPolicy::newton(), 16);
            println!("FC-tile ADC slowdown vs chip peak power (vgg-a):");
            for (s, w) in tiles::fc_slowdown_sweep(&chip, &m, &[1.0, 8.0, 32.0, 128.0]) {
                println!("  {s:>5}x : {w:.2} W");
            }
            println!("FC-tile xbars/ADC vs chip area (vgg-a):");
            for (r, a) in tiles::fc_sharing_sweep(&chip, &m, &[1, 2, 4]) {
                println!("  {r}:1   : {a:.1} mm²");
            }
        }
        other => bail!("unknown sweep {other:?}; try ima|buffer|fc"),
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get_or("artifacts", ""));
    let dir = if dir.as_os_str().is_empty() {
        default_artifacts_dir()
    } else {
        dir
    };
    let mut rt = Runtime::new(&dir)?;

    // fused model vs golden logits
    let (_, input) = rt.manifest.load_testvec("input_b8")?;
    let (_, want_logits) = rt.manifest.load_testvec("logits_b8")?;
    let got = rt.run("model_b8", &input)?;
    if got != want_logits {
        bail!("model_b8 output mismatches golden logits");
    }
    println!("model_b8 matches golden logits ({} values)", got.len());

    // staged pipeline == fused model
    let mut act = input.clone();
    for s in 0..4 {
        act = rt.run(&format!("stage{s}_b8"), &act)?;
        let (_, want) = rt.manifest.load_testvec(&format!("stage{s}_out_b8"))?;
        if act != want {
            bail!("stage{s} output mismatches golden");
        }
    }
    println!("staged pipeline matches per-stage goldens");

    // single-IMA VMM vs rust golden model and testvec
    let (_, vin) = rt.manifest.load_testvec("vmm_in")?;
    let (_, vout) = rt.manifest.load_testvec("vmm_out")?;
    let got = rt.run("vmm_plain", &vin)?;
    if got != vout {
        bail!("vmm_plain mismatches golden");
    }
    let got_k = rt.run("vmm_karatsuba", &vin)?;
    if got_k != vout {
        bail!("vmm_karatsuba mismatches plain VMM");
    }
    println!("vmm artifacts match goldens (plain == karatsuba)");
    println!("verify OK");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let trace_out = init_tracing(args)?;
    let n_req = args.get_usize("requests", 64);
    let dir = default_artifacts_dir();
    let cfg = ServerConfig::newton_mini(dir);
    let mut rng = Rng::new(args.get_usize("seed", 42) as u64);
    let images: Vec<Vec<i32>> = (0..n_req)
        .map(|_| (0..32 * 32 * 3).map(|_| rng.below(256) as i32).collect())
        .collect();

    // --adc selects the multi-replica golden path: N installed replicas fed
    // from the batcher through the work-stealing executor, every batch
    // checked against the lossless golden reference. Runs in a fresh
    // checkout — no PJRT artifacts involved. --pipeline (implies the
    // golden path; exact ADC unless --adc says otherwise) switches it to
    // pipelined stage scheduling across the replica pool.
    if args.get("adc").is_some() || args.has_flag("pipeline") {
        let kind = AdcKind::parse(args.get_or("adc", "exact")).map_err(|e| anyhow!("{e}"))?;
        serve_replicated(&images, kind, args)?;
        print_simulated_hw();
        export_trace(trace_out.as_deref());
        return Ok(());
    }

    match PipelineServer::start(cfg) {
        Ok(mut server) => {
            let t0 = std::time::Instant::now();
            for img in &images {
                server.submit(img.clone())?;
            }
            let results = server.collect(n_req)?;
            let wall = t0.elapsed();
            let report = server.shutdown(&results, wall);

            println!("served {} requests in {:.2}s", report.completed, wall.as_secs_f64());
            println!("  throughput : {:.1} req/s (wallclock, interpret-mode kernels)", report.throughput_rps);
            println!("  latency p50: {:.1} ms   max: {:.1} ms", report.latency_p50_ms, report.latency_max_ms);
            println!("  batches    : {} (fill {:.0}%)", report.batches, report.batch_fill * 100.0);
        }
        Err(e) => {
            println!("PJRT serving unavailable ({e:#});");
            println!("golden-model fallback: newton-mini weights installed once in-crossbar");
            let server = GoldenServer::newton_mini_default();
            let t0 = std::time::Instant::now();
            let logits = server.infer(&images);
            let wall = t0.elapsed();
            println!("served {} requests in {:.2}s", logits.len(), wall.as_secs_f64());
            println!("  throughput : {:.1} req/s (golden model)", logits.len() as f64 / wall.as_secs_f64());
            if !server.verify_head(&images) {
                bail!("golden-model verification failed: installed != per-call engine");
            }
            println!("  verified   : first batch bit-identical to the per-call engine ✓");
        }
    }

    print_simulated_hw();
    export_trace(trace_out.as_deref());
    Ok(())
}

/// Simulated hardware-side metrics for the served model.
fn print_simulated_hw() {
    let sim = evaluate(&newton_mini(), &ChipConfig::newton());
    println!("simulated newton hardware for newton-mini:");
    println!("  throughput : {:.0} images/s   energy/op: {:.2} pJ", sim.throughput, sim.energy_per_op_pj);
}

/// Multi-replica golden serving with per-batch deviation reporting.
fn serve_replicated(images: &[Vec<i32>], kind: AdcKind, args: &Args) -> Result<()> {
    let n_rep = args.get_usize("replicas", 2);
    let batch = args.get_usize("batch", 8);
    if n_rep == 0 {
        bail!("--replicas must be >= 1");
    }
    if batch == 0 {
        bail!("--batch must be >= 1");
    }
    let t0 = std::time::Instant::now();
    let mut server = GoldenServer::replicated(0, kind, n_rep, batch);
    if args.has_flag("pipeline") {
        server = server
            .with_pipeline(StagePolicy::newton())
            .map_err(|e| anyhow!("--pipeline: {e}"))?;
    }
    println!(
        "multi-replica golden serving: {} replicas{}, batch {}, adc {}",
        server.n_replicas(),
        if server.has_golden_reference() { " + 1 lossless golden" } else { "" },
        server.batch(),
        kind.label()
    );
    if let Some(map) = server.pipeline_map() {
        println!(
            "  pipelined stage scheduling: stage -> replica {:?} (classifier isolated)",
            map.assignment
        );
    }
    println!("  installed in {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);

    let t0 = std::time::Instant::now();
    let reports = server.serve_batches(images);
    let wall = t0.elapsed();

    let mut t = Table::new(&["batch", "replica", "real", "max|err| vs golden"]);
    for r in &reports {
        t.row(&[
            r.index.to_string(),
            r.replica.to_string(),
            r.n_real.to_string(),
            r.max_abs_err.to_string(),
        ]);
    }
    t.print();

    let (served, worst) = newton::coordinator::serve_totals(&reports);
    println!(
        "served {} requests / {} batches in {:.2}s ({:.1} req/s)",
        served,
        reports.len(),
        wall.as_secs_f64(),
        served as f64 / wall.as_secs_f64().max(1e-9)
    );
    println!("  worst per-batch deviation vs lossless golden: {worst}");
    if !server.verify_head(images) {
        bail!("golden-model verification failed: installed != per-call engine");
    }
    println!("  verified   : head batch bit-identical to the per-call engine ✓");
    Ok(())
}

/// TCP serving endpoint: the `serve --adc` engine behind `rust/src/net/`.
/// Blocks until a client sends a `Shutdown` frame, then drains and prints
/// the final stats.
fn cmd_serve_net(args: &Args) -> Result<()> {
    let trace_out = init_tracing(args)?;
    let kind = AdcKind::parse(args.get_or("adc", "exact")).map_err(|e| anyhow!("{e}"))?;
    let replicas = args.get_usize("replicas", 2);
    let batch = args.get_usize("batch", 8);
    let seed = args.get_usize("seed", 0) as u64;
    let max_inflight = args.get_usize("max-inflight", 64);
    let wait_ms = args.get_usize("batch-wait-ms", 2);
    if replicas == 0 {
        bail!("--replicas must be >= 1");
    }
    if batch == 0 {
        bail!("--batch must be >= 1");
    }
    if max_inflight == 0 {
        bail!("--max-inflight must be >= 1");
    }

    let t0 = std::time::Instant::now();
    let mut engine = GoldenServer::replicated(seed, kind, replicas, batch);
    if args.has_flag("pipeline") {
        engine = engine
            .with_pipeline(StagePolicy::newton())
            .map_err(|e| anyhow!("--pipeline: {e}"))?;
    }
    // any health knob arms the monitor, so `--deviation-threshold 0` alone
    // works in scripts without a separate --health
    if args.has_flag("health") || args.get("health").is_some() || args.get("deviation-threshold").is_some() {
        let policy = HealthPolicy {
            deviation_threshold: args.get_usize("deviation-threshold", 0) as i64,
            suspect_after: args.get_usize("suspect-after", 1) as u32,
            quarantine_after: args.get_usize("quarantine-after", 3) as u32,
            ..HealthPolicy::default()
        };
        engine = engine.with_health(policy);
    }
    if let Some(r) = args.get("inject-drift") {
        let replica: usize = r
            .parse()
            .map_err(|_| anyhow!("--inject-drift wants a replica index, got {r:?}"))?;
        if replica >= replicas {
            bail!("--inject-drift {replica} out of range (replicas: {replicas})");
        }
        let plan = FaultPlan::drift(
            args.get_usize("drift-seed", 7) as u64,
            args.get_f64("drift-rate", 0.05),
            args.get_usize("drift-mag", 30) as i64,
        );
        engine.inject_cell_faults(replica, &plan);
        println!(
            "injected cell drift into replica {replica} (seed {}, rate {}, mag {})",
            args.get_usize("drift-seed", 7),
            args.get_f64("drift-rate", 0.05),
            args.get_usize("drift-mag", 30)
        );
    }
    let engine = Arc::new(engine);
    println!(
        "installed engine in {:.1} ms: {}",
        t0.elapsed().as_secs_f64() * 1e3,
        newton::net::Engine::describe(engine.as_ref())
    );

    let timeouts = net::Timeouts::default();
    let timeouts = net::Timeouts {
        read_tick: Duration::from_millis(args.get_usize("read-tick-ms", timeouts.read_tick.as_millis() as usize) as u64),
        write_timeout: Duration::from_millis(args.get_usize("write-timeout-ms", timeouts.write_timeout.as_millis() as usize) as u64),
        wake_connect: Duration::from_millis(args.get_usize("wake-timeout-ms", timeouts.wake_connect.as_millis() as usize) as u64),
        ..timeouts
    };
    // the hardware cost ledger is on by default for the long-lived
    // endpoint (per-forward overhead is a few relaxed adds; see
    // ledger_overhead_b8 in PERF.md) — it feeds the admin exposition,
    // the Stats frame's ledger.* counters, and --cost-reports
    newton::obs::ledger::set_enabled(!args.has_flag("no-ledger"));
    // --event-loop: readiness-driven serving (connections cost fds, not
    // threads) with per-connection pipelining up to --max-pipeline tagged
    // requests, dispatched by a --workers-sized engine pool
    let event_loop = (args.has_flag("event-loop")
        || args.get("max-pipeline").is_some()
        || args.get("workers").is_some())
    .then(|| {
        let d = newton::net::EventLoopConfig::default();
        newton::net::EventLoopConfig {
            workers: args.get_usize("workers", d.workers),
            max_pipeline: args.get_usize("max-pipeline", d.max_pipeline),
        }
    });
    if let Some(el) = &event_loop {
        if el.workers == 0 {
            bail!("--workers must be >= 1");
        }
        if el.max_pipeline == 0 {
            bail!("--max-pipeline must be >= 1");
        }
    }
    let server = NetServer::start(
        engine,
        ServeConfig {
            addr: args.get_or("addr", "127.0.0.1:0").to_string(),
            max_inflight,
            batch_wait: Duration::from_millis(wait_ms as u64),
            timeouts,
            admin_addr: args.get("admin-addr").map(str::to_string),
            cost_reports: args.has_flag("cost-reports"),
            event_loop: event_loop.clone(),
        },
    )?;
    let addr = server.local_addr();
    match &event_loop {
        Some(el) => println!(
            "serve-net listening on {addr} (event loop: {} workers, pipeline window {}, max {max_inflight} in flight)",
            el.workers, el.max_pipeline
        ),
        None => println!("serve-net listening on {addr} (max {max_inflight} in flight)"),
    }
    if let Some(pf) = args.get("port-file") {
        std::fs::write(pf, addr.to_string())?;
        println!("  bound address written to {pf}");
    }
    if let Some(admin) = server.admin_addr() {
        println!("  admin plane on {admin} (scrape with: newton statz --addr {admin})");
        if let Some(pf) = args.get("admin-port-file") {
            std::fs::write(pf, admin.to_string())?;
            println!("  admin address written to {pf}");
        }
    }
    println!("  drain with: newton bench-net --addr {addr} --shutdown");

    // --metrics-out: a background writer that rewrites PATH with a sorted
    // registry snapshot every interval (and once more on the way out), so
    // an operator can tail live ledger/serving counters without a scrape
    let stop_writer = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let writer = args.get("metrics-out").map(str::to_string).map(|path| {
        let stop = stop_writer.clone();
        let interval =
            Duration::from_millis(args.get_usize("metrics-interval-ms", 1000).max(10) as u64);
        std::thread::spawn(move || loop {
            write_metrics_snapshot(&path);
            if stop.load(std::sync::atomic::Ordering::Acquire) {
                break;
            }
            std::thread::sleep(interval);
        })
    });

    let stats = server.join();
    stop_writer.store(true, std::sync::atomic::Ordering::Release);
    if let Some(w) = writer {
        let _ = w.join();
    }
    print_net_stats(&stats);
    export_trace(trace_out.as_deref());
    if let Some(dir) = args.get("export") {
        let f = metrics::export::export_net_summary(std::path::Path::new(dir), &stats)?;
        println!("wrote {dir}/{f}");
    }
    Ok(())
}

/// One shard-serving worker process: programs the full model, serves
/// `ShardInstall`/`Fwd` on its shard port and a `newton_worker_*`
/// exposition on the admin port, and exits when a `Shutdown` frame (or a
/// coordinator drain) lands.
fn cmd_worker(args: &Args) -> Result<()> {
    let kind = AdcKind::parse(args.get_or("adc", "exact")).map_err(|e| anyhow!("{e}"))?;
    let seed = args.get_usize("seed", 0) as u64;
    let cfg = WorkerConfig::new(seed, kind).map_err(|e| anyhow!("{e}"))?;
    // workers price their own hops: FwdReply ships the hop's CostLedger
    // and energy, and cluster conservation is asserted against it
    newton::obs::ledger::set_enabled(!args.has_flag("no-ledger"));
    let t0 = std::time::Instant::now();
    let worker = ClusterWorker::start(cfg, args.get_or("addr", "127.0.0.1:0"), args.get("admin-addr"))?;
    println!(
        "worker listening on {} (programmed full model in {:.1} ms, seed {seed})",
        worker.local_addr(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    if let Some(pf) = args.get("port-file") {
        std::fs::write(pf, worker.local_addr().to_string())?;
    }
    if let Some(admin) = worker.admin_addr() {
        println!("  worker admin plane on {admin}");
        if let Some(pf) = args.get("admin-port-file") {
            std::fs::write(pf, admin.to_string())?;
        }
    }
    worker.join();
    println!("worker drained");
    Ok(())
}

/// Coordinator endpoint: shards the stage pipeline across `--workers`
/// processes and serves the ordinary client protocol on `--addr` — to a
/// client there is no difference between a cluster and a single process.
fn cmd_cluster_serve(args: &Args) -> Result<()> {
    let trace_out = init_tracing(args)?;
    let kind = AdcKind::parse(args.get_or("adc", "exact")).map_err(|e| anyhow!("{e}"))?;
    let seed = args.get_usize("seed", 0) as u64;
    let batch = args.get_usize("batch", 8);
    let max_inflight = args.get_usize("max-inflight", 64);
    let wait_ms = args.get_usize("batch-wait-ms", 2);
    let workers_spec = args
        .get("workers")
        .ok_or_else(|| anyhow!("--workers A,B,C is required (shard addresses)"))?;
    let workers: Vec<String> = workers_spec
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if workers.is_empty() {
        bail!("--workers needs at least one address");
    }
    // optional parallel list of worker admin planes (heartbeat scrape
    // targets); empty entries fall back to stats-probe heartbeats
    let admins: Vec<Option<String>> = match args.get("worker-admins") {
        Some(spec) => spec
            .split(',')
            .map(|s| {
                let s = s.trim();
                if s.is_empty() { None } else { Some(s.to_string()) }
            })
            .collect(),
        None => vec![None; workers.len()],
    };
    if admins.len() != workers.len() {
        bail!(
            "--worker-admins has {} entries for {} workers",
            admins.len(),
            workers.len()
        );
    }
    let endpoints: Vec<(String, Option<String>)> =
        workers.into_iter().zip(admins).collect();

    let mut ccfg = ClusterConfig::new(seed, kind, batch).map_err(|e| anyhow!("{e}"))?;
    if let Some(ms) = args.get("hop-deadline-ms") {
        let ms: u64 = ms.parse().map_err(|_| anyhow!("--hop-deadline-ms wants a number"))?;
        ccfg.hop_deadline = Duration::from_millis(ms.max(1));
    }
    ccfg.link_fault_rate = args.get_f64("link-fault-rate", 0.0);
    ccfg.link_fault_seed = args.get_usize("link-fault-seed", 0) as u64;
    if !(0.0..=1.0).contains(&ccfg.link_fault_rate) {
        bail!("--link-fault-rate must be in [0, 1]");
    }

    newton::obs::ledger::set_enabled(!args.has_flag("no-ledger"));
    let t0 = std::time::Instant::now();
    let engine = ClusterEngine::connect(ccfg, &endpoints).map_err(|e| anyhow!("cluster connect: {e}"))?;
    let heartbeats = engine.spawn_heartbeats();
    println!(
        "cluster up in {:.1} ms: {}",
        t0.elapsed().as_secs_f64() * 1e3,
        newton::net::Engine::describe(engine.as_ref())
    );

    let timeouts = net::Timeouts::default();
    let server = NetServer::start(
        engine.clone(),
        ServeConfig {
            addr: args.get_or("addr", "127.0.0.1:0").to_string(),
            max_inflight,
            batch_wait: Duration::from_millis(wait_ms as u64),
            timeouts,
            admin_addr: args.get("admin-addr").map(str::to_string),
            cost_reports: args.has_flag("cost-reports"),
        },
    )?;
    let addr = server.local_addr();
    println!("cluster-serve listening on {addr} (max {max_inflight} in flight)");
    if let Some(pf) = args.get("port-file") {
        std::fs::write(pf, addr.to_string())?;
    }
    if let Some(admin) = server.admin_addr() {
        println!("  admin plane on {admin}");
        if let Some(pf) = args.get("admin-port-file") {
            std::fs::write(pf, admin.to_string())?;
        }
    }
    println!("  drain with: newton bench-net --addr {addr} --shutdown");

    let stats = server.join();
    engine.stop();
    let _ = heartbeats.join();
    if args.has_flag("shutdown-workers") {
        engine.shutdown_workers();
        println!("sent shutdown to every worker");
    }
    println!("final re-shard count: {} (generation {})", engine.reshard_count(), engine.generation());
    print_net_stats(&stats);
    export_trace(trace_out.as_deref());
    Ok(())
}

fn print_net_stats(s: &net::StatsSnapshot) {
    println!(
        "drained: {} served / {} busy-rejected / {} protocol errors",
        s.served, s.busy, s.proto_errors
    );
    println!(
        "  batches    : {} (fill {:.0}%)   latency p50 {:.1} ms  p99 {:.1} ms  p999 {:.1} ms",
        s.batches,
        s.batch_fill * 100.0,
        s.p50_us as f64 / 1e3,
        s.p99_us as f64 / 1e3,
        s.p999_us as f64 / 1e3
    );
    println!("  worst batch deviation vs lossless golden: {}", s.worst_abs_err);
    if s.health.is_empty() {
        let mut t = Table::new(&["replica", "requests"]);
        for (i, n) in s.per_replica.iter().enumerate() {
            t.row(&[i.to_string(), n.to_string()]);
        }
        t.print();
    } else {
        println!(
            "  health     : {} batch re-runs, {} quarantines{}",
            s.reruns,
            s.quarantines,
            if s.degraded { " — DEGRADED (all replicas quarantined)" } else { "" }
        );
        let mut t = Table::new(&["replica", "requests", "health"]);
        for (i, n) in s.per_replica.iter().enumerate() {
            let state = s
                .health
                .get(i)
                .map(|&b| HealthState::from_u8(b).label())
                .unwrap_or("?");
            t.row(&[i.to_string(), n.to_string(), state.to_string()]);
        }
        t.print();
    }
    if !s.metrics.is_empty() {
        println!("  counters   :");
        for (name, value) in &s.metrics {
            println!("    {name:<28} {value}");
        }
    }
}

/// One sorted `metric_<name> value` snapshot of the obs registry —
/// the `--metrics-out` writer's file format (histograms expand to
/// `.count`/`.p50`/`.p99` rows). Best-effort: a failed write is skipped,
/// not fatal to serving.
fn write_metrics_snapshot(path: &str) {
    let snap = newton::obs::metrics_snapshot();
    let mut lines: Vec<String> = Vec::new();
    for (name, v) in &snap.counters {
        lines.push(format!("metric_{name} {v}"));
    }
    for (name, h) in &snap.histograms {
        lines.push(format!("metric_{name}.count {}", h.count));
        lines.push(format!("metric_{name}.p50 {}", h.percentile(0.50)));
        lines.push(format!("metric_{name}.p99 {}", h.percentile(0.99)));
    }
    lines.sort_unstable();
    let mut body = lines.join("\n");
    body.push('\n');
    let _ = std::fs::write(path, body);
}

/// Scrape a serve-net admin plane (`--admin-addr`) and print the plain
/// text exposition.
fn cmd_statz(args: &Args) -> Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow!("--addr is required (serve-net --admin-addr prints it)"))?;
    let timeout = Duration::from_millis(args.get_usize("timeout-ms", 5000) as u64);
    let body = net::scrape_statz(addr, timeout)?;
    if body.is_empty() {
        bail!("empty exposition from {addr}");
    }
    print!("{body}");
    Ok(())
}

/// Multi-threaded load generator against a `serve-net` endpoint. Writes
/// `BENCH_net.json`; `--expect-exact` additionally re-runs the identical
/// request stream through an in-process `GoldenServer` and asserts
/// bit-identity plus zero deviation; `--shutdown` drains the server.
fn cmd_bench_net(args: &Args) -> Result<()> {
    if args.has_flag("cluster") {
        // --cluster owns its own server and worker fleet; everything else
        // in this function benches an endpoint somebody else started
        return cmd_bench_net_cluster(args);
    }
    let trace_out = init_tracing(args)?;
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow!("--addr is required (serve-net prints the bound address)"))?;
    // --concurrency takes a single lane count or a comma list (1,8,64);
    // the first entry is the primary pass (chaos/verification/top-level
    // JSON), the rest are latency-sweep passes
    let conc_spec = args.get_or("concurrency", "8");
    let concurrencies: Vec<usize> = conc_spec
        .split(',')
        .map(|s| {
            s.trim().parse::<usize>().map_err(|_| {
                anyhow!("--concurrency wants N or a comma list like 1,8,64, got {conc_spec:?}")
            })
        })
        .collect::<Result<_>>()?;
    let mut cfg = BenchConfig::new(addr);
    cfg.requests = args.get_usize("requests", 64);
    cfg.concurrency = concurrencies[0];
    cfg.seed = args.get_usize("seed", 0) as u64;
    cfg.deadline = Duration::from_millis(args.get_usize("deadline-ms", 30_000) as u64);
    cfg.fault_seed = args.get_usize("fault-seed", 0) as u64;
    cfg.fault_rate = args.get_f64("fault-rate", 0.0);
    if cfg.requests == 0 || concurrencies.iter().any(|&c| c == 0) {
        bail!("--requests and --concurrency must be >= 1");
    }
    if !(0.0..=1.0).contains(&cfg.fault_rate) {
        bail!("--fault-rate must be in [0, 1], got {}", cfg.fault_rate);
    }

    println!(
        "bench-net: {} requests x {} lanes against {addr}{}",
        cfg.requests,
        cfg.concurrency,
        if cfg.fault_rate > 0.0 {
            format!(" (chaos: fault rate {} seed {})", cfg.fault_rate, cfg.fault_seed)
        } else {
            String::new()
        }
    );
    // chaos mode measures its overhead against a clean pass of the same
    // stream first, so fault_overhead_b8 comes from one process and one
    // warmed server
    let clean = if cfg.fault_rate > 0.0 {
        let clean_cfg = BenchConfig {
            fault_rate: 0.0,
            ..cfg.clone()
        };
        let c = net::load_generate(&clean_cfg)?;
        println!(
            "clean pass: {} requests in {:.2}s ({:.1} req/s)",
            c.requests, c.wall_s, c.throughput_rps
        );
        Some(c)
    } else {
        None
    };
    let mut report = net::load_generate(&cfg)?;
    let fault_overhead = clean
        .as_ref()
        .map(|c| c.throughput_rps / report.throughput_rps.max(1e-9));
    println!(
        "completed {} requests in {:.2}s ({:.1} req/s, {} busy retries)",
        report.requests, report.wall_s, report.throughput_rps, report.busy_retries
    );
    if cfg.fault_rate > 0.0 {
        println!(
            "  chaos      : {} faults injected, {} transport retries, {} reconnects, overhead {:.2}x",
            report.injected_faults,
            report.fault_retries,
            report.reconnects,
            fault_overhead.unwrap_or(1.0)
        );
    }
    println!(
        "  latency p50 : {:.1} ms   p99: {:.1} ms   p999: {:.1} ms   max: {:.1} ms",
        report.p50_ms,
        report.p99_ms,
        report.p999_us as f64 / 1e3,
        report.max_ms
    );
    println!("  worst batch deviation vs lossless golden: {}", report.worst_abs_err);

    // latency sweep: the primary pass plus one pass per extra lane count,
    // all against the same warmed server
    let mut sweep: Vec<(usize, u64, u64, u64)> =
        vec![(cfg.concurrency, report.p50_us, report.p99_us, report.p999_us)];
    for &c in &concurrencies[1..] {
        let pass_cfg = BenchConfig {
            concurrency: c,
            ..cfg.clone()
        };
        let p = net::load_generate(&pass_cfg)?;
        println!(
            "  sweep c={c:<3}: {:.1} req/s   p50 {} us  p99 {} us  p999 {} us",
            p.throughput_rps, p.p50_us, p.p99_us, p.p999_us
        );
        sweep.push((c, p.p50_us, p.p99_us, p.p999_us));
    }

    // server-side view of the same run
    let mut ctl = net::Client::connect(addr)?;
    let stats = ctl.stats()?;
    // the client only sees replicas that replied; pad with the server's
    // replica count so idle replicas show as explicit zeros
    if report.per_replica.len() < stats.per_replica.len() {
        report.per_replica.resize(stats.per_replica.len(), 0);
    }
    let mut t = Table::new(&["replica", "replies"]);
    for (i, n) in report.per_replica.iter().enumerate() {
        t.row(&[i.to_string(), n.to_string()]);
    }
    t.print();
    println!(
        "server: {} served / {} busy / {} batches (fill {:.0}%)",
        stats.served,
        stats.busy,
        stats.batches,
        stats.batch_fill * 100.0
    );

    // pipeline sweep: one tagged v4 connection per depth, window-bounded
    // out-of-order completion (the event-loop server reorders; the
    // threaded server serializes but echoes tags, so both modes work)
    let mut pipelined: Vec<net::PipelinedReport> = Vec::new();
    if let Some(spec) = args.get("pipeline-depth") {
        let depths: Vec<usize> = spec
            .split(',')
            .map(|s| {
                s.trim().parse::<usize>().map_err(|_| {
                    anyhow!("--pipeline-depth wants N or a comma list like 1,8,32, got {spec:?}")
                })
            })
            .collect::<Result<_>>()?;
        if depths.iter().any(|&d| d == 0) {
            bail!("--pipeline-depth entries must be >= 1");
        }
        for &d in &depths {
            let p = net::load_generate_pipelined(&cfg, d)?;
            println!(
                "  pipeline d={d:<3}: {:.1} req/s   p50 {} us  p99 {} us  p999 {} us  ({} busy retries)",
                p.throughput_rps, p.p50_us, p.p99_us, p.p999_us, p.busy_retries
            );
            pipelined.push(p);
        }
    }

    let verified = if args.has_flag("expect-exact") {
        // the in-process reference must install the same weights the
        // server did: --engine-seed mirrors serve-net's --seed (default 0)
        let engine_seed = args.get_usize("engine-seed", 0) as u64;
        let images: Vec<Vec<i32>> =
            (0..cfg.requests).map(|i| net::bench_image(cfg.seed, i)).collect();
        let want = GoldenServer::replicated(engine_seed, AdcKind::Exact, 1, 8).infer(&images);
        if report.logits != want {
            bail!("--expect-exact: served logits are NOT bit-identical to the in-process GoldenServer");
        }
        if report.worst_abs_err != 0 {
            bail!(
                "--expect-exact: server reported a nonzero deviation ({}) under an exact config",
                report.worst_abs_err
            );
        }
        // each pipelined pass replays the identical request stream, so its
        // tag-reassembled logits must match the same golden bit for bit
        for p in &pipelined {
            if p.logits != want {
                bail!(
                    "--expect-exact: pipelined pass (depth {}) logits are NOT bit-identical to the in-process GoldenServer",
                    p.depth
                );
            }
            if p.worst_abs_err != 0 {
                bail!(
                    "--expect-exact: pipelined pass (depth {}) reported a nonzero deviation ({}) under an exact config",
                    p.depth,
                    p.worst_abs_err
                );
            }
        }
        println!(
            "  verified   : {} responses bit-identical to the in-process path, zero deviation ✓{}",
            cfg.requests,
            if pipelined.is_empty() {
                String::new()
            } else {
                format!(" ({} pipelined passes included)", pipelined.len())
            }
        );
        Some(true)
    } else {
        None
    };

    write_bench_net_json(&report, &stats, verified, fault_overhead, &sweep, &pipelined, None);

    if args.has_flag("shutdown") {
        ctl.shutdown()?;
        println!("sent shutdown; server drained and acked");
    }
    export_trace(trace_out.as_deref());
    Ok(())
}

/// One worker child process owned by the cluster bench harness: the
/// `newton worker` subprocess plus the addresses it bound. A chaos
/// `Restart` revives it on the exact same ports, because the coordinator
/// re-dials the address it already knows.
struct WorkerProc {
    child: std::process::Child,
    addr: String,
    admin: String,
    alive: bool,
}

impl WorkerProc {
    /// SIGKILL and reap; idempotent.
    fn kill(&mut self) {
        if self.alive {
            let _ = self.child.kill();
            let _ = self.child.wait();
            self.alive = false;
        }
    }
}

/// Spawn one `newton worker` child and wait for its port files. On a
/// restart the worker must rebind the exact ports it had, which can
/// transiently fail while the dead process's socket drains — a child that
/// exits before writing its port files is respawned after a short pause.
fn spawn_worker_proc(
    exe: &std::path::Path,
    dir: &std::path::Path,
    i: usize,
    engine_seed: u64,
    adc: &str,
    addr: &str,
    admin: &str,
) -> Result<WorkerProc> {
    let pf = dir.join(format!("worker{i}.port"));
    let af = dir.join(format!("worker{i}.admin"));
    for _attempt in 0..40 {
        let _ = std::fs::remove_file(&pf);
        let _ = std::fs::remove_file(&af);
        let mut child = std::process::Command::new(exe)
            .args([
                "worker",
                "--seed",
                &engine_seed.to_string(),
                "--adc",
                adc,
                "--addr",
                addr,
                "--admin-addr",
                admin,
                "--port-file",
                pf.to_str().unwrap_or_default(),
                "--admin-port-file",
                af.to_str().unwrap_or_default(),
            ])
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .spawn()?;
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        loop {
            if let (Ok(a), Ok(ad)) = (std::fs::read_to_string(&pf), std::fs::read_to_string(&af)) {
                if !a.is_empty() && !ad.is_empty() {
                    return Ok(WorkerProc { child, addr: a, admin: ad, alive: true });
                }
            }
            if matches!(child.try_wait(), Ok(Some(_))) {
                // exited before binding (old port still draining) — respawn
                break;
            }
            if std::time::Instant::now() >= deadline {
                let _ = child.kill();
                let _ = child.wait();
                bail!("worker {i} did not come up on {addr} within 20s");
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        std::thread::sleep(Duration::from_millis(250));
    }
    bail!("worker {i} could not rebind {addr} after repeated attempts")
}

/// `bench-net --cluster`: the sharded-serving failover benchmark. Owns a
/// fleet of `newton worker` child processes, serves them through an
/// in-process cluster coordinator, runs a clean pass, then replays the
/// identical request stream while a seeded [`ChaosPlan`] kills, stalls,
/// and restarts workers mid-load. Replies must stay bit-identical to the
/// single-process golden path through every schedule; `BENCH_net.json`
/// gains the `cluster_failover_*` series (worst recovery latency,
/// re-shard count, chaos overhead vs the clean sequential pass).
fn cmd_bench_net_cluster(args: &Args) -> Result<()> {
    let trace_out = init_tracing(args)?;
    let adc = args.get_or("adc", "exact");
    let kind = AdcKind::parse(adc).map_err(|e| anyhow!("{e}"))?;
    let engine_seed = args.get_usize("engine-seed", 0) as u64;
    let n_workers = args.get_usize("workers", 3);
    let requests = args.get_usize("requests", 48);
    let batch = args.get_usize("batch", 8);
    let concurrency = args.get_usize("concurrency", 4);
    let stream_seed = args.get_usize("seed", 0) as u64;
    let deadline = Duration::from_millis(args.get_usize("deadline-ms", 60_000) as u64);
    if n_workers == 0 || requests < 2 || concurrency == 0 {
        bail!("--cluster needs --workers >= 1, --requests >= 2, --concurrency >= 1");
    }

    // fleet of real worker processes on ephemeral ports
    let exe = std::env::current_exe()?;
    let dir = std::env::temp_dir().join(format!("newton-cluster-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    println!("bench-net --cluster: spawning {n_workers} worker processes (seed {engine_seed})");
    let mut fleet: Vec<WorkerProc> = Vec::new();
    for i in 0..n_workers {
        fleet.push(spawn_worker_proc(
            &exe,
            &dir,
            i,
            engine_seed,
            adc,
            "127.0.0.1:0",
            "127.0.0.1:0",
        )?);
    }
    let endpoints: Vec<(String, Option<String>)> =
        fleet.iter().map(|w| (w.addr.clone(), Some(w.admin.clone()))).collect();

    // in-process coordinator plus the ordinary client-facing endpoint
    let mut ccfg = ClusterConfig::new(engine_seed, kind, batch).map_err(|e| anyhow!("{e}"))?;
    if let Some(ms) = args.get("hop-deadline-ms") {
        let ms: u64 = ms.parse().map_err(|_| anyhow!("--hop-deadline-ms wants a number"))?;
        ccfg.hop_deadline = Duration::from_millis(ms.max(1));
    }
    ccfg.link_fault_rate = args.get_f64("link-fault-rate", 0.0);
    ccfg.link_fault_seed = args.get_usize("link-fault-seed", 0) as u64;
    if !(0.0..=1.0).contains(&ccfg.link_fault_rate) {
        bail!("--link-fault-rate must be in [0, 1]");
    }
    newton::obs::ledger::set_enabled(!args.has_flag("no-ledger"));
    let engine =
        ClusterEngine::connect(ccfg, &endpoints).map_err(|e| anyhow!("cluster connect: {e}"))?;
    let heartbeats = engine.spawn_heartbeats();
    let server = NetServer::start(
        engine.clone(),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_inflight: args.get_usize("max-inflight", 64),
            batch_wait: Duration::from_millis(2),
            timeouts: net::Timeouts::default(),
            admin_addr: None,
            cost_reports: false,
        },
    )?;
    let addr = server.local_addr().to_string();
    println!("cluster up: {}", newton::net::Engine::describe(engine.as_ref()));

    // one request stream shared by every pass and by the golden reference
    let images: Vec<Vec<i32>> =
        (0..requests).map(|i| net::bench_image(stream_seed, i)).collect();
    let want = GoldenServer::replicated(engine_seed, AdcKind::Exact, 1, batch).infer(&images);

    // clean pass 1: the standard concurrent load generator — primary
    // BenchReport for the JSON latency keys
    let mut cfg = BenchConfig::new(&addr);
    cfg.requests = requests;
    cfg.concurrency = concurrency;
    cfg.seed = stream_seed;
    cfg.deadline = deadline;
    let mut report = net::load_generate(&cfg)?;
    println!(
        "clean pass: {} requests in {:.2}s ({:.1} req/s)",
        report.requests, report.wall_s, report.throughput_rps
    );

    // clean pass 2: sequential, through the same retrying client the
    // chaos pass uses, so the overhead ratio compares like against like
    let policy = net::RetryPolicy {
        deadline,
        ..net::RetryPolicy::default()
    };
    let mut rc = net::RetryClient::new(&addr, policy, stream_seed);
    let t_clean = std::time::Instant::now();
    for (i, img) in images.iter().enumerate() {
        rc.infer_timed(i as u64, img)
            .map_err(|e| anyhow!("clean sequential pass, request {i}: {e}"))?;
    }
    let clean_seq_s = t_clean.elapsed().as_secs_f64().max(1e-9);

    // chaos pass: replay the stream sequentially under the seeded
    // schedule, so event positions in the request stream are exact. A
    // Stall pauses the request stream (the coordinator keeps heartbeating
    // underneath); Kill/Restart act on the real child processes.
    let mut plan = match args.get("kill-worker") {
        Some(w) => {
            let w: usize =
                w.parse().map_err(|_| anyhow!("--kill-worker wants a worker index"))?;
            if w >= n_workers {
                bail!("--kill-worker {w} out of range for {n_workers} workers");
            }
            let at = args.get_usize("kill-at", requests / 2).max(1) as u64;
            ChaosPlan::kill_one(w, at)
        }
        None => ChaosPlan::seeded(
            args.get_usize("chaos-seed", 7) as u64,
            n_workers,
            requests as u64,
            args.get_usize("chaos-events", 4),
        ),
    };
    println!(
        "chaos pass: {} scheduled events (seed {})",
        plan.events().len(),
        plan.seed()
    );
    let reshards_before = engine.reshard_count();
    let policy = net::RetryPolicy {
        deadline,
        ..net::RetryPolicy::default()
    };
    let mut rc = net::RetryClient::new(&addr, policy, stream_seed.wrapping_add(1));
    let mut chaos_logits: Vec<Vec<i32>> = Vec::with_capacity(requests);
    let mut kill_pending: Option<std::time::Instant> = None;
    let mut recovery_worst_ms = 0.0f64;
    let mut kills = 0u64;
    let t_chaos = std::time::Instant::now();
    for (i, img) in images.iter().enumerate() {
        for ev in plan.take_due(i as u64) {
            match ev.action {
                ChaosAction::Kill => {
                    if fleet[ev.worker].alive {
                        fleet[ev.worker].kill();
                        kills += 1;
                        if kill_pending.is_none() {
                            kill_pending = Some(std::time::Instant::now());
                        }
                        println!("  chaos: SIGKILL worker {} before request {i}", ev.worker);
                    }
                }
                ChaosAction::Stall(ms) => {
                    println!("  chaos: stall {ms} ms before request {i}");
                    std::thread::sleep(Duration::from_millis(ms));
                }
                ChaosAction::Restart => {
                    if !fleet[ev.worker].alive {
                        let (a, ad) =
                            (fleet[ev.worker].addr.clone(), fleet[ev.worker].admin.clone());
                        fleet[ev.worker] =
                            spawn_worker_proc(&exe, &dir, ev.worker, engine_seed, adc, &a, &ad)?;
                        println!("  chaos: restarted worker {} on {a} before request {i}", ev.worker);
                    }
                }
            }
        }
        let (reply, _us) = rc
            .infer_timed(i as u64, img)
            .map_err(|e| anyhow!("chaos pass, request {i}: {e}"))?;
        if let Some(k) = kill_pending.take() {
            recovery_worst_ms = recovery_worst_ms.max(k.elapsed().as_secs_f64() * 1e3);
        }
        chaos_logits.push(reply.logits);
    }
    let chaos_s = t_chaos.elapsed().as_secs_f64().max(1e-9);
    let fault_overhead = chaos_s / clean_seq_s;
    let reshards = engine.reshard_count().saturating_sub(reshards_before);

    // bit-exactness across every schedule is the whole point of the
    // generation protocol; check it on both passes, hard-fail only under
    // --expect-exact so exploratory runs still report
    let clean_ok = report.logits == want && report.worst_abs_err == 0;
    let chaos_ok = chaos_logits == want;
    if args.has_flag("expect-exact") {
        if !clean_ok {
            bail!("--cluster --expect-exact: clean pass NOT bit-identical to the golden path");
        }
        if !chaos_ok {
            bail!("--cluster --expect-exact: chaos pass NOT bit-identical to the golden path");
        }
        println!("  verified   : both passes bit-identical to the in-process golden path ✓");
    } else if !(clean_ok && chaos_ok) {
        println!("  verified   : FAILED — replies deviate from the in-process golden path");
    }
    let verified = Some(clean_ok && chaos_ok);
    println!(
        "  failover   : {kills} kills, {reshards} re-shards, worst recovery {:.1} ms, \
         chaos overhead {:.2}x{}",
        recovery_worst_ms,
        fault_overhead,
        if newton::net::Engine::degraded(engine.as_ref()) {
            " — DEGRADED (fallback engine)"
        } else {
            ""
        }
    );

    // server-side view, JSON, then drain everything we own
    let sweep = vec![(concurrency, report.p50_us, report.p99_us, report.p999_us)];
    let mut ctl = net::Client::connect(&addr)?;
    let stats = ctl.stats()?;
    if report.per_replica.len() < stats.per_replica.len() {
        report.per_replica.resize(stats.per_replica.len(), 0);
    }
    write_bench_net_json(
        &report,
        &stats,
        verified,
        Some(fault_overhead),
        &sweep,
        &[],
        Some((recovery_worst_ms, reshards, fault_overhead)),
    );
    ctl.shutdown()?;
    let stats = server.join();
    engine.stop();
    let _ = heartbeats.join();
    engine.shutdown_workers();
    for w in &mut fleet {
        w.kill();
    }
    let _ = std::fs::remove_dir_all(&dir);
    print_net_stats(&stats);
    export_trace(trace_out.as_deref());
    Ok(())
}

fn write_bench_net_json(
    r: &net::BenchReport,
    server: &net::StatsSnapshot,
    verified: Option<bool>,
    fault_overhead: Option<f64>,
    sweep: &[(usize, u64, u64, u64)],
    pipelined: &[net::PipelinedReport],
    cluster: Option<(f64, u64, f64)>,
) {
    let per_replica = r
        .per_replica
        .iter()
        .map(|n| n.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    let health = server
        .health
        .iter()
        .map(|b| b.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    // one exact-microsecond triple per swept lane count (first = primary)
    let mut sweep_keys = String::new();
    for (c, p50, p99, p999) in sweep {
        sweep_keys.push_str(&format!(
            "  \"latency_p50_us_c{c}\": {p50},\n  \"latency_p99_us_c{c}\": {p99},\n  \
             \"latency_p999_us_c{c}\": {p999},\n"
        ));
    }
    // one throughput + exact-microsecond latency block per pipelined
    // depth (bench-net --pipeline-depth): single tagged connection,
    // window-bounded out-of-order completion
    let mut pipelined_keys = String::new();
    for p in pipelined {
        let d = p.depth;
        pipelined_keys.push_str(&format!(
            "  \"pipelined_throughput_d{d}\": {:.3},\n  \"latency_p50_us_d{d}\": {},\n  \
             \"latency_p99_us_d{d}\": {},\n  \"latency_p999_us_d{d}\": {},\n",
            p.throughput_rps, p.p50_us, p.p99_us, p.p999_us
        ));
    }
    // cluster failover series (bench-net --cluster only): worst
    // kill-to-next-reply latency, re-shards during the chaos pass, and
    // chaos wall time over the clean sequential pass
    let cluster_keys = cluster.map_or(String::new(), |(recovery_ms, reshards, overhead)| {
        format!(
            "  \"cluster_failover_recovery_ms\": {recovery_ms:.3},\n  \
             \"cluster_failover_reshards\": {reshards},\n  \
             \"cluster_failover_fault_overhead\": {overhead:.3},\n"
        )
    });
    let metrics_json = server
        .metrics
        .iter()
        .map(|(k, v)| format!("\"{k}\": {v}"))
        .collect::<Vec<_>>()
        .join(", ");
    // hardware-cost headline keys, derived client-side from the Stats
    // frame's ledger.* counters divided by requests served (all zeros
    // when the server runs --no-ledger)
    let lookup = |name: &str| {
        server
            .metrics
            .iter()
            .find(|(k, _)| k == name)
            .map_or(0u64, |&(_, v)| v)
    };
    let served_f = (server.served as f64).max(1.0);
    let adc_ops_per_infer = lookup("ledger.adc_ops") as f64 / served_f;
    let energy_pj_per_infer = lookup("ledger.energy_pj") as f64 / served_f;
    let slice_total = lookup("ledger.slice_iters_executed")
        + lookup("ledger.slice_iters_folded")
        + lookup("ledger.slice_iters_skipped");
    let skipped_slice_frac = if slice_total > 0 {
        lookup("ledger.slice_iters_skipped") as f64 / slice_total as f64
    } else {
        0.0
    };
    let json = format!(
        "{{\n  \"requests\": {},\n  \"concurrency\": {},\n  \"wall_s\": {:.6},\n  \
         \"throughput_rps\": {:.3},\n  \"p50_ms\": {:.3},\n  \"p99_ms\": {:.3},\n  \
         \"max_ms\": {:.3},\n{}{}{}  \"busy_retries\": {},\n  \"fault_retries\": {},\n  \
         \"reconnects\": {},\n  \"injected_faults\": {},\n  \"fault_overhead_b8\": {},\n  \
         \"worst_abs_err\": {},\n  \
         \"adc_ops_per_infer\": {adc_ops_per_infer:.3},\n  \
         \"skipped_slice_frac\": {skipped_slice_frac:.6},\n  \
         \"energy_pj_per_infer\": {energy_pj_per_infer:.3},\n  \
         \"verified_exact\": {},\n  \"per_replica\": [{}],\n  \"server\": {{\n    \
         \"served\": {},\n    \"busy\": {},\n    \"proto_errors\": {},\n    \
         \"batches\": {},\n    \"batch_fill\": {:.4},\n    \"p50_us\": {},\n    \
         \"p99_us\": {},\n    \"p999_us\": {},\n    \"reruns\": {},\n    \"quarantines\": {},\n    \
         \"degraded\": {},\n    \"health\": [{}],\n    \"metrics\": {{{}}}\n  }}\n}}\n",
        r.requests,
        r.concurrency,
        r.wall_s,
        r.throughput_rps,
        r.p50_ms,
        r.p99_ms,
        r.max_ms,
        sweep_keys,
        pipelined_keys,
        cluster_keys,
        r.busy_retries,
        r.fault_retries,
        r.reconnects,
        r.injected_faults,
        fault_overhead.map_or("null".to_string(), |x| format!("{x:.3}")),
        r.worst_abs_err,
        match verified {
            Some(true) => "true",
            Some(false) => "false",
            None => "null",
        },
        per_replica,
        server.served,
        server.busy,
        server.proto_errors,
        server.batches,
        server.batch_fill,
        server.p50_us,
        server.p99_us,
        server.p999_us,
        server.reruns,
        server.quarantines,
        server.degraded,
        health,
        metrics_json,
    );
    match std::fs::write("BENCH_net.json", &json) {
        Ok(()) => println!("wrote BENCH_net.json"),
        Err(e) => println!("could not write BENCH_net.json: {e}"),
    }
}

fn cmd_help() -> Result<()> {
    println!("newton <command> [--flags]");
    for (name, desc) in cli::SUBCOMMANDS {
        println!("  {name:12} {desc}");
    }
    Ok(())
}

/// Work-stealing executor stress smoke (scripts/verify.sh): oversubscribed
/// pool, 10x-skewed job mix, asserts completion + bit-determinism inside
/// `sched::stress`, and that stealing actually moved work.
fn cmd_sched_stress(args: &Args) -> Result<()> {
    let jobs = args.get_usize("jobs", 512);
    let oversub = args.get_usize("oversub", 4);
    let heavy = args.get_usize("heavy-spins", 2_000_000);
    println!(
        "sched stress: {jobs} jobs (front-loaded first tenth cost 10x), {oversub}x oversubscribed pool"
    );
    let t0 = std::time::Instant::now();
    let stats = newton::sched::stress(jobs, oversub, heavy);
    let wall = t0.elapsed();
    let min = stats.executed.iter().min().copied().unwrap_or(0);
    let max = stats.executed.iter().max().copied().unwrap_or(0);
    println!("  workers  : {}", stats.workers);
    println!("  steals   : {}", stats.steals);
    println!(
        "  executed : {min}..{max} jobs per worker (imbalance {:.2}x)",
        stats.imbalance()
    );
    if stats.steals == 0 {
        bail!("stress run saw zero steals on a 10x-skewed mix");
    }
    println!("sched stress OK ({:.2}s): deterministic, all jobs completed", wall.as_secs_f64());
    Ok(())
}

fn cmd_export(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get_or("out", "results"));
    let files = newton::metrics::export::export_all(&dir)?;
    println!("wrote {} CSV series to {dir:?}:", files.len());
    for f in files {
        println!("  {f}");
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("subcommands:");
    for (name, desc) in cli::SUBCOMMANDS {
        println!("  {name:12} {desc}");
    }
    println!("workloads:");
    for n in workloads::suite() {
        println!(
            "  {:10} {:3} layers  {:6.1}M weights  {:7.2}G MACs/image",
            n.name,
            n.layers.len(),
            n.total_weights() as f64 / 1e6,
            n.total_macs() as f64 / 1e9
        );
    }
    println!("  newton-mini (serving demo model)");
    if let Ok(rt) = Runtime::new(&default_artifacts_dir()) {
        println!("artifacts:");
        for a in rt.artifact_names() {
            println!("  {a}");
        }
    } else {
        println!("artifacts: none (run `make artifacts`)");
    }
    Ok(())
}
