//! Layer -> IMA/tile mapping (paper §III-B1, §III-C, Figs 6, 7, 10, 15).
//!
//! The mapper decides, per layer: the replication factor needed to balance
//! the inter-tile pipeline, how many IMAs the (replicated) layer occupies,
//! how under-utilised those IMAs are, and how much eDRAM buffering the tile
//! hosting it needs. Two policies:
//!
//! * **Unconstrained (ISAAC)** — crossbars from different layers can share
//!   an IMA, so utilisation is ~perfect, but every IMA's HTree and buffers
//!   must be provisioned for the worst case (the cost shows up in
//!   `TileConfig::in_streams = 8` and the 64 KB buffer).
//! * **Constrained (Newton)** — an IMA serves one layer and at most
//!   `ima.inputs` inputs; the HTree collapses to a single shared stream and
//!   partial sums reduce at its junctions, at the price of fragmentation
//!   (Fig 10's under-utilisation).
//!
//! Buffering (Figs 6/7/15): a conv layer in steady state holds
//! `((k-1)*W + k) * Cin` input values; replicated copies co-located in a
//! tile *share* that buffer (Fig 6d), and spreading every layer across many
//! tiles (Fig 7b) moves the per-tile requirement from the worst case to the
//! average case.
//!
//! The same constrained-vs-worst-case-provisioning idea recurs on the
//! serving path: [`StagePolicy`]/[`StageMap`] record which pipeline
//! *stages* may share a serving replica (Newton's conv-tile /
//! classifier-tile split, §III-B2) for the pipelined stage scheduler in
//! [`crate::coordinator::pipeline`], so replica-sharing rules live here as
//! an explicit policy instead of ad-hoc conditionals in the scheduler.

use crate::config::{ImaConfig, XbarParams};
use crate::workloads::{Layer, Network};

/// Mapping policy knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MappingPolicy {
    /// Newton's single-layer-per-IMA, <=128-input constraint.
    pub constrained: bool,
    /// Spread layers across tiles to average buffer demand (Fig 7b).
    pub spread_layers: bool,
}

impl MappingPolicy {
    pub fn isaac() -> Self {
        MappingPolicy {
            constrained: false,
            spread_layers: false,
        }
    }

    pub fn newton() -> Self {
        MappingPolicy {
            constrained: true,
            spread_layers: true,
        }
    }
}

/// Per-layer allocation result.
#[derive(Clone, Debug)]
pub struct LayerAlloc {
    pub layer: Layer,
    /// Pipeline-balance replication (1 for the slowest layer).
    pub replication: usize,
    /// IMAs allocated for all copies.
    pub imas: usize,
    /// Fraction of allocated IMA capacity holding real weights.
    pub utilization: f64,
    /// Steady-state input buffer for this layer (bytes, shared by copies).
    pub buffer_bytes: f64,
    /// Inter-layer traffic out of this layer per image (bytes).
    pub traffic_bytes: usize,
}

/// Whole-network mapping.
#[derive(Clone, Debug)]
pub struct Mapping {
    pub allocs: Vec<LayerAlloc>,
    pub policy: MappingPolicy,
    /// IMAs per tile used to convert IMA counts into tile counts.
    pub imas_per_tile: usize,
    pub conv_imas: usize,
    pub fc_imas: usize,
}

/// Bytes per neuron value on the wire / in buffers (16-bit fixed point).
pub const BYTES_PER_NEURON: usize = 2;

fn ima_capacity(ima: &ImaConfig) -> usize {
    ima.inputs * ima.outputs
}

impl Mapping {
    /// Map `net` onto IMAs of shape `ima` (tile granularity `imas_per_tile`).
    pub fn build(
        net: &Network,
        ima: &ImaConfig,
        _xbar: &XbarParams,
        policy: MappingPolicy,
        imas_per_tile: usize,
    ) -> Mapping {
        // Replication balances conv layers to the slowest layer's rate
        // (out_pixels per image; the layer producing the fewest pixels sets
        // the pipeline period).
        let min_pixels = net
            .conv_layers()
            .map(|l| l.out_hw() * l.out_hw())
            .min()
            .unwrap_or(1)
            .max(1);

        let mut allocs = Vec::new();
        let mut conv_imas = 0usize;
        let mut fc_imas = 0usize;
        for l in &net.layers {
            let Some((rows, cols)) = l.matrix() else {
                continue;
            };
            let replication = if l.is_conv() {
                (l.out_hw() * l.out_hw()).div_ceil(min_pixels)
            } else {
                1 // FC layers are off the critical path (§III-B2)
            };
            let used_cells = rows * cols * replication;
            let imas = if policy.constrained {
                // replicated copies of the SAME layer may share an IMA's
                // output columns (the constraint forbids sharing across
                // *different* layers, §III-C), so the copies pack together
                rows.div_ceil(ima.inputs) * (cols * replication).div_ceil(ima.outputs)
            } else {
                // ISAAC packs crossbars densely across layer boundaries
                used_cells.div_ceil(ima_capacity(ima))
            };
            let utilization = used_cells as f64 / (imas * ima_capacity(ima)) as f64;
            let buffer_bytes = match *l {
                Layer::Conv {
                    k, cin, in_hw, ..
                } => (((k - 1) * in_hw + k) * cin * BYTES_PER_NEURON) as f64,
                Layer::Fc { inputs, .. } => {
                    // inputs seen once, discarded right after (§III-B2)
                    (inputs * BYTES_PER_NEURON) as f64
                }
                Layer::Rnn { inputs, .. } => {
                    // one timestep's input + the recurrent state
                    (inputs * BYTES_PER_NEURON) as f64
                }
                Layer::Pool { .. } => 0.0,
            };
            if l.is_fc() {
                fc_imas += imas;
            } else {
                conv_imas += imas;
            }
            allocs.push(LayerAlloc {
                layer: *l,
                replication,
                imas,
                utilization,
                buffer_bytes,
                traffic_bytes: l.out_neurons() * BYTES_PER_NEURON,
            });
        }
        Mapping {
            allocs,
            policy,
            imas_per_tile,
            conv_imas,
            fc_imas,
        }
    }

    /// Conv tiles needed (IMA granularity rounded up to tiles).
    pub fn conv_tiles(&self) -> usize {
        self.conv_imas.div_ceil(self.imas_per_tile).max(1)
    }

    pub fn fc_tiles(&self) -> usize {
        self.fc_imas.div_ceil(self.imas_per_tile)
    }

    /// Capacity-weighted crossbar under-utilisation (Fig 10's metric),
    /// over conv layers.
    pub fn underutilization(&self) -> f64 {
        let (mut used, mut alloc) = (0.0f64, 0.0f64);
        for a in self.allocs.iter().filter(|a| a.layer.is_conv()) {
            alloc += a.imas as f64;
            used += a.imas as f64 * a.utilization;
        }
        if alloc == 0.0 {
            return 0.0;
        }
        1.0 - used / alloc
    }

    /// Worst-case per-tile buffer under this policy, bytes (Fig 15).
    ///
    /// Without spreading, a tile is dedicated to (part of) one layer: its
    /// buffer must hold that layer's working set, divided across the tiles
    /// the layer's *input splits* span (Fig 6a: split inputs are not
    /// replicated). With spreading, every tile hosts a proportional slice
    /// of every layer, so the requirement is the network average.
    pub fn buffer_per_tile_bytes(&self) -> f64 {
        let conv: Vec<&LayerAlloc> = self
            .allocs
            .iter()
            .filter(|a| a.layer.is_conv() || a.layer.is_fc())
            .collect();
        if conv.is_empty() {
            return 0.0;
        }
        if self.policy.spread_layers {
            let total: f64 = conv.iter().map(|a| a.buffer_bytes).sum();
            let tiles = (self.conv_imas + self.fc_imas).div_ceil(self.imas_per_tile).max(1);
            total / tiles as f64
        } else {
            conv.iter()
                .map(|a| {
                    let tiles_for_layer = a.imas.div_ceil(self.imas_per_tile).max(1);
                    // only splits along the *input* dimension reduce the
                    // per-tile buffer (Fig 6a); replication shares it
                    let row_splits = match a.layer.matrix() {
                        Some((rows, _)) => rows.div_ceil(
                            self.imas_per_tile * 128, // inputs a tile can host
                        ),
                        None => 1,
                    }
                    .clamp(1, tiles_for_layer);
                    a.buffer_bytes / row_splits as f64
                })
                .fold(0.0, f64::max)
        }
    }

    /// Total inter-layer traffic per image, bytes.
    pub fn traffic_per_image(&self) -> usize {
        self.allocs.iter().map(|a| a.traffic_bytes).sum()
    }
}

// ---- pipelined stage scheduling policy ------------------------------------

/// Role of one serving-pipeline stage, mirroring Newton's tile
/// specialisation (§III-B2): conv tiles run their ADCs at full rate,
/// classifier tiles are capacity-bound and differently provisioned — so
/// the two are distinct hardware and a stage's role decides which replicas
/// it may land on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageRole {
    Conv,
    Classifier,
}

/// Replica-sharing constraints for pipelined stage scheduling
/// ([`crate::coordinator::pipeline`]): which stages may co-reside on one
/// serving replica, and whether pipeline jobs draw their forward scratch
/// from a per-replica pool. One policy value replaces what would otherwise
/// be scattered conditionals in the scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StagePolicy {
    /// Conv stages may pack onto one replica when replicas are scarce
    /// (they serialise there — correctness-neutral, overlap shrinks).
    pub share_conv: bool,
    /// A conv stage may share a replica with the classifier tail. Newton
    /// forbids this: conv and classifier tiles are distinct hardware.
    pub share_mixed: bool,
    /// Pipeline jobs borrow one [`crate::xbar::cnn::ForwardScratch`] per
    /// replica from a shared pool instead of allocating per wave (a
    /// replica runs at most one stage at a time, so per-replica pooling is
    /// race-free by construction).
    pub pooled_scratch: bool,
}

impl StagePolicy {
    /// Newton's constraints: conv stages may pack together, the classifier
    /// tail keeps a dedicated replica, scratch is pooled per replica.
    pub fn newton() -> Self {
        StagePolicy {
            share_conv: true,
            share_mixed: false,
            pooled_scratch: true,
        }
    }

    /// ISAAC-style worst-case provisioning: any stage anywhere (including
    /// everything on a single replica, which degenerates to the sequential
    /// forward).
    pub fn unconstrained() -> Self {
        StagePolicy {
            share_conv: true,
            share_mixed: true,
            pooled_scratch: true,
        }
    }
}

/// A stage → replica assignment honouring a [`StagePolicy`]. Built once per
/// served model, then consulted by the pipelined scheduler on every wave.
///
/// # Examples
///
/// ```
/// use newton::mapping::{StageMap, StagePolicy};
///
/// // newton-mini: 3 conv stages + classifier tail over 2 replicas —
/// // convs pack on replica 0, the classifier keeps replica 1 to itself
/// let map = StageMap::build(3, 2, StagePolicy::newton()).unwrap();
/// assert_eq!(map.assignment, vec![0, 0, 0, 1]);
/// assert_eq!(map.concurrency(), 2);
///
/// // one replica cannot satisfy Newton's conv/classifier isolation
/// assert!(StageMap::build(3, 1, StagePolicy::newton()).is_err());
/// assert!(StageMap::build(3, 1, StagePolicy::unconstrained()).is_ok());
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageMap {
    /// `assignment[s]` = replica that executes stage `s`; stages
    /// `0..assignment.len()-1` are convs, the last is the classifier.
    pub assignment: Vec<usize>,
    /// Replicas the assignment draws from (some may stay idle when there
    /// are more replicas than stages).
    pub n_replicas: usize,
    /// The policy the assignment was built under.
    pub policy: StagePolicy,
}

impl StageMap {
    /// Assign `n_conv + 1` stages (convs then the classifier tail) onto
    /// `n_replicas` replicas under `policy`. Fails when the policy's
    /// sharing constraints cannot be met with this replica count.
    pub fn build(
        n_conv: usize,
        n_replicas: usize,
        policy: StagePolicy,
    ) -> Result<StageMap, String> {
        if n_replicas == 0 {
            return Err("stage map needs at least one replica".to_string());
        }
        let n_stages = n_conv + 1;
        let assignment = if n_replicas >= n_stages {
            // one replica per stage: every wave runs fully overlapped
            (0..n_stages).collect()
        } else if policy.share_mixed {
            // unconstrained packing: round-robin everything
            if !policy.share_conv && n_replicas < n_stages {
                return Err(format!(
                    "{n_stages} stages need {n_stages} replicas when conv stages may not share (have {n_replicas})"
                ));
            }
            (0..n_stages).map(|s| s % n_replicas).collect()
        } else {
            // Newton: the classifier tail owns the last replica, convs
            // spread over the rest
            if n_replicas < 2 {
                return Err(
                    "conv/classifier isolation needs >= 2 replicas (or an unconstrained policy)"
                        .to_string(),
                );
            }
            let conv_replicas = n_replicas - 1;
            if !policy.share_conv && conv_replicas < n_conv {
                return Err(format!(
                    "{n_conv} conv stages need {} replicas when conv stages may not share (have {n_replicas})",
                    n_conv + 1
                ));
            }
            let mut a: Vec<usize> = (0..n_conv).map(|s| s % conv_replicas).collect();
            a.push(n_replicas - 1);
            a
        };
        Ok(StageMap {
            assignment,
            n_replicas,
            policy,
        })
    }

    /// [`Self::build`] over a *subset* of a pool's replicas — the health
    /// machinery re-derives stage placement around quarantined replicas
    /// without shrinking the pool itself. `usable` lists the eligible
    /// replica indices (ascending, non-empty, all `< n_pool`); the
    /// assignment is built as if those were the whole pool, then remapped
    /// onto the real indices, while `n_replicas` stays `n_pool` so the
    /// map remains valid against the full pool's scratch slots.
    ///
    /// # Examples
    ///
    /// ```
    /// use newton::mapping::{StageMap, StagePolicy};
    ///
    /// // replica 1 of a 3-replica pool is quarantined: convs pack on 0,
    /// // the classifier takes 2, nothing lands on 1
    /// let map = StageMap::build_over(3, &[0, 2], 3, StagePolicy::newton()).unwrap();
    /// assert_eq!(map.assignment, vec![0, 0, 0, 2]);
    /// assert_eq!(map.n_replicas, 3);
    /// ```
    pub fn build_over(
        n_conv: usize,
        usable: &[usize],
        n_pool: usize,
        policy: StagePolicy,
    ) -> Result<StageMap, String> {
        assert!(
            usable.windows(2).all(|w| w[0] < w[1]),
            "usable replica list must be ascending and duplicate-free"
        );
        assert!(
            usable.iter().all(|&r| r < n_pool),
            "usable replica outside the pool"
        );
        let inner = Self::build(n_conv, usable.len(), policy)?;
        Ok(StageMap {
            assignment: inner.assignment.iter().map(|&r| usable[r]).collect(),
            n_replicas: n_pool,
            policy,
        })
    }

    /// Replica assigned to stage `s`.
    pub fn replica_of(&self, s: usize) -> usize {
        self.assignment[s]
    }

    /// Distinct replicas actually used — the pipeline's concurrency
    /// ceiling (at most this many stages execute in one wave).
    pub fn concurrency(&self) -> usize {
        let mut seen = vec![false; self.n_replicas];
        for &r in &self.assignment {
            seen[r] = true;
        }
        seen.iter().filter(|&&s| s).count()
    }
}

/// A stage → *shard* assignment for multi-process serving
/// ([`crate::coordinator::cluster`]): the process-level analogue of
/// [`StageMap`], reusing the same [`StagePolicy`] vocabulary one level up.
/// Where a `StageMap` places stages on replicas inside one process, a
/// `ShardMap` places them on worker *processes*, and activations cross a
/// wire at every shard boundary — so assignments are always **contiguous
/// runs of stages**: a batch crosses each inter-shard link exactly once,
/// front to back, and [`Self::segments`] *is* the forwarding plan.
///
/// # Examples
///
/// ```
/// use newton::mapping::{ShardMap, StagePolicy};
///
/// // newton-mini over 3 workers: convs chunk over shards 0-1, the
/// // classifier tail keeps the last shard to itself (§III-B2, one level
/// // up: classifier *processes* are distinct provisioning)
/// let map = ShardMap::build(3, 3, StagePolicy::newton()).unwrap();
/// assert_eq!(map.assignment, vec![0, 1, 1, 2]);
/// assert_eq!(map.segments(), vec![(0, 0, 1), (1, 1, 3), (2, 3, 4)]);
///
/// // a worker died: re-shard over the survivors, pool size kept
/// let map = ShardMap::build_over(3, &[0, 2], 3, StagePolicy::newton()).unwrap();
/// assert_eq!(map.segments(), vec![(0, 0, 3), (2, 3, 4)]);
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMap {
    /// `assignment[s]` = shard that executes stage `s`; stages
    /// `0..assignment.len()-1` are convs, the last is the classifier.
    /// Always a non-decreasing sequence (contiguity invariant).
    pub assignment: Vec<usize>,
    /// Shards the assignment draws from — the worker-pool size; some may
    /// hold no stages (dead or surplus workers).
    pub n_shards: usize,
    /// The policy the assignment was built under.
    pub policy: StagePolicy,
}

impl ShardMap {
    /// Assign `n_conv + 1` stages onto `n_shards` worker shards under
    /// `policy`. Contiguous by construction: shard indices are assigned in
    /// stage order. Fails when the policy cannot be met with this shard
    /// count ([`StagePolicy::newton`] needs >= 2: the classifier tail owns
    /// the last shard alone).
    pub fn build(n_conv: usize, n_shards: usize, policy: StagePolicy) -> Result<ShardMap, String> {
        if n_shards == 0 {
            return Err("shard map needs at least one worker".to_string());
        }
        let n_stages = n_conv + 1;
        let assignment: Vec<usize> = if policy.share_mixed {
            // unconstrained: balanced contiguous chunks over the pool
            let k = n_shards.min(n_stages);
            let mut a = Vec::with_capacity(n_stages);
            for i in 0..k {
                let n = (i + 1) * n_stages / k - i * n_stages / k;
                a.resize(a.len() + n, i);
            }
            a
        } else {
            // Newton: the classifier tail owns the last shard, convs chunk
            // contiguously over the rest
            if n_shards < 2 {
                return Err(
                    "conv/classifier isolation needs >= 2 shards (or an unconstrained policy)"
                        .to_string(),
                );
            }
            let k = (n_shards - 1).min(n_conv.max(1));
            if !policy.share_conv && n_shards - 1 < n_conv {
                return Err(format!(
                    "{n_conv} conv stages need {} shards when conv stages may not share (have {n_shards})",
                    n_conv + 1
                ));
            }
            let mut a: Vec<usize> = Vec::with_capacity(n_stages);
            for i in 0..k {
                let n = (i + 1) * n_conv / k - i * n_conv / k;
                a.resize(a.len() + n, i);
            }
            a.push(n_shards - 1);
            a
        };
        debug_assert!(assignment.windows(2).all(|w| w[0] <= w[1]));
        Ok(ShardMap {
            assignment,
            n_shards,
            policy,
        })
    }

    /// [`Self::build`] over a *subset* of the worker pool — the failover
    /// path: dead workers leave the usable set, stage placement re-derives
    /// over the survivors, and `n_shards` stays the pool size so shard
    /// indices remain stable across re-shards (same contract as
    /// [`StageMap::build_over`]). `usable` must be ascending,
    /// duplicate-free, and within the pool.
    pub fn build_over(
        n_conv: usize,
        usable: &[usize],
        n_pool: usize,
        policy: StagePolicy,
    ) -> Result<ShardMap, String> {
        assert!(
            usable.windows(2).all(|w| w[0] < w[1]),
            "usable shard list must be ascending and duplicate-free"
        );
        assert!(
            usable.iter().all(|&r| r < n_pool),
            "usable shard outside the pool"
        );
        let inner = Self::build(n_conv, usable.len(), policy)?;
        Ok(ShardMap {
            assignment: inner.assignment.iter().map(|&r| usable[r]).collect(),
            n_shards: n_pool,
            policy,
        })
    }

    /// Shard assigned to stage `s`.
    pub fn shard_of(&self, s: usize) -> usize {
        self.assignment[s]
    }

    /// The forwarding plan: `(shard, stage_lo, stage_hi)` per occupied
    /// shard, in stage order, with half-open contiguous stage ranges that
    /// partition `0..n_stages`. A batch visits these left to right, one
    /// wire hop each.
    pub fn segments(&self) -> Vec<(usize, usize, usize)> {
        let mut out: Vec<(usize, usize, usize)> = Vec::new();
        for (s, &shard) in self.assignment.iter().enumerate() {
            match out.last_mut() {
                Some(seg) if seg.0 == shard => seg.2 = s + 1,
                _ => out.push((shard, s, s + 1)),
            }
        }
        out
    }

    /// Distinct shards actually holding stages.
    pub fn occupancy(&self) -> usize {
        self.segments().len()
    }
}

/// Fig 10 sweep entry: average conv under-utilisation across a suite for a
/// given constrained-IMA shape.
pub fn avg_underutilization(
    nets: &[Network],
    ima: &ImaConfig,
    xbar: &XbarParams,
    imas_per_tile: usize,
) -> f64 {
    let vals: Vec<f64> = nets
        .iter()
        .map(|n| {
            Mapping::build(n, ima, xbar, MappingPolicy::newton(), imas_per_tile)
                .underutilization()
        })
        .collect();
    crate::util::mean(&vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn newton_ima() -> ImaConfig {
        ImaConfig::newton_default()
    }

    fn build(net: &Network, policy: MappingPolicy) -> Mapping {
        Mapping::build(net, &newton_ima(), &XbarParams::default(), policy, 16)
    }

    #[test]
    fn replication_balances_early_layers() {
        let m = build(&workloads::vgg_a(), MappingPolicy::newton());
        let reps: Vec<usize> = m
            .allocs
            .iter()
            .filter(|a| a.layer.is_conv())
            .map(|a| a.replication)
            .collect();
        // early layers replicate more; the deepest conv layer has r = 1
        assert!(reps.first().unwrap() > reps.last().unwrap());
        assert_eq!(*reps.last().unwrap(), 1);
    }

    #[test]
    fn constrained_mapping_wastes_some_crossbars() {
        let m = build(&workloads::alexnet(), MappingPolicy::newton());
        let u = m.underutilization();
        assert!(u > 0.0 && u < 0.5, "{u}");
    }

    #[test]
    fn unconstrained_mapping_packs_tightly() {
        let m = build(&workloads::alexnet(), MappingPolicy::isaac());
        assert!(m.underutilization() < 0.02, "{}", m.underutilization());
    }

    #[test]
    fn default_ima_underutilization_is_about_nine_percent() {
        // paper Fig 10: the 128x256 IMA leaves ~9% of crossbars unused on
        // average across the suite
        let nets = workloads::suite();
        let u = avg_underutilization(&nets, &newton_ima(), &XbarParams::default(), 16);
        assert!((0.03..0.20).contains(&u), "{u}");
    }

    #[test]
    fn bigger_imas_waste_more() {
        let nets = workloads::suite();
        let p = XbarParams::default();
        let small = avg_underutilization(&nets, &newton_ima(), &p, 16);
        let big = ImaConfig {
            inputs: 2048,
            outputs: 1024,
            ..newton_ima()
        };
        let u_big = avg_underutilization(&nets, &big, &p, 16);
        assert!(u_big > small + 0.1, "{u_big} vs {small}");
    }

    #[test]
    fn spreading_reduces_per_tile_buffer() {
        for net in [workloads::vgg_a(), workloads::msra_a()] {
            let worst = build(&net, MappingPolicy::isaac()).buffer_per_tile_bytes();
            let avg = build(&net, MappingPolicy::newton()).buffer_per_tile_bytes();
            assert!(
                avg < 0.6 * worst,
                "{}: avg {avg} vs worst {worst}",
                net.name
            );
        }
    }

    #[test]
    fn isaac_worst_case_buffer_is_around_64kb() {
        // the paper sized ISAAC's buffer at 64 KB for the worst case
        let worst = workloads::suite()
            .iter()
            .map(|n| build(n, MappingPolicy::isaac()).buffer_per_tile_bytes())
            .fold(0.0, f64::max);
        assert!((30_000.0..90_000.0).contains(&worst), "{worst}");
    }

    #[test]
    fn newton_buffer_fits_16kb_at_224(){
        let worst = workloads::suite()
            .iter()
            .map(|n| build(n, MappingPolicy::newton()).buffer_per_tile_bytes())
            .fold(0.0, f64::max);
        assert!(worst <= 16.0 * 1024.0, "{worst}");
    }

    #[test]
    fn buffer_scales_with_image_size() {
        let net = workloads::vgg_a();
        let b224 = build(&net, MappingPolicy::newton()).buffer_per_tile_bytes();
        let b448 = build(&net.with_input_width(448), MappingPolicy::newton())
            .buffer_per_tile_bytes();
        assert!(b448 > 1.5 * b224, "{b448} vs {b224}");
    }

    #[test]
    fn fc_imas_dominate_for_vgg() {
        // VGG's classifier holds ~90% of the weights -> most IMAs are FC
        let m = build(&workloads::vgg_a(), MappingPolicy::newton());
        assert!(m.fc_imas > m.conv_imas, "{} vs {}", m.fc_imas, m.conv_imas);
        assert!(m.fc_tiles() > 0 && m.conv_tiles() > 0);
    }

    #[test]
    fn traffic_counts_all_layers() {
        let m = build(&workloads::alexnet(), MappingPolicy::newton());
        assert!(m.traffic_per_image() > 100_000);
    }

    #[test]
    fn stage_map_gives_each_stage_its_own_replica_when_it_can() {
        let m = StageMap::build(3, 4, StagePolicy::newton()).unwrap();
        assert_eq!(m.assignment, vec![0, 1, 2, 3]);
        assert_eq!(m.concurrency(), 4);
        // surplus replicas stay idle rather than splitting a stage
        let m = StageMap::build(3, 6, StagePolicy::newton()).unwrap();
        assert_eq!(m.assignment, vec![0, 1, 2, 3]);
        assert_eq!(m.concurrency(), 4);
    }

    #[test]
    fn stage_map_isolates_the_classifier_under_newton_policy() {
        for n_replicas in 2..4 {
            let m = StageMap::build(3, n_replicas, StagePolicy::newton()).unwrap();
            let classifier = *m.assignment.last().unwrap();
            assert_eq!(classifier, n_replicas - 1);
            assert!(
                m.assignment[..3].iter().all(|&r| r != classifier),
                "conv stage shares the classifier replica: {:?}",
                m.assignment
            );
            assert!(m.assignment.iter().all(|&r| r < n_replicas));
        }
    }

    #[test]
    fn stage_map_rejects_infeasible_policies() {
        // Newton needs a dedicated classifier replica
        assert!(StageMap::build(3, 1, StagePolicy::newton()).is_err());
        assert!(StageMap::build(3, 0, StagePolicy::newton()).is_err());
        // no sharing at all: one replica per stage or bust
        let rigid = StagePolicy {
            share_conv: false,
            share_mixed: false,
            pooled_scratch: false,
        };
        assert!(StageMap::build(3, 3, rigid).is_err());
        assert_eq!(
            StageMap::build(3, 4, rigid).unwrap().assignment,
            vec![0, 1, 2, 3]
        );
    }

    #[test]
    fn build_over_remaps_onto_the_usable_subset() {
        // full pool healthy: identical to build()
        let m = StageMap::build_over(3, &[0, 1, 2, 3], 4, StagePolicy::newton()).unwrap();
        assert_eq!(m, StageMap::build(3, 4, StagePolicy::newton()).unwrap());
        // middle replica quarantined: assignment avoids it, pool size kept
        let m = StageMap::build_over(3, &[0, 2, 3], 4, StagePolicy::newton()).unwrap();
        assert_eq!(m.assignment, vec![0, 2, 0, 3]);
        assert_eq!(m.n_replicas, 4);
        assert!(!m.assignment.contains(&1));
        // down to one usable replica: newton infeasible, unconstrained packs
        assert!(StageMap::build_over(3, &[2], 4, StagePolicy::newton()).is_err());
        let m = StageMap::build_over(3, &[2], 4, StagePolicy::unconstrained()).unwrap();
        assert_eq!(m.assignment, vec![2, 2, 2, 2]);
        assert_eq!(m.concurrency(), 1);
        // no usable replicas is a policy error, not a panic
        assert!(StageMap::build_over(3, &[], 4, StagePolicy::unconstrained()).is_err());
    }

    #[test]
    fn unconstrained_stage_map_packs_round_robin() {
        let m = StageMap::build(3, 2, StagePolicy::unconstrained()).unwrap();
        assert_eq!(m.assignment, vec![0, 1, 0, 1]);
        assert_eq!(m.concurrency(), 2);
        let m = StageMap::build(3, 1, StagePolicy::unconstrained()).unwrap();
        assert_eq!(m.assignment, vec![0, 0, 0, 0]);
        assert_eq!(m.concurrency(), 1);
    }

    #[test]
    fn shard_map_is_contiguous_and_partitions_the_stages() {
        for n_shards in 1..6 {
            for policy in [StagePolicy::newton(), StagePolicy::unconstrained()] {
                let Ok(m) = ShardMap::build(3, n_shards, policy) else {
                    assert!(!policy.share_mixed && n_shards < 2);
                    continue;
                };
                assert_eq!(m.assignment.len(), 4);
                assert!(m.assignment.windows(2).all(|w| w[0] <= w[1]), "{:?}", m.assignment);
                assert!(m.assignment.iter().all(|&s| s < n_shards));
                // segments partition 0..4 exactly, in order
                let segs = m.segments();
                assert_eq!(segs.first().unwrap().1, 0);
                assert_eq!(segs.last().unwrap().2, 4);
                for w in segs.windows(2) {
                    assert_eq!(w[0].2, w[1].1, "gap between segments: {segs:?}");
                    assert_ne!(w[0].0, w[1].0, "adjacent segments share a shard");
                }
                assert_eq!(m.occupancy(), segs.len());
            }
        }
    }

    #[test]
    fn shard_map_isolates_the_classifier_under_newton_policy() {
        let m = ShardMap::build(3, 3, StagePolicy::newton()).unwrap();
        assert_eq!(m.assignment, vec![0, 1, 1, 2]);
        assert_eq!(m.shard_of(3), 2);
        assert!(m.assignment[..3].iter().all(|&s| s != 2));
        // exactly enough shards: one stage each
        let m = ShardMap::build(3, 4, StagePolicy::newton()).unwrap();
        assert_eq!(m.assignment, vec![0, 1, 2, 3]);
        // surplus shards stay empty rather than splitting a stage
        let m = ShardMap::build(3, 6, StagePolicy::newton()).unwrap();
        assert_eq!(m.occupancy(), 4);
        assert_eq!(*m.assignment.last().unwrap(), 5);
    }

    #[test]
    fn shard_map_rejects_infeasible_policies() {
        assert!(ShardMap::build(3, 1, StagePolicy::newton()).is_err());
        assert!(ShardMap::build(3, 0, StagePolicy::unconstrained()).is_err());
        let rigid = StagePolicy {
            share_conv: false,
            share_mixed: false,
            pooled_scratch: false,
        };
        assert!(ShardMap::build(3, 3, rigid).is_err());
        assert_eq!(ShardMap::build(3, 4, rigid).unwrap().assignment, vec![0, 1, 2, 3]);
        // a single unconstrained shard degenerates to single-process serving
        let m = ShardMap::build(3, 1, StagePolicy::unconstrained()).unwrap();
        assert_eq!(m.segments(), vec![(0, 0, 4)]);
    }

    #[test]
    fn shard_build_over_reshards_onto_survivors() {
        // full pool: identical to build()
        let m = ShardMap::build_over(3, &[0, 1, 2], 3, StagePolicy::newton()).unwrap();
        assert_eq!(m, ShardMap::build(3, 3, StagePolicy::newton()).unwrap());
        // worker 1 died: stages re-chunk over 0 and 2, pool size kept
        let m = ShardMap::build_over(3, &[0, 2], 3, StagePolicy::newton()).unwrap();
        assert_eq!(m.assignment, vec![0, 0, 0, 2]);
        assert_eq!(m.n_shards, 3);
        assert!(!m.assignment.contains(&1));
        // last survivor: newton infeasible, unconstrained takes everything
        assert!(ShardMap::build_over(3, &[1], 3, StagePolicy::newton()).is_err());
        let m = ShardMap::build_over(3, &[1], 3, StagePolicy::unconstrained()).unwrap();
        assert_eq!(m.segments(), vec![(1, 0, 4)]);
        // empty pool is an error, not a panic
        assert!(ShardMap::build_over(3, &[], 3, StagePolicy::unconstrained()).is_err());
    }
}
