//! A small CNN forward pass over the golden crossbar model — the rust twin
//! of `python/compile/model.py` (newton-mini), used for accuracy ablations
//! (lossy ADCs, adaptive sampling, noise) without touching PJRT.
//!
//! Geometry and quantisation match model.py exactly: u8-range activations,
//! signed-7-bit weights, per-stage scaling shifts (10, 9, 9, 8), im2col
//! convolutions chunked into 128-row crossbar pieces with digital
//! partial-sum reduction before a single scaling stage.

use crate::config::XbarParams;
use crate::util::Rng;
use crate::xbar::{scale_clamp, vmm_raw, Matrix};

/// An activation tensor (B, H, W, C), i64 values.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub b: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<i64>,
}

impl Tensor {
    pub fn zeros(b: usize, h: usize, w: usize, c: usize) -> Self {
        Tensor {
            b,
            h,
            w,
            c,
            data: vec![0; b * h * w * c],
        }
    }

    #[inline]
    pub fn at(&self, b: usize, y: usize, x: usize, ch: usize) -> i64 {
        self.data[((b * self.h + y) * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn set(&mut self, b: usize, y: usize, x: usize, ch: usize, v: i64) {
        self.data[((b * self.h + y) * self.w + x) * self.c + ch] = v;
    }
}

/// newton-mini weights: three 3x3 convs (3->32->64->128) + fc 2048->10.
pub struct MiniCnn {
    pub convs: Vec<Matrix>, // (9*Cin, Cout)
    pub fc: Matrix,         // (2048, 10)
    pub shifts: [u32; 4],
    pub act_max: i64,
}

impl MiniCnn {
    /// Deterministic synthetic weights (|w| < 64, like model.py).
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut mk = |rows: usize, cols: usize| {
            Matrix::from_fn(rows, cols, |_, _| rng.range_i64(-63, 64))
        };
        MiniCnn {
            convs: vec![mk(27, 32), mk(288, 64), mk(576, 128)],
            fc: mk(2048, 10),
            shifts: [10, 9, 9, 8],
            act_max: 255,
        }
    }

    /// Full forward pass: (B,32,32,3) image -> (B,10) logits, with the
    /// crossbar pipeline parameterised by `p` (lossy/adaptive configs
    /// change the numerics; the default config is exact).
    pub fn forward(&self, img: &Tensor, p: &XbarParams, adaptive: bool) -> Matrix {
        let mut act = img.clone();
        for (i, w) in self.convs.iter().enumerate() {
            let pp = XbarParams {
                out_shift: self.shifts[i],
                ..*p
            };
            act = conv3x3(&act, w, &pp, adaptive, self.act_max);
            act = maxpool2(&act);
        }
        let flat = Matrix::from_fn(act.b, act.h * act.w * act.c, |b, i| act.data[b * act.h * act.w * act.c + i]);
        let pp = XbarParams {
            out_shift: self.shifts[3],
            ..*p
        };
        xbar_linear(&flat, &self.fc, &pp, adaptive)
    }

    /// Argmax classes for a batch of images.
    pub fn classify(&self, img: &Tensor, p: &XbarParams, adaptive: bool) -> Vec<usize> {
        let logits = self.forward(img, p, adaptive);
        (0..logits.rows)
            .map(|r| {
                (0..logits.cols)
                    .max_by_key(|&c| (logits.at(r, c), std::cmp::Reverse(c)))
                    .unwrap()
            })
            .collect()
    }
}

/// SAME-padded 3x3 im2col.
pub fn im2col3(x: &Tensor) -> Matrix {
    let k = 3usize;
    let mut out = Matrix::zeros(x.b * x.h * x.w, k * k * x.c);
    for b in 0..x.b {
        for y in 0..x.h {
            for xx in 0..x.w {
                let row = (b * x.h + y) * x.w + xx;
                let mut col = 0;
                for dy in 0..k {
                    for dx in 0..k {
                        let sy = y as isize + dy as isize - 1;
                        let sx = xx as isize + dx as isize - 1;
                        for ch in 0..x.c {
                            let v = if sy >= 0
                                && sy < x.h as isize
                                && sx >= 0
                                && sx < x.w as isize
                            {
                                x.at(b, sy as usize, sx as usize, ch)
                            } else {
                                0
                            };
                            out.set(row, col, v);
                            col += 1;
                        }
                    }
                }
            }
        }
    }
    out
}

/// Chunked crossbar linear: split the reduction dim into 128-row pieces,
/// sum raw partials digitally, then scale once (mirrors model.py).
pub fn xbar_linear(x: &Matrix, w: &Matrix, p: &XbarParams, adaptive: bool) -> Matrix {
    let rows = p.rows;
    let chunks = x.cols.div_ceil(rows);
    let mut acc = Matrix::zeros(x.rows, w.cols);
    for ch in 0..chunks {
        let lo = ch * rows;
        let hi = (lo + rows).min(x.cols);
        let xc = Matrix::from_fn(x.rows, rows, |r, c| {
            if lo + c < hi {
                x.at(r, lo + c)
            } else {
                0
            }
        });
        let wc = Matrix::from_fn(rows, w.cols, |r, c| {
            if lo + r < hi {
                w.at(lo + r, c)
            } else {
                0
            }
        });
        let part = vmm_raw(&xc, &wc, p, adaptive);
        for i in 0..acc.data.len() {
            acc.data[i] += part.data[i];
        }
    }
    scale_clamp(&acc, p)
}

fn conv3x3(x: &Tensor, w: &Matrix, p: &XbarParams, adaptive: bool, act_max: i64) -> Tensor {
    let patches = im2col3(x);
    let y = xbar_linear(&patches, w, p, adaptive);
    let mut out = Tensor::zeros(x.b, x.h, x.w, w.cols);
    for r in 0..y.rows {
        for c in 0..y.cols {
            out.data[r * w.cols + c] = y.at(r, c).clamp(0, act_max); // relu8
        }
    }
    out
}

fn maxpool2(x: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(x.b, x.h / 2, x.w / 2, x.c);
    for b in 0..x.b {
        for y in 0..out.h {
            for xx in 0..out.w {
                for c in 0..x.c {
                    let m = x
                        .at(b, 2 * y, 2 * xx, c)
                        .max(x.at(b, 2 * y + 1, 2 * xx, c))
                        .max(x.at(b, 2 * y, 2 * xx + 1, c))
                        .max(x.at(b, 2 * y + 1, 2 * xx + 1, c));
                    out.set(b, y, xx, c, m);
                }
            }
        }
    }
    out
}

/// Random u8-range test images.
pub fn random_images(b: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut t = Tensor::zeros(b, 32, 32, 3);
    for v in t.data.iter_mut() {
        *v = rng.below(256) as i64;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let cnn = MiniCnn::new(0);
        let img = random_images(1, 1);
        let logits = cnn.forward(&img, &XbarParams::default(), false);
        assert_eq!((logits.rows, logits.cols), (1, 10));
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release or see ablation_adc_accuracy bench")]
    fn default_config_deterministic() {
        let cnn = MiniCnn::new(0);
        let img = random_images(2, 2);
        let p = XbarParams::default();
        assert_eq!(cnn.forward(&img, &p, false).data, cnn.forward(&img, &p, false).data);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release or see ablation_adc_accuracy bench")]
    fn adaptive_adc_preserves_classification() {
        // the paper's zero-accuracy-impact claim, end-to-end at model scale
        let cnn = MiniCnn::new(0);
        let img = random_images(4, 3);
        let p = XbarParams::default();
        let exact = cnn.classify(&img, &p, false);
        let adaptive = cnn.classify(&img, &p, true);
        assert_eq!(exact, adaptive);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release or see ablation_adc_accuracy bench")]
    fn lossy_adc_degrades_but_deterministically() {
        // Without ISAAC's data-encoding trick, a *truncating* 8-bit ADC
        // accumulates a systematic rounding bias across the 128 samples per
        // output and wrecks classification — which is exactly why the paper
        // keeps a lossless 9-bit budget and only gates bits *outside* the
        // kept window (the adaptive scheme, tested above, stays exact).
        let cnn = MiniCnn::new(0);
        let img = random_images(4, 4);
        let lossy = XbarParams {
            adc_bits: 8,
            ..XbarParams::default()
        };
        let a = cnn.classify(&img, &lossy, false);
        let b = cnn.classify(&img, &lossy, false);
        assert_eq!(a, b, "lossy path must still be deterministic");
        // 9-bit is bit-exact by construction
        let exact = cnn.classify(&img, &XbarParams::default(), false);
        let nine = cnn.classify(
            &img,
            &XbarParams {
                adc_bits: 9,
                ..XbarParams::default()
            },
            false,
        );
        assert_eq!(exact, nine);
    }

    #[test]
    fn im2col_centre_tap() {
        let mut x = Tensor::zeros(1, 4, 4, 2);
        x.set(0, 1, 1, 0, 7);
        x.set(0, 1, 1, 1, 9);
        let p = im2col3(&x);
        let row = (0 * 4 + 1) * 4 + 1;
        // centre tap = patch position (1,1) -> columns (1*3+1)*2 ..
        assert_eq!(p.at(row, (1 * 3 + 1) * 2), 7);
        assert_eq!(p.at(row, (1 * 3 + 1) * 2 + 1), 9);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn relu_and_pool_ranges() {
        let cnn = MiniCnn::new(0);
        let img = random_images(1, 5);
        // run one conv stage manually
        let y = conv3x3(&img, &cnn.convs[0], &XbarParams { out_shift: 10, ..Default::default() }, false, 255);
        assert!(y.data.iter().all(|&v| (0..=255).contains(&v)));
        let p = maxpool2(&y);
        assert_eq!((p.h, p.w), (16, 16));
    }
}
