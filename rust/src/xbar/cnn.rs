//! A small CNN forward pass over the golden crossbar model — the rust twin
//! of `python/compile/model.py` (newton-mini), used for accuracy ablations
//! (lossy ADCs, adaptive sampling, noise) without touching PJRT.
//!
//! Geometry and quantisation match model.py exactly: u8-range activations,
//! signed-7-bit weights, per-stage scaling shifts (10, 9, 9, 8), im2col
//! convolutions chunked into 128-row crossbar pieces with digital
//! partial-sum reduction before a single scaling stage.

use crate::config::XbarParams;
use crate::util::Rng;
use crate::xbar::{scale_clamp, Matrix, ProgrammedXbar, RunScratch};

/// An activation tensor (B, H, W, C), i64 values.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub b: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<i64>,
}

impl Tensor {
    pub fn zeros(b: usize, h: usize, w: usize, c: usize) -> Self {
        Tensor {
            b,
            h,
            w,
            c,
            data: vec![0; b * h * w * c],
        }
    }

    #[inline]
    pub fn at(&self, b: usize, y: usize, x: usize, ch: usize) -> i64 {
        self.data[((b * self.h + y) * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn set(&mut self, b: usize, y: usize, x: usize, ch: usize, v: i64) {
        self.data[((b * self.h + y) * self.w + x) * self.c + ch] = v;
    }

    /// Copy out one image of the batch as a `b = 1` tensor (the unit the
    /// per-image forward split works on).
    pub fn image(&self, b: usize) -> Tensor {
        assert!(b < self.b);
        let per = self.h * self.w * self.c;
        Tensor {
            b: 1,
            h: self.h,
            w: self.w,
            c: self.c,
            data: self.data[b * per..(b + 1) * per].to_vec(),
        }
    }
}

/// newton-mini weights: three 3x3 convs (3->32->64->128) + fc 2048->10.
pub struct MiniCnn {
    pub convs: Vec<Matrix>, // (9*Cin, Cout)
    pub fc: Matrix,         // (2048, 10)
    pub shifts: [u32; 4],
    pub act_max: i64,
}

impl MiniCnn {
    /// Deterministic synthetic weights (|w| < 64, like model.py).
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut mk = |rows: usize, cols: usize| {
            Matrix::from_fn(rows, cols, |_, _| rng.range_i64(-63, 64))
        };
        MiniCnn {
            convs: vec![mk(27, 32), mk(288, 64), mk(576, 128)],
            fc: mk(2048, 10),
            shifts: [10, 9, 9, 8],
            act_max: 255,
        }
    }

    /// Full forward pass: (B,32,32,3) image -> (B,10) logits, with the
    /// crossbar pipeline parameterised by `p` (lossy/adaptive configs
    /// change the numerics; the default config is exact).
    pub fn forward(&self, img: &Tensor, p: &XbarParams, adaptive: bool) -> Matrix {
        let mut act = img.clone();
        for (i, w) in self.convs.iter().enumerate() {
            let pp = XbarParams {
                out_shift: self.shifts[i],
                ..*p
            };
            act = conv3x3(&act, w, &pp, adaptive, self.act_max);
            act = maxpool2(&act);
        }
        let flat = Matrix::from_fn(act.b, act.h * act.w * act.c, |b, i| act.data[b * act.h * act.w * act.c + i]);
        let pp = XbarParams {
            out_shift: self.shifts[3],
            ..*p
        };
        xbar_linear(&flat, &self.fc, &pp, adaptive)
    }

    /// Install every layer's weights once for the given pipeline config,
    /// with the per-stage scaling shifts baked in. The returned
    /// [`ProgrammedCnn`] forwards bit-identically to
    /// `self.forward(img, p, adaptive)` without re-touching weights.
    pub fn program(&self, p: &XbarParams, adaptive: bool) -> ProgrammedCnn {
        let convs = self
            .convs
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let pp = XbarParams {
                    out_shift: self.shifts[i],
                    ..*p
                };
                ProgrammedLinear::install(w, &pp, adaptive)
            })
            .collect();
        let pp = XbarParams {
            out_shift: self.shifts[3],
            ..*p
        };
        ProgrammedCnn {
            convs,
            fc: ProgrammedLinear::install(&self.fc, &pp, adaptive),
            act_max: self.act_max,
        }
    }

    /// Argmax classes for a batch of images.
    pub fn classify(&self, img: &Tensor, p: &XbarParams, adaptive: bool) -> Vec<usize> {
        let logits = self.forward(img, p, adaptive);
        (0..logits.rows)
            .map(|r| {
                (0..logits.cols)
                    .max_by_key(|&c| (logits.at(r, c), std::cmp::Reverse(c)))
                    .unwrap()
            })
            .collect()
    }
}

/// SAME-padded 3x3 im2col. Allocating wrapper over [`im2col3_into`] for
/// external callers; the programmed forward path reuses one patch matrix
/// through a [`ForwardScratch`] instead.
pub fn im2col3(x: &Tensor) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    im2col3_into(x, &mut out);
    out
}

/// SAME-padded 3x3 im2col into a caller-owned matrix: `out` is reshaped
/// (reusing its allocation) and zero-filled, then only in-bounds taps are
/// written — the zero padding of the SAME border is the fill itself.
pub fn im2col3_into(x: &Tensor, out: &mut Matrix) {
    let k = 3usize;
    out.reset_zeroed(x.b * x.h * x.w, k * k * x.c);
    for b in 0..x.b {
        for y in 0..x.h {
            for xx in 0..x.w {
                let row = (b * x.h + y) * x.w + xx;
                for dy in 0..k {
                    let sy = y as isize + dy as isize - 1;
                    if sy < 0 || sy >= x.h as isize {
                        continue;
                    }
                    for dx in 0..k {
                        let sx = xx as isize + dx as isize - 1;
                        if sx < 0 || sx >= x.w as isize {
                            continue;
                        }
                        let col = (dy * k + dx) * x.c;
                        for ch in 0..x.c {
                            out.set(row, col + ch, x.at(b, sy as usize, sx as usize, ch));
                        }
                    }
                }
            }
        }
    }
}

/// Reusable buffers for one sequential CNN forward pass: the im2col patch
/// matrix and the raw pre-scaling accumulator, grown to the largest layer
/// once and reused across layers, calls, and served batches. One scratch
/// serves one forward at a time; parallel per-image jobs each own one
/// (allocated per image, still shared by every layer of that image).
pub struct ForwardScratch {
    /// im2col patch matrix (`B·H·W × 9·Cin`), reused by every conv layer.
    patches: Matrix,
    /// Raw (pre-scaling) chunk accumulator for the linear layers.
    raw: Matrix,
    /// Engine scratch (digit plane + column sums), grown to each chunk's
    /// geometry in place — the sequential VMM path allocates nothing.
    xbar: RunScratch,
}

impl ForwardScratch {
    pub fn new() -> Self {
        ForwardScratch {
            patches: Matrix::zeros(0, 0),
            raw: Matrix::zeros(0, 0),
            xbar: RunScratch::empty(),
        }
    }

    /// Hardware cost accrued by forwards through this scratch since the
    /// last [`Self::take_ledger`] (empty unless `obs::ledger` is enabled).
    pub fn ledger(&self) -> &crate::obs::CostLedger {
        &self.xbar.ledger
    }

    /// Drain the accrued cost ledger, resetting it to empty — the capture
    /// point the serving layers use to attribute one forward's cost to one
    /// request (and to discard residue from forwards that must not count,
    /// e.g. health-check reruns).
    pub fn take_ledger(&mut self) -> crate::obs::CostLedger {
        self.xbar.take_ledger()
    }
}

impl Default for ForwardScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// A weight matrix of arbitrary reduction length, installed once across as
/// many 128-row crossbar chunks as it needs. Raw chunk partials are summed
/// digitally and scaled once, exactly mirroring `xbar_linear` / model.py.
pub struct ProgrammedLinear {
    chunks: Vec<ProgrammedXbar>,
    /// Column-window start of each chunk within the input activations.
    offsets: Vec<usize>,
    in_cols: usize,
    out_cols: usize,
    p: XbarParams,
}

impl ProgrammedLinear {
    /// Install `w` (signed, `(K, N)` with any `K`) against crossbars of
    /// `p.rows` wordlines. Chunks are installed unpadded: zero-padded rows
    /// carry `x = 0` in the legacy path and contribute nothing, so the
    /// shorter reduction is bit-identical.
    pub fn install(w: &Matrix, p: &XbarParams, adaptive: bool) -> Self {
        let rows = p.rows;
        let n_chunks = w.rows.div_ceil(rows).max(1);
        let mut chunks = Vec::with_capacity(n_chunks);
        let mut offsets = Vec::with_capacity(n_chunks);
        for ch in 0..n_chunks {
            let lo = ch * rows;
            let hi = (lo + rows).min(w.rows);
            let wc = Matrix::from_fn(hi - lo, w.cols, |r, c| w.at(lo + r, c));
            chunks.push(ProgrammedXbar::install(&wc, p, adaptive));
            offsets.push(lo);
        }
        ProgrammedLinear {
            chunks,
            offsets,
            in_cols: w.rows,
            out_cols: w.cols,
            p: *p,
        }
    }

    pub fn in_cols(&self) -> usize {
        self.in_cols
    }

    pub fn out_cols(&self) -> usize {
        self.out_cols
    }

    /// Crossbar chunks this layer occupies.
    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Raw (pre-scaling) product: digital sum of per-chunk raw partials.
    /// Allocating wrapper over [`Self::run_raw_into`].
    pub fn run_raw(&self, x: &Matrix) -> Matrix {
        let mut acc = Matrix::zeros(0, 0);
        self.run_raw_into(x, &mut acc, &mut RunScratch::empty());
        acc
    }

    /// Raw product into a caller-owned accumulator: `out` is reshaped in
    /// place (reusing its allocation) and every chunk's partial is summed
    /// straight into it via [`ProgrammedXbar::run_window_acc_with`] — no
    /// per-chunk partial matrix is allocated, and the shared engine
    /// scratch is regrown in place per chunk (sequential sweeps allocate
    /// nothing at all).
    pub fn run_raw_into(&self, x: &Matrix, out: &mut Matrix, scratch: &mut RunScratch) {
        assert_eq!(x.cols, self.in_cols);
        out.reset_zeroed(x.rows, self.out_cols);
        for (xbar, &lo) in self.chunks.iter().zip(&self.offsets) {
            xbar.run_window_acc_with(x, lo, out, scratch);
        }
    }

    /// Full layer: raw partial sum, then one scale/clamp stage.
    pub fn run(&self, x: &Matrix) -> Matrix {
        scale_clamp(&self.run_raw(x), &self.p)
    }

    /// [`Self::run`] with the raw accumulator and engine scratch in
    /// caller-owned buffers — only the scaled output matrix allocates.
    /// Bit-identical to `run`.
    pub fn run_with(&self, x: &Matrix, raw: &mut Matrix, scratch: &mut RunScratch) -> Matrix {
        self.run_raw_into(x, raw, scratch);
        scale_clamp(raw, &self.p)
    }
}

/// Chunked crossbar linear: split the reduction dim into 128-row pieces,
/// sum raw partials digitally, then scale once (mirrors model.py).
///
/// Thin wrapper installing a [`ProgrammedLinear`] for one call; reuse the
/// installed form when the weights serve more than one batch.
pub fn xbar_linear(x: &Matrix, w: &Matrix, p: &XbarParams, adaptive: bool) -> Matrix {
    assert_eq!(x.cols, w.rows);
    ProgrammedLinear::install(w, p, adaptive).run(x)
}

/// Activation flowing between pipeline stages: conv stages consume and
/// produce feature-map tensors, the classifier tail produces logits. The
/// unit of exchange for [`ProgrammedCnn::run_stage`] and the pipelined
/// stage scheduler ([`crate::coordinator::pipeline`]) — the software
/// analogue of neuron values crossing the tile mesh between Newton's
/// conv tiles and classifier tiles.
#[derive(Clone, Debug)]
pub enum StageData {
    /// A feature map: the input of every conv stage and of the classifier.
    Act(Tensor),
    /// Classifier output — only the final stage produces this.
    Logits(Matrix),
}

impl StageData {
    /// Unwrap the classifier output. Panics when called on a feature map,
    /// i.e. when the stage pipeline stopped before its classifier tail.
    pub fn logits(self) -> Matrix {
        match self {
            StageData::Logits(m) => m,
            StageData::Act(_) => panic!("stage pipeline ended before the classifier tail"),
        }
    }
}

/// The install-once CNN: every layer's weights programmed into crossbar
/// chunks with the per-stage scaling shifts baked in. Produced by
/// [`MiniCnn::program`]; `forward` is bit-identical to [`MiniCnn::forward`]
/// with the same `(p, adaptive)` but does no weight work per call — the
/// serving analogue of the paper's in-situ weights.
///
/// The network is also exposed as per-stage executable units
/// ([`Self::run_stage`]): one stage per conv layer (conv + relu8 + pool)
/// plus the classifier tail (flatten + fc), mirroring Newton's conv-tile /
/// classifier-tile split. [`Self::forward_seq_with`] is literally a fold of
/// `run_stage` over `0..n_stages()`, so the staged decomposition and the
/// sequential forward can never drift apart numerically.
pub struct ProgrammedCnn {
    convs: Vec<ProgrammedLinear>,
    fc: ProgrammedLinear,
    act_max: i64,
}

impl ProgrammedCnn {
    /// Assemble a programmed CNN from already-installed layers — the hook
    /// for staged pools over geometries other than newton-mini (the
    /// pipelined-scheduling property tests, future heterogeneous
    /// backends). Shapes must chain: each conv's `out_cols` is the next
    /// stage's channel count after pooling, and `fc.in_cols()` must equal
    /// the flattened final feature map.
    pub fn from_layers(convs: Vec<ProgrammedLinear>, fc: ProgrammedLinear, act_max: i64) -> Self {
        ProgrammedCnn { convs, fc, act_max }
    }

    /// Executable pipeline stages: one per conv layer plus the classifier
    /// tail (4 for newton-mini).
    pub fn n_stages(&self) -> usize {
        self.convs.len() + 1
    }

    /// Conv stages only (stages `0..n_conv_stages()` are convs; stage
    /// `n_conv_stages()` is the classifier tail).
    pub fn n_conv_stages(&self) -> usize {
        self.convs.len()
    }

    /// Run one pipeline stage. Conv stages (`s < n_conv_stages()`) map a
    /// feature tensor through conv3x3 + relu8 + maxpool2; the final stage
    /// flattens and runs the fc classifier, producing logits. Chaining
    /// stages `0..n_stages()` is bit-identical to [`Self::forward_seq`] —
    /// the sequential forward is implemented as exactly that fold.
    ///
    /// Panics when `s` is out of range or `input` is not a feature map
    /// (only the last stage emits [`StageData::Logits`]).
    pub fn run_stage(&self, s: usize, input: &StageData, scratch: &mut ForwardScratch) -> StageData {
        let _sp = crate::obs::span("stage", "cnn").arg("s", s as u64);
        // per-stage cost attribution: snapshot the scratch ledger around
        // the stage body and credit the delta to this stage's registry
        // counters (one Copy each way; nothing when the ledger is off)
        let before = crate::obs::ledger::enabled().then(|| scratch.xbar.ledger);
        let StageData::Act(act) = input else {
            panic!("stage {s}: input must be a feature map, not logits");
        };
        let out = if s < self.convs.len() {
            let conv = conv3x3_programmed(act, &self.convs[s], self.act_max, scratch);
            StageData::Act(maxpool2(&conv))
        } else {
            assert_eq!(s, self.convs.len(), "stage {s} out of range");
            let flat = Matrix::from_fn(act.b, act.h * act.w * act.c, |b, i| {
                act.data[b * act.h * act.w * act.c + i]
            });
            let ForwardScratch { raw, xbar, .. } = scratch;
            StageData::Logits(self.fc.run_with(&flat, raw, xbar))
        };
        if let Some(b) = before {
            crate::obs::ledger::record_stage(s, &scratch.xbar.ledger.delta_since(&b));
        }
        out
    }

    /// Full forward pass: (B,32,32,3) image -> (B,10) logits.
    ///
    /// Batches split per image across the work-stealing executor
    /// ([`crate::sched`]) when the batch can fill the pool: every layer of
    /// the stack is row-independent (im2col rows never mix batch entries,
    /// VMMs and the scaling stage are per-row, pooling is per-image), so
    /// each image runs the whole conv stack as one job, bit-identical to
    /// the sequential pass for any worker count. With fewer images than
    /// cores the whole-batch pass wins instead — its per-VMM batch-row
    /// fan-out parallelises across all im2col rows, not just `B` jobs —
    /// so this entry point picks whichever covers the machine.
    pub fn forward(&self, img: &Tensor) -> Matrix {
        if crate::sched::in_worker() {
            // already inside a pool job: the outer decomposition owns the
            // pool (callers that want nested fan-out use forward_on)
            return self.forward_seq(img);
        }
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        if img.b >= cores {
            self.forward_on(img, &crate::sched::Executor::new(cores))
        } else {
            self.forward_seq(img)
        }
    }

    /// [`Self::forward`] on a caller-sized executor — the property tests
    /// sweep worker counts against [`Self::forward_seq`].
    pub fn forward_on(&self, img: &Tensor, exec: &crate::sched::Executor) -> Matrix {
        self.forward_on_ledgered(img, exec).0
    }

    /// [`Self::forward_on`] returning the batch's hardware cost ledger
    /// alongside the logits: each per-image job owns a fresh scratch and
    /// hands its accrued ledger back with its row, merged here — the
    /// executor fan-out would otherwise strand per-image cost inside
    /// worker-local scratches. Empty ledger unless `obs::ledger` is
    /// enabled; the logits are bit-identical to [`Self::forward_on`]
    /// either way.
    pub fn forward_on_ledgered(
        &self,
        img: &Tensor,
        exec: &crate::sched::Executor,
    ) -> (Matrix, crate::obs::CostLedger) {
        if img.b <= 1 || exec.workers() <= 1 {
            let mut scratch = ForwardScratch::new();
            let out = self.forward_seq_with(img, &mut scratch);
            return (out, scratch.take_ledger());
        }
        let rows = exec.map(img.b, |i| {
            let mut scratch = ForwardScratch::new();
            let m = self.forward_seq_with(&img.image(i), &mut scratch);
            (m.data, scratch.take_ledger())
        });
        let cols = self.fc.out_cols();
        let mut out = Matrix::zeros(img.b, cols);
        let mut ledger = crate::obs::CostLedger::new();
        for (r, (row, l)) in rows.into_iter().enumerate() {
            debug_assert_eq!(row.len(), cols);
            out.data[r * cols..(r + 1) * cols].copy_from_slice(&row);
            ledger.merge(&l);
        }
        (out, ledger)
    }

    /// Sequential whole-batch forward — the reference the parallel split
    /// is pinned against. Allocates one [`ForwardScratch`] per call; reuse
    /// one across calls via [`Self::forward_seq_with`] on serving paths.
    pub fn forward_seq(&self, img: &Tensor) -> Matrix {
        self.forward_seq_with(img, &mut ForwardScratch::new())
    }

    /// [`Self::forward_seq`] reusing a caller-owned scratch: the im2col
    /// patch matrix and the raw accumulator are shared by every layer of
    /// the pass and survive across calls, so steady-state serving stops
    /// allocating them per layer per batch. Bit-identical to
    /// [`Self::forward_seq`] with a fresh scratch (pinned by the
    /// scratch-purity property tests). Implemented as a fold of
    /// [`Self::run_stage`], so the staged pipeline path shares these exact
    /// numerics.
    pub fn forward_seq_with(&self, img: &Tensor, scratch: &mut ForwardScratch) -> Matrix {
        let mut data = StageData::Act(img.clone());
        for s in 0..self.n_stages() {
            data = self.run_stage(s, &data, scratch);
        }
        data.logits()
    }

    /// Argmax classes for a batch of images.
    pub fn classify(&self, img: &Tensor) -> Vec<usize> {
        let logits = self.forward(img);
        (0..logits.rows)
            .map(|r| {
                (0..logits.cols)
                    .max_by_key(|&c| (logits.at(r, c), std::cmp::Reverse(c)))
                    .unwrap()
            })
            .collect()
    }
}

fn conv3x3(x: &Tensor, w: &Matrix, p: &XbarParams, adaptive: bool, act_max: i64) -> Tensor {
    let patches = im2col3(x);
    let y = xbar_linear(&patches, w, p, adaptive);
    let mut out = Tensor::zeros(x.b, x.h, x.w, w.cols);
    for r in 0..y.rows {
        for c in 0..y.cols {
            out.data[r * w.cols + c] = y.at(r, c).clamp(0, act_max); // relu8
        }
    }
    out
}

fn conv3x3_programmed(
    x: &Tensor,
    conv: &ProgrammedLinear,
    act_max: i64,
    scratch: &mut ForwardScratch,
) -> Tensor {
    // split the scratch borrows: patches feeds the layer while raw/xbar
    // accumulate its chunk partials and digit planes
    let ForwardScratch { patches, raw, xbar } = scratch;
    im2col3_into(x, patches);
    let y = conv.run_with(patches, raw, xbar);
    let n = conv.out_cols();
    let mut out = Tensor::zeros(x.b, x.h, x.w, n);
    for r in 0..y.rows {
        for c in 0..y.cols {
            out.data[r * n + c] = y.at(r, c).clamp(0, act_max); // relu8
        }
    }
    out
}

fn maxpool2(x: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(x.b, x.h / 2, x.w / 2, x.c);
    for b in 0..x.b {
        for y in 0..out.h {
            for xx in 0..out.w {
                for c in 0..x.c {
                    let m = x
                        .at(b, 2 * y, 2 * xx, c)
                        .max(x.at(b, 2 * y + 1, 2 * xx, c))
                        .max(x.at(b, 2 * y, 2 * xx + 1, c))
                        .max(x.at(b, 2 * y + 1, 2 * xx + 1, c));
                    out.set(b, y, xx, c, m);
                }
            }
        }
    }
    out
}

/// Random u8-range test images.
pub fn random_images(b: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut t = Tensor::zeros(b, 32, 32, 3);
    for v in t.data.iter_mut() {
        *v = rng.below(256) as i64;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let cnn = MiniCnn::new(0);
        let img = random_images(1, 1);
        let logits = cnn.forward(&img, &XbarParams::default(), false);
        assert_eq!((logits.rows, logits.cols), (1, 10));
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release or see ablation_adc_accuracy bench")]
    fn default_config_deterministic() {
        let cnn = MiniCnn::new(0);
        let img = random_images(2, 2);
        let p = XbarParams::default();
        assert_eq!(cnn.forward(&img, &p, false).data, cnn.forward(&img, &p, false).data);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release or see ablation_adc_accuracy bench")]
    fn adaptive_adc_preserves_classification() {
        // the paper's zero-accuracy-impact claim, end-to-end at model scale
        let cnn = MiniCnn::new(0);
        let img = random_images(4, 3);
        let p = XbarParams::default();
        let exact = cnn.classify(&img, &p, false);
        let adaptive = cnn.classify(&img, &p, true);
        assert_eq!(exact, adaptive);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release or see ablation_adc_accuracy bench")]
    fn lossy_adc_degrades_but_deterministically() {
        // Without ISAAC's data-encoding trick, a *truncating* 8-bit ADC
        // accumulates a systematic rounding bias across the 128 samples per
        // output and wrecks classification — which is exactly why the paper
        // keeps a lossless 9-bit budget and only gates bits *outside* the
        // kept window (the adaptive scheme, tested above, stays exact).
        let cnn = MiniCnn::new(0);
        let img = random_images(4, 4);
        let lossy = XbarParams {
            adc_bits: 8,
            ..XbarParams::default()
        };
        let a = cnn.classify(&img, &lossy, false);
        let b = cnn.classify(&img, &lossy, false);
        assert_eq!(a, b, "lossy path must still be deterministic");
        // 9-bit is bit-exact by construction
        let exact = cnn.classify(&img, &XbarParams::default(), false);
        let nine = cnn.classify(
            &img,
            &XbarParams {
                adc_bits: 9,
                ..XbarParams::default()
            },
            false,
        );
        assert_eq!(exact, nine);
    }

    #[test]
    fn programmed_linear_matches_legacy_chunking() {
        // reduction dim 200 spans two crossbar chunks (128 + 72); the
        // installed form must match the padded per-call path bit-for-bit
        // across exact, lossy and adaptive configs
        let mut rng = Rng::new(9);
        let x = Matrix::from_fn(3, 200, |_, _| rng.range_i64(0, 1 << 16));
        let w = Matrix::from_fn(200, 10, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        for (adc_bits, adaptive) in [(9, false), (9, true), (8, false)] {
            let p = XbarParams {
                adc_bits,
                ..XbarParams::default()
            };
            let installed = ProgrammedLinear::install(&w, &p, adaptive);
            assert_eq!(installed.n_chunks(), 2);
            let legacy = legacy_xbar_linear(&x, &w, &p, adaptive);
            assert_eq!(
                installed.run(&x),
                legacy,
                "adc={adc_bits} adaptive={adaptive}"
            );
        }
    }

    /// The pre-refactor chunking (padded copies + per-call vmm), kept as
    /// the oracle for `programmed_linear_matches_legacy_chunking`.
    fn legacy_xbar_linear(x: &Matrix, w: &Matrix, p: &XbarParams, adaptive: bool) -> Matrix {
        use crate::xbar::reference::vmm_raw_reference;
        let rows = p.rows;
        let chunks = x.cols.div_ceil(rows);
        let mut acc = Matrix::zeros(x.rows, w.cols);
        for ch in 0..chunks {
            let lo = ch * rows;
            let hi = (lo + rows).min(x.cols);
            let xc = Matrix::from_fn(x.rows, rows, |r, c| {
                if lo + c < hi {
                    x.at(r, lo + c)
                } else {
                    0
                }
            });
            let wc = Matrix::from_fn(rows, w.cols, |r, c| {
                if lo + r < hi {
                    w.at(lo + r, c)
                } else {
                    0
                }
            });
            let part = vmm_raw_reference(&xc, &wc, p, adaptive);
            for i in 0..acc.data.len() {
                acc.data[i] += part.data[i];
            }
        }
        scale_clamp(&acc, p)
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn programmed_cnn_matches_legacy_forward() {
        let cnn = MiniCnn::new(0);
        let img = random_images(1, 8);
        for (p, adaptive) in [
            (XbarParams::default(), false),
            (XbarParams::default(), true),
        ] {
            let programmed = cnn.program(&p, adaptive);
            assert_eq!(programmed.forward(&img).data, cnn.forward(&img, &p, adaptive).data);
            assert_eq!(programmed.classify(&img), cnn.classify(&img, &p, adaptive));
        }
    }

    #[test]
    fn tensor_image_slices_one_batch_entry() {
        let t = random_images(3, 6);
        for b in 0..3 {
            let one = t.image(b);
            assert_eq!((one.b, one.h, one.w, one.c), (1, 32, 32, 3));
            for y in 0..32 {
                for x in 0..32 {
                    for c in 0..3 {
                        assert_eq!(one.at(0, y, x, c), t.at(b, y, x, c));
                    }
                }
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn parallel_forward_matches_sequential() {
        // per-image sched split must be bit-identical to the sequential
        // whole-batch pass for any worker count
        let cnn = MiniCnn::new(0);
        let img = random_images(3, 11);
        let programmed = cnn.program(&XbarParams::default(), false);
        let want = programmed.forward_seq(&img);
        for workers in [1, 2, 5] {
            let got = programmed.forward_on(&img, &crate::sched::Executor::new(workers));
            assert_eq!(got.data, want.data, "workers={workers}");
        }
        assert_eq!(programmed.forward(&img).data, want.data);
    }

    #[test]
    fn im2col3_into_matches_allocating_and_reuses_buffers() {
        let a = random_images(2, 17);
        let b = random_images(1, 18);
        let want_a = im2col3(&a);
        let want_b = im2col3(&b);
        // one reused matrix across differently-shaped calls, including a
        // shrink, must reproduce the fresh result exactly
        let mut out = Matrix::zeros(0, 0);
        im2col3_into(&a, &mut out);
        assert_eq!(out, want_a);
        im2col3_into(&b, &mut out);
        assert_eq!(out, want_b);
        im2col3_into(&a, &mut out);
        assert_eq!(out, want_a, "stale data leaked through buffer reuse");
    }

    #[test]
    fn linear_run_with_reused_raw_matches_run() {
        // chunked layer (200 rows = 2 chunks) on the slice engine: the
        // caller-owned raw accumulator must not change a bit, even when
        // reused across interleaved inputs
        let mut rng = Rng::new(23);
        let x1 = Matrix::from_fn(2, 200, |_, _| rng.range_i64(0, 1 << 16));
        let x2 = Matrix::from_fn(3, 200, |_, _| rng.range_i64(0, 1 << 16));
        let w = Matrix::from_fn(200, 7, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        let p = XbarParams {
            adc_bits: 8,
            ..XbarParams::default()
        };
        let layer = ProgrammedLinear::install(&w, &p, false);
        let want1 = layer.run(&x1);
        let want2 = layer.run(&x2);
        let mut raw = Matrix::zeros(0, 0);
        let mut xs = RunScratch::empty();
        assert_eq!(layer.run_with(&x1, &mut raw, &mut xs), want1);
        assert_eq!(layer.run_with(&x2, &mut raw, &mut xs), want2);
        assert_eq!(layer.run_with(&x1, &mut raw, &mut xs), want1);
        assert_eq!(layer.run_raw(&x1), {
            let mut out = Matrix::zeros(0, 0);
            layer.run_raw_into(&x1, &mut out, &mut xs);
            out
        });
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn forward_scratch_reuse_is_bit_identical() {
        // one ForwardScratch reused across interleaved forward passes must
        // equal fresh-scratch runs bit-for-bit, in the adaptive regime the
        // slice engine serves
        let cnn = MiniCnn::new(0);
        let a = random_images(1, 21);
        let b = random_images(1, 22);
        let programmed = cnn.program(&XbarParams::default(), true);
        let want_a = programmed.forward_seq(&a);
        let want_b = programmed.forward_seq(&b);
        let mut scratch = ForwardScratch::new();
        assert_eq!(programmed.forward_seq_with(&a, &mut scratch).data, want_a.data);
        assert_eq!(programmed.forward_seq_with(&b, &mut scratch).data, want_b.data);
        assert_eq!(
            programmed.forward_seq_with(&a, &mut scratch).data,
            want_a.data,
            "reused forward scratch leaked state"
        );
    }

    #[test]
    fn stage_counts_match_the_layer_stack() {
        let cnn = MiniCnn::new(0);
        let programmed = cnn.program(&XbarParams::default(), false);
        assert_eq!(programmed.n_stages(), 4);
        assert_eq!(programmed.n_conv_stages(), 3);
    }

    #[test]
    #[should_panic(expected = "input must be a feature map")]
    fn classifier_output_cannot_feed_another_stage() {
        let cnn = MiniCnn::new(0);
        let programmed = cnn.program(&XbarParams::default(), false);
        let logits = StageData::Logits(Matrix::zeros(1, 10));
        programmed.run_stage(0, &logits, &mut ForwardScratch::new());
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn staged_fold_matches_forward_seq_and_tracks_shapes() {
        // one image walked stage by stage: each conv stage halves H/W and
        // widens C, the tail emits (1, 10) logits bit-identical to the
        // sequential pass
        let cnn = MiniCnn::new(0);
        let programmed = cnn.program(&XbarParams::default(), false);
        let img = random_images(1, 31);
        let want = programmed.forward_seq(&img);
        let mut scratch = ForwardScratch::new();
        let mut data = StageData::Act(img.clone());
        let conv_shapes = [(16usize, 32usize), (8, 64), (4, 128)];
        for s in 0..programmed.n_stages() {
            data = programmed.run_stage(s, &data, &mut scratch);
            if let StageData::Act(t) = &data {
                let (hw, c) = conv_shapes[s];
                assert_eq!((t.h, t.w, t.c), (hw, hw, c), "stage {s}");
            }
        }
        assert_eq!(data.logits().data, want.data);
    }

    #[test]
    fn im2col_centre_tap() {
        let mut x = Tensor::zeros(1, 4, 4, 2);
        x.set(0, 1, 1, 0, 7);
        x.set(0, 1, 1, 1, 9);
        let p = im2col3(&x);
        let row = (0 * 4 + 1) * 4 + 1;
        // centre tap = patch position (1,1) -> columns (1*3+1)*2 ..
        assert_eq!(p.at(row, (1 * 3 + 1) * 2), 7);
        assert_eq!(p.at(row, (1 * 3 + 1) * 2 + 1), 9);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn relu_and_pool_ranges() {
        let cnn = MiniCnn::new(0);
        let img = random_images(1, 5);
        // run one conv stage manually
        let y = conv3x3(&img, &cnn.convs[0], &XbarParams { out_shift: 10, ..Default::default() }, false, 255);
        assert!(y.data.iter().all(|&v| (0..=255).contains(&v)));
        let p = maxpool2(&y);
        assert_eq!((p.h, p.w), (16, 16));
    }
}
