//! Reference implementation of the bit-serial crossbar pipeline — the
//! pre-install/run engine, kept verbatim as (a) the independent oracle the
//! property tests pin [`super::ProgrammedXbar`] against, and (b) the
//! "before" side of the `perf_hotpath` install-once comparison.
//!
//! Everything here re-slices the weight cell planes on every call, exactly
//! like the original `biased_product` hot path did.

use crate::config::XbarParams;

use super::{adc_sample, Matrix};

/// Raw biased product `x @ wb` through the bit-serial + ADC pipeline,
/// re-slicing the weight planes on every call (the legacy per-call layout).
pub fn biased_product_reference(
    x: &Matrix,
    wb: &Matrix,
    in_bits: u32,
    w_bits: u32,
    p: &XbarParams,
    adaptive: bool,
) -> Matrix {
    assert_eq!(x.cols, wb.rows);
    assert!(x.cols <= p.rows, "reduction dim exceeds crossbar rows");
    let iters = (in_bits as usize).div_ceil(p.dac_bits as usize);
    let slices = (w_bits as usize).div_ceil(p.cell_bits as usize);
    let dac_mask = (1i64 << p.dac_bits) - 1;
    let cell_mask = (1i64 << p.cell_bits) - 1;
    let (kdim, n) = (x.cols, wb.cols);

    // per-call weight slicing: planes[s][k][c], flat
    let mut planes = vec![0i64; slices * kdim * n];
    for s in 0..slices {
        let shift = s as u32 * p.cell_bits;
        for k in 0..kdim {
            let dst = &mut planes[(s * kdim + k) * n..(s * kdim + k) * n + n];
            let src = &wb.data[k * n..k * n + n];
            for c in 0..n {
                dst[c] = (src[c] >> shift) & cell_mask;
            }
        }
    }

    let mut acc = Matrix::zeros(x.rows, n);
    let mut cols = vec![0i64; slices * n]; // per-(i) analog column sums
    for r in 0..x.rows {
        for i in 0..iters {
            let shift = i as u32 * p.dac_bits;
            cols.fill(0);
            for k in 0..kdim {
                let xb = (x.at(r, k) >> shift) & dac_mask;
                if xb == 0 {
                    continue;
                }
                for s in 0..slices {
                    let row = &planes[(s * kdim + k) * n..(s * kdim + k) * n + n];
                    let dst = &mut cols[s * n..s * n + n];
                    if xb == 1 {
                        for c in 0..n {
                            dst[c] += row[c];
                        }
                    } else {
                        for c in 0..n {
                            dst[c] += xb * row[c];
                        }
                    }
                }
            }
            let lossless = p.lossless_adc_bits() <= p.adc_bits;
            for s in 0..slices {
                let place = i as u32 * p.dac_bits + s as u32 * p.cell_bits;
                let out = &mut acc.data[r * n..r * n + n];
                let src = &cols[s * n..s * n + n];
                if lossless && (!adaptive || place >= p.out_shift) {
                    // identity ADC: fold straight into the accumulator
                    for c in 0..n {
                        out[c] += src[c] << place;
                    }
                } else {
                    for c in 0..n {
                        let q = adc_sample(src[c], place, p, adaptive);
                        out[c] += q << place;
                    }
                }
            }
        }
    }
    acc
}

/// Reference signed-weight raw product (ISAAC bias encoding), per-call.
pub fn vmm_raw_reference(x: &Matrix, w: &Matrix, p: &XbarParams, adaptive: bool) -> Matrix {
    let bias = 1i64 << (p.weight_bits - 1);
    let wb = Matrix::from_fn(w.rows, w.cols, |r, c| w.at(r, c) + bias);
    let mut raw = biased_product_reference(x, &wb, p.input_bits, p.weight_bits, p, adaptive);
    for r in 0..x.rows {
        let sx: i64 = (0..x.cols).map(|k| x.at(r, k)).sum();
        for c in 0..w.cols {
            raw.data[r * w.cols + c] -= bias * sx;
        }
    }
    raw
}

/// Reference signed-input variant (both operand biases applied digitally).
pub fn vmm_raw_signed_reference(
    x: &Matrix,
    w: &Matrix,
    p: &XbarParams,
    adaptive: bool,
) -> Matrix {
    let bi = 1i64 << (p.input_bits - 1);
    let bw = 1i64 << (p.weight_bits - 1);
    let xs = Matrix::from_fn(x.rows, x.cols, |r, c| x.at(r, c) + bi);
    let wb = Matrix::from_fn(w.rows, w.cols, |r, c| w.at(r, c) + bw);
    let raw = biased_product_reference(&xs, &wb, p.input_bits, p.weight_bits, p, adaptive);
    let k = x.cols as i64;
    Matrix::from_fn(x.rows, w.cols, |r, c| {
        let rowsum: i64 = (0..x.cols).map(|j| xs.at(r, j)).sum();
        let colsum: i64 = (0..w.rows).map(|j| wb.at(j, c)).sum();
        raw.at(r, c) - bw * rowsum - bi * colsum + k * bi * bw
    })
}
