//! Crossbar non-idealities (paper Appendix): write noise, cell-level
//! tolerance, and IR drop — and the design rules they impose.
//!
//! The appendix's sizing rule: if a closed-loop program-and-verify write
//! can place a cell's resistance within `Δr`, a cell stores `l` levels over
//! a resistance range `r_range`, then the number of simultaneously active
//! rows must satisfy `rows <= r_range / (l * Δr)` so that accumulated
//! per-cell error stays below half an ADC LSB. The Monte-Carlo model here
//! checks that rule end-to-end: noisy conductances + IR drop through the
//! bit-serial pipeline vs the ideal output.

use crate::config::XbarParams;
use crate::util::Rng;
use crate::xbar::Matrix;

/// Physical cell/array parameters for the noise model.
#[derive(Clone, Copy, Debug)]
pub struct NoiseParams {
    /// Relative write tolerance after program-and-verify: a programmed
    /// level deviates by at most this fraction of one level step.
    pub write_tolerance: f64,
    /// Program-and-verify iterations (more iterations -> tighter Δr).
    pub pv_iterations: u32,
    /// Wire resistance per cell pitch relative to LRS cell resistance
    /// (drives IR drop along rows/columns).
    pub wire_r_rel: f64,
    /// Whether install-time compensation (Hu et al. [14]) pre-adjusts
    /// conductances for the expected IR drop.
    pub compensate_ir: bool,
}

impl Default for NoiseParams {
    fn default() -> Self {
        NoiseParams {
            write_tolerance: 0.04,
            pv_iterations: 6,
            wire_r_rel: 0.002,
            compensate_ir: true,
        }
    }
}

impl NoiseParams {
    /// Effective per-level deviation after `pv_iterations` of closed-loop
    /// writing (each verify-correct cycle roughly halves the residual,
    /// floored by thermal/RTN noise — Hu et al. [14] demonstrate 256x256
    /// with 5-bit cells, implying a sub-0.1% floor).
    pub fn delta_r(&self) -> f64 {
        let floor = 0.0008;
        (self.write_tolerance * 0.5f64.powi(self.pv_iterations as i32)).max(floor)
    }

    /// Appendix rule: max simultaneously active rows for `l` levels/cell.
    pub fn max_active_rows(&self, levels: u32) -> usize {
        // rows * l * Δr <= 1/2 LSB of the column sum => rows <= 1/(2*l*Δr)
        let rows = 1.0 / (2.0 * levels as f64 * self.delta_r());
        rows.floor().max(1.0) as usize
    }

    /// Write latency for one program-and-verify pass over a whole chip
    /// (paper §IV: "a delay of 16.4 ms to pre-load weights in a chip").
    /// One cell write+verify ~ 100 ns; 128 cells of a row write in
    /// parallel; crossbars across the chip program concurrently per tile.
    pub fn chip_program_ms(&self, total_weights: usize, p: &XbarParams, tiles: usize) -> f64 {
        let cells = total_weights * p.slices();
        let rows_to_write = cells as f64 / p.cols as f64; // a row per step
        let per_row_ns = 100.0 * self.pv_iterations as f64;
        // tiles program in parallel; within a tile, one crossbar at a time
        rows_to_write * per_row_ns / tiles as f64 * 1e-6
    }
}

/// Monte-Carlo noisy crossbar evaluation: returns (max, mean) absolute
/// error of the scaled 16-bit output vs the ideal pipeline.
pub fn noisy_vmm_error(
    x: &Matrix,
    w: &Matrix,
    p: &XbarParams,
    np: &NoiseParams,
    seed: u64,
) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let levels = (1u32 << p.cell_bits) as f64 - 1.0;
    let dr = np.delta_r();
    let bias = 1i64 << (p.weight_bits - 1);

    // per-cell multiplicative level error, fixed at install time
    let cell_err = |rng: &mut Rng| 1.0 + dr * (2.0 * rng.f64() - 1.0);

    // IR drop: a cell at row r, col c sees an effective read voltage
    // reduced by the cumulative wire resistance; compensation pre-scales
    // the programmed conductance by the expected droop.
    let droop = |r: usize, c: usize, rows: usize, cols: usize| {
        let dist = (r as f64 / rows as f64 + c as f64 / cols as f64) * 0.5;
        1.0 - np.wire_r_rel * dist * rows as f64
    };

    let iters = p.iters();
    let slices = p.slices();
    let dac_mask = (1i64 << p.dac_bits) - 1;
    let mut max_err = 0.0f64;
    let mut sum_err = 0.0f64;
    let mut n = 0usize;

    // install noisy cell values once (they persist across iterations),
    // alongside the ideal integer level planes — the per-iteration
    // `(wb >> shift) & mask` re-derivation used to run inside the
    // innermost loop. The float model keeps its own slice-major layout:
    // it never reads the digit-major planes of `super::ProgrammedXbar`,
    // so the engine's layout transpose cannot reach into the noise model.
    let mut cells = vec![0.0f64; w.rows * w.cols * slices];
    let mut level_planes = vec![0i64; w.rows * w.cols * slices];
    for s in 0..slices {
        for r in 0..w.rows {
            for c in 0..w.cols {
                let wb = (w.at(r, c) + bias) as u64;
                let ilvl = ((wb >> (s as u32 * p.cell_bits)) & ((1 << p.cell_bits) - 1)) as i64;
                let lvl = ilvl as f64;
                let mut v = lvl * cell_err(&mut rng);
                let d = droop(r, c, w.rows, w.cols);
                v *= if np.compensate_ir {
                    // install-time compensation: divide by expected droop,
                    // clamped to the max level
                    (d).max(1e-3).recip().min(levels.max(1.0) / lvl.max(1e-9))
                } else {
                    1.0
                };
                cells[(s * w.rows + r) * w.cols + c] = v * d;
                level_planes[(s * w.rows + r) * w.cols + c] = ilvl;
            }
        }
    }

    // per-row DAC digits extracted once (`iters × kdim`, like the int
    // engine's digit plane) instead of re-shifting per (column, slice).
    // Summation order is unchanged, so the floats match the pre-refactor
    // loop bit-for-bit.
    let kdim = x.cols;
    let mut digits = vec![0i64; iters * kdim];
    for br in 0..x.rows {
        for k in 0..kdim {
            let mut xv = x.at(br, k);
            for i in 0..iters {
                digits[i * kdim + k] = xv & dac_mask;
                xv >>= p.dac_bits;
            }
        }
        for c in 0..w.cols {
            let mut acc = 0.0f64;
            let mut ideal_acc = 0i64;
            for i in 0..iters {
                let row_digits = &digits[i * kdim..(i + 1) * kdim];
                for s in 0..slices {
                    let place = (i as u32) * p.dac_bits + (s as u32) * p.cell_bits;
                    let mut col = 0.0f64;
                    let mut ideal_col = 0i64;
                    for (r, &xb) in row_digits.iter().enumerate() {
                        if xb != 0 {
                            col += xb as f64 * cells[(s * w.rows + r) * w.cols + c];
                            ideal_col += xb * level_planes[(s * w.rows + r) * w.cols + c];
                        }
                    }
                    // ADC rounds the analog sum to the nearest integer code
                    acc += col.round() * (1i64 << place) as f64;
                    ideal_acc += ideal_col << place;
                }
            }
            let err = (acc - ideal_acc as f64).abs() / (1i64 << p.out_shift) as f64;
            max_err = max_err.max(err);
            sum_err += err;
            n += 1;
        }
    }
    (max_err, sum_err / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn small_xw(seed: u64, p: &XbarParams) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(2, p.rows, |_, _| rng.range_i64(0, 1 << p.input_bits));
        let w = Matrix::from_fn(p.rows, 8, |_, _| {
            rng.range_i64(-(1 << (p.weight_bits - 1)), 1 << (p.weight_bits - 1))
        });
        (x, w)
    }

    #[test]
    fn pv_iterations_tighten_delta_r() {
        let few = NoiseParams {
            pv_iterations: 2,
            ..Default::default()
        };
        let many = NoiseParams {
            pv_iterations: 8,
            ..Default::default()
        };
        assert!(many.delta_r() <= few.delta_r());
        assert!(many.delta_r() >= 0.0008, "floored by thermal/RTN noise");
    }

    #[test]
    fn appendix_row_limit_shrinks_with_levels() {
        let np = NoiseParams::default();
        // 2-bit cells (l=4) allow fewer active rows than 1-bit (l=2)
        assert!(np.max_active_rows(4) < np.max_active_rows(2));
        // the paper's conservative design point: 128x128 with 2-bit cells
        // must be admissible
        assert!(np.max_active_rows(4) >= 128, "{}", np.max_active_rows(4));
    }

    #[test]
    fn chip_program_time_matches_paper_scale() {
        // paper §IV: ~16.4 ms to preload a chip's weights
        let np = NoiseParams::default();
        let p = XbarParams::default();
        // a VGG-scale chip: ~135M weights over ~160 tiles
        let ms = np.chip_program_ms(135_000_000, &p, 160);
        assert!((1.0..100.0).contains(&ms), "{ms} ms");
    }

    #[test]
    fn noise_free_params_give_zero_error() {
        let p = XbarParams::default();
        let np = NoiseParams {
            write_tolerance: 0.0,
            pv_iterations: 20,
            wire_r_rel: 0.0,
            compensate_ir: false,
        };
        // delta_r floors at 0.5%, so force the pure-ideal path by zeroing
        // wire resistance and checking mean error stays < 1 output ulp
        let (x, w) = small_xw(1, &p);
        let (_max, mean) = noisy_vmm_error(&x, &w, &p, &np, 7);
        assert!(mean < 1.5, "{mean}");
    }

    #[test]
    fn compensation_reduces_ir_error() {
        let p = XbarParams::default();
        let (x, w) = small_xw(2, &p);
        let base = NoiseParams {
            wire_r_rel: 0.004,
            compensate_ir: false,
            ..Default::default()
        };
        let comp = NoiseParams {
            compensate_ir: true,
            ..base
        };
        let (_, e_raw) = noisy_vmm_error(&x, &w, &p, &base, 11);
        let (_, e_comp) = noisy_vmm_error(&x, &w, &p, &comp, 11);
        assert!(e_comp < e_raw, "{e_comp} !< {e_raw}");
    }

    #[test]
    fn errors_grow_with_write_tolerance() {
        let p = XbarParams::default();
        let (x, w) = small_xw(3, &p);
        let tight = NoiseParams::default();
        let loose = NoiseParams {
            write_tolerance: 0.5,
            pv_iterations: 1,
            ..Default::default()
        };
        let (_, e_t) = noisy_vmm_error(&x, &w, &p, &tight, 5);
        let (_, e_l) = noisy_vmm_error(&x, &w, &p, &loose, 5);
        assert!(e_l > e_t, "{e_l} !> {e_t}");
    }

    #[test]
    fn deterministic_given_seed() {
        let p = XbarParams::default();
        let (x, w) = small_xw(4, &p);
        let np = NoiseParams::default();
        assert_eq!(
            noisy_vmm_error(&x, &w, &p, &np, 9),
            noisy_vmm_error(&x, &w, &p, &np, 9)
        );
    }
}
