//! Bit-accurate functional model of the analog crossbar pipeline — the rust
//! twin of `python/compile/kernels/crossbar.py` (L1).
//!
//! Used by the coordinator's golden-model verification path, the examples
//! that run without PJRT, and the property tests that pin down the numeric
//! contract the artifacts must satisfy: with the default lossless ADC the
//! whole pipeline equals `clamp(round_half_up((x @ w) >> out_shift))`.
//!
//! Hot-path layout (rust/PERF.md): weights are *installed once* into a
//! [`ProgrammedXbar`] — bias encoding, cell-plane slicing into flat
//! `slices × K × N` buffers, the per-column `colsum(Wb)` correction, and
//! the lossless/adaptive ADC decision all happen at install time, mirroring
//! the paper's in-situ premise that a crossbar is programmed once and read
//! many times. `run(&x)` then streams input bits through the pre-sliced
//! planes with a reusable scratch buffer, parallelising across batch rows.
//! The historical free functions ([`biased_product`], [`vmm_raw`],
//! [`vmm_raw_signed`], [`vmm`]) are thin install-and-run wrappers; the
//! pre-refactor per-call engine survives verbatim in [`reference`] as the
//! oracle the property tests compare against.

pub mod cnn;
pub mod noise;
pub mod reference;

use crate::config::XbarParams;

/// A dense signed matrix in row-major order.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> i64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i64) {
        self.data[r * self.cols + c] = v;
    }
}

/// Plain exact matmul (the oracle).
pub fn matmul(x: &Matrix, w: &Matrix) -> Matrix {
    assert_eq!(x.cols, w.rows);
    let mut out = Matrix::zeros(x.rows, w.cols);
    for r in 0..x.rows {
        for k in 0..x.cols {
            let xv = x.at(r, k);
            if xv == 0 {
                continue;
            }
            for c in 0..w.cols {
                out.data[r * w.cols + c] += xv * w.at(k, c);
            }
        }
    }
    out
}

/// ADC digitisation of one analog column sum (mirrors `adc_sample`).
fn adc_sample(col_sum: i64, place: u32, p: &XbarParams, adaptive: bool) -> i64 {
    let mut q = col_sum;
    let lossy = p.lossless_adc_bits() as i64 - p.adc_bits as i64;
    if lossy > 0 {
        let half = 1i64 << (lossy - 1);
        q = ((q + half) >> lossy) << lossy;
    }
    if adaptive && place < p.out_shift {
        let d = (p.out_shift - place) as i64;
        let half = 1i64 << (d - 1);
        q = ((q + half) >> d) << d;
    }
    q
}

/// All-ones mask over the low `bits` bits (saturating below the sign bit).
fn mask_bits(bits: u32) -> i64 {
    if bits >= 63 {
        i64::MAX
    } else {
        (1i64 << bits) - 1
    }
}

/// Reusable per-thread scratch for [`ProgrammedXbar::run_with_scratch`]:
/// holds the `slices × N` analog column sums of one bit-serial iteration,
/// so steady-state runs allocate nothing but their output.
pub struct RunScratch {
    cols: Vec<i64>,
}

/// A crossbar with weights installed once and read many times — the
/// in-situ compute model of the paper made literal in software.
///
/// Install time does all data-independent work: ISAAC bias encoding
/// (`Wb = w + 2^(wb-1)`), slicing `Wb` into `slices × K × N` cell planes,
/// the per-column `colsum(Wb)` needed by the signed-input correction, and
/// the lossless/adaptive ADC decision. When every ADC sample is an identity
/// (lossless config, non-adaptive), install also selects a fused fast path
/// that is algebraically — and therefore bit — identical to the bit-serial
/// sweep: the place-value sums telescope back into a plain masked matmul,
/// so no cell planes are materialised at all.
///
/// `run` borrows `&self` and is thread-safe; large batches are split across
/// `std::thread::available_parallelism()` worker threads, each with its own
/// [`RunScratch`].
pub struct ProgrammedXbar {
    p: XbarParams,
    in_bits: u32,
    w_bits: u32,
    adaptive: bool,
    kdim: usize,
    n: usize,
    slices: usize,
    iters: usize,
    /// Identity-ADC config (install-time hoist of the per-iteration check).
    lossless: bool,
    /// Fused masked-matmul path: lossless and non-adaptive.
    fast: bool,
    /// `2^(weight_bits-1)` when installed from signed weights, else 0.
    w_bias: i64,
    /// Mask reconstructing exactly the bits the DAC sweep would stream.
    in_mask: i64,
    dac_mask: i64,
    /// Flat `slices × K × N` cell planes (empty on the fast path).
    planes: Vec<i64>,
    /// Biased weight matrix, masked to the bits the cell planes hold.
    wb: Vec<i64>,
    /// Per-column sum of the (unmasked) biased weights, for `run_signed`.
    colsum_wb: Vec<i64>,
}

impl ProgrammedXbar {
    /// Install signed weights (ISAAC bias encoding applied here, once).
    pub fn install(w: &Matrix, p: &XbarParams, adaptive: bool) -> Self {
        let bias = 1i64 << (p.weight_bits - 1);
        let wb = Matrix::from_fn(w.rows, w.cols, |r, c| w.at(r, c) + bias);
        let mut programmed = Self::install_biased(&wb, p.input_bits, p.weight_bits, p, adaptive);
        programmed.w_bias = bias;
        programmed
    }

    /// Install an already-biased (unsigned) weight matrix with explicit
    /// streaming widths — the programmed form of [`biased_product`].
    pub fn install_biased(
        wb: &Matrix,
        in_bits: u32,
        w_bits: u32,
        p: &XbarParams,
        adaptive: bool,
    ) -> Self {
        assert!(wb.rows <= p.rows, "reduction dim exceeds crossbar rows");
        let iters = (in_bits as usize).div_ceil(p.dac_bits as usize);
        let slices = (w_bits as usize).div_ceil(p.cell_bits as usize);
        let (kdim, n) = (wb.rows, wb.cols);
        let lossless = p.lossless_adc_bits() <= p.adc_bits;
        let fast = lossless && !adaptive;
        let in_mask = mask_bits(iters as u32 * p.dac_bits);
        let w_mask = mask_bits(slices as u32 * p.cell_bits);
        let cell_mask = (1i64 << p.cell_bits) - 1;

        let wb_masked: Vec<i64> = wb.data.iter().map(|&v| v & w_mask).collect();
        let mut colsum_wb = vec![0i64; n];
        for k in 0..kdim {
            for c in 0..n {
                colsum_wb[c] += wb.data[k * n + c];
            }
        }

        // install-time weight slicing: planes[s][k][c], flat. The fast path
        // reads the fused `wb` buffer instead, so skip the planes entirely.
        let planes = if fast {
            Vec::new()
        } else {
            let mut planes = vec![0i64; slices * kdim * n];
            for s in 0..slices {
                let shift = s as u32 * p.cell_bits;
                for k in 0..kdim {
                    let dst = &mut planes[(s * kdim + k) * n..(s * kdim + k) * n + n];
                    let src = &wb.data[k * n..k * n + n];
                    for c in 0..n {
                        dst[c] = (src[c] >> shift) & cell_mask;
                    }
                }
            }
            planes
        };

        ProgrammedXbar {
            p: *p,
            in_bits,
            w_bits,
            adaptive,
            kdim,
            n,
            slices,
            iters,
            lossless,
            fast,
            w_bias: 0,
            in_mask,
            dac_mask: (1i64 << p.dac_bits) - 1,
            planes,
            wb: wb_masked,
            colsum_wb,
        }
    }

    /// Reduction length (crossbar rows in use).
    pub fn kdim(&self) -> usize {
        self.kdim
    }

    /// Output columns.
    pub fn n(&self) -> usize {
        self.n
    }

    /// DAC iterations one VMM streams.
    pub fn iters(&self) -> usize {
        self.iters
    }

    /// Weight cell planes (crossbar slices) one VMM reads.
    pub fn slices(&self) -> usize {
        self.slices
    }

    /// Logical ADC samples one VMM digitises per output column.
    pub fn adc_samples_per_column(&self) -> usize {
        self.iters * self.slices
    }

    /// `(input, weight)` streaming widths the installation was built for.
    pub fn stream_widths(&self) -> (u32, u32) {
        (self.in_bits, self.w_bits)
    }

    /// Whether install selected the fused identity-ADC fast path.
    pub fn is_fused(&self) -> bool {
        self.fast
    }

    /// Fresh scratch sized for this installation.
    pub fn scratch(&self) -> RunScratch {
        RunScratch {
            cols: if self.fast {
                Vec::new()
            } else {
                vec![0i64; self.slices * self.n]
            },
        }
    }

    /// Raw product for unsigned inputs against the installed weights;
    /// equals `vmm_raw(x, w, ..)` when installed via [`Self::install`], or
    /// `biased_product(x, wb, ..)` when installed via
    /// [`Self::install_biased`].
    pub fn run(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.kdim);
        self.run_window(x, 0)
    }

    /// Like [`Self::run`], but reads the reduction slice
    /// `x[:, x_col0 .. x_col0 + kdim]` in place — chunked layers stream one
    /// wide activation matrix through several installed crossbars without
    /// copying column windows out.
    pub fn run_window(&self, x: &Matrix, x_col0: usize) -> Matrix {
        let mut raw = self.raw_product(x, x_col0, 0);
        if self.w_bias != 0 {
            // signed-weight correction: subtract Bw * rowsum(x) digitally
            for r in 0..x.rows {
                let sx: i64 = (0..self.kdim).map(|k| x.at(r, x_col0 + k)).sum();
                let out = &mut raw.data[r * self.n..(r + 1) * self.n];
                for v in out.iter_mut() {
                    *v -= self.w_bias * sx;
                }
            }
        }
        raw
    }

    /// Signed-input raw product (both operand biases corrected digitally,
    /// §III-A2); equals `vmm_raw_signed(x, w, ..)`. Uses the install-time
    /// `colsum(Wb)`.
    pub fn run_signed(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.kdim);
        assert!(
            self.w_bias != 0,
            "run_signed needs signed-weight installation (ProgrammedXbar::install)"
        );
        let bi = 1i64 << (self.in_bits - 1);
        let bw = self.w_bias;
        let k = self.kdim as i64;
        let mut raw = self.raw_product(x, 0, bi);
        for r in 0..x.rows {
            let rowsum: i64 = (0..self.kdim).map(|j| x.at(r, j) + bi).sum();
            let out = &mut raw.data[r * self.n..(r + 1) * self.n];
            for (c, v) in out.iter_mut().enumerate() {
                *v += k * bi * bw - bw * rowsum - bi * self.colsum_wb[c];
            }
        }
        raw
    }

    /// Full pipeline against the installed weights:
    /// `clamp(round((x @ w) >> out_shift))` for lossless configs.
    pub fn vmm(&self, x: &Matrix) -> Matrix {
        scale_clamp(&self.run(x), &self.p)
    }

    /// Sequential run reusing caller-owned scratch: zero allocation beyond
    /// the output once the scratch exists. Bit-identical to [`Self::run`].
    pub fn run_with_scratch(&self, x: &Matrix, scratch: &mut RunScratch) -> Matrix {
        assert_eq!(x.cols, self.kdim);
        let n = self.n;
        let mut acc = Matrix::zeros(x.rows, n);
        if n == 0 {
            return acc;
        }
        for (r, out) in acc.data.chunks_mut(n).enumerate() {
            self.run_row(x, r, 0, 0, out, scratch);
        }
        if self.w_bias != 0 {
            for r in 0..x.rows {
                let sx: i64 = (0..self.kdim).map(|k| x.at(r, k)).sum();
                for v in acc.data[r * n..(r + 1) * n].iter_mut() {
                    *v -= self.w_bias * sx;
                }
            }
        }
        acc
    }

    /// Approximate i64 ops per batch row, for the parallel-split decision.
    fn work_per_row(&self) -> usize {
        if self.fast {
            self.kdim * self.n
        } else {
            self.iters * self.kdim * self.slices.max(1) * self.n
        }
    }

    /// Biased product of `(x[:, x_col0..] + x_off)` against the planes.
    fn raw_product(&self, x: &Matrix, x_col0: usize, x_off: i64) -> Matrix {
        assert!(x_col0 + self.kdim <= x.cols, "window exceeds input columns");
        let n = self.n;
        let mut acc = Matrix::zeros(x.rows, n);
        if n == 0 || x.rows == 0 {
            return acc;
        }
        // split across cores only when the work dwarfs thread spawn cost —
        // and never from inside a sched worker: the outer job decomposition
        // (per-image forward, batch serving) owns the pool, and nesting a
        // per-VMM fan-out under it would thrash ~cores² threads per read
        let workers = if x.rows >= 2
            && x.rows * self.work_per_row() >= 1 << 20
            && !crate::sched::in_worker()
        {
            crate::util::worker_count(x.rows)
        } else {
            1
        };
        if workers <= 1 {
            let mut scratch = self.scratch();
            for (r, out) in acc.data.chunks_mut(n).enumerate() {
                self.run_row(x, r, x_col0, x_off, out, &mut scratch);
            }
        } else {
            // batch rows fan out through the work-stealing executor
            // (crate::sched), ~2 row-chunk jobs per worker so stealing can
            // even out OS-timing skew. Each job claims its disjoint &mut
            // chunk of the output (one uncontended lock per chunk) and
            // writes rows in place — no per-call buffers or copy-back —
            // with a private scratch, bit-identical to the sequential loop.
            let rows_per = x.rows.div_ceil(workers * 2).max(1);
            let chunk_slots: Vec<std::sync::Mutex<Option<&mut [i64]>>> = acc
                .data
                .chunks_mut(rows_per * n)
                .map(|c| std::sync::Mutex::new(Some(c)))
                .collect();
            crate::sched::Executor::new(workers).map(chunk_slots.len(), |ci| {
                let chunk = chunk_slots[ci]
                    .lock()
                    .unwrap()
                    .take()
                    .expect("output chunk claimed exactly once");
                let mut scratch = self.scratch();
                for (j, out) in chunk.chunks_mut(n).enumerate() {
                    self.run_row(x, ci * rows_per + j, x_col0, x_off, out, &mut scratch);
                }
            });
        }
        acc
    }

    /// One batch row through the pipeline, accumulating into `out`.
    fn run_row(
        &self,
        x: &Matrix,
        r: usize,
        x_col0: usize,
        x_off: i64,
        out: &mut [i64],
        scratch: &mut RunScratch,
    ) {
        let n = self.n;
        if self.fast {
            // identity-ADC configs telescope back into a masked matmul:
            // sum_i sum_s (x_bits_i @ w_slice_s) << place == (x & m) @ (Wb & m')
            for k in 0..self.kdim {
                let xv = (x.at(r, x_col0 + k) + x_off) & self.in_mask;
                if xv == 0 {
                    continue;
                }
                let row = &self.wb[k * n..k * n + n];
                if xv == 1 {
                    for c in 0..n {
                        out[c] += row[c];
                    }
                } else {
                    for c in 0..n {
                        out[c] += xv * row[c];
                    }
                }
            }
            return;
        }

        let cols = &mut scratch.cols;
        for i in 0..self.iters {
            let shift = i as u32 * self.p.dac_bits;
            cols.fill(0);
            for k in 0..self.kdim {
                let xb = ((x.at(r, x_col0 + k) + x_off) >> shift) & self.dac_mask;
                if xb == 0 {
                    continue;
                }
                let base = k * n;
                for s in 0..self.slices {
                    let row = &self.planes[s * self.kdim * n + base..s * self.kdim * n + base + n];
                    let dst = &mut cols[s * n..s * n + n];
                    if xb == 1 {
                        for c in 0..n {
                            dst[c] += row[c];
                        }
                    } else {
                        for c in 0..n {
                            dst[c] += xb * row[c];
                        }
                    }
                }
            }
            for s in 0..self.slices {
                let place = i as u32 * self.p.dac_bits + s as u32 * self.p.cell_bits;
                let src = &cols[s * n..s * n + n];
                if self.lossless && (!self.adaptive || place >= self.p.out_shift) {
                    // identity ADC: fold straight into the accumulator
                    for c in 0..n {
                        out[c] += src[c] << place;
                    }
                } else {
                    for c in 0..n {
                        let q = adc_sample(src[c], place, &self.p, self.adaptive);
                        out[c] += q << place;
                    }
                }
            }
        }
    }
}

/// Raw biased product `x @ wb` through the bit-serial + ADC pipeline.
/// `x` unsigned (`in_bits` wide), `wb` unsigned (`w_bits` wide).
///
/// Thin wrapper: installs a [`ProgrammedXbar`] and runs once. Call sites
/// that reuse one weight matrix should install once and call `run` per
/// batch instead (rust/PERF.md).
pub fn biased_product(
    x: &Matrix,
    wb: &Matrix,
    in_bits: u32,
    w_bits: u32,
    p: &XbarParams,
    adaptive: bool,
) -> Matrix {
    assert_eq!(x.cols, wb.rows);
    ProgrammedXbar::install_biased(wb, in_bits, w_bits, p, adaptive).run(x)
}

/// Signed raw product via bias encoding (ISAAC): store `w + 2^(wb-1)`,
/// subtract `2^(wb-1) * sum(x)` digitally. Install-and-run wrapper.
pub fn vmm_raw(x: &Matrix, w: &Matrix, p: &XbarParams, adaptive: bool) -> Matrix {
    ProgrammedXbar::install(w, p, adaptive).run(x)
}

/// Signed-input variant: offsets inputs into the unsigned DAC window and
/// corrects digitally (both operand biases applied). Needed by Strassen's
/// pre-subtractions, whose operands can be negative (§III-A2).
///
///   x@w = (X - Bi)(Wb - Bw) = X@Wb - Bw*rowsum(X) - Bi*colsum(Wb) + K*Bi*Bw
///
/// where X = x + Bi, Wb = w + Bw, K = reduction length. `colsum(Wb)` is
/// computed at weight-install time. Install-and-run wrapper.
pub fn vmm_raw_signed(x: &Matrix, w: &Matrix, p: &XbarParams, adaptive: bool) -> Matrix {
    ProgrammedXbar::install(w, p, adaptive).run_signed(x)
}

/// Scaling stage: round-half-up shift + clamp to the signed output window.
pub fn scale_clamp(raw: &Matrix, p: &XbarParams) -> Matrix {
    let half = if p.out_shift > 0 {
        1i64 << (p.out_shift - 1)
    } else {
        0
    };
    let lo = -(1i64 << (p.out_bits - 1));
    let hi = (1i64 << (p.out_bits - 1)) - 1;
    Matrix::from_fn(raw.rows, raw.cols, |r, c| {
        ((raw.at(r, c) + half) >> p.out_shift).clamp(lo, hi)
    })
}

/// Full pipeline: `clamp(round((x @ w) >> out_shift))` for lossless configs.
pub fn vmm(x: &Matrix, w: &Matrix, p: &XbarParams) -> Matrix {
    scale_clamp(&vmm_raw(x, w, p, false), p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_xw(seed: u64, b: usize, n: usize, p: &XbarParams) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(b, p.rows, |_, _| rng.range_i64(0, 1 << p.input_bits));
        let w = Matrix::from_fn(p.rows, n, |_, _| {
            rng.range_i64(-(1 << (p.weight_bits - 1)), 1 << (p.weight_bits - 1))
        });
        (x, w)
    }

    #[test]
    fn pipeline_is_exact_for_default_config() {
        let p = XbarParams::default();
        let (x, w) = rand_xw(1, 4, 16, &p);
        let got = vmm(&x, &w, &p);
        let want = scale_clamp(&matmul(&x, &w), &p);
        assert_eq!(got, want);
    }

    #[test]
    fn raw_matches_matmul() {
        let p = XbarParams::default();
        let (x, w) = rand_xw(2, 3, 8, &p);
        assert_eq!(vmm_raw(&x, &w, &p, false), matmul(&x, &w));
    }

    #[test]
    fn adaptive_within_one_ulp_here() {
        let p = XbarParams::default();
        let (x, w) = rand_xw(3, 3, 8, &p);
        let a = scale_clamp(&vmm_raw(&x, &w, &p, true), &p);
        let e = scale_clamp(&matmul(&x, &w), &p);
        for (av, ev) in a.data.iter().zip(e.data.iter()) {
            assert!((av - ev).abs() <= 2, "{av} vs {ev}");
        }
    }

    #[test]
    fn clamps_at_extremes() {
        let p = XbarParams::default();
        let x = Matrix::from_fn(1, p.rows, |_, _| (1 << p.input_bits) - 1);
        let w = Matrix::from_fn(p.rows, 2, |_, _| (1 << (p.weight_bits - 1)) - 1);
        assert_eq!(vmm(&x, &w, &p).at(0, 0), (1 << (p.out_bits - 1)) - 1);
        let wn = Matrix::from_fn(p.rows, 2, |_, _| -(1 << (p.weight_bits - 1)));
        assert_eq!(vmm(&x, &wn, &p).at(0, 0), -(1 << (p.out_bits - 1)));
    }

    #[test]
    fn lossy_adc_deviates_but_deterministically() {
        let p = XbarParams {
            adc_bits: 6,
            out_shift: 0,
            ..XbarParams::default()
        };
        let (x, w) = rand_xw(5, 2, 4, &p);
        let a = vmm_raw(&x, &w, &p, false);
        let b = vmm_raw(&x, &w, &p, false);
        assert_eq!(a, b);
        assert_ne!(a, matmul(&x, &w));
    }

    #[test]
    fn zero_in_zero_out() {
        let p = XbarParams::default();
        let x = Matrix::zeros(2, p.rows);
        let w = Matrix::from_fn(p.rows, 3, |r, c| (r + c) as i64);
        assert!(vmm(&x, &w, &p).data.iter().all(|&v| v == 0));
    }

    #[test]
    fn installed_run_is_bit_identical_to_reference_engine() {
        // the install/run refactor (and the install-time hoist of the
        // lossless flag) must not move a single bit, in any ADC regime
        for (adc_bits, out_shift, adaptive) in
            [(9, 10, false), (9, 10, true), (6, 0, false), (7, 4, true)]
        {
            let p = XbarParams {
                adc_bits,
                out_shift,
                ..XbarParams::default()
            };
            let (x, w) = rand_xw(11 + adc_bits as u64, 5, 12, &p);
            let programmed = ProgrammedXbar::install(&w, &p, adaptive);
            assert_eq!(
                programmed.run(&x),
                reference::vmm_raw_reference(&x, &w, &p, adaptive),
                "adc={adc_bits} shift={out_shift} adaptive={adaptive}"
            );
        }
    }

    #[test]
    fn signed_run_is_bit_identical_to_reference_engine() {
        let p = XbarParams::default();
        let mut rng = Rng::new(77);
        let x = Matrix::from_fn(3, p.rows, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        let w = Matrix::from_fn(p.rows, 6, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        for adaptive in [false, true] {
            let programmed = ProgrammedXbar::install(&w, &p, adaptive);
            assert_eq!(
                programmed.run_signed(&x),
                reference::vmm_raw_signed_reference(&x, &w, &p, adaptive)
            );
        }
    }

    #[test]
    fn repeated_runs_on_one_install_do_not_interfere() {
        // scratch reuse must be observationally pure, across both engines
        let p = XbarParams {
            adc_bits: 7,
            ..XbarParams::default()
        };
        let (x1, w) = rand_xw(21, 4, 10, &p);
        let (x2, _) = rand_xw(22, 4, 10, &p);
        let programmed = ProgrammedXbar::install(&w, &p, true);
        let first = programmed.run(&x1);
        let _ = programmed.run(&x2); // interleave a different batch
        let again = programmed.run(&x1);
        assert_eq!(first, again);
        let mut scratch = programmed.scratch();
        assert_eq!(programmed.run_with_scratch(&x1, &mut scratch), first);
        let _ = programmed.run_with_scratch(&x2, &mut scratch);
        assert_eq!(programmed.run_with_scratch(&x1, &mut scratch), first);
    }

    #[test]
    fn run_window_matches_column_slice() {
        let p = XbarParams::default();
        let mut rng = Rng::new(31);
        let wide = Matrix::from_fn(3, 2 * p.rows, |_, _| rng.range_i64(0, 1 << 16));
        let w = Matrix::from_fn(p.rows, 5, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        let programmed = ProgrammedXbar::install(&w, &p, false);
        let sliced = Matrix::from_fn(3, p.rows, |r, c| wide.at(r, p.rows + c));
        assert_eq!(programmed.run_window(&wide, p.rows), programmed.run(&sliced));
    }

    #[test]
    fn fused_fast_path_engages_only_when_lossless() {
        let p = XbarParams::default();
        let w = Matrix::zeros(p.rows, 2);
        assert!(ProgrammedXbar::install(&w, &p, false).is_fused());
        assert!(!ProgrammedXbar::install(&w, &p, true).is_fused());
        let lossy = XbarParams {
            adc_bits: 8,
            ..XbarParams::default()
        };
        assert!(!ProgrammedXbar::install(&w, &lossy, false).is_fused());
    }

    #[test]
    fn parallel_batch_split_matches_sequential() {
        // large enough to cross the parallel-split threshold
        let p = XbarParams {
            adc_bits: 8, // lossy: exercises the slice engine in parallel
            ..XbarParams::default()
        };
        let (x, w) = rand_xw(41, 16, 64, &p);
        let programmed = ProgrammedXbar::install(&w, &p, false);
        let parallel = programmed.run(&x);
        let mut scratch = programmed.scratch();
        let sequential = programmed.run_with_scratch(&x, &mut scratch);
        assert_eq!(parallel, sequential);
    }
}
