//! Bit-accurate functional model of the analog crossbar pipeline — the rust
//! twin of `python/compile/kernels/crossbar.py` (L1).
//!
//! Used by the coordinator's golden-model verification path, the examples
//! that run without PJRT, and the property tests that pin down the numeric
//! contract the artifacts must satisfy: with the default lossless ADC the
//! whole pipeline equals `clamp(round_half_up((x @ w) >> out_shift))`.
//!
//! Hot-path layout (rust/PERF.md): weights are *installed once* into a
//! [`ProgrammedXbar`] — bias encoding, cell-plane slicing, the per-column
//! `colsum(Wb)` correction, and the lossless/adaptive ADC decision all
//! happen at install time, mirroring the paper's in-situ premise that a
//! crossbar is programmed once and read many times. Identity-ADC configs
//! take a fused masked-matmul path; everything else (adaptive, lossy —
//! the configurations the paper's fidelity sweeps live in) runs the
//! **digit-major slice engine**: cell planes stored k-major (`K × slices
//! × N`, one contiguous block per input digit), per-slice zero/uniform
//! classification at install, and per-row DAC digits extracted once into
//! a [`RunScratch`]-owned digit plane. `run(&x)` parallelises across
//! batch rows. The historical free functions ([`biased_product`],
//! [`vmm_raw`], [`vmm_raw_signed`], [`vmm`]) are thin install-and-run
//! wrappers; the pre-refactor per-call engine survives verbatim in
//! [`reference`] as the oracle the property tests compare against.

pub mod cnn;
pub mod noise;
pub mod reference;

use crate::config::XbarParams;

/// Engine op counters (process-global, cached `Arc`s — one registry lock
/// per process, then a relaxed add per `accumulate_into` call, never per
/// row or per sample): fused vs slice-engine VMM rows, plus the logical
/// ADC sample count a real chip would have digitised for the same work
/// (`rows × iters × slices × n`, the paper's ADC-pressure accounting).
static FUSED_ROWS: std::sync::OnceLock<std::sync::Arc<crate::obs::Counter>> =
    std::sync::OnceLock::new();
static SLICE_ROWS: std::sync::OnceLock<std::sync::Arc<crate::obs::Counter>> =
    std::sync::OnceLock::new();
static ADC_SAMPLES: std::sync::OnceLock<std::sync::Arc<crate::obs::Counter>> =
    std::sync::OnceLock::new();

/// A dense signed matrix in row-major order.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> i64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    /// Reshape in place to an all-zero `rows × cols` matrix, keeping the
    /// allocation when capacity suffices — the scratch-reuse primitive the
    /// forward buffers (`cnn::ForwardScratch`) are built on.
    pub fn reset_zeroed(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0);
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i64) {
        self.data[r * self.cols + c] = v;
    }
}

/// Plain exact matmul (the oracle).
pub fn matmul(x: &Matrix, w: &Matrix) -> Matrix {
    assert_eq!(x.cols, w.rows);
    let mut out = Matrix::zeros(x.rows, w.cols);
    for r in 0..x.rows {
        for k in 0..x.cols {
            let xv = x.at(r, k);
            if xv == 0 {
                continue;
            }
            for c in 0..w.cols {
                out.data[r * w.cols + c] += xv * w.at(k, c);
            }
        }
    }
    out
}

/// ADC digitisation of one analog column sum (mirrors `adc_sample`).
fn adc_sample(col_sum: i64, place: u32, p: &XbarParams, adaptive: bool) -> i64 {
    let mut q = col_sum;
    let lossy = p.lossless_adc_bits() as i64 - p.adc_bits as i64;
    if lossy > 0 {
        let half = 1i64 << (lossy - 1);
        q = ((q + half) >> lossy) << lossy;
    }
    if adaptive && place < p.out_shift {
        let d = (p.out_shift - place) as i64;
        let half = 1i64 << (d - 1);
        q = ((q + half) >> d) << d;
    }
    q
}

/// All-ones mask over the low `bits` bits (saturating below the sign bit).
fn mask_bits(bits: u32) -> i64 {
    if bits >= 63 {
        i64::MAX
    } else {
        (1i64 << bits) - 1
    }
}

/// Reusable per-thread scratch for [`ProgrammedXbar::run_with_scratch`]:
/// the `dense × N` analog column sums of one DAC iteration plus the
/// current row's digit plane (`iters × K` DAC digits, extracted once per
/// row) and per-iteration digit sums — so steady-state runs allocate
/// nothing but their output. Sized by [`ProgrammedXbar::scratch`] for one
/// installation; do not share across installs.
pub struct RunScratch {
    cols: Vec<i64>,
    digits: Vec<i64>,
    digit_sums: Vec<i64>,
    /// Hardware cost counted by runs using this scratch (plain `u64`s, no
    /// allocation), accrued only while `obs::ledger::enabled()`. Callers
    /// that attribute cost (stage/batch aggregation in `cnn`/`coordinator`)
    /// snapshot and reset it between units of work; it never feeds back
    /// into the numerics.
    pub ledger: crate::obs::CostLedger,
}

impl RunScratch {
    /// Empty scratch: any engine grows it to its own geometry on use
    /// (`ProgrammedXbar::ensure_scratch`), so one scratch can serve
    /// several installations — e.g. every chunk of a [`cnn::ProgrammedLinear`].
    pub fn empty() -> Self {
        RunScratch {
            cols: Vec::new(),
            digits: Vec::new(),
            digit_sums: Vec::new(),
            ledger: crate::obs::CostLedger::new(),
        }
    }

    /// Take the accrued cost ledger, leaving zeros (the delta-capture
    /// primitive for per-stage and per-batch attribution).
    pub fn take_ledger(&mut self) -> crate::obs::CostLedger {
        std::mem::take(&mut self.ledger)
    }
}

impl Default for RunScratch {
    fn default() -> Self {
        Self::empty()
    }
}

/// A crossbar with weights installed once and read many times — the
/// in-situ compute model of the paper made literal in software.
///
/// Install time does all data-independent work: ISAAC bias encoding
/// (`Wb = w + 2^(wb-1)`), cell-plane slicing, the per-column `colsum(Wb)`
/// needed by the signed-input correction, and the lossless/adaptive ADC
/// decision. When every ADC sample is an identity (lossless config,
/// non-adaptive), install also selects a fused fast path that is
/// algebraically — and therefore bit — identical to the bit-serial sweep:
/// the place-value sums telescope back into a plain masked matmul, so no
/// cell planes are materialised at all.
///
/// For every other config the **digit-major slice engine** is installed:
///
/// * planes are stored k-major (`K × dense × N`), so the digit of one
///   input row touches a single contiguous `dense × N` block instead of
///   striding `s · K · N` apart per slice;
/// * each slice is classified once — an all-zero plane is dropped
///   entirely (it digitises to 0 in every regime), a *uniform* plane
///   (every cell the same value, e.g. a bias-encoding constant slice of
///   narrow weights) is folded into one quantise-and-broadcast per
///   iteration instead of `K × N` work; only the remaining *dense*
///   slices are materialised;
/// * at run time each row's DAC digits are extracted once into the
///   scratch digit plane, all-zero iterations are skipped outright, and
///   identity-ADC samples of adaptive schedules fold straight into the
///   accumulator without the quantise call.
///
/// `run` borrows `&self` and is thread-safe; large batches are split
/// across the work-stealing executor, each worker with its own
/// [`RunScratch`]. All of it is wall-clock only: the engine is pinned
/// bit-for-bit against [`reference`] across every ADC regime.
pub struct ProgrammedXbar {
    p: XbarParams,
    in_bits: u32,
    w_bits: u32,
    adaptive: bool,
    kdim: usize,
    n: usize,
    slices: usize,
    iters: usize,
    /// Identity-ADC config (install-time hoist of the per-iteration check).
    lossless: bool,
    /// Fused masked-matmul path: lossless and non-adaptive.
    fast: bool,
    /// `2^(weight_bits-1)` when installed from signed weights, else 0.
    w_bias: i64,
    /// Mask reconstructing exactly the bits the DAC sweep would stream.
    in_mask: i64,
    dac_mask: i64,
    /// Digit-major cell planes, flat `K × dense × N`: the dense slices of
    /// row k are one contiguous block (empty on the fast path).
    planes: Vec<i64>,
    /// Place shift (`s · cell_bits`) of each materialised (dense) slice.
    dense_shifts: Vec<u32>,
    /// Uniform slices as `(cell value, place shift)`: every cell of the
    /// plane holds the same non-zero value, so its column sum is
    /// `value × digit_sum` — one quantise per iteration, broadcast.
    uniform_slices: Vec<(i64, u32)>,
    /// All-zero slices dropped at install (they digitise to 0).
    zero_slices: usize,
    /// Biased weight matrix, masked to the bits the cell planes hold.
    wb: Vec<i64>,
    /// Per-column sum of the (unmasked) biased weights, for `run_signed`.
    colsum_wb: Vec<i64>,
}

impl ProgrammedXbar {
    /// Install signed weights (ISAAC bias encoding applied here, once).
    ///
    /// # Examples
    ///
    /// Install once, run many — with the default lossless config the raw
    /// crossbar product equals a plain matmul bit-for-bit:
    ///
    /// ```
    /// use newton::config::XbarParams;
    /// use newton::xbar::{matmul, scale_clamp, Matrix, ProgrammedXbar};
    ///
    /// let p = XbarParams::default();
    /// let w = Matrix::from_fn(p.rows, 4, |r, c| (r as i64 % 7) - 3 + c as i64);
    /// let xbar = ProgrammedXbar::install(&w, &p, false);
    /// let x = Matrix::from_fn(2, p.rows, |_, c| c as i64);
    /// assert_eq!(xbar.run(&x), matmul(&x, &w));
    /// let logits = scale_clamp(&xbar.run(&x), &p); // the full pipeline
    /// assert_eq!(logits.rows, 2);
    /// ```
    pub fn install(w: &Matrix, p: &XbarParams, adaptive: bool) -> Self {
        let bias = 1i64 << (p.weight_bits - 1);
        let wb = Matrix::from_fn(w.rows, w.cols, |r, c| w.at(r, c) + bias);
        let mut programmed = Self::install_biased(&wb, p.input_bits, p.weight_bits, p, adaptive);
        programmed.w_bias = bias;
        programmed
    }

    /// Install an already-biased (unsigned) weight matrix with explicit
    /// streaming widths — the programmed form of [`biased_product`].
    pub fn install_biased(
        wb: &Matrix,
        in_bits: u32,
        w_bits: u32,
        p: &XbarParams,
        adaptive: bool,
    ) -> Self {
        assert!(wb.rows <= p.rows, "reduction dim exceeds crossbar rows");
        let iters = (in_bits as usize).div_ceil(p.dac_bits as usize);
        let slices = (w_bits as usize).div_ceil(p.cell_bits as usize);
        let (kdim, n) = (wb.rows, wb.cols);
        let lossless = p.lossless_adc_bits() <= p.adc_bits;
        let fast = lossless && !adaptive;
        let in_mask = mask_bits(iters as u32 * p.dac_bits);
        let w_mask = mask_bits(slices as u32 * p.cell_bits);
        let cell_mask = (1i64 << p.cell_bits) - 1;

        let wb_masked: Vec<i64> = wb.data.iter().map(|&v| v & w_mask).collect();
        let mut colsum_wb = vec![0i64; n];
        for k in 0..kdim {
            for (sum, &v) in colsum_wb.iter_mut().zip(&wb.data[k * n..k * n + n]) {
                *sum += v;
            }
        }

        // install-time slice classification: an all-zero plane contributes
        // an exact 0 through every ADC regime (rounding of 0 is 0) so it
        // is dropped; a uniform plane's column sum is value × digit-sum,
        // so it needs no materialised cells; the rest are dense
        let mut dense_shifts = Vec::new();
        let mut uniform_slices = Vec::new();
        let mut zero_slices = 0usize;
        if !fast {
            for s in 0..slices {
                let shift = s as u32 * p.cell_bits;
                let cell = |v: i64| (v >> shift) & cell_mask;
                let first = wb.data.first().map_or(0, |&v| cell(v));
                if wb.data.iter().all(|&v| cell(v) == first) {
                    if first == 0 {
                        zero_slices += 1;
                    } else {
                        uniform_slices.push((first, shift));
                    }
                } else {
                    dense_shifts.push(shift);
                }
            }
        }

        // digit-major weight slicing: planes[k][j][c], flat — the dense
        // slices of one reduction row are contiguous, so streaming one
        // input digit reads one `dense × n` block instead of striding
        // `s·K·N` apart per slice. The fast path reads the fused `wb`
        // buffer instead, so no planes are materialised there at all.
        let dense = dense_shifts.len();
        let planes = if fast || dense == 0 {
            Vec::new()
        } else {
            let mut planes = vec![0i64; kdim * dense * n];
            for k in 0..kdim {
                let src = &wb.data[k * n..k * n + n];
                for (j, &shift) in dense_shifts.iter().enumerate() {
                    let dst = &mut planes[(k * dense + j) * n..(k * dense + j + 1) * n];
                    for (d, &v) in dst.iter_mut().zip(src) {
                        *d = (v >> shift) & cell_mask;
                    }
                }
            }
            planes
        };

        ProgrammedXbar {
            p: *p,
            in_bits,
            w_bits,
            adaptive,
            kdim,
            n,
            slices,
            iters,
            lossless,
            fast,
            w_bias: 0,
            in_mask,
            dac_mask: (1i64 << p.dac_bits) - 1,
            planes,
            dense_shifts,
            uniform_slices,
            zero_slices,
            wb: wb_masked,
            colsum_wb,
        }
    }

    /// Reduction length (crossbar rows in use).
    pub fn kdim(&self) -> usize {
        self.kdim
    }

    /// Output columns.
    pub fn n(&self) -> usize {
        self.n
    }

    /// DAC iterations one VMM streams.
    pub fn iters(&self) -> usize {
        self.iters
    }

    /// Weight cell planes (crossbar slices) one VMM reads — the logical
    /// count; see [`Self::slice_profile`] for what install materialised.
    pub fn slices(&self) -> usize {
        self.slices
    }

    /// Logical ADC samples one VMM digitises per output column.
    pub fn adc_samples_per_column(&self) -> usize {
        self.iters * self.slices
    }

    /// `(input, weight)` streaming widths the installation was built for.
    pub fn stream_widths(&self) -> (u32, u32) {
        (self.in_bits, self.w_bits)
    }

    /// Whether install selected the fused identity-ADC fast path.
    pub fn is_fused(&self) -> bool {
        self.fast
    }

    /// `(dense, uniform, zero)` slice classification of this installation:
    /// dense slices are materialised k-major, uniform slices fold into one
    /// quantise per iteration, zero slices are dropped. Sums to
    /// [`Self::slices`] on the slice engine; all zero on the fused path.
    pub fn slice_profile(&self) -> (usize, usize, usize) {
        (
            self.dense_shifts.len(),
            self.uniform_slices.len(),
            self.zero_slices,
        )
    }

    /// Resolved bit-width of one quantising ADC conversion at `place`:
    /// the deployed resolution (capped at the lossless budget) minus the
    /// bits the adaptive schedule gates below the kept output window —
    /// the bucket key of [`crate::obs::CostLedger::adc_ops_by_bits`].
    fn resolved_adc_bits(&self, place: u32) -> u32 {
        let base = self.p.adc_bits.min(self.p.lossless_adc_bits());
        if self.adaptive && place < self.p.out_shift {
            base.saturating_sub(self.p.out_shift - place)
        } else {
            base
        }
    }

    /// Fresh scratch sized for this installation.
    pub fn scratch(&self) -> RunScratch {
        let mut s = RunScratch::empty();
        self.ensure_scratch(&mut s);
        s
    }

    /// Grow `scratch` to this installation's geometry (idempotent, keeps
    /// the allocations). Safe across installs: the run loops overwrite
    /// every element they read (`digit_sums.fill(0)`, a full digit
    /// rewrite per row, `cols.fill(0)` per iteration), so stale contents
    /// from another installation cannot leak into results.
    fn ensure_scratch(&self, scratch: &mut RunScratch) {
        if self.fast {
            return; // the fused path touches no scratch
        }
        scratch.cols.resize(self.dense_shifts.len() * self.n, 0);
        scratch.digits.resize(self.iters * self.kdim, 0);
        scratch.digit_sums.resize(self.iters, 0);
    }

    /// Raw product for unsigned inputs against the installed weights;
    /// equals `vmm_raw(x, w, ..)` when installed via [`Self::install`], or
    /// `biased_product(x, wb, ..)` when installed via
    /// [`Self::install_biased`].
    pub fn run(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.kdim);
        self.run_window(x, 0)
    }

    /// Like [`Self::run`], but reads the reduction slice
    /// `x[:, x_col0 .. x_col0 + kdim]` in place — chunked layers stream one
    /// wide activation matrix through several installed crossbars without
    /// copying column windows out.
    pub fn run_window(&self, x: &Matrix, x_col0: usize) -> Matrix {
        let mut raw = self.raw_product(x, x_col0, 0);
        self.correct_w_bias(x, x_col0, &mut raw);
        raw
    }

    /// [`Self::run_window`] with the batch-row fan-out forced onto a
    /// caller-sized executor (1 worker = sequential on the caller thread)
    /// — the property tests pin bit-identity across worker counts here.
    pub fn run_window_on(&self, x: &Matrix, x_col0: usize, exec: &crate::sched::Executor) -> Matrix {
        let mut raw = Matrix::zeros(x.rows, self.n);
        self.accumulate_into(x, x_col0, 0, &mut raw.data, Some(exec), None);
        self.correct_w_bias(x, x_col0, &mut raw);
        raw
    }

    /// Accumulating variant of [`Self::run_window`]: adds this crossbar's
    /// (bias-corrected) window product into `acc` in place. Chunked layers
    /// ([`cnn::ProgrammedLinear`]) sum their raw partials straight into one
    /// caller-owned accumulator instead of allocating a partial matrix per
    /// chunk per call.
    pub fn run_window_acc(&self, x: &Matrix, x_col0: usize, acc: &mut Matrix) {
        self.run_window_acc_with(x, x_col0, acc, &mut RunScratch::empty());
    }

    /// [`Self::run_window_acc`] reusing a caller-owned [`RunScratch`]
    /// (grown to this installation's geometry in place), so sequential
    /// chunk sweeps allocate nothing at all. The scratch serves the
    /// single-threaded path; if the batch is large enough to fan out,
    /// each worker still brings its own.
    pub fn run_window_acc_with(
        &self,
        x: &Matrix,
        x_col0: usize,
        acc: &mut Matrix,
        scratch: &mut RunScratch,
    ) {
        assert_eq!(acc.rows, x.rows, "accumulator row mismatch");
        assert_eq!(acc.cols, self.n, "accumulator column mismatch");
        self.accumulate_into(x, x_col0, 0, &mut acc.data, None, Some(scratch));
        self.correct_w_bias(x, x_col0, acc);
    }

    /// Signed-weight correction: subtract `Bw * rowsum(x)` digitally.
    fn correct_w_bias(&self, x: &Matrix, x_col0: usize, raw: &mut Matrix) {
        if self.w_bias == 0 {
            return;
        }
        for r in 0..x.rows {
            let sx: i64 = (0..self.kdim).map(|k| x.at(r, x_col0 + k)).sum();
            for v in raw.data[r * self.n..(r + 1) * self.n].iter_mut() {
                *v -= self.w_bias * sx;
            }
        }
    }

    /// Signed-input raw product (both operand biases corrected digitally,
    /// §III-A2); equals `vmm_raw_signed(x, w, ..)`. Uses the install-time
    /// `colsum(Wb)`.
    pub fn run_signed(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.kdim);
        assert!(
            self.w_bias != 0,
            "run_signed needs signed-weight installation (ProgrammedXbar::install)"
        );
        let bi = 1i64 << (self.in_bits - 1);
        let bw = self.w_bias;
        let k = self.kdim as i64;
        let mut raw = self.raw_product(x, 0, bi);
        for r in 0..x.rows {
            let rowsum: i64 = (0..self.kdim).map(|j| x.at(r, j) + bi).sum();
            let out = &mut raw.data[r * self.n..(r + 1) * self.n];
            for (c, v) in out.iter_mut().enumerate() {
                *v += k * bi * bw - bw * rowsum - bi * self.colsum_wb[c];
            }
        }
        raw
    }

    /// Full pipeline against the installed weights:
    /// `clamp(round((x @ w) >> out_shift))` for lossless configs.
    pub fn vmm(&self, x: &Matrix) -> Matrix {
        scale_clamp(&self.run(x), &self.p)
    }

    /// Sequential run reusing caller-owned scratch: zero allocation beyond
    /// the output once the scratch exists. Bit-identical to [`Self::run`].
    pub fn run_with_scratch(&self, x: &Matrix, scratch: &mut RunScratch) -> Matrix {
        assert_eq!(x.cols, self.kdim);
        self.ensure_scratch(scratch);
        let n = self.n;
        let mut acc = Matrix::zeros(x.rows, n);
        if n == 0 {
            return acc;
        }
        for (r, out) in acc.data.chunks_mut(n).enumerate() {
            self.run_row(x, r, 0, 0, out, scratch);
        }
        self.correct_w_bias(x, 0, &mut acc);
        acc
    }

    /// Approximate i64 ops per batch row, for the parallel-split decision.
    fn work_per_row(&self) -> usize {
        if self.fast {
            self.kdim * self.n
        } else {
            self.iters * self.kdim * self.dense_shifts.len().max(1) * self.n
        }
    }

    /// Biased product of `(x[:, x_col0..] + x_off)` against the planes.
    fn raw_product(&self, x: &Matrix, x_col0: usize, x_off: i64) -> Matrix {
        let mut acc = Matrix::zeros(x.rows, self.n);
        self.accumulate_into(x, x_col0, x_off, &mut acc.data, None, None);
        acc
    }

    /// Core engine: accumulate the biased product of `(x[:, x_col0..] +
    /// x_off)` into `acc` (`rows × n`, += semantics). `exec` pins the
    /// batch-row fan-out to a caller-sized executor; `None` sizes it
    /// automatically (sequential below the work threshold and inside sched
    /// workers, where the outer decomposition owns the pool). `scratch`
    /// is reused on the sequential path (grown in place); workers of a
    /// parallel fan-out always bring their own.
    fn accumulate_into(
        &self,
        x: &Matrix,
        x_col0: usize,
        x_off: i64,
        acc: &mut [i64],
        exec: Option<&crate::sched::Executor>,
        scratch: Option<&mut RunScratch>,
    ) {
        assert!(x_col0 + self.kdim <= x.cols, "window exceeds input columns");
        let n = self.n;
        assert_eq!(acc.len(), x.rows * n, "accumulator shape mismatch");
        if n == 0 || x.rows == 0 {
            return;
        }
        if self.fast {
            FUSED_ROWS
                .get_or_init(|| crate::obs::counter("xbar.fused_vmm_rows"))
                .add(x.rows as u64);
        } else {
            SLICE_ROWS
                .get_or_init(|| crate::obs::counter("xbar.slice_vmm_rows"))
                .add(x.rows as u64);
            ADC_SAMPLES
                .get_or_init(|| crate::obs::counter("xbar.adc_samples"))
                .add((x.rows * self.iters * self.slices * n) as u64);
        }
        // split across cores only when the work dwarfs thread spawn cost —
        // and never from inside a sched worker: the outer job decomposition
        // (per-image forward, batch serving) owns the pool, and nesting a
        // per-VMM fan-out under it would thrash ~cores² threads per read
        let workers = match exec {
            Some(e) => e.workers().min(x.rows),
            None => {
                if x.rows >= 2
                    && x.rows * self.work_per_row() >= 1 << 20
                    && !crate::sched::in_worker()
                {
                    crate::util::worker_count(x.rows)
                } else {
                    1
                }
            }
        };
        if workers <= 1 {
            let mut owned;
            let scratch = match scratch {
                Some(s) => {
                    self.ensure_scratch(s);
                    s
                }
                None => {
                    owned = self.scratch();
                    &mut owned
                }
            };
            for (r, out) in acc.chunks_mut(n).enumerate() {
                self.run_row(x, r, x_col0, x_off, out, scratch);
            }
        } else {
            // batch rows fan out through the work-stealing executor
            // (crate::sched), ~2 row-chunk jobs per worker so stealing can
            // even out OS-timing skew. Each job claims its disjoint &mut
            // chunk of the output (one uncontended lock per chunk) and
            // writes rows in place — no per-call buffers or copy-back —
            // with a private scratch, bit-identical to the sequential loop.
            // Each job returns its private scratch's cost ledger so the
            // fan-out loses no counts: they merge into the caller scratch
            // (cost attribution needs a scratch to land in — callers that
            // pass None get no ledger, by design).
            let pool = match exec {
                Some(e) => *e,
                None => crate::sched::Executor::new(workers),
            };
            let rows_per = x.rows.div_ceil(workers * 2).max(1);
            let chunk_slots: Vec<std::sync::Mutex<Option<&mut [i64]>>> = acc
                .chunks_mut(rows_per * n)
                .map(|c| std::sync::Mutex::new(Some(c)))
                .collect();
            let ledgers = pool.map(chunk_slots.len(), |ci| {
                let chunk = chunk_slots[ci]
                    .lock()
                    .unwrap()
                    .take()
                    .expect("output chunk claimed exactly once");
                let mut scratch = self.scratch();
                for (j, out) in chunk.chunks_mut(n).enumerate() {
                    self.run_row(x, ci * rows_per + j, x_col0, x_off, out, &mut scratch);
                }
                scratch.ledger
            });
            if let Some(s) = scratch {
                for l in &ledgers {
                    s.ledger.merge(l);
                }
            }
        }
    }

    /// One batch row through the pipeline, accumulating into `out`.
    fn run_row(
        &self,
        x: &Matrix,
        r: usize,
        x_col0: usize,
        x_off: i64,
        out: &mut [i64],
        scratch: &mut RunScratch,
    ) {
        let n = self.n;
        let ledger_on = crate::obs::ledger::enabled();
        if self.fast {
            if ledger_on {
                // every sample of an identity-ADC config telescopes away,
                // so the whole row's ADC work is an analytic identity count
                let l = &mut scratch.ledger;
                l.fused_rows += 1;
                l.row_elems += self.kdim as u64;
                l.identity_folds += (self.iters * self.slices * n) as u64;
            }
            // identity-ADC configs telescope back into a masked matmul:
            // sum_i sum_s (x_bits_i @ w_slice_s) << place == (x & m) @ (Wb & m')
            for k in 0..self.kdim {
                let xv = (x.at(r, x_col0 + k) + x_off) & self.in_mask;
                if xv == 0 {
                    continue;
                }
                let row = &self.wb[k * n..k * n + n];
                if xv == 1 {
                    for (o, &v) in out.iter_mut().zip(row) {
                        *o += v;
                    }
                } else {
                    for (o, &v) in out.iter_mut().zip(row) {
                        *o += xv * v;
                    }
                }
            }
            return;
        }

        // digit-major slice engine. Split borrows: digits/digit_sums are
        // read-only once extracted, cols stays the mutable accumulator.
        let RunScratch {
            cols,
            digits,
            digit_sums,
            ledger,
        } = scratch;
        let kdim = self.kdim;
        if ledger_on {
            ledger.slice_rows += 1;
            ledger.row_elems += kdim as u64;
        }

        // 1. extract this row's DAC digits once (iteration-major `iters ×
        // kdim` plane) and the per-iteration digit sums. Iterated
        // arithmetic shifts compose, so digit i equals the reference's
        // `(xv >> (i·dac_bits)) & dac_mask` bit-for-bit.
        digit_sums.fill(0);
        for k in 0..kdim {
            let mut xv = x.at(r, x_col0 + k) + x_off;
            for i in 0..self.iters {
                let d = xv & self.dac_mask;
                digits[i * kdim + k] = d;
                digit_sums[i] += d;
                xv >>= self.p.dac_bits;
            }
        }

        let dense = self.dense_shifts.len();
        for i in 0..self.iters {
            if digit_sums[i] == 0 {
                // every digit of this iteration is zero (digits are
                // non-negative): all column sums are 0 and 0 digitises to
                // 0 in every regime, so the whole iteration is skipped —
                // u8-range activations streamed at 16 input bits skip
                // half their iterations here
                if ledger_on {
                    ledger.iters_skipped += 1;
                    ledger.slice_iters_skipped += self.slices as u64;
                }
                continue;
            }
            if ledger_on {
                ledger.iters_executed += 1;
                ledger.slice_iters_executed += dense as u64;
                ledger.slice_iters_folded += self.uniform_slices.len() as u64;
                ledger.slice_iters_skipped += self.zero_slices as u64;
            }
            let iter_place = i as u32 * self.p.dac_bits;
            if dense > 0 {
                cols.fill(0);
                let row_digits = &digits[i * kdim..(i + 1) * kdim];
                for (k, &xb) in row_digits.iter().enumerate() {
                    if xb == 0 {
                        continue;
                    }
                    // one contiguous `dense × n` block per input digit
                    let block = &self.planes[k * dense * n..(k + 1) * dense * n];
                    if xb == 1 {
                        for (dst, &src) in cols.iter_mut().zip(block) {
                            *dst += src;
                        }
                    } else {
                        for (dst, &src) in cols.iter_mut().zip(block) {
                            *dst += xb * src;
                        }
                    }
                }
                for (j, &shift) in self.dense_shifts.iter().enumerate() {
                    let place = iter_place + shift;
                    let src = &cols[j * n..(j + 1) * n];
                    if self.lossless && (!self.adaptive || place >= self.p.out_shift) {
                        if ledger_on {
                            ledger.identity_folds += n as u64;
                        }
                        // identity ADC: fold straight into the accumulator
                        for (o, &v) in out.iter_mut().zip(src) {
                            *o += v << place;
                        }
                    } else {
                        if ledger_on {
                            ledger.count_adc(self.resolved_adc_bits(place), n as u64);
                        }
                        for (o, &v) in out.iter_mut().zip(src) {
                            *o += adc_sample(v, place, &self.p, self.adaptive) << place;
                        }
                    }
                }
            }
            // uniform slices: the column sum is value × digit-sum for every
            // column, so quantise once and broadcast (i64 addition is
            // exact, so reordering slice contributions moves no bits)
            for &(v, shift) in &self.uniform_slices {
                let place = iter_place + shift;
                let col = v * digit_sums[i];
                let q = if self.lossless && (!self.adaptive || place >= self.p.out_shift) {
                    if ledger_on {
                        ledger.identity_folds += n as u64;
                    }
                    col
                } else {
                    if ledger_on {
                        ledger.count_adc(self.resolved_adc_bits(place), n as u64);
                    }
                    adc_sample(col, place, &self.p, self.adaptive)
                };
                if q != 0 {
                    let add = q << place;
                    for o in out.iter_mut() {
                        *o += add;
                    }
                }
            }
        }
    }
}

/// Raw biased product `x @ wb` through the bit-serial + ADC pipeline.
/// `x` unsigned (`in_bits` wide), `wb` unsigned (`w_bits` wide).
///
/// Thin wrapper: installs a [`ProgrammedXbar`] and runs once. Call sites
/// that reuse one weight matrix should install once and call `run` per
/// batch instead (rust/PERF.md).
pub fn biased_product(
    x: &Matrix,
    wb: &Matrix,
    in_bits: u32,
    w_bits: u32,
    p: &XbarParams,
    adaptive: bool,
) -> Matrix {
    assert_eq!(x.cols, wb.rows);
    ProgrammedXbar::install_biased(wb, in_bits, w_bits, p, adaptive).run(x)
}

/// Signed raw product via bias encoding (ISAAC): store `w + 2^(wb-1)`,
/// subtract `2^(wb-1) * sum(x)` digitally. Install-and-run wrapper.
pub fn vmm_raw(x: &Matrix, w: &Matrix, p: &XbarParams, adaptive: bool) -> Matrix {
    ProgrammedXbar::install(w, p, adaptive).run(x)
}

/// Signed-input variant: offsets inputs into the unsigned DAC window and
/// corrects digitally (both operand biases applied). Needed by Strassen's
/// pre-subtractions, whose operands can be negative (§III-A2).
///
///   x@w = (X - Bi)(Wb - Bw) = X@Wb - Bw*rowsum(X) - Bi*colsum(Wb) + K*Bi*Bw
///
/// where X = x + Bi, Wb = w + Bw, K = reduction length. `colsum(Wb)` is
/// computed at weight-install time. Install-and-run wrapper.
pub fn vmm_raw_signed(x: &Matrix, w: &Matrix, p: &XbarParams, adaptive: bool) -> Matrix {
    ProgrammedXbar::install(w, p, adaptive).run_signed(x)
}

/// Scaling stage: round-half-up shift + clamp to the signed output window.
pub fn scale_clamp(raw: &Matrix, p: &XbarParams) -> Matrix {
    let half = if p.out_shift > 0 {
        1i64 << (p.out_shift - 1)
    } else {
        0
    };
    let lo = -(1i64 << (p.out_bits - 1));
    let hi = (1i64 << (p.out_bits - 1)) - 1;
    Matrix::from_fn(raw.rows, raw.cols, |r, c| {
        ((raw.at(r, c) + half) >> p.out_shift).clamp(lo, hi)
    })
}

/// Full pipeline: `clamp(round((x @ w) >> out_shift))` for lossless configs.
pub fn vmm(x: &Matrix, w: &Matrix, p: &XbarParams) -> Matrix {
    scale_clamp(&vmm_raw(x, w, p, false), p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_xw(seed: u64, b: usize, n: usize, p: &XbarParams) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(b, p.rows, |_, _| rng.range_i64(0, 1 << p.input_bits));
        let w = Matrix::from_fn(p.rows, n, |_, _| {
            rng.range_i64(-(1 << (p.weight_bits - 1)), 1 << (p.weight_bits - 1))
        });
        (x, w)
    }

    #[test]
    fn pipeline_is_exact_for_default_config() {
        let p = XbarParams::default();
        let (x, w) = rand_xw(1, 4, 16, &p);
        let got = vmm(&x, &w, &p);
        let want = scale_clamp(&matmul(&x, &w), &p);
        assert_eq!(got, want);
    }

    #[test]
    fn raw_matches_matmul() {
        let p = XbarParams::default();
        let (x, w) = rand_xw(2, 3, 8, &p);
        assert_eq!(vmm_raw(&x, &w, &p, false), matmul(&x, &w));
    }

    #[test]
    fn adaptive_within_one_ulp_here() {
        let p = XbarParams::default();
        let (x, w) = rand_xw(3, 3, 8, &p);
        let a = scale_clamp(&vmm_raw(&x, &w, &p, true), &p);
        let e = scale_clamp(&matmul(&x, &w), &p);
        for (av, ev) in a.data.iter().zip(e.data.iter()) {
            assert!((av - ev).abs() <= 2, "{av} vs {ev}");
        }
    }

    #[test]
    fn clamps_at_extremes() {
        let p = XbarParams::default();
        let x = Matrix::from_fn(1, p.rows, |_, _| (1 << p.input_bits) - 1);
        let w = Matrix::from_fn(p.rows, 2, |_, _| (1 << (p.weight_bits - 1)) - 1);
        assert_eq!(vmm(&x, &w, &p).at(0, 0), (1 << (p.out_bits - 1)) - 1);
        let wn = Matrix::from_fn(p.rows, 2, |_, _| -(1 << (p.weight_bits - 1)));
        assert_eq!(vmm(&x, &wn, &p).at(0, 0), -(1 << (p.out_bits - 1)));
    }

    #[test]
    fn lossy_adc_deviates_but_deterministically() {
        let p = XbarParams {
            adc_bits: 6,
            out_shift: 0,
            ..XbarParams::default()
        };
        let (x, w) = rand_xw(5, 2, 4, &p);
        let a = vmm_raw(&x, &w, &p, false);
        let b = vmm_raw(&x, &w, &p, false);
        assert_eq!(a, b);
        assert_ne!(a, matmul(&x, &w));
    }

    #[test]
    fn zero_in_zero_out() {
        let p = XbarParams::default();
        let x = Matrix::zeros(2, p.rows);
        let w = Matrix::from_fn(p.rows, 3, |r, c| (r + c) as i64);
        assert!(vmm(&x, &w, &p).data.iter().all(|&v| v == 0));
    }

    #[test]
    fn reset_zeroed_reshapes_and_clears() {
        let mut m = Matrix::from_fn(3, 4, |r, c| (r * 4 + c) as i64 + 1);
        let cap = m.data.capacity();
        m.reset_zeroed(2, 5);
        assert_eq!((m.rows, m.cols), (2, 5));
        assert!(m.data.iter().all(|&v| v == 0));
        // shrinking keeps the allocation (clear+resize never shrinks
        // capacity) — the scratch-reuse property the buffers depend on
        m.reset_zeroed(1, 2);
        assert!(m.data.capacity() >= cap, "reset_zeroed reallocated");
        assert_eq!(m.data, vec![0, 0]);
    }

    #[test]
    fn installed_run_is_bit_identical_to_reference_engine() {
        // the install/run refactor (and the install-time hoist of the
        // lossless flag) must not move a single bit, in any ADC regime
        for (adc_bits, out_shift, adaptive) in
            [(9, 10, false), (9, 10, true), (6, 0, false), (7, 4, true)]
        {
            let p = XbarParams {
                adc_bits,
                out_shift,
                ..XbarParams::default()
            };
            let (x, w) = rand_xw(11 + adc_bits as u64, 5, 12, &p);
            let programmed = ProgrammedXbar::install(&w, &p, adaptive);
            assert_eq!(
                programmed.run(&x),
                reference::vmm_raw_reference(&x, &w, &p, adaptive),
                "adc={adc_bits} shift={out_shift} adaptive={adaptive}"
            );
        }
    }

    #[test]
    fn signed_run_is_bit_identical_to_reference_engine() {
        let p = XbarParams::default();
        let mut rng = Rng::new(77);
        let x = Matrix::from_fn(3, p.rows, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        let w = Matrix::from_fn(p.rows, 6, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        for adaptive in [false, true] {
            let programmed = ProgrammedXbar::install(&w, &p, adaptive);
            assert_eq!(
                programmed.run_signed(&x),
                reference::vmm_raw_signed_reference(&x, &w, &p, adaptive)
            );
        }
    }

    #[test]
    fn zero_and_uniform_slices_are_classified_and_skipped() {
        // 4-aligned weights: Wb = w + 2^15 stays 4-aligned, so the low
        // 2-bit cell slice is all-zero and must be dropped at install —
        // while staying bit-identical to the reference sweep
        let p = XbarParams {
            adc_bits: 7,
            ..XbarParams::default()
        };
        let mut rng = Rng::new(91);
        let w = Matrix::from_fn(p.rows, 6, |_, _| rng.range_i64(-8, 8) * 4);
        let programmed = ProgrammedXbar::install(&w, &p, false);
        let (dense, uniform, zero) = programmed.slice_profile();
        assert_eq!(dense + uniform + zero, programmed.slices());
        assert!(zero >= 1, "low slice of 4-aligned weights is all zero");
        let x = Matrix::from_fn(3, p.rows, |_, _| rng.range_i64(0, 1 << 16));
        assert_eq!(
            programmed.run(&x),
            reference::vmm_raw_reference(&x, &w, &p, false)
        );

        // constant weights: every slice is uniform, none dense — covered
        // entirely by the quantise-and-broadcast fold, in both regimes
        let wu = Matrix::from_fn(p.rows, 4, |_, _| 5);
        for adaptive in [false, true] {
            let programmed = ProgrammedXbar::install(&wu, &p, adaptive);
            let (dense, uniform, zero) = programmed.slice_profile();
            assert_eq!(dense, 0, "constant weights have no dense slice");
            assert!(uniform >= 1);
            assert_eq!(dense + uniform + zero, programmed.slices());
            assert_eq!(
                programmed.run(&x),
                reference::vmm_raw_reference(&x, &wu, &p, adaptive),
                "adaptive={adaptive}"
            );
        }
    }

    #[test]
    fn sparse_high_input_bits_match_reference() {
        // u8-range activations streamed at 16 input bits: the top 8 DAC
        // iterations are all-zero and skipped outright — still bit-equal
        let p = XbarParams {
            adc_bits: 8,
            ..XbarParams::default()
        };
        let mut rng = Rng::new(97);
        let x = Matrix::from_fn(2, p.rows, |_, _| rng.range_i64(0, 256));
        let w = Matrix::from_fn(p.rows, 9, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        for adaptive in [false, true] {
            let programmed = ProgrammedXbar::install(&w, &p, adaptive);
            assert_eq!(
                programmed.run(&x),
                reference::vmm_raw_reference(&x, &w, &p, adaptive),
                "adaptive={adaptive}"
            );
        }
    }

    #[test]
    fn repeated_runs_on_one_install_do_not_interfere() {
        // scratch reuse must be observationally pure, across both engines
        let p = XbarParams {
            adc_bits: 7,
            ..XbarParams::default()
        };
        let (x1, w) = rand_xw(21, 4, 10, &p);
        let (x2, _) = rand_xw(22, 4, 10, &p);
        let programmed = ProgrammedXbar::install(&w, &p, true);
        let first = programmed.run(&x1);
        let _ = programmed.run(&x2); // interleave a different batch
        let again = programmed.run(&x1);
        assert_eq!(first, again);
        let mut scratch = programmed.scratch();
        assert_eq!(programmed.run_with_scratch(&x1, &mut scratch), first);
        let _ = programmed.run_with_scratch(&x2, &mut scratch);
        assert_eq!(programmed.run_with_scratch(&x1, &mut scratch), first);
    }

    #[test]
    fn run_window_matches_column_slice() {
        let p = XbarParams::default();
        let mut rng = Rng::new(31);
        let wide = Matrix::from_fn(3, 2 * p.rows, |_, _| rng.range_i64(0, 1 << 16));
        let w = Matrix::from_fn(p.rows, 5, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        let programmed = ProgrammedXbar::install(&w, &p, false);
        let sliced = Matrix::from_fn(3, p.rows, |r, c| wide.at(r, p.rows + c));
        assert_eq!(programmed.run_window(&wide, p.rows), programmed.run(&sliced));
    }

    #[test]
    fn run_window_acc_accumulates_in_place() {
        // the chunked-layer path: two windowed crossbars accumulated into
        // one caller-owned matrix equal the sum of their run_window parts
        let p = XbarParams {
            adc_bits: 8, // slice engine
            ..XbarParams::default()
        };
        let mut rng = Rng::new(53);
        let wide = Matrix::from_fn(3, 2 * p.rows, |_, _| rng.range_i64(0, 1 << 16));
        let wa = Matrix::from_fn(p.rows, 5, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        let wb = Matrix::from_fn(p.rows, 5, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        let a = ProgrammedXbar::install(&wa, &p, false);
        let b = ProgrammedXbar::install(&wb, &p, false);
        let mut acc = Matrix::zeros(3, 5);
        a.run_window_acc(&wide, 0, &mut acc);
        b.run_window_acc(&wide, p.rows, &mut acc);
        let mut want = a.run_window(&wide, 0);
        for (v, part) in want.data.iter_mut().zip(b.run_window(&wide, p.rows).data) {
            *v += part;
        }
        assert_eq!(acc, want);
    }

    #[test]
    fn fused_fast_path_engages_only_when_lossless() {
        let p = XbarParams::default();
        let w = Matrix::zeros(p.rows, 2);
        assert!(ProgrammedXbar::install(&w, &p, false).is_fused());
        assert!(!ProgrammedXbar::install(&w, &p, true).is_fused());
        let lossy = XbarParams {
            adc_bits: 8,
            ..XbarParams::default()
        };
        assert!(!ProgrammedXbar::install(&w, &lossy, false).is_fused());
    }

    #[test]
    fn parallel_batch_split_matches_sequential() {
        // large enough to cross the parallel-split threshold
        let p = XbarParams {
            adc_bits: 8, // lossy: exercises the slice engine in parallel
            ..XbarParams::default()
        };
        let (x, w) = rand_xw(41, 16, 64, &p);
        let programmed = ProgrammedXbar::install(&w, &p, false);
        let parallel = programmed.run(&x);
        let mut scratch = programmed.scratch();
        let sequential = programmed.run_with_scratch(&x, &mut scratch);
        assert_eq!(parallel, sequential);
    }

    #[test]
    fn forced_executor_fan_out_matches_sequential() {
        let p = XbarParams {
            adc_bits: 7,
            ..XbarParams::default()
        };
        let (x, w) = rand_xw(63, 5, 12, &p);
        let programmed = ProgrammedXbar::install(&w, &p, true);
        let want = programmed.run(&x);
        for workers in [1, 2, 8] {
            let got = programmed.run_window_on(&x, 0, &crate::sched::Executor::new(workers));
            assert_eq!(got, want, "workers={workers}");
        }
    }

    #[test]
    fn ledger_conserves_and_never_moves_a_bit() {
        // the four ADC regimes of installed_run_is_bit_identical_...: the
        // ledger must (a) stay empty when off, (b) change no output bit
        // when on, (c) satisfy the conservation identities against the
        // install-time slice profile
        let _guard = crate::obs::ledger::test_guard();
        for (adc_bits, out_shift, adaptive) in
            [(9, 10, false), (9, 10, true), (6, 0, false), (7, 4, true)]
        {
            let p = XbarParams {
                adc_bits,
                out_shift,
                ..XbarParams::default()
            };
            let (x, w) = rand_xw(131 + adc_bits as u64, 4, 9, &p);
            let programmed = ProgrammedXbar::install(&w, &p, adaptive);
            crate::obs::ledger::set_enabled(false);
            let mut scratch = programmed.scratch();
            let off = programmed.run_with_scratch(&x, &mut scratch);
            assert!(scratch.ledger.is_empty(), "disabled ledger counted work");
            crate::obs::ledger::set_enabled(true);
            let on = programmed.run_with_scratch(&x, &mut scratch);
            crate::obs::ledger::set_enabled(false);
            assert_eq!(off, on, "enabling the ledger moved bits");
            let l = scratch.take_ledger();
            assert!(scratch.ledger.is_empty(), "take_ledger left residue");

            let rows = x.rows as u64;
            let n = programmed.n() as u64;
            let iters = programmed.iters() as u64;
            let (dense, uniform, zero) = programmed.slice_profile();
            assert_eq!(l.row_elems, rows * programmed.kdim() as u64);
            if programmed.is_fused() {
                assert_eq!(l.fused_rows, rows);
                assert_eq!(l.slice_rows, 0);
                assert_eq!(l.adc_ops(), 0, "fused path quantises nothing");
                assert_eq!(
                    l.identity_folds,
                    rows * iters * programmed.slices() as u64 * n
                );
                assert_eq!(
                    l.slice_iters_executed + l.slice_iters_folded + l.slice_iters_skipped,
                    0,
                    "fused path walks no slices (profile is all zero)"
                );
            } else {
                assert_eq!(l.slice_rows, rows);
                assert_eq!(l.iters_executed + l.iters_skipped, rows * iters);
                // slice iterations account exactly against slice_profile()
                assert_eq!(
                    l.slice_iters_executed + l.slice_iters_folded + l.slice_iters_skipped,
                    rows * iters * (dense + uniform + zero) as u64
                );
                assert_eq!(l.slice_iters_executed, l.iters_executed * dense as u64);
                assert_eq!(l.slice_iters_folded, l.iters_executed * uniform as u64);
                // every non-skipped slice sample is quantised or folded
                assert_eq!(
                    l.adc_ops() + l.identity_folds,
                    (l.slice_iters_executed + l.slice_iters_folded) * n
                );
            }
        }
    }

    #[test]
    fn adaptive_ledger_buckets_are_heterogeneous() {
        // the adaptive schedule truncates more bits at lower places, so a
        // lossy+adaptive run must spread its conversions over several
        // resolved-width buckets — the heterogeneity the ledger exists to
        // expose
        let _guard = crate::obs::ledger::test_guard();
        let p = XbarParams {
            adc_bits: 7,
            out_shift: 4,
            ..XbarParams::default()
        };
        let (x, w) = rand_xw(17, 3, 8, &p);
        let programmed = ProgrammedXbar::install(&w, &p, true);
        crate::obs::ledger::set_enabled(true);
        let mut scratch = programmed.scratch();
        let _ = programmed.run_with_scratch(&x, &mut scratch);
        crate::obs::ledger::set_enabled(false);
        let l = scratch.take_ledger();
        let populated = l.adc_ops_by_bits.iter().filter(|&&c| c > 0).count();
        assert!(
            populated >= 2,
            "adaptive run used {populated} bit-width bucket(s): {:?}",
            l.adc_ops_by_bits
        );
    }
}
