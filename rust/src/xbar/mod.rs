//! Bit-accurate functional model of the analog crossbar pipeline — the rust
//! twin of `python/compile/kernels/crossbar.py` (L1).
//!
//! Used by the coordinator's golden-model verification path, the examples
//! that run without PJRT, and the property tests that pin down the numeric
//! contract the artifacts must satisfy: with the default lossless ADC the
//! whole pipeline equals `clamp(round_half_up((x @ w) >> out_shift))`.

pub mod cnn;
pub mod noise;

use crate::config::XbarParams;

/// A dense signed matrix in row-major order.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> i64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i64) {
        self.data[r * self.cols + c] = v;
    }
}

/// Plain exact matmul (the oracle).
pub fn matmul(x: &Matrix, w: &Matrix) -> Matrix {
    assert_eq!(x.cols, w.rows);
    let mut out = Matrix::zeros(x.rows, w.cols);
    for r in 0..x.rows {
        for k in 0..x.cols {
            let xv = x.at(r, k);
            if xv == 0 {
                continue;
            }
            for c in 0..w.cols {
                out.data[r * w.cols + c] += xv * w.at(k, c);
            }
        }
    }
    out
}

/// ADC digitisation of one analog column sum (mirrors `adc_sample`).
fn adc_sample(col_sum: i64, place: u32, p: &XbarParams, adaptive: bool) -> i64 {
    let mut q = col_sum;
    let lossy = p.lossless_adc_bits() as i64 - p.adc_bits as i64;
    if lossy > 0 {
        let half = 1i64 << (lossy - 1);
        q = ((q + half) >> lossy) << lossy;
    }
    if adaptive && place < p.out_shift {
        let d = (p.out_shift - place) as i64;
        let half = 1i64 << (d - 1);
        q = ((q + half) >> d) << d;
    }
    q
}

/// Raw biased product `x @ wb` through the bit-serial + ADC pipeline.
/// `x` unsigned (`in_bits` wide), `wb` unsigned (`w_bits` wide).
///
/// Hot-path layout (EXPERIMENTS.md §Perf): weight cell planes are sliced
/// once into flat `slices x K x N` buffers; per (batch row, iteration) the
/// active input bits stream through all slice planes with linear column
/// accumulation — ~40x over the naive per-element bit-extraction loop.
pub fn biased_product(
    x: &Matrix,
    wb: &Matrix,
    in_bits: u32,
    w_bits: u32,
    p: &XbarParams,
    adaptive: bool,
) -> Matrix {
    assert_eq!(x.cols, wb.rows);
    assert!(x.cols <= p.rows, "reduction dim exceeds crossbar rows");
    let iters = (in_bits as usize).div_ceil(p.dac_bits as usize);
    let slices = (w_bits as usize).div_ceil(p.cell_bits as usize);
    let dac_mask = (1i64 << p.dac_bits) - 1;
    let cell_mask = (1i64 << p.cell_bits) - 1;
    let (kdim, n) = (x.cols, wb.cols);

    // install-time weight slicing: planes[s][k][c], flat
    let mut planes = vec![0i64; slices * kdim * n];
    for s in 0..slices {
        let shift = s as u32 * p.cell_bits;
        for k in 0..kdim {
            let dst = &mut planes[(s * kdim + k) * n..(s * kdim + k) * n + n];
            let src = &wb.data[k * n..k * n + n];
            for c in 0..n {
                dst[c] = (src[c] >> shift) & cell_mask;
            }
        }
    }

    let mut acc = Matrix::zeros(x.rows, n);
    let mut cols = vec![0i64; slices * n]; // per-(i) analog column sums
    for r in 0..x.rows {
        for i in 0..iters {
            let shift = i as u32 * p.dac_bits;
            cols.fill(0);
            for k in 0..kdim {
                let xb = (x.at(r, k) >> shift) & dac_mask;
                if xb == 0 {
                    continue;
                }
                for s in 0..slices {
                    let row = &planes[(s * kdim + k) * n..(s * kdim + k) * n + n];
                    let dst = &mut cols[s * n..s * n + n];
                    if xb == 1 {
                        for c in 0..n {
                            dst[c] += row[c];
                        }
                    } else {
                        for c in 0..n {
                            dst[c] += xb * row[c];
                        }
                    }
                }
            }
            let lossless = p.lossless_adc_bits() <= p.adc_bits;
            for s in 0..slices {
                let place = i as u32 * p.dac_bits + s as u32 * p.cell_bits;
                let out = &mut acc.data[r * n..r * n + n];
                let src = &cols[s * n..s * n + n];
                if lossless && (!adaptive || place >= p.out_shift) {
                    // identity ADC: fold straight into the accumulator
                    for c in 0..n {
                        out[c] += src[c] << place;
                    }
                } else {
                    for c in 0..n {
                        let q = adc_sample(src[c], place, p, adaptive);
                        out[c] += q << place;
                    }
                }
            }
        }
    }
    acc
}

/// Signed raw product via bias encoding (ISAAC): store `w + 2^(wb-1)`,
/// subtract `2^(wb-1) * sum(x)` digitally.
pub fn vmm_raw(x: &Matrix, w: &Matrix, p: &XbarParams, adaptive: bool) -> Matrix {
    let bias = 1i64 << (p.weight_bits - 1);
    let wb = Matrix::from_fn(w.rows, w.cols, |r, c| w.at(r, c) + bias);
    let mut raw = biased_product(x, &wb, p.input_bits, p.weight_bits, p, adaptive);
    for r in 0..x.rows {
        let sx: i64 = (0..x.cols).map(|k| x.at(r, k)).sum();
        for c in 0..w.cols {
            raw.data[r * w.cols + c] -= bias * sx;
        }
    }
    raw
}

/// Signed-input variant: offsets inputs into the unsigned DAC window and
/// corrects digitally (both operand biases applied). Needed by Strassen's
/// pre-subtractions, whose operands can be negative (§III-A2).
///
///   x@w = (X - Bi)(Wb - Bw) = X@Wb - Bw*rowsum(X) - Bi*colsum(Wb) + K*Bi*Bw
///
/// where X = x + Bi, Wb = w + Bw, K = reduction length. `colsum(Wb)` is
/// known at weight-install time.
pub fn vmm_raw_signed(x: &Matrix, w: &Matrix, p: &XbarParams, adaptive: bool) -> Matrix {
    let bi = 1i64 << (p.input_bits - 1);
    let bw = 1i64 << (p.weight_bits - 1);
    let xs = Matrix::from_fn(x.rows, x.cols, |r, c| x.at(r, c) + bi);
    let wb = Matrix::from_fn(w.rows, w.cols, |r, c| w.at(r, c) + bw);
    let raw = biased_product(&xs, &wb, p.input_bits, p.weight_bits, p, adaptive);
    let k = x.cols as i64;
    Matrix::from_fn(x.rows, w.cols, |r, c| {
        let rowsum: i64 = (0..x.cols).map(|j| xs.at(r, j)).sum();
        let colsum: i64 = (0..w.rows).map(|j| wb.at(j, c)).sum();
        raw.at(r, c) - bw * rowsum - bi * colsum + k * bi * bw
    })
}

/// Scaling stage: round-half-up shift + clamp to the signed output window.
pub fn scale_clamp(raw: &Matrix, p: &XbarParams) -> Matrix {
    let half = if p.out_shift > 0 {
        1i64 << (p.out_shift - 1)
    } else {
        0
    };
    let lo = -(1i64 << (p.out_bits - 1));
    let hi = (1i64 << (p.out_bits - 1)) - 1;
    Matrix::from_fn(raw.rows, raw.cols, |r, c| {
        ((raw.at(r, c) + half) >> p.out_shift).clamp(lo, hi)
    })
}

/// Full pipeline: `clamp(round((x @ w) >> out_shift))` for lossless configs.
pub fn vmm(x: &Matrix, w: &Matrix, p: &XbarParams) -> Matrix {
    scale_clamp(&vmm_raw(x, w, p, false), p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_xw(seed: u64, b: usize, n: usize, p: &XbarParams) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(b, p.rows, |_, _| rng.range_i64(0, 1 << p.input_bits));
        let w = Matrix::from_fn(p.rows, n, |_, _| {
            rng.range_i64(-(1 << (p.weight_bits - 1)), 1 << (p.weight_bits - 1))
        });
        (x, w)
    }

    #[test]
    fn pipeline_is_exact_for_default_config() {
        let p = XbarParams::default();
        let (x, w) = rand_xw(1, 4, 16, &p);
        let got = vmm(&x, &w, &p);
        let want = scale_clamp(&matmul(&x, &w), &p);
        assert_eq!(got, want);
    }

    #[test]
    fn raw_matches_matmul() {
        let p = XbarParams::default();
        let (x, w) = rand_xw(2, 3, 8, &p);
        assert_eq!(vmm_raw(&x, &w, &p, false), matmul(&x, &w));
    }

    #[test]
    fn adaptive_within_one_ulp_here() {
        let p = XbarParams::default();
        let (x, w) = rand_xw(3, 3, 8, &p);
        let a = scale_clamp(&vmm_raw(&x, &w, &p, true), &p);
        let e = scale_clamp(&matmul(&x, &w), &p);
        for (av, ev) in a.data.iter().zip(e.data.iter()) {
            assert!((av - ev).abs() <= 2, "{av} vs {ev}");
        }
    }

    #[test]
    fn clamps_at_extremes() {
        let p = XbarParams::default();
        let x = Matrix::from_fn(1, p.rows, |_, _| (1 << p.input_bits) - 1);
        let w = Matrix::from_fn(p.rows, 2, |_, _| (1 << (p.weight_bits - 1)) - 1);
        assert_eq!(vmm(&x, &w, &p).at(0, 0), (1 << (p.out_bits - 1)) - 1);
        let wn = Matrix::from_fn(p.rows, 2, |_, _| -(1 << (p.weight_bits - 1)));
        assert_eq!(vmm(&x, &wn, &p).at(0, 0), -(1 << (p.out_bits - 1)));
    }

    #[test]
    fn lossy_adc_deviates_but_deterministically() {
        let p = XbarParams {
            adc_bits: 6,
            out_shift: 0,
            ..XbarParams::default()
        };
        let (x, w) = rand_xw(5, 2, 4, &p);
        let a = vmm_raw(&x, &w, &p, false);
        let b = vmm_raw(&x, &w, &p, false);
        assert_eq!(a, b);
        assert_ne!(a, matmul(&x, &w));
    }

    #[test]
    fn zero_in_zero_out() {
        let p = XbarParams::default();
        let x = Matrix::zeros(2, p.rows);
        let w = Matrix::from_fn(p.rows, 3, |r, c| (r + c) as i64);
        assert!(vmm(&x, &w, &p).data.iter().all(|&v| v == 0));
    }
}
