//! Small shared utilities: deterministic RNG, stats, and table printing.
//!
//! No external crates are available offline (ARCHITECTURE.md §Substitutions), so
//! the RNG is a xorshift64* generator — plenty for synthetic workloads and
//! the property-test harness, not for cryptography.

/// Deterministic xorshift64* PRNG.
#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + (self.below((hi - lo) as u64) as i64)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// Median of a sample (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean — the paper reports cross-workload averages this way.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(xs.iter().all(|&x| x > 0.0));
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Fixed-width ASCII table writer used by benches and `newton report`.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

/// `format!("{:.2}", x)` helper for f64 cells.
pub fn f2(x: f64) -> String {
    format!("{:.2}", x)
}

pub fn f1(x: f64) -> String {
    format!("{:.1}", x)
}

/// Ceiling division for usize.
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Worker-thread count for a parallel region with `jobs` independent units:
/// `min(jobs, available_parallelism)`, never zero. Centralised so every
/// executor fan-out (`sched::Executor::for_jobs`: ProgrammedXbar batches,
/// evaluate_grid, DES sweeps, replica serving) sizes itself the same way.
pub fn worker_count(jobs: usize) -> usize {
    if jobs <= 1 {
        return 1;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(jobs)
}

/// Evaluate an `outer × inner` grid of independent cells in parallel and
/// return `out[outer][inner]`. Thin compatibility wrapper over
/// [`crate::sched::grid`] — one work-stealing job per cell, results
/// deterministic regardless of worker count or steal schedule.
pub fn grid_par<T, F>(n_outer: usize, n_inner: usize, cell: F) -> Vec<Vec<T>>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    crate::sched::grid(n_outer, n_inner, cell)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
            let v = r.range_i64(-5, 6);
            assert!((-5..6).contains(&v));
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn rng_is_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[r.below(8) as usize] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "bucket {c}");
        }
    }

    #[test]
    fn stats() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.to_string();
        assert!(s.contains("a"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    fn ceil_div_works() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
    }

    #[test]
    fn worker_count_bounds() {
        assert_eq!(worker_count(0), 1);
        assert_eq!(worker_count(1), 1);
        let w = worker_count(1000);
        assert!(w >= 1 && w <= 1000);
        assert!(worker_count(2) <= 2);
    }

    #[test]
    fn grid_par_orders_cells_deterministically() {
        let grid = grid_par(3, 5, |o, i| o * 100 + i);
        assert_eq!(grid.len(), 3);
        for (o, row) in grid.iter().enumerate() {
            assert_eq!(row.len(), 5);
            for (i, v) in row.iter().enumerate() {
                assert_eq!(*v, o * 100 + i);
            }
        }
        assert!(grid_par(0, 5, |_, _| 0).is_empty());
        let empty_rows = grid_par(2, 0, |_, _| 0);
        assert_eq!(empty_rows.len(), 2);
        assert!(empty_rows[0].is_empty());
    }
}
