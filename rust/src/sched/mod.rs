//! Work-stealing executor — the repo's single parallel driver.
//!
//! Newton's thesis is heterogeneity: resources sized per sub-computation
//! instead of worst-case provisioning. The execution layer mirrors that.
//! A contiguous split (the pre-sched `util::grid_par`) provisions every
//! worker an equal *count* of jobs, which strands cores when job costs are
//! skewed — resnet34 grid cells cost ~10x mlp-class cells, so on a wide
//! design grid the worker that drew the resnet column finishes last while
//! the rest idle. The executor here sizes work to workers dynamically:
//!
//! * **per-worker deques** (mutex-protected; no external crates offline,
//!   ARCHITECTURE.md §Substitutions — a Chase-Lev array would need atomics+unsafe
//!   for little gain at these job granularities): the owner pops from the
//!   front of its deque, preserving the contiguous seed order and its cache
//!   locality;
//! * **steal-half**: an idle worker takes the *back* half of a victim's
//!   deque in one lock acquisition, so a loaded victim loses future work,
//!   not the job it is about to run, and steal traffic is O(log jobs);
//! * **injector queue**: a shared overflow queue seeded with the jobs that
//!   don't divide evenly across workers; any idle worker drains it before
//!   stealing. It is also the hook later PRs (pipelined stage scheduling)
//!   use to submit work from outside the pool;
//! * **deterministic results**: every job writes to its own index slot, so
//!   `map(n, f)[i] == f(i)` bit-for-bit regardless of worker count, steal
//!   schedule, or OS timing. Parallelism here is a pure wall-clock
//!   optimisation, never a numerics change.
//!
//! Everything parallel in the repo rides on this pool:
//! `pipeline::evaluate_grid` and `pipeline::des::simulate_grid` submit one
//! job per grid cell, `xbar::ProgrammedXbar` fans batch rows out through
//! it, `xbar::cnn::ProgrammedCnn::forward` splits per image, and
//! `coordinator::GoldenServer` feeds batches to installed replicas
//! through it.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::obs;
use crate::util::worker_count;

/// Global sched counters, flushed once per `map_stats` run from its local
/// tallies (never per job — the hot path stays untouched). Sites cache the
/// registry `Arc` so only the first run per process takes the registry
/// lock.
static JOBS_CTR: OnceLock<Arc<obs::Counter>> = OnceLock::new();
static STEALS_CTR: OnceLock<Arc<obs::Counter>> = OnceLock::new();
static INJECTOR_CTR: OnceLock<Arc<obs::Counter>> = OnceLock::new();

thread_local! {
    /// Set for the lifetime of a pool worker thread (never cleared: worker
    /// threads are born and die inside one `map_stats` scope).
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True while the current thread is a sched pool worker. Lower layers
/// (`xbar::ProgrammedXbar::raw_product`) consult this to stay sequential
/// inside an executor job: the outer job decomposition owns the pool, so
/// nesting another per-VMM fan-out would only thrash threads (~cores² per
/// crossbar read). Nested `Executor::map` calls are still fine — their
/// workers are fresh threads with their own flag.
pub fn in_worker() -> bool {
    IN_WORKER.with(|f| f.get())
}

fn flush_sched_metrics(jobs: usize, steals: usize) {
    JOBS_CTR.get_or_init(|| obs::counter("sched.jobs")).add(jobs as u64);
    if steals > 0 {
        STEALS_CTR
            .get_or_init(|| obs::counter("sched.steals"))
            .add(steals as u64);
    }
}

/// A mutex-protected job deque. The owning worker pops from the front
/// (contiguous seed order => cache locality); thieves split off the back
/// half. Job handles are plain indices into the caller's job space.
struct Deque {
    jobs: Mutex<VecDeque<usize>>,
}

impl Deque {
    fn new() -> Self {
        Deque {
            jobs: Mutex::new(VecDeque::new()),
        }
    }

    fn seed(&self, idxs: std::ops::Range<usize>) {
        self.jobs.lock().unwrap().extend(idxs);
    }

    /// Owner-side pop (front).
    fn pop(&self) -> Option<usize> {
        self.jobs.lock().unwrap().pop_front()
    }

    /// Thief-side steal: take the back ceil(half) in one lock acquisition.
    fn steal_half(&self) -> VecDeque<usize> {
        let mut q = self.jobs.lock().unwrap();
        let n = q.len();
        if n == 0 {
            return VecDeque::new();
        }
        q.split_off(n - n.div_ceil(2))
    }

    /// Append a stolen batch into this (own) deque.
    fn give(&self, mut batch: VecDeque<usize>) {
        self.jobs.lock().unwrap().append(&mut batch);
    }
}

/// Unwind-safe decrement of the pending-jobs counter: dropped after the
/// job runs, *including* when the job panics — otherwise the surviving
/// workers would spin forever waiting for a count that can no longer
/// reach zero, and the panic would never propagate out of the scope join.
struct PendingGuard<'a>(&'a AtomicUsize);

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Release);
    }
}

/// Scheduling statistics from one `map_stats` run — the observability the
/// stress smoke and the perf benches assert against.
#[derive(Clone, Debug, Default)]
pub struct SchedStats {
    /// Workers actually spawned (after clamping to the job count).
    pub workers: usize,
    /// Total jobs submitted.
    pub jobs: usize,
    /// Successful steal-half operations across the run.
    pub steals: usize,
    /// Jobs executed by each worker; sums to `jobs`.
    pub executed: Vec<usize>,
}

impl SchedStats {
    /// Max/min executed-jobs imbalance, 1.0 = perfectly even.
    pub fn imbalance(&self) -> f64 {
        let max = self.executed.iter().copied().max().unwrap_or(0);
        let min = self.executed.iter().copied().min().unwrap_or(0);
        max as f64 / min.max(1) as f64
    }
}

/// A sized executor. `Executor::new(w)` steals; `Executor::contiguous(w)`
/// pins the legacy contiguous split (each worker runs exactly its seeded
/// chunk) — kept as the measurable baseline for the scheduler win.
#[derive(Clone, Copy, Debug)]
pub struct Executor {
    workers: usize,
    steal: bool,
}

impl Executor {
    /// Work-stealing pool of `workers` threads (clamped to >= 1). Workers
    /// beyond `available_parallelism` are allowed — oversubscription is a
    /// correctness-neutral stress configuration.
    pub fn new(workers: usize) -> Self {
        Executor {
            workers: workers.max(1),
            steal: true,
        }
    }

    /// Contiguous-split baseline: same pool, stealing disabled.
    pub fn contiguous(workers: usize) -> Self {
        Executor {
            workers: workers.max(1),
            steal: false,
        }
    }

    /// Stealing pool sized like every fan-out in the repo:
    /// `min(jobs, available_parallelism)`.
    pub fn for_jobs(n_jobs: usize) -> Self {
        Self::new(worker_count(n_jobs))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `job(0..n_jobs)` across the pool; `out[i] == job(i)` regardless
    /// of worker count or steal schedule.
    pub fn map<T, F>(&self, n_jobs: usize, job: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.map_stats(n_jobs, job).0
    }

    /// Like [`Self::map`], also returning scheduling statistics.
    pub fn map_stats<T, F>(&self, n_jobs: usize, job: F) -> (Vec<T>, SchedStats)
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let workers = self.workers.min(n_jobs.max(1));
        if workers <= 1 {
            let out: Vec<T> = (0..n_jobs).map(&job).collect();
            flush_sched_metrics(n_jobs, 0);
            return (
                out,
                SchedStats {
                    workers: 1,
                    jobs: n_jobs,
                    steals: 0,
                    executed: vec![n_jobs],
                },
            );
        }

        // Seed: each worker gets a contiguous base chunk; the indivisible
        // tail goes to the injector, where any idle worker grabs it.
        let deques: Vec<Deque> = (0..workers).map(|_| Deque::new()).collect();
        let injector = Deque::new();
        let base = n_jobs / workers;
        for (w, d) in deques.iter().enumerate() {
            d.seed(w * base..(w + 1) * base);
        }
        injector.seed(workers * base..n_jobs);
        let tail = n_jobs - workers * base;
        if tail > 0 {
            INJECTOR_CTR
                .get_or_init(|| obs::counter("sched.injector_jobs"))
                .add(tail as u64);
        }

        let steals = AtomicUsize::new(0);
        let pending = AtomicUsize::new(n_jobs);
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n_jobs);
        slots.resize_with(n_jobs, || None);
        let mut executed = vec![0usize; workers];
        {
            let job = &job;
            let deques = &deques;
            let injector = &injector;
            let steals = &steals;
            let pending = &pending;
            let steal_mode = self.steal;
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..workers)
                    .map(|me| {
                        s.spawn(move || {
                            IN_WORKER.with(|f| f.set(true));
                            let mut done: Vec<(usize, T)> = Vec::new();
                            let mut idle_rounds = 0u32;
                            loop {
                                let next = deques[me]
                                    .pop()
                                    .or_else(|| injector.pop())
                                    .or_else(|| {
                                        if !steal_mode {
                                            return None;
                                        }
                                        for k in 1..deques.len() {
                                            let victim = (me + k) % deques.len();
                                            let mut batch = deques[victim].steal_half();
                                            if let Some(first) = batch.pop_front() {
                                                steals.fetch_add(1, Ordering::Relaxed);
                                                if !batch.is_empty() {
                                                    deques[me].give(batch);
                                                }
                                                return Some(first);
                                            }
                                        }
                                        None
                                    });
                                match next {
                                    Some(i) => {
                                        let _dec = PendingGuard(pending);
                                        done.push((i, job(i)));
                                        idle_rounds = 0;
                                    }
                                    None => {
                                        // contiguous mode: static chunks, a
                                        // drained worker is finished. In
                                        // steal mode a thief may hold jobs
                                        // in flight between steal_half and
                                        // give, so only the pending counter
                                        // (0 = every job *executed*) may
                                        // retire a worker; until then spin
                                        // politely and rescan.
                                        if !steal_mode
                                            || pending.load(Ordering::Acquire) == 0
                                        {
                                            break;
                                        }
                                        // back off while the tail drains:
                                        // yield a few rounds, then sleep
                                        // with a growing, capped interval
                                        // so big idle pools don't hammer
                                        // the deque locks
                                        idle_rounds += 1;
                                        if idle_rounds < 8 {
                                            std::thread::yield_now();
                                        } else {
                                            let us =
                                                (50 * (idle_rounds - 7) as u64).min(2000);
                                            std::thread::sleep(
                                                std::time::Duration::from_micros(us),
                                            );
                                        }
                                    }
                                }
                            }
                            done
                        })
                    })
                    .collect();
                for (w, h) in handles.into_iter().enumerate() {
                    let list = h.join().expect("sched worker panicked");
                    executed[w] = list.len();
                    for (i, t) in list {
                        slots[i] = Some(t);
                    }
                }
            });
        }
        let out: Vec<T> = slots
            .into_iter()
            .map(|s| s.expect("sched job completed"))
            .collect();
        let stolen = steals.load(Ordering::Relaxed);
        flush_sched_metrics(n_jobs, stolen);
        (
            out,
            SchedStats {
                workers,
                jobs: n_jobs,
                steals: stolen,
                executed,
            },
        )
    }

    /// Evaluate an `outer × inner` grid, one job per cell, returning
    /// `out[outer][inner]` — the engine behind `pipeline::evaluate_grid`
    /// and `pipeline::des::simulate_grid`.
    pub fn grid<T, F>(&self, n_outer: usize, n_inner: usize, cell: F) -> Vec<Vec<T>>
    where
        T: Send,
        F: Fn(usize, usize) -> T + Sync,
    {
        if n_inner == 0 {
            return (0..n_outer).map(|_| Vec::new()).collect();
        }
        let flat = self.map(n_outer * n_inner, |j| cell(j / n_inner, j % n_inner));
        let mut grid = Vec::with_capacity(n_outer);
        let mut cells = flat.into_iter();
        for _ in 0..n_outer {
            grid.push((0..n_inner).map(|_| cells.next().unwrap()).collect());
        }
        grid
    }
}

/// Auto-sized stealing map: `out[i] == job(i)`.
pub fn map<T, F>(n_jobs: usize, job: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    Executor::for_jobs(n_jobs).map(n_jobs, job)
}

/// Auto-sized stealing grid: one job per cell, `out[outer][inner]`.
pub fn grid<T, F>(n_outer: usize, n_inner: usize, cell: F) -> Vec<Vec<T>>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    Executor::for_jobs(n_outer * n_inner).grid(n_outer, n_inner, cell)
}

/// Deterministic synthetic job used by the stress smoke and the perf
/// bench: `spins` xorshift64* steps folded into a checksum. Cost scales
/// linearly in `spins`, result depends only on `(seed, spins)`.
pub fn spin_job(seed: u64, spins: usize) -> u64 {
    let mut r = crate::util::Rng::new(seed);
    let mut acc = 0u64;
    for _ in 0..spins {
        acc = acc.wrapping_add(r.next_u64());
    }
    acc
}

/// The stress configuration `scripts/verify.sh` smokes: an oversubscribed
/// pool (`oversub × available_parallelism` workers) over a 10x-skewed job
/// mix — the first tenth of the jobs cost 10x, *front-loaded* so the
/// contiguous seeding lands all heavy work on the leading workers and
/// stealing is structurally required (an evenly interleaved mix would
/// cost-balance the chunks and leave steals to OS jitter). Asserts
/// completion and bit-determinism against the sequential reference;
/// returns the stats so callers can assert on steal counts.
pub fn stress(n_jobs: usize, oversub: usize, heavy_spins: usize) -> SchedStats {
    let cost = move |i: usize| {
        if i * 10 < n_jobs {
            heavy_spins
        } else {
            heavy_spins / 10
        }
    };
    let want: Vec<u64> = (0..n_jobs).map(|i| spin_job(i as u64, cost(i))).collect();
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        * oversub.max(1);
    let (got, stats) = Executor::new(workers).map_stats(n_jobs, |i| spin_job(i as u64, cost(i)));
    assert_eq!(got, want, "oversubscribed stealing run diverged from sequential");
    let total: usize = stats.executed.iter().sum();
    assert_eq!(total, n_jobs, "executed-job count does not cover the job set");
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_matches_sequential_for_any_worker_count() {
        let want: Vec<usize> = (0..97).map(|i| i * i + 1).collect();
        for workers in [1, 2, 3, 7, 16, 64] {
            let got = Executor::new(workers).map(97, |i| i * i + 1);
            assert_eq!(got, want, "workers={workers}");
            let got = Executor::contiguous(workers).map(97, |i| i * i + 1);
            assert_eq!(got, want, "contiguous workers={workers}");
        }
    }

    #[test]
    fn empty_and_single_job_maps() {
        let empty: Vec<u32> = Executor::new(8).map(0, |_| 7u32);
        assert!(empty.is_empty());
        assert_eq!(Executor::new(8).map(1, |i| i + 41), vec![41]);
        assert_eq!(map(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn stats_conserve_jobs() {
        for workers in [1, 3, 5] {
            let (out, stats) = Executor::new(workers).map_stats(23, |i| i as u64);
            assert_eq!(out.len(), 23);
            assert_eq!(stats.jobs, 23);
            assert_eq!(stats.executed.len(), stats.workers);
            assert_eq!(stats.executed.iter().sum::<usize>(), 23);
        }
    }

    #[test]
    fn contiguous_mode_never_steals() {
        let (_, stats) = Executor::contiguous(4).map_stats(64, |i| spin_job(i as u64, 50));
        assert_eq!(stats.steals, 0);
        // contiguous split: every worker executes exactly its seeded chunk
        assert_eq!(stats.executed, vec![16, 16, 16, 16]);
    }

    #[test]
    fn stealing_rebalances_a_skewed_front_chunk() {
        // jobs 0..4 (worker 0's whole seed chunk) cost ~100x the rest;
        // idle workers must steal from worker 0's deque. Heavy jobs span
        // several OS timeslices so even a single-core box interleaves the
        // thieves before worker 0 can drain its own chunk.
        let heavy = 4_000_000;
        let cost = |i: usize| if i < 4 { heavy } else { heavy / 100 };
        let want: Vec<u64> = (0..16).map(|i| spin_job(i as u64, cost(i))).collect();
        let (got, stats) = Executor::new(4).map_stats(16, |i| spin_job(i as u64, cost(i)));
        assert_eq!(got, want);
        assert!(stats.steals > 0, "no steals on a 100x-skewed front chunk");
        // worker 0 cannot have run its whole chunk alone
        assert!(stats.executed[0] < 16, "{:?}", stats.executed);
    }

    #[test]
    fn grid_orders_cells_row_major() {
        let g = Executor::new(3).grid(3, 5, |o, i| o * 100 + i);
        for (o, row) in g.iter().enumerate() {
            assert_eq!(row.len(), 5);
            for (i, v) in row.iter().enumerate() {
                assert_eq!(*v, o * 100 + i);
            }
        }
        assert!(grid(0, 5, |_, _| 0).is_empty());
        let empty_rows = grid(2, 0, |_, _| 0);
        assert_eq!(empty_rows.len(), 2);
        assert!(empty_rows[0].is_empty());
    }

    #[test]
    fn injector_serves_the_indivisible_tail() {
        // 4 workers, 7 jobs: base chunk 1 each, 3 jobs through the injector
        let (out, stats) = Executor::new(4).map_stats(7, |i| i * 2);
        assert_eq!(out, vec![0, 2, 4, 6, 8, 10, 12]);
        assert_eq!(stats.executed.iter().sum::<usize>(), 7);
    }

    #[test]
    fn stress_smoke_completes_and_is_deterministic() {
        let stats = stress(120, 2, 5_000);
        assert_eq!(stats.executed.iter().sum::<usize>(), 120);
        assert!(stats.workers >= 2);
    }

    #[test]
    fn panicking_job_propagates_instead_of_hanging() {
        // a job panic must not strand the surviving workers on the pending
        // counter: the guard decrements on unwind, the pool drains, and the
        // panic resurfaces at the scope join
        let result = std::panic::catch_unwind(|| {
            Executor::new(4).map(16, |i| {
                if i == 5 {
                    panic!("boom");
                }
                spin_job(i as u64, 10_000)
            })
        });
        assert!(result.is_err(), "job panic was swallowed");
    }

    #[test]
    fn worker_flag_marks_pool_threads_only() {
        assert!(!in_worker());
        let flags = Executor::new(4).map(8, |_| in_worker());
        assert!(flags.iter().all(|&f| f), "jobs on spawned workers");
        assert!(!in_worker(), "caller thread is not a worker");
        // a 1-worker map runs inline on the caller thread
        let flags = Executor::new(1).map(3, |_| in_worker());
        assert!(flags.iter().all(|&f| !f), "inline jobs are not workers");
    }

    #[test]
    fn spin_job_is_deterministic_and_cost_monotone() {
        assert_eq!(spin_job(7, 100), spin_job(7, 100));
        assert_ne!(spin_job(7, 100), spin_job(8, 100));
        assert_ne!(spin_job(7, 100), spin_job(7, 101));
    }
}
