//! # newton — crossbar-accelerator simulator & serving stack
//!
//! A reproduction of *"Newton: Gravitating Towards the Physical Limits of
//! Crossbar Acceleration"* (Nag et al.). The paper's substrate — memristor
//! crossbars, SAR ADCs, eDRAM tiles, HTree interconnect — is simulated
//! (see ARCHITECTURE.md §Substitutions); the paper's evaluation is an analytic,
//! deterministic model, which this crate reimplements bottom-up from the
//! published component constants, plus a functional bit-accurate crossbar
//! pipeline and a serving coordinator that executes real inference through
//! AOT-compiled XLA artifacts (PJRT).
//!
//! Layer map (rust/ARCHITECTURE.md):
//! * L1 — `python/compile/kernels/crossbar.py` (Pallas, build-time); its
//!   bit-exact twin lives in [`xbar`] so the rust side can verify artifacts.
//! * L2 — `python/compile/model.py` (JAX, build-time).
//! * L3 — this crate: [`coordinator`] + [`runtime`] on the request path
//!   (exposed over TCP by [`net`]: `newton serve-net` / `bench-net`),
//!   everything else is the architecture model regenerating the paper's
//!   tables and figures (see `rust/benches/`).

pub mod adc;
pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod faults;
pub mod karatsuba;
pub mod mapping;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod pipeline;
pub mod proptest_lite;
pub mod runtime;
pub mod sched;
pub mod strassen;
pub mod tiles;
pub mod util;
pub mod workloads;
pub mod xbar;

pub use config::{ChipConfig, ImaConfig, NewtonFeatures, TileConfig, XbarParams};
