//! Efficiency metrics and report assembly (CE, PE, incremental technique
//! stacking — the paper §IV evaluation and Fig 20/21/22/23 machinery).
//! Serve-path role: [`export`] also writes the serving endpoint's
//! `net_summary.csv` (`serve-net --export`) next to the figure series.

pub mod export;

use crate::config::{ChipConfig, NewtonFeatures};
use crate::energy::TileModel;
use crate::pipeline::{evaluate, WorkloadReport};
use crate::util::geomean;
use crate::workloads::Network;

/// Peak CE/PE of a design point (conv tile only, like Fig 20).
#[derive(Clone, Copy, Debug)]
pub struct PeakMetrics {
    pub ce_gops_mm2: f64,
    pub pe_gops_w: f64,
    pub energy_per_op_pj: f64,
}

/// Peak metrics for a chip configuration's conv tile.
pub fn peak_metrics(chip: &ChipConfig) -> PeakMetrics {
    let t = TileModel::with_features(
        chip.conv_tile,
        chip.xbar,
        chip.features.adaptive_adc,
        chip.features.karatsuba,
    );
    PeakMetrics {
        ce_gops_mm2: t.ce(),
        pe_gops_w: t.pe(),
        energy_per_op_pj: t.energy_per_op_pj(),
    }
}

/// One row of the incremental-technique progression (Fig 20): label, peak
/// metrics, and suite-geomean workload metrics.
#[derive(Clone, Debug)]
pub struct IncrementalRow {
    pub label: &'static str,
    pub peak: PeakMetrics,
    /// geomean over the suite
    pub energy_per_op_pj: f64,
    pub ce_eff: f64,
    pub peak_power_w: f64,
}

/// Evaluate the paper's incremental stacking of techniques over a suite.
pub fn incremental_progression(nets: &[Network]) -> Vec<IncrementalRow> {
    NewtonFeatures::incremental()
        .into_iter()
        .map(|(label, f)| {
            let chip = if label == "isaac" {
                ChipConfig::isaac()
            } else {
                ChipConfig::newton_with(f)
            };
            let reports: Vec<WorkloadReport> =
                nets.iter().map(|n| evaluate(n, &chip)).collect();
            IncrementalRow {
                label,
                peak: peak_metrics(&chip),
                energy_per_op_pj: geomean(
                    &reports.iter().map(|r| r.energy_per_op_pj).collect::<Vec<_>>(),
                ),
                ce_eff: geomean(&reports.iter().map(|r| r.ce_eff).collect::<Vec<_>>()),
                peak_power_w: geomean(
                    &reports.iter().map(|r| r.peak_power_w).collect::<Vec<_>>(),
                ),
            }
        })
        .collect()
}

/// Headline comparison (abstract): Newton vs ISAAC over a suite.
#[derive(Clone, Copy, Debug)]
pub struct Headline {
    /// 1 - power(newton)/power(isaac); paper: 0.77
    pub power_decrease: f64,
    /// 1 - energy(newton)/energy(isaac); paper: 0.51
    pub energy_decrease: f64,
    /// throughput-per-area ratio; paper: 2.2x
    pub throughput_area_ratio: f64,
    /// newton average pJ/op; paper: 0.85
    pub newton_pj_per_op: f64,
    /// isaac average pJ/op; paper: 1.8
    pub isaac_pj_per_op: f64,
}

pub fn headline(nets: &[Network]) -> Headline {
    let isaac = ChipConfig::isaac();
    let newton = ChipConfig::newton();
    let mut p = vec![];
    let mut e = vec![];
    let mut ta = vec![];
    let mut npj = vec![];
    let mut ipj = vec![];
    for net in nets {
        let i = evaluate(net, &isaac);
        let n = evaluate(net, &newton);
        p.push(n.peak_power_w / i.peak_power_w);
        e.push(n.energy_per_op_pj / i.energy_per_op_pj);
        ta.push(n.ce_eff / i.ce_eff);
        npj.push(n.energy_per_op_pj);
        ipj.push(i.energy_per_op_pj);
    }
    Headline {
        power_decrease: 1.0 - geomean(&p),
        energy_decrease: 1.0 - geomean(&e),
        throughput_area_ratio: geomean(&ta),
        newton_pj_per_op: geomean(&npj),
        isaac_pj_per_op: geomean(&ipj),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn progression_is_monotone_in_pe() {
        let nets = workloads::suite();
        let rows = incremental_progression(&nets);
        assert_eq!(rows.len(), 7);
        // every added technique must not hurt peak power efficiency
        for w in rows.windows(2) {
            assert!(
                w[1].peak.pe_gops_w >= w[0].peak.pe_gops_w * 0.98,
                "{} -> {}: {} vs {}",
                w[0].label,
                w[1].label,
                w[0].peak.pe_gops_w,
                w[1].peak.pe_gops_w
            );
        }
    }

    #[test]
    fn headline_shape() {
        let h = headline(&workloads::suite());
        assert!(h.power_decrease > 0.5, "{}", h.power_decrease);
        assert!(h.energy_decrease > 0.3, "{}", h.energy_decrease);
        assert!(h.throughput_area_ratio > 1.5, "{}", h.throughput_area_ratio);
        assert!(h.newton_pj_per_op < h.isaac_pj_per_op);
    }

    #[test]
    fn newton_sits_between_isaac_and_ideal() {
        let h = headline(&workloads::suite());
        let ideal = crate::baselines::ideal_neuron().pj_per_op;
        assert!(h.newton_pj_per_op > ideal);
        assert!(h.newton_pj_per_op < h.isaac_pj_per_op);
    }
}
