//! CSV export of every figure's data series — the machine-readable output
//! a downstream user plots (`newton export --out results/`).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::{ChipConfig, ImaConfig, NewtonFeatures, XbarParams};
use crate::mapping::{self, Mapping, MappingPolicy};
use crate::net::StatsSnapshot;
use crate::pipeline::evaluate;
use crate::workloads;

fn write_csv(dir: &Path, name: &str, header: &str, rows: &[String]) -> Result<()> {
    let path = dir.join(name);
    let mut f =
        std::fs::File::create(&path).with_context(|| format!("creating {path:?}"))?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(())
}

/// Export all figure data series as CSVs into `dir`. Returns the file names
/// written.
pub fn export_all(dir: &Path) -> Result<Vec<String>> {
    std::fs::create_dir_all(dir)?;
    let nets = workloads::suite();
    let p = XbarParams::default();
    let mut written = Vec::new();

    // fig10: under-utilisation vs IMA shape
    {
        let mut rows = Vec::new();
        for (i, o) in [
            (128usize, 64usize),
            (128, 128),
            (128, 256),
            (128, 512),
            (256, 512),
            (512, 512),
            (1024, 1024),
            (2048, 1024),
            (8192, 1024),
        ] {
            let ima = ImaConfig {
                inputs: i,
                outputs: o,
                ..ImaConfig::newton_default()
            };
            let u = mapping::avg_underutilization(&nets, &ima, &p, 16);
            rows.push(format!("{i}x{o},{u:.4}"));
        }
        write_csv(dir, "fig10_underutilization.csv", "ima,underutil", &rows)?;
        written.push("fig10_underutilization.csv".into());
    }

    // fig15: buffer per tile vs image size
    {
        let mut rows = Vec::new();
        for w in [64usize, 128, 224, 256, 384, 512] {
            let worst = nets
                .iter()
                .map(|n| {
                    Mapping::build(
                        &n.with_input_width(w),
                        &ImaConfig::newton_default(),
                        &p,
                        MappingPolicy::newton(),
                        16,
                    )
                    .buffer_per_tile_bytes()
                })
                .fold(0.0f64, f64::max);
            rows.push(format!("{w},{:.1}", worst / 1024.0));
        }
        write_csv(dir, "fig15_buffer_kb.csv", "image_px,buffer_kb", &rows)?;
        written.push("fig15_buffer_kb.csv".into());
    }

    // per-net suite metrics for isaac / newton (figs 11/12/14/21/22/23 base data)
    for (tag, chip) in [("isaac", ChipConfig::isaac()), ("newton", ChipConfig::newton())] {
        let mut rows = Vec::new();
        for net in &nets {
            let r = evaluate(net, &chip);
            rows.push(format!(
                "{},{:.2},{:.2},{:.4},{:.2},{:.1},{:.1},{},{}",
                net.name,
                r.throughput,
                r.peak_power_w,
                r.energy_per_image_mj,
                r.energy_per_op_pj,
                r.area_mm2,
                r.ce_eff,
                r.conv_tiles,
                r.fc_tiles
            ));
        }
        let name = format!("suite_{tag}.csv");
        write_csv(
            dir,
            &name,
            "net,throughput,peak_w,energy_mj,pj_per_op,area_mm2,ce_eff,conv_tiles,fc_tiles",
            &rows,
        )?;
        written.push(name);
    }

    // fig20: incremental progression
    {
        let mut rows = Vec::new();
        for r in crate::metrics::incremental_progression(&nets) {
            rows.push(format!(
                "{},{:.1},{:.1},{:.3}",
                r.label, r.peak.ce_gops_mm2, r.peak.pe_gops_w, r.energy_per_op_pj
            ));
        }
        write_csv(dir, "fig20_incremental.csv", "step,peak_ce,peak_pe,pj_per_op", &rows)?;
        written.push("fig20_incremental.csv".into());
    }

    // fig24: tpu comparison
    {
        let tpu = crate::baselines::TpuModel::default();
        let chip8 = {
            let mut c = ChipConfig::newton();
            c.xbar = XbarParams {
                weight_bits: 8,
                input_bits: 8,
                out_shift: 4,
                out_bits: 8,
                ..c.xbar
            };
            c
        };
        let mut rows = Vec::new();
        for net in &nets {
            let t = tpu.evaluate(net);
            let n = evaluate(net, &chip8);
            rows.push(format!(
                "{},{},{:.1},{:.1},{:.3},{:.3}",
                net.name,
                t.batch,
                t.throughput,
                n.throughput,
                t.energy_per_image_mj,
                n.energy_per_image_mj
            ));
        }
        write_csv(
            dir,
            "fig24_tpu.csv",
            "net,tpu_batch,tpu_imgs,newton_imgs,tpu_mj,newton_mj",
            &rows,
        )?;
        written.push("fig24_tpu.csv".into());
    }

    // feature ablation grid: every single-feature config over the suite
    {
        let mut rows = Vec::new();
        for (label, f) in NewtonFeatures::incremental() {
            let chip = if label == "isaac" {
                ChipConfig::isaac()
            } else {
                ChipConfig::newton_with(f)
            };
            for net in &nets {
                let r = evaluate(net, &chip);
                rows.push(format!(
                    "{label},{},{:.2},{:.2},{:.1}",
                    net.name, r.energy_per_op_pj, r.peak_power_w, r.ce_eff
                ));
            }
        }
        write_csv(dir, "ablation_grid.csv", "step,net,pj_per_op,peak_w,ce_eff", &rows)?;
        written.push("ablation_grid.csv".into());
    }

    // net serving summary: a live run writes the real drained snapshot via
    // `newton serve-net --export <dir>` (export_net_summary) — never
    // clobber that with zeros; only a fresh directory gets the zero-filled
    // placeholder so the artifact set is complete
    if !dir.join("net_summary.csv").exists() {
        export_net_summary(dir, &StatsSnapshot::default())?;
    }
    written.push("net_summary.csv".into());

    Ok(written)
}

/// Serialize a `serve-net` [`StatsSnapshot`] as `net_summary.csv` next to
/// the figure exports: one `metric,value` row per counter plus a
/// `replica_<i>_requests` row per installed replica and a `metric_<name>`
/// row per obs-registry counter the server shipped in its Stats frame.
pub fn export_net_summary(dir: &Path, s: &StatsSnapshot) -> Result<String> {
    std::fs::create_dir_all(dir)?;
    let mut rows = vec![
        format!("served,{}", s.served),
        format!("busy_rejections,{}", s.busy),
        format!("protocol_errors,{}", s.proto_errors),
        format!("batches,{}", s.batches),
        format!("batch_fill,{:.4}", s.batch_fill),
        format!("worst_abs_err,{}", s.worst_abs_err),
        format!("latency_p50_us,{}", s.p50_us),
        format!("latency_p99_us,{}", s.p99_us),
        format!("latency_p999_us,{}", s.p999_us),
        format!("replicas,{}", s.per_replica.len()),
        format!("batch_reruns,{}", s.reruns),
        format!("quarantines,{}", s.quarantines),
        format!("degraded,{}", s.degraded as u8),
    ];
    for (i, n) in s.per_replica.iter().enumerate() {
        rows.push(format!("replica_{i}_requests,{n}"));
    }
    for (i, b) in s.health.iter().enumerate() {
        rows.push(format!(
            "replica_{i}_health,{}",
            crate::coordinator::HealthState::from_u8(*b).label()
        ));
    }
    // sort the obs-registry rows by name regardless of wire order, so two
    // exports of one snapshot are byte-identical and diffs stay clean
    let mut metrics: Vec<&(String, u64)> = s.metrics.iter().collect();
    metrics.sort_by(|a, b| a.0.cmp(&b.0));
    for (name, value) in metrics {
        rows.push(format!("metric_{name},{value}"));
    }
    write_csv(dir, "net_summary.csv", "metric,value", &rows)?;
    Ok("net_summary.csv".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_summary_serializes_a_populated_snapshot() {
        let dir = std::env::temp_dir().join("newton-net-summary-test");
        let _ = std::fs::remove_dir_all(&dir);
        let snap = StatsSnapshot {
            served: 64,
            busy: 3,
            proto_errors: 1,
            batches: 9,
            batch_fill: 0.8889,
            worst_abs_err: 0,
            p50_us: 1500,
            p99_us: 9000,
            p999_us: 12_000,
            per_replica: vec![33, 31],
            reruns: 2,
            quarantines: 1,
            degraded: false,
            health: vec![0, 2],
            // deliberately unsorted: the exporter must order these rows
            metrics: vec![
                ("sched.steals".to_string(), 5),
                ("net.requests".to_string(), 64),
                ("ledger.adc_ops".to_string(), 147_456),
            ],
        };
        let name = export_net_summary(&dir, &snap).unwrap();
        assert_eq!(name, "net_summary.csv");
        let text = std::fs::read_to_string(dir.join(&name)).unwrap();
        assert_eq!(text.lines().next(), Some("metric,value"));
        for want in [
            "served,64",
            "busy_rejections,3",
            "protocol_errors,1",
            "batches,9",
            "batch_fill,0.8889",
            "worst_abs_err,0",
            "latency_p50_us,1500",
            "latency_p99_us,9000",
            "latency_p999_us,12000",
            "replicas,2",
            "batch_reruns,2",
            "quarantines,1",
            "degraded,0",
            "replica_0_requests,33",
            "replica_1_requests,31",
            "replica_0_health,healthy",
            "replica_1_health,quarantined",
            "metric_net.requests,64",
            "metric_sched.steals,5",
            "metric_ledger.adc_ops,147456",
        ] {
            assert!(text.lines().any(|l| l == want), "missing row {want:?} in:\n{text}");
        }
        // metric_ rows come out name-sorted even though the snapshot
        // carried them out of order
        let metric_rows: Vec<&str> =
            text.lines().filter(|l| l.starts_with("metric_")).collect();
        let mut sorted = metric_rows.clone();
        sorted.sort_unstable();
        assert_eq!(metric_rows, sorted, "metric_ rows are not name-sorted");
        // every data row is exactly metric,value
        for l in text.lines().skip(1) {
            assert_eq!(l.matches(',').count(), 1, "{l}");
        }
        // a subsequent offline export_all must not clobber the live summary
        let files = export_all(&dir).unwrap();
        assert!(files.iter().any(|f| f == "net_summary.csv"));
        let text2 = std::fs::read_to_string(dir.join("net_summary.csv")).unwrap();
        assert_eq!(text, text2, "export_all clobbered a live net summary");
    }

    #[test]
    fn export_writes_all_series() {
        let dir = std::env::temp_dir().join("newton-export-test");
        let _ = std::fs::remove_dir_all(&dir);
        let files = export_all(&dir).unwrap();
        assert!(files.len() >= 8);
        assert!(files.iter().any(|f| f == "net_summary.csv"));
        for f in &files {
            let text = std::fs::read_to_string(dir.join(f)).unwrap();
            assert!(text.lines().count() > 1, "{f} is empty");
            // every row has the same number of commas as the header
            let commas = text.lines().next().unwrap().matches(',').count();
            for l in text.lines().skip(1) {
                assert_eq!(l.matches(',').count(), commas, "{f}: {l}");
            }
        }
    }
}
