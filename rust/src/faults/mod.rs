//! Deterministic fault injection for chaos testing (std-only, like
//! [`crate::sched`] — no `rand` dependency).
//!
//! Newton's crossbars are analog devices: conductance drift and
//! programming error silently corrupt installed weights over time
//! (arXiv:2109.01262 measures exactly this erosion in deployed analog
//! inference), and the network in front of them fails in its own ways —
//! corrupted frames, stalled peers, mid-frame disconnects. This module
//! injects both failure classes *on a deterministic schedule*, so every
//! chaos run is reproducible from a single seed:
//!
//! * [`FaultPlan`] perturbs a replica's programmed cells — per-cell
//!   conductance drift and stuck-at faults over the weight matrices,
//!   re-installed through the ordinary
//!   [`ProgrammedLinear::install`](crate::xbar::cnn::ProgrammedLinear::install)
//!   path so the perturbed install is a first-class replica
//!   ([`FaultPlan::program_drifted`]). The health machinery in
//!   [`crate::coordinator::health`] is expected to catch the resulting
//!   deviation and quarantine the replica.
//! * [`FaultyStream`] wraps any `Read + Write` transport and injects
//!   frame corruption, partial writes, stalls, and mid-frame disconnects
//!   at a configured rate. The retrying client
//!   ([`crate::net::RetryClient`]) must mask every one of them without
//!   ever surfacing a wrong answer.
//!
//! Determinism contract: the same `(seed, rate)` against the same call
//! sequence makes the same decisions in the same order. The RNG is the
//! repo-wide xorshift64* ([`crate::util::Rng`]); every fault site derives
//! its stream from the plan seed and a site index, so schedules never
//! alias across layers or connections.

use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::config::XbarParams;
use crate::util::Rng;
use crate::xbar::cnn::{MiniCnn, ProgrammedCnn, ProgrammedLinear};
use crate::xbar::Matrix;

/// Signed-7-bit weight range of the golden model (|w| < 64, model.py):
/// drifted cells clamp here, stuck-on cells pin to the positive rail.
const WEIGHT_MAX: i64 = 63;

/// A seeded, reproducible plan for perturbing programmed crossbar cells.
///
/// Two analog failure modes, applied per cell:
///
/// * **drift** — with probability `drift_rate`, a cell's conductance moves
///   by a uniform nonzero delta in `[-drift_mag, drift_mag]`, clamped to
///   the weight range (gradual conductance drift);
/// * **stuck-at** — with probability `stuck_rate`, a cell pins to rail:
///   stuck-off (0) or stuck-on (±full scale, keeping the cell's sign bias)
///   with equal probability (hard programming faults).
///
/// The same plan applied to the same matrix always produces the same
/// perturbation; distinct `layer` indices draw from distinct streams.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    seed: u64,
    drift_rate: f64,
    drift_mag: i64,
    stuck_rate: f64,
}

impl FaultPlan {
    /// Pure conductance-drift plan: `rate` of cells move by up to `mag`.
    pub fn drift(seed: u64, rate: f64, mag: i64) -> Self {
        assert!((0.0..=1.0).contains(&rate), "drift rate {rate} out of [0,1]");
        assert!(mag > 0, "drift magnitude must be positive");
        FaultPlan {
            seed,
            drift_rate: rate,
            drift_mag: mag,
            stuck_rate: 0.0,
        }
    }

    /// Pure stuck-at plan: `rate` of cells pin to a rail (0 or ±63).
    pub fn stuck_at(seed: u64, rate: f64) -> Self {
        assert!((0.0..=1.0).contains(&rate), "stuck rate {rate} out of [0,1]");
        FaultPlan {
            seed,
            drift_rate: 0.0,
            drift_mag: 1,
            stuck_rate: rate,
        }
    }

    /// Add stuck-at faults to a drift plan.
    pub fn with_stuck(mut self, rate: f64) -> Self {
        assert!((0.0..=1.0).contains(&rate), "stuck rate {rate} out of [0,1]");
        self.stuck_rate = rate;
        self
    }

    /// The plan's seed (chaos drivers report it so a run can be replayed).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Perturbed copy of one layer's weight matrix. Deterministic in
    /// `(self, layer, w)`; layers draw from distinct RNG streams.
    pub fn perturb(&self, layer: usize, w: &Matrix) -> Matrix {
        let mut rng = Rng::new(self.seed ^ (layer as u64).wrapping_mul(0xA076_1D64_78BD_642F));
        let mut out = w.clone();
        for v in out.data.iter_mut() {
            if self.stuck_rate > 0.0 && rng.f64() < self.stuck_rate {
                // stuck-off or stuck-on (keep the cell's sign so the rail
                // is reachable by drift too)
                *v = if rng.below(2) == 0 {
                    0
                } else if *v < 0 {
                    -WEIGHT_MAX
                } else {
                    WEIGHT_MAX
                };
            } else if self.drift_rate > 0.0 && rng.f64() < self.drift_rate {
                let mut delta = rng.range_i64(-self.drift_mag, self.drift_mag + 1);
                if delta == 0 {
                    delta = self.drift_mag; // drifted cells actually move
                }
                *v = (*v + delta).clamp(-WEIGHT_MAX, WEIGHT_MAX);
            }
        }
        out
    }

    /// Install a fault-perturbed replica of `cnn`: every layer's weights
    /// run through [`Self::perturb`], then through the ordinary install
    /// path with the per-stage scaling shifts — the exact twin of
    /// [`MiniCnn::program`] over drifted cells. The result is a
    /// first-class [`ProgrammedCnn`] the health machinery must catch by
    /// its served deviation, not by any special marking.
    pub fn program_drifted(&self, cnn: &MiniCnn, p: &XbarParams, adaptive: bool) -> ProgrammedCnn {
        let convs = cnn
            .convs
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let pp = XbarParams {
                    out_shift: cnn.shifts[i],
                    ..*p
                };
                ProgrammedLinear::install(&self.perturb(i, w), &pp, adaptive)
            })
            .collect();
        let pp = XbarParams {
            out_shift: cnn.shifts[cnn.convs.len()],
            ..*p
        };
        let fc = ProgrammedLinear::install(&self.perturb(cnn.convs.len(), &cnn.fc), &pp, adaptive);
        ProgrammedCnn::from_layers(convs, fc, cnn.act_max)
    }
}

/// Network fault kinds [`FaultyStream`] injects. `u8` repr is the RNG
/// draw; the set mirrors how real sockets fail under a flaky peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum NetFault {
    /// Delay the operation, then perform it normally.
    Stall,
    /// Fail with `ConnectionReset` and kill the stream.
    Disconnect,
    /// Flip one bit of the payload (write: before sending; read: after
    /// receiving) — downstream framing must catch it by checksum.
    Corrupt,
    /// Write a prefix of the buffer, then kill the stream (mid-frame
    /// disconnect). On the read side this degrades to `Disconnect`.
    Partial,
}

impl NetFault {
    fn draw(rng: &mut Rng) -> Self {
        match rng.below(4) {
            0 => NetFault::Stall,
            1 => NetFault::Disconnect,
            2 => NetFault::Corrupt,
            _ => NetFault::Partial,
        }
    }
}

/// A `Read + Write` wrapper that injects faults on a deterministic,
/// seeded schedule. Each IO call rolls once against `rate`; a triggered
/// roll draws one of [`NetFault`]'s kinds. After a disconnect-class fault
/// the stream is dead: every further call fails with `BrokenPipe`, like a
/// real torn socket.
///
/// Generic over the transport so the schedule is unit-testable on
/// in-memory buffers; the chaos bench wraps `TcpStream`.
pub struct FaultyStream<S> {
    inner: S,
    rng: Rng,
    rate: f64,
    stall: Duration,
    dead: bool,
    injected: Arc<AtomicU64>,
}

impl<S> FaultyStream<S> {
    /// Wrap `inner`; a fault fires on each read/write with probability
    /// `rate` (0 disables injection entirely — a pure passthrough).
    pub fn new(inner: S, seed: u64, rate: f64) -> Self {
        Self::with_counter(inner, seed, rate, Arc::new(AtomicU64::new(0)))
    }

    /// [`Self::new`] sharing an injected-fault counter across streams
    /// (the chaos bench aggregates one counter over all lanes).
    pub fn with_counter(inner: S, seed: u64, rate: f64, counter: Arc<AtomicU64>) -> Self {
        assert!((0.0..=1.0).contains(&rate), "fault rate {rate} out of [0,1]");
        FaultyStream {
            inner,
            rng: Rng::new(seed),
            rate,
            stall: Duration::from_millis(5),
            dead: false,
            injected: counter,
        }
    }

    /// Faults injected so far through this stream's counter.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// The wrapped transport.
    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    fn roll(&mut self) -> Option<NetFault> {
        if self.rate > 0.0 && self.rng.f64() < self.rate {
            self.injected.fetch_add(1, Ordering::Relaxed);
            Some(NetFault::draw(&mut self.rng))
        } else {
            None
        }
    }

    fn torn() -> io::Error {
        io::Error::new(io::ErrorKind::BrokenPipe, "fault-injected stream is dead")
    }

    fn reset() -> io::Error {
        io::Error::new(io::ErrorKind::ConnectionReset, "injected disconnect")
    }
}

impl<S: Read> Read for FaultyStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.dead {
            return Err(Self::torn());
        }
        match self.roll() {
            None => self.inner.read(buf),
            Some(NetFault::Stall) => {
                std::thread::sleep(self.stall);
                self.inner.read(buf)
            }
            Some(NetFault::Disconnect) | Some(NetFault::Partial) => {
                self.dead = true;
                Err(Self::reset())
            }
            Some(NetFault::Corrupt) => {
                let n = self.inner.read(buf)?;
                if n > 0 {
                    let i = self.rng.below(n as u64) as usize;
                    let bit = self.rng.below(8) as u8;
                    buf[i] ^= 1 << bit;
                }
                Ok(n)
            }
        }
    }
}

impl<S: Write> Write for FaultyStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.dead {
            return Err(Self::torn());
        }
        if buf.is_empty() {
            return self.inner.write(buf);
        }
        match self.roll() {
            None => self.inner.write(buf),
            Some(NetFault::Stall) => {
                std::thread::sleep(self.stall);
                self.inner.write(buf)
            }
            Some(NetFault::Disconnect) => {
                self.dead = true;
                Err(Self::reset())
            }
            Some(NetFault::Corrupt) => {
                let mut c = buf.to_vec();
                let i = self.rng.below(c.len() as u64) as usize;
                let bit = self.rng.below(8) as u8;
                c[i] ^= 1 << bit;
                self.inner.write(&c)
            }
            Some(NetFault::Partial) => {
                // deliver a nonempty prefix, then tear the stream: the
                // peer sees a frame that stops mid-payload
                let n = 1 + self.rng.below(buf.len() as u64) as usize;
                let n = n.min(buf.len());
                let written = self.inner.write(&buf[..n])?;
                self.dead = true;
                Ok(written)
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        if self.dead {
            return Err(Self::torn());
        }
        self.inner.flush()
    }
}

/// One process-level chaos action against a cluster worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosAction {
    /// SIGKILL the worker process (no drain, no goodbye — the coordinator
    /// finds out from missed heartbeats / torn connections).
    Kill,
    /// Stall the worker's links for this many milliseconds (the harness
    /// suspends forwarding to it, modelling a long GC-style pause).
    Stall(u64),
    /// Restart a previously killed worker so it can rejoin; a `Restart`
    /// for a live worker is a no-op.
    Restart,
}

/// One scheduled event: after the `at_request`-th request completes,
/// apply `action` to `worker`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosEvent {
    pub at_request: u64,
    pub worker: usize,
    pub action: ChaosAction,
}

/// A seeded, reproducible schedule of process-level chaos — the cluster
/// analogue of [`FaultPlan`] (cells) and [`FaultyStream`] (links): workers
/// are killed, stalled, and restarted at fixed points in the request
/// stream, so a chaos run replays exactly from `(seed, workers,
/// requests)`. The plan is pure data; `bench-net --cluster` owns the
/// worker processes and applies the events.
#[derive(Clone, Debug)]
pub struct ChaosPlan {
    seed: u64,
    /// Events sorted by `at_request`; `cursor` marks the first not yet
    /// taken.
    events: Vec<ChaosEvent>,
    cursor: usize,
}

impl ChaosPlan {
    /// Draw `n_events` events over `workers` workers spread across a
    /// `requests`-long run. Kills dominate (half the draws); a drawn
    /// `Restart` revives the most recent kill of that worker, or is a
    /// no-op if it was never killed. Deterministic in every argument.
    pub fn seeded(seed: u64, workers: usize, requests: u64, n_events: usize) -> Self {
        assert!(workers > 0, "chaos plan needs at least one worker");
        assert!(requests > 1, "chaos plan needs a request stream to schedule into");
        let mut rng = Rng::new(seed ^ 0xC3A5_C85C_97CB_3127);
        let mut events: Vec<ChaosEvent> = (0..n_events)
            .map(|_| {
                let at_request = 1 + rng.below(requests - 1);
                let worker = rng.below(workers as u64) as usize;
                let action = match rng.below(4) {
                    0 | 1 => ChaosAction::Kill,
                    2 => ChaosAction::Stall(1 + rng.below(50)),
                    _ => ChaosAction::Restart,
                };
                ChaosEvent {
                    at_request,
                    worker,
                    action,
                }
            })
            .collect();
        events.sort_by_key(|e| e.at_request);
        ChaosPlan {
            seed,
            events,
            cursor: 0,
        }
    }

    /// The minimal failover schedule: SIGKILL `worker` once the
    /// `at_request`-th request has completed (the verify.sh cluster smoke
    /// and the worker-kill-mid-batch test pin exactly this shape).
    pub fn kill_one(worker: usize, at_request: u64) -> Self {
        ChaosPlan {
            seed: 0,
            events: vec![ChaosEvent {
                at_request,
                worker,
                action: ChaosAction::Kill,
            }],
            cursor: 0,
        }
    }

    /// Kill `worker` at `at_request`, then restart it `gap` requests
    /// later — the rejoin path in one schedule.
    pub fn kill_then_restart(worker: usize, at_request: u64, gap: u64) -> Self {
        ChaosPlan {
            seed: 0,
            events: vec![
                ChaosEvent {
                    at_request,
                    worker,
                    action: ChaosAction::Kill,
                },
                ChaosEvent {
                    at_request: at_request + gap.max(1),
                    worker,
                    action: ChaosAction::Restart,
                },
            ],
            cursor: 0,
        }
    }

    /// The seed the schedule was drawn from (0 for explicit plans).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The full schedule, sorted by request index.
    pub fn events(&self) -> &[ChaosEvent] {
        &self.events
    }

    /// Events not yet taken by [`Self::take_due`].
    pub fn remaining(&self) -> usize {
        self.events.len() - self.cursor
    }

    /// Pop every event due once `completed` requests have finished. The
    /// driver calls this after each completion; each event is returned
    /// exactly once, in schedule order.
    pub fn take_due(&mut self, completed: u64) -> Vec<ChaosEvent> {
        let start = self.cursor;
        while self.cursor < self.events.len() && self.events[self.cursor].at_request <= completed {
            self.cursor += 1;
        }
        self.events[start..self.cursor].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.range_i64(-63, 64))
    }

    #[test]
    fn perturbation_is_deterministic_per_seed_and_layer() {
        let w = mat(16, 12, 3);
        let plan = FaultPlan::drift(7, 0.2, 20).with_stuck(0.05);
        assert_eq!(plan.perturb(0, &w).data, plan.perturb(0, &w).data);
        assert_eq!(plan.perturb(1, &w).data, plan.perturb(1, &w).data);
        // distinct layers draw distinct streams
        assert_ne!(plan.perturb(0, &w).data, plan.perturb(1, &w).data);
        // distinct seeds differ
        let other = FaultPlan::drift(8, 0.2, 20).with_stuck(0.05);
        assert_ne!(plan.perturb(0, &w).data, other.perturb(0, &w).data);
    }

    #[test]
    fn drift_moves_cells_but_stays_in_weight_range() {
        let w = mat(32, 32, 5);
        let out = FaultPlan::drift(1, 1.0, 10).perturb(0, &w);
        assert_ne!(out.data, w.data, "rate-1 drift must move something");
        let moved = out
            .data
            .iter()
            .zip(&w.data)
            .filter(|(a, b)| a != b)
            .count();
        // rate 1.0: every cell not already pinned at a rail moves
        assert!(moved > w.data.len() / 2, "only {moved} cells moved");
        assert!(out.data.iter().all(|v| (-63..=63).contains(v)));
    }

    #[test]
    fn zero_rates_are_identity() {
        let w = mat(8, 8, 2);
        let out = FaultPlan::drift(9, 0.0, 5).perturb(0, &w);
        assert_eq!(out.data, w.data);
    }

    #[test]
    fn stuck_cells_pin_to_rails() {
        let w = mat(16, 16, 11);
        let out = FaultPlan::stuck_at(3, 1.0).perturb(0, &w);
        assert!(out.data.iter().all(|&v| v == 0 || v == 63 || v == -63));
    }

    #[test]
    fn drifted_install_deviates_from_pristine() {
        let cnn = MiniCnn::new(0);
        let p = XbarParams::default();
        let pristine = cnn.program(&p, false);
        let plan = FaultPlan::drift(7, 0.02, 30);
        let drifted = plan.program_drifted(&cnn, &p, false);
        let img = crate::xbar::cnn::random_images(1, 4);
        let a = pristine.forward_seq(&img);
        let b = drifted.forward_seq(&img);
        assert_ne!(a.data, b.data, "2% drift at mag 30 must be visible");
        // and the same plan reproduces the same drifted install
        let again = plan.program_drifted(&cnn, &p, false);
        assert_eq!(b.data, again.forward_seq(&img).data);
    }

    /// In-memory transport for schedule tests: reads stream zeros.
    struct Loop {
        wrote: Vec<u8>,
    }

    impl Read for Loop {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            for b in buf.iter_mut() {
                *b = 0;
            }
            Ok(buf.len())
        }
    }

    impl Write for Loop {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.wrote.extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    /// Drive a fixed IO script; return (bytes sunk, per-call outcomes).
    fn run_script(seed: u64, rate: f64) -> (Vec<u8>, Vec<String>) {
        let mut s = FaultyStream::new(Loop { wrote: Vec::new() }, seed, rate);
        let mut log = Vec::new();
        for i in 0..40u8 {
            let out = [i; 8];
            match s.write(&out) {
                Ok(n) => log.push(format!("w{n}")),
                Err(e) => log.push(format!("we:{:?}", e.kind())),
            }
            let mut inb = [0u8; 4];
            match s.read(&mut inb) {
                Ok(n) => log.push(format!("r{n}:{}", inb[0])),
                Err(e) => log.push(format!("re:{:?}", e.kind())),
            }
        }
        (s.inner.wrote, log)
    }

    #[test]
    fn fault_schedule_is_reproducible_from_the_seed() {
        let (a_bytes, a_log) = run_script(7, 0.3);
        let (b_bytes, b_log) = run_script(7, 0.3);
        assert_eq!(a_bytes, b_bytes);
        assert_eq!(a_log, b_log);
        let (_, c_log) = run_script(8, 0.3);
        assert_ne!(a_log, c_log, "different seed, different schedule");
    }

    #[test]
    fn dead_stream_stays_dead_and_counts_faults() {
        let mut s = FaultyStream::new(Loop { wrote: Vec::new() }, 1, 1.0);
        // drive until a disconnect-class fault kills it
        let mut died = false;
        for _ in 0..64 {
            if s.write(&[1, 2, 3]).is_err() && s.dead {
                died = true;
                break;
            }
        }
        assert!(died, "rate-1 injection never tore the stream");
        assert!(s.injected() > 0);
        let err = s.write(&[4]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        let err = s.read(&mut [0; 4]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
    }

    #[test]
    fn chaos_plan_is_deterministic_and_sorted() {
        let a = ChaosPlan::seeded(11, 3, 100, 8);
        let b = ChaosPlan::seeded(11, 3, 100, 8);
        assert_eq!(a.events(), b.events());
        assert_eq!(a.seed(), 11);
        assert_eq!(a.events().len(), 8);
        assert!(a
            .events()
            .windows(2)
            .all(|w| w[0].at_request <= w[1].at_request));
        for e in a.events() {
            assert!(e.worker < 3);
            assert!((1..100).contains(&e.at_request), "never before the first or after the last request");
            if let ChaosAction::Stall(ms) = e.action {
                assert!((1..=50).contains(&ms));
            }
        }
        let c = ChaosPlan::seeded(12, 3, 100, 8);
        assert_ne!(a.events(), c.events(), "different seed, different schedule");
    }

    #[test]
    fn chaos_take_due_returns_each_event_exactly_once_in_order() {
        let mut p = ChaosPlan::seeded(5, 2, 50, 6);
        let all = p.events().to_vec();
        let mut taken = Vec::new();
        for completed in 0..=50 {
            taken.extend(p.take_due(completed));
        }
        assert_eq!(taken, all);
        assert_eq!(p.remaining(), 0);
        assert!(p.take_due(u64::MAX).is_empty(), "drained plan yields nothing");
    }

    #[test]
    fn explicit_plans_pin_their_shape() {
        let mut p = ChaosPlan::kill_one(1, 4);
        assert!(p.take_due(3).is_empty());
        assert_eq!(
            p.take_due(4),
            vec![ChaosEvent {
                at_request: 4,
                worker: 1,
                action: ChaosAction::Kill,
            }]
        );
        let p = ChaosPlan::kill_then_restart(0, 2, 0);
        // a zero gap still restarts strictly after the kill
        assert_eq!(p.events()[0].action, ChaosAction::Kill);
        assert_eq!(
            p.events()[1],
            ChaosEvent {
                at_request: 3,
                worker: 0,
                action: ChaosAction::Restart,
            }
        );
    }

    #[test]
    fn zero_rate_is_a_passthrough() {
        let mut s = FaultyStream::new(Loop { wrote: Vec::new() }, 42, 0.0);
        for _ in 0..100 {
            assert_eq!(s.write(&[9; 16]).unwrap(), 16);
            let mut b = [1u8; 8];
            assert_eq!(s.read(&mut b).unwrap(), 8);
            assert_eq!(b, [0; 8]);
        }
        assert_eq!(s.injected(), 0);
        assert_eq!(s.inner.wrote.len(), 1600);
    }
}
