//! Deterministic fault injection for chaos testing (std-only, like
//! [`crate::sched`] — no `rand` dependency).
//!
//! Newton's crossbars are analog devices: conductance drift and
//! programming error silently corrupt installed weights over time
//! (arXiv:2109.01262 measures exactly this erosion in deployed analog
//! inference), and the network in front of them fails in its own ways —
//! corrupted frames, stalled peers, mid-frame disconnects. This module
//! injects both failure classes *on a deterministic schedule*, so every
//! chaos run is reproducible from a single seed:
//!
//! * [`FaultPlan`] perturbs a replica's programmed cells — per-cell
//!   conductance drift and stuck-at faults over the weight matrices,
//!   re-installed through the ordinary
//!   [`ProgrammedLinear::install`](crate::xbar::cnn::ProgrammedLinear::install)
//!   path so the perturbed install is a first-class replica
//!   ([`FaultPlan::program_drifted`]). The health machinery in
//!   [`crate::coordinator::health`] is expected to catch the resulting
//!   deviation and quarantine the replica.
//! * [`FaultyStream`] wraps any `Read + Write` transport and injects
//!   frame corruption, partial writes, stalls, and mid-frame disconnects
//!   at a configured rate. The retrying client
//!   ([`crate::net::RetryClient`]) must mask every one of them without
//!   ever surfacing a wrong answer.
//!
//! Determinism contract: the same `(seed, rate)` against the same call
//! sequence makes the same decisions in the same order. The RNG is the
//! repo-wide xorshift64* ([`crate::util::Rng`]); every fault site derives
//! its stream from the plan seed and a site index, so schedules never
//! alias across layers or connections.

use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::config::XbarParams;
use crate::util::Rng;
use crate::xbar::cnn::{MiniCnn, ProgrammedCnn, ProgrammedLinear};
use crate::xbar::Matrix;

/// Signed-7-bit weight range of the golden model (|w| < 64, model.py):
/// drifted cells clamp here, stuck-on cells pin to the positive rail.
const WEIGHT_MAX: i64 = 63;

/// A seeded, reproducible plan for perturbing programmed crossbar cells.
///
/// Two analog failure modes, applied per cell:
///
/// * **drift** — with probability `drift_rate`, a cell's conductance moves
///   by a uniform nonzero delta in `[-drift_mag, drift_mag]`, clamped to
///   the weight range (gradual conductance drift);
/// * **stuck-at** — with probability `stuck_rate`, a cell pins to rail:
///   stuck-off (0) or stuck-on (±full scale, keeping the cell's sign bias)
///   with equal probability (hard programming faults).
///
/// The same plan applied to the same matrix always produces the same
/// perturbation; distinct `layer` indices draw from distinct streams.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    seed: u64,
    drift_rate: f64,
    drift_mag: i64,
    stuck_rate: f64,
}

impl FaultPlan {
    /// Pure conductance-drift plan: `rate` of cells move by up to `mag`.
    pub fn drift(seed: u64, rate: f64, mag: i64) -> Self {
        assert!((0.0..=1.0).contains(&rate), "drift rate {rate} out of [0,1]");
        assert!(mag > 0, "drift magnitude must be positive");
        FaultPlan {
            seed,
            drift_rate: rate,
            drift_mag: mag,
            stuck_rate: 0.0,
        }
    }

    /// Pure stuck-at plan: `rate` of cells pin to a rail (0 or ±63).
    pub fn stuck_at(seed: u64, rate: f64) -> Self {
        assert!((0.0..=1.0).contains(&rate), "stuck rate {rate} out of [0,1]");
        FaultPlan {
            seed,
            drift_rate: 0.0,
            drift_mag: 1,
            stuck_rate: rate,
        }
    }

    /// Add stuck-at faults to a drift plan.
    pub fn with_stuck(mut self, rate: f64) -> Self {
        assert!((0.0..=1.0).contains(&rate), "stuck rate {rate} out of [0,1]");
        self.stuck_rate = rate;
        self
    }

    /// The plan's seed (chaos drivers report it so a run can be replayed).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Perturbed copy of one layer's weight matrix. Deterministic in
    /// `(self, layer, w)`; layers draw from distinct RNG streams.
    pub fn perturb(&self, layer: usize, w: &Matrix) -> Matrix {
        let mut rng = Rng::new(self.seed ^ (layer as u64).wrapping_mul(0xA076_1D64_78BD_642F));
        let mut out = w.clone();
        for v in out.data.iter_mut() {
            if self.stuck_rate > 0.0 && rng.f64() < self.stuck_rate {
                // stuck-off or stuck-on (keep the cell's sign so the rail
                // is reachable by drift too)
                *v = if rng.below(2) == 0 {
                    0
                } else if *v < 0 {
                    -WEIGHT_MAX
                } else {
                    WEIGHT_MAX
                };
            } else if self.drift_rate > 0.0 && rng.f64() < self.drift_rate {
                let mut delta = rng.range_i64(-self.drift_mag, self.drift_mag + 1);
                if delta == 0 {
                    delta = self.drift_mag; // drifted cells actually move
                }
                *v = (*v + delta).clamp(-WEIGHT_MAX, WEIGHT_MAX);
            }
        }
        out
    }

    /// Install a fault-perturbed replica of `cnn`: every layer's weights
    /// run through [`Self::perturb`], then through the ordinary install
    /// path with the per-stage scaling shifts — the exact twin of
    /// [`MiniCnn::program`] over drifted cells. The result is a
    /// first-class [`ProgrammedCnn`] the health machinery must catch by
    /// its served deviation, not by any special marking.
    pub fn program_drifted(&self, cnn: &MiniCnn, p: &XbarParams, adaptive: bool) -> ProgrammedCnn {
        let convs = cnn
            .convs
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let pp = XbarParams {
                    out_shift: cnn.shifts[i],
                    ..*p
                };
                ProgrammedLinear::install(&self.perturb(i, w), &pp, adaptive)
            })
            .collect();
        let pp = XbarParams {
            out_shift: cnn.shifts[cnn.convs.len()],
            ..*p
        };
        let fc = ProgrammedLinear::install(&self.perturb(cnn.convs.len(), &cnn.fc), &pp, adaptive);
        ProgrammedCnn::from_layers(convs, fc, cnn.act_max)
    }
}

/// Network fault kinds [`FaultyStream`] injects. `u8` repr is the RNG
/// draw; the set mirrors how real sockets fail under a flaky peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum NetFault {
    /// Delay the operation, then perform it normally.
    Stall,
    /// Fail with `ConnectionReset` and kill the stream.
    Disconnect,
    /// Flip one bit of the payload (write: before sending; read: after
    /// receiving) — downstream framing must catch it by checksum.
    Corrupt,
    /// Write a prefix of the buffer, then kill the stream (mid-frame
    /// disconnect). On the read side this degrades to `Disconnect`.
    Partial,
}

impl NetFault {
    fn draw(rng: &mut Rng) -> Self {
        match rng.below(4) {
            0 => NetFault::Stall,
            1 => NetFault::Disconnect,
            2 => NetFault::Corrupt,
            _ => NetFault::Partial,
        }
    }
}

/// A `Read + Write` wrapper that injects faults on a deterministic,
/// seeded schedule. Each IO call rolls once against `rate`; a triggered
/// roll draws one of [`NetFault`]'s kinds. After a disconnect-class fault
/// the stream is dead: every further call fails with `BrokenPipe`, like a
/// real torn socket.
///
/// Generic over the transport so the schedule is unit-testable on
/// in-memory buffers; the chaos bench wraps `TcpStream`.
pub struct FaultyStream<S> {
    inner: S,
    rng: Rng,
    rate: f64,
    stall: Duration,
    dead: bool,
    injected: Arc<AtomicU64>,
}

impl<S> FaultyStream<S> {
    /// Wrap `inner`; a fault fires on each read/write with probability
    /// `rate` (0 disables injection entirely — a pure passthrough).
    pub fn new(inner: S, seed: u64, rate: f64) -> Self {
        Self::with_counter(inner, seed, rate, Arc::new(AtomicU64::new(0)))
    }

    /// [`Self::new`] sharing an injected-fault counter across streams
    /// (the chaos bench aggregates one counter over all lanes).
    pub fn with_counter(inner: S, seed: u64, rate: f64, counter: Arc<AtomicU64>) -> Self {
        assert!((0.0..=1.0).contains(&rate), "fault rate {rate} out of [0,1]");
        FaultyStream {
            inner,
            rng: Rng::new(seed),
            rate,
            stall: Duration::from_millis(5),
            dead: false,
            injected: counter,
        }
    }

    /// Faults injected so far through this stream's counter.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// The wrapped transport.
    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    fn roll(&mut self) -> Option<NetFault> {
        if self.rate > 0.0 && self.rng.f64() < self.rate {
            self.injected.fetch_add(1, Ordering::Relaxed);
            Some(NetFault::draw(&mut self.rng))
        } else {
            None
        }
    }

    fn torn() -> io::Error {
        io::Error::new(io::ErrorKind::BrokenPipe, "fault-injected stream is dead")
    }

    fn reset() -> io::Error {
        io::Error::new(io::ErrorKind::ConnectionReset, "injected disconnect")
    }
}

impl<S: Read> Read for FaultyStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.dead {
            return Err(Self::torn());
        }
        match self.roll() {
            None => self.inner.read(buf),
            Some(NetFault::Stall) => {
                std::thread::sleep(self.stall);
                self.inner.read(buf)
            }
            Some(NetFault::Disconnect) | Some(NetFault::Partial) => {
                self.dead = true;
                Err(Self::reset())
            }
            Some(NetFault::Corrupt) => {
                let n = self.inner.read(buf)?;
                if n > 0 {
                    let i = self.rng.below(n as u64) as usize;
                    let bit = self.rng.below(8) as u8;
                    buf[i] ^= 1 << bit;
                }
                Ok(n)
            }
        }
    }
}

impl<S: Write> Write for FaultyStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.dead {
            return Err(Self::torn());
        }
        if buf.is_empty() {
            return self.inner.write(buf);
        }
        match self.roll() {
            None => self.inner.write(buf),
            Some(NetFault::Stall) => {
                std::thread::sleep(self.stall);
                self.inner.write(buf)
            }
            Some(NetFault::Disconnect) => {
                self.dead = true;
                Err(Self::reset())
            }
            Some(NetFault::Corrupt) => {
                let mut c = buf.to_vec();
                let i = self.rng.below(c.len() as u64) as usize;
                let bit = self.rng.below(8) as u8;
                c[i] ^= 1 << bit;
                self.inner.write(&c)
            }
            Some(NetFault::Partial) => {
                // deliver a nonempty prefix, then tear the stream: the
                // peer sees a frame that stops mid-payload
                let n = 1 + self.rng.below(buf.len() as u64) as usize;
                let n = n.min(buf.len());
                let written = self.inner.write(&buf[..n])?;
                self.dead = true;
                Ok(written)
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        if self.dead {
            return Err(Self::torn());
        }
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.range_i64(-63, 64))
    }

    #[test]
    fn perturbation_is_deterministic_per_seed_and_layer() {
        let w = mat(16, 12, 3);
        let plan = FaultPlan::drift(7, 0.2, 20).with_stuck(0.05);
        assert_eq!(plan.perturb(0, &w).data, plan.perturb(0, &w).data);
        assert_eq!(plan.perturb(1, &w).data, plan.perturb(1, &w).data);
        // distinct layers draw distinct streams
        assert_ne!(plan.perturb(0, &w).data, plan.perturb(1, &w).data);
        // distinct seeds differ
        let other = FaultPlan::drift(8, 0.2, 20).with_stuck(0.05);
        assert_ne!(plan.perturb(0, &w).data, other.perturb(0, &w).data);
    }

    #[test]
    fn drift_moves_cells_but_stays_in_weight_range() {
        let w = mat(32, 32, 5);
        let out = FaultPlan::drift(1, 1.0, 10).perturb(0, &w);
        assert_ne!(out.data, w.data, "rate-1 drift must move something");
        let moved = out
            .data
            .iter()
            .zip(&w.data)
            .filter(|(a, b)| a != b)
            .count();
        // rate 1.0: every cell not already pinned at a rail moves
        assert!(moved > w.data.len() / 2, "only {moved} cells moved");
        assert!(out.data.iter().all(|v| (-63..=63).contains(v)));
    }

    #[test]
    fn zero_rates_are_identity() {
        let w = mat(8, 8, 2);
        let out = FaultPlan::drift(9, 0.0, 5).perturb(0, &w);
        assert_eq!(out.data, w.data);
    }

    #[test]
    fn stuck_cells_pin_to_rails() {
        let w = mat(16, 16, 11);
        let out = FaultPlan::stuck_at(3, 1.0).perturb(0, &w);
        assert!(out.data.iter().all(|&v| v == 0 || v == 63 || v == -63));
    }

    #[test]
    fn drifted_install_deviates_from_pristine() {
        let cnn = MiniCnn::new(0);
        let p = XbarParams::default();
        let pristine = cnn.program(&p, false);
        let plan = FaultPlan::drift(7, 0.02, 30);
        let drifted = plan.program_drifted(&cnn, &p, false);
        let img = crate::xbar::cnn::random_images(1, 4);
        let a = pristine.forward_seq(&img);
        let b = drifted.forward_seq(&img);
        assert_ne!(a.data, b.data, "2% drift at mag 30 must be visible");
        // and the same plan reproduces the same drifted install
        let again = plan.program_drifted(&cnn, &p, false);
        assert_eq!(b.data, again.forward_seq(&img).data);
    }

    /// In-memory transport for schedule tests: reads stream zeros.
    struct Loop {
        wrote: Vec<u8>,
    }

    impl Read for Loop {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            for b in buf.iter_mut() {
                *b = 0;
            }
            Ok(buf.len())
        }
    }

    impl Write for Loop {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.wrote.extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    /// Drive a fixed IO script; return (bytes sunk, per-call outcomes).
    fn run_script(seed: u64, rate: f64) -> (Vec<u8>, Vec<String>) {
        let mut s = FaultyStream::new(Loop { wrote: Vec::new() }, seed, rate);
        let mut log = Vec::new();
        for i in 0..40u8 {
            let out = [i; 8];
            match s.write(&out) {
                Ok(n) => log.push(format!("w{n}")),
                Err(e) => log.push(format!("we:{:?}", e.kind())),
            }
            let mut inb = [0u8; 4];
            match s.read(&mut inb) {
                Ok(n) => log.push(format!("r{n}:{}", inb[0])),
                Err(e) => log.push(format!("re:{:?}", e.kind())),
            }
        }
        (s.inner.wrote, log)
    }

    #[test]
    fn fault_schedule_is_reproducible_from_the_seed() {
        let (a_bytes, a_log) = run_script(7, 0.3);
        let (b_bytes, b_log) = run_script(7, 0.3);
        assert_eq!(a_bytes, b_bytes);
        assert_eq!(a_log, b_log);
        let (_, c_log) = run_script(8, 0.3);
        assert_ne!(a_log, c_log, "different seed, different schedule");
    }

    #[test]
    fn dead_stream_stays_dead_and_counts_faults() {
        let mut s = FaultyStream::new(Loop { wrote: Vec::new() }, 1, 1.0);
        // drive until a disconnect-class fault kills it
        let mut died = false;
        for _ in 0..64 {
            if s.write(&[1, 2, 3]).is_err() && s.dead {
                died = true;
                break;
            }
        }
        assert!(died, "rate-1 injection never tore the stream");
        assert!(s.injected() > 0);
        let err = s.write(&[4]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        let err = s.read(&mut [0; 4]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
    }

    #[test]
    fn zero_rate_is_a_passthrough() {
        let mut s = FaultyStream::new(Loop { wrote: Vec::new() }, 42, 0.0);
        for _ in 0..100 {
            assert_eq!(s.write(&[9; 16]).unwrap(), 16);
            let mut b = [1u8; 8];
            assert_eq!(s.read(&mut b).unwrap(), 8);
            assert_eq!(b, [0; 8]);
        }
        assert_eq!(s.injected(), 0);
        assert_eq!(s.inner.wrote.len(), 1600);
    }
}
