//! The paper's benchmark suite (Table II): nine ImageNet CNNs spanning the
//! dataflow space — shallow (Alexnet), deep/wide (VGG, MSRA PReLU-nets) and
//! residual (Resnet-34). Serve-path role: these are analytic workload
//! *descriptions* (the served model is `coordinator::newton_mini`, which
//! reuses the same [`Network`] type for its simulated-hardware report).
//!
//! Table-II notes: the printed table garbles a few entries (OCR of the
//! original): Alexnet's conv1 stride ("11x11, 96 (4)" = 11x11, 96/4) and
//! VGG-C's 1x1 widths (standard VGG-C uses 1x1 at the block width). We
//! encode the canonical architectures those entries refer to, and keep the
//! printed layer counts where they are unambiguous (e.g. VGG-D with 4-deep
//! 256/512 blocks, MSRA-A/B/C with 5/6/6-deep blocks).

/// One network layer, as the resource model sees it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Layer {
    Conv {
        /// Square kernel size.
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        /// Input feature-map width (= height).
        in_hw: usize,
    },
    Pool {
        k: usize,
        stride: usize,
        cin: usize,
        in_hw: usize,
    },
    Fc {
        inputs: usize,
        outputs: usize,
    },
    /// Recurrent cell (paper conclusion: "would also apply to ... RNN,
    /// LSTM"): the weight matrix is installed once and fired `steps` times
    /// per sequence — in-situ reuse digital accelerators cannot match.
    Rnn {
        inputs: usize,
        outputs: usize,
        steps: usize,
    },
}

impl Layer {
    pub fn is_conv(&self) -> bool {
        matches!(self, Layer::Conv { .. })
    }

    pub fn is_fc(&self) -> bool {
        matches!(self, Layer::Fc { .. })
    }

    /// Output feature-map width (convs/pools; SAME padding model).
    pub fn out_hw(&self) -> usize {
        match *self {
            Layer::Conv { stride, in_hw, .. } => in_hw.div_ceil(stride),
            Layer::Pool { stride, in_hw, .. } => in_hw.div_ceil(stride),
            Layer::Fc { .. } | Layer::Rnn { .. } => 1,
        }
    }

    /// Synaptic weights (16-bit words).
    pub fn weights(&self) -> usize {
        match *self {
            Layer::Conv { k, cin, cout, .. } => k * k * cin * cout,
            Layer::Fc { inputs, outputs } => inputs * outputs,
            Layer::Rnn { inputs, outputs, .. } => inputs * outputs,
            Layer::Pool { .. } => 0,
        }
    }

    /// MACs per image (per sequence for recurrent layers).
    pub fn macs(&self) -> usize {
        match *self {
            Layer::Conv { .. } => self.weights() * self.out_hw() * self.out_hw(),
            Layer::Fc { .. } => self.weights(),
            Layer::Rnn { steps, .. } => self.weights() * steps,
            Layer::Pool { .. } => 0,
        }
    }

    /// Logical crossbar matrix: (reduction rows, output columns).
    pub fn matrix(&self) -> Option<(usize, usize)> {
        match *self {
            Layer::Conv { k, cin, cout, .. } => Some((k * k * cin, cout)),
            Layer::Fc { inputs, outputs } => Some((inputs, outputs)),
            Layer::Rnn { inputs, outputs, .. } => Some((inputs, outputs)),
            Layer::Pool { .. } => None,
        }
    }

    /// Output neurons produced per image (the inter-layer traffic).
    pub fn out_neurons(&self) -> usize {
        match *self {
            Layer::Conv { cout, .. } => cout * self.out_hw() * self.out_hw(),
            Layer::Fc { outputs, .. } => outputs,
            Layer::Rnn { outputs, steps, .. } => outputs * steps,
            Layer::Pool { cin, .. } => cin * self.out_hw() * self.out_hw(),
        }
    }

    /// VMM firings per image (what the tile pipeline schedules).
    pub fn fires(&self) -> usize {
        match *self {
            Layer::Conv { .. } => self.out_hw() * self.out_hw(),
            Layer::Fc { .. } => 1,
            Layer::Rnn { steps, .. } => steps,
            Layer::Pool { .. } => 0,
        }
    }
}

/// A benchmark network.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: &'static str,
    pub layers: Vec<Layer>,
}

/// Builder that tracks the running feature-map size.
struct Net {
    name: &'static str,
    hw: usize,
    c: usize,
    layers: Vec<Layer>,
}

impl Net {
    fn new(name: &'static str, hw: usize, c: usize) -> Self {
        Net {
            name,
            hw,
            c,
            layers: Vec::new(),
        }
    }

    fn conv(mut self, k: usize, cout: usize, stride: usize) -> Self {
        let l = Layer::Conv {
            k,
            cin: self.c,
            cout,
            stride,
            in_hw: self.hw,
        };
        self.hw = l.out_hw();
        self.c = cout;
        self.layers.push(l);
        self
    }

    fn convs(mut self, k: usize, cout: usize, t: usize) -> Self {
        for _ in 0..t {
            self = self.conv(k, cout, 1);
        }
        self
    }

    fn pool(mut self, k: usize, stride: usize) -> Self {
        let l = Layer::Pool {
            k,
            stride,
            cin: self.c,
            in_hw: self.hw,
        };
        self.hw = l.out_hw();
        self.layers.push(l);
        self
    }

    /// Spatial pyramid pooling (MSRA): bins 7,3,2,1 -> 63 spatial outputs.
    fn spp(mut self) -> Self {
        let in_hw = self.hw;
        self.layers.push(Layer::Pool {
            k: 7,
            stride: 7,
            cin: self.c,
            in_hw,
        });
        self.hw = 0; // consumed; fc() then uses the 63-bin spp output
        self
    }

    fn fc(mut self, outputs: usize) -> Self {
        let inputs = if self.hw == 0 {
            63 * self.c // spp bins: 49 + 9 + 4 + 1
        } else {
            self.hw * self.hw * self.c
        };
        self.layers.push(Layer::Fc { inputs, outputs });
        self.hw = 0;
        self.c = outputs;
        self
    }

    fn fc_from(mut self, inputs: usize, outputs: usize) -> Self {
        self.layers.push(Layer::Fc { inputs, outputs });
        self.hw = 0;
        self.c = outputs;
        self
    }

    fn build(self) -> Network {
        Network {
            name: self.name,
            layers: self.layers,
        }
    }
}

impl Network {
    pub fn conv_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.is_conv())
    }

    pub fn fc_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.is_fc())
    }

    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weights()).sum()
    }

    pub fn total_macs(&self) -> usize {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Scale every feature map (Fig 15's image-size axis). Input 224 -> w.
    pub fn with_input_width(&self, w: usize) -> Network {
        let f = w as f64 / 224.0;
        let scale = |hw: usize| ((hw as f64 * f).round() as usize).max(1);
        Network {
            name: self.name,
            layers: self
                .layers
                .iter()
                .map(|l| match *l {
                    Layer::Conv {
                        k,
                        cin,
                        cout,
                        stride,
                        in_hw,
                    } => Layer::Conv {
                        k,
                        cin,
                        cout,
                        stride,
                        in_hw: scale(in_hw),
                    },
                    Layer::Pool {
                        k,
                        stride,
                        cin,
                        in_hw,
                    } => Layer::Pool {
                        k,
                        stride,
                        cin,
                        in_hw: scale(in_hw),
                    },
                    fc => fc,
                })
                .collect(),
        }
    }
}

pub fn alexnet() -> Network {
    Net::new("alexnet", 224, 3)
        .conv(11, 96, 4)
        .pool(3, 2)
        .conv(5, 256, 1)
        .pool(3, 2)
        .conv(3, 384, 1)
        .conv(3, 384, 1)
        .conv(3, 256, 1)
        .pool(3, 2)
        .fc(4096)
        .fc_from(4096, 4096)
        .fc_from(4096, 1000)
        .build()
}

fn vgg(name: &'static str, depths: [usize; 5], one_by_one: bool) -> Network {
    let widths = [64, 128, 256, 512, 512];
    let mut n = Net::new(name, 224, 3);
    for (i, (&d, &w)) in depths.iter().zip(widths.iter()).enumerate() {
        n = n.convs(3, w, d);
        if one_by_one && i >= 2 {
            n = n.conv(1, w, 1);
        }
        n = n.pool(2, 2);
    }
    n.fc(4096).fc_from(4096, 4096).fc_from(4096, 1000).build()
}

pub fn vgg_a() -> Network {
    vgg("vgg-a", [1, 1, 2, 2, 2], false)
}

pub fn vgg_b() -> Network {
    vgg("vgg-b", [2, 2, 2, 2, 2], false)
}

pub fn vgg_c() -> Network {
    vgg("vgg-c", [2, 2, 2, 2, 2], true)
}

pub fn vgg_d() -> Network {
    vgg("vgg-d", [2, 2, 4, 4, 4], false)
}

fn msra(name: &'static str, t: usize, widths: [usize; 3]) -> Network {
    Net::new(name, 224, 3)
        .conv(7, 96, 2)
        .pool(3, 2)
        .convs(3, widths[0], t)
        .pool(2, 2)
        .convs(3, widths[1], t)
        .pool(2, 2)
        .convs(3, widths[2], t)
        .spp()
        .fc(4096)
        .fc_from(4096, 4096)
        .fc_from(4096, 1000)
        .build()
}

pub fn msra_a() -> Network {
    msra("msra-a", 5, [256, 512, 512])
}

pub fn msra_b() -> Network {
    msra("msra-b", 6, [256, 512, 512])
}

pub fn msra_c() -> Network {
    msra("msra-c", 6, [384, 768, 896])
}

/// An LSTM stack (conclusion's extension): `layers` LSTM cells of width
/// `hidden` over sequences of `steps` tokens, then a classifier. Each cell
/// holds the four gate matrices as one (input+hidden) x 4*hidden crossbar
/// matrix — installed once, fired every timestep.
pub fn lstm(name: &'static str, input: usize, hidden: usize, layers: usize, steps: usize) -> Network {
    let mut net = Vec::new();
    let mut in_dim = input;
    for _ in 0..layers {
        net.push(Layer::Rnn {
            inputs: in_dim + hidden,
            outputs: 4 * hidden,
            steps,
        });
        in_dim = hidden;
    }
    net.push(Layer::Fc {
        inputs: hidden,
        outputs: 1000,
    });
    Network { name, layers: net }
}

pub fn resnet34() -> Network {
    Net::new("resnet-34", 224, 3)
        .conv(7, 64, 2)
        .pool(3, 2)
        .convs(3, 64, 6)
        .conv(3, 128, 2)
        .convs(3, 128, 7)
        .conv(3, 256, 2)
        .convs(3, 256, 11)
        .conv(3, 512, 2)
        .convs(3, 512, 5)
        .pool(7, 7)
        .fc(1000)
        .build()
}

/// The full Table-II suite, in the paper's order.
pub fn suite() -> Vec<Network> {
    vec![
        alexnet(),
        vgg_a(),
        vgg_b(),
        vgg_c(),
        vgg_d(),
        msra_a(),
        msra_b(),
        msra_c(),
        resnet34(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_nine_networks() {
        let s = suite();
        assert_eq!(s.len(), 9);
        let names: Vec<_> = s.iter().map(|n| n.name).collect();
        assert!(names.contains(&"alexnet") && names.contains(&"resnet-34"));
    }

    #[test]
    fn alexnet_parameter_count_is_canonical() {
        // canonical AlexNet is ~61M params; ours lands slightly higher
        // because the SAME-padding model gives fc1 a 7x7x256 input (51.4M)
        // vs the canonical 6x6x256 (37.7M)
        let w = alexnet().total_weights();
        assert!((55e6..85e6).contains(&(w as f64)), "{w}");
    }

    #[test]
    fn msra_c_is_much_bigger_than_alexnet() {
        // paper §II-A: MSRA has ~330M params, ~5.5x Alexnet
        let a = alexnet().total_weights() as f64;
        let m = msra_c().total_weights() as f64;
        assert!(m / a > 4.0, "ratio {}", m / a);
        assert!((250e6..400e6).contains(&m), "{m}");
    }

    #[test]
    fn resnet_has_few_weights_but_many_layers() {
        let r = resnet34();
        assert_eq!(r.conv_layers().count(), 33);
        let w = r.total_weights() as f64;
        assert!((18e6..30e6).contains(&w), "{w}");
    }

    #[test]
    fn vgg_macs_dominated_by_convs() {
        let v = vgg_d();
        let conv_macs: usize = v.conv_layers().map(|l| l.macs()).sum();
        assert!(conv_macs as f64 / v.total_macs() as f64 > 0.85);
    }

    #[test]
    fn feature_maps_shrink_monotonically() {
        for net in suite() {
            let mut last = usize::MAX;
            for l in net.conv_layers() {
                if let Layer::Conv { in_hw, .. } = l {
                    assert!(*in_hw <= last);
                    last = *in_hw;
                }
            }
        }
    }

    #[test]
    fn input_width_scaling_is_linear_in_pixels() {
        let n = vgg_a();
        let n2 = n.with_input_width(448);
        let m1 = n.total_macs() as f64;
        let m2 = n2.total_macs() as f64;
        assert!((m2 / m1 - 4.0).abs() < 0.3, "{}", m2 / m1);
    }

    #[test]
    fn layer_geometry_helpers() {
        let l = Layer::Conv {
            k: 3,
            cin: 64,
            cout: 128,
            stride: 1,
            in_hw: 56,
        };
        assert_eq!(l.out_hw(), 56);
        assert_eq!(l.matrix(), Some((576, 128)));
        assert_eq!(l.weights(), 73728);
        assert_eq!(l.macs(), 73728 * 56 * 56);
        let f = Layer::Fc {
            inputs: 4096,
            outputs: 1000,
        };
        assert_eq!(f.matrix(), Some((4096, 1000)));
        assert_eq!(f.out_neurons(), 1000);
    }
}
