//! Architecture configuration: crossbar, IMA, tile and chip parameters.
//!
//! Defaults follow the paper's optimal design point (§IV "Design Points"):
//! 128x128 crossbars with 2-bit cells and 1-bit DACs, IMAs that process 128
//! inputs for 256 neurons (16 crossbars), 16 IMAs per tile. The ISAAC
//! baseline (§II-C) is 8 crossbars per IMA, 12 IMAs per tile, 64 KB eDRAM,
//! with an unconstrained mapping and a worst-case-provisioned HTree.

/// Physical crossbar + converter parameters (paper Table I).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct XbarParams {
    /// Wordlines (simultaneously active rows).
    pub rows: usize,
    /// Bitlines.
    pub cols: usize,
    /// Bits stored per memristor cell.
    pub cell_bits: u32,
    /// Input bits applied per 100 ns iteration (DAC resolution).
    pub dac_bits: u32,
    /// Fixed-point weight width.
    pub weight_bits: u32,
    /// Fixed-point input width.
    pub input_bits: u32,
    /// SAR ADC resolution (bits at full precision).
    pub adc_bits: u32,
    /// Crossbar read (one iteration) latency in nanoseconds.
    pub read_ns: f64,
    /// LSBs dropped by the scaling stage (paper: 10).
    pub out_shift: u32,
    /// Output fixed-point window (paper: 16).
    pub out_bits: u32,
}

impl Default for XbarParams {
    fn default() -> Self {
        XbarParams {
            rows: 128,
            cols: 128,
            cell_bits: 2,
            dac_bits: 1,
            weight_bits: 16,
            input_bits: 16,
            adc_bits: 9,
            read_ns: 100.0,
            out_shift: 10,
            out_bits: 16,
        }
    }
}

impl XbarParams {
    /// Crossbars (cell planes) holding one full-width weight.
    pub fn slices(&self) -> usize {
        (self.weight_bits as usize).div_ceil(self.cell_bits as usize)
    }

    /// Iterations streaming one full-width input.
    pub fn iters(&self) -> usize {
        (self.input_bits as usize).div_ceil(self.dac_bits as usize)
    }

    /// Full-width weights stored per crossbar.
    pub fn weights_per_xbar(&self) -> usize {
        self.rows * self.cols / self.slices()
    }

    /// Latency of one full vector-matrix multiply (all input iterations).
    pub fn vmm_ns(&self) -> f64 {
        self.read_ns * self.iters() as f64
    }

    /// Worst-case analog column sum needs this many ADC bits to be lossless.
    pub fn lossless_adc_bits(&self) -> u32 {
        let max_sum = self.rows as u64
            * ((1u64 << self.dac_bits) - 1)
            * ((1u64 << self.cell_bits) - 1);
        64 - max_sum.leading_zeros()
    }
}

/// ADC operating mode for a served pipeline — the fidelity-vs-cost knob the
/// serving stack plumbs end-to-end (`newton serve --adc ...`), so the
/// sweeps in the spirit of arXiv:2109.01262 / arXiv:2403.13082 can run
/// against served traffic instead of only the analytic model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdcKind {
    /// Lossless full-resolution ADC (the paper's default 9-bit budget).
    Exact,
    /// Adaptive SAR scheme (§III-A3): bits outside the kept output window
    /// are gated; numerics stay within the analytic rounding bound.
    Adaptive,
    /// Truncating lossy ADC at the given resolution (bits). On the CLI a
    /// bare `--adc lossy` means `Lossy(8)` — one bit below the default
    /// geometry's 9-bit lossless budget, i.e. the cheapest resolution that
    /// actually truncates (see [`AdcKind::parse`]). A resolution at or
    /// above [`XbarParams::lossless_adc_bits`] keeps the `lossy` label but
    /// is numerically exact, so no golden reference install rides along.
    Lossy(u32),
}

impl AdcKind {
    /// Parse a `--adc` flag value: `exact` (alias `lossless`), `adaptive`,
    /// `lossy` or `lossy:<bits>`. Matching is case-insensitive and ignores
    /// surrounding whitespace.
    ///
    /// Bare `lossy` means **`lossy:8`** — 8 bits is one below the default
    /// geometry's 9-bit lossless budget ([`XbarParams::lossless_adc_bits`]),
    /// i.e. the cheapest resolution that actually truncates, which is the
    /// interesting starting point for a fidelity sweep. Spell out
    /// `lossy:<bits>` to pick any other resolution.
    pub fn parse(s: &str) -> Result<AdcKind, String> {
        let norm = s.trim().to_ascii_lowercase();
        match norm.as_str() {
            "exact" | "lossless" => Ok(AdcKind::Exact),
            "adaptive" => Ok(AdcKind::Adaptive),
            "lossy" => Ok(AdcKind::Lossy(8)),
            other => match other.strip_prefix("lossy:") {
                Some(bits) => {
                    let b: u32 = bits
                        .parse()
                        .map_err(|_| format!("bad --adc lossy resolution {bits:?}"))?;
                    if !(1..=16).contains(&b) {
                        return Err(format!("--adc lossy:{b}: resolution must be 1..=16 bits"));
                    }
                    Ok(AdcKind::Lossy(b))
                }
                None => Err(format!(
                    "unknown --adc kind {other:?}; try exact|adaptive|lossy:<bits>"
                )),
            },
        }
    }

    /// Apply the kind to base pipeline parameters, returning the effective
    /// `(XbarParams, adaptive)` pair every crossbar entry point takes.
    pub fn apply(&self, base: &XbarParams) -> (XbarParams, bool) {
        match *self {
            AdcKind::Exact => (*base, false),
            AdcKind::Adaptive => (*base, true),
            AdcKind::Lossy(bits) => (
                XbarParams {
                    adc_bits: bits,
                    ..*base
                },
                false,
            ),
        }
    }

    /// Human label for tables and serve output (same as [`Display`]).
    pub fn label(&self) -> String {
        self.to_string()
    }
}

/// Renders in the exact syntax [`AdcKind::parse`] accepts, so every kind
/// round-trips: `parse(&k.to_string()) == Ok(k)`.
impl std::fmt::Display for AdcKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            AdcKind::Exact => f.write_str("exact"),
            AdcKind::Adaptive => f.write_str("adaptive"),
            AdcKind::Lossy(bits) => write!(f, "lossy:{bits}"),
        }
    }
}

/// In-situ multiply-accumulate unit: a group of crossbars sharing an input
/// HTree, their ADCs, and shift-and-add reduction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ImaConfig {
    /// Inputs the IMA accepts per VMM (= crossbar rows under the Newton
    /// constraint "a maximum of 128 inputs").
    pub inputs: usize,
    /// Output neurons produced per VMM.
    pub outputs: usize,
    /// Crossbars per ADC (1 for conv tiles, up to 4 for FC tiles, §III-B2).
    pub xbars_per_adc: usize,
    /// ADC sampling-rate slowdown vs 1.28 GS/s (1 = full rate; FC tiles run
    /// 8x/32x/128x slower, Fig 17).
    pub adc_slowdown: f64,
    /// Karatsuba divide-&-conquer recursion depth (0 = off, §III-A1).
    pub karatsuba: u32,
}

impl ImaConfig {
    /// The paper's optimal IMA: 128 inputs -> 256 neurons.
    pub fn newton_default() -> Self {
        ImaConfig {
            inputs: 128,
            outputs: 256,
            xbars_per_adc: 1,
            adc_slowdown: 1.0,
            karatsuba: 0,
        }
    }

    /// ISAAC IMA: 8 crossbars, unconstrained input feed.
    pub fn isaac_default() -> Self {
        ImaConfig {
            inputs: 128,
            outputs: 128,
            xbars_per_adc: 1,
            adc_slowdown: 1.0,
            karatsuba: 0,
        }
    }

    /// Crossbars needed for the logical (inputs x outputs) matrix at full
    /// weight precision (no Karatsuba).
    pub fn xbars(&self, p: &XbarParams) -> usize {
        let row_groups = self.inputs.div_ceil(p.rows);
        let col_xbars = (self.outputs * p.slices()).div_ceil(p.cols);
        row_groups * col_xbars
    }

    /// ADCs in the IMA.
    pub fn adcs(&self, p: &XbarParams) -> usize {
        self.xbars(p).div_ceil(self.xbars_per_adc)
    }

    /// Peak 16-bit ops per second (1 MAC = 2 ops, ISAAC counting).
    pub fn peak_gops(&self, p: &XbarParams) -> f64 {
        let macs = (self.inputs * self.outputs) as f64;
        2.0 * macs / p.vmm_ns() / 1.0 // ns -> GOPS: ops/ns = GOPS
    }
}

/// Tile flavour (§III-B2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileKind {
    Conv,
    Fc,
}

/// A tile: eDRAM buffer + IMAs + digital units + router share.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TileConfig {
    pub kind: TileKind,
    pub imas_per_tile: usize,
    pub ima: ImaConfig,
    /// eDRAM input buffer per tile, KB.
    pub edram_kb: f64,
    /// Output-HTree width in bits per neuron result carried to the tile
    /// output register (39 for ISAAC's full accumulator, 16 once the
    /// adaptive ADC clamps/rounds at the source, Fig 12).
    pub out_htree_bits: u32,
    /// Input HTree provisioned for this many independent input streams
    /// (ISAAC worst case: one per crossbar; Newton constrained: 1).
    pub in_streams: usize,
}

impl TileConfig {
    pub fn newton_conv() -> Self {
        TileConfig {
            kind: TileKind::Conv,
            imas_per_tile: 16,
            ima: ImaConfig::newton_default(),
            edram_kb: 16.0,
            out_htree_bits: 16,
            in_streams: 1,
        }
    }

    pub fn newton_fc() -> Self {
        TileConfig {
            kind: TileKind::Fc,
            imas_per_tile: 16,
            ima: ImaConfig {
                xbars_per_adc: 4,
                adc_slowdown: 128.0,
                ..ImaConfig::newton_default()
            },
            edram_kb: 4.0,
            out_htree_bits: 16,
            in_streams: 1,
        }
    }

    pub fn isaac() -> Self {
        TileConfig {
            kind: TileKind::Conv,
            imas_per_tile: 12,
            ima: ImaConfig::isaac_default(),
            edram_kb: 64.0,
            out_htree_bits: 39,
            // ISAAC's HTree can feed every crossbar an independent stream.
            in_streams: 8,
        }
    }

    /// Peak tile throughput in GOPS.
    pub fn peak_gops(&self, p: &XbarParams) -> f64 {
        self.imas_per_tile as f64 * self.ima.peak_gops(p) / self.ima.adc_slowdown
    }
}

/// Which Newton techniques are enabled — the incremental-results axis of
/// Figs 11/12/14/16/19/20.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NewtonFeatures {
    /// Constrained mapping + compact HTree (§III-C first enhancement).
    pub constrained_mapping: bool,
    /// Adaptive (heterogeneous-resolution) SAR ADC sampling (§III-A3).
    pub adaptive_adc: bool,
    /// Karatsuba divide & conquer depth (0 = off).
    pub karatsuba: u32,
    /// Layer spreading for small eDRAM buffers (§III-B1).
    pub small_buffers: bool,
    /// Heterogeneous conv/FC tiles (§III-B2).
    pub hetero_tiles: bool,
    /// Strassen's algorithm across IMAs (§III-A2).
    pub strassen: bool,
}

impl NewtonFeatures {
    pub fn none() -> Self {
        Self::default()
    }

    /// Everything on — the full Newton design point.
    pub fn all() -> Self {
        NewtonFeatures {
            constrained_mapping: true,
            adaptive_adc: true,
            karatsuba: 1,
            small_buffers: true,
            hetero_tiles: true,
            strassen: true,
        }
    }

    /// The incremental stacking order used by the paper's results section.
    pub fn incremental() -> Vec<(&'static str, NewtonFeatures)> {
        let mut f = NewtonFeatures::none();
        let mut out = vec![("isaac", f)];
        f.constrained_mapping = true;
        out.push(("+constrained-htree", f));
        f.adaptive_adc = true;
        out.push(("+adaptive-adc", f));
        f.karatsuba = 1;
        out.push(("+karatsuba", f));
        f.small_buffers = true;
        out.push(("+small-buffers", f));
        f.strassen = true;
        out.push(("+strassen", f));
        f.hetero_tiles = true;
        out.push(("+fc-tiles (newton)", f));
        out
    }
}

/// Whole-chip configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ChipConfig {
    pub xbar: XbarParams,
    pub conv_tile: TileConfig,
    pub fc_tile: TileConfig,
    pub features: NewtonFeatures,
    /// Tiles sharing one router (ISAAC: 4).
    pub tiles_per_router: usize,
    /// Router payload bandwidth, GB/s per router.
    pub router_gbps: f64,
    /// Off-chip HyperTransport links per chip.
    pub ht_links: usize,
    /// Max tiles per chip (area budget guard).
    pub max_tiles: usize,
}

impl ChipConfig {
    pub fn isaac() -> Self {
        ChipConfig {
            xbar: XbarParams::default(),
            conv_tile: TileConfig::isaac(),
            fc_tile: TileConfig::isaac(),
            features: NewtonFeatures::none(),
            tiles_per_router: 4,
            router_gbps: 32.0,
            ht_links: 4,
            max_tiles: 168,
        }
    }

    pub fn newton() -> Self {
        Self::newton_with(NewtonFeatures::all())
    }

    /// Newton hardware with a chosen feature subset. Disabled features fall
    /// back to the ISAAC provisioning for the corresponding resource.
    pub fn newton_with(features: NewtonFeatures) -> Self {
        let mut conv = TileConfig::newton_conv();
        conv.ima.karatsuba = features.karatsuba;
        if !features.constrained_mapping {
            conv.in_streams = TileConfig::isaac().in_streams;
        }
        if !features.adaptive_adc {
            conv.out_htree_bits = 39;
        }
        if !features.small_buffers {
            conv.edram_kb = 64.0;
        }
        let fc = if features.hetero_tiles {
            let mut fc = TileConfig::newton_fc();
            fc.ima.karatsuba = features.karatsuba;
            fc
        } else {
            conv
        };
        ChipConfig {
            xbar: XbarParams::default(),
            conv_tile: conv,
            fc_tile: fc,
            features,
            tiles_per_router: 4,
            router_gbps: 32.0,
            ht_links: 4,
            max_tiles: 168,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adc_kind_parses_and_applies() {
        assert_eq!(AdcKind::parse("exact"), Ok(AdcKind::Exact));
        assert_eq!(AdcKind::parse("lossless"), Ok(AdcKind::Exact));
        assert_eq!(AdcKind::parse("adaptive"), Ok(AdcKind::Adaptive));
        assert_eq!(AdcKind::parse("lossy"), Ok(AdcKind::Lossy(8)));
        assert_eq!(AdcKind::parse("lossy:7"), Ok(AdcKind::Lossy(7)));
        assert!(AdcKind::parse("lossy:0").is_err());
        assert!(AdcKind::parse("lossy:17").is_err());
        assert!(AdcKind::parse("lossy:x").is_err());
        assert!(AdcKind::parse("nope").is_err());

        let base = XbarParams::default();
        let (p, a) = AdcKind::Exact.apply(&base);
        assert_eq!((p, a), (base, false));
        let (p, a) = AdcKind::Adaptive.apply(&base);
        assert_eq!((p, a), (base, true));
        let (p, a) = AdcKind::Lossy(7).apply(&base);
        assert_eq!(p.adc_bits, 7);
        assert!(!a);
        assert_eq!(AdcKind::Lossy(7).label(), "lossy:7");
        assert_eq!(AdcKind::Adaptive.label(), "adaptive");
    }

    #[test]
    fn adc_kind_parse_edge_cases() {
        // bare `lossy` is the documented 8-bit default
        assert_eq!(AdcKind::parse("lossy"), Ok(AdcKind::Lossy(8)));
        // `lossy:` with nothing / zero / oversized / overflowing bits
        assert!(AdcKind::parse("lossy:").is_err());
        assert!(AdcKind::parse("lossy:0").is_err());
        assert!(AdcKind::parse("lossy:00").is_err());
        assert!(AdcKind::parse("lossy:17").is_err());
        assert!(AdcKind::parse("lossy:4294967296").is_err());
        assert!(AdcKind::parse("lossy:8.0").is_err());
        assert!(AdcKind::parse("lossy:-3").is_err());
        // boundary resolutions are accepted
        assert_eq!(AdcKind::parse("lossy:1"), Ok(AdcKind::Lossy(1)));
        assert_eq!(AdcKind::parse("lossy:16"), Ok(AdcKind::Lossy(16)));
        // case and surrounding whitespace are ignored
        assert_eq!(AdcKind::parse("Exact"), Ok(AdcKind::Exact));
        assert_eq!(AdcKind::parse("LOSSLESS"), Ok(AdcKind::Exact));
        assert_eq!(AdcKind::parse("ADAPTIVE"), Ok(AdcKind::Adaptive));
        assert_eq!(AdcKind::parse("LoSsY:8"), Ok(AdcKind::Lossy(8)));
        assert_eq!(AdcKind::parse("  exact  "), Ok(AdcKind::Exact));
        // interior whitespace is not tolerated
        assert!(AdcKind::parse("lossy : 8").is_err());
        assert!(AdcKind::parse("").is_err());
    }

    #[test]
    fn adc_kind_round_trips_via_display() {
        for k in [
            AdcKind::Exact,
            AdcKind::Adaptive,
            AdcKind::Lossy(1),
            AdcKind::Lossy(8),
            AdcKind::Lossy(16),
        ] {
            assert_eq!(AdcKind::parse(&k.to_string()), Ok(k), "{k}");
            assert_eq!(k.label(), k.to_string());
        }
    }

    #[test]
    fn default_xbar_matches_paper() {
        let p = XbarParams::default();
        assert_eq!(p.slices(), 8);
        assert_eq!(p.iters(), 16);
        assert_eq!(p.weights_per_xbar(), 2048);
        assert_eq!(p.vmm_ns(), 1600.0);
        // 128 rows * 1-bit DAC * 2-bit cells -> 384 needs 9 bits
        assert_eq!(p.lossless_adc_bits(), 9);
    }

    #[test]
    fn newton_ima_is_16_xbars_256_neurons() {
        let p = XbarParams::default();
        let ima = ImaConfig::newton_default();
        assert_eq!(ima.xbars(&p), 16);
        assert_eq!(ima.adcs(&p), 16);
        // 128x256 MACs per 1.6us = 40.96 GOPS
        assert!((ima.peak_gops(&p) - 40.96).abs() < 1e-9);
    }

    #[test]
    fn isaac_ima_is_8_xbars() {
        let p = XbarParams::default();
        assert_eq!(ImaConfig::isaac_default().xbars(&p), 8);
    }

    #[test]
    fn fc_tile_shares_adcs() {
        let p = XbarParams::default();
        let fc = TileConfig::newton_fc();
        assert_eq!(fc.ima.adcs(&p), 4);
        assert_eq!(fc.ima.xbars(&p), 16);
    }

    #[test]
    fn incremental_ends_at_full_newton() {
        let steps = NewtonFeatures::incremental();
        assert_eq!(steps.len(), 7);
        assert_eq!(steps.last().unwrap().1, NewtonFeatures::all());
        assert_eq!(steps[0].1, NewtonFeatures::none());
    }

    #[test]
    fn newton_without_small_buffers_keeps_isaac_edram() {
        let f = NewtonFeatures {
            small_buffers: false,
            ..NewtonFeatures::all()
        };
        assert_eq!(ChipConfig::newton_with(f).conv_tile.edram_kb, 64.0);
        assert_eq!(ChipConfig::newton().conv_tile.edram_kb, 16.0);
    }
}
