//! Sharded multi-process serving: a coordinator that splits the
//! newton-mini stage pipeline across worker *processes* over the v3 wire
//! protocol, with worker lifecycle tracking, failure detection, and
//! automatic re-sharding onto survivors.
//!
//! The software shape mirrors the paper's hardware shape one level up:
//! where [`crate::coordinator::pipeline`] pipelines stages across threads
//! the way Newton pipelines layers across tiles on one chip, this module
//! pipelines stages across *processes* the way a multi-chip deployment
//! forwards activations over the inter-chip mesh. A
//! [`crate::mapping::ShardMap`] assigns the `n_conv + 1` stages to worker
//! shards (contiguous, classifier isolated under
//! [`StagePolicy::newton`]); its `segments()` are literally the
//! forwarding plan — one wire hop per occupied shard.
//!
//! Robustness model, in one paragraph: every worker programs the **full**
//! model at startup from the shared `(seed, adc)` config, so installs are
//! bit-identical across processes and "installing" a shard is flipping a
//! served-stage window — a re-shard after a failure is one small frame
//! per survivor, not a weight transfer. The coordinator tracks each
//! worker through a [`WorkerState`] lifecycle (Joining → Ready → Suspect
//! → Dead → Rejoining) fed by heartbeats (admin-plane scrapes, with a
//! stats round-trip fallback) and by retryable wire errors on the data
//! path. Any worker death triggers [`ClusterEngine::reshard`]: survivors
//! get a new generation's windows, the batch restarts from its input, and
//! because the forward is integer-exact and every install is
//! bit-identical, replies stay bit-exact across arbitrary kill schedules.
//! When the pool empties entirely the engine degrades to an in-process
//! [`GoldenServer`] fallback and latches `degraded` — visible through
//! [`Engine::degraded`], the admin exposition, and `cluster.*` counters.
//!
//! Chaos is first-class: inter-shard links are wrapped in
//! [`crate::faults::FaultyStream`] (rate 0 = passthrough), and
//! `bench-net --cluster` drives seeded [`crate::faults::ChaosPlan`]
//! schedules that kill/stall/restart worker processes mid-load while
//! asserting bit-exactness (`--expect-exact`).

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::thread;
use std::time::{Duration, Instant};

use crate::config::{AdcKind, XbarParams};
use crate::coordinator::batcher::Batch;
use crate::coordinator::golden::{GoldenServer, IMAGE_ELEMS};
use crate::coordinator::health::{rebaseline_marker, HealthReport};
use crate::coordinator::health::HealthState;
use crate::faults::FaultyStream;
use crate::mapping::{ShardMap, StagePolicy};
use crate::net::proto::{
    self, FwdReply, FwdRequest, Msg, ProtoError, ShardAck, ShardInstall, StatsSnapshot,
    WireError, WireStage,
};
use crate::net::{Backoff, Client, Engine, EngineBatch, NetError};
use crate::obs;
use crate::obs::CostLedger;
use crate::xbar::cnn::{ForwardScratch, MiniCnn, ProgrammedCnn, StageData, Tensor};
use crate::xbar::Matrix;

/// Largest batch the cluster path serves: the widest stage boundary is
/// `batch × 16×16×32` i64s after stage 0, and 63 × that leaves 64 KiB of
/// [`proto::MAX_PAYLOAD`] for the `Fwd`/`FwdOut` frame fields (a batch of
/// 64 would fill the cap exactly, with no room for the frame).
pub const MAX_CLUSTER_BATCH: usize = 63;

// ---------------------------------------------------------------------------
// Worker lifecycle
// ---------------------------------------------------------------------------

/// One worker's place in the coordinator's lifecycle state machine.
///
/// ```text
/// Joining ──install ack──▶ Ready ◀──heartbeat ok── Suspect
///    │                       │ missed ≥ suspect_after ▲
///    │                       └───────────────────────-┘
///    │ missed ≥ dead_after / wire failure
///    ▼                                   heartbeat ok
///  Dead ─────────────────────────────▶ Rejoining ──install ack──▶ Ready
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerState {
    /// Known but not yet serving a shard window (startup).
    Joining,
    /// Heartbeating and holding the current generation's window.
    Ready,
    /// Missed heartbeats; still in the map, one good beat heals it.
    Suspect,
    /// Declared failed: out of the map until it proves itself again.
    Dead,
    /// A dead worker answered a heartbeat; needs a fresh install before
    /// it can carry stages again.
    Rejoining,
}

impl WorkerState {
    /// Stable byte for stats/exposition.
    pub fn as_u8(self) -> u8 {
        match self {
            WorkerState::Joining => 0,
            WorkerState::Ready => 1,
            WorkerState::Suspect => 2,
            WorkerState::Dead => 3,
            WorkerState::Rejoining => 4,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            WorkerState::Joining => "joining",
            WorkerState::Ready => "ready",
            WorkerState::Suspect => "suspect",
            WorkerState::Dead => "dead",
            WorkerState::Rejoining => "rejoining",
        }
    }

    /// Projection onto the replica-health vocabulary the stats plane
    /// already speaks ([`HealthState`] bytes in `StatsSnapshot::health`).
    pub fn health(self) -> HealthState {
        match self {
            WorkerState::Ready => HealthState::Healthy,
            WorkerState::Suspect => HealthState::Suspect,
            WorkerState::Dead => HealthState::Quarantined,
            WorkerState::Joining | WorkerState::Rejoining => HealthState::Probation,
        }
    }
}

/// Thresholds for the missed-heartbeat failure detector.
#[derive(Clone, Copy, Debug)]
pub struct LifecyclePolicy {
    /// Consecutive missed beats before Ready demotes to Suspect.
    pub suspect_after: u32,
    /// Consecutive missed beats before any live state is declared Dead.
    pub dead_after: u32,
    /// Heartbeat probe interval. With the defaults a dead worker is
    /// detected within one second — the "deadline window" the bench's
    /// recovery-latency series is measured against.
    pub heartbeat_every: Duration,
}

impl Default for LifecyclePolicy {
    fn default() -> Self {
        LifecyclePolicy {
            suspect_after: 2,
            dead_after: 4,
            heartbeat_every: Duration::from_millis(250),
        }
    }
}

/// The pure lifecycle state machine — no sockets, no timers, so every
/// transition is unit-testable. The [`ClusterEngine`] feeds it heartbeat
/// outcomes and data-path failures and reads back the candidate/usable
/// sets its shard maps are built over.
#[derive(Debug)]
pub struct ClusterMonitor {
    policy: LifecyclePolicy,
    states: Vec<WorkerState>,
    missed: Vec<u32>,
    /// Transitions *into* Dead (analogous to health's `quarantines`).
    deaths: u64,
}

impl ClusterMonitor {
    pub fn new(n: usize, policy: LifecyclePolicy) -> Self {
        assert!(n > 0, "a cluster needs at least one worker");
        assert!(
            policy.suspect_after > 0 && policy.dead_after > policy.suspect_after,
            "lifecycle thresholds must order 0 < suspect_after < dead_after"
        );
        ClusterMonitor {
            policy,
            states: vec![WorkerState::Joining; n],
            missed: vec![0; n],
            deaths: 0,
        }
    }

    pub fn n(&self) -> usize {
        self.states.len()
    }

    pub fn state(&self, i: usize) -> WorkerState {
        self.states[i]
    }

    /// Per-worker [`HealthState`] projection bytes for the stats plane.
    pub fn health_bytes(&self) -> Vec<u8> {
        self.states.iter().map(|s| s.health().as_u8()).collect()
    }

    /// Transitions into Dead so far (monotone).
    pub fn deaths(&self) -> u64 {
        self.deaths
    }

    /// One heartbeat outcome. A good beat clears the missed counter and
    /// heals Suspect back to Ready (Joining/Rejoining promote only via
    /// [`Self::joined`] — liveness alone does not mean a window is
    /// installed). A missed beat walks Ready → Suspect at
    /// `suspect_after` and any live state → Dead at `dead_after`.
    /// Returns `true` exactly when this beat killed the worker.
    pub fn heartbeat(&mut self, i: usize, ok: bool) -> bool {
        if ok {
            self.missed[i] = 0;
            if self.states[i] == WorkerState::Suspect {
                self.states[i] = WorkerState::Ready;
            }
            return false;
        }
        if self.states[i] == WorkerState::Dead {
            return false;
        }
        self.missed[i] = self.missed[i].saturating_add(1);
        if self.missed[i] >= self.policy.dead_after {
            self.states[i] = WorkerState::Dead;
            self.deaths += 1;
            return true;
        }
        if self.missed[i] >= self.policy.suspect_after && self.states[i] == WorkerState::Ready {
            self.states[i] = WorkerState::Suspect;
        }
        false
    }

    /// A retryable wire error on the data path counts as one missed beat:
    /// the failure detector sees transport evidence without waiting for
    /// the next probe tick.
    pub fn wire_error(&mut self, i: usize) -> bool {
        self.heartbeat(i, false)
    }

    /// Declare a worker failed outright (exhausted data-path retries,
    /// refused an install). Counts a death only on the transition.
    pub fn fail(&mut self, i: usize) {
        if self.states[i] != WorkerState::Dead {
            self.states[i] = WorkerState::Dead;
            self.deaths += 1;
        }
        self.missed[i] = 0;
    }

    /// Install acked: the worker holds the current generation's window.
    pub fn joined(&mut self, i: usize) {
        self.states[i] = WorkerState::Ready;
        self.missed[i] = 0;
    }

    /// A dead worker answered a probe; it re-enters the candidate set but
    /// stays out of `usable()` until an install promotes it.
    pub fn rejoining(&mut self, i: usize) {
        if self.states[i] == WorkerState::Dead {
            self.states[i] = WorkerState::Rejoining;
            self.missed[i] = 0;
        }
    }

    /// Workers a new shard map may be built over: everyone not Dead
    /// (ascending — [`ShardMap::build_over`]'s contract).
    pub fn candidates(&self) -> Vec<usize> {
        (0..self.states.len())
            .filter(|&i| self.states[i] != WorkerState::Dead)
            .collect()
    }

    /// Workers currently trusted to serve: Ready or Suspect. Empty means
    /// the cluster is degraded to the in-process fallback.
    pub fn usable(&self) -> Vec<usize> {
        (0..self.states.len())
            .filter(|&i| {
                matches!(self.states[i], WorkerState::Ready | WorkerState::Suspect)
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Coordinator engine
// ---------------------------------------------------------------------------

/// Cluster serving configuration. Constructed through
/// [`ClusterConfig::new`], which enforces the cluster's correctness
/// envelope: a lossless ADC config (bit-exact replies are the failover
/// contract, so drifting configs are rejected up front, not discovered as
/// mysterious deviations mid-chaos) and a batch that fits the widest
/// stage boundary under [`proto::MAX_PAYLOAD`].
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub seed: u64,
    pub kind: AdcKind,
    pub batch: usize,
    pub policy: StagePolicy,
    pub lifecycle: LifecyclePolicy,
    /// Per-hop budget: one inter-shard forward must land (across link
    /// retries) within this window or the worker is declared failed.
    pub hop_deadline: Duration,
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// Seeded fault-injection rate on inter-shard links (0 = clean).
    pub link_fault_rate: f64,
    pub link_fault_seed: u64,
}

impl ClusterConfig {
    pub fn new(seed: u64, kind: AdcKind, batch: usize) -> Result<Self, String> {
        lossless_kind(&kind)?;
        if batch == 0 || batch > MAX_CLUSTER_BATCH {
            return Err(format!(
                "cluster batch must be in 1..={MAX_CLUSTER_BATCH} (stage boundaries must fit one frame), got {batch}"
            ));
        }
        Ok(ClusterConfig {
            seed,
            kind,
            batch,
            policy: StagePolicy::newton(),
            lifecycle: LifecyclePolicy::default(),
            hop_deadline: Duration::from_secs(2),
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(50),
            link_fault_rate: 0.0,
            link_fault_seed: 0,
        })
    }
}

/// Reject ADC configs whose forward is not bit-exact: the cluster's
/// failover contract ("killing any worker yields bit-exact replies")
/// only holds when every install computes the same integers.
pub fn lossless_kind(kind: &AdcKind) -> Result<(), String> {
    let (p, adaptive) = kind.apply(&XbarParams::default());
    if adaptive || p.adc_bits < p.lossless_adc_bits() {
        return Err(format!(
            "cluster serving requires a lossless ADC config (got {kind}): \
             failover re-runs batches and asserts bit-exact replies"
        ));
    }
    Ok(())
}

/// Why a forward attempt over one shard map failed.
enum FwdFail {
    /// This worker is gone (deadline exhausted / non-retryable error):
    /// fail it and re-shard.
    Worker(usize),
    /// A re-shard landed mid-batch; retry with the fresh map.
    Stale,
}

struct WorkerSlot {
    addr: String,
    /// Worker admin-plane address (heartbeat scrape target); falls back
    /// to a stats round-trip on the shard port when absent.
    admin: Option<String>,
    /// Persistent coordinator→worker link, re-dialed lazily after any
    /// failure. Always wrapped in [`FaultyStream`]; rate 0 is a
    /// passthrough.
    link: Mutex<Option<FaultyStream<TcpStream>>>,
}

/// The coordinator-side [`Engine`]: shards the stage pipeline across
/// worker processes and forwards activations shard to shard. Plugs into
/// the unmodified [`crate::net::NetServer`], so clients speak the same
/// protocol to a cluster as to a single process.
pub struct ClusterEngine {
    cfg: ClusterConfig,
    workers: Vec<WorkerSlot>,
    n_conv: usize,
    monitor: Mutex<ClusterMonitor>,
    /// Current `(generation, map)` — kept under one lock so readers never
    /// see a generation paired with another generation's map.
    map: Mutex<(u64, ShardMap)>,
    generation: AtomicU64,
    /// Serializes re-shards (heartbeat thread vs data-path failures).
    reshard_lock: Mutex<()>,
    /// In-process single-replica engine serving while the pool is empty.
    fallback: GoldenServer,
    degraded: AtomicBool,
    reshards: AtomicU64,
    hop_retries: AtomicU64,
    next_id: AtomicU64,
    stop: AtomicBool,
}

impl ClusterEngine {
    /// Connect to `endpoints` (`(shard_addr, admin_addr)` per worker) and
    /// install generation 1. Fails when no initial map can be installed
    /// on any subset of the pool.
    pub fn connect(
        cfg: ClusterConfig,
        endpoints: &[(String, Option<String>)],
    ) -> Result<Arc<ClusterEngine>, String> {
        if endpoints.is_empty() {
            return Err("cluster needs at least one worker endpoint".to_string());
        }
        let n_conv = crate::coordinator::newton_mini().conv_layers().count();
        let workers: Vec<WorkerSlot> = endpoints
            .iter()
            .map(|(addr, admin)| WorkerSlot {
                addr: addr.clone(),
                admin: admin.clone(),
                link: Mutex::new(None),
            })
            .collect();
        // Placeholder map; connect() always re-shards before returning.
        let seed_map = ShardMap::build_over(
            n_conv,
            &(0..workers.len()).collect::<Vec<_>>(),
            workers.len(),
            cfg.policy,
        )
        .or_else(|_| {
            ShardMap::build_over(
                n_conv,
                &(0..workers.len()).collect::<Vec<_>>(),
                workers.len(),
                StagePolicy::unconstrained(),
            )
        })?;
        let engine = Arc::new(ClusterEngine {
            fallback: GoldenServer::replicated(cfg.seed, cfg.kind, 1, cfg.batch),
            monitor: Mutex::new(ClusterMonitor::new(workers.len(), cfg.lifecycle)),
            map: Mutex::new((0, seed_map)),
            generation: AtomicU64::new(0),
            reshard_lock: Mutex::new(()),
            degraded: AtomicBool::new(false),
            reshards: AtomicU64::new(0),
            hop_retries: AtomicU64::new(0),
            next_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            n_conv,
            workers,
            cfg,
        });
        // The initial install doubles as the join handshake: workers that
        // ack flip Joining -> Ready, workers that don't start dying.
        engine.reshard()?;
        Ok(engine)
    }

    /// Completed re-shards (generation installs after the first success
    /// counts too — the bench's `cluster_failover_reshards` series).
    pub fn reshard_count(&self) -> u64 {
        // the initial install is generation 1, not a failover
        self.reshards.load(Ordering::Relaxed).saturating_sub(1)
    }

    /// Current shard-map generation.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Stop background heartbeats (the thread also exits when the last
    /// `Arc` drops).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
    }

    /// Best-effort `Shutdown` to every worker (drain for clean exits).
    pub fn shutdown_workers(&self) {
        for (i, slot) in self.workers.iter().enumerate() {
            let _ = self.send_recv(i, &Msg::Shutdown);
            *slot.link.lock().unwrap() = None;
        }
    }

    /// One framed round trip on worker `shard`'s persistent link,
    /// (re)dialing lazily. Any failure tears the link down so the next
    /// attempt starts on a fresh connection.
    fn send_recv(&self, shard: usize, msg: &Msg) -> Result<Msg, NetError> {
        let slot = &self.workers[shard];
        let mut link = slot.link.lock().unwrap();
        if link.is_none() {
            // connect_timeout, not connect: a blackholed worker must not
            // pin this link's mutex (and with it reshard installs and
            // shutdown) for the OS SYN timeout
            let addr = slot
                .addr
                .to_socket_addrs()
                .map_err(NetError::from)?
                .next()
                .ok_or_else(|| {
                    NetError::from(io::Error::new(
                        io::ErrorKind::AddrNotAvailable,
                        "worker address resolved to nothing",
                    ))
                })?;
            let stream = TcpStream::connect_timeout(&addr, self.cfg.hop_deadline)
                .map_err(NetError::from)?;
            stream.set_nodelay(true).map_err(NetError::from)?;
            let t = Some(self.cfg.hop_deadline);
            stream.set_read_timeout(t).map_err(NetError::from)?;
            stream.set_write_timeout(t).map_err(NetError::from)?;
            // per-link seed salt keeps fault schedules independent
            let salt = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(shard as u64 + 1);
            *link = Some(FaultyStream::new(
                stream,
                self.cfg.link_fault_seed ^ salt,
                self.cfg.link_fault_rate,
            ));
        }
        let r = (|| -> Result<Msg, NetError> {
            let s = link.as_mut().expect("link dialed above");
            proto::write_msg(s, msg)?;
            Ok(proto::read_msg(s)?)
        })();
        if r.is_err() {
            *link = None;
        }
        r
    }

    /// Rebuild the shard map over the monitor's candidate set and install
    /// it on every occupied shard, retrying on a shrinking pool until a
    /// whole generation acks or no candidates remain. Success clears the
    /// degraded latch and marks a rebaseline
    /// ([`rebaseline_marker`]) so the admin watchdog re-learns its drift
    /// baselines against the new pool shape.
    fn reshard(&self) -> Result<(), String> {
        let _g = self.reshard_lock.lock().unwrap();
        let _sp = obs::span("cluster.reshard", "cluster");
        loop {
            let candidates = self.monitor.lock().unwrap().candidates();
            if candidates.is_empty() {
                return Err("no live workers to re-shard onto".to_string());
            }
            let map = ShardMap::build_over(
                self.n_conv,
                &candidates,
                self.workers.len(),
                self.cfg.policy,
            )
            .or_else(|_| {
                // constrained placement impossible on the shrunken pool:
                // correctness beats isolation, fall back to unconstrained
                ShardMap::build_over(
                    self.n_conv,
                    &candidates,
                    self.workers.len(),
                    StagePolicy::unconstrained(),
                )
            })
            .map_err(|e| format!("shard map over survivors: {e}"))?;
            let gen = self.generation.fetch_add(1, Ordering::AcqRel) + 1;
            let mut lost = false;
            for (shard, lo, hi) in map.segments() {
                let install = Msg::ShardInstall(ShardInstall {
                    generation: gen,
                    shard: shard as u32,
                    stage_lo: lo as u32,
                    stage_hi: hi as u32,
                });
                match self.send_recv(shard, &install) {
                    Ok(Msg::ShardAck(ShardAck { generation, shard: s }))
                        if generation == gen && s == shard as u32 =>
                    {
                        self.monitor.lock().unwrap().joined(shard);
                    }
                    _ => {
                        self.monitor.lock().unwrap().fail(shard);
                        lost = true;
                        break;
                    }
                }
            }
            if lost {
                continue;
            }
            *self.map.lock().unwrap() = (gen, map);
            self.reshards.fetch_add(1, Ordering::Relaxed);
            obs::counter("cluster.reshards").inc();
            obs::event("cluster.reshard", "cluster", &[("generation", gen)]);
            // the pool changed shape: old drift baselines and the
            // degraded latch describe a cluster that no longer exists
            rebaseline_marker();
            self.degraded.store(false, Ordering::Release);
            return Ok(());
        }
    }

    /// One inter-shard hop under a per-hop deadline: send the stage range,
    /// retry retryable wire errors with [`Backoff`] on a fresh link, heal
    /// [`proto::ERR_STALE_SHARD`] by re-installing the window (same
    /// generation — the worker restarted), and give up as
    /// [`FwdFail::Worker`] when the deadline passes.
    fn hop(
        &self,
        shard: usize,
        gen: u64,
        lo: usize,
        hi: usize,
        data: &WireStage,
        trace: u64,
    ) -> Result<FwdReply, FwdFail> {
        let deadline = Instant::now() + self.cfg.hop_deadline;
        let mut backoff = Backoff::new(
            self.cfg.backoff_base,
            self.cfg.backoff_cap,
            self.cfg.link_fault_seed ^ ((shard as u64) << 8) ^ gen,
        );
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        loop {
            let req = Msg::Fwd(FwdRequest {
                id,
                trace,
                generation: gen,
                stage_lo: lo as u32,
                stage_hi: hi as u32,
                data: data.clone(),
            });
            match self.send_recv(shard, &req) {
                Ok(Msg::FwdOut(r)) if r.id == id && r.generation == gen => return Ok(r),
                Ok(Msg::Error(e)) if e.code == proto::ERR_STALE_SHARD => {
                    if self.generation.load(Ordering::Acquire) != gen {
                        // a re-shard moved the map under this batch
                        return Err(FwdFail::Stale);
                    }
                    // same generation: the worker lost its window (a
                    // restart wiped it) — re-install and retry the hop
                    let install = Msg::ShardInstall(ShardInstall {
                        generation: gen,
                        shard: shard as u32,
                        stage_lo: lo as u32,
                        stage_hi: hi as u32,
                    });
                    match self.send_recv(shard, &install) {
                        Ok(Msg::ShardAck(a)) if a.generation == gen => {
                            self.monitor.lock().unwrap().joined(shard);
                        }
                        _ => return Err(FwdFail::Worker(shard)),
                    }
                }
                Ok(_) => return Err(FwdFail::Worker(shard)),
                Err(e) if e.retryable() => {
                    self.hop_retries.fetch_add(1, Ordering::Relaxed);
                    obs::counter("cluster.hop_retries").inc();
                    self.monitor.lock().unwrap().wire_error(shard);
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(FwdFail::Worker(shard));
                    }
                    thread::sleep(backoff.next_delay().min(deadline - now));
                }
                Err(_) => return Err(FwdFail::Worker(shard)),
            }
            if Instant::now() >= deadline {
                return Err(FwdFail::Worker(shard));
            }
        }
    }

    /// Run one batch across a snapshot of the shard map: one hop per
    /// segment, activations forwarded shard to shard, hop ledgers merged
    /// (stages partition, so the merged ledger equals a single-process
    /// run's) and attributed per shard.
    fn forward_once(
        &self,
        gen: u64,
        map: &ShardMap,
        t: &Tensor,
        trace: u64,
    ) -> Result<(Matrix, CostLedger, f64), FwdFail> {
        let mut data = WireStage::Act {
            b: t.b as u32,
            h: t.h as u32,
            w: t.w as u32,
            c: t.c as u32,
            data: t.data.clone(),
        };
        let mut total = CostLedger::new();
        let mut energy_pj = 0.0;
        let segments = map.segments();
        let last_shard = segments.last().map(|s| s.0).unwrap_or(0);
        let mut hops: Vec<(usize, CostLedger)> = Vec::with_capacity(segments.len());
        for (shard, lo, hi) in segments {
            let _sp = obs::span("cluster.hop", "cluster");
            let r = self.hop(shard, gen, lo, hi, &data, trace)?;
            if !r.cost.is_empty() {
                hops.push((shard, r.cost));
            }
            total.merge(&r.cost);
            energy_pj += r.energy_pj;
            data = r.data;
        }
        // attribute per-shard cost only for the attempt that served: a
        // failed-over batch charges the map that answered, keeping the
        // merged total equal to a single-process run's ledger
        for (shard, cost) in &hops {
            obs::ledger::record_replica(*shard, cost);
        }
        match data {
            WireStage::Logits { rows, cols, data } => Ok((
                Matrix {
                    rows: rows as usize,
                    cols: cols as usize,
                    data,
                },
                total,
                energy_pj,
            )),
            // a map whose last segment is not the classifier cannot be
            // built; a worker answering activations here is misbehaving
            WireStage::Act { .. } => Err(FwdFail::Worker(last_shard)),
        }
    }

    /// Heartbeat probe for one worker: scrape its admin plane when known
    /// (cheap, read-only), else a stats round trip on the shard port over
    /// a transient connection.
    fn ping(&self, i: usize) -> bool {
        let slot = &self.workers[i];
        let t = Duration::from_millis(200);
        if let Some(admin) = &slot.admin {
            return matches!(
                crate::net::scrape_statz(admin.as_str(), t),
                Ok(body) if body.contains("newton_worker_up 1")
            );
        }
        let Some(addr) = slot
            .addr
            .to_socket_addrs()
            .ok()
            .and_then(|mut a| a.next())
        else {
            return false;
        };
        let Ok(stream) = TcpStream::connect_timeout(&addr, t) else {
            return false;
        };
        if stream.set_read_timeout(Some(t)).is_err() || stream.set_write_timeout(Some(t)).is_err()
        {
            return false;
        }
        Client::from_stream(stream).stats().is_ok()
    }

    /// One failure-detector sweep over the pool. Deaths trigger a
    /// re-shard onto survivors; a dead worker answering again is pulled
    /// back in (Rejoining, then a fresh install in the re-shard).
    fn heartbeat_tick(&self) {
        for i in 0..self.workers.len() {
            if self.stop.load(Ordering::Acquire) {
                return;
            }
            let ok = self.ping(i);
            let (died, revived) = {
                let mut m = self.monitor.lock().unwrap();
                if m.state(i) == WorkerState::Dead {
                    if ok {
                        m.rejoining(i);
                        (false, true)
                    } else {
                        (false, false)
                    }
                } else {
                    (m.heartbeat(i, ok), false)
                }
            };
            if died {
                *self.workers[i].link.lock().unwrap() = None;
                obs::counter("cluster.worker_deaths").inc();
                obs::event("cluster.worker_dead", "cluster", &[("worker", i as u64)]);
                let _ = self.reshard();
            } else if revived {
                obs::counter("cluster.worker_rejoins").inc();
                let _ = self.reshard();
            }
        }
    }

    /// Spawn the background failure detector. Holds only a [`Weak`]: the
    /// thread exits when the engine drops or [`Self::stop`] is called.
    pub fn spawn_heartbeats(self: &Arc<Self>) -> thread::JoinHandle<()> {
        let weak: Weak<ClusterEngine> = Arc::downgrade(self);
        thread::Builder::new()
            .name("cluster-heartbeat".to_string())
            .spawn(move || loop {
                let Some(engine) = weak.upgrade() else { return };
                if engine.stop.load(Ordering::Acquire) {
                    return;
                }
                let every = engine.cfg.lifecycle.heartbeat_every;
                engine.heartbeat_tick();
                drop(engine);
                thread::sleep(every);
            })
            .expect("spawn cluster-heartbeat thread")
    }
}

/// Rebuild the batcher's padded flat tensor (same layout as the golden
/// engine's private helper: batch-major, one `IMAGE_ELEMS` image per row).
fn tensor_from_flat(data: &[i32], batch: usize) -> Tensor {
    assert_eq!(data.len(), batch * IMAGE_ELEMS, "padded batch shape");
    let mut t = Tensor::zeros(batch, 32, 32, 3);
    for (i, &v) in data.iter().enumerate() {
        t.data[i] = v as i64;
    }
    t
}

impl Engine for ClusterEngine {
    fn image_elems(&self) -> usize {
        IMAGE_ELEMS
    }

    fn batch_capacity(&self) -> usize {
        self.cfg.batch
    }

    fn n_replicas(&self) -> usize {
        self.workers.len()
    }

    fn describe(&self) -> String {
        format!(
            "cluster engine: {} workers, adc {}, batch {}, gen {}",
            self.workers.len(),
            self.cfg.kind.label(),
            self.cfg.batch,
            self.generation()
        )
    }

    /// Serve one batch with failover: forward over a `(generation, map)`
    /// snapshot; a worker failure fails that worker, re-shards onto
    /// survivors, and restarts the batch from its input (integer-exact
    /// forward + bit-identical installs ⇒ the retry's logits are
    /// bit-identical to an undisturbed run). When the pool empties the
    /// batch lands on the in-process fallback and `degraded` latches.
    fn run(&self, index: usize, b: &Batch) -> EngineBatch {
        let _sp = obs::span("cluster.batch", "coordinator");
        let t = tensor_from_flat(&b.data, self.cfg.batch);
        let trace = b.traces.first().copied().unwrap_or(0);
        // Stale retries (a re-shard moved the map mid-batch) are benign
        // coordination noise, not evidence of failure, so they spend
        // their own generous budget rather than the worker-failure one —
        // a burst of re-shards on a healthy cluster must not push a
        // batch onto the fallback engine.
        const MAX_STALE_RETRIES: usize = 32;
        let mut worker_failures = 0usize;
        let mut stale_retries = 0usize;
        while worker_failures <= self.workers.len() + 1 && stale_retries <= MAX_STALE_RETRIES {
            let (gen, map) = self.map.lock().unwrap().clone();
            match self.forward_once(gen, &map, &t, trace) {
                Ok((m, cost, energy_pj)) => {
                    let logits: Vec<Vec<i32>> = (0..b.n_real)
                        .map(|r| {
                            m.data[r * m.cols..(r + 1) * m.cols]
                                .iter()
                                .map(|&v| v as i32)
                                .collect()
                        })
                        .collect();
                    if !cost.is_empty() {
                        obs::ledger::record_serving(&cost, b.n_real, energy_pj);
                    }
                    let classifier = map.segments().last().map(|s| s.0).unwrap_or(0);
                    return EngineBatch {
                        replica: classifier,
                        n_real: b.n_real,
                        logits,
                        // the config is validated lossless; deviations are
                        // impossible, not merely unobserved
                        max_abs_err: 0,
                        cost,
                        energy_pj,
                    };
                }
                Err(FwdFail::Stale) => {
                    // a re-shard landed mid-batch: the generation bumped
                    // before the new map committed, so wait for the map
                    // snapshot to move off our stale generation (bounded
                    // by one hop deadline) before retrying on it
                    stale_retries += 1;
                    let wait = Instant::now() + self.cfg.hop_deadline;
                    while self.map.lock().unwrap().0 == gen && Instant::now() < wait {
                        thread::sleep(Duration::from_millis(1));
                    }
                    continue;
                }
                Err(FwdFail::Worker(w)) => {
                    worker_failures += 1;
                    {
                        let mut m = self.monitor.lock().unwrap();
                        m.fail(w);
                    }
                    *self.workers[w].link.lock().unwrap() = None;
                    obs::counter("cluster.worker_deaths").inc();
                    obs::event("cluster.worker_dead", "cluster", &[("worker", w as u64)]);
                    if self.reshard().is_err() {
                        break; // pool is empty
                    }
                }
            }
        }
        // graceful degradation: the in-process single-replica engine
        // serves (bit-identically — same seed, same lossless config)
        // until a re-shard over rejoined workers clears the latch.
        self.degraded.store(true, Ordering::Release);
        obs::counter("cluster.fallback_batches").inc();
        let r = self.fallback.run_one(index, b);
        EngineBatch {
            replica: r.replica,
            n_real: r.n_real,
            logits: r.logits,
            max_abs_err: r.max_abs_err,
            cost: r.cost,
            energy_pj: r.energy_pj,
        }
    }

    fn health(&self) -> Option<HealthReport> {
        let m = self.monitor.lock().unwrap();
        let usable_empty = m.usable().is_empty();
        Some(HealthReport {
            states: m.health_bytes(),
            reruns: self.hop_retries.load(Ordering::Relaxed),
            quarantines: m.deaths(),
            degraded: usable_empty || self.degraded.load(Ordering::Acquire),
        })
    }

    fn degraded(&self) -> bool {
        self.degraded.load(Ordering::Acquire)
            || self.monitor.lock().unwrap().usable().is_empty()
    }
}

// ---------------------------------------------------------------------------
// Worker process
// ---------------------------------------------------------------------------

/// Worker-side configuration; `(seed, kind)` must match the coordinator's
/// so every process programs a bit-identical model.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub seed: u64,
    pub kind: AdcKind,
    /// Read-timeout tick on shard connections (drain poll granularity).
    pub read_tick: Duration,
    pub write_timeout: Duration,
}

impl WorkerConfig {
    pub fn new(seed: u64, kind: AdcKind) -> Result<Self, String> {
        lossless_kind(&kind)?;
        Ok(WorkerConfig {
            seed,
            kind,
            read_tick: Duration::from_millis(100),
            write_timeout: Duration::from_secs(5),
        })
    }
}

struct WorkerShared {
    cnn: ProgrammedCnn,
    tile: crate::energy::TileModel,
    /// The served-stage window: `(generation, shard, stage_lo, stage_hi)`.
    /// `None` until the first install — forwards answer
    /// [`proto::ERR_STALE_SHARD`] so the coordinator knows to install.
    window: Mutex<Option<(u64, u32, u32, u32)>>,
    draining: AtomicBool,
    fwds: AtomicU64,
    installs: AtomicU64,
    read_tick: Duration,
    write_timeout: Duration,
}

/// A shard-serving worker process body: programs the full model at
/// startup, then serves `ShardInstall`/`Fwd` on its shard port and a
/// read-only `newton_worker_*` exposition on an optional admin port
/// (the coordinator's heartbeat target).
pub struct ClusterWorker {
    addr: SocketAddr,
    admin_addr: Option<SocketAddr>,
    shared: Arc<WorkerShared>,
    accept: Option<thread::JoinHandle<()>>,
}

impl ClusterWorker {
    /// Bind `addr` (and `admin_addr` when given — pass port 0 for
    /// ephemeral) and start serving. The worker prices its own hops: the
    /// returned `FwdReply.energy_pj` runs the same tile energy model the
    /// single-process engine uses, so merged cluster totals stay
    /// comparable to `BENCH_energy` numbers.
    pub fn start(
        cfg: WorkerConfig,
        addr: &str,
        admin_addr: Option<&str>,
    ) -> io::Result<ClusterWorker> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let admin_listener = match admin_addr {
            Some(a) => {
                let l = TcpListener::bind(a)?;
                l.set_nonblocking(true)?;
                Some(l)
            }
            None => None,
        };
        let admin_local = admin_listener.as_ref().map(|l| l.local_addr()).transpose()?;
        let (p, adaptive) = cfg.kind.apply(&XbarParams::default());
        let shared = Arc::new(WorkerShared {
            cnn: MiniCnn::new(cfg.seed).program(&p, adaptive),
            tile: crate::energy::TileModel::new(
                crate::config::ChipConfig::newton().conv_tile,
                p,
            ),
            window: Mutex::new(None),
            draining: AtomicBool::new(false),
            fwds: AtomicU64::new(0),
            installs: AtomicU64::new(0),
            read_tick: cfg.read_tick,
            write_timeout: cfg.write_timeout,
        });
        if let Some(l) = admin_listener {
            let s = Arc::clone(&shared);
            thread::Builder::new()
                .name("worker-admin".to_string())
                .spawn(move || worker_admin_loop(l, s))
                .expect("spawn worker admin thread");
        }
        let accept = {
            let s = Arc::clone(&shared);
            thread::Builder::new()
                .name("worker-accept".to_string())
                .spawn(move || worker_accept_loop(listener, s))
                .expect("spawn worker accept thread")
        };
        Ok(ClusterWorker {
            addr: local,
            admin_addr: admin_local,
            shared,
            accept: Some(accept),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn admin_addr(&self) -> Option<SocketAddr> {
        self.admin_addr
    }

    /// Ask the worker to drain (idempotent; also triggered by a
    /// `Shutdown` frame on any shard connection).
    pub fn shutdown(&self) {
        self.shared.draining.store(true, Ordering::Release);
    }

    /// Wait for the accept loop to exit (it polls the drain flag).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn worker_accept_loop(listener: TcpListener, shared: Arc<WorkerShared>) {
    loop {
        if shared.draining.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let s = Arc::clone(&shared);
                // detached: handlers notice the drain flag via read ticks
                let _ = thread::Builder::new()
                    .name("worker-conn".to_string())
                    .spawn(move || worker_conn(s, stream));
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// `read_exact` tolerating read-timeout ticks; polls the drain flag at
/// frame boundaries. `Ok(false)` = clean stop (EOF / draining idle).
///
/// Idle between frames is unbounded (coordinator links legitimately sit
/// idle between batches), but a peer that stalls *mid-frame* — partial
/// header or payload, never completing, never closing — is cut off after
/// a bounded number of progress-free ticks whether draining or not, so a
/// wedged peer cannot leak a worker-conn thread forever.
fn worker_read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    shared: &WorkerShared,
    frame_start: bool,
) -> Result<bool, ProtoError> {
    // with the default 100 ms read tick: ~5 s mid-frame stall budget
    // normally, tightened to ~2.5 s while draining
    const STALL_TICKS: u32 = 50;
    const DRAIN_STALL_TICKS: u32 = 25;
    let mut off = 0;
    let mut idle_ticks = 0u32;
    while off < buf.len() {
        match stream.read(&mut buf[off..]) {
            Ok(0) => {
                if off == 0 && frame_start {
                    return Ok(false);
                }
                return Err(ProtoError::Malformed("connection closed mid-frame"));
            }
            Ok(n) => {
                off += n;
                idle_ticks = 0;
            }
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                idle_ticks += 1;
                let draining = shared.draining.load(Ordering::Acquire);
                let stall_limit = if draining { DRAIN_STALL_TICKS } else { STALL_TICKS };
                if off == 0 && frame_start {
                    if draining && idle_ticks > 2 {
                        return Ok(false);
                    }
                } else if idle_ticks > stall_limit {
                    return Err(ProtoError::Malformed("peer stalled mid-frame"));
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
    Ok(true)
}

fn worker_read_msg(
    stream: &mut TcpStream,
    shared: &WorkerShared,
) -> Result<Option<Msg>, ProtoError> {
    let mut h = [0u8; proto::HEADER_LEN];
    if !worker_read_full(stream, &mut h, shared, true)? {
        return Ok(None);
    }
    let (ty, len, sum) = proto::parse_header(&h)?;
    let mut payload = vec![0u8; len];
    if len > 0 && !worker_read_full(stream, &mut payload, shared, false)? {
        return Err(ProtoError::Malformed("connection closed mid-frame"));
    }
    let got = proto::checksum(&payload);
    if got != sum {
        return Err(ProtoError::Checksum { want: sum, got });
    }
    proto::decode_payload(ty, &payload).map(Some)
}

fn worker_conn(shared: Arc<WorkerShared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(shared.read_tick));
    let _ = stream.set_write_timeout(Some(shared.write_timeout));
    loop {
        let msg = match worker_read_msg(&mut stream, &shared) {
            Ok(Some(m)) => m,
            Ok(None) => return,
            Err(e) => {
                let _ = proto::write_msg(
                    &mut stream,
                    &Msg::Error(WireError {
                        code: proto::ERR_MALFORMED,
                        message: format!("protocol error: {e}"),
                    }),
                );
                return;
            }
        };
        if !worker_serve_msg(&shared, &mut stream, msg) {
            return;
        }
    }
}

/// Handle one decoded frame; returns `false` to close the connection.
/// [`proto::ERR_STALE_SHARD`] replies keep the connection **open** — a
/// stale window is a recoverable coordination state, not a protocol
/// violation.
fn worker_serve_msg(shared: &Arc<WorkerShared>, stream: &mut TcpStream, msg: Msg) -> bool {
    match msg {
        Msg::ShardInstall(inst) => {
            if shared.draining.load(Ordering::Acquire) {
                let _ = proto::write_msg(
                    stream,
                    &Msg::Error(WireError {
                        code: proto::ERR_DRAINING,
                        message: "worker is draining".to_string(),
                    }),
                );
                return false;
            }
            let n_stages = shared.cnn.n_stages() as u32;
            if inst.stage_lo >= inst.stage_hi || inst.stage_hi > n_stages {
                let _ = proto::write_msg(
                    stream,
                    &Msg::Error(WireError {
                        code: proto::ERR_BAD_SHAPE,
                        message: format!(
                            "stage window [{}, {}) outside 0..{n_stages}",
                            inst.stage_lo, inst.stage_hi
                        ),
                    }),
                );
                return false;
            }
            *shared.window.lock().unwrap() =
                Some((inst.generation, inst.shard, inst.stage_lo, inst.stage_hi));
            shared.installs.fetch_add(1, Ordering::Relaxed);
            obs::counter("worker.installs").inc();
            proto::write_msg(
                stream,
                &Msg::ShardAck(ShardAck {
                    generation: inst.generation,
                    shard: inst.shard,
                }),
            )
            .is_ok()
        }
        Msg::Fwd(req) => worker_serve_fwd(shared, stream, req),
        Msg::StatsReq => {
            // minimal snapshot so a coordinator without an admin address
            // can still heartbeat over the shard port
            let snap = StatsSnapshot {
                served: shared.fwds.load(Ordering::Relaxed),
                batches: shared.fwds.load(Ordering::Relaxed),
                ..StatsSnapshot::default()
            };
            proto::write_msg(stream, &Msg::Stats(snap)).is_ok()
        }
        Msg::Shutdown => {
            shared.draining.store(true, Ordering::Release);
            let _ = proto::write_msg(stream, &Msg::ShutdownAck);
            false
        }
        _ => {
            let _ = proto::write_msg(
                stream,
                &Msg::Error(WireError {
                    code: proto::ERR_MALFORMED,
                    message: "message type not served by a shard worker".to_string(),
                }),
            );
            false
        }
    }
}

fn worker_serve_fwd(shared: &Arc<WorkerShared>, stream: &mut TcpStream, req: FwdRequest) -> bool {
    if shared.draining.load(Ordering::Acquire) {
        // refuse new work while draining: ERR_DRAINING is non-retryable,
        // so the coordinator fails this worker and re-shards immediately
        // instead of spinning its hop deadline down on a dying process
        let _ = proto::write_msg(
            stream,
            &Msg::Error(WireError {
                code: proto::ERR_DRAINING,
                message: "worker is draining".to_string(),
            }),
        );
        return false;
    }
    let window = *shared.window.lock().unwrap();
    let stale = match window {
        Some((gen, _, lo, hi)) => {
            gen != req.generation || req.stage_lo < lo || req.stage_hi > hi
        }
        None => true,
    };
    if stale {
        obs::counter("worker.stale_fwds").inc();
        // recoverable: the coordinator re-installs on this connection
        return proto::write_msg(
            stream,
            &Msg::Error(WireError {
                code: proto::ERR_STALE_SHARD,
                message: format!(
                    "window {:?} does not cover generation {} stages [{}, {})",
                    window, req.generation, req.stage_lo, req.stage_hi
                ),
            }),
        )
        .is_ok();
    }
    let (b, h, w, c, data) = match req.data {
        WireStage::Act { b, h, w, c, data } => (b, h, w, c, data),
        WireStage::Logits { .. } => {
            let _ = proto::write_msg(
                stream,
                &Msg::Error(WireError {
                    code: proto::ERR_BAD_SHAPE,
                    message: "forward input must be an activation tensor".to_string(),
                }),
            );
            return false;
        }
    };
    let t = Tensor {
        b: b as usize,
        h: h as usize,
        w: w as usize,
        c: c as usize,
        data,
    };
    if t.data.len() != t.b * t.h * t.w * t.c {
        let _ = proto::write_msg(
            stream,
            &Msg::Error(WireError {
                code: proto::ERR_BAD_SHAPE,
                message: "tensor data does not match its dims".to_string(),
            }),
        );
        return false;
    }
    let _sp = obs::span("worker.fwd", "cluster");
    let mut scratch = ForwardScratch::new();
    let mut sd = StageData::Act(t);
    for s in req.stage_lo..req.stage_hi {
        sd = shared.cnn.run_stage(s as usize, &sd, &mut scratch);
    }
    let cost = scratch.take_ledger();
    let energy_pj = if cost.is_empty() {
        0.0
    } else {
        shared.tile.ledger_energy_pj(&cost)
    };
    let out = match sd {
        StageData::Act(t) => WireStage::Act {
            b: t.b as u32,
            h: t.h as u32,
            w: t.w as u32,
            c: t.c as u32,
            data: t.data,
        },
        StageData::Logits(m) => WireStage::Logits {
            rows: m.rows as u32,
            cols: m.cols as u32,
            data: m.data,
        },
    };
    shared.fwds.fetch_add(1, Ordering::Relaxed);
    obs::counter("worker.fwds").inc();
    proto::write_msg(
        stream,
        &Msg::FwdOut(FwdReply {
            id: req.id,
            trace: req.trace,
            generation: req.generation,
            cost,
            energy_pj,
            data: out,
        }),
    )
    .is_ok()
}

/// Worker admin plane: read-only `newton_worker_*` exposition, one
/// detached thread per scrape with read *and* write timeouts so a
/// stalled scraper can never pin the accept loop (the same discipline
/// the serving admin plane applies).
fn worker_admin_loop(listener: TcpListener, shared: Arc<WorkerShared>) {
    loop {
        if shared.draining.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                let s = Arc::clone(&shared);
                let _ = thread::Builder::new()
                    .name("worker-admin-conn".to_string())
                    .spawn(move || {
                        let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
                        let _ = stream.set_write_timeout(Some(s.write_timeout));
                        let _ = stream.write_all(worker_exposition(&s).as_bytes());
                    });
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(20));
            }
            Err(_) => thread::sleep(Duration::from_millis(20)),
        }
    }
}

/// Name-sorted `newton_worker_*` lines (the heartbeat probe greps
/// `newton_worker_up 1`).
fn worker_exposition(shared: &WorkerShared) -> String {
    let (generation, shard, lo, hi) = shared.window.lock().unwrap().unwrap_or((0, 0, 0, 0));
    format!(
        "newton_worker_fwds {}\nnewton_worker_generation {}\nnewton_worker_installs {}\nnewton_worker_shard {}\nnewton_worker_stage_hi {}\nnewton_worker_stage_lo {}\nnewton_worker_up 1\n",
        shared.fwds.load(Ordering::Relaxed),
        generation,
        shared.installs.load(Ordering::Relaxed),
        shard,
        hi,
        lo,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor(n: usize) -> ClusterMonitor {
        ClusterMonitor::new(n, LifecyclePolicy::default())
    }

    #[test]
    fn lifecycle_walks_joining_ready_suspect_dead() {
        let mut m = monitor(2);
        assert_eq!(m.state(0), WorkerState::Joining);
        m.joined(0);
        m.joined(1);
        assert_eq!(m.state(0), WorkerState::Ready);
        // default policy: suspect at 2 missed beats, dead at 4
        assert!(!m.heartbeat(0, false));
        assert_eq!(m.state(0), WorkerState::Ready);
        assert!(!m.heartbeat(0, false));
        assert_eq!(m.state(0), WorkerState::Suspect);
        assert!(!m.heartbeat(0, false));
        assert!(m.heartbeat(0, false), "4th miss kills");
        assert_eq!(m.state(0), WorkerState::Dead);
        assert_eq!(m.deaths(), 1);
        // dead workers do not die twice
        assert!(!m.heartbeat(0, false));
        assert_eq!(m.deaths(), 1);
        assert_eq!(m.candidates(), vec![1]);
        assert_eq!(m.usable(), vec![1]);
    }

    #[test]
    fn one_good_beat_heals_a_suspect() {
        let mut m = monitor(1);
        m.joined(0);
        m.heartbeat(0, false);
        m.heartbeat(0, false);
        assert_eq!(m.state(0), WorkerState::Suspect);
        assert!(m.usable().contains(&0), "suspects still serve");
        m.heartbeat(0, true);
        assert_eq!(m.state(0), WorkerState::Ready);
        // and the missed counter restarted from zero
        m.heartbeat(0, false);
        assert_eq!(m.state(0), WorkerState::Ready);
    }

    #[test]
    fn wire_errors_feed_the_failure_detector() {
        let mut m = monitor(1);
        m.joined(0);
        m.wire_error(0);
        m.wire_error(0);
        assert_eq!(m.state(0), WorkerState::Suspect);
        m.wire_error(0);
        assert!(m.wire_error(0));
        assert_eq!(m.state(0), WorkerState::Dead);
    }

    #[test]
    fn rejoin_cycle_needs_an_install_to_serve_again() {
        let mut m = monitor(2);
        m.joined(0);
        m.joined(1);
        m.fail(0);
        assert_eq!(m.state(0), WorkerState::Dead);
        assert_eq!(m.deaths(), 1);
        // a live probe pulls it back as a candidate, not as usable
        m.rejoining(0);
        assert_eq!(m.state(0), WorkerState::Rejoining);
        assert_eq!(m.candidates(), vec![0, 1]);
        assert_eq!(m.usable(), vec![1]);
        // the re-shard's install ack promotes it
        m.joined(0);
        assert_eq!(m.usable(), vec![0, 1]);
        // rejoining() on a live worker is a no-op
        m.rejoining(1);
        assert_eq!(m.state(1), WorkerState::Ready);
    }

    #[test]
    fn health_projection_speaks_the_stats_vocabulary() {
        assert_eq!(WorkerState::Ready.health(), HealthState::Healthy);
        assert_eq!(WorkerState::Suspect.health(), HealthState::Suspect);
        assert_eq!(WorkerState::Dead.health(), HealthState::Quarantined);
        assert_eq!(WorkerState::Joining.health(), HealthState::Probation);
        assert_eq!(WorkerState::Rejoining.health(), HealthState::Probation);
        let mut m = monitor(3);
        m.joined(0);
        m.fail(2);
        assert_eq!(
            m.health_bytes(),
            vec![
                HealthState::Healthy.as_u8(),
                HealthState::Probation.as_u8(),
                HealthState::Quarantined.as_u8()
            ]
        );
    }

    #[test]
    fn config_rejects_lossy_and_adaptive_adcs() {
        assert!(ClusterConfig::new(7, AdcKind::Exact, 8).is_ok());
        assert!(ClusterConfig::new(7, AdcKind::Adaptive, 8).is_err());
        assert!(ClusterConfig::new(7, AdcKind::Lossy(6), 8).is_err());
        // a "lossy" width at/above the lossless threshold is exact
        let wide = AdcKind::Lossy(16);
        let (p, _) = wide.apply(&XbarParams::default());
        if p.adc_bits >= p.lossless_adc_bits() {
            assert!(ClusterConfig::new(7, wide, 8).is_ok());
        }
    }

    #[test]
    fn config_bounds_the_batch_to_one_frame() {
        assert!(ClusterConfig::new(7, AdcKind::Exact, 0).is_err());
        assert!(ClusterConfig::new(7, AdcKind::Exact, MAX_CLUSTER_BATCH).is_ok());
        assert!(ClusterConfig::new(7, AdcKind::Exact, MAX_CLUSTER_BATCH + 1).is_err());
        // the bound actually protects the wire: widest boundary is
        // batch × 16×16×32 i64s after stage 0
        let widest = MAX_CLUSTER_BATCH * 16 * 16 * 32 * 8;
        assert!(widest + 64 < proto::MAX_PAYLOAD);
    }

    #[test]
    fn worker_state_bytes_are_stable() {
        for (s, b) in [
            (WorkerState::Joining, 0u8),
            (WorkerState::Ready, 1),
            (WorkerState::Suspect, 2),
            (WorkerState::Dead, 3),
            (WorkerState::Rejoining, 4),
        ] {
            assert_eq!(s.as_u8(), b);
            assert!(!s.label().is_empty());
        }
    }

    /// End-to-end over loopback: two in-process workers, a coordinator
    /// engine, bit-exact replies vs the single-process golden engine —
    /// then one worker drains away mid-session and the survivor serves
    /// the same bits after an automatic re-shard.
    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn loopback_cluster_serves_bit_exact_and_survives_a_worker_loss() {
        let seed = 11;
        let batch = 2;
        // hop ledgers ride the wire regardless, but counting only happens
        // while the process-global ledger is on
        let _ledger = crate::obs::ledger::test_guard();
        crate::obs::ledger::set_enabled(true);
        let wcfg = WorkerConfig::new(seed, AdcKind::Exact).unwrap();
        let w0 = ClusterWorker::start(wcfg.clone(), "127.0.0.1:0", None).unwrap();
        let w1 = ClusterWorker::start(wcfg, "127.0.0.1:0", None).unwrap();
        let endpoints = vec![
            (w0.local_addr().to_string(), None),
            (w1.local_addr().to_string(), None),
        ];
        let mut ccfg = ClusterConfig::new(seed, AdcKind::Exact, batch).unwrap();
        // keep the loss detectable quickly but the test deterministic:
        // no background heartbeats — the data path drives failover
        ccfg.hop_deadline = Duration::from_millis(500);
        let engine = ClusterEngine::connect(ccfg, &endpoints).unwrap();
        assert_eq!(engine.generation(), 1);
        assert!(!engine.degraded());

        let golden = GoldenServer::replicated(seed, AdcKind::Exact, 1, batch);
        let images: Vec<Vec<i32>> = (0..batch)
            .map(|i| crate::net::bench_image(seed, i as u64))
            .collect();
        let want = golden.infer(&images);

        let mk_batch = || {
            let mut data = vec![0i32; batch * IMAGE_ELEMS];
            for (i, img) in images.iter().enumerate() {
                data[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].copy_from_slice(img);
            }
            Batch {
                ids: (0..batch as u64).collect(),
                traces: vec![0; batch],
                data,
                n_real: batch,
                enqueued: vec![std::time::Instant::now(); batch],
            }
        };

        let r = engine.run(0, &mk_batch());
        assert_eq!(r.logits, want, "cluster must match the golden engine bit for bit");
        assert_eq!(r.max_abs_err, 0);
        assert!(!r.cost.is_empty(), "hop ledgers must survive the wire");

        // kill worker 0 (drain: its connections die, new dials are
        // refused once the accept loop exits) and serve again
        w0.shutdown();
        w0.join();
        let r2 = engine.run(1, &mk_batch());
        assert_eq!(r2.logits, want, "failover must reproduce the same bits");
        assert!(engine.reshard_count() >= 1, "the loss must have re-sharded");
        assert!(!engine.degraded(), "one survivor is a serving pool, not degraded");

        engine.stop();
        engine.shutdown_workers();
        w1.shutdown();
        w1.join();
        crate::obs::ledger::set_enabled(false);
    }
}

