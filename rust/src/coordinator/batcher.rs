//! Dynamic batcher: collects inference requests into fixed-shape batches.
//!
//! The crossbar pipeline (and the AOT-compiled stage artifacts) work on a
//! fixed batch shape, so the batcher pads short batches with zero images
//! and remembers how many rows are real. A batch closes when it is full or
//! when the oldest request has waited `max_wait` (vLLM-style deadline).

use std::time::{Duration, Instant};

/// A request queued for inference.
#[derive(Debug)]
pub struct PendingRequest {
    pub id: u64,
    /// Client-minted trace id riding the request through dispatch so batch
    /// spans correlate with client retries; 0 means untraced.
    pub trace: u64,
    pub image: Vec<i32>,
    pub enqueued: Instant,
}

/// A closed batch ready for the stage pipeline.
#[derive(Debug)]
pub struct Batch {
    pub ids: Vec<u64>,
    /// Per-request trace ids, parallel to `ids`.
    pub traces: Vec<u64>,
    /// Flattened batch-major data, padded to `capacity` images.
    pub data: Vec<i32>,
    /// Real images in the batch (the rest is padding).
    pub n_real: usize,
    pub enqueued: Vec<Instant>,
}

/// Fixed-shape batch assembler.
pub struct Batcher {
    capacity: usize,
    image_elems: usize,
    max_wait: Duration,
    pending: Vec<PendingRequest>,
}

impl Batcher {
    pub fn new(capacity: usize, image_elems: usize, max_wait: Duration) -> Self {
        assert!(capacity > 0 && image_elems > 0);
        Batcher {
            capacity,
            image_elems,
            max_wait,
            pending: Vec::new(),
        }
    }

    /// Queue a request. Panics if the image shape is wrong (callers validate
    /// at the API edge).
    pub fn push(&mut self, req: PendingRequest) {
        assert_eq!(req.image.len(), self.image_elems, "bad image shape");
        self.pending.push(req);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// True if a batch should close now (full, or deadline hit).
    pub fn ready(&self, now: Instant) -> bool {
        if self.pending.len() >= self.capacity {
            return true;
        }
        match self.pending.first() {
            Some(first) => now.duration_since(first.enqueued) >= self.max_wait,
            None => false,
        }
    }

    /// Close and return a batch (padded to capacity), or None if empty.
    pub fn take_batch(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        let n = self.pending.len().min(self.capacity);
        let taken: Vec<PendingRequest> = self.pending.drain(..n).collect();
        let mut data = Vec::with_capacity(self.capacity * self.image_elems);
        let mut ids = Vec::with_capacity(n);
        let mut traces = Vec::with_capacity(n);
        let mut enqueued = Vec::with_capacity(n);
        for r in &taken {
            ids.push(r.id);
            traces.push(r.trace);
            enqueued.push(r.enqueued);
            data.extend_from_slice(&r.image);
        }
        data.resize(self.capacity * self.image_elems, 0);
        Some(Batch {
            ids,
            traces,
            data,
            n_real: n,
            enqueued,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, elems: usize) -> PendingRequest {
        PendingRequest {
            id,
            trace: id.wrapping_mul(1000),
            image: vec![id as i32; elems],
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn fills_to_capacity() {
        let mut b = Batcher::new(4, 2, Duration::from_secs(60));
        for i in 0..5 {
            b.push(req(i, 2));
        }
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch().unwrap();
        assert_eq!(batch.n_real, 4);
        assert_eq!(batch.ids, vec![0, 1, 2, 3]);
        assert_eq!(batch.traces, vec![0, 1000, 2000, 3000]);
        assert_eq!(batch.data.len(), 8);
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn pads_short_batches() {
        let mut b = Batcher::new(4, 3, Duration::from_millis(0));
        b.push(req(7, 3));
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch().unwrap();
        assert_eq!(batch.n_real, 1);
        assert_eq!(batch.data.len(), 12);
        assert_eq!(&batch.data[..3], &[7, 7, 7]);
        assert!(batch.data[3..].iter().all(|&v| v == 0));
    }

    #[test]
    fn deadline_closes_partial_batch() {
        let mut b = Batcher::new(8, 1, Duration::from_millis(5));
        b.push(req(1, 1));
        assert!(!b.ready(Instant::now()));
        std::thread::sleep(Duration::from_millis(10));
        assert!(b.ready(Instant::now()));
    }

    #[test]
    fn empty_batcher_not_ready() {
        let b = Batcher::new(8, 1, Duration::from_millis(0));
        assert!(!b.ready(Instant::now()));
        let mut b = b;
        assert!(b.take_batch().is_none());
    }

    #[test]
    fn empty_flush_yields_nothing_even_past_deadline() {
        // zero deadline + nothing pending: an idle flush loop must neither
        // report ready nor fabricate a batch, including after a drain
        let mut b = Batcher::new(4, 2, Duration::from_millis(0));
        assert!(!b.ready(Instant::now() + Duration::from_secs(1)));
        assert!(b.take_batch().is_none());
        b.push(req(1, 2));
        assert!(b.take_batch().is_some());
        assert!(!b.ready(Instant::now()));
        assert!(b.take_batch().is_none());
    }

    #[test]
    fn exact_capacity_closes_without_waiting_for_deadline() {
        // exactly `capacity` requests close immediately under an hour-long
        // deadline, drain completely, and leave the batcher not-ready
        let mut b = Batcher::new(4, 1, Duration::from_secs(3600));
        for i in 0..3 {
            b.push(req(i, 1));
            assert!(!b.ready(Instant::now()), "ready below capacity");
        }
        b.push(req(3, 1));
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch().unwrap();
        assert_eq!(batch.n_real, 4);
        assert_eq!(batch.ids, vec![0, 1, 2, 3]);
        assert_eq!(b.pending_len(), 0);
        assert!(!b.ready(Instant::now()));
        assert!(b.take_batch().is_none());
    }

    #[test]
    fn drain_flushes_pending_batches_in_fifo_order_with_partial_tail_last() {
        // the drain-on-shutdown contract the dispatcher (and the pipelined
        // serving path behind it) relies on: repeated take_batch calls — a
        // drain is exactly that loop — return full batches in submission
        // order and the partial tail last, never reordering ids across the
        // drain boundary
        let mut b = Batcher::new(4, 2, Duration::from_secs(3600));
        for i in 0..10 {
            b.push(req(i, 2));
        }
        let mut drained: Vec<Vec<u64>> = Vec::new();
        while let Some(batch) = b.take_batch() {
            drained.push(batch.ids.clone());
            // padding appears only in the final (partial) flush
            if batch.n_real < 4 {
                assert!(b.take_batch().is_none(), "partial batch was not the tail");
                assert!(batch.data[batch.n_real * 2..].iter().all(|&v| v == 0));
            }
        }
        assert_eq!(drained, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9]]);
        let flat: Vec<u64> = drained.into_iter().flatten().collect();
        assert_eq!(flat, (0..10).collect::<Vec<_>>(), "drain reordered requests");
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    #[should_panic(expected = "bad image shape")]
    fn rejects_wrong_shape() {
        let mut b = Batcher::new(2, 4, Duration::from_secs(1));
        b.push(req(1, 4 + 1));
    }
}
