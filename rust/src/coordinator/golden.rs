//! Golden-model serving path: batched newton-mini inference through the
//! install-once crossbar engine, used (a) as the coordinator's fallback
//! when the PJRT artifacts are absent — the serve example stays usable in
//! a fresh checkout — and (b) as the golden-model verification path: the
//! same batch re-executed through the legacy per-call engine must match
//! bit-for-bit, which pins the install/run refactor at model scale on the
//! real serving geometry.
//!
//! Multi-replica mode (`GoldenServer::replicated`): N copies of the model
//! installed once each — the software analogue of provisioning N crossbar
//! chip instances — fed fixed-shape batches from the [`Batcher`] through
//! the work-stealing executor ([`crate::sched`]), one job per batch with
//! round-robin replica affinity. Adaptive/lossy ADC configs ([`AdcKind`])
//! are served next to a lossless golden install, and every batch reports
//! its max-abs-error against that golden reference — fidelity-vs-cost
//! sweeps (arXiv:2109.01262 / 2403.13082) against served traffic.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::{AdcKind, XbarParams};
use crate::coordinator::batcher::{Batch, Batcher, PendingRequest};
use crate::sched::Executor;
use crate::xbar::cnn::{ForwardScratch, MiniCnn, ProgrammedCnn, Tensor};

/// Elements in one newton-mini input image — the request shape every
/// serving surface (CLI, example, network endpoint) validates against.
pub const IMAGE_ELEMS: usize = 32 * 32 * 3;

/// Batched golden-model inference over installed crossbar weights.
pub struct GoldenServer {
    cnn: MiniCnn,
    /// Installed serving replicas (>= 1), all with the serving ADC config.
    replicas: Vec<ProgrammedCnn>,
    /// Lossless reference install, present whenever the serving config can
    /// deviate from it (adaptive or lossy ADC).
    golden: Option<ProgrammedCnn>,
    kind: AdcKind,
    p: XbarParams,
    adaptive: bool,
    batch: usize,
    /// Forward scratch reused across sequentially served batches (the
    /// net dispatcher and single-worker serving paths). `try_lock` only:
    /// concurrent batch jobs fall back to a fresh scratch instead of
    /// serialising on the lock.
    scratch: Mutex<ForwardScratch>,
}

/// One served batch from [`GoldenServer::serve_batches`].
#[derive(Clone, Debug)]
pub struct BatchReport {
    /// Batch index in submission order (reports come back in this order).
    pub index: usize,
    /// Replica that executed the batch (round-robin affinity).
    pub replica: usize,
    /// Request ids of the real rows.
    pub ids: Vec<u64>,
    /// Real images in the batch (the rest was padding).
    pub n_real: usize,
    /// Per-request logits, real rows only.
    pub logits: Vec<Vec<i32>>,
    /// Max |served - golden| over the real logits of this batch; 0 when
    /// the serving config is itself lossless.
    pub max_abs_err: i64,
}

/// Aggregate a serve run's per-batch reports into
/// `(requests_served, worst_deviation)` — the summary `newton serve
/// --adc` prints and tests assert against.
pub fn serve_totals(reports: &[BatchReport]) -> (usize, i64) {
    (
        reports.iter().map(|r| r.n_real).sum(),
        reports.iter().map(|r| r.max_abs_err).max().unwrap_or(0),
    )
}

/// Flat `32*32*3` i32 images -> a (B,32,32,3) activation tensor, zero-padded
/// to `batch` rows.
fn tensor_from(images: &[Vec<i32>], batch: usize) -> Tensor {
    let mut t = Tensor::zeros(batch, 32, 32, 3);
    for (i, img) in images.iter().enumerate() {
        assert_eq!(img.len(), IMAGE_ELEMS, "image {i}: want {IMAGE_ELEMS} elements");
        for (j, &v) in img.iter().enumerate() {
            t.data[i * IMAGE_ELEMS + j] = v as i64;
        }
    }
    t
}

/// A batcher-padded flat batch -> a (batch,32,32,3) tensor.
fn tensor_from_flat(data: &[i32], batch: usize) -> Tensor {
    assert_eq!(data.len(), batch * IMAGE_ELEMS, "bad batch shape");
    let mut t = Tensor::zeros(batch, 32, 32, 3);
    for (d, &v) in t.data.iter_mut().zip(data) {
        *d = v as i64;
    }
    t
}

impl GoldenServer {
    /// `kind`: the caller's constructed [`AdcKind`] when there is one
    /// (`replicated`), else derived from the raw `(p, adaptive)` pair.
    fn build(
        seed: u64,
        p: XbarParams,
        adaptive: bool,
        n_replicas: usize,
        batch: usize,
        kind: Option<AdcKind>,
    ) -> Self {
        assert!(batch > 0);
        assert!(n_replicas > 0);
        let cnn = MiniCnn::new(seed);
        let replicas: Vec<ProgrammedCnn> =
            (0..n_replicas).map(|_| cnn.program(&p, adaptive)).collect();
        // the golden install is numerics-driven: present iff the serving
        // config can actually deviate (e.g. Lossy(10) at a 9-bit lossless
        // budget is exact and needs no reference, whatever its label)
        let lossless = !adaptive && p.adc_bits >= p.lossless_adc_bits();
        let golden = (!lossless).then(|| {
            cnn.program(
                &XbarParams {
                    adc_bits: p.lossless_adc_bits(),
                    ..p
                },
                false,
            )
        });
        let kind = kind.unwrap_or(if adaptive {
            AdcKind::Adaptive
        } else if lossless {
            AdcKind::Exact
        } else {
            AdcKind::Lossy(p.adc_bits)
        });
        GoldenServer {
            cnn,
            replicas,
            golden,
            kind,
            p,
            adaptive,
            batch,
            scratch: Mutex::new(ForwardScratch::new()),
        }
    }

    /// Install the newton-mini weights once for the given pipeline config.
    pub fn new(seed: u64, p: &XbarParams, adaptive: bool, batch: usize) -> Self {
        Self::build(seed, *p, adaptive, 1, batch, None)
    }

    /// Multi-replica serving: `n_replicas` installs of the `kind` serving
    /// config (plus a lossless golden install when `kind` can deviate).
    pub fn replicated(seed: u64, kind: AdcKind, n_replicas: usize, batch: usize) -> Self {
        let (p, adaptive) = kind.apply(&XbarParams::default());
        Self::build(seed, p, adaptive, n_replicas, batch, Some(kind))
    }

    /// The standard fallback configuration shared by `newton serve` and the
    /// serve example: seed-0 newton-mini weights, exact pipeline, batch 8.
    pub fn newton_mini_default() -> Self {
        Self::new(0, &XbarParams::default(), false, 8)
    }

    /// Batch capacity per forward pass.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Installed serving replicas.
    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// The serving ADC mode.
    pub fn adc_kind(&self) -> AdcKind {
        self.kind
    }

    /// True when a lossless golden install rides along for per-batch
    /// deviation reporting.
    pub fn has_golden_reference(&self) -> bool {
        self.golden.is_some()
    }

    /// Verification of the head batch (or every image if fewer): true when
    /// the installed-crossbar forward matches the per-call engine, or when
    /// there is nothing to check.
    pub fn verify_head(&self, images: &[Vec<i32>]) -> bool {
        let head = &images[..self.batch.min(images.len())];
        head.is_empty() || self.verify_batch(head)
    }

    /// Serve a request list: chunks into batches (padding the tail), runs
    /// each through the installed weights, returns per-request logits.
    pub fn infer(&self, images: &[Vec<i32>]) -> Vec<Vec<i32>> {
        let mut out = Vec::with_capacity(images.len());
        for chunk in images.chunks(self.batch) {
            let t = tensor_from(chunk, self.batch);
            let logits = self.replicas[0].forward(&t);
            for i in 0..chunk.len() {
                out.push((0..logits.cols).map(|c| logits.at(i, c) as i32).collect());
            }
        }
        out
    }

    /// Multi-replica serving path: requests flow through the [`Batcher`]
    /// into fixed-shape batches, each batch is one work-stealing job with
    /// round-robin replica affinity, and every batch's real logits are
    /// compared against the lossless golden install. Reports come back in
    /// submission order regardless of worker count. The pool is sized by
    /// the total image count, so spare capacity beyond the batch-level
    /// fan-out flows into per-image splits inside each batch.
    pub fn serve_batches(&self, images: &[Vec<i32>]) -> Vec<BatchReport> {
        self.serve_batches_on(images, &Executor::for_jobs(images.len()))
    }

    /// [`Self::serve_batches`] on a caller-sized executor, which bounds
    /// the total sched-level fan-out: batch jobs run on it, and the pool's
    /// capacity is divided across in-flight batches for the per-image
    /// split inside each one (the per-VMM fan-out stays sequential inside
    /// pool workers — see `sched::in_worker` — so compute threads stay
    /// ~`exec.workers()` rather than multiplying per layer). With a
    /// 1-worker executor everything runs sequentially on the caller
    /// thread, like [`Self::infer`].
    pub fn serve_batches_on(&self, images: &[Vec<i32>], exec: &Executor) -> Vec<BatchReport> {
        let mut batcher = Batcher::new(self.batch, IMAGE_ELEMS, Duration::from_millis(0));
        for (i, img) in images.iter().enumerate() {
            batcher.push(PendingRequest {
                id: i as u64,
                image: img.clone(),
                enqueued: Instant::now(),
            });
        }
        let mut batches: Vec<Batch> = Vec::new();
        while let Some(b) = batcher.take_batch() {
            batches.push(b);
        }
        // divide the pool: in-flight batch jobs × per-image workers ≈ pool
        // (ceil so an uneven batch count never idles cores)
        let in_flight = exec.workers().min(batches.len()).max(1);
        let image_workers = exec.workers().div_ceil(in_flight);
        exec.map(batches.len(), |bi| self.run_batch(bi, &batches[bi], image_workers))
    }

    /// Run one batcher-shaped (padded) batch through replica
    /// `index % n_replicas` — the network serving entry point
    /// ([`crate::net::Engine`]). The per-image split inside the batch gets
    /// the whole pool: the network dispatcher executes batches one at a
    /// time, unlike [`Self::serve_batches_on`] which divides the pool
    /// across in-flight batches.
    pub fn run_one(&self, index: usize, b: &Batch) -> BatchReport {
        self.run_batch(index, b, crate::util::worker_count(self.batch))
    }

    fn run_batch(&self, index: usize, b: &Batch, image_workers: usize) -> BatchReport {
        let replica = index % self.replicas.len();
        let t = tensor_from_flat(&b.data, self.batch);
        let (served, want) = if image_workers <= 1 || self.batch <= 1 {
            // sequential forward: reuse the server-owned scratch across
            // served batches (im2col patches + raw accumulators survive
            // between batches). try_lock so concurrent sequential batch
            // jobs degrade to a fresh scratch, never to lock convoy.
            let mut owned: Option<ForwardScratch> = None;
            let mut guard = self.scratch.try_lock();
            let scratch = match guard {
                Ok(ref mut g) => &mut **g,
                Err(_) => owned.get_or_insert_with(ForwardScratch::new),
            };
            let served = self.replicas[replica].forward_seq_with(&t, scratch);
            let want = self
                .golden
                .as_ref()
                .map(|g| g.forward_seq_with(&t, scratch));
            (served, want)
        } else {
            let image_exec = Executor::new(image_workers);
            let served = self.replicas[replica].forward_on(&t, &image_exec);
            let want = self.golden.as_ref().map(|g| g.forward_on(&t, &image_exec));
            (served, want)
        };
        let max_abs_err = match &want {
            Some(want) => {
                let mut worst = 0i64;
                for r in 0..b.n_real {
                    for c in 0..served.cols {
                        worst = worst.max((served.at(r, c) - want.at(r, c)).abs());
                    }
                }
                worst
            }
            None => 0,
        };
        let logits = (0..b.n_real)
            .map(|r| (0..served.cols).map(|c| served.at(r, c) as i32).collect())
            .collect();
        BatchReport {
            index,
            replica,
            ids: b.ids.clone(),
            n_real: b.n_real,
            logits,
            max_abs_err,
        }
    }

    /// Verification path: the installed-crossbar forward must equal the
    /// legacy per-call engine bit-for-bit on this batch.
    pub fn verify_batch(&self, images: &[Vec<i32>]) -> bool {
        let t = tensor_from(images, images.len().max(1));
        let installed = self.replicas[0].forward(&t);
        let legacy = self.cnn.forward(&t, &self.p, self.adaptive);
        installed.data == legacy.data
    }
}

/// The golden crossbar engine is the network endpoint's default backend:
/// batches arrive from the server's `Batcher`, run on round-robin replicas
/// through the work-stealing executor, and report deviation vs the
/// lossless golden install. PJRT (or any heterogeneous replica pool) can
/// implement the same trait later without touching the wire layer.
impl crate::net::Engine for GoldenServer {
    fn image_elems(&self) -> usize {
        IMAGE_ELEMS
    }

    fn batch_capacity(&self) -> usize {
        self.batch
    }

    fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    fn describe(&self) -> String {
        format!(
            "golden newton-mini · adc {} · {} replica(s){} · batch {}",
            self.kind.label(),
            self.replicas.len(),
            if self.golden.is_some() { " + lossless golden" } else { "" },
            self.batch
        )
    }

    fn run(&self, index: usize, batch: &Batch) -> crate::net::EngineBatch {
        let r = self.run_one(index, batch);
        crate::net::EngineBatch {
            replica: r.replica,
            n_real: r.n_real,
            logits: r.logits,
            max_abs_err: r.max_abs_err,
        }
    }
}

#[cfg(test)]
mod engine_trait_tests {
    use crate::net::Engine;

    #[test]
    fn golden_server_exposes_its_geometry_through_the_engine_trait() {
        let s = super::GoldenServer::newton_mini_default();
        let e: &dyn Engine = &s;
        assert_eq!(e.image_elems(), super::IMAGE_ELEMS);
        assert_eq!(e.batch_capacity(), 8);
        assert_eq!(e.n_replicas(), 1);
        assert!(e.describe().contains("exact"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn images(n: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..32 * 32 * 3).map(|_| rng.below(256) as i32).collect())
            .collect()
    }

    #[test]
    fn construction_installs_weights() {
        let s = GoldenServer::newton_mini_default();
        assert_eq!(s.batch(), 8);
        assert_eq!(s.n_replicas(), 1);
        assert_eq!(s.adc_kind(), AdcKind::Exact);
        assert!(!s.has_golden_reference()); // exact config is its own golden
        assert!(s.verify_head(&[])); // nothing to check is vacuously true
    }

    #[test]
    fn replicated_kinds_carry_a_golden_reference() {
        let s = GoldenServer::replicated(0, AdcKind::Adaptive, 2, 2);
        assert_eq!(s.n_replicas(), 2);
        assert_eq!(s.adc_kind(), AdcKind::Adaptive);
        assert!(s.has_golden_reference());
        let s = GoldenServer::replicated(0, AdcKind::Lossy(8), 3, 2);
        assert_eq!(s.adc_kind(), AdcKind::Lossy(8));
        assert!(s.has_golden_reference());
        // a lossy resolution at/above the lossless budget keeps its label
        // but is exact numerically: no golden reference needed
        let s = GoldenServer::replicated(0, AdcKind::Lossy(10), 1, 2);
        assert_eq!(s.adc_kind(), AdcKind::Lossy(10));
        assert!(!s.has_golden_reference());
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn serves_and_verifies_against_legacy_engine() {
        let s = GoldenServer::new(0, &XbarParams::default(), false, 2);
        let imgs = images(3, 4); // 1.5 batches: exercises tail padding
        let logits = s.infer(&imgs);
        assert_eq!(logits.len(), 3);
        assert!(logits.iter().all(|l| l.len() == 10));
        assert!(s.verify_batch(&imgs[..2]));
        // a lone image padded into a full batch must match its solo run
        let solo = s.infer(&imgs[2..3]);
        assert_eq!(solo[0], logits[2]);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn multi_replica_serving_matches_single_replica_infer() {
        // replica fan-out must not change the numbers: serve_batches on an
        // exact config returns the same logits as the sequential infer path
        let s = GoldenServer::replicated(0, AdcKind::Exact, 3, 2);
        let imgs = images(5, 9); // 2.5 batches across 3 replicas
        let want = s.infer(&imgs);
        let reports = s.serve_batches(&imgs);
        assert_eq!(reports.len(), 3);
        let mut got: Vec<Vec<i32>> = Vec::new();
        for (bi, r) in reports.iter().enumerate() {
            assert_eq!(r.index, bi);
            assert_eq!(r.replica, bi % 3);
            assert_eq!(r.max_abs_err, 0, "exact serving deviated from itself");
            got.extend(r.logits.iter().cloned());
        }
        assert_eq!(got, want);
        let ids: Vec<u64> = reports.iter().flat_map(|r| r.ids.clone()).collect();
        assert_eq!(ids, (0..5u64).collect::<Vec<_>>());
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn adaptive_serving_reports_exact_deviation() {
        // per-batch max-abs-error must equal an independently computed
        // served-vs-lossless comparison, bit for bit
        let s = GoldenServer::replicated(0, AdcKind::Adaptive, 2, 2);
        let imgs = images(4, 12); // 2 full batches, no padding
        let reports = s.serve_batches(&imgs);
        assert_eq!(reports.len(), 2);
        let cnn = MiniCnn::new(0);
        let p = XbarParams::default();
        let served_prog = cnn.program(&p, true);
        let golden_prog = cnn.program(&p, false);
        for (bi, r) in reports.iter().enumerate() {
            let t = tensor_from(&imgs[bi * 2..bi * 2 + 2], 2);
            let a = served_prog.forward(&t);
            let g = golden_prog.forward(&t);
            let want = a
                .data
                .iter()
                .zip(g.data.iter())
                .map(|(x, y)| (x - y).abs())
                .max()
                .unwrap();
            assert_eq!(r.max_abs_err, want, "batch {bi}");
        }
    }
}
