//! Golden-model serving path: batched newton-mini inference through the
//! install-once crossbar engine, used (a) as the coordinator's fallback
//! when the PJRT artifacts are absent — the serve example stays usable in
//! a fresh checkout — and (b) as the golden-model verification path: the
//! same batch re-executed through the legacy per-call engine must match
//! bit-for-bit, which pins the install/run refactor at model scale on the
//! real serving geometry.
//!
//! Multi-replica mode (`GoldenServer::replicated`): N copies of the model
//! installed once each — the software analogue of provisioning N crossbar
//! chip instances — fed fixed-shape batches from the [`Batcher`] through
//! the work-stealing executor ([`crate::sched`]), one job per batch with
//! round-robin replica affinity. Adaptive/lossy ADC configs ([`AdcKind`])
//! are served next to a lossless golden install, and every batch reports
//! its max-abs-error against that golden reference — fidelity-vs-cost
//! sweeps (arXiv:2109.01262 / 2403.13082) against served traffic.
//!
//! [`GoldenServer::with_pipeline`] switches the same pool to *pipelined
//! stage scheduling* ([`crate::coordinator::pipeline`]): instead of whole
//! batches pinned to single replicas, each batch's images flow through the
//! per-stage units wavefront-style across the pool, with stage placement
//! governed by a [`StageMap`] — bit-identical either way.
//!
//! [`GoldenServer::with_health`] arms the replica health machinery
//! ([`crate::coordinator::health`]): every batch's deviation feeds the
//! per-replica state machine, bad batches are transparently re-run on a
//! healthy replica, quarantined replicas leave the rotation (the pipelined
//! stage map re-derives around them), and
//! [`GoldenServer::reinstall`] reprograms a replica from pristine weights
//! back to probation. Replicas live behind [`RwLock`]s so a reinstall (or
//! a fault injection, [`GoldenServer::inject_cell_faults`]) swaps the
//! install without stopping the server — in-flight batches hold read
//! locks and finish on the old install first.

use std::sync::{Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::config::{AdcKind, XbarParams};
use crate::coordinator::batcher::{Batch, Batcher, PendingRequest};
use crate::coordinator::health::{HealthMonitor, HealthPolicy, HealthReport};
use crate::coordinator::pipeline::forward_pipelined_ledgered;
use crate::mapping::{StageMap, StagePolicy};
use crate::obs;
use crate::sched::Executor;
use crate::xbar::cnn::{ForwardScratch, MiniCnn, ProgrammedCnn, Tensor};
use crate::xbar::Matrix;

/// Elements in one newton-mini input image — the request shape every
/// serving surface (CLI, example, network endpoint) validates against.
pub const IMAGE_ELEMS: usize = 32 * 32 * 3;

/// Batched golden-model inference over installed crossbar weights.
pub struct GoldenServer {
    cnn: MiniCnn,
    /// Installed serving replicas (>= 1), all with the serving ADC config.
    /// Behind [`RwLock`]s so [`Self::reinstall`] /
    /// [`Self::inject_cell_faults`] can swap an install mid-serve: batch
    /// execution holds the read lock, a swap waits for it under the write
    /// lock — uncontended in steady state.
    replicas: Vec<RwLock<ProgrammedCnn>>,
    /// Lossless reference install, present whenever the serving config can
    /// deviate from it (adaptive or lossy ADC), and always once
    /// [`Self::with_health`] arms the health machinery (drift detection
    /// needs a pristine reference even for exact configs).
    golden: Option<ProgrammedCnn>,
    kind: AdcKind,
    p: XbarParams,
    adaptive: bool,
    batch: usize,
    /// Pipelined stage scheduling: when set, batches run wavefront-style
    /// through [`crate::coordinator::pipeline`] across the replica pool
    /// under this stage map, instead of whole batches on one replica.
    /// Behind a mutex because quarantines re-derive it mid-serve.
    pipeline: Option<Mutex<StageMap>>,
    /// Replica health state machine ([`Self::with_health`]); `None` keeps
    /// the pre-health serving behaviour bit-for-bit.
    health: Option<HealthMonitor>,
    /// Forward scratch reused across sequentially served batches (the
    /// net dispatcher and single-worker serving paths). `try_lock` only:
    /// concurrent batch jobs fall back to a fresh scratch instead of
    /// serialising on the lock.
    scratch: Mutex<ForwardScratch>,
    /// Tile energy model pricing served cost ledgers into picojoules
    /// (paper Table I constants over the serving crossbar geometry).
    tile: crate::energy::TileModel,
}

/// One served batch from [`GoldenServer::serve_batches`].
#[derive(Clone, Debug)]
pub struct BatchReport {
    /// Batch index in submission order (reports come back in this order).
    pub index: usize,
    /// Replica that executed the batch (round-robin affinity). In
    /// pipelined mode the batch flows across the whole pool, so this is
    /// the replica of the *classifier* stage — the one that produced the
    /// logits.
    pub replica: usize,
    /// Request ids of the real rows.
    pub ids: Vec<u64>,
    /// Real images in the batch (the rest was padding).
    pub n_real: usize,
    /// Per-request logits, real rows only.
    pub logits: Vec<Vec<i32>>,
    /// Max |served - golden| over the real logits of this batch; 0 when
    /// the serving config is itself lossless.
    pub max_abs_err: i64,
    /// Hardware cost ledger of the forward whose logits were served
    /// (empty unless `obs::ledger` is enabled). Golden-reference forwards
    /// and discarded health re-runs are excluded — the ledger prices what
    /// this batch's answer cost, not everything the server did around it.
    pub cost: obs::CostLedger,
    /// `cost` priced through the tile energy model, picojoules (0 when
    /// the ledger is off).
    pub energy_pj: f64,
}

/// Aggregate a serve run's per-batch reports into
/// `(requests_served, worst_deviation)` — the summary `newton serve
/// --adc` prints and tests assert against.
pub fn serve_totals(reports: &[BatchReport]) -> (usize, i64) {
    (
        reports.iter().map(|r| r.n_real).sum(),
        reports.iter().map(|r| r.max_abs_err).max().unwrap_or(0),
    )
}

/// Flat `32*32*3` i32 images -> a (B,32,32,3) activation tensor, zero-padded
/// to `batch` rows.
fn tensor_from(images: &[Vec<i32>], batch: usize) -> Tensor {
    let mut t = Tensor::zeros(batch, 32, 32, 3);
    for (i, img) in images.iter().enumerate() {
        assert_eq!(img.len(), IMAGE_ELEMS, "image {i}: want {IMAGE_ELEMS} elements");
        for (j, &v) in img.iter().enumerate() {
            t.data[i * IMAGE_ELEMS + j] = v as i64;
        }
    }
    t
}

/// A batcher-padded flat batch -> a (batch,32,32,3) tensor.
fn tensor_from_flat(data: &[i32], batch: usize) -> Tensor {
    assert_eq!(data.len(), batch * IMAGE_ELEMS, "bad batch shape");
    let mut t = Tensor::zeros(batch, 32, 32, 3);
    for (d, &v) in t.data.iter_mut().zip(data) {
        *d = v as i64;
    }
    t
}

impl GoldenServer {
    /// `kind`: the caller's constructed [`AdcKind`] when there is one
    /// (`replicated`), else derived from the raw `(p, adaptive)` pair.
    fn build(
        seed: u64,
        p: XbarParams,
        adaptive: bool,
        n_replicas: usize,
        batch: usize,
        kind: Option<AdcKind>,
    ) -> Self {
        assert!(batch > 0);
        assert!(n_replicas > 0);
        let cnn = MiniCnn::new(seed);
        let replicas: Vec<RwLock<ProgrammedCnn>> = (0..n_replicas)
            .map(|_| RwLock::new(cnn.program(&p, adaptive)))
            .collect();
        // the golden install is numerics-driven: present iff the serving
        // config can actually deviate (e.g. Lossy(10) at a 9-bit lossless
        // budget is exact and needs no reference, whatever its label)
        let lossless = !adaptive && p.adc_bits >= p.lossless_adc_bits();
        let golden = (!lossless).then(|| {
            cnn.program(
                &XbarParams {
                    adc_bits: p.lossless_adc_bits(),
                    ..p
                },
                false,
            )
        });
        let kind = kind.unwrap_or(if adaptive {
            AdcKind::Adaptive
        } else if lossless {
            AdcKind::Exact
        } else {
            AdcKind::Lossy(p.adc_bits)
        });
        GoldenServer {
            cnn,
            replicas,
            golden,
            kind,
            p,
            adaptive,
            batch,
            pipeline: None,
            health: None,
            scratch: Mutex::new(ForwardScratch::new()),
            // price ledgers against the newton conv tile built over the
            // *serving* crossbar params (resolved ADC widths already live
            // in the ledger, so no activity-factor scaling here)
            tile: crate::energy::TileModel::new(
                crate::config::ChipConfig::newton().conv_tile,
                p,
            ),
        }
    }

    /// Install the newton-mini weights once for the given pipeline config.
    pub fn new(seed: u64, p: &XbarParams, adaptive: bool, batch: usize) -> Self {
        Self::build(seed, *p, adaptive, 1, batch, None)
    }

    /// Multi-replica serving: `n_replicas` installs of the `kind` serving
    /// config (plus a lossless golden install when `kind` can deviate).
    ///
    /// # Examples
    ///
    /// ```
    /// use newton::config::AdcKind;
    /// use newton::coordinator::GoldenServer;
    ///
    /// // 2 adaptive-ADC replicas at batch 4; adaptive can deviate from
    /// // lossless, so a golden reference install rides along
    /// let s = GoldenServer::replicated(0, AdcKind::Adaptive, 2, 4);
    /// assert_eq!(s.n_replicas(), 2);
    /// assert_eq!(s.batch(), 4);
    /// assert!(s.has_golden_reference());
    /// ```
    pub fn replicated(seed: u64, kind: AdcKind, n_replicas: usize, batch: usize) -> Self {
        let (p, adaptive) = kind.apply(&XbarParams::default());
        Self::build(seed, p, adaptive, n_replicas, batch, Some(kind))
    }

    /// Enable pipelined stage scheduling: batches flow wavefront-style
    /// through the per-stage units across the replica pool
    /// ([`crate::coordinator::pipeline`]), with stage → replica placement
    /// built under `policy`'s sharing constraints. Bit-identical to the
    /// non-pipelined path. Fails when the policy cannot be satisfied with
    /// this replica count (e.g. [`StagePolicy::newton`] needs >= 2
    /// replicas for conv/classifier isolation).
    pub fn with_pipeline(mut self, policy: StagePolicy) -> Result<Self, String> {
        let map = crate::coordinator::pipeline::build_map(&self.replicas[..], policy)?;
        self.pipeline = Some(Mutex::new(map));
        Ok(self)
    }

    /// Arm the replica health machinery: per-batch deviations feed the
    /// [`HealthMonitor`] state machine, bad batches re-run on healthy
    /// replicas, quarantined replicas leave the rotation. Forces a golden
    /// reference install even for exact configs — drifted cells can only
    /// be detected against pristine weights.
    pub fn with_health(mut self, policy: HealthPolicy) -> Self {
        if self.golden.is_none() {
            self.golden = Some(self.cnn.program(
                &XbarParams {
                    adc_bits: self.p.lossless_adc_bits(),
                    ..self.p
                },
                false,
            ));
        }
        self.health = Some(HealthMonitor::new(self.replicas.len(), policy));
        self
    }

    /// The health monitor when [`Self::with_health`] armed it.
    pub fn health_monitor(&self) -> Option<&HealthMonitor> {
        self.health.as_ref()
    }

    /// Aggregate health counters for `Stats`, when health is armed.
    pub fn health_report(&self) -> Option<HealthReport> {
        self.health.as_ref().map(|h| h.report())
    }

    /// The stage → replica map when pipelined stage scheduling is on
    /// (a snapshot — quarantines re-derive the live map mid-serve).
    pub fn pipeline_map(&self) -> Option<StageMap> {
        self.pipeline.as_ref().map(|m| m.lock().unwrap().clone())
    }

    /// Replace replica `replica`'s install with a fault-perturbed one
    /// (deterministic cell drift / stuck-at faults from `plan`) — the
    /// chaos entry point: the perturbed replica is indistinguishable from
    /// a drifted crossbar and must be caught by its served deviation.
    /// Waits for the replica's in-flight batch under the write lock.
    pub fn inject_cell_faults(&self, replica: usize, plan: &crate::faults::FaultPlan) {
        let drifted = plan.program_drifted(&self.cnn, &self.p, self.adaptive);
        *self.replicas[replica].write().unwrap() = drifted;
    }

    /// Reprogram replica `replica` from pristine weights — the crossbar
    /// reinstall path. The swap waits for the replica's in-flight batch
    /// (write lock); with health armed the replica returns to probation
    /// and the pipelined stage map is re-derived to include it again.
    pub fn reinstall(&self, replica: usize) {
        let fresh = self.cnn.program(&self.p, self.adaptive);
        *self.replicas[replica].write().unwrap() = fresh;
        if let Some(h) = &self.health {
            h.reinstalled(replica);
        }
        self.rebuild_pipeline_map();
    }

    /// Re-derive the pipelined stage map over the currently usable
    /// replicas (no-op without health or without pipelining). Falls back
    /// to the unconstrained policy when the armed policy is infeasible on
    /// the survivors (e.g. newton's classifier isolation with one usable
    /// replica) — degraded placement beats an outage.
    fn rebuild_pipeline_map(&self) {
        let (Some(m), Some(h)) = (&self.pipeline, &self.health) else {
            return;
        };
        let usable = h.usable();
        let mut g = m.lock().unwrap();
        let n_conv = g.assignment.len() - 1;
        let rebuilt = StageMap::build_over(n_conv, &usable, self.replicas.len(), g.policy)
            .or_else(|_| {
                StageMap::build_over(
                    n_conv,
                    &usable,
                    self.replicas.len(),
                    StagePolicy::unconstrained(),
                )
            })
            .expect("health keeps at least one usable replica");
        *g = rebuilt;
    }

    /// The standard fallback configuration shared by `newton serve` and the
    /// serve example: seed-0 newton-mini weights, exact pipeline, batch 8.
    pub fn newton_mini_default() -> Self {
        Self::new(0, &XbarParams::default(), false, 8)
    }

    /// Batch capacity per forward pass.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Installed serving replicas.
    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// The serving ADC mode.
    pub fn adc_kind(&self) -> AdcKind {
        self.kind
    }

    /// The tile energy model pricing served cost ledgers (paper Table I
    /// constants over the serving crossbar geometry).
    pub fn energy_model(&self) -> &crate::energy::TileModel {
        &self.tile
    }

    /// True when a lossless golden install rides along for per-batch
    /// deviation reporting.
    pub fn has_golden_reference(&self) -> bool {
        self.golden.is_some()
    }

    /// Verification of the head batch (or every image if fewer): true when
    /// the installed-crossbar forward matches the per-call engine, or when
    /// there is nothing to check.
    pub fn verify_head(&self, images: &[Vec<i32>]) -> bool {
        let head = &images[..self.batch.min(images.len())];
        head.is_empty() || self.verify_batch(head)
    }

    /// Serve a request list: chunks into batches (padding the tail), runs
    /// each through the installed weights, returns per-request logits.
    pub fn infer(&self, images: &[Vec<i32>]) -> Vec<Vec<i32>> {
        let mut out = Vec::with_capacity(images.len());
        for chunk in images.chunks(self.batch) {
            let t = tensor_from(chunk, self.batch);
            let logits = self.replicas[0].read().unwrap().forward(&t);
            for i in 0..chunk.len() {
                out.push((0..logits.cols).map(|c| logits.at(i, c) as i32).collect());
            }
        }
        out
    }

    /// Multi-replica serving path: requests flow through the [`Batcher`]
    /// into fixed-shape batches, each batch is one work-stealing job with
    /// round-robin replica affinity, and every batch's real logits are
    /// compared against the lossless golden install. Reports come back in
    /// submission order regardless of worker count. The pool is sized by
    /// the total image count, so spare capacity beyond the batch-level
    /// fan-out flows into per-image splits inside each batch.
    pub fn serve_batches(&self, images: &[Vec<i32>]) -> Vec<BatchReport> {
        self.serve_batches_on(images, &Executor::for_jobs(images.len()))
    }

    /// [`Self::serve_batches`] on a caller-sized executor, which bounds
    /// the total sched-level fan-out: batch jobs run on it, and the pool's
    /// capacity is divided across in-flight batches for the per-image
    /// split inside each one (the per-VMM fan-out stays sequential inside
    /// pool workers — see `sched::in_worker` — so compute threads stay
    /// ~`exec.workers()` rather than multiplying per layer). With a
    /// 1-worker executor everything runs sequentially on the caller
    /// thread, like [`Self::infer`].
    pub fn serve_batches_on(&self, images: &[Vec<i32>], exec: &Executor) -> Vec<BatchReport> {
        let mut batcher = Batcher::new(self.batch, IMAGE_ELEMS, Duration::from_millis(0));
        for (i, img) in images.iter().enumerate() {
            batcher.push(PendingRequest {
                id: i as u64,
                trace: 0,
                image: img.clone(),
                enqueued: Instant::now(),
            });
        }
        let mut batches: Vec<Batch> = Vec::new();
        while let Some(b) = batcher.take_batch() {
            batches.push(b);
        }
        if self.pipeline.is_some() {
            // pipelined mode: batches run one at a time — the wavefront
            // itself keeps the replica pool busy, and overlapping two
            // batches would put one physical replica under two stages at
            // once (exactly what the stage map forbids)
            return batches
                .iter()
                .enumerate()
                .map(|(bi, b)| self.run_batch(bi, b, exec.workers()))
                .collect();
        }
        // divide the pool: in-flight batch jobs × per-image workers ≈ pool
        // (ceil so an uneven batch count never idles cores)
        let in_flight = exec.workers().min(batches.len()).max(1);
        let image_workers = exec.workers().div_ceil(in_flight);
        exec.map(batches.len(), |bi| self.run_batch(bi, &batches[bi], image_workers))
    }

    /// Run one batcher-shaped (padded) batch through replica
    /// `index % n_replicas` (with health armed: round-robin over the
    /// *usable* replicas, bad batches transparently re-run) — the network
    /// serving entry point ([`crate::net::Engine`]). The per-image split
    /// inside the batch gets
    /// the whole pool: the network dispatcher executes batches one at a
    /// time, unlike [`Self::serve_batches_on`] which divides the pool
    /// across in-flight batches.
    pub fn run_one(&self, index: usize, b: &Batch) -> BatchReport {
        let sp = obs::span("batch", "serve")
            .arg("index", index as u64)
            .arg("n_real", b.n_real as u64)
            .arg("trace0", b.traces.first().copied().unwrap_or(0));
        let r = self.run_batch(index, b, crate::util::worker_count(self.batch));
        // the executing replica is only known after the fact; attach it so
        // the exported trace can be grouped per replica
        let _sp = sp.arg("replica", r.replica as u64);
        r
    }

    /// Run `f` with the server-owned forward scratch when it is free, else
    /// a fresh one — concurrent batch jobs degrade to allocation, never to
    /// a lock convoy.
    fn with_scratch<T>(&self, f: impl FnOnce(&mut ForwardScratch) -> T) -> T {
        match self.scratch.try_lock() {
            Ok(mut g) => f(&mut g),
            Err(_) => f(&mut ForwardScratch::new()),
        }
    }

    /// Max |served - want| over the batch's real rows.
    fn batch_err(served: &Matrix, want: &Matrix, n_real: usize) -> i64 {
        let mut worst = 0i64;
        for r in 0..n_real {
            for c in 0..served.cols {
                worst = worst.max((served.at(r, c) - want.at(r, c)).abs());
            }
        }
        worst
    }

    /// Whole-batch forward on one replica under its read lock — parallel
    /// per-image split on `exec` when one is provided, else the sequential
    /// pass over the server-owned scratch — returning the forward's
    /// hardware cost ledger (empty unless `obs::ledger` is enabled). The
    /// shared sequential scratch is drained *before* the forward too, so
    /// residue from forwards that must not count — golden references
    /// through [`Self::with_scratch`] — never leaks into this attribution.
    fn forward_replica_ledgered(
        &self,
        replica: usize,
        t: &Tensor,
        exec: Option<&Executor>,
    ) -> (Matrix, obs::CostLedger) {
        let guard = self.replicas[replica].read().unwrap();
        match exec {
            Some(e) => guard.forward_on_ledgered(t, e),
            None => self.with_scratch(|s| {
                let _ = s.take_ledger();
                let out = guard.forward_seq_with(t, s);
                (out, s.take_ledger())
            }),
        }
    }

    fn run_batch(&self, index: usize, b: &Batch, image_workers: usize) -> BatchReport {
        let t = tensor_from_flat(&b.data, self.batch);
        let (replica, served, max_abs_err, cost) = if self.pipeline.is_some() {
            self.run_batch_pipelined(&t, b.n_real, image_workers)
        } else {
            self.run_batch_routed(index, &t, b.n_real, image_workers)
        };
        let energy_pj = if cost.is_empty() {
            0.0
        } else {
            let pj = self.tile.ledger_energy_pj(&cost);
            obs::ledger::record_serving(&cost, b.n_real, pj);
            obs::ledger::record_replica(replica, &cost);
            pj
        };
        let logits = (0..b.n_real)
            .map(|r| (0..served.cols).map(|c| served.at(r, c) as i32).collect())
            .collect();
        BatchReport {
            index,
            replica,
            ids: b.ids.clone(),
            n_real: b.n_real,
            logits,
            max_abs_err,
            cost,
            energy_pj,
        }
    }

    /// Whole-batch-per-replica serving: route, run, compare vs golden,
    /// and (with health armed) transparently re-run a bad batch on
    /// alternative replicas until one serves it cleanly or the pool is
    /// exhausted — the report carries the best result found.
    fn run_batch_routed(
        &self,
        index: usize,
        t: &Tensor,
        n_real: usize,
        image_workers: usize,
    ) -> (usize, Matrix, i64, obs::CostLedger) {
        let exec = (image_workers > 1 && self.batch > 1).then(|| Executor::new(image_workers));
        let route = match &self.health {
            Some(h) => h.route(index),
            None => index % self.replicas.len(),
        };
        let (served, cost) = self.forward_replica_ledgered(route, t, exec.as_ref());
        let want = self.golden.as_ref().map(|g| match exec.as_ref() {
            Some(e) => g.forward_on(t, e),
            None => self.with_scratch(|s| g.forward_seq_with(t, s)),
        });
        let Some(want) = want else {
            return (route, served, 0, cost);
        };
        let err = Self::batch_err(&served, &want, n_real);
        let Some(h) = &self.health else {
            return (route, served, err, cost);
        };
        h.observe(route, err);
        let threshold = h.policy().deviation_threshold;
        let (mut best, mut tried) = ((route, served, err, cost), vec![route]);
        while best.2 > threshold {
            let Some(alt) = h.alternative(&tried, index) else {
                break; // every replica tried: serve the least-bad result
            };
            h.record_rerun();
            obs::counter("health.reruns").inc();
            obs::event(
                "health_rerun",
                "health",
                &[("batch", index as u64), ("replica", alt as u64)],
            );
            let (served, cost) = self.forward_replica_ledgered(alt, t, exec.as_ref());
            let err = Self::batch_err(&served, &want, n_real);
            h.observe(alt, err);
            tried.push(alt);
            if err < best.2 {
                best = (alt, served, err, cost);
            }
        }
        best
    }

    /// Pipelined serving: the wavefront flows across the mapped replicas,
    /// so a bad batch cannot be blamed on one replica directly — with
    /// health armed, the batch is re-run *solo* on each mapped replica to
    /// localise the drift, each solo run feeds the state machine, the
    /// stage map re-derives around any quarantine, and the best solo
    /// result is served. The report's replica is the classifier stage's
    /// (clean path) or the solo replica that produced the logits.
    fn run_batch_pipelined(
        &self,
        t: &Tensor,
        n_real: usize,
        image_workers: usize,
    ) -> (usize, Matrix, i64, obs::CostLedger) {
        let map = self
            .pipeline
            .as_ref()
            .expect("pipelined path without a map")
            .lock()
            .unwrap()
            .clone();
        // wavefront over the replica pool: one worker per distinct
        // replica in the map is the concurrency ceiling, more would
        // only idle. The report's replica is the classifier stage's —
        // the one that produced these logits.
        let exec = Executor::new(image_workers.clamp(1, map.concurrency()));
        let (served, cost) = forward_pipelined_ledgered(&self.replicas[..], &map, t, &exec);
        let classifier = *map.assignment.last().unwrap();
        let want = self
            .golden
            .as_ref()
            .map(|g| self.with_scratch(|s| g.forward_seq_with(t, s)));
        let Some(want) = want else {
            return (classifier, served, 0, cost);
        };
        let err = Self::batch_err(&served, &want, n_real);
        let Some(h) = &self.health else {
            return (classifier, served, err, cost);
        };
        let threshold = h.policy().deviation_threshold;
        let mut mapped: Vec<usize> = map.assignment.clone();
        mapped.sort_unstable();
        mapped.dedup();
        if err <= threshold {
            // clean wavefront: every mapped replica contributed a clean
            // share (lets probation replicas earn Healthy back)
            for &r in &mapped {
                h.observe(r, err);
            }
            return (classifier, served, err, cost);
        }
        // localise the drift: solo-run the batch on each mapped replica
        h.record_rerun();
        obs::counter("health.reruns").inc();
        obs::event("health_rerun", "health", &[("pipelined", 1)]);
        let mut best: Option<(usize, Matrix, i64, obs::CostLedger)> = None;
        for &r in &mapped {
            let (solo, solo_cost) = self.forward_replica_ledgered(r, t, None);
            let solo_err = Self::batch_err(&solo, &want, n_real);
            h.observe(r, solo_err);
            if best.as_ref().map_or(true, |(_, _, e, _)| solo_err < *e) {
                best = Some((r, solo, solo_err, solo_cost));
            }
        }
        // try surviving replicas outside the map too, if the mapped ones
        // all drifted
        let mut best = best.expect("stage map uses at least one replica");
        let mut tried = mapped;
        while best.2 > threshold {
            let Some(alt) = h.alternative(&tried, 0) else {
                break;
            };
            h.record_rerun();
            obs::counter("health.reruns").inc();
            obs::event(
                "health_rerun",
                "health",
                &[("pipelined", 1), ("replica", alt as u64)],
            );
            let (solo, solo_cost) = self.forward_replica_ledgered(alt, t, None);
            let solo_err = Self::batch_err(&solo, &want, n_real);
            h.observe(alt, solo_err);
            tried.push(alt);
            if solo_err < best.2 {
                best = (alt, solo, solo_err, solo_cost);
            }
        }
        self.rebuild_pipeline_map();
        best
    }

    /// Verification path: the installed-crossbar forward must equal the
    /// legacy per-call engine bit-for-bit on this batch.
    pub fn verify_batch(&self, images: &[Vec<i32>]) -> bool {
        let t = tensor_from(images, images.len().max(1));
        let installed = self.replicas[0].read().unwrap().forward(&t);
        let legacy = self.cnn.forward(&t, &self.p, self.adaptive);
        installed.data == legacy.data
    }
}

/// The golden crossbar engine is the network endpoint's default backend:
/// batches arrive from the server's `Batcher`, run on round-robin replicas
/// through the work-stealing executor, and report deviation vs the
/// lossless golden install. PJRT (or any heterogeneous replica pool) can
/// implement the same trait later without touching the wire layer.
impl crate::net::Engine for GoldenServer {
    fn image_elems(&self) -> usize {
        IMAGE_ELEMS
    }

    fn batch_capacity(&self) -> usize {
        self.batch
    }

    fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    fn describe(&self) -> String {
        format!(
            "golden newton-mini · adc {} · {} replica(s){}{}{} · batch {}",
            self.kind.label(),
            self.replicas.len(),
            if self.golden.is_some() { " + lossless golden" } else { "" },
            match &self.pipeline {
                Some(map) => {
                    format!(" · pipelined stages {:?}", map.lock().unwrap().assignment)
                }
                None => String::new(),
            },
            if self.health.is_some() { " · health armed" } else { "" },
            self.batch
        )
    }

    fn run(&self, index: usize, batch: &Batch) -> crate::net::EngineBatch {
        let r = self.run_one(index, batch);
        crate::net::EngineBatch {
            replica: r.replica,
            n_real: r.n_real,
            logits: r.logits,
            max_abs_err: r.max_abs_err,
            cost: r.cost,
            energy_pj: r.energy_pj,
        }
    }

    fn health(&self) -> Option<HealthReport> {
        self.health_report()
    }
}

#[cfg(test)]
mod engine_trait_tests {
    use crate::net::Engine;

    #[test]
    fn golden_server_exposes_its_geometry_through_the_engine_trait() {
        let s = super::GoldenServer::newton_mini_default();
        let e: &dyn Engine = &s;
        assert_eq!(e.image_elems(), super::IMAGE_ELEMS);
        assert_eq!(e.batch_capacity(), 8);
        assert_eq!(e.n_replicas(), 1);
        assert!(e.describe().contains("exact"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn images(n: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..32 * 32 * 3).map(|_| rng.below(256) as i32).collect())
            .collect()
    }

    #[test]
    fn construction_installs_weights() {
        let s = GoldenServer::newton_mini_default();
        assert_eq!(s.batch(), 8);
        assert_eq!(s.n_replicas(), 1);
        assert_eq!(s.adc_kind(), AdcKind::Exact);
        assert!(!s.has_golden_reference()); // exact config is its own golden
        assert!(s.verify_head(&[])); // nothing to check is vacuously true
    }

    #[test]
    fn replicated_kinds_carry_a_golden_reference() {
        let s = GoldenServer::replicated(0, AdcKind::Adaptive, 2, 2);
        assert_eq!(s.n_replicas(), 2);
        assert_eq!(s.adc_kind(), AdcKind::Adaptive);
        assert!(s.has_golden_reference());
        let s = GoldenServer::replicated(0, AdcKind::Lossy(8), 3, 2);
        assert_eq!(s.adc_kind(), AdcKind::Lossy(8));
        assert!(s.has_golden_reference());
        // a lossy resolution at/above the lossless budget keeps its label
        // but is exact numerically: no golden reference needed
        let s = GoldenServer::replicated(0, AdcKind::Lossy(10), 1, 2);
        assert_eq!(s.adc_kind(), AdcKind::Lossy(10));
        assert!(!s.has_golden_reference());
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn serves_and_verifies_against_legacy_engine() {
        let s = GoldenServer::new(0, &XbarParams::default(), false, 2);
        let imgs = images(3, 4); // 1.5 batches: exercises tail padding
        let logits = s.infer(&imgs);
        assert_eq!(logits.len(), 3);
        assert!(logits.iter().all(|l| l.len() == 10));
        assert!(s.verify_batch(&imgs[..2]));
        // a lone image padded into a full batch must match its solo run
        let solo = s.infer(&imgs[2..3]);
        assert_eq!(solo[0], logits[2]);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn multi_replica_serving_matches_single_replica_infer() {
        // replica fan-out must not change the numbers: serve_batches on an
        // exact config returns the same logits as the sequential infer path
        let s = GoldenServer::replicated(0, AdcKind::Exact, 3, 2);
        let imgs = images(5, 9); // 2.5 batches across 3 replicas
        let want = s.infer(&imgs);
        let reports = s.serve_batches(&imgs);
        assert_eq!(reports.len(), 3);
        let mut got: Vec<Vec<i32>> = Vec::new();
        for (bi, r) in reports.iter().enumerate() {
            assert_eq!(r.index, bi);
            assert_eq!(r.replica, bi % 3);
            assert_eq!(r.max_abs_err, 0, "exact serving deviated from itself");
            got.extend(r.logits.iter().cloned());
        }
        assert_eq!(got, want);
        let ids: Vec<u64> = reports.iter().flat_map(|r| r.ids.clone()).collect();
        assert_eq!(ids, (0..5u64).collect::<Vec<_>>());
    }

    #[test]
    fn pipeline_policy_feasibility_is_checked_at_construction() {
        let err = GoldenServer::replicated(0, AdcKind::Exact, 1, 2)
            .with_pipeline(StagePolicy::newton());
        assert!(err.is_err(), "newton policy needs a dedicated classifier replica");
        let s = GoldenServer::replicated(0, AdcKind::Exact, 1, 2)
            .with_pipeline(StagePolicy::unconstrained())
            .unwrap();
        let map = s.pipeline_map().unwrap();
        assert_eq!(map.assignment, vec![0, 0, 0, 0]);
        let s = GoldenServer::replicated(0, AdcKind::Exact, 2, 2)
            .with_pipeline(StagePolicy::newton())
            .unwrap();
        assert_eq!(s.pipeline_map().unwrap().assignment, vec![0, 0, 0, 1]);
        assert!(crate::net::Engine::describe(&s).contains("pipelined stages"));
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn pipelined_serving_is_bit_identical_to_non_pipelined() {
        // same seed, same config: the pipelined wavefront over 3 replicas
        // must reproduce the single-replica sequential logits bit for bit,
        // and the reported replica is the classifier stage's
        let imgs = images(5, 21); // 2.5 batches exercises tail padding
        let plain = GoldenServer::replicated(0, AdcKind::Exact, 1, 2);
        let want = plain.infer(&imgs);
        let piped = GoldenServer::replicated(0, AdcKind::Exact, 3, 2)
            .with_pipeline(StagePolicy::newton())
            .unwrap();
        let reports = piped.serve_batches(&imgs);
        assert_eq!(reports.len(), 3);
        let classifier = *piped.pipeline_map().unwrap().assignment.last().unwrap();
        let mut got: Vec<Vec<i32>> = Vec::new();
        for (bi, r) in reports.iter().enumerate() {
            assert_eq!(r.index, bi);
            assert_eq!(r.replica, classifier);
            assert_eq!(r.max_abs_err, 0, "exact pipelined serving deviated");
            got.extend(r.logits.iter().cloned());
        }
        assert_eq!(got, want, "pipelined stage scheduling changed the numbers");
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn pipelined_adaptive_serving_keeps_the_golden_deviation_report() {
        // deviation-vs-lossless must survive the pipelined path unchanged
        let imgs = images(4, 23);
        let plain = GoldenServer::replicated(0, AdcKind::Adaptive, 2, 2);
        let piped = GoldenServer::replicated(0, AdcKind::Adaptive, 2, 2)
            .with_pipeline(StagePolicy::newton())
            .unwrap();
        let want = plain.serve_batches(&imgs);
        let got = piped.serve_batches(&imgs);
        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.logits, g.logits, "batch {}", w.index);
            assert_eq!(w.max_abs_err, g.max_abs_err, "batch {}", w.index);
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn health_quarantines_a_drifted_replica_and_keeps_answers_exact() {
        use crate::coordinator::health::{HealthPolicy, HealthState};
        let policy = HealthPolicy {
            quarantine_after: 2,
            ..HealthPolicy::default()
        };
        let s = GoldenServer::replicated(0, AdcKind::Exact, 3, 2).with_health(policy);
        s.inject_cell_faults(1, &crate::faults::FaultPlan::drift(7, 0.05, 30));
        let imgs = images(12, 31); // 6 batches: replica 1 drawn at least twice
        let want = GoldenServer::replicated(0, AdcKind::Exact, 1, 2).infer(&imgs);
        // sequential executor: deterministic route/observe order
        let reports = s.serve_batches_on(&imgs, &Executor::new(1));
        let mut got: Vec<Vec<i32>> = Vec::new();
        for r in &reports {
            assert_eq!(r.max_abs_err, 0, "batch {}: drifted result served", r.index);
            assert_ne!(r.replica, 1, "batch {}: logits came from the drifted replica", r.index);
            got.extend(r.logits.iter().cloned());
        }
        assert_eq!(got, want, "health re-runs changed the served numbers");
        let rep = s.health_report().unwrap();
        assert_eq!(rep.states[1], HealthState::Quarantined.as_u8());
        assert_eq!(rep.quarantines, 1);
        assert!(rep.reruns >= 2, "bad batches were not re-run ({})", rep.reruns);
        assert!(!rep.degraded);
        // the fault schedule is seed-deterministic: a second injection from
        // the same plan reproduces the identical drifted install
        let s2 = GoldenServer::replicated(0, AdcKind::Exact, 3, 2).with_health(policy);
        s2.inject_cell_faults(1, &crate::faults::FaultPlan::drift(7, 0.05, 30));
        let r2 = s2.serve_batches_on(&imgs, &Executor::new(1));
        let errs: Vec<i64> = reports.iter().map(|r| r.max_abs_err).collect();
        let errs2: Vec<i64> = r2.iter().map(|r| r.max_abs_err).collect();
        assert_eq!(errs, errs2, "same seed, different fault schedule");
        assert_eq!(s2.health_report().unwrap().quarantines, 1);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn all_replicas_quarantined_degrades_to_least_bad_serving() {
        use crate::coordinator::health::HealthPolicy;
        let policy = HealthPolicy {
            quarantine_after: 2,
            ..HealthPolicy::default()
        };
        let s = GoldenServer::replicated(0, AdcKind::Exact, 2, 2).with_health(policy);
        s.inject_cell_faults(0, &crate::faults::FaultPlan::drift(3, 0.01, 4));
        s.inject_cell_faults(1, &crate::faults::FaultPlan::drift(4, 0.10, 40));
        let imgs = images(8, 33);
        let reports = s.serve_batches_on(&imgs, &Executor::new(1));
        let rep = s.health_report().unwrap();
        assert!(rep.degraded, "both replicas drifted but not flagged degraded");
        assert_eq!(rep.quarantines, 2);
        // serving never stopped: every request got logits, deviation is
        // reported honestly rather than hidden
        assert_eq!(reports.iter().map(|r| r.n_real).sum::<usize>(), 8);
        assert!(reports.iter().all(|r| r.max_abs_err > 0));
        // reinstalling one replica restores exact service
        s.reinstall(0);
        let after = s.serve_batches_on(&imgs, &Executor::new(1));
        assert!(after.iter().all(|r| r.max_abs_err == 0 && r.replica == 0));
        assert!(!s.health_report().unwrap().degraded);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn pipelined_health_localises_drift_and_rederives_the_map() {
        use crate::coordinator::health::{HealthPolicy, HealthState};
        let policy = HealthPolicy {
            quarantine_after: 2,
            ..HealthPolicy::default()
        };
        // newton map over 3 replicas: convs on 0..1, classifier on 2;
        // replica 0 drifts, so the wavefront result goes bad and the solo
        // blame pass must pin it on replica 0 alone
        let s = GoldenServer::replicated(0, AdcKind::Exact, 3, 2)
            .with_pipeline(StagePolicy::newton())
            .unwrap()
            .with_health(policy);
        s.inject_cell_faults(0, &crate::faults::FaultPlan::drift(11, 0.05, 30));
        let imgs = images(8, 35);
        let want = GoldenServer::replicated(0, AdcKind::Exact, 1, 2).infer(&imgs);
        let reports = s.serve_batches(&imgs); // pipelined: sequential already
        let mut got: Vec<Vec<i32>> = Vec::new();
        for r in &reports {
            assert_eq!(r.max_abs_err, 0, "batch {}: drift leaked through", r.index);
            got.extend(r.logits.iter().cloned());
        }
        assert_eq!(got, want);
        let rep = s.health_report().unwrap();
        assert_eq!(rep.states[0], HealthState::Quarantined.as_u8());
        assert_eq!(rep.states[2], HealthState::Healthy.as_u8());
        assert!(rep.reruns >= 1);
        // the live map re-derived around the quarantined replica
        let map = s.pipeline_map().unwrap();
        assert!(!map.assignment.contains(&0), "map still places stages on 0: {:?}", map.assignment);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn ledgered_serving_attaches_cost_without_moving_bits() {
        let _guard = crate::obs::ledger::test_guard();
        let s = GoldenServer::replicated(0, AdcKind::Adaptive, 2, 2);
        let imgs = images(3, 41); // 1.5 batches: padding rows count too
        crate::obs::ledger::set_enabled(false);
        let off = s.serve_batches_on(&imgs, &Executor::new(1));
        crate::obs::ledger::set_enabled(true);
        let on = s.serve_batches_on(&imgs, &Executor::new(1));
        crate::obs::ledger::set_enabled(false);
        assert_eq!(off.len(), on.len());
        for (a, b) in off.iter().zip(&on) {
            assert_eq!(a.logits, b.logits, "enabling the ledger moved served bits");
            assert!(a.cost.is_empty(), "disabled serving accrued cost");
            assert_eq!(a.energy_pj, 0.0);
            assert!(!b.cost.is_empty(), "enabled serving accrued no cost");
            assert!(b.energy_pj > 0.0, "served forward priced as free");
            assert!(b.cost.rows() > 0);
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn adaptive_serving_reports_exact_deviation() {
        // per-batch max-abs-error must equal an independently computed
        // served-vs-lossless comparison, bit for bit
        let s = GoldenServer::replicated(0, AdcKind::Adaptive, 2, 2);
        let imgs = images(4, 12); // 2 full batches, no padding
        let reports = s.serve_batches(&imgs);
        assert_eq!(reports.len(), 2);
        let cnn = MiniCnn::new(0);
        let p = XbarParams::default();
        let served_prog = cnn.program(&p, true);
        let golden_prog = cnn.program(&p, false);
        for (bi, r) in reports.iter().enumerate() {
            let t = tensor_from(&imgs[bi * 2..bi * 2 + 2], 2);
            let a = served_prog.forward(&t);
            let g = golden_prog.forward(&t);
            let want = a
                .data
                .iter()
                .zip(g.data.iter())
                .map(|(x, y)| (x - y).abs())
                .max()
                .unwrap();
            assert_eq!(r.max_abs_err, want, "batch {bi}");
        }
    }
}
