//! Golden-model serving path: batched newton-mini inference through the
//! install-once crossbar engine, used (a) as the coordinator's fallback
//! when the PJRT artifacts are absent — the serve example stays usable in
//! a fresh checkout — and (b) as the golden-model verification path: the
//! same batch re-executed through the legacy per-call engine must match
//! bit-for-bit, which pins the install/run refactor at model scale on the
//! real serving geometry.

use crate::config::XbarParams;
use crate::xbar::cnn::{MiniCnn, ProgrammedCnn, Tensor};

/// Batched golden-model inference over installed crossbar weights.
pub struct GoldenServer {
    cnn: MiniCnn,
    programmed: ProgrammedCnn,
    p: XbarParams,
    adaptive: bool,
    batch: usize,
}

/// Flat `32*32*3` i32 images -> a (B,32,32,3) activation tensor, zero-padded
/// to `batch` rows.
fn tensor_from(images: &[Vec<i32>], batch: usize) -> Tensor {
    let mut t = Tensor::zeros(batch, 32, 32, 3);
    let per = 32 * 32 * 3;
    for (i, img) in images.iter().enumerate() {
        assert_eq!(img.len(), per, "image {i}: want {per} elements");
        for (j, &v) in img.iter().enumerate() {
            t.data[i * per + j] = v as i64;
        }
    }
    t
}

impl GoldenServer {
    /// Install the newton-mini weights once for the given pipeline config.
    pub fn new(seed: u64, p: &XbarParams, adaptive: bool, batch: usize) -> Self {
        assert!(batch > 0);
        let cnn = MiniCnn::new(seed);
        let programmed = cnn.program(p, adaptive);
        GoldenServer {
            cnn,
            programmed,
            p: *p,
            adaptive,
            batch,
        }
    }

    /// The standard fallback configuration shared by `newton serve` and the
    /// serve example: seed-0 newton-mini weights, exact pipeline, batch 8.
    pub fn newton_mini_default() -> Self {
        Self::new(0, &XbarParams::default(), false, 8)
    }

    /// Batch capacity per forward pass.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Verification of the head batch (or every image if fewer): true when
    /// the installed-crossbar forward matches the per-call engine, or when
    /// there is nothing to check.
    pub fn verify_head(&self, images: &[Vec<i32>]) -> bool {
        let head = &images[..self.batch.min(images.len())];
        head.is_empty() || self.verify_batch(head)
    }

    /// Serve a request list: chunks into batches (padding the tail), runs
    /// each through the installed weights, returns per-request logits.
    pub fn infer(&self, images: &[Vec<i32>]) -> Vec<Vec<i32>> {
        let mut out = Vec::with_capacity(images.len());
        for chunk in images.chunks(self.batch) {
            let t = tensor_from(chunk, self.batch);
            let logits = self.programmed.forward(&t);
            for i in 0..chunk.len() {
                out.push((0..logits.cols).map(|c| logits.at(i, c) as i32).collect());
            }
        }
        out
    }

    /// Verification path: the installed-crossbar forward must equal the
    /// legacy per-call engine bit-for-bit on this batch.
    pub fn verify_batch(&self, images: &[Vec<i32>]) -> bool {
        let t = tensor_from(images, images.len().max(1));
        let installed = self.programmed.forward(&t);
        let legacy = self.cnn.forward(&t, &self.p, self.adaptive);
        installed.data == legacy.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn images(n: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..32 * 32 * 3).map(|_| rng.below(256) as i32).collect())
            .collect()
    }

    #[test]
    fn construction_installs_weights() {
        let s = GoldenServer::newton_mini_default();
        assert_eq!(s.batch(), 8);
        assert!(s.verify_head(&[])); // nothing to check is vacuously true
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow in debug; run with --release")]
    fn serves_and_verifies_against_legacy_engine() {
        let s = GoldenServer::new(0, &XbarParams::default(), false, 2);
        let imgs = images(3, 4); // 1.5 batches: exercises tail padding
        let logits = s.infer(&imgs);
        assert_eq!(logits.len(), 3);
        assert!(logits.iter().all(|l| l.len() == 10));
        assert!(s.verify_batch(&imgs[..2]));
        // a lone image padded into a full batch must match its solo run
        let solo = s.infer(&imgs[2..3]);
        assert_eq!(solo[0], logits[2]);
    }
}
