//! L3 coordinator: the serving stack on top of the PJRT runtime.
//!
//! Newton is an inference accelerator, so the L3 contribution is a serving
//! pipeline shaped like the chip itself: requests are routed to a leader,
//! batched (the crossbar pipeline works on fixed-shape batches, like tiles
//! working on fixed 128-input VMMs), and pushed through one worker thread
//! per pipeline *stage* — the software analogue of the paper's inter-tile
//! pipeline, where stage k's tiles hand neuron outputs to stage k+1's tiles
//! over the mesh. Stage artifacts are the per-stage HLO modules produced by
//! `python/compile/aot.py`; weights ride inside them ("in-situ").
//!
//! Alongside the real numerics, the coordinator reports *simulated* hardware
//! metrics for the served model by running the same analytic pipeline model
//! used for the paper's figures on the newton-mini geometry.
//!
//! Two pipelines live here, one per backend: [`server::PipelineServer`]
//! runs the PJRT stage artifacts on one thread per stage (artifact-gated),
//! and [`pipeline`] schedules the golden engine's per-stage units
//! wavefront-style over a replica pool under the sharing constraints of a
//! [`crate::mapping::StageMap`] (`GoldenServer` serves either way; see
//! `--pipeline` on `newton serve`/`serve-net`).

pub mod batcher;
pub mod cluster;
pub mod golden;
pub mod health;
pub mod pipeline;
pub mod server;

pub use batcher::{Batch, Batcher};
pub use cluster::{
    ClusterConfig, ClusterEngine, ClusterMonitor, ClusterWorker, LifecyclePolicy, WorkerConfig,
    WorkerState,
};
pub use golden::{serve_totals, BatchReport, GoldenServer};
pub use health::{HealthMonitor, HealthPolicy, HealthReport, HealthState};
pub use pipeline::{build_map, forward_pipelined, ScratchPool, StagePool};
pub use server::{PipelineServer, ServerConfig, ServerReport};

use crate::workloads::{Layer, Network};

/// The newton-mini CNN served by the examples (mirrors
/// `python/compile/model.py`): 32x32x3 -> conv 32/64/128 -> fc 10.
pub fn newton_mini() -> Network {
    let mk_conv = |cin, cout, in_hw| Layer::Conv {
        k: 3,
        cin,
        cout,
        stride: 1,
        in_hw,
    };
    Network {
        name: "newton-mini",
        layers: vec![
            mk_conv(3, 32, 32),
            Layer::Pool {
                k: 2,
                stride: 2,
                cin: 32,
                in_hw: 32,
            },
            mk_conv(32, 64, 16),
            Layer::Pool {
                k: 2,
                stride: 2,
                cin: 64,
                in_hw: 16,
            },
            mk_conv(64, 128, 8),
            Layer::Pool {
                k: 2,
                stride: 2,
                cin: 128,
                in_hw: 8,
            },
            Layer::Fc {
                inputs: 4 * 4 * 128,
                outputs: 10,
            },
        ],
    }
}

/// Argmax over a logits row (ties -> lowest index).
pub fn argmax(logits: &[i32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn newton_mini_geometry_matches_model_py() {
        let n = newton_mini();
        assert_eq!(n.conv_layers().count(), 3);
        let fc: Vec<_> = n.fc_layers().collect();
        assert_eq!(fc.len(), 1);
        assert_eq!(fc[0].matrix(), Some((2048, 10)));
        // conv2: 3x3x32 -> 64 at 16x16
        let c2 = n.conv_layers().nth(1).unwrap();
        assert_eq!(c2.matrix(), Some((288, 64)));
        assert_eq!(c2.out_hw(), 16);
    }

    #[test]
    fn newton_mini_evaluates_under_the_analytic_model() {
        let r = crate::pipeline::evaluate(&newton_mini(), &crate::config::ChipConfig::newton());
        assert!(r.energy_per_op_pj > 0.0 && r.energy_per_op_pj < 20.0);
        assert!(r.throughput > 0.0);
    }

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[1, 5, 5, 2]), 1);
        assert_eq!(argmax(&[-3, -1, -2]), 1);
        assert_eq!(argmax(&[7]), 0);
    }
}
