//! Pipelined stage scheduling over a replica pool (paper §III-B/§IV: the
//! inter-tile pipeline over heterogeneous conv/classifier tiles).
//!
//! Newton keeps early conv tiles and the classifier tail busy at the same
//! time: while image `k` is in conv1, image `k+1` is already in conv0 on a
//! *different* tile group. This module is the software twin of that
//! schedule for the golden serving stack: a batch of images flows through
//! the per-stage units [`ProgrammedCnn::run_stage`] cut out of the CNN,
//! mapped onto a pool of installed replicas by a
//! [`StageMap`] (which records Newton's sharing constraints —
//! the classifier tail never co-resides with a conv stage — instead of
//! hard-coding them here).
//!
//! The schedule is a deterministic wavefront: wave `t` executes every
//! ready cell `(image k, stage s)` with `k + s == t`, so stage `s` of
//! image `k+1` overlaps stage `s+1` of image `k` exactly as in the chip's
//! pipeline diagram. Cells of one wave that map to the same replica are
//! grouped into a single job (a physical replica runs one stage at a
//! time); distinct replicas run concurrently through the work-stealing
//! executor ([`crate::sched`] — each wave is one `Executor::map` whose
//! indivisible tail rides the injector queue). Every job writes its own
//! result slot, so the pipelined forward is **bit-identical** to
//! [`ProgrammedCnn::forward_seq`] for any replica count, worker count, or
//! steal schedule — pinned by `prop_pipelined_forward_equals_seq_across_replicas_and_workers` in
//! `rust/tests/properties.rs`.
//!
//! Scratch follows [`crate::mapping::StagePolicy::pooled_scratch`] (the
//! per-worker scratch pooling left open by PR 4): one
//! [`ForwardScratch`] per replica lives in a [`ScratchPool`], handed to
//! whichever job runs on that replica this wave — race-free because a
//! replica executes at most one stage per wave, and pure because scratch
//! reuse is observationally pure (property-pinned since PR 4).
//!
//! The pool behind the scheduler is the [`StagePool`] trait, not a
//! concrete engine: `[ProgrammedCnn]` implements it for the golden
//! engine, and a PJRT-backed (or mixed) pool can implement it later
//! without touching the scheduler — the same seam
//! [`crate::net::Engine`] cut for the wire layer.

use std::sync::{Mutex, RwLock};

use crate::mapping::{StageMap, StagePolicy, StageRole};
use crate::sched::Executor;
use crate::xbar::cnn::{ForwardScratch, ProgrammedCnn, StageData, Tensor};
use crate::xbar::Matrix;

/// A pool of installed serving replicas, each able to execute any single
/// pipeline stage. The seam between the wavefront scheduler and the
/// compute backend: the golden engine implements it for `[ProgrammedCnn]`;
/// a PJRT or heterogeneous pool slots in later without touching the
/// scheduler (mirroring [`crate::net::Engine`] one layer down).
pub trait StagePool: Sync {
    /// Installed replicas the scheduler may map stages onto.
    fn n_replicas(&self) -> usize;
    /// Pipeline stages per image (conv stages + classifier tail).
    fn n_stages(&self) -> usize;
    /// Role of stage `s` — [`build_map`] derives the conv/classifier
    /// split the [`StageMap`] sharing constraints apply to from these.
    fn stage_role(&self, s: usize) -> StageRole;
    /// Execute stage `s` on replica `replica`. Must be deterministic and
    /// callable concurrently for distinct replicas.
    fn run_stage(
        &self,
        replica: usize,
        s: usize,
        input: &StageData,
        scratch: &mut ForwardScratch,
    ) -> StageData;
}

/// A homogeneous golden-engine pool: every element is an install of the
/// same weights and ADC config, so any replica may run any stage with
/// bit-identical results.
impl StagePool for [ProgrammedCnn] {
    fn n_replicas(&self) -> usize {
        self.len()
    }

    fn n_stages(&self) -> usize {
        self[0].n_stages()
    }

    fn stage_role(&self, s: usize) -> StageRole {
        if s < self[0].n_conv_stages() {
            StageRole::Conv
        } else {
            StageRole::Classifier
        }
    }

    fn run_stage(
        &self,
        replica: usize,
        s: usize,
        input: &StageData,
        scratch: &mut ForwardScratch,
    ) -> StageData {
        self[replica].run_stage(s, input, scratch)
    }
}

/// The fault-tolerant pool: replicas live behind [`RwLock`]s so a
/// reinstall ("reprogram the crossbar",
/// [`crate::coordinator::GoldenServer::reinstall`]) can swap one out
/// under a write lock while serving holds read locks. Wave jobs take the
/// read lock per stage execution — uncontended in steady state, and a
/// reinstall simply waits for the in-flight stage on that replica to
/// finish before swapping.
impl StagePool for [RwLock<ProgrammedCnn>] {
    fn n_replicas(&self) -> usize {
        self.len()
    }

    fn n_stages(&self) -> usize {
        self[0].read().unwrap().n_stages()
    }

    fn stage_role(&self, s: usize) -> StageRole {
        if s < self[0].read().unwrap().n_conv_stages() {
            StageRole::Conv
        } else {
            StageRole::Classifier
        }
    }

    fn run_stage(
        &self,
        replica: usize,
        s: usize,
        input: &StageData,
        scratch: &mut ForwardScratch,
    ) -> StageData {
        self[replica].read().unwrap().run_stage(s, input, scratch)
    }
}

/// Per-replica forward-scratch pooling
/// ([`crate::mapping::StagePolicy::pooled_scratch`]). A replica runs at most one
/// stage per wave, so one scratch per replica suffices; the mutex is
/// uncontended in steady state and only guards against a misbehaving
/// [`StagePool`] mapping two concurrent jobs to one replica.
pub struct ScratchPool {
    slots: Option<Vec<Mutex<ForwardScratch>>>,
    /// Hardware cost spilled out of the scratches: [`Self::with`] drains
    /// each scratch's accrued ledger here after every job, so per-forward
    /// attribution survives both pooled reuse (no cross-batch residue)
    /// and the fresh-scratch drop when pooling is off.
    spill: Mutex<crate::obs::CostLedger>,
}

impl ScratchPool {
    /// `pooled = false` disables reuse: every job allocates a fresh
    /// scratch (the measurable baseline for the pooling win).
    pub fn new(n_replicas: usize, pooled: bool) -> Self {
        ScratchPool {
            slots: pooled.then(|| {
                (0..n_replicas)
                    .map(|_| Mutex::new(ForwardScratch::new()))
                    .collect()
            }),
            spill: Mutex::new(crate::obs::CostLedger::new()),
        }
    }

    /// Run `f` with replica `r`'s pooled scratch (or a fresh one when
    /// pooling is off).
    pub fn with<T>(&self, r: usize, f: impl FnOnce(&mut ForwardScratch) -> T) -> T {
        let (out, ledger) = match &self.slots {
            Some(slots) => {
                let mut scr = slots[r].lock().unwrap();
                let out = f(&mut scr);
                (out, scr.take_ledger())
            }
            None => {
                let mut scr = ForwardScratch::new();
                let out = f(&mut scr);
                (out, scr.take_ledger())
            }
        };
        if !ledger.is_empty() {
            self.spill.lock().unwrap().merge(&ledger);
        }
        out
    }

    /// Drain everything [`Self::with`] spilled since the last drain — the
    /// per-forward capture point of the pipelined path.
    pub fn drain_ledger(&self) -> crate::obs::CostLedger {
        std::mem::take(&mut *self.spill.lock().unwrap())
    }
}

/// Build the stage → replica map for `pool` under `policy`, deriving the
/// conv/classifier split from the pool's [`StagePool::stage_role`]s. The
/// wavefront scheduler assumes the stage chain is convs followed by one
/// classifier tail (the only shape [`ProgrammedCnn`] produces); pools
/// with any other role layout are rejected here, before anything runs.
pub fn build_map<P: StagePool + ?Sized>(
    pool: &P,
    policy: StagePolicy,
) -> Result<StageMap, String> {
    let n_stages = pool.n_stages();
    let n_conv = (0..n_stages)
        .filter(|&s| pool.stage_role(s) == StageRole::Conv)
        .count();
    if n_conv + 1 != n_stages || pool.stage_role(n_stages - 1) != StageRole::Classifier {
        return Err(
            "stage pool must be conv stages followed by one classifier tail".to_string(),
        );
    }
    StageMap::build(n_conv, pool.n_replicas(), policy)
}

/// Pipelined staged forward over a replica pool: images of `img` flow
/// through the stage pipeline wavefront-style (stage `s` of image `k+1`
/// concurrent with stage `s+1` of image `k` on distinct replicas, as
/// scheduled by `map`). Returns the `(B, classes)` logits matrix,
/// bit-identical to [`ProgrammedCnn::forward_seq`] on the whole batch.
pub fn forward_pipelined<P: StagePool + ?Sized>(
    pool: &P,
    map: &StageMap,
    img: &Tensor,
    exec: &Executor,
) -> Matrix {
    forward_pipelined_ledgered(pool, map, img, exec).0
}

/// [`forward_pipelined`] returning the batch's hardware cost ledger
/// alongside the logits: every wave job's cost is spilled out of the
/// [`ScratchPool`] and drained once the wavefront completes. The ledger
/// is empty unless `obs::ledger` is enabled; the logits are bit-identical
/// to [`forward_pipelined`] either way.
pub fn forward_pipelined_ledgered<P: StagePool + ?Sized>(
    pool: &P,
    map: &StageMap,
    img: &Tensor,
    exec: &Executor,
) -> (Matrix, crate::obs::CostLedger) {
    let n_stages = pool.n_stages();
    assert_eq!(
        map.assignment.len(),
        n_stages,
        "stage map was built for a different pipeline depth"
    );
    assert!(
        map.n_replicas <= pool.n_replicas(),
        "stage map wants {} replicas, pool has {}",
        map.n_replicas,
        pool.n_replicas()
    );
    assert!(img.b > 0, "empty batch");

    // per-image in-flight activation; slot k is taken for the duration of
    // image k's wave cell and restored with the stage output
    let mut state: Vec<Option<StageData>> = (0..img.b)
        .map(|k| Some(StageData::Act(img.image(k))))
        .collect();
    let scratch = ScratchPool::new(pool.n_replicas(), map.policy.pooled_scratch);

    for wave in 0..(img.b + n_stages - 1) {
        // ready cells on this anti-diagonal (k + s == wave), grouped by
        // replica: same-replica cells serialise inside one job, distinct
        // replicas overlap across jobs
        let mut groups: Vec<(usize, Vec<(usize, usize)>)> = Vec::new();
        for s in 0..n_stages {
            let Some(k) = wave.checked_sub(s) else { break };
            if k >= img.b {
                continue;
            }
            let r = map.replica_of(s);
            match groups.iter_mut().find(|(gr, _)| *gr == r) {
                Some((_, cells)) => cells.push((k, s)),
                None => groups.push((r, vec![(k, s)])),
            }
        }
        let inputs: Vec<Vec<(usize, usize, StageData)>> = groups
            .iter()
            .map(|(_, cells)| {
                cells
                    .iter()
                    .map(|&(k, s)| (k, s, state[k].take().expect("stage input ready")))
                    .collect()
            })
            .collect();
        let outs = exec.map(groups.len(), |g| {
            let r = groups[g].0;
            scratch.with(r, |scr| {
                inputs[g]
                    .iter()
                    .map(|(k, s, data)| {
                        // one span per wavefront cell: (image k, stage s)
                        // on replica r — the trace-completeness contract
                        // (tests/properties.rs, verify.sh) keys on these
                        // exact name/arg labels
                        let _sp = crate::obs::span("cell", "pipeline")
                            .arg("k", *k as u64)
                            .arg("s", *s as u64)
                            .arg("replica", r as u64);
                        (*k, pool.run_stage(r, *s, data, scr))
                    })
                    .collect::<Vec<(usize, StageData)>>()
            })
        });
        for group in outs {
            for (k, data) in group {
                state[k] = Some(data);
            }
        }
    }

    // reassemble the (B, classes) logits in image order
    let mut rows: Vec<Matrix> = Vec::with_capacity(img.b);
    for slot in state {
        let logits = slot.expect("image completed the pipeline").logits();
        debug_assert_eq!(logits.rows, 1, "per-image stage chain widened its batch");
        rows.push(logits);
    }
    let cols = rows[0].cols;
    let mut out = Matrix::zeros(img.b, cols);
    for (k, row) in rows.into_iter().enumerate() {
        out.data[k * cols..(k + 1) * cols].copy_from_slice(&row.data);
    }
    (out, scratch.drain_ledger())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::StagePolicy;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Cheap synthetic pool over 1x1x1 "images": stage `s` on replica `r`
    /// appends digit `s + 1` to the running value (base 10), so the final
    /// "logits" encode the exact stage order each image saw; the last
    /// stage emits logits. Also asserts no replica ever runs two cells
    /// concurrently.
    struct TracePool {
        n_replicas: usize,
        n_stages: usize,
        active: Vec<AtomicUsize>,
        max_overlap: AtomicUsize,
    }

    impl TracePool {
        fn new(n_replicas: usize, n_stages: usize) -> Self {
            TracePool {
                n_replicas,
                n_stages,
                active: (0..n_replicas).map(|_| AtomicUsize::new(0)).collect(),
                max_overlap: AtomicUsize::new(0),
            }
        }
    }

    impl StagePool for TracePool {
        fn n_replicas(&self) -> usize {
            self.n_replicas
        }

        fn n_stages(&self) -> usize {
            self.n_stages
        }

        fn stage_role(&self, s: usize) -> StageRole {
            if s + 1 < self.n_stages {
                StageRole::Conv
            } else {
                StageRole::Classifier
            }
        }

        fn run_stage(
            &self,
            replica: usize,
            s: usize,
            input: &StageData,
            _scratch: &mut ForwardScratch,
        ) -> StageData {
            let before = self.active[replica].fetch_add(1, Ordering::SeqCst);
            assert_eq!(before, 0, "replica {replica} ran two stages concurrently");
            // count replicas busy right now, across the pool
            let busy = self
                .active
                .iter()
                .map(|a| a.load(Ordering::SeqCst))
                .sum::<usize>();
            self.max_overlap.fetch_max(busy, Ordering::SeqCst);
            // long enough that concurrent wave jobs reliably overlap even
            // when worker spawn is slow on a loaded CI box
            std::thread::sleep(std::time::Duration::from_millis(5));
            let StageData::Act(t) = input else {
                panic!("stage {s}: want activation");
            };
            let v = t.at(0, 0, 0, 0) * 10 + (s as i64 + 1);
            self.active[replica].fetch_sub(1, Ordering::SeqCst);
            if s + 1 == self.n_stages {
                StageData::Logits(Matrix::from_fn(1, 1, |_, _| v))
            } else {
                let mut out = Tensor::zeros(1, 1, 1, 1);
                out.set(0, 0, 0, 0, v);
                StageData::Act(out)
            }
        }
    }

    fn trace_images(b: usize) -> Tensor {
        let mut t = Tensor::zeros(b, 1, 1, 1);
        for k in 0..b {
            t.set(k, 0, 0, 0, (k + 1) as i64);
        }
        t
    }

    /// Image k's expected trace: seed k+1 with digits 1..=n_stages
    /// appended in order.
    fn want_trace(b: usize, n_stages: usize) -> Vec<i64> {
        (0..b)
            .map(|k| {
                let mut v = (k + 1) as i64;
                for s in 0..n_stages {
                    v = v * 10 + (s as i64 + 1);
                }
                v
            })
            .collect()
    }

    #[test]
    fn wavefront_runs_every_stage_in_order_for_every_image() {
        for (replicas, workers) in [(1, 1), (2, 2), (4, 2), (4, 8)] {
            let policy = if replicas == 1 {
                StagePolicy::unconstrained()
            } else {
                StagePolicy::newton()
            };
            let pool = TracePool::new(replicas, 4);
            // build_map derives the conv/classifier split from stage_role
            let map = build_map(&pool, policy).unwrap();
            assert_eq!(map, StageMap::build(3, replicas, policy).unwrap());
            let out = forward_pipelined(
                &pool,
                &map,
                &trace_images(5),
                &Executor::new(workers),
            );
            assert_eq!(out.rows, 5);
            assert_eq!(out.data, want_trace(5, 4), "r={replicas} w={workers}");
        }
    }

    #[test]
    fn distinct_replicas_actually_overlap() {
        // 4 stages on 4 replicas, plenty of images and workers: at some
        // wave at least two replicas must be busy simultaneously (the
        // stage sleep spans the wave's concurrent jobs)
        let pool = TracePool::new(4, 4);
        let map = StageMap::build(3, 4, StagePolicy::newton()).unwrap();
        let out = forward_pipelined(&pool, &map, &trace_images(8), &Executor::new(4));
        assert_eq!(out.data, want_trace(8, 4));
        assert!(
            pool.max_overlap.load(Ordering::SeqCst) >= 2,
            "no stage overlap observed on a 4-replica pool"
        );
    }

    #[test]
    fn single_worker_pipeline_is_equivalent_and_sequential() {
        let pool = TracePool::new(4, 4);
        let map = StageMap::build(3, 4, StagePolicy::newton()).unwrap();
        let out = forward_pipelined(&pool, &map, &trace_images(3), &Executor::new(1));
        assert_eq!(out.data, want_trace(3, 4));
        assert_eq!(pool.max_overlap.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn unpooled_scratch_matches_pooled() {
        let mut unpooled = StagePolicy::newton();
        unpooled.pooled_scratch = false;
        let pool = TracePool::new(2, 4);
        let map = StageMap::build(3, 2, unpooled).unwrap();
        let out = forward_pipelined(&pool, &map, &trace_images(4), &Executor::new(2));
        assert_eq!(out.data, want_trace(4, 4));
    }

    #[test]
    fn build_map_rejects_non_conv_classifier_layouts() {
        // a pool whose roles are not convs-then-classifier must be
        // refused before anything runs
        struct AllConv(TracePool);
        impl StagePool for AllConv {
            fn n_replicas(&self) -> usize {
                self.0.n_replicas()
            }
            fn n_stages(&self) -> usize {
                self.0.n_stages()
            }
            fn stage_role(&self, _s: usize) -> StageRole {
                StageRole::Conv
            }
            fn run_stage(
                &self,
                r: usize,
                s: usize,
                input: &StageData,
                scratch: &mut ForwardScratch,
            ) -> StageData {
                self.0.run_stage(r, s, input, scratch)
            }
        }
        let err = build_map(&AllConv(TracePool::new(2, 3)), StagePolicy::newton());
        assert!(err.is_err(), "all-conv pool accepted");
    }

    #[test]
    #[should_panic(expected = "different pipeline depth")]
    fn mismatched_stage_map_is_rejected() {
        let pool = TracePool::new(2, 3);
        let map = StageMap::build(3, 2, StagePolicy::newton()).unwrap(); // 4 stages
        forward_pipelined(&pool, &map, &trace_images(1), &Executor::new(1));
    }
}
