//! Replica health tracking for fault-tolerant serving.
//!
//! Newton's crossbars are analog: installed conductances drift, and a
//! replica can silently start returning wrong logits while still
//! answering quickly (arXiv:2109.01262's accuracy erosion). The golden
//! serving stack already *measures* this — every batch reports its
//! max-abs deviation vs the lossless golden install — and this module is
//! the policy that *acts* on the measurement:
//!
//! ```text
//!            bad batch                 bad streak /
//!            (err > threshold)         EWMA drift
//!  Healthy ────────────────▶ Suspect ─────────────▶ Quarantined
//!     ▲                        │                        │
//!     │  clean batch           │                        │ reinstall
//!     ◀────────────────────────┘                        │ ("reprogram
//!     ▲                                                 ▼  the xbar")
//!     └──────────────────────────────────────────── Probation
//!                    clean streak
//! ```
//!
//! * **Healthy → Suspect**: `suspect_after` consecutive bad batches
//!   (deviation strictly above `deviation_threshold`; a batch *exactly at*
//!   the threshold is healthy).
//! * **Suspect → Quarantined**: `quarantine_after` consecutive bad
//!   batches, or the per-replica EWMA drift score exceeding
//!   `ewma_quarantine`. Quarantined replicas leave the serving rotation
//!   ([`HealthMonitor::route`]) and the pipelined stage map is re-derived
//!   around them ([`crate::mapping::StageMap::build_over`]).
//! * **Quarantined → Probation**: only via reinstall
//!   ([`crate::coordinator::GoldenServer::reinstall`] reprograms the
//!   crossbar from pristine weights, then calls
//!   [`HealthMonitor::reinstalled`]).
//! * **Probation → Healthy**: `probation_clean` consecutive clean batches.
//!
//! When *every* replica is quarantined the server keeps serving on the
//! least-bad one (lowest EWMA) and flags the degradation in `Stats` —
//! graceful degradation down to one replica, never an outage.
//!
//! The monitor is pure bookkeeping behind one mutex: the serving engine
//! ([`crate::coordinator::GoldenServer`]) owns the re-run and reinstall
//! mechanics, this module owns only state and placement decisions — so
//! the state machine is unit-testable without a single forward pass.

use std::sync::Mutex;

/// Per-replica health state. Wire encoding (`Stats`): the `repr` value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum HealthState {
    Healthy = 0,
    Suspect = 1,
    Quarantined = 2,
    /// Reinstalled, serving again, not yet trusted as Healthy.
    Probation = 3,
}

impl HealthState {
    /// Stable wire byte for `Stats` snapshots.
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    /// Decode a wire byte (unknown values read as Quarantined — the
    /// conservative direction).
    pub fn from_u8(v: u8) -> HealthState {
        match v {
            0 => HealthState::Healthy,
            1 => HealthState::Suspect,
            3 => HealthState::Probation,
            _ => HealthState::Quarantined,
        }
    }

    /// Human label for stats printouts.
    pub fn label(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Suspect => "suspect",
            HealthState::Quarantined => "quarantined",
            HealthState::Probation => "probation",
        }
    }
}

/// Deviation policy driving the state machine. The defaults suit exact
/// serving configs, where any nonzero deviation is a fault; adaptive or
/// lossy ADC configs deviate legitimately, so raise
/// `deviation_threshold` above the config's expected deviation band
/// before enabling health there.
#[derive(Clone, Copy, Debug)]
pub struct HealthPolicy {
    /// A batch is *bad* when its max-abs deviation vs golden is strictly
    /// above this; a batch exactly at the threshold is healthy.
    pub deviation_threshold: i64,
    /// Consecutive bad batches before Healthy demotes to Suspect.
    pub suspect_after: u32,
    /// Consecutive bad batches before quarantine.
    pub quarantine_after: u32,
    /// EWMA smoothing factor for the per-replica drift score
    /// (`score = alpha * err + (1 - alpha) * score`).
    pub ewma_alpha: f64,
    /// Quarantine when the EWMA drift score exceeds this, regardless of
    /// the consecutive count (infinite by default: streaks decide).
    pub ewma_quarantine: f64,
    /// Consecutive clean batches before Probation promotes to Healthy.
    pub probation_clean: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            deviation_threshold: 0,
            suspect_after: 1,
            quarantine_after: 3,
            ewma_alpha: 0.25,
            ewma_quarantine: f64::INFINITY,
            probation_clean: 2,
        }
    }
}

/// One replica's bookkeeping.
#[derive(Clone, Debug)]
struct ReplicaHealth {
    state: HealthState,
    consecutive_bad: u32,
    clean_streak: u32,
    /// EWMA of per-batch max-abs deviation — the drift score.
    ewma: f64,
    observed: u64,
}

impl ReplicaHealth {
    fn new() -> Self {
        ReplicaHealth {
            state: HealthState::Healthy,
            consecutive_bad: 0,
            clean_streak: 0,
            ewma: 0.0,
            observed: 0,
        }
    }
}

/// Aggregate health counters a serving engine reports through `Stats`
/// (carried on the wire next to the per-replica request counts).
#[derive(Clone, Debug, PartialEq)]
pub struct HealthReport {
    /// Per-replica [`HealthState::as_u8`] bytes.
    pub states: Vec<u8>,
    /// Batches transparently re-run on another replica after a bad result.
    pub reruns: u64,
    /// Transitions *into* Quarantined (a replica re-quarantined after a
    /// failed reinstall counts again).
    pub quarantines: u64,
    /// Every replica is quarantined: serving continues on the least-bad
    /// one, results may deviate.
    pub degraded: bool,
}

/// Mark a serving-pool reshape — quarantine, reinstall, or cluster
/// re-shard. Bumps the `obs.rebaseline` counter the admin watchdog tick
/// watches; when it moves, the watchdog re-learns its drift baselines
/// ([`crate::obs::watchdog::Watchdog::rebaseline`]) and un-latches `degraded`, so a
/// recovered pool is judged against its own normal rather than the old
/// pool's.
pub fn rebaseline_marker() {
    crate::obs::counter("obs.rebaseline").inc();
}

struct MonitorInner {
    replicas: Vec<ReplicaHealth>,
    reruns: u64,
    quarantines: u64,
}

/// The replica health state machine (see module docs for the diagram).
/// Thread-safe: observations and placement queries take one short lock.
pub struct HealthMonitor {
    policy: HealthPolicy,
    inner: Mutex<MonitorInner>,
}

impl HealthMonitor {
    pub fn new(n_replicas: usize, policy: HealthPolicy) -> Self {
        assert!(n_replicas > 0);
        assert!(policy.quarantine_after >= 1);
        assert!(policy.suspect_after >= 1);
        assert!((0.0..=1.0).contains(&policy.ewma_alpha));
        HealthMonitor {
            policy,
            inner: Mutex::new(MonitorInner {
                replicas: (0..n_replicas).map(|_| ReplicaHealth::new()).collect(),
                reruns: 0,
                quarantines: 0,
            }),
        }
    }

    pub fn policy(&self) -> &HealthPolicy {
        &self.policy
    }

    pub fn n_replicas(&self) -> usize {
        self.inner.lock().unwrap().replicas.len()
    }

    /// Record one served batch's deviation for `replica` and run the
    /// state machine. Returns the replica's state after the observation.
    pub fn observe(&self, replica: usize, max_abs_err: i64) -> HealthState {
        let mut g = self.inner.lock().unwrap();
        let p = self.policy;
        let bad = max_abs_err > p.deviation_threshold;
        let h = &mut g.replicas[replica];
        h.observed += 1;
        h.ewma = p.ewma_alpha * max_abs_err as f64 + (1.0 - p.ewma_alpha) * h.ewma;
        let was = h.state;
        if bad {
            h.consecutive_bad += 1;
            h.clean_streak = 0;
            if h.state != HealthState::Quarantined
                && (h.consecutive_bad >= p.quarantine_after || h.ewma > p.ewma_quarantine)
            {
                h.state = HealthState::Quarantined;
            } else if matches!(h.state, HealthState::Healthy | HealthState::Probation)
                && h.consecutive_bad >= p.suspect_after
            {
                h.state = HealthState::Suspect;
            }
        } else {
            h.consecutive_bad = 0;
            h.clean_streak += 1;
            match h.state {
                HealthState::Suspect => h.state = HealthState::Healthy,
                HealthState::Probation if h.clean_streak >= p.probation_clean => {
                    h.state = HealthState::Healthy
                }
                _ => {}
            }
        }
        let now = h.state;
        if was != HealthState::Quarantined && now == HealthState::Quarantined {
            g.quarantines += 1;
            crate::obs::counter("health.quarantines").inc();
            crate::obs::event("quarantine", "health", &[("replica", replica as u64)]);
            // a quarantine reshapes the serving pool: whatever latency /
            // energy baseline the watchdog froze describes the old pool
            rebaseline_marker();
        }
        now
    }

    /// Current state of one replica.
    pub fn state(&self, replica: usize) -> HealthState {
        self.inner.lock().unwrap().replicas[replica].state
    }

    /// Replicas eligible for placement: everything not quarantined, in
    /// index order. Empty **never** — when all are quarantined, the
    /// least-bad one (lowest EWMA drift score, ties to the lowest index)
    /// is returned alone so serving degrades instead of stopping.
    pub fn usable(&self) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        Self::usable_of(&g.replicas)
    }

    fn usable_of(replicas: &[ReplicaHealth]) -> Vec<usize> {
        let up: Vec<usize> = (0..replicas.len())
            .filter(|&r| replicas[r].state != HealthState::Quarantined)
            .collect();
        if !up.is_empty() {
            return up;
        }
        let least_bad = (0..replicas.len())
            .min_by(|&a, &b| {
                replicas[a]
                    .ewma
                    .partial_cmp(&replicas[b].ewma)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .expect("monitor has at least one replica");
        vec![least_bad]
    }

    /// Replica for batch `index`: round-robin over [`Self::usable`], so
    /// with every replica healthy this is exactly `index % n_replicas` —
    /// the health-off placement, bit-compatible by construction.
    pub fn route(&self, index: usize) -> usize {
        let up = self.usable();
        up[index % up.len()]
    }

    /// A usable replica not in `exclude`, for re-running a bad batch.
    /// Falls back to any non-excluded replica (least-bad first) when all
    /// usable ones are excluded; `None` once every replica was tried.
    pub fn alternative(&self, exclude: &[usize], index: usize) -> Option<usize> {
        let g = self.inner.lock().unwrap();
        let up: Vec<usize> = Self::usable_of(&g.replicas)
            .into_iter()
            .filter(|r| !exclude.contains(r))
            .collect();
        if !up.is_empty() {
            return Some(up[index % up.len()]);
        }
        (0..g.replicas.len())
            .filter(|r| !exclude.contains(r))
            .min_by(|&a, &b| {
                g.replicas[a]
                    .ewma
                    .partial_cmp(&g.replicas[b].ewma)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
    }

    /// Count one transparent re-run of a bad batch on another replica.
    pub fn record_rerun(&self) {
        self.inner.lock().unwrap().reruns += 1;
    }

    /// The replica was reprogrammed from pristine weights: back to
    /// [`HealthState::Probation`] with fresh counters — it must earn
    /// Healthy through `probation_clean` clean batches.
    pub fn reinstalled(&self, replica: usize) {
        let mut g = self.inner.lock().unwrap();
        g.replicas[replica] = ReplicaHealth {
            state: HealthState::Probation,
            ..ReplicaHealth::new()
        };
        drop(g);
        rebaseline_marker();
    }

    /// Snapshot for `Stats`.
    pub fn report(&self) -> HealthReport {
        let g = self.inner.lock().unwrap();
        HealthReport {
            states: g.replicas.iter().map(|h| h.state.as_u8()).collect(),
            reruns: g.reruns,
            quarantines: g.quarantines,
            degraded: g
                .replicas
                .iter()
                .all(|h| h.state == HealthState::Quarantined),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deviation_exactly_at_threshold_is_healthy() {
        let m = HealthMonitor::new(
            2,
            HealthPolicy {
                deviation_threshold: 5,
                ..HealthPolicy::default()
            },
        );
        for _ in 0..20 {
            assert_eq!(m.observe(0, 5), HealthState::Healthy);
        }
        // one unit over the line is bad
        assert_eq!(m.observe(0, 6), HealthState::Suspect);
    }

    #[test]
    fn consecutive_bad_batches_walk_healthy_suspect_quarantined() {
        let m = HealthMonitor::new(2, HealthPolicy::default());
        assert_eq!(m.observe(0, 10), HealthState::Suspect); // suspect_after = 1
        assert_eq!(m.observe(0, 10), HealthState::Suspect);
        assert_eq!(m.observe(0, 10), HealthState::Quarantined); // quarantine_after = 3
        // quarantine is sticky: further observations do not resurrect it
        assert_eq!(m.observe(0, 0), HealthState::Quarantined);
        assert_eq!(m.report().quarantines, 1);
    }

    #[test]
    fn clean_batch_resets_a_suspect() {
        let m = HealthMonitor::new(1, HealthPolicy::default());
        assert_eq!(m.observe(0, 3), HealthState::Suspect);
        assert_eq!(m.observe(0, 0), HealthState::Healthy);
        // the streak restarts: two more bads only reach Suspect again
        assert_eq!(m.observe(0, 3), HealthState::Suspect);
        assert_eq!(m.observe(0, 3), HealthState::Suspect);
    }

    #[test]
    fn ewma_drift_quarantines_without_a_full_streak() {
        let m = HealthMonitor::new(
            1,
            HealthPolicy {
                quarantine_after: 100, // streaks effectively off
                ewma_alpha: 0.5,
                ewma_quarantine: 6.0,
                ..HealthPolicy::default()
            },
        );
        // ewma after one batch of 16 at alpha 0.5 is 8 — past the 6.0
        // line immediately, no 100-batch streak needed
        assert_eq!(m.observe(0, 16), HealthState::Quarantined);
    }

    #[test]
    fn routing_skips_quarantined_replicas_and_matches_modulo_when_healthy() {
        let m = HealthMonitor::new(3, HealthPolicy::default());
        for i in 0..6 {
            assert_eq!(m.route(i), i % 3, "healthy routing must be index % n");
        }
        // quarantine replica 1
        for _ in 0..3 {
            m.observe(1, 9);
        }
        assert_eq!(m.state(1), HealthState::Quarantined);
        assert_eq!(m.usable(), vec![0, 2]);
        for i in 0..6 {
            assert_ne!(m.route(i), 1, "quarantined replica still routed");
        }
    }

    #[test]
    fn all_quarantined_serves_the_least_bad_and_reports_degraded() {
        let m = HealthMonitor::new(
            3,
            HealthPolicy {
                ewma_alpha: 1.0, // score = last err, for a readable test
                ..HealthPolicy::default()
            },
        );
        for (r, err) in [(0, 30), (1, 10), (2, 50)] {
            for _ in 0..3 {
                m.observe(r, err);
            }
        }
        let rep = m.report();
        assert!(rep.degraded);
        assert_eq!(rep.states, vec![2, 2, 2]);
        assert_eq!(rep.quarantines, 3);
        // least-bad EWMA is replica 1
        assert_eq!(m.usable(), vec![1]);
        for i in 0..4 {
            assert_eq!(m.route(i), 1);
        }
    }

    #[test]
    fn alternative_excludes_the_failing_replica() {
        let m = HealthMonitor::new(3, HealthPolicy::default());
        let alt = m.alternative(&[0], 0).unwrap();
        assert_ne!(alt, 0);
        // everything tried -> no alternative left
        assert_eq!(m.alternative(&[0, 1, 2], 0), None);
        // all usable excluded but one replica untried: least-bad fallback
        for _ in 0..3 {
            m.observe(2, 9);
        }
        assert_eq!(m.alternative(&[0, 1], 0), Some(2));
    }

    #[test]
    fn reinstall_restores_probation_then_healthy_after_a_clean_streak() {
        let m = HealthMonitor::new(2, HealthPolicy::default());
        for _ in 0..3 {
            m.observe(0, 7);
        }
        assert_eq!(m.state(0), HealthState::Quarantined);
        m.reinstalled(0);
        assert_eq!(m.state(0), HealthState::Probation);
        assert!(m.usable().contains(&0), "probation serves again");
        // probation_clean = 2 clean batches to earn Healthy
        assert_eq!(m.observe(0, 0), HealthState::Probation);
        assert_eq!(m.observe(0, 0), HealthState::Healthy);
    }

    #[test]
    fn failed_reinstall_requarantines_and_counts_again() {
        let m = HealthMonitor::new(2, HealthPolicy::default());
        for _ in 0..3 {
            m.observe(0, 7);
        }
        m.reinstalled(0);
        // still drifted after "reprogramming": walks back to quarantine
        for _ in 0..3 {
            m.observe(0, 7);
        }
        assert_eq!(m.state(0), HealthState::Quarantined);
        assert_eq!(m.report().quarantines, 2);
    }

    #[test]
    fn report_counts_reruns() {
        let m = HealthMonitor::new(2, HealthPolicy::default());
        m.record_rerun();
        m.record_rerun();
        let rep = m.report();
        assert_eq!(rep.reruns, 2);
        assert_eq!(rep.quarantines, 0);
        assert!(!rep.degraded);
        assert_eq!(rep.states, vec![0, 0]);
    }

    #[test]
    fn quarantine_and_reinstall_bump_the_rebaseline_marker() {
        let m = HealthMonitor::new(2, HealthPolicy::default());
        let before = crate::obs::counter("obs.rebaseline").get();
        for _ in 0..3 {
            m.observe(0, 7);
        }
        assert_eq!(m.state(0), HealthState::Quarantined);
        // other tests quarantine replicas in parallel, so the global
        // counter can only be bounded from below
        assert!(
            crate::obs::counter("obs.rebaseline").get() >= before + 1,
            "entering quarantine must tell the watchdog to re-learn"
        );
        let mid = crate::obs::counter("obs.rebaseline").get();
        m.reinstalled(0);
        assert!(crate::obs::counter("obs.rebaseline").get() >= mid + 1);
    }

    #[test]
    fn state_bytes_roundtrip() {
        for s in [
            HealthState::Healthy,
            HealthState::Suspect,
            HealthState::Quarantined,
            HealthState::Probation,
        ] {
            assert_eq!(HealthState::from_u8(s.as_u8()), s);
            assert!(!s.label().is_empty());
        }
        assert_eq!(HealthState::from_u8(200), HealthState::Quarantined);
    }
}
