//! Pipeline inference server: leader + one worker thread per stage.
//!
//! Topology (mirrors the chip's inter-tile pipeline):
//!
//! ```text
//!   clients -> leader (router + batcher)
//!          -> stage0 thread -> stage1 -> stage2 -> stage3 (threads)
//!          -> completion router -> per-request response channels
//! ```
//!
//! Each stage thread owns its *own* PJRT client and compiled artifact (PJRT
//! handles are not Send; per-stage clients also model per-tile-group
//! hardware). Activations move between stages as host `Vec<i32>` — the
//! software analogue of neuron values crossing the tile mesh.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::batcher::{Batcher, PendingRequest};
use crate::runtime::Runtime;
use crate::util::median;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    /// Stage artifact names, in pipeline order.
    pub stages: Vec<String>,
    /// Batch capacity (must match the stage artifacts' leading dim).
    pub batch: usize,
    /// Elements per input image.
    pub image_elems: usize,
    /// Batch-close deadline.
    pub max_wait: Duration,
}

impl ServerConfig {
    /// The newton-mini 4-stage pipeline at batch 8.
    pub fn newton_mini(artifacts_dir: PathBuf) -> Self {
        ServerConfig {
            artifacts_dir,
            stages: (0..4).map(|s| format!("stage{s}_b8")).collect(),
            batch: 8,
            image_elems: 32 * 32 * 3,
            max_wait: Duration::from_millis(20),
        }
    }
}

/// One completed inference.
#[derive(Clone, Debug)]
pub struct InferenceResult {
    pub id: u64,
    pub logits: Vec<i32>,
    pub latency: Duration,
}

struct StageBatch {
    ids: Vec<u64>,
    enqueued: Vec<Instant>,
    n_real: usize,
    data: Vec<i32>,
}

/// Aggregate serving statistics.
#[derive(Clone, Debug)]
pub struct ServerReport {
    pub completed: usize,
    pub batches: usize,
    pub wall: Duration,
    pub throughput_rps: f64,
    pub latency_p50_ms: f64,
    pub latency_max_ms: f64,
    /// Mean per-batch pipeline occupancy (real images / capacity).
    pub batch_fill: f64,
}

/// The running server: request sender + worker handles.
pub struct PipelineServer {
    req_tx: Option<Sender<PendingRequest>>,
    res_rx: Receiver<InferenceResult>,
    handles: Vec<JoinHandle<Result<()>>>,
    batch: usize,
    next_id: u64,
    batches_submitted: usize,
}

impl PipelineServer {
    /// Spawn the leader + stage threads. Fails fast if any stage artifact
    /// is missing or does not compile.
    pub fn start(cfg: ServerConfig) -> Result<PipelineServer> {
        // Pre-flight on the main thread for crisp errors.
        {
            let rt = Runtime::new(&cfg.artifacts_dir)?;
            for s in &cfg.stages {
                rt.manifest.artifact(s)?;
            }
        }

        let (req_tx, req_rx) = channel::<PendingRequest>();
        let mut handles = Vec::new();

        // stage channels: leader -> s0 -> s1 -> ... -> completion
        let mut stage_rx: Receiver<StageBatch>;
        let (leader_out, first_rx) = channel::<StageBatch>();
        stage_rx = first_rx;

        // Leader: batcher loop.
        let leader_cfg = cfg.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut batcher = Batcher::new(
                leader_cfg.batch,
                leader_cfg.image_elems,
                leader_cfg.max_wait,
            );
            loop {
                // Block for the first request, then drain greedily.
                match req_rx.recv_timeout(Duration::from_millis(5)) {
                    Ok(r) => batcher.push(r),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        // flush and exit
                        while let Some(b) = batcher.take_batch() {
                            let _ = leader_out.send(StageBatch {
                                ids: b.ids,
                                enqueued: b.enqueued,
                                n_real: b.n_real,
                                data: b.data,
                            });
                        }
                        return Ok(());
                    }
                }
                while let Ok(r) = req_rx.try_recv() {
                    batcher.push(r);
                }
                while batcher.ready(Instant::now()) {
                    if let Some(b) = batcher.take_batch() {
                        leader_out
                            .send(StageBatch {
                                ids: b.ids,
                                enqueued: b.enqueued,
                                n_real: b.n_real,
                                data: b.data,
                            })
                            .map_err(|_| anyhow!("pipeline closed"))?;
                    }
                }
            }
        }));

        // Stage threads.
        for stage_name in cfg.stages.clone() {
            let (tx, rx_next) = channel::<StageBatch>();
            let dir = cfg.artifacts_dir.clone();
            let rx = stage_rx;
            stage_rx = rx_next;
            handles.push(std::thread::spawn(move || -> Result<()> {
                let mut rt =
                    Runtime::new(&dir).with_context(|| format!("stage {stage_name}: runtime"))?;
                rt.compile(&stage_name)?;
                for mut batch in rx.iter() {
                    batch.data = rt.run(&stage_name, &batch.data)?;
                    if tx.send(batch).is_err() {
                        break; // downstream closed
                    }
                }
                Ok(())
            }));
        }

        // Completion router: split batch outputs back into per-request
        // results.
        let (res_tx, res_rx) = channel::<InferenceResult>();
        let batch_cap = cfg.batch;
        let final_rx = stage_rx;
        handles.push(std::thread::spawn(move || -> Result<()> {
            for batch in final_rx.iter() {
                let per = batch.data.len() / batch_cap;
                for (i, id) in batch.ids.iter().enumerate().take(batch.n_real) {
                    let logits = batch.data[i * per..(i + 1) * per].to_vec();
                    let latency = batch.enqueued[i].elapsed();
                    if res_tx
                        .send(InferenceResult {
                            id: *id,
                            logits,
                            latency,
                        })
                        .is_err()
                    {
                        return Ok(());
                    }
                }
            }
            Ok(())
        }));

        Ok(PipelineServer {
            req_tx: Some(req_tx),
            res_rx,
            handles,
            batch: cfg.batch,
            next_id: 0,
            batches_submitted: 0,
        })
    }

    /// Submit one image; returns its request id.
    pub fn submit(&mut self, image: Vec<i32>) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        if id as usize % self.batch == 0 {
            self.batches_submitted += 1;
        }
        self.req_tx
            .as_ref()
            .ok_or_else(|| anyhow!("server draining"))?
            .send(PendingRequest {
                id,
                image,
                enqueued: Instant::now(),
            })
            .map_err(|_| anyhow!("pipeline closed"))?;
        Ok(id)
    }

    /// Collect `n` results (blocking).
    pub fn collect(&self, n: usize) -> Result<Vec<InferenceResult>> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            out.push(
                self.res_rx
                    .recv_timeout(Duration::from_secs(120))
                    .map_err(|e| anyhow!("waiting for results: {e:?}"))?,
            );
        }
        Ok(out)
    }

    /// Stop accepting requests, drain workers, and summarise.
    pub fn shutdown(mut self, results: &[InferenceResult], wall: Duration) -> ServerReport {
        self.req_tx.take(); // closes the leader
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let lat_ms: Vec<f64> = results
            .iter()
            .map(|r| r.latency.as_secs_f64() * 1e3)
            .collect();
        let completed = results.len();
        let batches = completed.div_ceil(self.batch);
        ServerReport {
            completed,
            batches,
            wall,
            throughput_rps: completed as f64 / wall.as_secs_f64(),
            latency_p50_ms: if lat_ms.is_empty() { 0.0 } else { median(&lat_ms) },
            latency_max_ms: lat_ms.iter().cloned().fold(0.0, f64::max),
            batch_fill: completed as f64 / (batches * self.batch).max(1) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // End-to-end server tests live in rust/tests/serving.rs (they need the
    // artifacts). Here: config shape only.
    #[test]
    fn newton_mini_config() {
        let c = ServerConfig::newton_mini(PathBuf::from("artifacts"));
        assert_eq!(c.stages.len(), 4);
        assert_eq!(c.batch, 8);
        assert_eq!(c.image_elems, 3072);
    }
}
