//! Discrete-event validation of the analytic pipeline model (§IV).
//!
//! The paper asserts that "analytical estimates are enough to capture the
//! behavior of cycle-accurate simulations" because the dataflow has no
//! run-time dependencies. This module *checks* that claim: it simulates the
//! inter-tile pipeline as a deterministic tandem queue — each mapped layer
//! is a stage whose per-image service time is `pixels / replication * vmm`,
//! plus a router transfer stage between layers — and compares steady-state
//! throughput/latency against `pipeline::evaluate`.

use crate::config::ChipConfig;
use crate::karatsuba::DncSchedule;
use crate::mapping::{Mapping, MappingPolicy};
use crate::workloads::Network;
use crate::xbar::{reference, Matrix, ProgrammedXbar};

/// DES result over `n_images` streamed back-to-back.
#[derive(Clone, Copy, Debug)]
pub struct DesReport {
    pub throughput: f64,
    pub latency_us: f64,
    pub n_stages: usize,
}

/// Simulate `n_images` through the mapped pipeline.
pub fn simulate(net: &Network, chip: &ChipConfig, n_images: usize) -> DesReport {
    assert!(n_images >= 2);
    let p = &chip.xbar;
    let policy = if chip.features.constrained_mapping {
        MappingPolicy::newton()
    } else {
        MappingPolicy::isaac()
    };
    let mapping = Mapping::build(net, &chip.conv_tile.ima, p, policy, chip.conv_tile.imas_per_tile);

    let kara_time = if chip.features.karatsuba > 0 {
        DncSchedule::new(chip.features.karatsuba, p).time_ratio(p)
    } else {
        1.0
    };
    let vmm_ns = p.vmm_ns() * kara_time;

    // per-stage service times, ns / image
    let routers = (mapping.conv_tiles() + mapping.fc_tiles())
        .div_ceil(chip.tiles_per_router)
        .max(1) as f64;
    let noc_bytes_per_ns = routers * chip.router_gbps / 8.0;
    let mut service: Vec<f64> = Vec::new();
    for a in &mapping.allocs {
        let pixels = a.layer.fires().max(1) as f64;
        service.push(pixels * vmm_ns / a.replication as f64);
        // transfer of this layer's outputs over the mesh
        service.push(a.traffic_bytes as f64 / noc_bytes_per_ns);
    }

    // deterministic tandem queue: done[s] = time stage s finishes its
    // current image
    let n_stages = service.len();
    let mut done = vec![0.0f64; n_stages];
    let mut first_out = 0.0;
    let mut last_out = 0.0;
    for img in 0..n_images {
        let mut t_prev = 0.0f64; // arrival into stage 0
        for (s, &svc) in service.iter().enumerate() {
            let start = t_prev.max(done[s]);
            let finish = start + svc;
            done[s] = finish;
            t_prev = finish;
        }
        if img == 0 {
            first_out = t_prev;
        }
        last_out = t_prev;
    }
    DesReport {
        throughput: (n_images - 1) as f64 / ((last_out - first_out) * 1e-9),
        latency_us: first_out * 1e-3,
        n_stages,
    }
}

/// Simulate every `(chip × net)` pair in parallel — the DES face of
/// `pipeline::evaluate_grid`: one work-stealing job per grid cell on the
/// `crate::sched` executor. Returns `out[chip][net]`.
pub fn simulate_grid(
    nets: &[Network],
    chips: &[ChipConfig],
    n_images: usize,
) -> Vec<Vec<DesReport>> {
    simulate_grid_on(
        nets,
        chips,
        n_images,
        &crate::sched::Executor::for_jobs(chips.len() * nets.len()),
    )
}

/// [`simulate_grid`] on a caller-sized executor (worker-count sweeps in
/// tests and benches).
pub fn simulate_grid_on(
    nets: &[Network],
    chips: &[ChipConfig],
    n_images: usize,
    exec: &crate::sched::Executor,
) -> Vec<Vec<DesReport>> {
    exec.grid(chips.len(), nets.len(), |ci, ni| {
        simulate(&nets[ni], &chips[ci], n_images)
    })
}

/// Functional spot-check behind the DES timing model: the per-VMM service
/// time charged above is `p.vmm_ns() = read_ns × iters`, so the crossbar
/// reads being timed must really behave like the installed engine.
/// Installs one representative crossbar, confirms its logical schedule
/// (`iters × slices` ADC samples) matches what the timing model charges,
/// and that a real read is bit-identical to the reference bit-serial
/// engine. Returns the number of 100 ns reads one VMM costs.
pub fn golden_read_probe(chip: &ChipConfig) -> usize {
    let p = &chip.xbar;
    let mut rng = crate::util::Rng::new(0xDE5);
    let x = Matrix::from_fn(1, p.rows, |_, _| rng.range_i64(0, 1 << p.input_bits));
    let w = Matrix::from_fn(p.rows, 4, |_, _| {
        rng.range_i64(-(1 << (p.weight_bits - 1)), 1 << (p.weight_bits - 1))
    });
    let programmed = ProgrammedXbar::install(&w, p, chip.features.adaptive_adc);
    assert_eq!(programmed.iters(), p.iters(), "timing model iters drifted");
    assert_eq!(programmed.slices(), p.slices(), "timing model slices drifted");
    assert_eq!(
        programmed.run(&x),
        reference::vmm_raw_reference(&x, &w, p, chip.features.adaptive_adc),
        "DES times crossbar reads that mismatch the golden engine"
    );
    programmed.iters()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::evaluate;
    use crate::workloads;

    #[test]
    fn des_matches_analytic_throughput() {
        // the §IV claim: deterministic dataflow -> analytics == simulation
        for net in [workloads::alexnet(), workloads::vgg_a(), workloads::resnet34()] {
            for chip in [ChipConfig::isaac(), ChipConfig::newton()] {
                let a = evaluate(&net, &chip);
                let d = simulate(&net, &chip, 50);
                let ratio = d.throughput / a.throughput;
                assert!(
                    (0.8..1.25).contains(&ratio),
                    "{}: DES {} vs analytic {} ({ratio})",
                    net.name,
                    d.throughput,
                    a.throughput
                );
            }
        }
    }

    #[test]
    fn des_latency_is_fill_time() {
        let net = workloads::vgg_a();
        let chip = ChipConfig::newton();
        let d = simulate(&net, &chip, 10);
        // latency must exceed the single slowest stage and be finite
        assert!(d.latency_us > 0.0 && d.latency_us.is_finite());
        assert!(d.n_stages >= net.layers.len());
    }

    #[test]
    fn simulate_grid_matches_pointwise() {
        let nets = [workloads::alexnet(), workloads::vgg_a()];
        let chips = [ChipConfig::isaac(), ChipConfig::newton()];
        let grid = simulate_grid(&nets, &chips, 20);
        assert_eq!(grid.len(), 2);
        for (ci, chip) in chips.iter().enumerate() {
            for (ni, net) in nets.iter().enumerate() {
                let want = simulate(net, chip, 20);
                assert_eq!(grid[ci][ni].throughput, want.throughput);
                assert_eq!(grid[ci][ni].latency_us, want.latency_us);
                assert_eq!(grid[ci][ni].n_stages, want.n_stages);
            }
        }
    }

    #[test]
    fn golden_probe_agrees_with_timing_model() {
        for chip in [ChipConfig::isaac(), ChipConfig::newton()] {
            assert_eq!(golden_read_probe(&chip), chip.xbar.iters());
        }
    }

    #[test]
    fn des_throughput_stable_in_n() {
        let net = workloads::alexnet();
        let chip = ChipConfig::newton();
        let d1 = simulate(&net, &chip, 20);
        let d2 = simulate(&net, &chip, 200);
        assert!(
            (d1.throughput / d2.throughput - 1.0).abs() < 0.02,
            "{} vs {}",
            d1.throughput,
            d2.throughput
        );
    }
}
