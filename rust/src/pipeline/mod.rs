//! Deterministic workload evaluation (paper §IV "Methodology").
//!
//! "Since there aren't any run-time dependencies on the control flow ... of
//! the deep networks, analytical estimates are enough to capture the
//! behavior of cycle-accurate simulations." This module is that analytic
//! model: given a network, a chip configuration and a mapping, it produces
//! throughput, latency, average power, energy per image and the
//! per-component energy breakdown — the quantities behind Figs 11-23.
//!
//! Timing: the intra-tile pipeline advances one crossbar read per 100 ns;
//! a full VMM takes `iters` reads (17 for Karatsuba k=1). Conv layers are
//! replicated so every layer produces its share of an image in the same
//! period; the layer with the fewest output pixels sets the period
//! (iso-throughput, like ISAAC). The router bandwidth bounds the period
//! from below (§IV: "we allocate enough resources till the network
//! saturates").

pub mod des;

use crate::adc::{AdaptiveSchedule, SarShares};
use crate::config::ChipConfig;
use crate::energy::constants as k;
use crate::energy::Component;
use crate::karatsuba::DncSchedule;
use crate::mapping::{Mapping, MappingPolicy};
use crate::strassen::{self, StrassenSchedule};
use crate::tiles::ChipPlan;
use crate::workloads::Network;

/// Evaluation result for one workload on one chip configuration.
#[derive(Clone, Debug)]
pub struct WorkloadReport {
    pub net: &'static str,
    /// Inference throughput, images/s.
    pub throughput: f64,
    /// Single-image latency, us.
    pub latency_us: f64,
    /// Peak power envelope (Fig 22), W.
    pub peak_power_w: f64,
    /// Average power while streaming, W.
    pub avg_power_w: f64,
    /// Energy per image, mJ.
    pub energy_per_image_mj: f64,
    /// Average energy per 16-bit op, pJ (Fig 23 / headline metric).
    pub energy_per_op_pj: f64,
    /// Chip area, mm² (tiles; HT excluded like Fig 20).
    pub area_mm2: f64,
    /// Delivered throughput per area, GOPS/mm².
    pub ce_eff: f64,
    /// Delivered throughput per watt, GOPS/W.
    pub pe_eff: f64,
    /// Dynamic-energy breakdown per image.
    pub energy_breakdown: Vec<(Component, f64)>,
    pub conv_tiles: usize,
    pub fc_tiles: usize,
}

/// Fraction of peak power burned while idle (clocking, leakage, refresh).
/// \[CAL\] keeps avg power between the dynamic floor and the peak envelope.
const IDLE_POWER_FRAC: f64 = 0.10;

/// Evaluate one network on one chip configuration.
pub fn evaluate(net: &Network, chip: &ChipConfig) -> WorkloadReport {
    let p = &chip.xbar;
    let policy = if chip.features.constrained_mapping {
        MappingPolicy::newton()
    } else {
        MappingPolicy::isaac()
    };
    let mapping = Mapping::build(
        net,
        &chip.conv_tile.ima,
        p,
        policy,
        chip.conv_tile.imas_per_tile,
    );
    let plan = ChipPlan::new(chip, &mapping);

    // ---- technique activity factors -------------------------------------
    let adc_scale = if chip.features.adaptive_adc {
        AdaptiveSchedule::new(p, p.input_bits, p.weight_bits).energy_scale(&SarShares::default())
    } else {
        1.0
    };
    let dnc = (chip.features.karatsuba > 0).then(|| DncSchedule::new(chip.features.karatsuba, p));
    let (kara_work, kara_time) = match &dnc {
        Some(d) => (d.adc_work_ratio(p), d.time_ratio(p)),
        None => (1.0, 1.0),
    };

    // ---- timing ----------------------------------------------------------
    let vmm_ns = p.vmm_ns() * kara_time;
    // pipeline period per image: the slowest mapped layer after
    // replication (FC tiles are off-path with fires = 1; recurrent layers
    // fire once per timestep and cannot be replicated)
    let period_fires = mapping
        .allocs
        .iter()
        .map(|a| a.layer.fires() as f64 / a.replication as f64)
        .fold(1.0, f64::max);
    let mut t_img_ns = period_fires * vmm_ns;
    // router bound: all inter-layer traffic must fit the mesh each period
    let routers = (plan.total_tiles().div_ceil(chip.tiles_per_router)).max(1) as f64;
    let noc_bytes_per_ns = routers * chip.router_gbps / 8.0; // GB/s -> B/ns
    let traffic = mapping.traffic_per_image() as f64;
    t_img_ns = t_img_ns.max(traffic / noc_bytes_per_ns);
    let throughput = 1e9 / t_img_ns;
    // latency: pipelined stages drain one period per mapped compute layer
    let n_stages = mapping.allocs.len() as f64;
    let latency_us = n_stages * t_img_ns * 1e-3;

    // ---- per-image dynamic energy ----------------------------------------
    let adc_pj_full = k::ADC_POWER_MW * 1e-3 / k::ADC_RATE_SPS * 1e12; // ~2.42
    let xbar_fire_pj = (k::XBAR_POWER_MW + k::DAC_ARRAY_POWER_MW + k::SH_POWER_MW)
        * 1e-3
        * k::CYCLE_NS; // one crossbar read incl. DAC + S&H
    let sa_pj_per_sample = 0.05; // [CAL] shift-and-add per digitised sample

    // Strassen: fraction of conv MACs on layers big enough to decompose
    let strassen_scale = if chip.features.strassen {
        let total: f64 = net.conv_layers().map(|l| l.macs() as f64).sum();
        let eligible: f64 = net
            .conv_layers()
            .filter(|l| {
                let (r, c) = l.matrix().unwrap();
                strassen::eligible(r, c, p)
            })
            .map(|l| l.macs() as f64)
            .sum();
        let s = StrassenSchedule::one_level();
        if total > 0.0 {
            1.0 - (eligible / total) * (1.0 - s.work_ratio)
        } else {
            1.0
        }
    } else {
        1.0
    };

    let mut adc_pj = 0.0f64;
    let mut xbar_pj = 0.0f64;
    let mut sa_pj = 0.0f64;
    let mut edram_pj = 0.0f64;
    for l in net.layers.iter() {
        let Some((rows, cols)) = l.matrix() else { continue };
        let outs = l.fires() as f64;
        let row_chunks = rows.div_ceil(p.rows) as f64;
        let col_xbars = (cols * p.slices()).div_ceil(p.cols) as f64;
        // one sample per column per iteration per row chunk
        let samples = outs * row_chunks * (cols * p.slices()) as f64 * p.iters() as f64;
        adc_pj += samples * adc_pj_full * adc_scale * kara_work * strassen_scale;
        sa_pj += samples * sa_pj_per_sample;
        // crossbar reads track the D&C work schedule, not the wall clock
        let fires = outs * row_chunks * col_xbars * p.iters() as f64 * kara_work;
        xbar_pj += fires * xbar_fire_pj * strassen_scale;
        // inputs broadcast across all columns: read once per output position
        // per row chunk; outputs written once
        let in_bytes = outs * rows as f64 * 2.0;
        let out_bytes = outs * cols as f64 * 2.0;
        edram_pj += (in_bytes + out_bytes) * k::EDRAM_PJ_PER_BYTE;
    }
    let noc_pj = traffic * k::NOC_PJ_PER_BYTE;

    let peak = plan.breakdown();
    let peak_power_w = peak.power_mw() / 1000.0;
    let idle_pj = peak_power_w * IDLE_POWER_FRAC * t_img_ns * 1e3; // W * ns -> pJ

    let dynamic_pj = adc_pj + xbar_pj + sa_pj + edram_pj + noc_pj;
    let total_pj = dynamic_pj + idle_pj;
    let energy_per_image_mj = total_pj * 1e-9;
    let avg_power_w = total_pj * 1e-12 / (t_img_ns * 1e-9);

    let total_ops = 2.0 * net.total_macs() as f64;
    let energy_per_op_pj = total_pj / total_ops;
    let gops_delivered = total_ops / t_img_ns; // ops/ns = GOPS
    let area = plan.area_mm2();

    WorkloadReport {
        net: net.name,
        throughput,
        latency_us,
        peak_power_w,
        avg_power_w,
        energy_per_image_mj,
        energy_per_op_pj,
        area_mm2: area,
        ce_eff: gops_delivered / area,
        pe_eff: gops_delivered / avg_power_w,
        energy_breakdown: vec![
            (Component::Adc, adc_pj),
            (Component::Xbar, xbar_pj),
            (Component::ShiftAdd, sa_pj),
            (Component::Edram, edram_pj),
            (Component::Router, noc_pj),
            (Component::Ctrl, idle_pj),
        ],
        conv_tiles: plan.conv_tiles,
        fc_tiles: plan.fc_tiles,
    }
}

/// Evaluate the full suite; returns one report per net. Runs the nets in
/// parallel across available cores (see [`evaluate_grid`]); `evaluate` is
/// pure, so the reports are identical to the sequential ones.
pub fn evaluate_suite(nets: &[Network], chip: &ChipConfig) -> Vec<WorkloadReport> {
    evaluate_grid(nets, std::slice::from_ref(chip))
        .pop()
        .unwrap_or_default()
}

/// Parallel sweep driver over the `(chip × net)` design grid — the inner
/// loop of every design-space exploration (Figs 10/15/17/18, the
/// incremental stack, CI sweeps). Returns `out[chip][net]`, row-major and
/// deterministic regardless of the worker count.
///
/// One work-stealing job per grid cell on the [`crate::sched`] executor:
/// skewed nets (a resnet34 cell costs ~10x an mlp-class cell) no longer
/// strand workers the way the old contiguous split did, so scaling stays
/// near-linear even on lopsided grids.
pub fn evaluate_grid(nets: &[Network], chips: &[ChipConfig]) -> Vec<Vec<WorkloadReport>> {
    evaluate_grid_on(
        nets,
        chips,
        &crate::sched::Executor::for_jobs(chips.len() * nets.len()),
    )
}

/// [`evaluate_grid`] on a caller-sized executor — the property tests pin
/// bit-identity to the sequential reference across worker counts, and the
/// perf bench contrasts stealing against the contiguous baseline.
pub fn evaluate_grid_on(
    nets: &[Network],
    chips: &[ChipConfig],
    exec: &crate::sched::Executor,
) -> Vec<Vec<WorkloadReport>> {
    exec.grid(chips.len(), nets.len(), |ci, ni| evaluate(&nets[ni], &chips[ci]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::geomean;
    use crate::workloads;

    #[test]
    fn isaac_energy_per_op_in_the_papers_ballpark() {
        // paper: "An average ISAAC operation consumes 1.8 pJ"
        let r = evaluate(&workloads::vgg_a(), &ChipConfig::isaac());
        assert!(
            (1.0..4.0).contains(&r.energy_per_op_pj),
            "{}",
            r.energy_per_op_pj
        );
    }

    #[test]
    fn newton_beats_isaac_on_energy_everywhere() {
        let nets = workloads::suite();
        for net in &nets {
            let i = evaluate(net, &ChipConfig::isaac());
            let n = evaluate(net, &ChipConfig::newton());
            assert!(
                n.energy_per_op_pj < i.energy_per_op_pj,
                "{}: {} !< {}",
                net.name,
                n.energy_per_op_pj,
                i.energy_per_op_pj
            );
        }
    }

    #[test]
    fn headline_ratios_have_the_right_shape() {
        // paper headline: -77% power, -51% energy, 2.2x throughput/area
        let nets = workloads::suite();
        let mut e_ratio = vec![];
        let mut p_ratio = vec![];
        let mut ta_ratio = vec![];
        for net in &nets {
            let i = evaluate(net, &ChipConfig::isaac());
            let n = evaluate(net, &ChipConfig::newton());
            e_ratio.push(i.energy_per_op_pj / n.energy_per_op_pj);
            p_ratio.push(n.peak_power_w / i.peak_power_w);
            ta_ratio.push(n.ce_eff / i.ce_eff);
        }
        let e = geomean(&e_ratio);
        let p = geomean(&p_ratio);
        let ta = geomean(&ta_ratio);
        // generous corridors: the shape must hold (energy roughly halves,
        // power drops by well over half, throughput/area about doubles)
        assert!(e > 1.5, "energy ratio {e}");
        assert!(p < 0.55, "power ratio {p}");
        assert!(ta > 1.5, "throughput/area ratio {ta}");
    }

    #[test]
    fn adc_dominates_isaac_dynamic_energy() {
        let r = evaluate(&workloads::vgg_b(), &ChipConfig::isaac());
        let adc = r
            .energy_breakdown
            .iter()
            .find(|(c, _)| *c == Component::Adc)
            .unwrap()
            .1;
        let total: f64 = r.energy_breakdown.iter().map(|(_, e)| e).sum();
        assert!(adc / total > 0.4, "{}", adc / total);
    }

    #[test]
    fn throughput_is_router_or_compute_bound() {
        let r = evaluate(&workloads::alexnet(), &ChipConfig::newton());
        assert!(r.throughput > 100.0, "{}", r.throughput);
        assert!(r.latency_us > 0.0);
    }

    #[test]
    fn resnet_gains_least_from_strassen() {
        let mut with = ChipConfig::newton();
        with.features.strassen = true;
        let mut without = with.clone();
        without.features.strassen = false;
        let gain = |net: &Network| {
            let w = evaluate(net, &with).energy_per_op_pj;
            let wo = evaluate(net, &without).energy_per_op_pj;
            wo / w
        };
        let g_res = gain(&workloads::resnet34());
        let g_msra = gain(&workloads::msra_c());
        assert!(g_msra >= g_res, "{g_msra} vs {g_res}");
    }

    #[test]
    fn evaluate_grid_matches_pointwise() {
        // parallel grid cells must be exactly the sequential evaluations
        let nets = workloads::suite();
        let chips = [ChipConfig::isaac(), ChipConfig::newton()];
        let grid = evaluate_grid(&nets[..3], &chips);
        assert_eq!(grid.len(), 2);
        for (ci, row) in grid.iter().enumerate() {
            assert_eq!(row.len(), 3);
            for (ni, report) in row.iter().enumerate() {
                let want = evaluate(&nets[ni], &chips[ci]);
                assert_eq!(report.net, want.net);
                assert_eq!(report.energy_per_op_pj, want.energy_per_op_pj);
                assert_eq!(report.throughput, want.throughput);
                assert_eq!(report.area_mm2, want.area_mm2);
            }
        }
    }

    #[test]
    fn evaluate_grid_handles_empty_axes() {
        let nets = workloads::suite();
        assert!(evaluate_grid(&nets, &[]).is_empty());
        let grid = evaluate_grid(&[], &[ChipConfig::newton()]);
        assert_eq!(grid.len(), 1);
        assert!(grid[0].is_empty());
    }

    #[test]
    fn suite_evaluation_is_fast_and_total() {
        let nets = workloads::suite();
        let reports = evaluate_suite(&nets, &ChipConfig::newton());
        assert_eq!(reports.len(), 9);
        for r in &reports {
            assert!(r.energy_per_op_pj.is_finite() && r.energy_per_op_pj > 0.0);
            assert!(r.area_mm2 > 0.0 && r.peak_power_w > 0.0);
        }
    }
}
