//! Minimal property-testing harness (proptest is unavailable offline —
//! ARCHITECTURE.md §Substitutions). Runs a property over N seeded random cases
//! and reports the first failing seed so failures reproduce exactly.

use crate::util::Rng;

/// Run `prop` over `cases` deterministic RNG streams. Panics with the
/// failing case index + seed on the first failure.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 10, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property 'boom'")]
    fn failing_property_panics_with_seed() {
        check("boom", 5, |rng| {
            let v = rng.below(100);
            if v < 1000 {
                Err(format!("v={v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn failures_are_reproducible() {
        // same seed stream across invocations
        let mut first = Vec::new();
        check("collect", 3, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("collect", 3, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
