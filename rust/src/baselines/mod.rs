//! Digital baselines: the ideal neuron, DaDianNao, Eyeriss and TPU-1
//! (paper §I energy ladder and Fig 24). Analytic only — nothing here is
//! on the serve path; the figures cite it as the comparison ladder.
//!
//! The first three are energy-per-operation models built from the same
//! component constants as the main model (paper §I: ideal 0.33 pJ,
//! DaDianNao 3.5 pJ, Eyeriss 1.67 pJ, ISAAC 1.8 pJ, Newton 0.85 pJ).
//! TPU-1 is a roofline model with the paper's batching rule: batch as large
//! as the 7 ms latency target allows; FC weights stream from GDDR5 once per
//! batch, which is what makes small-batch workloads (MSRA-C) memory-bound.

use crate::workloads::{Layer, Network};

/// Energy ladder entry, pJ per 16-bit op.
#[derive(Clone, Copy, Debug)]
pub struct EnergyPerOp {
    pub name: &'static str,
    pub pj_per_op: f64,
}

/// Ideal neuron (§I): weight in place next to a digital ALU, input from an
/// adjacent single-row eDRAM, result to another adjacent row.
/// ALU op ~0.2 pJ + two eDRAM row touches ~0.065 pJ each (2 B at the
/// per-byte constant) -> ~0.33 pJ.
pub fn ideal_neuron() -> EnergyPerOp {
    let alu = 0.20;
    let edram = 2.0 * 2.0 * crate::energy::constants::EDRAM_PJ_PER_BYTE / 20.0;
    EnergyPerOp {
        name: "ideal",
        pj_per_op: alu + edram, // ~0.33
    }
}

/// DaDianNao: pays eDRAM fetch for weights + on-chip wire movement for
/// inputs/outputs on top of the NFU op (paper: ~3.5 pJ/op).
pub fn dadiannao() -> EnergyPerOp {
    let nfu = 0.25;
    let weight_fetch = 2.0 * 0.65; // 2 B/op from big central eDRAM banks
    let movement = 1.95; // HTree/fat-tree hop energy to/from the NFU
    EnergyPerOp {
        name: "dadiannao",
        pj_per_op: nfu + weight_fetch + movement,
    }
}

/// Eyeriss: row-stationary dataflow maximises reuse, cutting the movement
/// term roughly in half (paper: ~1.67 pJ/op).
pub fn eyeriss() -> EnergyPerOp {
    let pe = 0.30;
    let spad = 0.55; // local scratchpad traffic
    let noc = 0.82; // reduced global movement thanks to reuse
    EnergyPerOp {
        name: "eyeriss",
        pj_per_op: pe + spad + noc,
    }
}

/// DaDianNao peak computational efficiency (GOPS/mm²) for Fig 20's left
/// edge: eDRAM-dominated area, NFU-limited throughput.
pub fn dadiannao_ce_pe() -> (f64, f64) {
    // 5.58 TOPS per 16-chip node, ~68 mm² per chip at 28 nm; per-chip:
    // ~349 GOPS / 68 mm² ~ 63 GOPS/mm²; PE ~ 286 GOPS/W (published).
    (63.0, 286.0)
}

// ---------------------------------------------------------------------------
// TPU-1 roofline (Fig 24)
// ---------------------------------------------------------------------------

/// TPU-1 analytic model parameters.
#[derive(Clone, Copy, Debug)]
pub struct TpuModel {
    /// Peak 8-bit MAC throughput, ops/s (2 ops per MAC).
    pub peak_ops: f64,
    /// Weight-memory bandwidth, bytes/s (paper models GDDR5).
    pub mem_bw: f64,
    /// On-chip unified buffer + accumulators, bytes.
    pub sram_bytes: f64,
    /// Latency target that caps the batch size, s.
    pub latency_target: f64,
    /// Die area for the iso-area comparison, mm².
    pub area_mm2: f64,
    /// Board TDP, W.
    pub power_w: f64,
}

impl Default for TpuModel {
    fn default() -> Self {
        TpuModel {
            peak_ops: 92e12,        // 256x256 MACs @ 700 MHz, 2 ops/MAC
            // TPU-1's weight-memory bandwidth. The paper "models GDDR5 to
            // allocate sufficient bandwidth" yet still reports MSRA-C stuck
            // at batch 1 — that requires the weight-streaming-bound regime,
            // i.e. an effective bandwidth near TPU-1's real 34 GB/s. We use
            // that value; Fig 24's shape (MSRA-C memory-bound, Alexnet
            // batch-rich) only emerges there.
            mem_bw: 34e9,
            sram_bytes: 28.0 * (1 << 20) as f64,
            latency_target: 7e-3,   // "7ms as demanded by most developers"
            area_mm2: 331.0,
            power_w: 40.0,
        }
    }
}

/// TPU evaluation of one workload.
#[derive(Clone, Copy, Debug)]
pub struct TpuReport {
    pub batch: usize,
    pub throughput: f64,
    pub latency_s: f64,
    pub energy_per_image_mj: f64,
}

impl TpuModel {
    /// Time to process a batch: conv layers are compute-bound (weights fit
    /// on-chip), FC layers stream weights once per batch.
    fn batch_time(&self, net: &Network, batch: usize) -> f64 {
        let mut t = 0.0;
        for l in &net.layers {
            match l {
                Layer::Conv { .. } => {
                    t += batch as f64 * l.macs() as f64 * 2.0 / self.peak_ops;
                }
                Layer::Fc { .. } | Layer::Rnn { .. } => {
                    // weights stream from memory once per batch; recurrent
                    // layers refetch per timestep on the TPU (no in-situ
                    // reuse) — macs() already folds the steps in
                    let compute = batch as f64 * l.macs() as f64 * 2.0 / self.peak_ops;
                    let weights = l.weights() as f64; // 1 B/weight (8-bit TPU)
                    let stream = weights / self.mem_bw;
                    t += compute.max(stream);
                }
                Layer::Pool { .. } => {}
            }
        }
        t
    }

    /// Largest batch meeting the latency target (at least 1).
    pub fn pick_batch(&self, net: &Network) -> usize {
        let mut batch = 1usize;
        while batch < 1024 {
            let next = batch * 2;
            if self.batch_time(net, next) > self.latency_target {
                break;
            }
            batch = next;
        }
        batch
    }

    pub fn evaluate(&self, net: &Network) -> TpuReport {
        let batch = self.pick_batch(net);
        let t = self.batch_time(net, batch);
        let throughput = batch as f64 / t;
        TpuReport {
            batch,
            throughput,
            latency_s: t,
            energy_per_image_mj: self.power_w * t / batch as f64 * 1e3,
        }
    }

    /// Peak computational efficiency, GOPS/mm².
    pub fn peak_ce(&self) -> f64 {
        self.peak_ops / 1e9 / self.area_mm2
    }

    /// Peak power efficiency, GOPS/W.
    pub fn peak_pe(&self) -> f64 {
        self.peak_ops / 1e9 / self.power_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn energy_ladder_matches_the_paper() {
        assert!((ideal_neuron().pj_per_op - 0.33).abs() < 0.05);
        assert!((dadiannao().pj_per_op - 3.5).abs() < 0.2);
        assert!((eyeriss().pj_per_op - 1.67).abs() < 0.1);
    }

    #[test]
    fn ladder_is_ordered() {
        assert!(ideal_neuron().pj_per_op < eyeriss().pj_per_op);
        assert!(eyeriss().pj_per_op < dadiannao().pj_per_op);
    }

    #[test]
    fn tpu_small_nets_get_big_batches() {
        let tpu = TpuModel::default();
        let b_alex = tpu.pick_batch(&workloads::alexnet());
        let b_msra = tpu.pick_batch(&workloads::msra_c());
        // paper: Alexnet/Resnet batch large; "for MSRA3, TPU can process
        // only one image per batch"
        assert!(b_alex >= 8, "{b_alex}");
        assert!(b_msra <= 2, "{b_msra}");
    }

    #[test]
    fn tpu_meets_latency_target() {
        let tpu = TpuModel::default();
        for net in workloads::suite() {
            let r = tpu.evaluate(&net);
            assert!(
                r.latency_s <= tpu.latency_target || r.batch == 1,
                "{}: {} s at batch {}",
                net.name,
                r.latency_s,
                r.batch
            );
        }
    }

    #[test]
    fn msra_c_is_memory_bound_and_energy_hungry() {
        let tpu = TpuModel::default();
        let msra = tpu.evaluate(&workloads::msra_c());
        let vgg = tpu.evaluate(&workloads::vgg_a());
        assert!(msra.energy_per_image_mj > vgg.energy_per_image_mj);
    }

    #[test]
    fn peak_metrics_reasonable() {
        let tpu = TpuModel::default();
        assert!((200.0..350.0).contains(&tpu.peak_ce()), "{}", tpu.peak_ce());
        assert!((1500.0..3000.0).contains(&tpu.peak_pe()), "{}", tpu.peak_pe());
    }
}
