//! SAR ADC behavioral + power model (paper §III-A3, Figs 5 & 12).
//!
//! Serve-path role: none directly — the serving stack's ADC *numerics*
//! live in [`crate::xbar`] (`AdcKind` selects them); this module is the
//! energy/schedule model behind the adaptive-ADC savings those configs
//! claim.
//!
//! A SAR ADC binary-searches the input voltage MSB-first; its energy splits
//! across six components (Kull et al. [18], Murmann survey [23]). We model
//! four groups: the capacitive DAC (CDAC), digital logic, other analog
//! (comparator/reference), and the sampling clock. Per the paper, when the
//! resolution is reduced the ADC "gates off its circuits until the next
//! sample": everything except the sampling clock scales with the number of
//! bit-tests actually performed; the CDAC additionally scales with *which*
//! bits are tested (MSB decisions charge the big capacitors).
//!
//! The adaptive schedule (Fig 5): the partial product of input-bit iteration
//! `i` and weight-slice `s` lands at bit position `p = i*dac_bits +
//! s*cell_bits` of the 39-bit accumulator. Only bits overlapping the kept
//! window `[out_shift, out_shift + out_bits)` matter; LSBs below it are
//! rounded away at the source and MSBs above it only need a single
//! clamp-detect comparison (the binary search starts at LSB+1: if that test
//! fires, some ignored MSB is 1 and the neuron output clamps).

use crate::config::XbarParams;

/// Energy-share of each SAR component group at full resolution.
/// `cdac + digital + analog + clock == 1.0`. Defaults follow the
/// conventional one-third split [29] with the clock carved out of digital;
/// the paper's sensitivity study varies `cdac` (10%..33%).
#[derive(Clone, Copy, Debug)]
pub struct SarShares {
    pub cdac: f64,
    pub digital: f64,
    pub analog: f64,
    pub clock: f64,
}

impl Default for SarShares {
    fn default() -> Self {
        // ~1/3 CDAC, ~1/3 digital, ~1/3 analog [29]; sampling clock is the
        // slice of digital that cannot be gated between samples.
        SarShares {
            cdac: 0.30,
            digital: 0.25,
            analog: 0.33,
            clock: 0.12,
        }
    }
}

impl SarShares {
    /// Sensitivity-analysis variant: pick the CDAC share, rescale the rest.
    pub fn with_cdac_share(cdac: f64) -> Self {
        let d = Self::default();
        let rest = d.digital + d.analog; // clock stays fixed
        let scale = (1.0 - cdac - d.clock) / rest;
        SarShares {
            cdac,
            digital: d.digital * scale,
            analog: d.analog * scale,
            clock: d.clock,
        }
    }
}

/// One ADC sample's work: which bit-tests of the `full_bits` binary search
/// actually run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleWork {
    /// Bit-tests performed (of `full_bits`).
    pub tests: u32,
    /// MSB-side tests skipped (these are the expensive CDAC decisions).
    pub msb_skipped: u32,
    /// Full resolution of the converter.
    pub full_bits: u32,
}

impl SampleWork {
    pub fn full(bits: u32) -> Self {
        SampleWork {
            tests: bits,
            msb_skipped: 0,
            full_bits: bits,
        }
    }

    /// Relative energy of this sample vs a full-resolution sample.
    pub fn energy_factor(&self, sh: &SarShares) -> f64 {
        if self.tests == 0 {
            // fully idle: only the sampling clock ticks
            return sh.clock;
        }
        let frac = self.tests as f64 / self.full_bits as f64;
        // CDAC: a binary-weighted array; testing bit b (MSB = full_bits-1)
        // charges ~2^b of the total capacitance. Skipping m MSBs removes
        // the top terms; stopping after `tests` bits removes the tail.
        let b = self.full_bits;
        let m = self.msb_skipped;
        let total = ((1u64 << b) - 1) as f64;
        let top_skipped = (((1u64 << b) - (1u64 << (b - m))) as f64).max(0.0);
        let tail_start = b - m - self.tests; // bits below this are skipped
        let tail = ((1u64 << tail_start) - 1) as f64;
        let cdac_frac = (total - top_skipped - tail) / total;
        sh.clock + sh.cdac * cdac_frac + (sh.digital + sh.analog) * frac
    }
}

/// The adaptive sampling schedule for one full VMM: what every
/// (iteration, slice) ADC sample must resolve. Mirrors
/// `python/compile/kernels/crossbar.py::relevant_bits`.
#[derive(Clone, Debug)]
pub struct AdaptiveSchedule {
    pub samples: Vec<SampleWork>,
    pub iters: usize,
    pub slices: usize,
}

impl AdaptiveSchedule {
    /// Build the schedule for operands of `in_bits` x `w_bits` on crossbar
    /// `p`, keeping the window `[p.out_shift, p.out_shift + p.out_bits)`.
    pub fn new(p: &XbarParams, in_bits: u32, w_bits: u32) -> Self {
        let iters = (in_bits as usize).div_ceil(p.dac_bits as usize);
        let slices = (w_bits as usize).div_ceil(p.cell_bits as usize);
        let full = p.adc_bits;
        let lo = p.out_shift as i64;
        let hi = (p.out_shift + p.out_bits) as i64;
        let mut samples = Vec::with_capacity(iters * slices);
        for i in 0..iters {
            for s in 0..slices {
                let place = (i as i64) * p.dac_bits as i64 + (s as i64) * p.cell_bits as i64;
                let top = place + full as i64; // one past sample MSB
                let lo_bit = place.max(lo);
                let hi_bit = top.min(hi);
                let kept = (hi_bit - lo_bit).max(0) as u32;
                let msb_skipped = (top - hi).clamp(0, full as i64) as u32;
                let mut tests = kept;
                if top > hi {
                    // clamp-detect comparison (binary search from LSB+1)
                    tests += 1;
                }
                let tests = tests.min(full);
                // re-derive msb_skipped consistent with the clamp test
                let msb_skipped = msb_skipped.saturating_sub(1).min(full - tests);
                samples.push(SampleWork {
                    tests,
                    msb_skipped,
                    full_bits: full,
                });
            }
        }
        AdaptiveSchedule {
            samples,
            iters,
            slices,
        }
    }

    /// Fig 5 matrix: bit-tests per (iteration, slice).
    pub fn tests_matrix(&self) -> Vec<Vec<u32>> {
        (0..self.iters)
            .map(|i| (0..self.slices).map(|s| self.samples[i * self.slices + s].tests).collect())
            .collect()
    }

    /// Average per-sample energy vs always-full-resolution sampling:
    /// the adaptive-ADC power scale factor.
    pub fn energy_scale(&self, sh: &SarShares) -> f64 {
        let adaptive: f64 = self.samples.iter().map(|s| s.energy_factor(sh)).sum();
        let full = self.samples.len() as f64
            * SampleWork::full(self.samples[0].full_bits).energy_factor(sh);
        adaptive / full
    }

    /// Total bit-tests (the Fig-5 "work" metric).
    pub fn total_tests(&self) -> u64 {
        self.samples.iter().map(|s| s.tests as u64).sum()
    }
}

/// ADC power in mW at a given sampling-rate slowdown and resolution scale.
/// Power scales linearly with sampling frequency (Kull et al. [18], used by
/// the paper for the 8x/32x/128x slow FC tiles, Fig 17).
pub fn adc_power_mw(base_mw: f64, slowdown: f64, energy_scale: f64) -> f64 {
    base_mw * energy_scale / slowdown
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> XbarParams {
        XbarParams::default()
    }

    #[test]
    fn schedule_shape_is_16x8() {
        let s = AdaptiveSchedule::new(&p(), 16, 16);
        assert_eq!(s.iters, 16);
        assert_eq!(s.slices, 8);
        assert_eq!(s.samples.len(), 128);
    }

    #[test]
    fn band_centre_is_full_resolution() {
        let s = AdaptiveSchedule::new(&p(), 16, 16);
        let m = s.tests_matrix();
        // (i=8, s=4): place = 16, well inside [10, 26) with top 25 <= 26
        assert_eq!(m[8][4], 9);
        // (i=0, s=0): place 0, sample [0,9) entirely below window -> 0 tests
        assert_eq!(m[0][0], 0);
        // (i=15, s=7): place 29 >= 26, only the clamp-detect test
        assert_eq!(m[15][7], 1);
    }

    #[test]
    fn adaptive_saves_tests_vs_full() {
        let s = AdaptiveSchedule::new(&p(), 16, 16);
        let full = (s.samples.len() * 9) as u64;
        let t = s.total_tests();
        assert!(t < full, "{t} !< {full}");
        // matches the python relevant_bits total for the same window
        // (python counts kept+clamp the same way)
        assert!(t > full / 2);
    }

    #[test]
    fn energy_scale_between_clock_floor_and_one() {
        let s = AdaptiveSchedule::new(&p(), 16, 16);
        let sh = SarShares::default();
        let e = s.energy_scale(&sh);
        assert!(e > sh.clock && e < 1.0, "{e}");
        // the paper reports ~15% chip power saved with ADC ~49% of chip
        // power => ADC energy scale ~0.7; ours must land in that region.
        assert!((0.55..0.90).contains(&e), "{e}");
    }

    #[test]
    fn full_sample_factor_is_one() {
        let sh = SarShares::default();
        assert!((SampleWork::full(9).energy_factor(&sh) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn idle_sample_costs_clock_only() {
        let sh = SarShares::default();
        let w = SampleWork {
            tests: 0,
            msb_skipped: 9,
            full_bits: 9,
        };
        assert_eq!(w.energy_factor(&sh), sh.clock);
    }

    #[test]
    fn msb_skips_save_more_cdac_than_lsb_skips() {
        let sh = SarShares::default();
        let msb = SampleWork {
            tests: 5,
            msb_skipped: 4,
            full_bits: 9,
        };
        let lsb = SampleWork {
            tests: 5,
            msb_skipped: 0,
            full_bits: 9,
        };
        assert!(msb.energy_factor(&sh) < lsb.energy_factor(&sh));
    }

    #[test]
    fn cdac_share_sensitivity_directionally_correct() {
        // Fig 12 discussion: with CDAC at 10% vs 27% of ADC power the
        // adaptive improvement changes by only ~1% absolute.
        let s = AdaptiveSchedule::new(&p(), 16, 16);
        let e10 = s.energy_scale(&SarShares::with_cdac_share(0.10));
        let e27 = s.energy_scale(&SarShares::with_cdac_share(0.27));
        assert!((e10 - e27).abs() < 0.08, "{e10} vs {e27}");
    }

    #[test]
    fn slow_adc_scales_linearly() {
        assert!((adc_power_mw(3.1, 128.0, 1.0) - 3.1 / 128.0).abs() < 1e-12);
    }

    #[test]
    fn shares_sum_to_one() {
        let d = SarShares::default();
        assert!((d.cdac + d.digital + d.analog + d.clock - 1.0).abs() < 1e-9);
        let v = SarShares::with_cdac_share(0.10);
        assert!((v.cdac + v.digital + v.analog + v.clock - 1.0).abs() < 1e-9);
    }
}
