//! Karatsuba bit-level divide & conquer (paper §III-A1, Figs 3, 9, 13, 14).
//!
//! Two halves: a *functional* decomposition (verified bit-exact against the
//! plain pipeline, mirroring the L1 kernel's `karatsuba_vmm`) and a
//! *schedule model* that accounts crossbars, iterations and ADC samples for
//! recursion depth `k` — the quantities that drive the Fig 13/14 results.
//!
//! Recursion follows the paper's construction: level `k` splits the two
//! equal-half products again, while the (n/2+1)-bit middle term
//! `(X1+X0)(W1+W0)` always runs as a plain bit-serial product (Fig 9 maps it
//! onto the right crossbars of the mats). The middle term starts as soon as
//! the first sub-phase ends, overlapping with the sub-products' own middle
//! terms (this is how k=2 ends up *faster* than the 16-iteration baseline).

use crate::config::XbarParams;
use crate::xbar::{biased_product, scale_clamp, Matrix};

/// One timeline phase: `adcs` converters busy for `iters` iterations.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Phase {
    pub iters: usize,
    pub adcs: usize,
}

/// Hardware cost of a divide-&-conquer VMM schedule on one IMA.
#[derive(Clone, Debug)]
pub struct DncSchedule {
    /// Crossbars actually holding weights (per baseline-slice group).
    pub xbars_used: usize,
    /// Crossbars allocated (mat structure rounds up; Fig 9: 16 for 13 used).
    pub xbars_allocated: usize,
    /// Critical-path iterations for one full VMM.
    pub time_iters: usize,
    /// Total ADC samples per output column (the energy driver).
    pub adc_samples: usize,
    /// Busy phases on the critical path.
    pub phases: Vec<Phase>,
    /// Recursion depth.
    pub k: u32,
}

fn iters_of(in_bits: u32, p: &XbarParams) -> usize {
    (in_bits as usize).div_ceil(p.dac_bits as usize)
}

fn slices_of(w_bits: u32, p: &XbarParams) -> usize {
    (w_bits as usize).div_ceil(p.cell_bits as usize)
}

struct Sub {
    time: usize,
    first_phase: usize,
    samples: usize,
    xbars: usize,
}

fn build(in_bits: u32, w_bits: u32, k: u32, p: &XbarParams) -> Sub {
    if k == 0 {
        let it = iters_of(in_bits, p);
        let sl = slices_of(w_bits, p);
        return Sub {
            time: it,
            first_phase: it,
            samples: it * sl,
            xbars: sl,
        };
    }
    let hi = in_bits / 2;
    let hw = w_bits / 2;
    let sub = build(hi, hw, k - 1, p);
    let mid = build(hi + 1, hw + 1, 0, p);
    Sub {
        // the two half products run in parallel; the middle term starts
        // when their first phase frees its ADCs
        time: (sub.first_phase + mid.time).max(sub.time),
        first_phase: sub.first_phase,
        samples: 2 * sub.samples + mid.samples,
        xbars: 2 * sub.xbars + mid.xbars,
    }
}

impl DncSchedule {
    /// Schedule for a full-width VMM at recursion depth `k` (k = 0 is the
    /// plain bit-serial baseline).
    pub fn new(k: u32, p: &XbarParams) -> Self {
        let s = build(p.input_bits, p.weight_bits, k, p);
        let baseline_slices = slices_of(p.weight_bits, p);
        // mats pair two crossbars behind one ADC/DAC (Fig 9); allocation
        // rounds up to the mat structure, at least one mat per baseline
        // slice position.
        let allocated = if k == 0 {
            baseline_slices
        } else {
            (2 * baseline_slices).max(s.xbars.div_ceil(2) * 2)
        };
        let phases = Self::phases_of(k, p);
        DncSchedule {
            xbars_used: s.xbars,
            xbars_allocated: allocated,
            time_iters: s.time,
            adc_samples: s.samples,
            phases,
            k,
        }
    }

    fn phases_of(k: u32, p: &XbarParams) -> Vec<Phase> {
        match k {
            0 => vec![Phase {
                iters: iters_of(p.input_bits, p),
                adcs: slices_of(p.weight_bits, p),
            }],
            _ => {
                // first phase: all equal-half leaf products in parallel;
                // afterwards the middle terms drain.
                let s = build(p.input_bits, p.weight_bits, k, p);
                let leaf_in = p.input_bits >> k;
                let leaf_sl = slices_of(p.weight_bits >> k, p);
                let leaves = 1usize << k;
                let first = Phase {
                    iters: iters_of(leaf_in, p),
                    adcs: leaves * leaf_sl,
                };
                let rest_iters = s.time - first.iters;
                let rest_samples = s.samples - first.iters * first.adcs;
                let rest = Phase {
                    iters: rest_iters,
                    adcs: rest_samples.div_ceil(rest_iters.max(1)),
                };
                vec![first, rest]
            }
        }
    }

    /// ADC-work ratio vs the k=0 baseline — the adaptive-energy multiplier
    /// the pipeline model applies when Karatsuba is on.
    pub fn adc_work_ratio(&self, p: &XbarParams) -> f64 {
        let base = iters_of(p.input_bits, p) * slices_of(p.weight_bits, p);
        self.adc_samples as f64 / base as f64
    }

    /// Execution-time ratio vs baseline.
    pub fn time_ratio(&self, p: &XbarParams) -> f64 {
        self.time_iters as f64 / iters_of(p.input_bits, p) as f64
    }

    /// Crossbar-area ratio vs baseline (xbars allocated per slice group).
    pub fn xbar_ratio(&self, p: &XbarParams) -> f64 {
        self.xbars_allocated as f64 / slices_of(p.weight_bits, p) as f64
    }

    /// Fraction of the allocated ADCs busy over the VMM window (the paper's
    /// "ADCs end up being used 75% of the time in the 1700 ns window").
    pub fn adc_busy_frac(&self, p: &XbarParams) -> f64 {
        let adcs = slices_of(p.weight_bits, p); // ADCs per mat group
        self.adc_samples as f64 / (self.time_iters as f64 * adcs as f64)
    }
}

// ---------------------------------------------------------------------------
// Functional Karatsuba (bit-exact; mirrors kernels/crossbar.py)
// ---------------------------------------------------------------------------

/// Signed VMM through one level of Karatsuba on the crossbar pipeline.
pub fn karatsuba_vmm_raw(x: &Matrix, w: &Matrix, p: &XbarParams) -> Matrix {
    assert!(p.input_bits % 2 == 0 && p.weight_bits % 2 == 0);
    let hi = p.input_bits / 2;
    let hw = p.weight_bits / 2;
    let bias = 1i64 << (p.weight_bits - 1);
    let mi = (1i64 << hi) - 1;
    let mw = (1i64 << hw) - 1;

    let x0 = Matrix::from_fn(x.rows, x.cols, |r, c| x.at(r, c) & mi);
    let x1 = Matrix::from_fn(x.rows, x.cols, |r, c| x.at(r, c) >> hi);
    let w0 = Matrix::from_fn(w.rows, w.cols, |r, c| (w.at(r, c) + bias) & mw);
    let w1 = Matrix::from_fn(w.rows, w.cols, |r, c| (w.at(r, c) + bias) >> hw);
    let xs = Matrix::from_fn(x.rows, x.cols, |r, c| x0.at(r, c) + x1.at(r, c));
    let ws = Matrix::from_fn(w.rows, w.cols, |r, c| w0.at(r, c) + w1.at(r, c));

    let p00 = biased_product(&x0, &w0, hi, hw, p, false);
    let p11 = biased_product(&x1, &w1, hi, hw, p, false);
    let pm = biased_product(&xs, &ws, hi + 1, hw + 1, p, false);

    Matrix::from_fn(x.rows, w.cols, |r, c| {
        let sx: i64 = (0..x.cols).map(|k| x.at(r, k)).sum();
        let v = (p11.at(r, c) << (hi + hw))
            + ((pm.at(r, c) - p11.at(r, c) - p00.at(r, c)) << hw)
            + p00.at(r, c);
        v - bias * sx
    })
}

/// Karatsuba VMM with the standard scaling stage.
pub fn karatsuba_vmm(x: &Matrix, w: &Matrix, p: &XbarParams) -> Matrix {
    scale_clamp(&karatsuba_vmm_raw(x, w, p), p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::xbar::matmul;

    fn p() -> XbarParams {
        XbarParams::default()
    }

    #[test]
    fn k0_is_the_baseline() {
        let s = DncSchedule::new(0, &p());
        assert_eq!(s.time_iters, 16);
        assert_eq!(s.adc_samples, 128);
        assert_eq!(s.xbars_allocated, 8);
        assert!((s.adc_work_ratio(&p()) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn k1_matches_paper_schedule() {
        // Paper: 8 ADCs for 8 iterations, then 5 ADCs for 9 iterations;
        // 109 samples = 15% less work; 17 iterations; 16 xbars per IMA slot.
        let s = DncSchedule::new(1, &p());
        assert_eq!(s.time_iters, 17);
        assert_eq!(s.adc_samples, 2 * 8 * 4 + 9 * 5);
        assert_eq!(s.adc_samples, 109);
        assert_eq!(s.xbars_used, 13);
        assert_eq!(s.xbars_allocated, 16);
        let ratio = s.adc_work_ratio(&p());
        assert!((ratio - 109.0 / 128.0).abs() < 1e-12);
        // "reduced by 15%"
        assert!((1.0 - ratio - 0.148).abs() < 0.01);
        // busy fraction ~0.75-0.80
        let busy = s.adc_busy_frac(&p());
        assert!((0.70..0.85).contains(&busy), "{busy}");
    }

    #[test]
    fn k2_is_faster_and_cheaper_but_bigger() {
        let s1 = DncSchedule::new(1, &p());
        let s2 = DncSchedule::new(2, &p());
        // paper: 20 crossbars, ~13% faster than baseline, more ADC savings
        assert_eq!(s2.xbars_used, 19);
        assert_eq!(s2.xbars_allocated, 20);
        assert!(s2.time_iters < 16, "{}", s2.time_iters);
        assert!(s2.adc_samples < s1.adc_samples);
        assert!(s2.xbars_allocated > s1.xbars_allocated);
    }

    #[test]
    fn functional_karatsuba_is_bit_exact() {
        let pp = p();
        let mut rng = Rng::new(9);
        let x = Matrix::from_fn(3, pp.rows, |_, _| rng.range_i64(0, 1 << 16));
        let w = Matrix::from_fn(pp.rows, 11, |_, _| rng.range_i64(-(1 << 15), 1 << 15));
        let got = karatsuba_vmm(&x, &w, &pp);
        let want = scale_clamp(&matmul(&x, &w), &pp);
        assert_eq!(got, want);
        assert_eq!(karatsuba_vmm_raw(&x, &w, &pp), matmul(&x, &w));
    }

    #[test]
    fn deeper_recursion_monotone_adc_savings() {
        let pp = p();
        let r: Vec<f64> = (0..3)
            .map(|k| DncSchedule::new(k, &pp).adc_work_ratio(&pp))
            .collect();
        assert!(r[0] > r[1] && r[1] > r[2], "{r:?}");
    }

    #[test]
    fn phases_cover_all_samples() {
        for k in 0..3 {
            let s = DncSchedule::new(k, &p());
            let by_phase: usize = s.phases.iter().map(|ph| ph.iters * ph.adcs).sum();
            // phase boxes over-approximate (rest phase rounds adcs up)
            assert!(by_phase >= s.adc_samples);
            let t: usize = s.phases.iter().map(|ph| ph.iters).sum();
            assert_eq!(t, s.time_iters);
        }
    }
}
