//! Offline stub for the `xla` PJRT bindings (ARCHITECTURE.md §Substitutions).
//!
//! The real bindings link against `libxla_extension`, which the offline
//! image does not ship, and the crate itself cannot be fetched. This stub
//! keeps the runtime layer compiling with the exact call shapes the real
//! bindings expose; client creation fails with a clear message, so every
//! caller's "artifacts unavailable" fallback fires (benches print a skip
//! note, tests skip, the serve example falls back to the golden model).
//!
//! Swapping the real bindings back in is a two-line change in
//! `runtime/mod.rs`: replace `use self::xla_stub as xla;` with the crate
//! import and add the dependency to `rust/Cargo.toml`.

use std::path::Path;

/// Error type mirroring the bindings' debug-printable error.
#[derive(Debug)]
pub struct XlaError(pub String);

pub type XlaResult<T> = std::result::Result<T, XlaError>;

const UNAVAILABLE: &str =
    "PJRT unavailable: the `xla` bindings are stubbed offline (rust/src/runtime/xla_stub.rs)";

fn unavailable<T>() -> XlaResult<T> {
    Err(XlaError(UNAVAILABLE.to_string()))
}

pub struct PjRtClient;

pub struct PjRtLoadedExecutable;

pub struct PjRtBuffer;

pub struct Literal;

pub struct HloModuleProto;

pub struct XlaComputation;

impl PjRtClient {
    pub fn cpu() -> XlaResult<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        unavailable()
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        unavailable()
    }
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> XlaResult<Literal> {
        unavailable()
    }

    pub fn to_tuple1(self) -> XlaResult<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        unavailable()
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> XlaResult<HloModuleProto> {
        unavailable()
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
