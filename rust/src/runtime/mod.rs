//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt` + manifest),
//! compile them once on the CPU PJRT client, and execute them from the
//! request path. Python never runs here (ARCHITECTURE.md §Layer map).
//! Serve-path role: backs `coordinator::PipelineServer` when artifacts
//! exist; without them every serving surface falls back to the golden
//! crossbar engine (`coordinator::GoldenServer`), which is also the seam
//! (`net::Engine`, `coordinator::pipeline::StagePool`) a real PJRT
//! replica pool will plug into.
//!
//! HLO *text* is the interchange format — see `python/compile/aot.py` and
//! /opt/xla-example/README.md: jax >= 0.5 emits protos with 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod xla_stub;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

// The real PJRT bindings are unavailable offline; the stub has identical
// call shapes and fails at client creation (see xla_stub.rs to swap back).
use self::xla_stub as xla;

/// Tensor shape + dtype tag from the manifest (`8x32x32x3:i32`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dims: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn parse(tag: &str) -> Result<TensorSpec> {
        let (dims_s, dtype) = tag
            .split_once(':')
            .ok_or_else(|| anyhow!("bad shape tag {tag:?}"))?;
        let dims = dims_s
            .split('x')
            .map(|d| d.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            dims,
            dtype: dtype.to_string(),
        })
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One artifact entry from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub input: TensorSpec,
    pub output: TensorSpec,
}

/// One golden test-vector entry.
#[derive(Clone, Debug)]
pub struct TestVecEntry {
    pub name: String,
    pub file: String,
    pub spec: TensorSpec,
}

/// Parsed `manifest.txt`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactEntry>,
    pub testvecs: Vec<TestVecEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        let mut m = Manifest {
            dir: dir.to_path_buf(),
            ..Default::default()
        };
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            match toks.as_slice() {
                ["artifact", name, file, inp, out] => {
                    let input = TensorSpec::parse(
                        inp.strip_prefix("in:").ok_or_else(|| anyhow!("bad in:"))?,
                    )?;
                    let output = TensorSpec::parse(
                        out.strip_prefix("out:").ok_or_else(|| anyhow!("bad out:"))?,
                    )?;
                    m.artifacts.push(ArtifactEntry {
                        name: name.to_string(),
                        file: file.to_string(),
                        input,
                        output,
                    });
                }
                ["testvec", name, file, tag] => {
                    m.testvecs.push(TestVecEntry {
                        name: name.to_string(),
                        file: file.to_string(),
                        spec: TensorSpec::parse(tag)?,
                    });
                }
                _ => bail!("manifest line {}: unrecognised: {line:?}", lineno + 1),
            }
        }
        Ok(m)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    /// Load a little-endian i32 test vector by name.
    pub fn load_testvec(&self, name: &str) -> Result<(TensorSpec, Vec<i32>)> {
        let tv = self
            .testvecs
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow!("testvec {name:?} not in manifest"))?;
        let bytes = std::fs::read(self.dir.join(&tv.file))?;
        if bytes.len() != tv.spec.elements() * 4 {
            bail!(
                "testvec {name}: {} bytes != {} elements * 4",
                bytes.len(),
                tv.spec.elements()
            );
        }
        let vals = bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok((tv.spec.clone(), vals))
    }
}

/// A compiled artifact ready to execute.
pub struct CompiledArtifact {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledArtifact {
    /// Execute on host `i32` data shaped per the manifest entry.
    pub fn run(&self, input: &[i32]) -> Result<Vec<i32>> {
        if input.len() != self.entry.input.elements() {
            bail!(
                "{}: input has {} elements, artifact wants {:?}",
                self.entry.name,
                input.len(),
                self.entry.input.dims
            );
        }
        let dims: Vec<i64> = self.entry.input.dims.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute {}: {e:?}", self.entry.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let out = out.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
        let v: Vec<i32> = out.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if v.len() != self.entry.output.elements() {
            bail!(
                "{}: output has {} elements, manifest says {:?}",
                self.entry.name,
                v.len(),
                self.entry.output.dims
            );
        }
        Ok(v)
    }
}

/// PJRT client + compiled artifact cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    compiled: HashMap<String, CompiledArtifact>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifacts directory.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Runtime {
            manifest,
            client,
            compiled: HashMap::new(),
        })
    }

    /// Compile (and cache) one artifact by manifest name.
    pub fn compile(&mut self, name: &str) -> Result<&CompiledArtifact> {
        if !self.compiled.contains_key(name) {
            let entry = self.manifest.artifact(name)?.clone();
            let path = self.manifest.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.compiled
                .insert(name.to_string(), CompiledArtifact { entry, exe });
        }
        Ok(&self.compiled[name])
    }

    /// Compile + run in one call.
    pub fn run(&mut self, name: &str, input: &[i32]) -> Result<Vec<i32>> {
        self.compile(name)?;
        self.compiled[name].run(input)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.iter().map(|a| a.name.clone()).collect()
    }
}

/// Default artifacts directory: `$NEWTON_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("NEWTON_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_parses() {
        let t = TensorSpec::parse("8x32x32x3:i32").unwrap();
        assert_eq!(t.dims, vec![8, 32, 32, 3]);
        assert_eq!(t.dtype, "i32");
        assert_eq!(t.elements(), 8 * 32 * 32 * 3);
        assert!(TensorSpec::parse("8x32").is_err());
        assert!(TensorSpec::parse("axb:i32").is_err());
    }

    #[test]
    fn manifest_parses_inline() {
        let dir = std::env::temp_dir().join("newton-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "artifact m m.hlo.txt in:2x2:i32 out:2x3:i32\ntestvec v v.bin 2x2:i32\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("v.bin"),
            1i32.to_le_bytes()
                .iter()
                .chain(2i32.to_le_bytes().iter())
                .chain(3i32.to_le_bytes().iter())
                .chain(4i32.to_le_bytes().iter())
                .copied()
                .collect::<Vec<u8>>(),
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        assert_eq!(m.artifact("m").unwrap().output.dims, vec![2, 3]);
        let (spec, vals) = m.load_testvec("v").unwrap();
        assert_eq!(spec.dims, vec![2, 2]);
        assert_eq!(vals, vec![1, 2, 3, 4]);
        assert!(m.artifact("nope").is_err());
        assert!(m.load_testvec("nope").is_err());
    }

    #[test]
    fn bad_manifest_rejected() {
        let dir = std::env::temp_dir().join("newton-manifest-bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "garbage line here\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
