//! Tiny argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional args.

use std::collections::HashMap;

/// Every `newton` subcommand with a one-line description — the single
/// source for `newton list`, `newton help`, and the unknown-command hint,
/// so the three can never drift apart again.
pub const SUBCOMMANDS: &[(&str, &str)] = &[
    ("report", "headline Newton-vs-ISAAC comparison"),
    ("simulate", "analytic evaluation of one workload (--net, --isaac)"),
    ("incremental", "Fig-20-style technique stacking table"),
    ("sweep", "design-space sweeps (--what ima|buffer|fc)"),
    ("verify", "run artifacts against golden test vectors"),
    ("serve", "in-process batched serving demo (--adc, --replicas, --pipeline, --trace-out)"),
    ("serve-net", "TCP serving endpoint (--addr, --adc, --replicas, --pipeline, --health, --admin-addr, --cost-reports, --trace-out; --event-loop --max-pipeline N --workers W = readiness-driven pipelined mode)"),
    ("worker", "cluster shard worker: serves the shard plane on --addr (--seed, --adc, --admin-addr)"),
    ("cluster-serve", "shard the stage pipeline across --workers A,B,C and serve clients on --addr"),
    ("bench-net", "load-generate against a serve-net endpoint (--addr; --concurrency 1,8,64 sweeps; --pipeline-depth 1,8,32 tagged-pipelining sweeps; --fault-rate = chaos; --cluster = failover benchmark; --trace-out)"),
    ("statz", "scrape a serve-net admin plane (--addr; see serve-net --admin-addr)"),
    ("sched-stress", "work-stealing executor stress smoke (CI)"),
    ("export", "write every figure's data series as CSV (--out)"),
    ("list", "workloads, artifacts, and subcommands"),
    ("help", "this command table"),
];

/// `report|simulate|...` — the hint appended to unknown-command errors.
pub fn command_summary() -> String {
    SUBCOMMANDS
        .iter()
        .map(|(name, _)| *name)
        .collect::<Vec<_>>()
        .join("|")
}

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a float, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["serve", "--batch", "8", "--net=vgg-a", "--verbose"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("batch"), Some("8"));
        assert_eq!(a.get("net"), Some("vgg-a"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_usize("batch", 1), 8);
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 3), 3);
        assert_eq!(a.get_f64("f", 1.5), 1.5);
        assert!(!a.has_flag("nope"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn command_table_is_complete_and_unique() {
        let names: Vec<&str> = SUBCOMMANDS.iter().map(|(n, _)| *n).collect();
        for want in [
            "serve",
            "serve-net",
            "worker",
            "cluster-serve",
            "bench-net",
            "export",
            "sched-stress",
            "list",
        ] {
            assert!(names.contains(&want), "missing {want}");
        }
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate subcommand names");
        let summary = command_summary();
        for n in names {
            assert!(summary.contains(n), "summary omits {n}");
        }
        assert!(SUBCOMMANDS.iter().all(|(_, d)| !d.is_empty()));
    }
}
