//! Readiness-driven event loop: every connection on one poll thread.
//!
//! The threaded server costs one OS thread per connection and leaves the
//! wire idle between each request/response pair. This module replaces
//! that tier (when [`crate::net::ServeConfig::event_loop`] is set) with:
//!
//! * **one loop thread** holding the listener and every accepted
//!   connection as nonblocking sockets, multiplexed with `poll(2)` (raw
//!   FFI — std already links libc, no crate added; a portable fallback
//!   emulates readiness with a short sleep on non-unix targets);
//! * **a fixed dispatcher pool** (`workers` threads running the same
//!   [`crate::net::server::dispatch_loop`] as the threaded server) that
//!   closes batches and runs the engine;
//! * **a completion bridge** carrying finished rows back: dispatchers
//!   push [`Completion`]s and poke the loop's waker (a socketpair byte),
//!   and the loop frames each reply into its connection's write buffer.
//!
//! Per-connection state machine (implicit in the buffers):
//!
//! ```text
//!   reading-header ──16 bytes──▶ reading-payload ──frame──▶ dispatched(k)
//!        ▲                                                       │
//!        │                     reply completes: frame appended   │
//!        └────────── writing ◀──────── to write_buf ─────────────┘
//! ```
//!
//! A connection may hold up to `max_pipeline` tagged (proto v4) requests
//! in `dispatched`; replies return in completion order, not arrival
//! order, each carrying its request's tag. Untagged (v3) requests keep
//! their strict one-in-flight contract: the loop stops parsing that
//! connection's bytes until the reply is enqueued, which is exactly the
//! pacing a blocking [`crate::net::Client`] produces — so v3 peers see
//! byte-identical behaviour.
//!
//! Backpressure is layered: over-window v4 requests get a *tagged*
//! [`Msg::Busy`] (per-request, the connection lives on), the global
//! admission ceiling returns `Busy` exactly as the threaded server does,
//! and a slow reader whose write buffer exceeds [`WRITE_BUF_CAP`] stops
//! being *read* (its socket stays registered for write-readiness only)
//! until it drains — one stalled consumer can never pin loop memory or
//! other connections.

use std::collections::{HashMap, HashSet};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::coordinator::batcher::PendingRequest;
use crate::net::proto::{self, InferReply, InferRequest, Msg, StatsSnapshot, WireError};
use crate::net::server::{site_counter, snapshot, try_admit, RouteSink, Shared};
use crate::obs::{self, Counter};

/// Event-loop serving knobs (see [`crate::net::ServeConfig::event_loop`]).
#[derive(Clone, Debug)]
pub struct EventLoopConfig {
    /// Dispatcher threads closing batches and running the engine. The
    /// server's thread count is bounded by this pool (plus the loop and
    /// admin threads) no matter how many connections are held open.
    pub workers: usize,
    /// Max tagged requests a single connection may hold in flight;
    /// request `max_pipeline + 1` gets a tagged `Busy` while the
    /// connection keeps serving.
    pub max_pipeline: usize,
}

impl Default for EventLoopConfig {
    fn default() -> Self {
        EventLoopConfig {
            workers: 2,
            max_pipeline: 32,
        }
    }
}

/// A slow reader's write buffer is capped here; past it the loop stops
/// reading that connection until the peer drains its replies.
const WRITE_BUF_CAP: usize = 1 << 20;

/// Read scratch size per syscall; the loop reads until `WouldBlock`, so
/// this bounds a single `read`, not a connection's frame size.
const READ_CHUNK: usize = 64 * 1024;

// ---- readiness primitives -------------------------------------------------

/// Minimal `poll(2)` wrapper. On unix this is the real syscall via FFI
/// (std links libc already — no dependency added). Elsewhere readiness is
/// emulated: a short sleep, then every entry is reported ready, which is
/// correct (all sockets are nonblocking, so spurious readiness costs a
/// `WouldBlock`) if wasteful — the unix path is the production one.
pub(crate) mod sys {
    use std::time::Duration;

    /// Mirror of `struct pollfd`.
    #[repr(C)]
    pub(crate) struct PollFd {
        pub(crate) fd: i32,
        pub(crate) events: i16,
        pub(crate) revents: i16,
    }

    pub(crate) const POLLIN: i16 = 0x001;
    pub(crate) const POLLOUT: i16 = 0x004;

    #[cfg(unix)]
    pub(crate) fn raw_fd<F: std::os::unix::io::AsRawFd>(f: &F) -> i32 {
        f.as_raw_fd()
    }

    #[cfg(not(unix))]
    pub(crate) fn raw_fd<F>(_f: &F) -> i32 {
        -1
    }

    #[cfg(unix)]
    pub(crate) fn poll_fds(fds: &mut [PollFd], timeout: Duration) -> usize {
        #[cfg(target_os = "linux")]
        type NfdsT = std::ffi::c_ulong;
        #[cfg(not(target_os = "linux"))]
        type NfdsT = std::ffi::c_uint;
        extern "C" {
            fn poll(fds: *mut super::sys::PollFd, nfds: NfdsT, timeout: std::ffi::c_int)
                -> std::ffi::c_int;
        }
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        loop {
            let r = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, ms) };
            if r >= 0 {
                return r as usize;
            }
            if std::io::Error::last_os_error().kind() != std::io::ErrorKind::Interrupted {
                return 0; // EBADF etc.: treat as a timed-out tick
            }
        }
    }

    #[cfg(not(unix))]
    pub(crate) fn poll_fds(fds: &mut [PollFd], timeout: Duration) -> usize {
        std::thread::sleep(timeout.min(Duration::from_millis(2)));
        for f in fds.iter_mut() {
            f.revents = f.events;
        }
        fds.len()
    }

    /// Sleep until `l` is accept-ready or `timeout` passes. Used by the
    /// admin plane so its accept loop is readiness-driven, not a
    /// sleep-and-retry spin.
    pub(crate) fn wait_readable(l: &std::net::TcpListener, timeout: Duration) -> bool {
        let mut fds = [PollFd {
            fd: raw_fd(l),
            events: POLLIN,
            revents: 0,
        }];
        poll_fds(&mut fds, timeout) > 0 && fds[0].revents & POLLIN != 0
    }
}

/// Wakes the loop thread out of `poll` when a dispatcher finishes a row.
/// One byte down a socketpair; coalesced by the `pending` flag so a burst
/// of completions costs one write. If the pair cannot be created the
/// bridge still works — the loop's poll timeout doubles as the delivery
/// tick, trading latency for liveness.
struct Waker {
    #[cfg(unix)]
    pair: Option<(std::os::unix::net::UnixStream, std::os::unix::net::UnixStream)>,
    pending: AtomicBool,
}

impl Waker {
    fn new() -> Waker {
        #[cfg(unix)]
        {
            let pair = std::os::unix::net::UnixStream::pair().ok().and_then(|(r, w)| {
                r.set_nonblocking(true).ok()?;
                w.set_nonblocking(true).ok()?;
                Some((r, w))
            });
            Waker {
                pair,
                pending: AtomicBool::new(false),
            }
        }
        #[cfg(not(unix))]
        Waker {
            pending: AtomicBool::new(false),
        }
    }

    fn wake(&self) {
        if self.pending.swap(true, Ordering::AcqRel) {
            return; // a byte is already in flight
        }
        #[cfg(unix)]
        if let Some((_, w)) = &self.pair {
            let _ = (&mut &*w).write(&[1u8]);
        }
    }

    /// Clear the pending flag and drain the pipe. Called by the loop
    /// *before* consuming completions, so a completion arriving after the
    /// drain leaves either the flag or a byte behind — never lost.
    fn drain(&self) {
        self.pending.store(false, Ordering::Release);
        #[cfg(unix)]
        if let Some((r, _)) = &self.pair {
            let mut buf = [0u8; 64];
            while matches!((&mut &*r).read(&mut buf), Ok(n) if n > 0) {}
        }
    }

    fn poll_fd(&self) -> Option<i32> {
        #[cfg(unix)]
        {
            self.pair.as_ref().map(|(r, _)| sys::raw_fd(r))
        }
        #[cfg(not(unix))]
        None
    }
}

/// A finished row travelling dispatcher → loop: everything needed to
/// frame the reply without the loop re-looking the request up.
pub(crate) struct Completion {
    pub(crate) conn: u64,
    pub(crate) tag: u16,
    pub(crate) tagged: bool,
    pub(crate) id: u64,
    pub(crate) trace: u64,
    pub(crate) t0: Instant,
    pub(crate) replica: u32,
    pub(crate) max_abs_err: i64,
    pub(crate) logits: Vec<i32>,
    pub(crate) cost: Option<proto::CostReport>,
}

/// Dispatcher-side handle: push a completion, poke the loop.
pub(crate) struct CompletionBridge {
    completions: Mutex<Vec<Completion>>,
    waker: Waker,
}

impl CompletionBridge {
    fn new() -> Arc<CompletionBridge> {
        Arc::new(CompletionBridge {
            completions: Mutex::new(Vec::new()),
            waker: Waker::new(),
        })
    }

    pub(crate) fn complete(&self, c: Completion) {
        self.completions.lock().unwrap().push(c);
        self.waker.wake();
    }

    fn drain(&self) -> Vec<Completion> {
        self.waker.drain();
        std::mem::take(&mut *self.completions.lock().unwrap())
    }
}

// ---- per-connection state -------------------------------------------------

struct Conn {
    stream: TcpStream,
    /// Unparsed inbound bytes; a torn frame simply stays here until the
    /// next readable event completes it.
    read_buf: Vec<u8>,
    /// Framed replies waiting for the socket; `write_pos` is the flushed
    /// prefix (compacted, not re-allocated, as it drains).
    write_buf: Vec<u8>,
    write_pos: usize,
    /// Live v4 tags: duplicates are a routing ambiguity and close the
    /// connection; completion removes its tag, freeing it for reuse.
    tags: HashSet<u16>,
    /// Dispatched-not-yet-written requests (tagged and untagged).
    outstanding: usize,
    /// An untagged (v3) `Infer` is in flight: stop parsing this
    /// connection until its reply is enqueued, preserving the strict
    /// request/response pacing a blocking client relies on.
    serial_wait: bool,
    /// Peer half-closed its write side (EOF); replies still flush.
    read_closed: bool,
    /// Close once `outstanding == 0` and the write buffer flushed.
    closing: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            read_buf: Vec::new(),
            write_buf: Vec::new(),
            write_pos: 0,
            tags: HashSet::new(),
            outstanding: 0,
            serial_wait: false,
            read_closed: false,
            closing: false,
        }
    }

    fn pending_write(&self) -> usize {
        self.write_buf.len() - self.write_pos
    }

    /// Frame `m` into the write buffer, echoing the request's framing:
    /// tagged v4 when `tag` is `Some`, untagged v3 otherwise.
    fn enqueue(&mut self, m: &Msg, tag: Option<u16>) {
        let frame = match tag {
            Some(t) => proto::encode_frame_tagged(m, t),
            None => proto::encode_frame(m),
        };
        self.write_buf.extend_from_slice(&frame);
    }

    /// Write until the socket pushes back. `Err` means the peer is gone.
    fn flush(&mut self) -> io::Result<()> {
        while self.write_pos < self.write_buf.len() {
            match self.stream.write(&self.write_buf[self.write_pos..]) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => self.write_pos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        if self.write_pos == self.write_buf.len() {
            self.write_buf.clear();
            self.write_pos = 0;
        } else if self.write_pos > READ_CHUNK {
            self.write_buf.drain(..self.write_pos);
            self.write_pos = 0;
        }
        Ok(())
    }

    /// Reading is paused while a v3 request is in flight, once the peer
    /// hit EOF or a fatal error, and while a slow reader's replies back
    /// up past the cap.
    fn wants_read(&self) -> bool {
        !self.read_closed
            && !self.closing
            && !self.serial_wait
            && self.pending_write() < WRITE_BUF_CAP
    }

    fn done(&self) -> bool {
        self.closing && self.outstanding == 0 && self.pending_write() == 0
    }
}

// ---- instrumentation sites ------------------------------------------------

static WAKEUPS: OnceLock<Arc<Counter>> = OnceLock::new();
static ACCEPTS: OnceLock<Arc<Counter>> = OnceLock::new();
static COMPLETIONS: OnceLock<Arc<Counter>> = OnceLock::new();
static BUSY_WINDOW: OnceLock<Arc<Counter>> = OnceLock::new();
static CONNS_CLOSED: OnceLock<Arc<Counter>> = OnceLock::new();
static EVREQS: OnceLock<Arc<Counter>> = OnceLock::new();
static DUP_TRACE: OnceLock<Arc<Counter>> = OnceLock::new();

// ---- the loop -------------------------------------------------------------

/// What a poll slot points at.
enum Slot {
    Listener,
    Waker,
    Conn(u64),
}

/// Run the event loop until the server drains. Owns the listener and
/// every accepted connection; spawned once by `NetServer::start` in event
/// mode, alongside the dispatcher pool.
pub(crate) fn run_loop(shared: &Arc<Shared>, listener: TcpListener, cfg: &EventLoopConfig) {
    if listener.set_nonblocking(true).is_err() {
        return; // cannot multiplex a blocking listener
    }
    let max_pipeline = cfg.max_pipeline.max(1);
    let bridge = CompletionBridge::new();
    let outstanding_hist = obs::histogram("net.evloop.outstanding");
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_conn: u64 = 1;
    let mut chunk = vec![0u8; READ_CHUNK];
    let tick = shared.timeouts.read_tick;
    let mut drain_ticks: u32 = 0;

    loop {
        let draining = shared.draining.load(Ordering::Acquire);

        // 1. build the poll set from current interest
        let mut fds: Vec<sys::PollFd> = Vec::with_capacity(conns.len() + 2);
        let mut slots: Vec<Slot> = Vec::with_capacity(conns.len() + 2);
        if !draining {
            fds.push(sys::PollFd {
                fd: sys::raw_fd(&listener),
                events: sys::POLLIN,
                revents: 0,
            });
            slots.push(Slot::Listener);
        }
        if let Some(fd) = bridge.waker.poll_fd() {
            fds.push(sys::PollFd {
                fd,
                events: sys::POLLIN,
                revents: 0,
            });
            slots.push(Slot::Waker);
        }
        for (&key, conn) in &conns {
            let mut events = 0i16;
            if conn.wants_read() {
                events |= sys::POLLIN;
            }
            if conn.pending_write() > 0 {
                events |= sys::POLLOUT;
            }
            if events != 0 {
                fds.push(sys::PollFd {
                    fd: sys::raw_fd(&conn.stream),
                    events,
                    revents: 0,
                });
                slots.push(Slot::Conn(key));
            }
        }

        // 2. sleep until something is ready (tick as drain/backstop)
        sys::poll_fds(&mut fds, tick);
        site_counter("net.evloop.wakeups", &WAKEUPS).inc();

        // 3. route completions into write buffers first: finished work
        // frees window slots before new frames are parsed below
        for c in bridge.drain() {
            shared.inflight.fetch_sub(1, Ordering::AcqRel);
            site_counter("net.evloop.completions", &COMPLETIONS).inc();
            let Some(conn) = conns.get_mut(&c.conn) else {
                continue; // client vanished mid-flight; the row is dropped
            };
            conn.enqueue(
                &Msg::Reply(InferReply {
                    id: c.id,
                    trace: c.trace,
                    replica: c.replica,
                    max_abs_err: c.max_abs_err,
                    logits: c.logits,
                    cost: c.cost,
                }),
                c.tagged.then_some(c.tag),
            );
            conn.outstanding -= 1;
            if c.tagged {
                conn.tags.remove(&c.tag);
            } else {
                conn.serial_wait = false;
                // the v3 pause lifted: frames buffered behind it (a peer
                // may have half-closed after sending them) parse now, not
                // at the next readable event that might never come
                parse_frames(shared, &bridge, conn, c.conn, max_pipeline, &outstanding_hist);
            }
            shared.latency.record(c.t0.elapsed().as_micros() as u64);
        }

        // 4. readable sockets: accept, then pull bytes + parse frames
        for (fd, slot) in fds.iter().zip(&slots) {
            match slot {
                Slot::Listener if fd.revents & sys::POLLIN != 0 => loop {
                    match listener.accept() {
                        Ok((s, _)) => {
                            let _ = s.set_nonblocking(true);
                            let _ = s.set_nodelay(true);
                            site_counter("net.evloop.accepts", &ACCEPTS).inc();
                            conns.insert(next_conn, Conn::new(s));
                            next_conn += 1;
                        }
                        Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                        Err(_) => break, // transient (EMFILE, ...): retry next tick
                    }
                },
                Slot::Conn(key) if fd.revents != 0 => {
                    let Some(conn) = conns.get_mut(key) else {
                        continue;
                    };
                    if conn.wants_read() {
                        read_into(conn, &mut chunk);
                        parse_frames(shared, &bridge, conn, *key, max_pipeline, &outstanding_hist);
                        if conn.read_closed && !conn.read_buf.is_empty() && !conn.serial_wait {
                            // EOF landed mid-frame: nothing can complete it
                            shared.stats.lock().unwrap().proto_errors += 1;
                            conn.read_buf.clear();
                        }
                    }
                }
                _ => {}
            }
        }

        // 5. drain entry: refuse new conns, schedule every conn to close
        // once its outstanding replies are flushed
        if draining {
            for conn in conns.values_mut() {
                conn.closing = true;
            }
        }

        // 6. flush every write buffer; drop dead/finished conns
        conns.retain(|_, conn| {
            if conn.flush().is_err() {
                site_counter("net.evloop.conns_closed", &CONNS_CLOSED).inc();
                return false; // peer gone; in-flight rows are dropped on arrival
            }
            // a half-closed idle peer (EOF, nothing in flight) is done
            if conn.read_closed && conn.outstanding == 0 && conn.pending_write() == 0 {
                site_counter("net.evloop.conns_closed", &CONNS_CLOSED).inc();
                return false;
            }
            if conn.done() {
                site_counter("net.evloop.conns_closed", &CONNS_CLOSED).inc();
                return false;
            }
            true
        });

        if draining {
            drain_ticks += 1;
            let grace_up = drain_ticks > shared.timeouts.drain_grace_ticks;
            if conns.is_empty() || grace_up {
                // force-dropping conns past the grace mirrors the threaded
                // server's drain deadline for wedged peers
                shared.work_cv.notify_all();
                return;
            }
        }
    }
}

/// Pull bytes until `WouldBlock` (or EOF / a fatal error, which stop
/// reading but leave buffered frames to be served — a peer may half-close
/// its write side and still collect replies).
fn read_into(conn: &mut Conn, chunk: &mut [u8]) {
    loop {
        match conn.stream.read(chunk) {
            Ok(0) => {
                conn.read_closed = true;
                return;
            }
            Ok(n) => {
                conn.read_buf.extend_from_slice(&chunk[..n]);
                // cap per-pass intake so one firehose connection cannot
                // starve the rest of the poll set
                if conn.read_buf.len() >= WRITE_BUF_CAP {
                    return;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => {
                conn.read_closed = true;
                conn.closing = true;
                return;
            }
        }
    }
}

/// Parse every complete frame buffered on `conn` and serve each message.
/// Stops at a torn frame (kept for the next readable event), when a v3
/// request pauses the connection, or at a fatal protocol error.
fn parse_frames(
    shared: &Arc<Shared>,
    bridge: &Arc<CompletionBridge>,
    conn: &mut Conn,
    key: u64,
    max_pipeline: usize,
    outstanding_hist: &obs::Histogram,
) {
    let mut pos = 0usize;
    while !conn.serial_wait && !conn.closing {
        let buf = &conn.read_buf[pos..];
        if buf.len() < proto::HEADER_LEN {
            break;
        }
        let h: [u8; proto::HEADER_LEN] = buf[..proto::HEADER_LEN].try_into().unwrap();
        let fh = match proto::parse_header_tagged(&h) {
            Ok(fh) => fh,
            Err(e) => {
                fatal_proto_error(shared, conn, &e);
                break;
            }
        };
        if buf.len() < proto::HEADER_LEN + fh.len {
            break; // torn frame: wait for the rest
        }
        let payload = &buf[proto::HEADER_LEN..proto::HEADER_LEN + fh.len];
        let got = proto::checksum(payload);
        if got != fh.checksum {
            fatal_proto_error(
                shared,
                conn,
                &proto::ProtoError::Checksum {
                    want: fh.checksum,
                    got,
                },
            );
            break;
        }
        let msg = match proto::decode_payload(fh.ty, payload) {
            Ok(m) => m,
            Err(e) => {
                fatal_proto_error(shared, conn, &e);
                break;
            }
        };
        pos += proto::HEADER_LEN + fh.len;
        let tag = fh.tagged().then_some(fh.tag);
        serve_msg(shared, bridge, conn, key, max_pipeline, outstanding_hist, msg, tag);
    }
    if pos > 0 {
        conn.read_buf.drain(..pos);
    }
    if conn.closing {
        conn.read_buf.clear();
    }
}

/// A framed stream cannot be resynced past a bad frame: count it, tell
/// the peer best-effort, close after the write buffer flushes.
fn fatal_proto_error(shared: &Arc<Shared>, conn: &mut Conn, e: &proto::ProtoError) {
    shared.stats.lock().unwrap().proto_errors += 1;
    conn.enqueue(
        &Msg::Error(WireError {
            code: proto::ERR_MALFORMED,
            message: format!("protocol error: {e}"),
        }),
        None,
    );
    conn.closing = true;
}

/// Serve one decoded message on the loop thread. Inline answers (stats,
/// errors, busy) are framed straight into the write buffer; infers are
/// admitted and routed to the dispatcher pool.
#[allow(clippy::too_many_arguments)]
fn serve_msg(
    shared: &Arc<Shared>,
    bridge: &Arc<CompletionBridge>,
    conn: &mut Conn,
    key: u64,
    max_pipeline: usize,
    outstanding_hist: &obs::Histogram,
    msg: Msg,
    tag: Option<u16>,
) {
    match msg {
        Msg::Infer(req) => serve_infer(
            shared,
            bridge,
            conn,
            key,
            max_pipeline,
            outstanding_hist,
            req,
            tag,
        ),
        Msg::StatsReq => {
            let snap: StatsSnapshot = snapshot(shared);
            conn.enqueue(&Msg::Stats(snap), tag);
        }
        Msg::Shutdown => {
            shared.draining.store(true, Ordering::Release);
            shared.work_cv.notify_all();
            conn.enqueue(&Msg::ShutdownAck, tag);
            conn.closing = true;
        }
        // server-to-client types and the shard plane are protocol
        // violations on this endpoint, exactly as in threaded mode
        Msg::Reply(_)
        | Msg::Busy
        | Msg::Error(_)
        | Msg::Stats(_)
        | Msg::ShutdownAck
        | Msg::ShardInstall(_)
        | Msg::ShardAck(_)
        | Msg::Fwd(_)
        | Msg::FwdOut(_) => {
            shared.stats.lock().unwrap().proto_errors += 1;
            conn.enqueue(
                &Msg::Error(WireError {
                    code: proto::ERR_MALFORMED,
                    message: "client sent a server-side message type".to_string(),
                }),
                tag,
            );
            conn.closing = true;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_infer(
    shared: &Arc<Shared>,
    bridge: &Arc<CompletionBridge>,
    conn: &mut Conn,
    key: u64,
    max_pipeline: usize,
    outstanding_hist: &obs::Histogram,
    req: InferRequest,
    tag: Option<u16>,
) {
    let _sp = obs::span("request", "net")
        .arg("trace", req.trace)
        .arg("id", req.id);
    site_counter("net.requests", &EVREQS).inc();
    let want = shared.engine.image_elems();
    if req.image.len() != want {
        conn.enqueue(
            &Msg::Error(WireError {
                code: proto::ERR_BAD_SHAPE,
                message: format!("want {want} image elements, got {}", req.image.len()),
            }),
            tag,
        );
        return;
    }
    if let Some(t) = tag {
        if conn.tags.contains(&t) {
            // two live requests with one tag is a routing ambiguity: the
            // reply stream would be undecodable, so the connection dies
            shared.stats.lock().unwrap().proto_errors += 1;
            conn.enqueue(
                &Msg::Error(WireError {
                    code: proto::ERR_MALFORMED,
                    message: format!("duplicate in-flight tag {t}"),
                }),
                tag,
            );
            conn.closing = true;
            return;
        }
        if conn.outstanding >= max_pipeline {
            // per-request backpressure: this request is refused, the
            // window's worth already in flight proceeds untouched
            site_counter("net.evloop.busy_window", &BUSY_WINDOW).inc();
            shared.stats.lock().unwrap().busy += 1;
            conn.enqueue(&Msg::Busy, tag);
            return;
        }
    }
    if shared.draining.load(Ordering::Acquire) {
        conn.enqueue(
            &Msg::Error(WireError {
                code: proto::ERR_DRAINING,
                message: "server is draining".to_string(),
            }),
            tag,
        );
        return;
    }
    if !try_admit(shared) {
        shared.stats.lock().unwrap().busy += 1;
        conn.enqueue(&Msg::Busy, tag);
        return;
    }

    let sid = shared.next_id.fetch_add(1, Ordering::Relaxed);
    let t0 = Instant::now();
    {
        let mut q = shared.queue.lock().unwrap();
        // re-check under the queue lock (the dispatcher exit check holds
        // it): an admitted request is guaranteed to be flushed by a drain
        if shared.draining.load(Ordering::Acquire) {
            drop(q);
            shared.inflight.fetch_sub(1, Ordering::AcqRel);
            conn.enqueue(
                &Msg::Error(WireError {
                    code: proto::ERR_DRAINING,
                    message: "server is draining".to_string(),
                }),
                tag,
            );
            return;
        }
        q.routes.insert(
            sid,
            RouteSink::Event {
                bridge: bridge.clone(),
                conn: key,
                tag: tag.unwrap_or(0),
                tagged: tag.is_some(),
                id: req.id,
                trace: req.trace,
                t0,
            },
        );
        q.batcher.push(PendingRequest {
            id: sid,
            trace: req.trace,
            image: req.image,
            enqueued: Instant::now(),
        });
    }
    if shared.traces.lock().unwrap().check_insert(req.trace) {
        site_counter("net.dup_trace_dispatch", &DUP_TRACE).inc();
        obs::event(
            "dup_trace_dispatch",
            "net",
            &[("trace", req.trace), ("id", req.id)],
        );
    }
    shared.work_cv.notify_one();
    conn.outstanding += 1;
    if let Some(t) = tag {
        conn.tags.insert(t);
    } else {
        conn.serial_wait = true;
    }
    outstanding_hist.record(conn.outstanding as u64);
}
