//! TCP serving endpoint: admission-limited, batched, drain-on-shutdown.
//!
//! Topology (one [`NetServer`], default threaded mode):
//!
//! * an **accept loop** thread takes connections off the `TcpListener`
//!   and spawns one handler thread per connection;
//! * **handler** threads decode frames, enforce the admission limit
//!   (explicit [`Msg::Busy`] backpressure — never unbounded queueing),
//!   push admitted requests into the shared [`Batcher`], and block on a
//!   per-request channel for the result;
//! * one **dispatcher** thread closes batches (full, or the batching
//!   deadline passed), runs each through the [`Engine`] — for the golden
//!   engine that is the `Batcher` -> `sched::Executor` ->
//!   `GoldenServer::replicated` path with round-robin replica affinity —
//!   and routes per-row results back to the waiting handlers.
//!
//! With [`ServeConfig::event_loop`] set, the accept/handler tier is
//! replaced by one readiness-driven event-loop thread
//! ([`crate::net::event_loop`]) holding every connection on nonblocking
//! sockets, plus a fixed pool of dispatcher threads; connections then
//! cost file descriptors, not threads, and a connection may pipeline up
//! to `max_pipeline` tagged (proto v4) requests. Both modes share this
//! module's admission, batching, dispatch, stats, and admin plumbing —
//! the event mode routes replies through a [`RouteSink::Event`]
//! completion bridge instead of a per-handler channel.
//!
//! Shutdown is a drain, not an abort: a `Shutdown` frame (or
//! [`NetServer::shutdown`]) flips the draining flag, the listener closes,
//! new inference requests are refused with `ERR_DRAINING`, the dispatcher
//! flushes every pending batch (including a partial tail), every blocked
//! handler receives and writes its reply, and all threads join. Stats
//! survive the drain and are returned from `join`/`shutdown`.
//!
//! A protocol error on a connection is fatal to that connection only (a
//! framed stream cannot be resynced past a bad frame); the server itself
//! keeps serving, and abrupt client disconnects are routine, not errors.
//!
//! Two optional side-planes ride the same lifecycle:
//!
//! * an **admin plane** (`ServeConfig::admin_addr`) — a second listener
//!   that answers every connection with one plain-text metrics exposition
//!   (sorted `name{label="v"} value` lines rendered from the `obs`
//!   registry, server stats, replica health, and the hardware cost
//!   ledger) and closes. Pull-based and frameless: `curl`, `nc`, or
//!   `newton statz` can scrape it without speaking the binary protocol,
//!   and a wedged scraper can never block the serving path;
//! * a **watchdog** on the admin thread: every tick it compares request
//!   p99 latency and energy-per-inference against a baseline frozen over
//!   the first ticks after startup, raises `obs.anomaly.*` counters on
//!   drift, and latches the exposition's `newton_degraded` line.

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, PendingRequest};
use crate::coordinator::Batch;
use crate::net::event_loop::{self, Completion, CompletionBridge, EventLoopConfig};
use crate::net::proto::{
    self, InferReply, InferRequest, Msg, ProtoError, StatsSnapshot, WireError,
};
use crate::net::Engine;
use crate::obs::{self, Counter, Histogram};

/// Every wall-clock knob the server's IO path uses, in one place.
///
/// These used to be scattered `const`s (plus a hardcoded connect timeout
/// buried in the accept wake-up); hoisting them into a config struct makes
/// them overridable from `serve-net` flags (`--read-tick-ms`,
/// `--write-timeout-ms`, `--wake-timeout-ms`) and lets tests tighten them
/// without waiting on production-sized timeouts.
#[derive(Clone, Debug)]
pub struct Timeouts {
    /// Read-timeout tick: handlers wake this often to notice a drain.
    pub read_tick: Duration,
    /// Write timeout: a dead client cannot wedge a handler forever.
    pub write_timeout: Duration,
    /// Connect timeout for the drain wake-up dial in [`wake_accept`] (was
    /// a hardcoded 1s), so a pathological network setup can never wedge
    /// shutdown.
    pub wake_connect: Duration,
    /// Read ticks a handler keeps waiting for the rest of a half-received
    /// frame once draining started, before giving the connection up.
    pub drain_grace_ticks: u32,
    /// Read ticks an *idle* connection stays open once draining started,
    /// so a request crossing the drain on the wire still gets its
    /// `ERR_DRAINING` reply instead of a bare EOF.
    pub drain_idle_ticks: u32,
}

impl Default for Timeouts {
    fn default() -> Self {
        Timeouts {
            read_tick: Duration::from_millis(100),
            write_timeout: Duration::from_secs(5),
            wake_connect: Duration::from_secs(1),
            drain_grace_ticks: 25,
            drain_idle_ticks: 2,
        }
    }
}

/// Server knobs. The batch shape itself comes from the [`Engine`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`NetServer::local_addr`]).
    pub addr: String,
    /// Admission limit: requests in flight (admitted, not yet replied)
    /// beyond this are refused with [`Msg::Busy`]. Must be >= 1.
    pub max_inflight: usize,
    /// vLLM-style batching deadline: a partial batch closes once its
    /// oldest request has waited this long.
    pub batch_wait: Duration,
    /// IO timeouts (read tick, write timeout, drain windows).
    pub timeouts: Timeouts,
    /// Admin-plane bind address (`None` disables the plane and its
    /// watchdog). Port 0 picks an ephemeral port — see
    /// [`NetServer::admin_addr`].
    pub admin_addr: Option<String>,
    /// Attach a per-request [`proto::CostReport`] to every `Reply` frame
    /// (proto v3 tail). Off by default: replies carry zero extra bytes.
    pub cost_reports: bool,
    /// `Some` switches the server to readiness-driven event-loop serving
    /// (nonblocking connections on one poll thread, a fixed dispatcher
    /// pool, per-connection pipelining up to `max_pipeline`). `None` (the
    /// default) keeps the thread-per-connection handler tier.
    pub event_loop: Option<EventLoopConfig>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_inflight: 64,
            batch_wait: Duration::from_millis(2),
            timeouts: Timeouts::default(),
            admin_addr: None,
            cost_reports: false,
            event_loop: None,
        }
    }
}

/// What the dispatcher hands back to a blocked handler: replica, batch
/// max-abs-err, the row's logits, and (when cost reports are on) the
/// request's amortised share of the batch's hardware cost.
pub(crate) type RouteReply = (u32, i64, Vec<i32>, Option<proto::CostReport>);

/// Where a dispatched request's reply goes: a blocked handler thread
/// (threaded mode) or the event loop's completion bridge (event mode).
pub(crate) enum RouteSink {
    /// Threaded mode: the handler blocks on the receiving end.
    Blocking(Sender<RouteReply>),
    /// Event mode: the reply is queued on the loop's [`CompletionBridge`]
    /// with everything needed to frame it without the loop re-looking the
    /// request up (connection key, v4 tag, client-visible id/trace, and
    /// the admission timestamp for the latency histogram).
    Event {
        bridge: Arc<CompletionBridge>,
        conn: u64,
        tag: u16,
        tagged: bool,
        id: u64,
        trace: u64,
        t0: Instant,
    },
}

/// Batcher plus the routing table, under one lock so an admission check,
/// route registration, and push are atomic against the dispatcher's
/// empty-and-draining exit check.
pub(crate) struct Queue {
    pub(crate) batcher: Batcher,
    pub(crate) routes: HashMap<u64, RouteSink>,
}

pub(crate) struct StatsInner {
    pub(crate) served: u64,
    pub(crate) busy: u64,
    pub(crate) proto_errors: u64,
    pub(crate) batches: u64,
    pub(crate) fill_sum: f64,
    pub(crate) worst_abs_err: i64,
    pub(crate) per_replica: Vec<u64>,
}

impl StatsInner {
    fn new(n_replicas: usize) -> Self {
        StatsInner {
            served: 0,
            busy: 0,
            proto_errors: 0,
            batches: 0,
            fill_sum: 0.0,
            worst_abs_err: 0,
            per_replica: vec![0; n_replicas],
        }
    }
}

/// Recently-dispatched client trace ids, bounded FIFO. A `RetryClient`
/// resend after a lost reply re-dispatches the same trace id on a fresh
/// connection; this window makes that duplicate-dispatch path observable
/// (counter + instant event) without unbounded memory.
pub(crate) struct TraceDedup {
    order: VecDeque<u64>,
    seen: HashSet<u64>,
}

/// Resends arrive within a retry deadline of the original, so a small
/// window of recent dispatches is enough to catch them.
const TRACE_DEDUP_WINDOW: usize = 1024;

impl TraceDedup {
    fn new() -> Self {
        TraceDedup {
            order: VecDeque::with_capacity(TRACE_DEDUP_WINDOW),
            seen: HashSet::with_capacity(TRACE_DEDUP_WINDOW),
        }
    }

    /// Record a dispatch; true if `trace` was already dispatched recently.
    pub(crate) fn check_insert(&mut self, trace: u64) -> bool {
        if trace == 0 {
            return false; // untraced request
        }
        if !self.seen.insert(trace) {
            return true;
        }
        self.order.push_back(trace);
        if self.order.len() > TRACE_DEDUP_WINDOW {
            if let Some(old) = self.order.pop_front() {
                self.seen.remove(&old);
            }
        }
        false
    }
}

/// Instrumentation-site counter cache: registry lookup once, relaxed
/// atomic add afterwards.
pub(crate) fn site_counter(
    name: &'static str,
    slot: &'static OnceLock<Arc<Counter>>,
) -> &'static Counter {
    slot.get_or_init(|| obs::counter(name))
}

static DUP_TRACE: OnceLock<Arc<Counter>> = OnceLock::new();
static REQS: OnceLock<Arc<Counter>> = OnceLock::new();

pub(crate) struct Shared {
    pub(crate) engine: Arc<dyn Engine>,
    pub(crate) local_addr: SocketAddr,
    pub(crate) batch_wait: Duration,
    pub(crate) timeouts: Timeouts,
    pub(crate) max_inflight: usize,
    pub(crate) inflight: AtomicUsize,
    pub(crate) draining: AtomicBool,
    pub(crate) next_id: AtomicU64,
    pub(crate) queue: Mutex<Queue>,
    pub(crate) work_cv: Condvar,
    pub(crate) stats: Mutex<StatsInner>,
    /// Request latency (admission -> reply written), µs. A log-bucket
    /// histogram outside the stats mutex: recording is two relaxed atomic
    /// adds on the reply path, and exact-bucket p50/p99/p999 replace the
    /// reservoir sampler whose tail quantiles were sampling-noisy at high
    /// request counts.
    pub(crate) latency: Histogram,
    pub(crate) traces: Mutex<TraceDedup>,
    /// Attach per-request cost reports to replies (proto v3 tail).
    pub(crate) cost_reports: bool,
    /// Admin-plane bound address, when the plane is enabled.
    pub(crate) admin_addr: Option<SocketAddr>,
    /// Latched by the watchdog on p99-latency or energy-per-inference
    /// drift; surfaces as `newton_degraded 1` in the admin exposition.
    pub(crate) watchdog_degraded: AtomicBool,
    /// Global batch index shared by every dispatcher thread: the engine's
    /// round-robin replica affinity keys off this, so N event-mode
    /// dispatchers spread batches across replicas the same way one does.
    pub(crate) batch_seq: AtomicUsize,
    /// Set after the serving threads joined; the admin loop keeps
    /// answering scrapes through the whole drain and exits on this, so a
    /// scrape racing a shutdown still gets its exposition.
    pub(crate) admin_stop: AtomicBool,
}

/// A running TCP serving endpoint (threaded or event-loop mode — see the
/// module docs; the mode is picked by [`ServeConfig::event_loop`]).
pub struct NetServer {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    dispatcher: Option<JoinHandle<()>>,
    admin: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// Event mode: the poll-loop thread holding every connection.
    loop_thread: Option<JoinHandle<()>>,
    /// Event mode: the fixed dispatcher pool.
    workers: Vec<JoinHandle<()>>,
}

impl NetServer {
    /// Bind and start serving `engine` with `cfg`. Returns once the
    /// listener is bound (the actual address is [`Self::local_addr`]).
    pub fn start(engine: Arc<dyn Engine>, cfg: ServeConfig) -> io::Result<NetServer> {
        assert!(cfg.max_inflight >= 1, "max_inflight must be >= 1");
        let listener = TcpListener::bind(cfg.addr.as_str())?;
        let local_addr = listener.local_addr()?;
        // bind the admin plane before any thread starts so a bad admin
        // address fails the whole start, not a background thread
        let admin_listener = match &cfg.admin_addr {
            Some(a) => Some(TcpListener::bind(a.as_str())?),
            None => None,
        };
        let admin_addr = match &admin_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };
        let shared = Arc::new(Shared {
            local_addr,
            batch_wait: cfg.batch_wait,
            timeouts: cfg.timeouts.clone(),
            max_inflight: cfg.max_inflight,
            inflight: AtomicUsize::new(0),
            draining: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            queue: Mutex::new(Queue {
                batcher: Batcher::new(engine.batch_capacity(), engine.image_elems(), cfg.batch_wait),
                routes: HashMap::new(),
            }),
            work_cv: Condvar::new(),
            stats: Mutex::new(StatsInner::new(engine.n_replicas())),
            latency: Histogram::new(),
            traces: Mutex::new(TraceDedup::new()),
            cost_reports: cfg.cost_reports,
            admin_addr,
            watchdog_degraded: AtomicBool::new(false),
            batch_seq: AtomicUsize::new(0),
            admin_stop: AtomicBool::new(false),
            engine,
        });
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let (accept, loop_thread, workers) = match &cfg.event_loop {
            None => {
                let dispatcher = {
                    let shared = shared.clone();
                    std::thread::spawn(move || dispatch_loop(&shared))
                };
                let accept = {
                    let shared = shared.clone();
                    let handlers = handlers.clone();
                    std::thread::spawn(move || accept_loop(&shared, listener, &handlers))
                };
                (Some(accept), None, vec![dispatcher])
            }
            Some(el) => {
                let el = el.clone();
                let n_workers = el.workers.max(1);
                let workers: Vec<JoinHandle<()>> = (0..n_workers)
                    .map(|_| {
                        let shared = shared.clone();
                        std::thread::spawn(move || dispatch_loop(&shared))
                    })
                    .collect();
                let loop_thread = {
                    let shared = shared.clone();
                    std::thread::spawn(move || event_loop::run_loop(&shared, listener, &el))
                };
                (None, Some(loop_thread), workers)
            }
        };
        let admin = admin_listener.map(|l| {
            let shared = shared.clone();
            std::thread::spawn(move || admin_loop(&shared, l))
        });
        // in threaded mode the single dispatcher rides the old field so
        // join order stays identical to the pre-event-loop server
        let (dispatcher, workers) = match (accept.is_some(), workers) {
            (true, mut v) => (v.pop(), Vec::new()),
            (false, v) => (None, v),
        };
        Ok(NetServer {
            shared,
            accept,
            dispatcher,
            admin,
            handlers,
            loop_thread,
            workers,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.local_addr
    }

    /// The admin plane's bound address (resolves port 0); `None` when the
    /// plane is disabled.
    pub fn admin_addr(&self) -> Option<SocketAddr> {
        self.shared.admin_addr
    }

    /// True once a drain started (client `Shutdown` frame or
    /// [`Self::shutdown`]).
    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::Acquire)
    }

    /// Current statistics snapshot.
    pub fn stats(&self) -> StatsSnapshot {
        snapshot(&self.shared)
    }

    /// Block until a client-initiated `Shutdown` drains the server, then
    /// join every thread and return the final stats.
    pub fn join(mut self) -> StatsSnapshot {
        self.join_all();
        snapshot(&self.shared)
    }

    /// Server-side shutdown: initiate the drain locally and join.
    pub fn shutdown(mut self) -> StatsSnapshot {
        self.shared.draining.store(true, Ordering::Release);
        self.shared.work_cv.notify_all();
        wake_accept(&self.shared);
        self.join_all();
        snapshot(&self.shared)
    }

    fn join_all(&mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // accept loop is gone, so no new handlers appear; drain the list
        // (handlers exit within a read tick of the drain flag)
        loop {
            let hs: Vec<JoinHandle<()>> =
                std::mem::take(&mut *self.handlers.lock().unwrap());
            if hs.is_empty() {
                break;
            }
            for h in hs {
                let _ = h.join();
            }
        }
        // event mode: the loop thread owns the listener and every
        // connection; it exits once the drain flushed all outstanding
        // replies, after which the dispatcher pool sees empty-and-draining
        if let Some(l) = self.loop_thread.take() {
            let _ = l.join();
        }
        self.shared.work_cv.notify_all();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // only now stop the admin plane: it keeps answering scrapes for
        // the whole drain (a scrape racing a shutdown still gets its
        // exposition), and exits within one poll of `admin_stop`
        self.shared.admin_stop.store(true, Ordering::Release);
        if let Some(a) = self.admin.take() {
            let _ = a.join();
        }
    }
}

/// Dial the listener to pop its accept loop out of `incoming()`. A
/// wildcard bind (0.0.0.0 / ::) is not dialable on every platform, so the
/// wake-up targets loopback at the bound port, with a timeout so a
/// pathological network setup can never wedge the caller.
fn wake_accept(shared: &Shared) {
    let mut addr = shared.local_addr;
    if addr.ip().is_unspecified() {
        addr.set_ip(match addr.ip() {
            std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
            std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
        });
    }
    let _ = TcpStream::connect_timeout(&addr, shared.timeouts.wake_connect);
}

pub(crate) fn snapshot(shared: &Shared) -> StatsSnapshot {
    let health = shared.engine.health();
    let lat = shared.latency.snapshot();
    let metrics = obs::metrics_snapshot().counters;
    let s = shared.stats.lock().unwrap();
    StatsSnapshot {
        served: s.served,
        busy: s.busy,
        proto_errors: s.proto_errors,
        batches: s.batches,
        batch_fill: if s.batches > 0 {
            s.fill_sum / s.batches as f64
        } else {
            0.0
        },
        worst_abs_err: s.worst_abs_err,
        p50_us: lat.percentile(0.50),
        p99_us: lat.percentile(0.99),
        p999_us: lat.percentile(0.999),
        per_replica: s.per_replica.clone(),
        reruns: health.as_ref().map_or(0, |h| h.reruns),
        quarantines: health.as_ref().map_or(0, |h| h.quarantines),
        degraded: health.as_ref().is_some_and(|h| h.degraded),
        health: health.map_or_else(Vec::new, |h| h.states),
        metrics,
    }
}

// ---- accept + dispatch ---------------------------------------------------

fn accept_loop(
    shared: &Arc<Shared>,
    listener: TcpListener,
    handlers: &Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    for conn in listener.incoming() {
        if shared.draining.load(Ordering::Acquire) {
            break; // the wake-up connection (or any late dial) during drain
        }
        let Ok(stream) = conn else {
            // transient accept failures (EMFILE under fd exhaustion, ...)
            // must not busy-spin the accept thread
            std::thread::sleep(Duration::from_millis(10));
            continue;
        };
        let sh = shared.clone();
        let h = std::thread::spawn(move || handle_conn(&sh, stream));
        let mut hs = handlers.lock().unwrap();
        // reap finished handlers so a long-lived endpoint with many
        // short-lived connections doesn't accrete JoinHandles
        let mut i = 0;
        while i < hs.len() {
            if hs[i].is_finished() {
                let _ = hs.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        hs.push(h);
    }
    // listener drops here: further connects are refused
}

/// Close and return the next batch, or `None` once draining and empty.
pub(crate) fn next_batch(shared: &Shared) -> Option<Batch> {
    let mut q = shared.queue.lock().unwrap();
    loop {
        if q.batcher.ready(Instant::now()) {
            if let Some(b) = q.batcher.take_batch() {
                return Some(b);
            }
        }
        if shared.draining.load(Ordering::Acquire) {
            // flush the partial tail before retiring
            return q.batcher.take_batch();
        }
        // pushes and drains notify the condvar, so an idle dispatcher can
        // sleep long (the timeout is only a safety backstop); with work
        // pending it wakes at batching-deadline granularity instead
        let timeout = if q.batcher.pending_len() > 0 {
            shared.batch_wait.max(Duration::from_millis(1))
        } else {
            Duration::from_millis(500)
        };
        let (guard, _) = shared.work_cv.wait_timeout(q, timeout).unwrap();
        q = guard;
    }
}

pub(crate) fn dispatch_loop(shared: &Arc<Shared>) {
    while let Some(b) = next_batch(shared) {
        // global sequence, not a thread-local counter: event mode runs N
        // dispatchers and replica affinity must round-robin across all of
        // them the way it does with one
        let batch_index = shared.batch_seq.fetch_add(1, Ordering::Relaxed);
        let _sp = obs::span("dispatch", "net")
            .arg("batch", batch_index as u64)
            .arg("n_real", b.n_real as u64)
            .arg("trace0", b.traces.first().copied().unwrap_or(0));
        let out = shared.engine.run(batch_index, &b);
        debug_assert_eq!(out.logits.len(), b.n_real, "engine row count");
        // account the batch *before* releasing replies: a client that has
        // its reply in hand must see it reflected in a stats request
        {
            let mut s = shared.stats.lock().unwrap();
            s.served += b.n_real as u64;
            s.batches += 1;
            s.fill_sum += b.n_real as f64 / shared.engine.batch_capacity() as f64;
            s.worst_abs_err = s.worst_abs_err.max(out.max_abs_err);
            if out.replica < s.per_replica.len() {
                s.per_replica[out.replica] += b.n_real as u64;
            }
        }
        // amortise the batch's hardware cost over its real rows: each
        // request's CostReport answers "what did my inference cost" in
        // batch-share terms (zeros when the ledger is off — the flag, not
        // the ledger, decides presence, so the wire contract is stable)
        let cost = (shared.cost_reports && b.n_real > 0).then(|| {
            let n = b.n_real as u64;
            proto::CostReport {
                adc_ops: out.cost.adc_ops() / n,
                identity_folds: out.cost.identity_folds / n,
                slice_iters_executed: out.cost.slice_iters_executed / n,
                slice_iters_folded: out.cost.slice_iters_folded / n,
                slice_iters_skipped: out.cost.slice_iters_skipped / n,
                rows: out.cost.rows() / n,
                energy_pj: out.energy_pj / b.n_real as f64,
            }
        });
        let sinks: Vec<Option<RouteSink>> = {
            let mut q = shared.queue.lock().unwrap();
            b.ids.iter().map(|id| q.routes.remove(id)).collect()
        };
        for (sink, logits) in sinks.into_iter().zip(out.logits.into_iter()) {
            match sink {
                // a handler that died mid-wait just drops the receiver
                Some(RouteSink::Blocking(tx)) => {
                    let _ = tx.send((out.replica as u32, out.max_abs_err, logits, cost));
                }
                Some(RouteSink::Event {
                    bridge,
                    conn,
                    tag,
                    tagged,
                    id,
                    trace,
                    t0,
                }) => bridge.complete(Completion {
                    conn,
                    tag,
                    tagged,
                    id,
                    trace,
                    t0,
                    replica: out.replica as u32,
                    max_abs_err: out.max_abs_err,
                    logits,
                    cost,
                }),
                None => {}
            }
        }
    }
}

// ---- per-connection handling ---------------------------------------------

/// Echo a reply in the framing its request used: tagged v4 when the
/// request carried a tag, untagged v3 otherwise — which keeps the
/// threaded server byte-exact for v3 peers while still answering a
/// pipelined client correctly (serialized, but correctly tagged).
fn write_echo(stream: &mut TcpStream, m: &Msg, tag: Option<u16>) -> io::Result<()> {
    match tag {
        Some(t) => proto::write_msg_tagged(stream, m, t),
        None => proto::write_msg(stream, m),
    }
}

fn handle_conn(shared: &Arc<Shared>, mut stream: TcpStream) {
    let _conn_sp = obs::span_verbose("conn", "net");
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(shared.timeouts.read_tick));
    let _ = stream.set_write_timeout(Some(shared.timeouts.write_timeout));
    loop {
        match read_msg_idle(&mut stream, shared) {
            Ok(Some((tag, msg))) => {
                if !serve_msg(shared, &mut stream, msg, tag) {
                    break;
                }
                // once draining, finish the message in hand and close:
                // a client polling stats or retrying infers must not be
                // able to keep its handler alive past the drain
                if shared.draining.load(Ordering::Acquire) {
                    break;
                }
            }
            Ok(None) => break, // clean close, or idle connection at drain
            Err(e) => {
                shared.stats.lock().unwrap().proto_errors += 1;
                // best-effort: tell the peer why before closing — the
                // stream cannot be resynced past a bad frame
                let _ = proto::write_msg(
                    &mut stream,
                    &Msg::Error(WireError {
                        code: proto::ERR_MALFORMED,
                        message: format!("protocol error: {e}"),
                    }),
                );
                break;
            }
        }
    }
}

/// `read_exact` that tolerates the handler's read-timeout ticks. Returns
/// `Ok(false)` for a clean stop (EOF or drain-idle, only possible at a
/// frame boundary with nothing consumed), `Ok(true)` when `buf` is full.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    shared: &Shared,
    frame_start: bool,
) -> Result<bool, ProtoError> {
    let mut off = 0;
    let mut drain_ticks = 0u32;
    while off < buf.len() {
        match stream.read(&mut buf[off..]) {
            Ok(0) => {
                if off == 0 && frame_start {
                    return Ok(false);
                }
                return Err(ProtoError::Malformed("connection closed mid-frame"));
            }
            Ok(n) => off += n,
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                if shared.draining.load(Ordering::Acquire) {
                    drain_ticks += 1;
                    if off == 0 && frame_start {
                        if drain_ticks > shared.timeouts.drain_idle_ticks {
                            return Ok(false);
                        }
                    } else if drain_ticks > shared.timeouts.drain_grace_ticks {
                        return Err(ProtoError::Malformed("drain deadline passed mid-frame"));
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
    Ok(true)
}

/// Server-side frame read with drain awareness. `Ok(None)` means the
/// connection is done (peer closed, or idle while draining). The inner
/// pair is `(tag, msg)`: `Some(tag)` for a v4 frame, `None` for v3.
#[allow(clippy::type_complexity)]
fn read_msg_idle(
    stream: &mut TcpStream,
    shared: &Shared,
) -> Result<Option<(Option<u16>, Msg)>, ProtoError> {
    let mut h = [0u8; proto::HEADER_LEN];
    if !read_full(stream, &mut h, shared, true)? {
        return Ok(None);
    }
    let fh = proto::parse_header_tagged(&h)?;
    let mut payload = vec![0u8; fh.len];
    if fh.len > 0 && !read_full(stream, &mut payload, shared, false)? {
        return Err(ProtoError::Malformed("connection closed mid-frame"));
    }
    let got = proto::checksum(&payload);
    if got != fh.checksum {
        return Err(ProtoError::Checksum {
            want: fh.checksum,
            got,
        });
    }
    let _sp = obs::span_verbose("decode", "net").arg("len", payload.len() as u64);
    let tag = if fh.tagged() { Some(fh.tag) } else { None };
    proto::decode_payload(fh.ty, &payload).map(|m| Some((tag, m)))
}

/// Handle one decoded message; returns false when the connection should
/// close. `tag` is echoed on every reply frame (v4 requests get v4
/// replies) — the threaded server serializes pipelined requests but
/// stays protocol-conformant for them.
fn serve_msg(shared: &Arc<Shared>, stream: &mut TcpStream, msg: Msg, tag: Option<u16>) -> bool {
    match msg {
        Msg::Infer(req) => serve_infer(shared, stream, req, tag),
        Msg::StatsReq => write_echo(stream, &Msg::Stats(snapshot(shared)), tag).is_ok(),
        Msg::Shutdown => {
            shared.draining.store(true, Ordering::Release);
            shared.work_cv.notify_all();
            let _ = write_echo(stream, &Msg::ShutdownAck, tag);
            wake_accept(shared);
            false
        }
        // server-to-client message types arriving at the server are a
        // protocol violation, as is the coordinator/worker shard plane —
        // this endpoint serves clients, not inter-shard forwards
        Msg::Reply(_)
        | Msg::Busy
        | Msg::Error(_)
        | Msg::Stats(_)
        | Msg::ShutdownAck
        | Msg::ShardInstall(_)
        | Msg::ShardAck(_)
        | Msg::Fwd(_)
        | Msg::FwdOut(_) => {
            shared.stats.lock().unwrap().proto_errors += 1;
            let _ = write_echo(
                stream,
                &Msg::Error(WireError {
                    code: proto::ERR_MALFORMED,
                    message: "client sent a server-side message type".to_string(),
                }),
                tag,
            );
            false
        }
    }
}

/// CAS admission against the in-flight ceiling.
pub(crate) fn try_admit(shared: &Shared) -> bool {
    let mut cur = shared.inflight.load(Ordering::Acquire);
    loop {
        if cur >= shared.max_inflight {
            return false;
        }
        match shared.inflight.compare_exchange_weak(
            cur,
            cur + 1,
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => return true,
            Err(seen) => cur = seen,
        }
    }
}

fn serve_infer(
    shared: &Arc<Shared>,
    stream: &mut TcpStream,
    req: InferRequest,
    tag: Option<u16>,
) -> bool {
    let _sp = obs::span("request", "net")
        .arg("trace", req.trace)
        .arg("id", req.id);
    site_counter("net.requests", &REQS).inc();
    let want = shared.engine.image_elems();
    if req.image.len() != want {
        return write_echo(
            stream,
            &Msg::Error(WireError {
                code: proto::ERR_BAD_SHAPE,
                message: format!("want {want} image elements, got {}", req.image.len()),
            }),
            tag,
        )
        .is_ok();
    }
    let draining_err = Msg::Error(WireError {
        code: proto::ERR_DRAINING,
        message: "server is draining".to_string(),
    });
    if shared.draining.load(Ordering::Acquire) {
        return write_echo(stream, &draining_err, tag).is_ok();
    }
    if !try_admit(shared) {
        shared.stats.lock().unwrap().busy += 1;
        return write_echo(stream, &Msg::Busy, tag).is_ok();
    }

    let sid = shared.next_id.fetch_add(1, Ordering::Relaxed);
    let (tx, rx) = mpsc::channel::<RouteReply>();
    let t0 = Instant::now();
    {
        let mut q = shared.queue.lock().unwrap();
        // re-check under the queue lock: the dispatcher's exit check holds
        // the same lock, so a request admitted here is guaranteed to be
        // flushed by the drain
        if shared.draining.load(Ordering::Acquire) {
            drop(q);
            shared.inflight.fetch_sub(1, Ordering::AcqRel);
            return write_echo(stream, &draining_err, tag).is_ok();
        }
        q.routes.insert(sid, RouteSink::Blocking(tx));
        q.batcher.push(PendingRequest {
            id: sid,
            trace: req.trace,
            image: req.image,
            enqueued: Instant::now(),
        });
    }
    // the request is now committed to dispatch: surface a resent trace id
    // (RetryClient reconnect after a lost reply) as the duplicate-dispatch
    // path — the answer is idempotent, so it is served, not refused
    if shared.traces.lock().unwrap().check_insert(req.trace) {
        site_counter("net.dup_trace_dispatch", &DUP_TRACE).inc();
        obs::event("dup_trace_dispatch", "net", &[("trace", req.trace), ("id", req.id)]);
    }
    shared.work_cv.notify_one();

    let reply = rx.recv();
    shared.inflight.fetch_sub(1, Ordering::AcqRel);
    match reply {
        Ok((replica, max_abs_err, logits, cost)) => {
            let ok = {
                let _enc = obs::span_verbose("encode", "net").arg("trace", req.trace);
                write_echo(
                    stream,
                    &Msg::Reply(InferReply {
                        id: req.id,
                        trace: req.trace,
                        replica,
                        max_abs_err,
                        logits,
                        cost,
                    }),
                    tag,
                )
                .is_ok()
            };
            shared.latency.record(t0.elapsed().as_micros() as u64);
            ok
        }
        // dispatcher gone without replying: only possible if it panicked
        Err(_) => write_echo(
            stream,
            &Msg::Error(WireError {
                code: proto::ERR_INTERNAL,
                message: "dispatcher terminated before replying".to_string(),
            }),
            tag,
        )
        .is_ok(),
    }
}

// ---- admin plane ---------------------------------------------------------

/// Readiness-poll backstop for the admin listener: the thread normally
/// sleeps in `poll(2)` until a scrape dials in, and wakes at most this
/// often to run the watchdog tick and check the stop flag. (This replaced
/// a 20ms nonblocking-accept busy loop that burned ~50 wakeups/s while
/// idle.)
const ADMIN_POLL: Duration = Duration::from_millis(50);
/// Watchdog cadence: drift checks run at this interval, not per scrape.
const WATCHDOG_TICK: Duration = Duration::from_millis(250);

/// Render the pull-plane text exposition: one `name{label="v"} value`
/// line per fact, sorted lexicographically so consecutive scrapes diff
/// cleanly and tests can pin positions. Frameless plain text — any
/// read-to-EOF client (curl, nc, `newton statz`) can consume it.
fn render_exposition(shared: &Shared) -> String {
    let snap = obs::metrics_snapshot();
    let stats = snapshot(shared);
    let mut lines: Vec<String> = Vec::new();
    for (name, v) in &snap.counters {
        lines.push(format!("newton_counter{{name=\"{name}\"}} {v}"));
    }
    for (name, h) in &snap.histograms {
        for (stat, v) in [
            ("count", h.count),
            ("sum", h.sum),
            ("p50", h.percentile(0.50)),
            ("p99", h.percentile(0.99)),
        ] {
            lines.push(format!("newton_histogram{{name=\"{name}\",stat=\"{stat}\"}} {v}"));
        }
    }
    for (r, &s) in stats.health.iter().enumerate() {
        let state = crate::coordinator::health::HealthState::from_u8(s).label();
        lines.push(format!("newton_replica_health{{replica=\"{r}\",state=\"{state}\"}} 1"));
    }
    lines.push(format!("newton_served {}", stats.served));
    lines.push(format!("newton_busy {}", stats.busy));
    lines.push(format!("newton_batches {}", stats.batches));
    lines.push(format!("newton_latency_us{{stat=\"p50\"}} {}", stats.p50_us));
    lines.push(format!("newton_latency_us{{stat=\"p99\"}} {}", stats.p99_us));
    // ledger aggregate -> live energy-per-inference gauge (0 until the
    // first ledgered batch retires, or always 0 with the ledger off)
    let energy_pj = snap
        .counters
        .iter()
        .find(|(n, _)| n == "ledger.energy_pj")
        .map_or(0, |&(_, v)| v);
    let epi = if stats.served > 0 {
        energy_pj as f64 / stats.served as f64
    } else {
        0.0
    };
    lines.push(format!("newton_energy_pj_per_infer {epi:.3}"));
    let degraded = stats.degraded
        || shared.watchdog_degraded.load(Ordering::Acquire)
        || shared.engine.degraded();
    lines.push(format!("newton_degraded {}", degraded as u8));
    lines.sort();
    let mut out = lines.join("\n");
    out.push('\n');
    out
}

/// Admin-plane thread: a readiness-driven accept loop (the listener is
/// nonblocking and waited on with `poll(2)`, [`ADMIN_POLL`] as the
/// watchdog-tick backstop) that hands each scrape to a short-lived
/// writer thread, interleaved with watchdog drift ticks.
///
/// The loop runs until [`Shared::admin_stop`], which flips only after
/// every serving thread joined — so a scrape racing a drain is still
/// answered, and the last exposition reflects the fully-drained stats.
///
/// Scrapes are answered off-thread with both read *and* write timeouts
/// ([`Timeouts`]) applied to the connection: the exposition can exceed a
/// socket send buffer, so an accepted-but-stalled scraper that never
/// reads would otherwise block `write_all` on the admin thread itself —
/// pinning watchdog ticks and every later scrape behind one bad client.
fn admin_loop(shared: &Arc<Shared>, listener: TcpListener) {
    if listener.set_nonblocking(true).is_err() {
        return; // cannot check the stop flag without nonblocking accepts
    }
    let mut dog = obs::watchdog::Watchdog::new();
    let mut last_tick = Instant::now();
    let mut last_energy = 0u64;
    let mut last_served = 0u64;
    let mut last_rebaseline = obs::counter("obs.rebaseline").get();
    while !shared.admin_stop.load(Ordering::Acquire) {
        // sleep until a scrape is ready (or the tick backstop): readiness,
        // not a sleep-and-retry spin, decides when accept runs
        event_loop::sys::wait_readable(&listener, ADMIN_POLL);
        match listener.accept() {
            Ok((mut s, _)) => {
                let _ = s.set_read_timeout(Some(shared.timeouts.read_tick));
                let _ = s.set_write_timeout(Some(shared.timeouts.write_timeout));
                let body = render_exposition(shared);
                let _ = std::thread::Builder::new()
                    .name("admin-scrape".to_string())
                    .spawn(move || {
                        // a stalled peer costs this thread its write
                        // timeout, never the admin loop
                        let _ = s.write_all(body.as_bytes());
                        // drop closes the socket: the scraper reads to EOF
                    });
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {}
            Err(_) => {}
        }
        if last_tick.elapsed() >= WATCHDOG_TICK {
            last_tick = Instant::now();
            // a moved rebaseline marker means the serving pool changed
            // shape (quarantine, reinstall, cluster re-shard): drop the
            // drift baselines — they describe the old pool — and
            // un-latch `degraded` so recovery is observable
            let rebaseline = obs::counter("obs.rebaseline").get();
            if rebaseline != last_rebaseline {
                last_rebaseline = rebaseline;
                dog.rebaseline();
                shared.watchdog_degraded.store(false, Ordering::Release);
            }
            // energy-per-inference over the tick window (not cumulative,
            // so a drift shows up at the tick it happens, undiluted by
            // history); 0 on idle ticks, which the watchdog ignores
            let served = shared.stats.lock().unwrap().served;
            let energy = obs::counter("ledger.energy_pj").get();
            let d_served = served.saturating_sub(last_served);
            let epi = if d_served > 0 {
                energy.saturating_sub(last_energy) as f64 / d_served as f64
            } else {
                0.0
            };
            if d_served > 0 {
                last_served = served;
                last_energy = energy;
            }
            let p99 = shared.latency.percentile(0.99);
            if dog.tick(p99 as f64, epi) {
                shared.watchdog_degraded.store(true, Ordering::Release);
            }
        }
    }
}
